//! External-process simulators (§2.2): run *any executable* as the
//! simulator — here a small shell script that "simulates" a damped
//! oscillator, writes `_results.txt` in its scratch directory, and exits.
//!
//! Demonstrates the full contract: parameters as argv, per-task temp
//! directory, `_results.txt` parsed and returned to the search engine —
//! and a grid search driving it.
//!
//! Usage: cargo run --release --example external_sim -- [--np 4]

use std::io::Write;
use std::sync::Arc;

use caravan::config::SchedulerConfig;
use caravan::engine::Session;
use caravan::extproc::CommandExecutor;
use caravan::tasklib::Payload;
use caravan::util::cli::Args;

fn main() {
    let args = Args::parse();
    let np = args.get_usize("np", 4);

    // Write the "user simulator": any language works; the framework only
    // sees argv in and _results.txt out.
    let dir = std::env::temp_dir().join(format!("caravan_extsim_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let sim = dir.join("oscillator.sh");
    {
        let mut f = std::fs::File::create(&sim).unwrap();
        f.write_all(
            br#"#!/bin/sh
# usage: oscillator.sh <omega> <damping> -- writes _results.txt in $PWD
omega="$1"; zeta="$2"
awk -v w="$omega" -v z="$zeta" 'BEGIN {
  x = 1.0; v = 0.0; dt = 0.01; peak = 0.0; energy = 0.0;
  for (i = 0; i < 2000; i++) {
    a = -2*z*w*v - w*w*x;
    v += a*dt; x += v*dt;
    if (x > peak) peak = x;
    energy += (v*v + w*w*x*x)*dt;
  }
  printf "%.6f %.6f %.6f\n", x, peak, energy > "_results.txt"
}'
"#,
        )
        .unwrap();
    }
    let mut perms = std::fs::metadata(&sim).unwrap().permissions();
    use std::os::unix::fs::PermissionsExt;
    perms.set_mode(0o755);
    std::fs::set_permissions(&sim, perms).unwrap();

    let cfg = SchedulerConfig { np, consumers_per_buffer: 4, flush_interval_ms: 2, ..Default::default() };
    let executor = Arc::new(CommandExecutor::new(dir.join("work")));
    let session = Session::start(cfg, executor);

    println!("# grid sweep over (omega, damping) via the external simulator");
    println!("{:>7} {:>7} {:>12} {:>12} {:>12}", "omega", "zeta", "x_final", "x_peak", "energy");
    let mut handles = Vec::new();
    let mut points = Vec::new();
    for wi in 1..=4 {
        for zi in 0..4 {
            let omega = wi as f64;
            let zeta = zi as f64 * 0.15;
            points.push((omega, zeta));
            handles.push(session.create_task(Payload::Command {
                cmdline: format!("{} {omega} {zeta}", sim.display()),
            }));
        }
    }
    let results = session.await_all(&handles);
    for ((omega, zeta), r) in points.iter().zip(&results) {
        assert!(r.ok(), "simulator failed: rc={}", r.rc);
        assert_eq!(r.results.len(), 3, "expected 3 values in _results.txt");
        println!(
            "{omega:>7.2} {zeta:>7.2} {:>12.6} {:>12.6} {:>12.6}",
            r.results[0], r.results[1], r.results[2]
        );
    }
    let report = session.shutdown();
    println!(
        "# {} external runs, filling rate {:.1}%",
        report.results.len(),
        report.rate(np) * 100.0
    );
    let _ = std::fs::remove_dir_all(&dir);
}
