//! Quickstart — the §2.3 API patterns, in Rust.
//!
//! Mirrors the three example programs of the paper:
//!   1. a minimal batch of ten parallel tasks,
//!   2. callbacks: each completion spawns a follow-up task,
//!   3. async/await: three concurrent activities of five sequential tasks,
//! plus the Job API v2 extras: priorities, cancellation and status.
//!
//! Run: `cargo run --release --example quickstart`
//! (uses time-compressed dummy tasks: one virtual second = 2 ms.)

use std::sync::Arc;

use caravan::api::JobSpec;
use caravan::config::SchedulerConfig;
use caravan::engine::Session;
use caravan::scheduler::SleepExecutor;
use caravan::tasklib::Payload;

fn main() {
    let time_scale = 0.002;
    let cfg = SchedulerConfig {
        np: 8,
        consumers_per_buffer: 4,
        flush_interval_ms: 2,
        time_scale,
        ..Default::default()
    };
    let session = Arc::new(Session::start(cfg, Arc::new(SleepExecutor { time_scale })));

    // --- 1. Ten parallel tasks -------------------------------------------
    println!("== ten parallel tasks ==");
    let tasks: Vec<_> = (0..10)
        .map(|i| session.create_task(Payload::Sleep { seconds: (i % 3 + 1) as f64 }))
        .collect();
    for (i, r) in session.await_all(&tasks).iter().enumerate() {
        println!("task {i}: consumer={} duration={:.3}s rc={}", r.consumer, r.duration(), r.rc);
    }

    // --- 2. Callbacks ----------------------------------------------------
    println!("== callbacks: 10 tasks, each spawning one follow-up ==");
    let firsts: Vec<_> = (0..10)
        .map(|i| {
            session.create_task_with_callback(
                Payload::Sleep { seconds: (i % 3 + 1) as f64 },
                Box::new(move |r, h| {
                    println!("  callback for task {} (finished at {:.3}s) -> spawning one more", r.id, r.finish);
                    h.create_task(Payload::Sleep { seconds: 1.0 });
                }),
            )
        })
        .collect();
    session.await_all(&firsts);

    // --- 3. Concurrent activities of sequential tasks --------------------
    println!("== three concurrent activities x five sequential tasks ==");
    let mut activities = Vec::new();
    for n in 0..3u64 {
        let s = Arc::clone(&session);
        activities.push(std::thread::spawn(move || {
            for t in 0..5u64 {
                let task = s.create_task(Payload::Sleep { seconds: ((t + n) % 3 + 1) as f64 });
                let r = s.await_task(&task);
                println!("  activity {n} step {t}: [{:.2}, {:.2}] on consumer {}", r.begin, r.finish, r.consumer);
            }
        }));
    }
    for a in activities {
        a.join().unwrap();
    }

    // --- 4. Job API v2: priority + cancellation ---------------------------
    println!("== v2: a prioritized job and a cancelled one ==");
    // Occupy every consumer so the cancellation target is still queued.
    let blockers: Vec<_> = (0..8).map(|_| session.submit(JobSpec::sleep(5.0))).collect();
    let urgent = session.submit(JobSpec::sleep(1.0).priority(9).tag("urgent"));
    let doomed = session.submit(JobSpec::sleep(30.0));
    session.cancel(&doomed);
    session.await_all(&blockers);
    let r = session.await_task(&urgent);
    println!("  urgent: rc={} attempt={}", r.rc, r.attempt);
    let r = session.await_task(&doomed);
    println!("  doomed: cancelled={} (status {:?})", r.cancelled(), session.status(&doomed));

    let report = session.shutdown();
    println!(
        "== done: {} tasks, filling rate {:.1}% (np=8), wall {:.2}s ==",
        report.results.len(),
        report.rate(8) * 100.0,
        report.wall_secs
    );
}
