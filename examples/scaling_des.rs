//! Fig. 3 reproduction driver: job filling rates of the three §3 test
//! cases at K-computer scale, via the virtual-time DES of the scheduler
//! protocol.
//!
//! Usage:
//!   cargo run --release --example scaling_des -- \
//!       [--np 256,1024,4096,16384] [--tasks-per-proc 100] [--seed 7] [--direct]

use caravan::des::{run_des, DesConfig, SleepDurations};
use caravan::util::cli::Args;
use caravan::workload::{TestCase, TestCaseEngine};

fn main() {
    let args = Args::parse();
    let nps = args.get_list_usize("np", &[256, 1024, 4096, 16384]);
    let per_proc = args.get_usize("tasks-per-proc", 100);
    let seed = args.get_u64("seed", 7);
    let direct = args.has_flag("direct");

    println!(
        "# CARAVAN Fig.3 (DES): filling rate r [%], N = {per_proc}*Np tasks{}",
        if direct { ", NAIVE single-master mode" } else { "" }
    );
    println!("{:>8} {:>10} {:>8} {:>8} {:>8} {:>12}", "Np", "N", "TC1", "TC2", "TC3", "events");
    for &np in &nps {
        let n = per_proc * np;
        let mut rates = Vec::new();
        let mut events = 0;
        for case in [TestCase::TC1, TestCase::TC2, TestCase::TC3] {
            let mut cfg = DesConfig::new(np);
            cfg.direct = direct;
            let t0 = std::time::Instant::now();
            let r = run_des(
                &cfg,
                Box::new(TestCaseEngine::new(case, n, seed)),
                Box::new(SleepDurations),
            );
            let wall = t0.elapsed().as_secs_f64();
            assert_eq!(r.results.len(), n, "lost tasks!");
            rates.push(r.rate(np) * 100.0);
            events += r.events_processed;
            caravan::debugln!("np={np} {case:?}: makespan {:.0}s wall {wall:.2}s", r.makespan);
        }
        println!(
            "{:>8} {:>10} {:>7.2}% {:>7.2}% {:>7.2}% {:>12}",
            np, n, rates[0], rates[1], rates[2], events
        );
    }
    println!("# paper: all three test cases stay near 100% up to Np=16384");
}
