//! MCMC parameter-space exploration (§1/§2.1 use case): Metropolis
//! sampling of evacuation plans weighted by `exp(-f1/T)` — chains
//! concentrate on fast-evacuating plans, mapping the "good" region of the
//! plan space rather than a single optimum.
//!
//! Usage:
//!   cargo run --release --example mcmc_explore -- \
//!       [--walkers 6] [--steps 80] [--temp 3.0] [--np 6] [--backend rust|pjrt]

use std::sync::Arc;

use caravan::config::SchedulerConfig;
use caravan::engine::{McmcConfig, McmcEngine};
use caravan::evac::{build_scenario, EvacEvaluator, RustSimBackend, ScenarioParams, SimBackend};
use caravan::runtime::PjrtServer;
use caravan::scheduler::run_scheduler;
use caravan::util::cli::Args;
use caravan::util::stats::Summary;

fn main() {
    let args = Args::parse();
    let sc = Arc::new(build_scenario(&ScenarioParams::tiny(), 1));
    let backend: Arc<dyn SimBackend> = match args.get_str("backend", "rust") {
        "pjrt" => Arc::new(
            PjrtServer::start("artifacts".into(), "tiny", sc.sim_arrays())
                .expect("run `make artifacts` first"),
        ),
        _ => Arc::new(RustSimBackend::for_scenario(&sc)),
    };
    let evaluator = Arc::new(EvacEvaluator::new(Arc::clone(&sc), backend));

    let mut cfg = McmcConfig::new(evaluator.bounds());
    cfg.walkers = args.get_usize("walkers", 6);
    cfg.steps = args.get_usize("steps", 80);
    cfg.temperature = args.get_f64("temp", 3.0);
    cfg.step_frac = 0.08;
    cfg.seed = args.get_u64("seed", 0);
    println!(
        "MCMC over {}-dim plan space: {} walkers × {} steps, T={}",
        evaluator.bounds().len(),
        cfg.walkers,
        cfg.steps,
        cfg.temperature
    );

    let (engine, outcome) = McmcEngine::new(cfg.clone());
    let sched = SchedulerConfig {
        np: args.get_usize("np", 6),
        consumers_per_buffer: 8,
        flush_interval_ms: 2,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let report = run_scheduler(&sched, Box::new(engine), evaluator);
    let wall = t0.elapsed().as_secs_f64();

    let out = outcome.lock().unwrap();
    println!(
        "{} evaluations in {:.1}s, acceptance rate {:.1}%, filling {:.1}%",
        report.results.len(),
        wall,
        out.acceptance_rate() * 100.0,
        report.rate(sched.np) * 100.0
    );
    for (w, values) in out.values.iter().enumerate() {
        let head = Summary::of(&values[..values.len().min(10)]);
        let tail = Summary::of(&values[values.len() / 2..]);
        println!(
            "walker {w}: f1 start mean {:.1} min → equilibrium mean {:.1} min (min {:.1})",
            head.mean, tail.mean, tail.min
        );
    }
    // Pooled posterior summary of f1 over the second half of each chain.
    let pooled: Vec<f64> = out
        .values
        .iter()
        .flat_map(|v| v[v.len() / 2..].to_vec())
        .collect();
    println!("pooled equilibrium f1: {}", Summary::of(&pooled));
}
