//! **End-to-end driver** (§4 of the paper): multi-objective optimization of
//! evacuation plans with asynchronous NSGA-II, evaluated by the
//! AOT-compiled JAX/Pallas pedestrian simulator through the PJRT runtime,
//! scheduled by the hierarchical CARAVAN scheduler.
//!
//! Reproduces the *shape* of Fig. 5: pairwise scatter/correlations of the
//! three objectives (f1 evacuation time, f2 plan complexity, f3 excess
//! evacuees) on the final archive — all pairwise Pearson correlations come
//! out negative (trade-offs), as in the paper.
//!
//! Usage:
//!   cargo run --release --example evacuation_opt -- \
//!       [--variant tiny|mini] [--backend pjrt|rust] [--gens 12]
//!       [--pini 48] [--pn 24] [--runs 2] [--np 8] [--seed 0] [--snapshot]
//!
//! Default is a few-minute run on the tiny scenario; `--variant mini`
//! uses the yodogawa-mini city (4 096 agents, ~1 300 links).

use std::sync::Arc;

use caravan::config::SchedulerConfig;
use caravan::engine::{MoeaConfig, Nsga2Engine};
use caravan::evac::{
    build_scenario, init_agents, EvacEvaluator, PlanCodec, RustSimBackend, ScenarioParams,
    SimBackend,
};
use caravan::runtime::PjrtServer;
use caravan::scheduler::run_scheduler;
use caravan::util::cli::Args;
use caravan::util::stats::{pearson, Histogram};

fn main() {
    let args = Args::parse();
    let variant = args.get_str("variant", "tiny").to_string();
    let backend_kind = args.get_str("backend", "pjrt").to_string();
    let seed = args.get_u64("seed", 0);

    let params = match variant.as_str() {
        "tiny" => ScenarioParams::tiny(),
        "mini" => ScenarioParams::yodogawa_mini(),
        other => panic!("unknown variant {other:?} (tiny|mini)"),
    };
    let sc = Arc::new(build_scenario(&params, 1));
    println!(
        "scenario {variant}: {} nodes, {} links, {} shelters, {} sub-areas, {} agents ({}k persons)",
        sc.net.n_nodes(),
        sc.net.n_links(),
        sc.shelters.len(),
        sc.subareas.len(),
        sc.n_agents,
        (sc.total_population() / 1000.0).round()
    );

    let backend: Arc<dyn SimBackend> = match backend_kind.as_str() {
        "pjrt" => Arc::new(
            PjrtServer::start("artifacts".into(), &variant, sc.sim_arrays())
                .expect("run `make artifacts` first"),
        ),
        "rust" => Arc::new(RustSimBackend::for_scenario(&sc)),
        other => panic!("unknown backend {other:?} (pjrt|rust)"),
    };
    println!("backend: {}", backend.name());
    let evaluator = Arc::new(EvacEvaluator::new(Arc::clone(&sc), backend));

    // Scaled-down §4.2 parameters (paper: Pini=1000, Pn=500, 40 gens, 5 runs).
    let mut moea = MoeaConfig::paper_defaults(evaluator.bounds());
    moea.p_ini = args.get_usize("pini", 48);
    moea.p_n = args.get_usize("pn", 24);
    moea.p_archive = moea.p_ini;
    moea.generations = args.get_usize("gens", 12);
    moea.n_runs = args.get_usize("runs", 2);
    moea.seed = seed;
    let total_evals = (moea.p_ini + moea.p_n * (moea.generations - 1)) * moea.n_runs;
    println!(
        "NSGA-II (async): Pini={} Pn={} Parchive={} gens={} runs/ind={} (~{} simulator runs)",
        moea.p_ini, moea.p_n, moea.p_archive, moea.generations, moea.n_runs, total_evals
    );

    let (engine, outcome) = Nsga2Engine::new(moea);
    let cfg = SchedulerConfig {
        np: args.get_usize("np", 8),
        consumers_per_buffer: 384,
        flush_interval_ms: 2,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let report = run_scheduler(&cfg, Box::new(engine), Arc::clone(&evaluator) as _);
    let wall = t0.elapsed().as_secs_f64();

    let out = outcome.lock().unwrap();
    println!(
        "\ncompleted {} simulator runs in {:.1}s ({:.1} runs/s), {} generations, filling rate {:.1}%",
        report.results.len(),
        wall,
        report.results.len() as f64 / wall,
        out.generations_done,
        report.rate(cfg.np) * 100.0
    );

    // ---- Fig. 5 analogue: archive objective statistics -----------------
    let f: [Vec<f64>; 3] = [
        out.archive.iter().map(|i| i.objectives[0]).collect(),
        out.archive.iter().map(|i| i.objectives[1]).collect(),
        out.archive.iter().map(|i| i.objectives[2]).collect(),
    ];
    let names = ["f1 evac-time[min]", "f2 complexity", "f3 excess[persons]"];
    println!("\narchive: {} non-dominated solutions", out.archive.len());
    for (k, name) in names.iter().enumerate() {
        let h = Histogram::from_data(&f[k], 24);
        println!(
            "  {name:>20}: min={:8.2} max={:8.2}  {}",
            f[k].iter().cloned().fold(f64::INFINITY, f64::min),
            f[k].iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            h.sparkline()
        );
    }
    println!("\npairwise Pearson correlations (paper Fig. 5: all negative):");
    for a in 0..3 {
        for b in (a + 1)..3 {
            println!("  corr({}, {}) = {:+.3}", names[a], names[b], pearson(&f[a], &f[b]));
        }
    }

    // ---- Fig. 4 analogue: agents-on-links snapshot ----------------------
    if args.has_flag("snapshot") {
        let codec = PlanCodec::for_scenario(&sc);
        let best = out
            .archive
            .iter()
            .min_by(|x, y| x.objectives[0].partial_cmp(&y.objectives[0]).unwrap())
            .expect("non-empty archive");
        let plan = codec.decode(&best.point);
        let st = init_agents(&sc, &plan, 0);
        println!("\nsnapshot (t=0) of the fastest plan: agent counts per occupied link");
        let mut counts = std::collections::BTreeMap::new();
        for &l in &st.link {
            *counts.entry(l).or_insert(0usize) += 1;
        }
        for (l, c) in counts.iter().take(30) {
            if (*l as usize) < sc.net.n_links() {
                let link = sc.net.links[*l as usize];
                println!("  link {:4} ({:3}→{:3}, {:5.0}m): {c} agents", l, link.from, link.to, link.length);
            }
        }
    }

    println!("\nconvergence (archive-mean objectives per generation):");
    for (g, mean) in out.history.iter().enumerate() {
        println!(
            "  gen {:3}: f1={:8.2} f2={:7.3} f3={:9.1}",
            g + 1,
            mean[0],
            mean[1],
            mean[2]
        );
    }
}
