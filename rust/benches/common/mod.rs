//! Shared harness for the benchmark binaries (`cargo bench`).
//!
//! criterion is not available offline, so each bench is a `harness = false`
//! binary printing the paper-style table it regenerates. This module
//! provides timing + table helpers so the benches stay declarative.
#![allow(dead_code)] // each bench binary uses a subset of these helpers

use std::time::Instant;

pub struct Timed<T> {
    pub value: T,
    pub wall_secs: f64,
}

pub fn timed<T>(f: impl FnOnce() -> T) -> Timed<T> {
    let t0 = Instant::now();
    let value = f();
    Timed { value, wall_secs: t0.elapsed().as_secs_f64() }
}

/// Print a markdown-ish header for a regenerated paper artifact.
pub fn banner(artifact: &str, detail: &str) {
    println!("\n## {artifact}");
    println!("# {detail}");
}
