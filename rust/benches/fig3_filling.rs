//! **Fig. 3** regeneration: job filling rate for TC1/TC2/TC3 at
//! N_p ∈ {256, 1024, 4096, 16384}, N = 100·N_p, via the virtual-time DES
//! of the scheduler protocol (same state machines as the real runtime).
//!
//! Paper result: all three test cases stay close to 100 % up to 16 384
//! MPI processes, TC2/TC3 slightly below TC1.

mod common;

use caravan::des::{run_des, DesConfig, SleepDurations};
use caravan::workload::{TestCase, TestCaseEngine};
use common::{banner, timed};

fn main() {
    banner(
        "Fig. 3 — job filling rate vs N_p (DES, N = 100·N_p)",
        "TC1: U[20,30]s | TC2: power-law −2 on [5,100]s | TC3: TC2 + dynamic task creation",
    );
    println!(
        "{:>8} {:>10} | {:>8} {:>8} {:>8} | {:>10} {:>9}",
        "Np", "N", "TC1 r%", "TC2 r%", "TC3 r%", "des-events", "bench-s"
    );
    for &np in &[256usize, 1024, 4096, 16384] {
        let n = 100 * np;
        let mut rates = Vec::new();
        let mut events = 0u64;
        let run = timed(|| {
            for (k, case) in [TestCase::TC1, TestCase::TC2, TestCase::TC3].into_iter().enumerate() {
                let cfg = DesConfig::new(np);
                let r = run_des(
                    &cfg,
                    Box::new(TestCaseEngine::new(case, n, 7 + k as u64)),
                    Box::new(SleepDurations),
                );
                assert_eq!(r.results.len(), n);
                assert_eq!(r.filling.overlap_violations(), 0);
                rates.push(r.rate(np) * 100.0);
                events += r.events_processed;
            }
        });
        println!(
            "{:>8} {:>10} | {:>7.2}% {:>7.2}% {:>7.2}% | {:>10} {:>9.2}",
            np, n, rates[0], rates[1], rates[2], events, run.wall_secs
        );
    }
    println!("# paper (Fig. 3): r stays near optimum (~100%) for all cases up to Np=16384");
}
