//! §4.4 headline scheduling claim: the real application — asynchronous
//! NSGA-II with 105 000 simulation runs of 30–50 min on 5 120 cores —
//! achieved a **93 % job filling rate**.
//!
//! Reproduced on the DES with the exact application shape: Pini=1000,
//! Pn=500, Parchive=1000, 40 generations, 5 runs per individual
//! (= 1000 + 500·39 individuals → 102 500–105 000 runs), durations
//! U[30, 50] minutes, N_p = 5120.

mod common;

use caravan::des::{run_des, DesConfig, DurationModel};
use caravan::engine::{MoeaConfig, Nsga2Engine};
use caravan::tasklib::{Payload, TaskSpec};
use caravan::util::rng::Pcg64;
use common::{banner, timed};

struct AppModel {
    rng: Pcg64,
}

impl DurationModel for AppModel {
    fn duration(&mut self, t: &TaskSpec) -> f64 {
        // §4.4: "elapsed time ranged from 30 to 50 minutes depending on the
        // simulation parameters" — duration is a function of the
        // *individual* (all five seeded runs take nearly the same time),
        // plus a small seed-level jitter.
        if let Payload::Eval { input, .. } = &t.payload {
            let mut h = 0xA5A5_5A5Au64;
            for x in input {
                h ^= x.to_bits().rotate_left(13);
                crate::splitmix(&mut h);
            }
            let u = (h >> 11) as f64 / (1u64 << 53) as f64;
            let jitter = self.rng.range_f64(-30.0, 30.0);
            (30.0 * 60.0 + u * 20.0 * 60.0 + jitter).max(60.0)
        } else {
            self.rng.range_f64(30.0 * 60.0, 50.0 * 60.0)
        }
    }
    fn results(&mut self, t: &TaskSpec) -> Vec<f64> {
        match &t.payload {
            Payload::Eval { input, seed } => {
                // Plausible objective surrogate; optimization trajectory is
                // irrelevant to the *scheduling* claim being reproduced.
                let n = input.len() as f64;
                let f1 = input.iter().sum::<f64>() / n + (*seed % 5) as f64 * 1e-3;
                let f2 = input.iter().map(|x| x * (1.0 - x)).sum::<f64>() / n;
                let f3 = input.iter().map(|x| (x - 0.3).abs()).sum::<f64>() / n;
                vec![f1, f2, f3]
            }
            _ => vec![],
        }
    }
}

/// splitmix64 helper shared with the duration hash.
pub fn splitmix(state: &mut u64) -> u64 {
    caravan::util::rng::splitmix64(state)
}

fn main() {
    banner(
        "§4.4 — application job filling rate (paper: 93% on 5120 cores, 105k runs)",
        "async NSGA-II Pini=1000 ×40 gens ×5 seeds, parameter-dependent durations 30–50min, DES Np=5120",
    );
    println!(
        "{:>6} {:>10} {:>12} {:>12} {:>14} {:>10} {:>9}",
        "Pn", "runs", "gens", "r%", "makespan[h]", "events", "bench-s"
    );
    // The in-flight pool oscillates between (Pini−Pn)·runs and Pini·runs:
    // the update granularity Pn sets how close the machine stays to full.
    // Paper ran Pn=500 and reported 93%; the sweep shows the framework
    // reaches that level — the residual gap at Pn=500 is the generation
    // wave, not scheduler overhead.
    let np = 5120;
    for &pn in &[500usize, 250, 100] {
        let mut cfg = MoeaConfig::paper_defaults(vec![(0.0, 1.0); 24]);
        cfg.p_n = pn;
        cfg.generations = 40 * 500 / pn; // same total ≈ 102.5k runs
        cfg.seed = 4;
        let (engine, outcome) = Nsga2Engine::new(cfg);
        let des = DesConfig::new(np);
        let run =
            timed(|| run_des(&des, Box::new(engine), Box::new(AppModel { rng: Pcg64::new(2) })));
        let r = run.value;
        let out = outcome.lock().unwrap();
        println!(
            "{:>6} {:>10} {:>12} {:>11.2}% {:>14.2} {:>10} {:>9.1}",
            pn,
            r.results.len(),
            out.generations_done,
            r.rate(np) * 100.0,
            r.makespan / 3600.0,
            r.events_processed,
            run.wall_secs
        );
    }
    println!("# paper: 93% filling with 105,000 runs of 30–50 min on 640 nodes / 5,120 cores");
}
