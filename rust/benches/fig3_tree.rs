//! **Fig. 3 extension** — job filling rate of the N-level buffer tree at
//! scales the flat two-level layout cannot sustain, via the virtual-time
//! DES of the scheduler protocol (same state machines as the real runtime).
//!
//! Sweeps tree depth ∈ {1, 2, 3} at 16 384 simulated consumers (the
//! paper's K-computer ceiling) and runs a depth-3 tree at 10⁵ consumers,
//! reporting the per-level filling rate (mean/min subtree rate) and the
//! producer's message load. The claim under test: stacking relay levels
//! bounds rank 0's fan-in, so the filling rate holds as N_p grows, and
//! sibling work stealing tightens the min-subtree rate under the
//! heavy-tailed TC2 durations.

mod common;

use caravan::des::{run_des, DesConfig, SleepDurations};
use caravan::scheduler::NodeStats;
use caravan::util::cli::Args;
use caravan::workload::{TestCase, TestCaseEngine};
use common::{banner, timed};

/// Aggregate the per-node counters level by level: `NodeStats` rows are
/// the raw observability surface, this is the digest the table prints.
fn node_stats_by_level(stats: &[NodeStats]) -> Vec<String> {
    let max_level = stats.iter().map(|s| s.level).max().unwrap_or(0);
    (1..=max_level)
        .map(|level| {
            let rows: Vec<&NodeStats> = stats.iter().filter(|s| s.level == level).collect();
            let msgs: u64 = rows.iter().map(|s| s.msgs_in + s.msgs_out).sum();
            let queue_frac = rows
                .iter()
                .map(|s| s.max_queue as f64 / s.credit_bound.max(1) as f64)
                .fold(0.0f64, f64::max);
            let steals: u64 = rows.iter().map(|s| s.steals_received).sum();
            let retried: u64 = rows.iter().map(|s| s.retried).sum();
            format!(
                "L{}×{}: msg {} q/cred {:.0}% stolen {} retried {}",
                level,
                rows.len(),
                msgs,
                queue_frac * 100.0,
                steals,
                retried
            )
        })
        .collect()
}

fn run_point(np: usize, depth: usize, steal: bool, tasks_per_proc: usize) {
    let n = tasks_per_proc * np;
    let mut cfg = DesConfig::new(np);
    cfg.sched.depth = depth;
    cfg.sched.fanout = 8;
    cfg.sched.steal = steal;
    let run = timed(|| {
        run_des(
            &cfg,
            Box::new(TestCaseEngine::new(TestCase::TC2, n, 7 + depth as u64)),
            Box::new(SleepDurations),
        )
    });
    let r = run.value;
    assert_eq!(r.results.len(), n, "task conservation");
    assert_eq!(r.filling.overlap_violations(), 0);
    for s in &r.node_stats {
        assert!(s.max_queue <= s.credit_bound, "credit bound violated at node {}", s.node);
        assert!(s.saw_shutdown, "shutdown missed node {}", s.node);
    }
    let levels: Vec<String> = r
        .level_fill
        .iter()
        .map(|l| {
            format!(
                "L{}×{}: {:.1}/{:.1}%",
                l.level,
                l.n_nodes,
                l.mean_rate * 100.0,
                l.min_rate * 100.0
            )
        })
        .collect();
    println!(
        "{:>7} {:>6} {:>6} {:>9} | {:>7.2}% | {:>9} {:>7} {:>8.2} | {}",
        np,
        depth,
        if steal { "yes" } else { "no" },
        n,
        r.rate(np) * 100.0,
        r.producer_msgs_in + r.producer_msgs_out,
        r.tasks_stolen(),
        run.wall_secs,
        levels.join("  ")
    );
    println!("        node-stats: {}", node_stats_by_level(&r.node_stats).join("  "));
}

fn main() {
    let args = Args::parse();
    banner(
        "Fig. 3 extension — filling rate vs buffer-tree depth (DES, TC2)",
        "per-level fill = mean/min subtree rate; prod-msgs = rank 0 messages in+out",
    );
    println!(
        "{:>7} {:>6} {:>6} {:>9} | {:>8} | {:>9} {:>7} {:>8} | per-level fill",
        "Np", "depth", "steal", "N", "fill", "prod-msg", "stolen", "bench-s"
    );
    if args.has_flag("quick") {
        // CI smoke config: same depth sweep and assertions (conservation,
        // credit bounds, shutdown), tiny scale so protocol regressions
        // surface in seconds.
        // 1024 consumers = 3 leaf buffers of 384, so depth ≥ 2 still
        // exercises real relay nodes.
        let np = args.get_usize("np", 1024);
        let tpp = args.get_usize("tasks-per-proc", 5);
        for depth in 1..=3usize {
            run_point(np, depth, false, tpp);
        }
        run_point(np, 3, true, tpp);
        println!("# quick smoke config (--quick): protocol invariants asserted at tiny scale.");
        return;
    }
    // The paper's ceiling: depth sweep at 16 384 consumers, 43 leaf buffers.
    for depth in 1..=3usize {
        run_point(16_384, depth, false, 25);
    }
    // Stealing tightens the per-leaf minimum under the heavy tail.
    run_point(16_384, 3, true, 25);
    // Beyond the paper: 10⁵ consumers only make sense with a deep tree —
    // rank 0 now talks to ⌈261/8/8⌉ = 5 children instead of 261 buffers.
    run_point(100_000, 3, true, 20);
    println!("# claim: depth ≥ 2 holds filling near the flat-layout optimum while");
    println!("# cutting rank 0 fan-in; stealing lifts the min-subtree rate.");
}
