//! **Fig. 3 extension** — job filling rate of the N-level buffer tree at
//! scales the flat two-level layout cannot sustain, via the virtual-time
//! DES of the scheduler protocol (same state machines as the real runtime).
//!
//! Sweeps tree depth ∈ {1, 2, 3} at 16 384 simulated consumers (the
//! paper's K-computer ceiling) and at 10⁵ consumers, reporting the
//! per-level filling rate (mean/min subtree rate) and the producer's
//! message load, plus an **auto** row (`TreeShape::Auto`) next to every
//! manual sweep: the adaptive controller must land within 5 % filling of
//! the best manually-swept depth — asserted here, at 10⁵ consumers, on
//! every run. A `batch_compare` section records the batched-vs-unbatched
//! hot path (Issue 10): in the full config, batched dispatch + coalesced
//! ascent must simulate ≥ 2× the unbatched tasks/sec at 10⁵ consumers.
//!
//! The table is a tracked artifact (`rust/BENCH_fig3.json`, regenerated
//! with `--json BENCH_fig3.json` / `make fig3-artifact`); CI runs the
//! `--quick` config with `--check-schema BENCH_fig3.json` and fails on
//! schema drift, so the committed artifact cannot rot as the bench
//! evolves. The DES is deterministic in virtual time, so regenerated
//! metric values are exactly reproducible per configuration.

mod common;

use caravan::config::{fanout_label, TreeShape};
use caravan::des::{run_des, DesConfig, SleepDurations};
use caravan::scheduler::NodeStats;
use caravan::util::cli::Args;
use caravan::util::json::Json;
use caravan::workload::{TestCase, TestCaseEngine};
use common::{banner, timed};

/// Aggregate the per-node counters level by level: `NodeStats` rows are
/// the raw observability surface, this is the digest the table prints.
fn node_stats_by_level(stats: &[NodeStats]) -> Vec<String> {
    let max_level = stats.iter().map(|s| s.level).max().unwrap_or(0);
    (1..=max_level)
        .map(|level| {
            let rows: Vec<&NodeStats> = stats.iter().filter(|s| s.level == level).collect();
            let msgs: u64 = rows.iter().map(|s| s.msgs_in + s.msgs_out).sum();
            let queue_frac = rows
                .iter()
                .map(|s| s.max_queue as f64 / s.credit_bound.max(1) as f64)
                .fold(0.0f64, f64::max);
            let steals: u64 = rows.iter().map(|s| s.steals_received).sum();
            let retried: u64 = rows.iter().map(|s| s.retried).sum();
            let lag_max = rows.iter().map(|s| s.req_lag_max).fold(0.0f64, f64::max);
            format!(
                "L{}×{}: msg {} q/cred {:.0}% stolen {} retried {} lag≤{:.1}ms",
                level,
                rows.len(),
                msgs,
                queue_frac * 100.0,
                steals,
                retried,
                lag_max * 1e3
            )
        })
        .collect()
}

/// One sweep point. `depth = None` runs `TreeShape::Auto` (the controller
/// picks depth and fanout from its calibration phase). Returns the
/// filling rate and pushes the JSON row for the tracked artifact.
fn run_point(
    np: usize,
    depth: Option<usize>,
    steal: bool,
    tasks_per_proc: usize,
    rows: &mut Vec<Json>,
) -> f64 {
    let n = tasks_per_proc * np;
    let mut cfg = DesConfig::new(np);
    cfg.sched.fanout = vec![8];
    cfg.sched.steal = steal;
    match depth {
        Some(d) => cfg.sched.depth = d,
        None => cfg.sched.shape = TreeShape::Auto,
    }
    // One seed for every row of a sweep: the auto-within-5%-of-best
    // assertion must compare identical TC2 workload realizations, so the
    // only variable across rows is the tree shape itself.
    let run = timed(|| {
        run_des(
            &cfg,
            Box::new(TestCaseEngine::new(TestCase::TC2, n, 7)),
            Box::new(SleepDurations),
        )
    });
    let r = run.value;
    assert_eq!(r.results.len(), n, "task conservation");
    assert_eq!(r.filling.overlap_violations(), 0);
    for s in &r.node_stats {
        assert!(s.max_queue <= s.credit_bound, "credit bound violated at node {}", s.node);
        assert!(s.saw_shutdown, "shutdown missed node {}", s.node);
        let hist_total: u64 = s.wait_hist.iter().map(|h| h.total()).sum();
        assert_eq!(hist_total, s.popped, "wait histogram drifted from pops at node {}", s.node);
    }
    let rate = r.rate(np);
    // Throughput over the virtual makespan — the schema's guard against a
    // run that conserves tasks but crawls. Null (not NaN — the artifact
    // must stay valid JSON) if the makespan degenerates.
    let tasks_per_sec = n as f64 / r.makespan;
    let levels: Vec<String> = r
        .level_fill
        .iter()
        .map(|l| {
            format!(
                "L{}×{}: {:.1}/{:.1}%",
                l.level,
                l.n_nodes,
                l.mean_rate * 100.0,
                l.min_rate * 100.0
            )
        })
        .collect();
    println!(
        "{:>7} {:>6} {:>6} {:>6} {:>9} | {:>7.2}% {:>9.0} | {:>9} {:>7} {:>8.2} | {}",
        np,
        depth.map_or_else(|| format!("auto:{}", r.depth), |d| d.to_string()),
        fanout_label(&r.fanout),
        if steal { "yes" } else { "no" },
        n,
        rate * 100.0,
        tasks_per_sec,
        r.producer_msgs_in + r.producer_msgs_out,
        r.tasks_stolen(),
        run.wall_secs,
        levels.join("  ")
    );
    println!("        node-stats: {}", node_stats_by_level(&r.node_stats).join("  "));
    let level_rows: Vec<Json> = r
        .level_fill
        .iter()
        .map(|l| {
            Json::obj(vec![
                ("level", Json::Num(l.level as f64)),
                ("nodes", Json::Num(l.n_nodes as f64)),
                ("mean_fill", Json::Num(l.mean_rate)),
                ("min_fill", Json::Num(l.min_rate)),
            ])
        })
        .collect();
    let max_req_lag = r.node_stats.iter().map(|s| s.req_lag_max).fold(0.0f64, f64::max);
    rows.push(Json::obj(vec![
        ("np", Json::Num(np as f64)),
        ("auto", Json::Bool(depth.is_none())),
        ("depth", Json::Num(r.depth as f64)),
        // Per-level plan since v5 ("6x8" = narrow root, wide leaves).
        ("fanout", Json::Str(fanout_label(&r.fanout))),
        ("steal", Json::Bool(steal)),
        ("n_tasks", Json::Num(n as f64)),
        ("fill", Json::Num(rate)),
        (
            "tasks_per_sec",
            if tasks_per_sec.is_finite() { Json::Num(tasks_per_sec) } else { Json::Null },
        ),
        ("prod_msgs", Json::Num((r.producer_msgs_in + r.producer_msgs_out) as f64)),
        ("stolen", Json::Num(r.tasks_stolen() as f64)),
        ("max_req_lag_s", Json::Num(max_req_lag)),
        ("levels", Json::Arr(level_rows)),
    ]));
    rate
}

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// **Batched-vs-unbatched hot path** (Issue 10 tentpole proof). Runs the
/// identical TC1 workload realization twice: once on the pre-batching
/// protocol (`dispatch_batch = 1`, one ascent send per event) and once
/// on the batched hot path (`RunBatch` dispatch + coalesced `Flush`
/// ascent). The DES pays one event per protocol message and one per
/// dispatch — exactly the per-task framework overhead the paper's Fig. 3
/// is about — so wall-clock simulation throughput (tasks simulated per
/// bench second) measures what batching removes. Virtual-time metrics
/// barely move, and that is the point: batching is transport, not
/// scheduling (the DES equivalence test in `tree_protocol.rs` proves
/// outcomes are bit-identical).
///
/// In the full config this asserts the acceptance bound: batched ≥ 2×
/// unbatched tasks/sec at 10⁵ consumers.
fn batch_compare(np: usize, tpp: usize, full: bool) -> Json {
    let n = np * tpp;
    let point = |label: &str, batch: usize, coalesce: bool, flush_every: usize| {
        let mut cfg = DesConfig::new(np);
        cfg.sched.depth = 2;
        cfg.sched.fanout = vec![8];
        cfg.sched.dispatch_batch = batch;
        cfg.sched.coalesce_flush = coalesce;
        cfg.sched.flush_every = flush_every;
        let run = timed(|| {
            run_des(
                &cfg,
                Box::new(TestCaseEngine::new(TestCase::TC1, n, 7)),
                Box::new(SleepDurations),
            )
        });
        let r = run.value;
        assert_eq!(r.results.len(), n, "{label}: task conservation");
        assert_eq!(r.filling.overlap_violations(), 0, "{label}");
        let tasks_per_sec = n as f64 / run.wall_secs;
        let batches: u64 = r.node_stats.iter().map(|s| s.dispatch_batches).sum();
        let coalesced: u64 = r.node_stats.iter().map(|s| s.coalesced_flushes).sum();
        let msgs = r.producer_msgs_in + r.producer_msgs_out;
        println!(
            "batch-compare {label:>9}: {n} tasks in {:.2}s wall = {:.0} tasks/s \
             (prod-msgs {msgs}, batches {batches}, coalesced {coalesced}, fill {:.2}%)",
            run.wall_secs,
            tasks_per_sec,
            r.rate(np) * 100.0
        );
        let row = Json::obj(vec![
            ("dispatch_batch", Json::Num(batch as f64)),
            ("coalesce_flush", Json::Bool(coalesce)),
            ("flush_every", Json::Num(flush_every as f64)),
            ("tasks_per_sec", num_or_null(tasks_per_sec)),
            ("prod_msgs", Json::Num(msgs as f64)),
            ("dispatch_batches", Json::Num(batches as f64)),
            ("coalesced_flushes", Json::Num(coalesced as f64)),
            ("fill", Json::Num(r.rate(np))),
        ]);
        (tasks_per_sec, row)
    };
    let (unbatched_tps, unbatched) = point("unbatched", 1, false, 1);
    let (batched_tps, batched) = point("batched", 8, true, 16);
    let speedup = batched_tps / unbatched_tps;
    println!("batch-compare speedup: {speedup:.2}x (batched over unbatched, wall-clock)");
    if full {
        assert!(
            speedup >= 2.0,
            "acceptance: batched hot path must be >= 2x unbatched tasks/sec \
             at np={np} (measured {speedup:.2}x)"
        );
    }
    Json::obj(vec![
        ("np", Json::Num(np as f64)),
        ("n_tasks", Json::Num(n as f64)),
        ("workload", Json::Str("TC1".into())),
        ("unbatched", unbatched),
        ("batched", batched),
        ("speedup", num_or_null(speedup)),
    ])
}

/// Depth sweep + auto row at one scale; asserts the acceptance bound:
/// auto within 5 % filling of the best manual depth.
fn sweep(np: usize, tpp: usize, steal_row: bool, rows: &mut Vec<Json>) {
    let mut best = f64::NEG_INFINITY;
    for depth in 1..=3usize {
        best = best.max(run_point(np, Some(depth), false, tpp, rows));
    }
    if steal_row {
        best = best.max(run_point(np, Some(3), true, tpp, rows));
    }
    let auto = run_point(np, None, steal_row, tpp, rows);
    assert!(
        auto >= best - 0.05,
        "np={np}: auto filling {auto:.4} more than 5% below best manual {best:.4}"
    );
}

/// Every key path in a JSON value, arrays represented by their first
/// element — the structural schema the CI drift check compares.
fn schema_keys(v: &Json, prefix: &str, out: &mut std::collections::BTreeSet<String>) {
    match v {
        Json::Obj(m) => {
            for (k, val) in m {
                let p =
                    if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                out.insert(p.clone());
                schema_keys(val, &p, out);
            }
        }
        Json::Arr(a) => {
            if let Some(first) = a.first() {
                schema_keys(first, &format!("{prefix}[]"), out);
            }
        }
        _ => {}
    }
}

fn table_json(rows: Vec<Json>, batch: Json, config: &str) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("fig3_tree".into())),
        // v2: rows gained `tasks_per_sec` (throughput over virtual makespan).
        // v3: top-level `batch_compare` (batched vs unbatched hot path).
        ("schema_version", Json::Num(3.0)),
        ("config", Json::Str(config.into())),
        ("workload", Json::Str("TC2".into())),
        ("generated_by", Json::Str("cargo bench --bench fig3_tree -- --json".into())),
        ("rows", Json::Arr(rows)),
        ("batch_compare", batch),
    ])
}

/// Collect the paths of every `null` in the artifact. A regenerated
/// table is fully populated — `Json::Null` only appears when a metric
/// degenerated (or in the null-seeded placeholder a toolchain-less seed
/// commits, which marks itself with a `generated_by` starting
/// "PENDING").
fn null_paths(v: &Json, prefix: &str, out: &mut Vec<String>) {
    match v {
        Json::Obj(m) => {
            for (k, val) in m {
                let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                null_paths(val, &p, out);
            }
        }
        Json::Arr(a) => {
            for (i, val) in a.iter().enumerate() {
                null_paths(val, &format!("{prefix}[{i}]"), out);
            }
        }
        Json::Null => out.push(if prefix.is_empty() { "<root>".into() } else { prefix.into() }),
        _ => {}
    }
}

/// Fail (exit 2) when the committed artifact's schema drifted from the
/// freshly generated table's. Values are free to differ — `--json`
/// regenerates them — but a row-format change without regenerating the
/// tracked artifact is an error.
fn check_schema(committed_path: &str, fresh: &Json) {
    let body = std::fs::read_to_string(committed_path).unwrap_or_else(|e| {
        eprintln!("--check-schema: cannot read {committed_path}: {e}");
        std::process::exit(2);
    });
    let committed = Json::parse(&body).unwrap_or_else(|e| {
        eprintln!("--check-schema: {committed_path} is not valid JSON: {e}");
        std::process::exit(2);
    });
    let mut want = std::collections::BTreeSet::new();
    let mut got = std::collections::BTreeSet::new();
    schema_keys(fresh, "", &mut want);
    schema_keys(&committed, "", &mut got);
    // Per-class decompositions are tolerated, not required: a
    // multi-tenant sweep may add `classes` subtrees to its rows without
    // invalidating a single-tenant artifact, and vice versa.
    let tolerated =
        |p: &String| p.split('.').any(|seg| seg == "classes" || seg == "classes[]");
    want.retain(|p| !tolerated(p));
    got.retain(|p| !tolerated(p));
    if want != got {
        eprintln!("--check-schema: {committed_path} drifted from the bench row format;");
        for missing in want.difference(&got) {
            eprintln!("  missing in artifact: {missing}");
        }
        for stale in got.difference(&want) {
            eprintln!("  stale in artifact:   {stale}");
        }
        eprintln!("  regenerate with: cargo bench --bench fig3_tree -- --json {committed_path}");
        std::process::exit(2);
    }
    // Null tightening (Issue 10): once the artifact has been generated
    // for real, it may never regress to placeholder nulls. The one
    // sanctioned exception is the explicitly self-declared PENDING seed
    // table, which exists only until the first `make fig3-artifact` run.
    let pending = matches!(
        committed.get("generated_by"),
        Some(Json::Str(s)) if s.starts_with("PENDING")
    );
    let mut nulls = Vec::new();
    null_paths(&committed, "", &mut nulls);
    if !nulls.is_empty() {
        if pending {
            println!(
                "# schema check: {committed_path} is the self-declared PENDING placeholder \
                 ({} null metrics tolerated until the first `make fig3-artifact` run)",
                nulls.len()
            );
        } else {
            eprintln!(
                "--check-schema: {committed_path} has {} null metric value(s); \
                 a generated artifact must be fully populated:",
                nulls.len()
            );
            for p in nulls.iter().take(8) {
                eprintln!("  null at {p}");
            }
            eprintln!("  regenerate with: make fig3-artifact");
            std::process::exit(2);
        }
    }
    println!("# schema check OK: {committed_path} matches the current row format");
}

fn main() {
    let args = Args::parse();
    banner(
        "Fig. 3 extension — filling rate vs buffer-tree depth (DES, TC2)",
        "per-level fill = mean/min subtree rate; prod-msgs = rank 0 messages in+out",
    );
    println!(
        "{:>7} {:>6} {:>6} {:>6} {:>9} | {:>8} {:>9} | {:>9} {:>7} {:>8} | per-level fill",
        "Np", "depth", "fanout", "steal", "N", "fill", "tasks/s", "prod-msg", "stolen", "bench-s"
    );
    let mut rows: Vec<Json> = Vec::new();
    let quick = args.has_flag("quick");
    if quick {
        // CI smoke config: same depth sweep, auto row and assertions
        // (conservation, credit bounds, shutdown, wait-histogram
        // conservation, auto-within-5%), tiny scale so protocol
        // regressions surface in seconds.
        // 1024 consumers = 3 leaf buffers of 384, so depth ≥ 2 still
        // exercises real relay nodes.
        let np = args.get_usize("np", 1024);
        let tpp = args.get_usize("tasks-per-proc", 5);
        sweep(np, tpp, true, &mut rows);
        println!("# quick smoke config (--quick): protocol invariants asserted at tiny scale.");
    } else {
        // The paper's ceiling: depth sweep at 16 384 consumers, 43 leaf
        // buffers; stealing tightens the per-leaf minimum under the heavy
        // tail; auto must match the best manual shape without a knob.
        sweep(16_384, 25, true, &mut rows);
        // Beyond the paper: 10⁵ consumers. Rank 0 talks to ⌈261/8/8⌉ = 5
        // children at depth 3 instead of 261 buffers; the acceptance
        // criterion (auto within 5% of the best manual sweep) is asserted
        // here at full scale.
        sweep(100_000, 20, true, &mut rows);
        println!("# claim: depth ≥ 2 holds filling near the flat-layout optimum while");
        println!("# cutting rank 0 fan-in; stealing lifts the min-subtree rate; auto");
        println!("# converges to the best manual shape with no user knob.");
    }
    // Batched-vs-unbatched hot path: tiny in the smoke config, the
    // acceptance scale (10⁵ consumers, ≥ 2× asserted) in the full run.
    let batch = if quick {
        batch_compare(args.get_usize("np", 1024), args.get_usize("tasks-per-proc", 5), false)
    } else {
        batch_compare(100_000, 20, true)
    };
    let table = table_json(rows, batch, if quick { "quick" } else { "full" });
    if let Some(path) = args.get_opt("json") {
        std::fs::write(path, format!("{table}\n")).unwrap_or_else(|e| {
            eprintln!("--json: cannot write {path}: {e}");
            std::process::exit(2);
        });
        println!("# wrote {path}");
    }
    if let Some(committed) = args.get_opt("check-schema") {
        check_schema(committed, &table);
    }
}
