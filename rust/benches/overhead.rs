//! §3 task-granularity claim: "CARAVAN does not perform quite well for
//! tasks that are complete in less than a few seconds" — because each task
//! pays temp-dir + process-spawn + result-parsing overhead.
//!
//! Measures, on the *real* threaded scheduler:
//!   1. per-task overhead of the external-process path (§2.2 contract);
//!   2. per-task cost of the in-process PJRT evaluation path;
//!   3. raw scheduler overhead (zero-duration dummy tasks → tasks/s);
//!   4. filling rate vs task duration for the external path, showing the
//!      efficiency knee at second-scale tasks.

mod common;

use std::sync::Arc;

use caravan::api::JobSink;
use caravan::config::SchedulerConfig;
use caravan::extproc::CommandExecutor;
use caravan::scheduler::{run_scheduler, SleepExecutor};
use caravan::tasklib::{Payload, SearchEngine, TaskResult, TaskSink};
use common::{banner, timed};

struct Cmds {
    n: usize,
    cmd: String,
}

impl SearchEngine for Cmds {
    fn start(&mut self, sink: &mut dyn JobSink) {
        for _ in 0..self.n {
            sink.submit(Payload::Command { cmdline: self.cmd.clone() });
        }
    }
    fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
}

struct Sleeps {
    n: usize,
    secs: f64,
}

impl SearchEngine for Sleeps {
    fn start(&mut self, sink: &mut dyn JobSink) {
        for _ in 0..self.n {
            sink.submit(Payload::Sleep { seconds: self.secs });
        }
    }
    fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
}

fn main() {
    banner(
        "§3 — per-task overhead and the fine-grained-task knee",
        "real threaded scheduler, np=4 (1 physical core host)",
    );
    let np = 4;
    let cfg = SchedulerConfig { np, consumers_per_buffer: 4, flush_interval_ms: 2, ..Default::default() };
    let work = std::env::temp_dir().join(format!("caravan_bench_{}", std::process::id()));

    // 1. external-process path: /bin/true in a fresh dir per task.
    let n = 200;
    let run = timed(|| {
        run_scheduler(
            &cfg,
            Box::new(Cmds { n, cmd: "/bin/sh -c 'echo 1 > _results.txt'".into() }),
            Arc::new(CommandExecutor::new(&work)),
        )
    });
    assert_eq!(run.value.results.len(), n);
    let per_task_ext = run.wall_secs / n as f64 * np as f64;
    println!("external-process task overhead : {:>9.2} ms/task (spawn+tmpdir+parse)", per_task_ext * 1e3);

    // 2. zero-duration dummy tasks: framework-only overhead.
    let n = 20_000;
    let run = timed(|| {
        run_scheduler(
            &cfg,
            Box::new(Sleeps { n, secs: 0.0 }),
            Arc::new(SleepExecutor { time_scale: 1.0 }),
        )
    });
    assert_eq!(run.value.results.len(), n);
    println!(
        "scheduler-only throughput      : {:>9.0} tasks/s ({:.1} µs/task framework cost)",
        n as f64 / run.wall_secs,
        run.wall_secs / n as f64 * 1e6
    );
    // Per-node / per-level observability of the threaded runtime — the
    // same NodeStats + LevelFill surface the DES benches report.
    for lf in &run.value.level_fill {
        println!(
            "  level {}: {} node(s), fill mean {:>5.1}% min {:>5.1}%",
            lf.level,
            lf.n_nodes,
            lf.mean_rate * 100.0,
            lf.min_rate * 100.0
        );
    }
    for s in &run.value.node_stats {
        // Wait-histogram digest: total pops and the share answered within
        // the first bucket (sub-millisecond queue wait).
        let hist_total: u64 = s.wait_hist.iter().map(|h| h.total()).sum();
        let fast: u64 = s.wait_hist.iter().map(|h| h.counts[0]).sum();
        println!(
            "  node {:>2} (L{}): msgs {:>7}/{:<7} max-queue {:>5}/{:<5} steals {}/{} retried {} cancelled {}+{} popped {} (<1ms {:.0}%) req-lag {:.2}/{:.2}ms",
            s.node,
            s.level,
            s.msgs_in,
            s.msgs_out,
            s.max_queue,
            s.credit_bound,
            s.steals_received,
            s.steals_given,
            s.retried,
            s.cancelled_dropped,
            s.cancelled_killed,
            s.popped,
            if hist_total == 0 { 0.0 } else { fast as f64 / hist_total as f64 * 100.0 },
            s.req_lag_mean * 1e3,
            s.req_lag_max * 1e3
        );
    }

    // 2b. adaptive shaping on the real runtime: the calibration phase
    // (channel round-trip probe + two inline task executions) runs before
    // the tree is built; the row reports what the controller picked.
    let n = 2_000;
    let mut auto_cfg = cfg.clone();
    auto_cfg.shape = caravan::config::TreeShape::Auto;
    let run = timed(|| {
        run_scheduler(
            &auto_cfg,
            Box::new(Sleeps { n, secs: 0.0 }),
            Arc::new(SleepExecutor { time_scale: 1.0 }),
        )
    });
    assert_eq!(run.value.results.len(), n);
    println!(
        "auto tree shaping (threaded)   : depth {} fanout {} chosen by calibration, {:>6.0} tasks/s",
        run.value.depth,
        caravan::config::fanout_label(&run.value.fanout),
        n as f64 / run.wall_secs
    );

    // 3. efficiency knee vs task duration (external path): the paper's
    // granularity claim. Efficiency = useful simulated seconds / consumer
    // seconds — the filling rate r counts spawn overhead as busy, so the
    // *useful* efficiency is the telling number for fine-grained tasks.
    println!("\n# efficiency vs task duration (external-process path, 64 tasks)");
    println!("{:>14} {:>12} {:>12} {:>32}", "task dur", "filling r%", "useful eff%", "note");
    for &ms in &[5u64, 20, 100, 500, 2000] {
        let n = 64;
        let run = timed(|| {
            run_scheduler(
                &cfg,
                Box::new(Cmds {
                    n,
                    cmd: format!("/bin/sh -c 'sleep {}; echo 1 > _results.txt'", ms as f64 / 1000.0),
                }),
                Arc::new(CommandExecutor::new(&work)),
            )
        });
        let r = run.value.rate(np) * 100.0;
        let useful = n as f64 * ms as f64 / 1000.0;
        let eff = useful / (run.value.filling.makespan() * np as f64) * 100.0;
        let note = if ms < 1000 { "sub-second: overhead-dominated" } else { "overhead amortized" };
        println!("{:>11} ms {:>11.1}% {:>11.1}% {:>32}", ms, r, eff, note);
    }
    println!("# paper: \"does not perform quite well for tasks < a few seconds\" — the");
    println!("# knee above shows why; second-scale+ tasks amortize the per-task cost.");
    let _ = std::fs::remove_dir_all(&work);
}
