//! §3 motivation ablation: the buffered layer vs a naive single master.
//!
//! "Without the buffered layer, the producer process must communicate with
//! thousands or more consumer processes, which causes technical problems
//! and the entire process cannot be completed normally."
//!
//! The DES models the producer as a serial server (50 µs/message): with
//! short tasks and many consumers the naive design saturates (filling rate
//! collapses, producer lag explodes) while the 1:384 buffered hierarchy
//! keeps the master's message rate low. Sweeps N_p and task duration.

mod common;

use caravan::api::JobSink;
use caravan::des::{run_des, DesConfig, SleepDurations};
use caravan::tasklib::{Payload, SearchEngine, TaskResult, TaskSink};
use common::banner;

struct FixedTasks {
    n: usize,
    secs: f64,
}

impl SearchEngine for FixedTasks {
    fn start(&mut self, sink: &mut dyn JobSink) {
        for _ in 0..self.n {
            sink.submit(Payload::Sleep { seconds: self.secs });
        }
    }
    fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
}

fn run(np: usize, n: usize, secs: f64, direct: bool) -> (f64, f64, u64) {
    let mut cfg = DesConfig::new(np);
    cfg.direct = direct;
    let r = run_des(&cfg, Box::new(FixedTasks { n, secs }), Box::new(SleepDurations));
    assert_eq!(r.results.len(), n);
    (r.rate(np) * 100.0, r.max_producer_lag, r.producer_msgs_in + r.producer_msgs_out)
}

fn main() {
    banner(
        "§3 ablation — buffered layer (1:384) vs naive single master",
        "20 tasks/consumer; producer service 50 µs/message; filling rate r% and peak producer lag",
    );
    println!(
        "{:>8} {:>8} | {:>10} {:>12} {:>11} | {:>10} {:>12} {:>11}",
        "Np", "task[s]", "buf r%", "buf lag[s]", "buf msgs", "naive r%", "naive lag[s]", "naive msgs"
    );
    for &(np, secs) in &[
        (1024usize, 2.0),
        (4096, 2.0),
        (16384, 2.0),
        (16384, 0.5),
        (16384, 8.0),
    ] {
        let n = np * 20;
        let (rb, lb, mb) = run(np, n, secs, false);
        let (rd, ld, md) = run(np, n, secs, true);
        println!(
            "{:>8} {:>8.1} | {:>9.2}% {:>12.4} {:>11} | {:>9.2}% {:>12.2} {:>11}",
            np, secs, rb, lb, mb, rd, ld, md
        );
    }
    println!("# expected: naive collapses once Np/duration exceeds the master's msg rate;");
    println!("# buffered stays near 100% with orders-of-magnitude fewer producer messages.");

    // ---- buffer-ratio sweep: why the paper defaults to 1:384 ------------
    banner(
        "§3 — consumers-per-buffer sweep (paper default 1:384)",
        "Np=16384, 0.5 s tasks, 20/consumer; few buffers → buffers saturate; \
         too many → producer traffic grows back toward the naive case",
    );
    println!("{:>12} {:>9} {:>10} {:>12} {:>12}", "cons/buffer", "buffers", "r%", "prod msgs", "max lag[s]");
    for &ratio in &[64usize, 128, 384, 1024, 4096, 16384] {
        let np = 16384;
        let n = np * 20;
        let mut cfg = DesConfig::new(np);
        cfg.sched.consumers_per_buffer = ratio;
        let r = run_des(&cfg, Box::new(FixedTasks { n, secs: 0.5 }), Box::new(SleepDurations));
        assert_eq!(r.results.len(), n);
        println!(
            "{:>12} {:>9} {:>9.2}% {:>12} {:>12.4}",
            ratio,
            cfg.sched.num_buffers(),
            r.rate(np) * 100.0,
            r.producer_msgs_in + r.producer_msgs_out,
            r.max_producer_lag
        );
    }
    println!("# paper: \"CARAVAN allocates one buffer process to 384 MPI processes, which");
    println!("# is a good parameter for a wide range of practical use cases.\"");
}
