//! **Fig. 5** regeneration (scaled): trade-offs between the three
//! objectives of the evacuation-planning problem after an asynchronous
//! NSGA-II run — scatter statistics, histograms (diagonal panels) and the
//! pairwise Pearson correlation coefficients (upper-triangle panels).
//!
//! Uses the tiny scenario + rust reference backend so the bench is
//! minutes-fast; `examples/evacuation_opt.rs` runs the same pipeline on
//! the yodogawa-mini scenario through the PJRT-compiled model.

mod common;

use std::sync::Arc;

use caravan::config::SchedulerConfig;
use caravan::engine::{MoeaConfig, Nsga2Engine};
use caravan::evac::{build_scenario, EvacEvaluator, RustSimBackend, ScenarioParams};
use caravan::scheduler::run_scheduler;
use caravan::util::stats::{pearson, Histogram};
use common::{banner, timed};

fn main() {
    banner(
        "Fig. 5 — Pareto-front trade-offs after async NSGA-II (tiny scenario)",
        "paper: negative Pearson correlations between f1/f2/f3 on the archived solutions",
    );
    let sc = Arc::new(build_scenario(&ScenarioParams::tiny(), 1));
    let backend = Arc::new(RustSimBackend::for_scenario(&sc));
    let evaluator = Arc::new(EvacEvaluator::new(Arc::clone(&sc), backend));

    let mut moea = MoeaConfig::paper_defaults(evaluator.bounds());
    moea.p_ini = 96;
    moea.p_n = 48;
    moea.p_archive = 96;
    moea.generations = 25;
    moea.n_runs = 2;
    moea.seed = 11;
    let (engine, outcome) = Nsga2Engine::new(moea);
    let cfg = SchedulerConfig { np: 8, flush_interval_ms: 2, ..Default::default() };
    let run = timed(|| run_scheduler(&cfg, Box::new(engine), Arc::clone(&evaluator) as _));
    let report = run.value;
    let out = outcome.lock().unwrap();

    println!(
        "# {} simulator runs in {:.1}s ({:.0} runs/s), {} generations, archive {}",
        report.results.len(),
        run.wall_secs,
        report.results.len() as f64 / run.wall_secs,
        out.generations_done,
        out.archive.len()
    );
    let f: [Vec<f64>; 3] = [
        out.archive.iter().map(|i| i.objectives[0]).collect(),
        out.archive.iter().map(|i| i.objectives[1]).collect(),
        out.archive.iter().map(|i| i.objectives[2]).collect(),
    ];
    let names = ["f1[min]", "f2[nats]", "f3[persons]"];
    println!("\n# diagonal panels (histograms over the archive):");
    for (k, name) in names.iter().enumerate() {
        let h = Histogram::from_data(&f[k], 24);
        println!(
            "{:>12}  [{:9.2}, {:9.2}]  {}",
            name,
            f[k].iter().cloned().fold(f64::INFINITY, f64::min),
            f[k].iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            h.sparkline()
        );
    }
    println!("\n# upper-triangle panels (Pearson correlation coefficients):");
    println!("{:>14} {:>10} {:>10}", "", "f2", "f3");
    println!(
        "{:>14} {:>+10.3} {:>+10.3}",
        "f1",
        pearson(&f[0], &f[1]),
        pearson(&f[0], &f[2])
    );
    println!("{:>14} {:>10} {:>+10.3}", "f2", "", pearson(&f[1], &f[2]));
    println!("# paper (Fig. 5): corr(f1,f2) < 0, corr(f1,f3) < 0, corr(f2,f3) < 0");
}
