//! §4.2 ablation: asynchronous generation update vs the conventional
//! synchronous NSGA-II barrier.
//!
//! "If we wait for the completion of the calculations for all individuals,
//! a significant amount of CPU resource is wasted because of the serious
//! load imbalance."
//!
//! Both engines run the same toy problem on the DES with heavy-tailed
//! evaluation durations (power-law exponent −2, [5,100] s — §3's TC2
//! distribution) and with the paper's narrow 30–50 min band; the async
//! variant should fill the machine, the sync variant idles at every
//! generation boundary.

mod common;

use caravan::des::{run_des, DesConfig, DurationModel};
use caravan::engine::{MoeaConfig, Nsga2Engine};
use caravan::tasklib::{Payload, TaskSpec};
use caravan::util::rng::Pcg64;
use common::banner;

struct EvalModel {
    rng: Pcg64,
    heavy_tail: bool,
}

impl DurationModel for EvalModel {
    fn duration(&mut self, _t: &TaskSpec) -> f64 {
        if self.heavy_tail {
            self.rng.power_law(5.0, 100.0, -2.0)
        } else {
            self.rng.range_f64(1800.0, 3000.0) // paper: 30–50 min
        }
    }
    fn results(&mut self, t: &TaskSpec) -> Vec<f64> {
        match &t.payload {
            Payload::Eval { input, .. } => {
                let n = input.len() as f64;
                let f1 = input.iter().sum::<f64>() / n;
                let f2 = input.iter().map(|x| (1.0 - x) * (1.0 - x)).sum::<f64>() / n;
                let f3 = input.iter().map(|x| (0.5 - x).abs()).sum::<f64>() / n;
                vec![f1, f2, f3]
            }
            _ => vec![],
        }
    }
}

fn run(np: usize, synchronous: bool, heavy_tail: bool) -> (f64, f64, usize) {
    let mut cfg = MoeaConfig::paper_defaults(vec![(0.0, 1.0); 8]);
    cfg.p_ini = 256;
    cfg.p_n = 128;
    cfg.p_archive = 256;
    cfg.generations = 12;
    cfg.n_runs = 5;
    cfg.synchronous = synchronous;
    cfg.seed = 1;
    let (engine, outcome) = Nsga2Engine::new(cfg);
    let des = DesConfig::new(np);
    let r = run_des(
        &des,
        Box::new(engine),
        Box::new(EvalModel { rng: Pcg64::new(9), heavy_tail }),
    );
    let out = outcome.lock().unwrap();
    (r.rate(np) * 100.0, r.makespan, out.tasks_completed)
}

fn main() {
    banner(
        "§4.2 ablation — asynchronous vs synchronous generation update",
        "NSGA-II Pini=256 Pn=128 ×12 gens ×5 runs/ind on the DES; filling rate and makespan",
    );
    println!(
        "{:>8} {:>22} | {:>9} {:>13} {:>8} | {:>9} {:>13} {:>8} | {:>8}",
        "Np", "eval duration", "async r%", "makespan[s]", "tasks", "sync r%", "makespan[s]", "tasks", "speedup"
    );
    for &(np, heavy) in &[(256usize, true), (1024, true), (256, false), (1024, false)] {
        let (ra, ma, ta) = run(np, false, heavy);
        let (rs, ms, ts) = run(np, true, heavy);
        let label = if heavy { "power-law [5,100]s" } else { "uniform 30-50min" };
        println!(
            "{:>8} {:>22} | {:>8.2}% {:>13.0} {:>8} | {:>8.2}% {:>13.0} {:>8} | {:>7.2}x",
            np, label, ra, ma, ta, rs, ms, ts, ms / ma
        );
    }
    println!("# expected: async keeps consumers busy (high r, shorter makespan);");
    println!("# sync idles at every generation barrier, worst under heavy tails.");
}
