//! Failure-injection and stress tests across the scheduler stack:
//! failing simulators, pathological workloads, degenerate topologies, and
//! larger property sweeps than the unit-level ones.

use std::sync::Arc;

use caravan::config::SchedulerConfig;
use caravan::des::{run_des, ConstResults, DesConfig, SleepDurations};
use caravan::engine::{GridEngine, McmcConfig, McmcEngine, MoeaConfig, Nsga2Engine, Session};
use caravan::extproc::CommandExecutor;
use caravan::api::JobSink;
use caravan::scheduler::{run_scheduler, Executor, SleepExecutor};
use caravan::tasklib::{Payload, SearchEngine, TaskResult, TaskSink, TaskSpec};
use caravan::workload::{TestCase, TestCaseEngine};

fn quick(np: usize) -> SchedulerConfig {
    SchedulerConfig {
        np,
        consumers_per_buffer: 4,
        flush_interval_ms: 2,
        time_scale: 0.001,
        ..Default::default()
    }
}

struct NCommands {
    n: usize,
    cmd: String,
}

impl SearchEngine for NCommands {
    fn start(&mut self, sink: &mut dyn JobSink) {
        for _ in 0..self.n {
            sink.submit(Payload::Command { cmdline: self.cmd.clone() });
        }
    }
    fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
}

#[test]
fn failing_simulator_propagates_rc_without_wedging() {
    // A simulator that always exits 2: the scheduler must complete the
    // workload and report rc=2 on every result, not hang or crash.
    let work = std::env::temp_dir().join(format!("caravan_fail_{}", std::process::id()));
    let report = run_scheduler(
        &quick(4),
        Box::new(NCommands { n: 12, cmd: "sh -c 'exit 2'".into() }),
        Arc::new(CommandExecutor::new(&work)),
    );
    assert_eq!(report.results.len(), 12);
    assert!(report.results.iter().all(|r| r.rc == 2 && r.results.is_empty()));
    let _ = std::fs::remove_dir_all(&work);
}

#[test]
fn mixed_success_failure_and_missing_results_file() {
    // Odd tasks fail, even tasks succeed but write no _results.txt —
    // both are legal per §2.2 (the file is optional).
    struct Mixed(usize);
    impl SearchEngine for Mixed {
        fn start(&mut self, sink: &mut dyn JobSink) {
            for i in 0..self.0 {
                let cmd = if i % 2 == 0 { "sh -c 'true'" } else { "sh -c 'exit 1'" };
                sink.submit(Payload::Command { cmdline: cmd.into() });
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
    }
    let work = std::env::temp_dir().join(format!("caravan_mixed_{}", std::process::id()));
    let report = run_scheduler(
        &quick(3),
        Box::new(Mixed(10)),
        Arc::new(CommandExecutor::new(&work)),
    );
    let ok = report.results.iter().filter(|r| r.ok()).count();
    assert_eq!(ok, 5);
    assert!(report.results.iter().all(|r| r.results.is_empty()));
    let _ = std::fs::remove_dir_all(&work);
}

#[test]
fn moea_survives_failed_evaluations() {
    // An executor that fails 20% of evaluations (empty results, rc=1):
    // the parameter-set averaging must skip them and the optimizer must
    // still finish all generations.
    struct Flaky;
    impl Executor for Flaky {
        fn run(&self, task: &TaskSpec, _c: usize) -> (Vec<f64>, i32) {
            match &task.payload {
                Payload::Eval { input, seed } => {
                    if seed % 5 == 0 {
                        return (vec![], 1); // injected failure
                    }
                    let f1 = input.iter().sum::<f64>() / input.len() as f64;
                    let f2 = input.iter().map(|x| (1.0 - x) * (1.0 - x)).sum::<f64>()
                        / input.len() as f64;
                    (vec![f1, f2], 0)
                }
                _ => (vec![], 1),
            }
        }
    }
    let mut cfg = MoeaConfig::small(vec![(0.0, 1.0); 3]);
    cfg.n_runs = 3; // at least one seed per pset survives
    cfg.generations = 3;
    let (engine, outcome) = Nsga2Engine::new(cfg);
    let report = run_scheduler(&quick(4), Box::new(engine), Arc::new(Flaky));
    assert!(!report.results.is_empty());
    let out = outcome.lock().unwrap();
    assert_eq!(out.generations_done, 3);
    // Archived objectives are finite despite injected failures.
    assert!(out
        .archive
        .iter()
        .all(|i| i.objectives.len() == 2 && i.objectives.iter().all(|o| o.is_finite())));
}

#[test]
fn nsga2_population_with_nan_objectives_completes_generations() {
    // Regression for the NaN-panic class: a simulator that returns NaN
    // objectives for every fourth task used to crash the whole MOEA run
    // in `partial_cmp().unwrap()` (crowding sort / archive truncation).
    // The run must now complete all generations, ranking NaN individuals
    // strictly worst instead of panicking.
    use caravan::des::DurationModel;

    struct SometimesNan(ConstResults);
    impl DurationModel for SometimesNan {
        fn duration(&mut self, t: &TaskSpec) -> f64 {
            self.0.duration(t)
        }
        fn results(&mut self, t: &TaskSpec) -> Vec<f64> {
            let mut r = self.0.results(t);
            if t.id % 4 == 0 {
                if let Some(x) = r.first_mut() {
                    *x = f64::NAN;
                }
            }
            r
        }
    }

    let mut cfg = MoeaConfig::small(vec![(0.0, 1.0); 3]);
    cfg.generations = 3;
    let (engine, outcome) = Nsga2Engine::new(cfg);
    let mut dcfg = DesConfig::new(8);
    dcfg.sched.consumers_per_buffer = 4;
    let r = run_des(
        &dcfg,
        Box::new(engine),
        Box::new(SometimesNan(ConstResults::new(1.0, 3.0, 2, 5))),
    );
    assert!(!r.results.is_empty());
    let out = outcome.lock().unwrap();
    assert_eq!(out.generations_done, 3, "NaN objectives must not stall the MOEA");
    assert!(!out.archive.is_empty());
}

#[test]
fn zero_duration_storm_des() {
    // 100k zero-length tasks: pure overhead — DES must terminate and
    // conserve all tasks.
    struct Zeros(usize);
    impl SearchEngine for Zeros {
        fn start(&mut self, sink: &mut dyn JobSink) {
            for _ in 0..self.0 {
                sink.submit(Payload::Sleep { seconds: 0.0 });
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
    }
    let r = run_des(&DesConfig::new(64), Box::new(Zeros(100_000)), Box::new(SleepDurations));
    assert_eq!(r.results.len(), 100_000);
    assert_eq!(r.filling.overlap_violations(), 0);
}

#[test]
fn single_consumer_single_buffer_degenerate_topology() {
    let mut cfg = DesConfig::new(1);
    cfg.sched.consumers_per_buffer = 1;
    let r = run_des(
        &cfg,
        Box::new(TestCaseEngine::new(TestCase::TC3, 50, 3)),
        Box::new(SleepDurations),
    );
    assert_eq!(r.results.len(), 50);
    // Serial: filling is essentially total-work/makespan ≈ 1 − overheads.
    assert!(r.rate(1) > 0.9, "{}", r.rate(1));
}

#[test]
fn np_not_divisible_by_buffer_ratio() {
    // 1000 consumers / 384 per buffer = 3 buffers of 334/333/333.
    let mut cfg = DesConfig::new(1000);
    cfg.sched.consumers_per_buffer = 384;
    let r = run_des(
        &cfg,
        Box::new(TestCaseEngine::new(TestCase::TC2, 20_000, 5)),
        Box::new(SleepDurations),
    );
    assert_eq!(r.results.len(), 20_000);
    // Heavy tail with only 20 tasks/consumer leaves a visible end tail.
    assert!(r.rate(1000) > 0.75, "{}", r.rate(1000));
    let ranks: std::collections::HashSet<usize> =
        r.results.iter().map(|x| x.consumer).collect();
    assert_eq!(ranks.len(), 1000, "all consumers participated");
}

#[test]
fn grid_engine_on_threaded_scheduler_with_eval_executor() {
    struct Quad;
    impl Executor for Quad {
        fn run(&self, task: &TaskSpec, _c: usize) -> (Vec<f64>, i32) {
            match &task.payload {
                Payload::Eval { input, .. } => {
                    (vec![input.iter().map(|x| x * x).sum::<f64>()], 0)
                }
                _ => (vec![], 1),
            }
        }
    }
    let (engine, outcome) = GridEngine::new(vec![vec![0.0, 1.0, 2.0], vec![0.0, 1.0]], 0);
    let report = run_scheduler(&quick(2), Box::new(engine), Arc::new(Quad));
    assert_eq!(report.results.len(), 6);
    let got = outcome.lock().unwrap();
    for (p, r) in got.iter() {
        let expect: f64 = p.iter().map(|x| x * x).sum();
        assert!((r[0] - expect).abs() < 1e-12);
    }
}

#[test]
fn mcmc_handles_constant_objective() {
    // Flat target density: every proposal accepted; chain must still
    // terminate with the right length.
    struct Flat;
    impl caravan::des::DurationModel for Flat {
        fn duration(&mut self, _t: &TaskSpec) -> f64 {
            1.0
        }
        fn results(&mut self, _t: &TaskSpec) -> Vec<f64> {
            vec![1.0]
        }
    }
    let mut cfg = McmcConfig::new(vec![(0.0, 1.0); 2]);
    cfg.walkers = 2;
    cfg.steps = 30;
    let (engine, outcome) = McmcEngine::new(cfg);
    let r = run_des(&DesConfig::new(2), Box::new(engine), Box::new(Flat));
    assert_eq!(r.results.len(), 2 * 31);
    let out = outcome.lock().unwrap();
    assert!((out.acceptance_rate() - 1.0).abs() < 1e-9);
}

#[test]
fn session_shutdown_with_work_in_flight_completes_it() {
    let s = Session::start(quick(2), Arc::new(SleepExecutor { time_scale: 0.001 }));
    let tasks: Vec<_> = (0..6).map(|_| s.create_task(Payload::Sleep { seconds: 5.0 })).collect();
    // Shut down immediately: in-flight tasks must finish first.
    let report = s.shutdown();
    assert_eq!(report.results.len(), 6);
    let _ = tasks;
}

#[test]
fn des_conserves_tasks_under_random_topologies_property() {
    use caravan::testutil::{check, pair, usize_in};
    check(
        "DES conserves tasks over random (np, ratio) topologies",
        pair(usize_in(1..40), usize_in(1..10)),
        |&(np, ratio)| {
            let mut cfg = DesConfig::new(np);
            cfg.sched.consumers_per_buffer = ratio;
            let n = np * 5;
            let r = run_des(
                &cfg,
                Box::new(TestCaseEngine::new(TestCase::TC3, n, np as u64)),
                Box::new(SleepDurations),
            );
            r.results.len() == n && r.filling.overlap_violations() == 0
        },
    );
}

#[test]
fn killing_a_worker_mid_run_loses_zero_tasks() {
    // Distributed dead-link handling: two remote subtrees serve a root;
    // one takes a grant of tasks and vanishes without running a single
    // one. The root must treat the dead link as a recall that never acks,
    // re-grant every outstanding task to the survivor, and finish with
    // exactly-once completions.
    use std::time::Duration;

    use caravan::scheduler::net::{serve_links, ServeOptions};
    use caravan::scheduler::run_worker;
    use caravan::transport::wire::{WireMsg, PROTO_VERSION};
    use caravan::transport::{ChannelTransport, Transport};

    struct Sleeps(usize);
    impl SearchEngine for Sleeps {
        fn start(&mut self, sink: &mut dyn JobSink) {
            for _ in 0..self.0 {
                sink.submit(Payload::Sleep { seconds: 5.0 });
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
    }

    let (srv_a, cli_a) = ChannelTransport::pair();
    let (srv_b, cli_b) = ChannelTransport::pair();

    let survivor = std::thread::spawn(move || {
        run_worker(Box::new(cli_a), Arc::new(SleepExecutor { time_scale: 0.001 }), 0)
    });
    let victim = std::thread::spawn(move || {
        let mut t: Box<dyn Transport> = Box::new(cli_b);
        t.send(&WireMsg::Hello { version: PROTO_VERSION, requested_np: 0 }).unwrap();
        loop {
            if let WireMsg::Welcome { .. } = t.recv_timeout(Duration::from_secs(10)).unwrap() {
                break;
            }
        }
        t.send(&WireMsg::Request { amount: 8 }).unwrap();
        loop {
            match t.recv_timeout(Duration::from_secs(10)) {
                // The interesting path: take a grant, then crash on it.
                Ok(WireMsg::Assign(tasks)) if !tasks.is_empty() => break,
                // Degenerate race: the survivor drained everything first.
                Ok(WireMsg::Shutdown) | Err(_) => break,
                Ok(_) => {}
            }
        }
        // Drop the transport with those tasks outstanding: a worker crash.
    });

    let n = 60;
    let report = serve_links(
        &quick(8),
        Box::new(Sleeps(n)),
        vec![
            (Box::new(srv_a) as Box<dyn Transport>, "mem:survivor".into()),
            (Box::new(srv_b) as Box<dyn Transport>, "mem:victim".into()),
        ],
        &ServeOptions { workers: 2, liveness: Duration::from_secs(5) },
    )
    .unwrap();
    victim.join().unwrap();
    let wr = survivor.join().unwrap().unwrap();

    assert_eq!(report.results.len(), n, "worker crash must lose zero tasks");
    let mut ids: Vec<u64> = report.results.iter().map(|r| r.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n, "re-granted tasks must complete exactly once");
    assert_eq!(wr.tasks_run, n, "every task ends up on the surviving worker");
}

#[test]
fn eval_results_deterministic_under_retry() {
    // ConstResults must be a pure function of (input, seed) so engines can
    // safely resubmit failed tasks.
    let mut m1 = ConstResults::new(1.0, 2.0, 3, 0);
    let mut m2 = ConstResults::new(1.0, 2.0, 3, 99); // different model seed
    use caravan::des::DurationModel;
    let t = TaskSpec::new(0, Payload::Eval { input: vec![0.25, 0.75], seed: 42 });
    assert_eq!(m1.results(&t), m2.results(&t));
}
