//! Acceptance tests for `caravan check` (Issue 9): the bounded model
//! checker must hold every oracle over the CI-sized state space, and a
//! deliberately seeded protocol bug must be *caught* — with a
//! minimized, replayable counterexample trace — not merely detected.
//!
//! Both the library seam ([`caravan::check`]) and the CLI contract
//! (exit 0 clean / 1 violation / 2 usage) are exercised.

use std::fs;
use std::process::Command;

use caravan::check::{replay_trace_text, run_check, CheckConfig, FaultSet, SeededBug};

fn check_cmd() -> Command {
    Command::new(env!("CARGO_BIN_EXE_caravan"))
}

/// A CI-speed configuration: small task count, a handful of fuzz seeds.
fn small(scenario: &str, faults: FaultSet) -> CheckConfig {
    CheckConfig {
        scenario: scenario.to_string(),
        n_tasks: 2,
        seeds: 8,
        fuzz_steps: 800,
        faults,
        ..CheckConfig::default()
    }
}

#[test]
fn exhaustive_flat2_holds_all_oracles() {
    let cfg = small("flat2", FaultSet { steal: true, cancel: true, recall: true, kill: false });
    let report = run_check(&cfg).expect("valid config");
    assert!(report.passed(), "violation: {:?}", report.counterexample);
    assert!(report.exhausted, "CI bound must drain the state space, not hit the budget");
    assert!(report.states > 0);
    assert_eq!(report.fuzz_schedules, 8, "fuzz phase runs after a clean exhaustive phase");
}

#[test]
fn exhaustive_deep4_with_kill_holds_all_oracles() {
    let cfg = small("deep4", FaultSet { steal: true, cancel: false, recall: true, kill: true });
    let report = run_check(&cfg).expect("valid config");
    assert!(report.passed(), "violation: {:?}", report.counterexample);
    assert!(report.states > 0);
}

#[test]
fn exhaustive_batched2_holds_all_oracles() {
    // The batched hot path (dispatch_batch=2, coalesced Flush ascent)
    // must satisfy the same oracles as the unbatched protocol across
    // every CI-sized interleaving, faults included.
    let cfg = small("batched2", FaultSet { steal: true, cancel: true, recall: true, kill: false });
    let report = run_check(&cfg).expect("valid config");
    assert!(report.passed(), "violation: {:?}", report.counterexample);
    assert!(report.exhausted, "CI bound must drain the state space, not hit the budget");
    assert!(report.states > 0);
}

#[test]
fn seeded_drop_returned_is_caught_minimized_and_replayable() {
    // Arm the exact bug a missing `on_returned` call would be: the
    // producer swallows the first Returned batch. Any schedule with a
    // recall then breaks task conservation.
    let cfg = CheckConfig {
        bug: Some(SeededBug::DropReturned { nth: 1 }),
        ..small("flat2", FaultSet { steal: true, cancel: false, recall: true, kill: false })
    };
    let report = run_check(&cfg).expect("valid config");
    let cex = report.counterexample.as_ref().expect("the seeded bug must be caught");
    assert!(
        cex.events.len() <= cex.original_len,
        "shrinking must never grow the schedule: {} > {}",
        cex.events.len(),
        cex.original_len
    );

    // The emitted artifact must replay to a violation of the same oracle.
    let trace = report.counterexample_trace().expect("trace accompanies the counterexample");
    let replayed = replay_trace_text(&trace).expect("emitted trace must parse");
    let rcex = replayed.counterexample.expect("replay must reproduce the violation");
    assert_eq!(rcex.violation.oracle, cex.violation.oracle, "replay disagrees with the find");
}

#[test]
fn usage_errors_are_reported_not_explored() {
    let bad_tasks = CheckConfig { n_tasks: 0, ..CheckConfig::default() };
    assert!(run_check(&bad_tasks).is_err());
    let bad_scenario = CheckConfig { scenario: "ring9".into(), ..CheckConfig::default() };
    assert!(run_check(&bad_scenario).unwrap_err().contains("unknown scenario"));
    let kill_on_flat = CheckConfig {
        faults: FaultSet { kill: true, ..FaultSet::default() },
        ..CheckConfig::default()
    };
    assert!(run_check(&kill_on_flat).unwrap_err().contains("kill"));
}

#[test]
fn cli_clean_run_exits_zero() {
    let out = check_cmd()
        .args(["check", "--max-tasks", "2", "--seeds", "4", "--fuzz-steps", "500"])
        .output()
        .expect("spawn caravan");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(0), "stdout: {stdout}\nstderr: {:?}", out.stderr);
    assert!(stdout.contains("all oracles held"), "{stdout}");
}

#[test]
fn cli_seeded_bug_exits_one_and_trace_replays_red() {
    let trace_path = std::env::temp_dir().join("caravan-check-cex-test.trace");
    let _ = fs::remove_file(&trace_path);

    let out = check_cmd()
        .args(["check", "--max-tasks", "2", "--faults", "steal,recall"])
        .args(["--inject-bug", "drop-returned:1", "--seeds", "4"])
        .arg("--trace-out")
        .arg(&trace_path)
        .output()
        .expect("spawn caravan");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(1), "{stdout}");
    assert!(stdout.contains("VIOLATION"), "{stdout}");
    assert!(stdout.contains("minimized schedule"), "{stdout}");

    // The written artifact replays through `--replay` to the same red
    // verdict — the counterexample is self-contained.
    let out = check_cmd()
        .arg("check")
        .arg("--replay")
        .arg(&trace_path)
        .output()
        .expect("spawn caravan");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(1), "{stdout}");
    assert!(stdout.contains("VIOLATION"), "{stdout}");

    let _ = fs::remove_file(&trace_path);
}

#[test]
fn cli_usage_errors_exit_two() {
    let out = check_cmd().args(["check", "--faults", "bogus"]).output().expect("spawn caravan");
    assert_eq!(out.status.code(), Some(2), "{out:?}");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown fault"), "{stderr}");

    let out = check_cmd()
        .args(["check", "--scenario", "flat2", "--faults", "kill"])
        .output()
        .expect("spawn caravan");
    assert_eq!(out.status.code(), Some(2), "{out:?}");
}

#[test]
fn cli_replay_accepts_committed_fixtures() {
    for fixture in [
        "steal_cancel_recall_overlap.trace",
        "dead_link_during_recall.trace",
        "batched_dispatch_coalesced_ascent.trace",
    ] {
        let path = format!("{}/tests/fixtures/check/{fixture}", env!("CARGO_MANIFEST_DIR"));
        let out = check_cmd().args(["check", "--replay", &path]).output().expect("spawn caravan");
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert_eq!(out.status.code(), Some(0), "{fixture}: {stdout}");
        assert!(stdout.contains("all oracles held"), "{fixture}: {stdout}");
    }
}
