//! Cross-layer integration tests.
//!
//! The heart of the three-layer validation: the AOT-compiled JAX/Pallas
//! model (L1+L2, loaded via PJRT) must agree with the pure-Rust reference
//! simulator (the canonical semantics) on real scenarios, and the whole
//! stack must run end-to-end through the scheduler.
//!
//! Requires `make artifacts`; when the artifacts are absent (plain
//! `cargo test` from a clean checkout) every PJRT-dependent case *skips*
//! instead of failing — the pure-Rust layers are covered regardless.

use std::sync::Arc;

use caravan::config::SchedulerConfig;
use caravan::engine::{MoeaConfig, Nsga2Engine};
use caravan::evac::{
    build_scenario, init_agents, EvacEvaluator, PlanCodec, RustSimBackend, ScenarioParams,
    SimBackend,
};
use caravan::runtime::{ArtifactMeta, PjrtEvacModel, PjrtServer};
use caravan::scheduler::run_scheduler;
use caravan::util::rng::Pcg64;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("meta.json").exists()
}

/// Skip (not fail) when `artifacts/meta.json` is absent: the compiled
/// JAX/Pallas model is an optional build product, and `cargo test` must be
/// green from a clean checkout.
macro_rules! need_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!(
                "skipping {}: artifacts/ missing — run `make artifacts` to enable",
                module_path!()
            );
            return;
        }
    };
}

#[test]
fn meta_matches_rust_scenario_shapes() {
    need_artifacts!();
    let meta = ArtifactMeta::load(artifacts_dir()).unwrap();
    let sc = build_scenario(&ScenarioParams::tiny(), 1);
    let v = meta.variant("tiny").unwrap();
    assert_eq!(v.a, sc.n_agents);
    assert_eq!(v.l, sc.padded_links());
    assert_eq!(v.n, sc.net.n_nodes());
    assert_eq!(v.s, sc.shelters.len());
    assert_eq!(v.t, sc.params.max_steps);
    // Physics constants must be in lock-step with SimParams::default().
    assert_eq!(meta.physics.dt, sc.params.dt);
    assert_eq!(meta.physics.v_free, sc.params.v_free);
    assert_eq!(meta.physics.rho_jam, sc.params.rho_jam);
}

#[test]
fn pjrt_model_agrees_with_rust_reference() {
    need_artifacts!();
    let sc = Arc::new(build_scenario(&ScenarioParams::tiny(), 1));
    let arrays = sc.sim_arrays();
    let model = PjrtEvacModel::load(artifacts_dir(), "tiny").unwrap();
    let rust = RustSimBackend::for_scenario(&sc);
    let codec = PlanCodec::for_scenario(&sc);
    let mut rng = Pcg64::new(77);

    for trial in 0..5u64 {
        let genome: Vec<f64> =
            codec.bounds().iter().map(|&(lo, hi)| rng.range_f64(lo, hi)).collect();
        let plan = codec.decode(&genome);
        let init = init_agents(&sc, &plan, trial);
        let out_pjrt = model.run(&arrays, &init).unwrap();
        let out_rust = rust.run(init);
        // Discrete outcomes must agree: the two implementations execute
        // the same canonical update in f32. Allow a 1-step / 1-agent slack
        // for FMA-borderline transitions.
        assert!(
            (out_pjrt.remaining as i64 - out_rust.remaining as i64).abs() <= 1,
            "trial {trial}: remaining {} vs {}",
            out_pjrt.remaining,
            out_rust.remaining
        );
        let dt = sc.params.dt as f64;
        assert!(
            (out_pjrt.evac_time - out_rust.evac_time).abs() <= 2.0 * dt + 1e-3,
            "trial {trial}: f1 {} vs {}",
            out_pjrt.evac_time,
            out_rust.evac_time
        );
        // Arrival curves track each other closely.
        let max_diff = out_pjrt
            .arrivals
            .iter()
            .zip(&out_rust.arrivals)
            .map(|(&a, &b)| (a as i64 - b as i64).unsigned_abs())
            .max()
            .unwrap();
        assert!(max_diff <= 2, "trial {trial}: curve diverges by {max_diff}");
    }
}

#[test]
fn evaluator_through_pjrt_backend() {
    need_artifacts!();
    let sc = Arc::new(build_scenario(&ScenarioParams::tiny(), 1));
    let arrays = sc.sim_arrays();
    let backend = Arc::new(PjrtServer::start(artifacts_dir(), "tiny", arrays).unwrap());
    let ev = EvacEvaluator::new(Arc::clone(&sc), backend);
    let genome: Vec<f64> = ev.bounds().iter().map(|&(lo, hi)| 0.5 * (lo + hi)).collect();
    let [f1, f2, f3] = ev.evaluate(&genome, 0);
    assert!(f1.is_finite() && f1 > 0.0);
    assert!(f2 >= 0.0 && f3 >= 0.0);
}

#[test]
fn end_to_end_nsga2_over_pjrt_on_scheduler() {
    // The full stack: NSGA-II engine → hierarchical scheduler (threads) →
    // EvacEvaluator → PJRT-compiled JAX/Pallas model.
    need_artifacts!();
    let sc = Arc::new(build_scenario(&ScenarioParams::tiny(), 1));
    let arrays = sc.sim_arrays();
    let backend = Arc::new(PjrtServer::start(artifacts_dir(), "tiny", arrays).unwrap());
    let ev = Arc::new(EvacEvaluator::new(Arc::clone(&sc), backend));

    let mut moea = MoeaConfig::small(ev.bounds());
    moea.p_ini = 8;
    moea.p_n = 4;
    moea.p_archive = 8;
    moea.generations = 2;
    moea.n_runs = 1;
    let (engine, outcome) = Nsga2Engine::new(moea);
    let cfg = SchedulerConfig { np: 2, consumers_per_buffer: 2, flush_interval_ms: 2, ..Default::default() };
    let report = run_scheduler(&cfg, Box::new(engine), ev);
    assert!(!report.results.is_empty());
    let out = outcome.lock().unwrap();
    assert_eq!(out.generations_done, 2);
    assert!(!out.archive.is_empty());
    for ind in &out.archive {
        assert_eq!(ind.objectives.len(), 3);
        assert!(ind.objectives.iter().all(|o| o.is_finite()));
    }
}

#[test]
fn rust_and_pjrt_backends_rank_plans_identically() {
    // The optimizer only needs consistent *ordering*: check that the two
    // backends agree on which of two contrasting plans evacuates faster.
    need_artifacts!();
    let sc = Arc::new(build_scenario(&ScenarioParams::tiny(), 1));
    let arrays = sc.sim_arrays();
    let pjrt = Arc::new(PjrtServer::start(artifacts_dir(), "tiny", arrays).unwrap());
    let rust = Arc::new(RustSimBackend::for_scenario(&sc));
    let ev_pjrt = EvacEvaluator::new(Arc::clone(&sc), pjrt);
    let ev_rust = EvacEvaluator::new(Arc::clone(&sc), rust);
    let mut rng = Pcg64::new(3);
    let bounds = ev_pjrt.bounds();
    let g1: Vec<f64> = bounds.iter().map(|&(lo, hi)| rng.range_f64(lo, hi)).collect();
    let g2: Vec<f64> = bounds.iter().map(|&(lo, hi)| rng.range_f64(lo, hi)).collect();
    let (a1, a2) = (ev_pjrt.evaluate(&g1, 0)[0], ev_pjrt.evaluate(&g2, 0)[0]);
    let (b1, b2) = (ev_rust.evaluate(&g1, 0)[0], ev_rust.evaluate(&g2, 0)[0]);
    assert_eq!(a1 < a2, b1 < b2, "backends disagree on ranking: {a1},{a2} vs {b1},{b2}");
}
