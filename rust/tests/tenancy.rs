//! Multi-tenant isolation, end to end in the DES.
//!
//! The headline scenario: a *steady* tenant runs closed-loop chains
//! (submit → await → resubmit, an interactive user), while a *bursty*
//! high-weight tenant dumps a large batch at t = 0. Weighted fair-share
//! inside every queue must keep the steady tenant's request→grant waits
//! bounded: its p99 wait under burst stays within a stated factor (≤ 3×)
//! of its solo-run baseline — and the whole schedule is bit-identically
//! reproducible, because the DES and the deficit-round-robin pop rule are
//! both deterministic.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use caravan::api::{job_engine, JobEngine, JobSpec, Jobs};
use caravan::des::{run_des, DesConfig, DesReport, SleepDurations};
use caravan::tasklib::TaskResult;
use caravan::tenancy::JobClass;

const NP: usize = 8;
const CHAINS: usize = 16; // steady closed-loop chains (2× consumers)
const ROUNDS: usize = 8; // tasks per chain
const BURST: usize = 400; // batch dumped by the bursty tenant at t = 0
const TASK_S: f64 = 1.0;

/// Steady closed-loop chains in class 0 plus an optional burst batch in
/// class 1. Chain membership of every steady task id is exported through
/// `track` so the test can reconstruct per-chain request→grant waits.
struct SteadyPlusBurst {
    burst: usize,
    fired: bool,
    done: Vec<usize>,
    track: Arc<Mutex<HashMap<u64, usize>>>,
}

impl JobEngine for SteadyPlusBurst {
    type Ctx = Option<usize>; // Some(chain) for steady tasks

    fn start(&mut self, jobs: &mut Jobs<'_, Option<usize>>) {
        for c in 0..CHAINS {
            let id = jobs.submit(JobSpec::sleep(TASK_S).class(0), Some(c));
            self.track.lock().unwrap().insert(id, c);
        }
    }

    fn on_done(&mut self, _r: &TaskResult, ctx: Option<usize>, jobs: &mut Jobs<'_, Option<usize>>) {
        // The burst lands the moment the steady tenant is warmed up (its
        // first completion), so every steady wait from round 1 on is
        // measured *under* the burst backlog.
        if !self.fired {
            self.fired = true;
            for _ in 0..self.burst {
                jobs.submit(JobSpec::sleep(TASK_S).class(1), None);
            }
        }
        if let Some(chain) = ctx {
            self.done[chain] += 1;
            if self.done[chain] < ROUNDS {
                let id = jobs.submit(JobSpec::sleep(TASK_S).class(0), Some(chain));
                self.track.lock().unwrap().insert(id, chain);
            }
        }
    }
}

/// Two registered classes: the steady tenant at weight 1, the bursty
/// tenant at weight 2 — the burst is *favoured*, so any isolation the
/// steady tenant gets comes from fair-share, not from priority.
fn tenant_cfg() -> DesConfig {
    let mut dcfg = DesConfig::new(NP);
    dcfg.sched.consumers_per_buffer = 4; // 2 leaves
    dcfg.sched.depth = 1;
    dcfg.sched.fanout = vec![2];
    dcfg.sched.classes = vec![JobClass::new("steady", 1), JobClass::new("burst", 2)];
    dcfg
}

fn run_scenario(burst: usize) -> (DesReport, HashMap<u64, usize>) {
    let track = Arc::new(Mutex::new(HashMap::new()));
    let engine =
        SteadyPlusBurst { burst, fired: false, done: vec![0; CHAINS], track: Arc::clone(&track) };
    let r = run_des(&tenant_cfg(), job_engine(engine), Box::new(SleepDurations));
    let map = Arc::try_unwrap(track).expect("engine dropped").into_inner().unwrap();
    (r, map)
}

/// Request→grant wait of every steady task: a chain's next request is
/// issued the moment its previous task finishes, so the wait is
/// `begin(k) − finish(k−1)` within the chain (and `begin − 0` for the
/// chain's first task).
fn steady_waits(r: &DesReport, track: &HashMap<u64, usize>) -> Vec<f64> {
    let mut per_chain: Vec<Vec<(f64, f64)>> = vec![Vec::new(); CHAINS];
    for x in &r.results {
        if let Some(&chain) = track.get(&x.id) {
            per_chain[chain].push((x.begin, x.finish));
        }
    }
    let mut waits = Vec::new();
    for chain in &mut per_chain {
        assert_eq!(chain.len(), ROUNDS, "every chain runs to completion");
        chain.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut prev_finish = 0.0;
        for &(begin, finish) in chain.iter() {
            waits.push(begin - prev_finish);
            prev_finish = finish;
        }
    }
    waits
}

fn p99(mut xs: Vec<f64>) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(f64::total_cmp);
    let idx = ((xs.len() as f64) * 0.99).ceil() as usize;
    xs[idx.clamp(1, xs.len()) - 1]
}

#[test]
fn burst_tenant_cannot_push_steady_p99_beyond_three_times_solo() {
    let (solo, solo_track) = run_scenario(0);
    let (burst, burst_track) = run_scenario(BURST);

    // Conservation first: every task of both tenants completes once.
    assert_eq!(solo.results.len(), CHAINS * ROUNDS);
    assert_eq!(burst.results.len(), CHAINS * ROUNDS + BURST);
    assert!(burst.results.iter().all(|x| x.ok()));

    let p99_solo = p99(steady_waits(&solo, &solo_track));
    let p99_burst = p99(steady_waits(&burst, &burst_track));
    assert!(p99_solo > 0.0, "closed loops over-subscribe the consumers: waits are real");
    assert!(
        p99_burst <= 3.0 * p99_solo,
        "isolation bound violated: steady p99 {p99_burst:.3}s under a {BURST}-task \
         weight-2 burst vs {p99_solo:.3}s solo (allowed ≤ 3×)"
    );

    // The burst really went through the same tree: every node that popped
    // work decomposes its dispatches per class, and the burst lane
    // dominates the counts.
    let (mut steady_pops, mut burst_pops) = (0u64, 0u64);
    for s in &burst.node_stats {
        let per_class: u64 = s.class_stats.iter().map(|c| c.popped).sum();
        assert_eq!(per_class, s.popped, "node {}: class decomposition", s.node);
        for c in &s.class_stats {
            if s.level == 1 {
                match c.class {
                    0 => steady_pops += c.popped,
                    _ => burst_pops += c.popped,
                }
            }
        }
    }
    assert_eq!(steady_pops, (CHAINS * ROUNDS) as u64);
    assert_eq!(burst_pops, BURST as u64);
}

#[test]
fn multi_tenant_scenario_is_bit_identical_across_runs() {
    let (a, _) = run_scenario(BURST);
    let (b, _) = run_scenario(BURST);
    assert_eq!(a.makespan, b.makespan, "virtual makespans must be bit-identical");
    let key = |r: &DesReport| {
        let mut k: Vec<(u64, u64, u64)> = r
            .results
            .iter()
            .map(|x| (x.id, x.begin.to_bits(), x.finish.to_bits()))
            .collect();
        k.sort();
        k
    };
    assert_eq!(key(&a), key(&b), "schedules must be bit-identical");
}
