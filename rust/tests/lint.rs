//! Integration tests for `caravan lint` (Issue 8): fixture snippets per
//! rule (violating / clean / allow-escaped), the CLI exit-code contract,
//! the self-check that the lint passes on the repo's own sources, and
//! the DES determinism property the `hash-iter` rule exists to protect.
//!
//! The fixtures live in `tests/fixtures/lint/*.txt` — a non-`.rs`
//! extension, so the directory walker never scans them and the
//! violations they contain can't fail the self-check. Rule scoping is
//! path-based, so each fixture is linted under a representative label
//! like `src/des/mod.rs`.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::Command;

use caravan::des::{run_des, ConstResults, DesConfig};
use caravan::engine::{GridEngine, McmcConfig, McmcEngine};
use caravan::lint::{lint_paths, lint_source};

// ---------------------------------------------------------------- fixtures

/// Lint a fixture under a path label and return the rule names hit.
fn rules_hit(label: &str, src: &str) -> Vec<&'static str> {
    lint_source(label, src).into_iter().map(|v| v.rule).collect()
}

#[test]
fn float_ord_fixtures() {
    let bad = include_str!("fixtures/lint/float_ord_violation.txt");
    let got = lint_source("src/engine/sweep.rs", bad);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, "float-ord");
    assert_eq!(got[0].line, 3);
    let clean = include_str!("fixtures/lint/float_ord_clean.txt");
    assert!(rules_hit("src/engine/sweep.rs", clean).is_empty());
    let allowed = include_str!("fixtures/lint/float_ord_allowed.txt");
    assert!(rules_hit("src/engine/sweep.rs", allowed).is_empty());
}

#[test]
fn wall_clock_fixtures() {
    let bad = include_str!("fixtures/lint/wall_clock_violation.txt");
    let got = lint_source("src/des/mod.rs", bad);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, "wall-clock");
    // The same source is fine in an allowlisted I/O module.
    assert!(rules_hit("src/scheduler/net.rs", bad).is_empty());
    let clean = include_str!("fixtures/lint/wall_clock_clean.txt");
    assert!(rules_hit("src/des/mod.rs", clean).is_empty());
    let allowed = include_str!("fixtures/lint/wall_clock_allowed.txt");
    assert!(rules_hit("src/des/mod.rs", allowed).is_empty());
}

#[test]
fn hash_iter_fixtures() {
    let bad = include_str!("fixtures/lint/hash_iter_violation.txt");
    let got = lint_source("src/des/mod.rs", bad);
    assert_eq!(got.len(), 3, "one per HashMap token: {got:?}");
    assert!(got.iter().all(|v| v.rule == "hash-iter"));
    // Out of the deterministic-output scope the rule does not run.
    assert!(rules_hit("src/transport/wire.rs", bad).is_empty());
    let clean = include_str!("fixtures/lint/hash_iter_clean.txt");
    assert!(rules_hit("src/des/mod.rs", clean).is_empty());
    let allowed = include_str!("fixtures/lint/hash_iter_allowed.txt");
    assert!(rules_hit("src/des/mod.rs", allowed).is_empty());
}

#[test]
fn unwrap_budget_fixtures() {
    let bad = include_str!("fixtures/lint/unwrap_budget_violation.txt");
    let got = lint_source("src/transport/wire.rs", bad);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, "unwrap-budget");
    // The budget only applies to the panic-free zones.
    assert!(rules_hit("src/engine/sweep.rs", bad).is_empty());
    let clean = include_str!("fixtures/lint/unwrap_budget_clean.txt");
    assert!(rules_hit("src/transport/wire.rs", clean).is_empty());
    let allowed = include_str!("fixtures/lint/unwrap_budget_allowed.txt");
    assert!(rules_hit("src/transport/wire.rs", allowed).is_empty());
}

#[test]
fn panic_path_fixtures() {
    let bad = include_str!("fixtures/lint/panic_path_violation.txt");
    let got = lint_source("src/scheduler/protocol.rs", bad);
    assert_eq!(got.len(), 2, "one for panic!, one for the indexing: {got:?}");
    assert!(got.iter().all(|v| v.rule == "panic-path"), "{got:?}");
    // The rule shares the unwrap-budget scope: transport and tenancy too.
    assert_eq!(rules_hit("src/transport/wire.rs", bad).len(), 2);
    assert_eq!(rules_hit("src/tenancy/mod.rs", bad).len(), 2);
    // Outside the panic-free zones the same code is fine.
    assert!(rules_hit("src/engine/sweep.rs", bad).is_empty());
    let clean = include_str!("fixtures/lint/panic_path_clean.txt");
    assert!(rules_hit("src/scheduler/protocol.rs", clean).is_empty());
    let allowed = include_str!("fixtures/lint/panic_path_allowed.txt");
    assert!(rules_hit("src/scheduler/protocol.rs", allowed).is_empty());
}

#[test]
fn no_unsafe_fixtures() {
    let bad = include_str!("fixtures/lint/no_unsafe_violation.txt");
    let got = lint_source("src/util/rng.rs", bad);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, "no-unsafe");
    let clean = include_str!("fixtures/lint/no_unsafe_clean.txt");
    assert!(rules_hit("src/lib.rs", clean).is_empty());
    let allowed = include_str!("fixtures/lint/no_unsafe_allowed.txt");
    assert!(rules_hit("src/util/rng.rs", allowed).is_empty());
    // A crate root without the forbid attribute is itself a violation.
    let bare_root = lint_source("src/lib.rs", "pub mod util;\n");
    assert_eq!(bare_root.len(), 1);
    assert_eq!(bare_root[0].rule, "no-unsafe");
    assert!(bare_root[0].msg.contains("forbid"));
}

// ------------------------------------------------------- exit-code contract

/// A throwaway source tree under the OS temp dir, removed on drop.
struct TempTree(PathBuf);

impl TempTree {
    fn new(name: &str, files: &[(&str, &str)]) -> Self {
        let root =
            std::env::temp_dir().join(format!("caravan-lint-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        for (rel, contents) in files {
            let path = root.join(rel);
            fs::create_dir_all(path.parent().expect("file under root")).expect("mkdir");
            fs::write(&path, contents).expect("write fixture");
        }
        fs::create_dir_all(&root).expect("mkdir root");
        TempTree(root)
    }

    fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempTree {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

fn lint_cmd() -> Command {
    Command::new(env!("CARGO_BIN_EXE_caravan"))
}

const VIOLATING_RS: &str = "fn f() -> u64 {\n    let t0 = Instant::now();\n    let _ = t0;\n    0\n}\n";
const CLEAN_RS: &str = "pub fn add(a: u64, b: u64) -> u64 {\n    a + b\n}\n";

#[test]
fn cli_exits_one_on_violations() {
    let tree = TempTree::new("violating", &[("src/bad.rs", VIOLATING_RS)]);
    let out = lint_cmd().arg("lint").arg(tree.path()).output().expect("spawn caravan");
    assert_eq!(out.status.code(), Some(1), "{out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("[wall-clock]"), "{stdout}");
    assert!(stdout.contains("violation"), "{stdout}");
    assert!(!stdout.contains("hint:"), "hints are opt-in: {stdout}");
}

#[test]
fn cli_exits_zero_on_clean_tree() {
    let tree = TempTree::new("clean", &[("src/ok.rs", CLEAN_RS)]);
    let out = lint_cmd().arg("lint").arg(tree.path()).output().expect("spawn caravan");
    assert_eq!(out.status.code(), Some(0), "{out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("clean (1 files)"), "{stdout}");
}

#[test]
fn cli_exits_two_on_missing_path() {
    let missing = std::env::temp_dir().join("caravan-lint-no-such-dir-zzz");
    let _ = fs::remove_dir_all(&missing);
    let out = lint_cmd().arg("lint").arg(&missing).output().expect("spawn caravan");
    assert_eq!(out.status.code(), Some(2), "{out:?}");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("no such path"), "{stderr}");
}

#[test]
fn cli_exits_two_when_no_sources_found() {
    let tree = TempTree::new("empty", &[]);
    let out = lint_cmd()
        .arg("lint")
        .current_dir(tree.path())
        .output()
        .expect("spawn caravan");
    assert_eq!(out.status.code(), Some(2), "{out:?}");
}

#[test]
fn cli_fix_hints_prints_hints_in_either_arg_order() {
    let tree = TempTree::new("hints", &[("src/bad.rs", VIOLATING_RS)]);
    for argv in [
        vec!["lint".to_string(), tree.path().display().to_string(), "--fix-hints".into()],
        vec!["lint".to_string(), "--fix-hints".into(), tree.path().display().to_string()],
    ] {
        let out = lint_cmd().args(&argv).output().expect("spawn caravan");
        assert_eq!(out.status.code(), Some(1), "{argv:?}: {out:?}");
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains("hint:"), "{argv:?}: {stdout}");
    }
}

// --------------------------------------------------------------- self-check

/// `caravan lint` must pass on the repo's own sources — the tree this PR
/// swept clean stays clean, or this test (and the CI gate) fails.
#[test]
fn lint_is_clean_on_own_sources() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut paths = vec![manifest.join("src")];
    for extra in ["tests", "benches"] {
        let p = manifest.join(extra);
        if p.is_dir() {
            paths.push(p);
        }
    }
    let report = lint_paths(&paths).expect("lint own tree");
    assert!(report.files_scanned > 40, "walked the real tree: {}", report.files_scanned);
    let mut listing = String::new();
    for (path, v) in &report.violations {
        listing.push_str(&format!("{path}:{}: [{}] {}\n", v.line, v.rule, v.msg));
    }
    assert!(report.is_clean(), "caravan lint must pass on its own sources:\n{listing}");
}

// ------------------------------------------------- determinism (satellite 2)

/// Everything a report prints, folded into one comparable string.
fn report_fingerprint(r: &caravan::des::DesReport) -> String {
    format!(
        "{:?}|{:?}|{}|{}|{}|{}|{}|{:?}|{:?}|{:?}|{}",
        r.results,
        r.filling.intervals(),
        r.makespan,
        r.events_processed,
        r.producer_msgs_in,
        r.producer_msgs_out,
        r.max_producer_lag,
        r.node_stats,
        r.retired_node_stats,
        r.level_fill,
        r.filling.overlap_violations(),
    )
}

/// Two identical runs must produce byte-identical reports — the
/// determinism property the BTreeMap sweep (des/, metrics, session)
/// protects. A reintroduced HashMap iteration would flake this test.
#[test]
fn des_report_is_identical_across_grid_runs() {
    let run = || {
        let (engine, outcome) = GridEngine::new(vec![vec![0.0, 0.5, 1.0]; 3], 7);
        let r = run_des(
            &DesConfig::new(16),
            Box::new(engine),
            Box::new(ConstResults::new(1.0, 2.0, 2, 0)),
        );
        let points = format!("{:?}", outcome.lock().expect("outcome"));
        (report_fingerprint(&r), points)
    };
    let (a, b) = (run(), run());
    assert_eq!(a.0, b.0, "grid DES report must be bit-identical");
    assert_eq!(a.1, b.1, "grid outcome order must be bit-identical");
}

#[test]
fn des_report_is_identical_across_mcmc_runs() {
    // MCMC exercises the dynamic callback path: every completion submits
    // the next proposal, so event ordering feeds back into the schedule.
    let run = || {
        let mut cfg = McmcConfig::new(vec![(0.0, 1.0); 2]);
        cfg.walkers = 3;
        cfg.steps = 25;
        cfg.seed = 5;
        let (engine, _outcome) = McmcEngine::new(cfg);
        let r = run_des(
            &DesConfig::new(8),
            Box::new(engine),
            Box::new(ConstResults::new(1.0, 2.0, 1, 0)),
        );
        report_fingerprint(&r)
    };
    assert_eq!(run(), run(), "MCMC DES report must be bit-identical");
}
