//! Protocol invariants of the N-level buffer tree, checked with the
//! in-repo `testutil` property harness across random tree shapes and task
//! counts:
//!
//! * **conservation** — no task is lost or duplicated, on any topology,
//!   with and without work stealing;
//! * **credit bound** — every node's queue stays within
//!   `credit_factor × subtree_consumers`;
//! * **shutdown** — the broadcast reaches every level of the tree;
//! * **runtime agreement** — the threaded runtime and the DES execute the
//!   same state machines, so they must agree on tasks-executed counts.

use std::sync::Arc;

use caravan::config::SchedulerConfig;
use caravan::des::{run_des, DesConfig, DesReport, SleepDurations};
use caravan::scheduler::{run_scheduler, SleepExecutor};
use caravan::testutil::{check, pair, usize_in};
use caravan::util::rng::Pcg64;
use caravan::workload::{TestCase, TestCaseEngine};

/// Random tree shape drawn from the property inputs.
fn shape(np: usize, cpb: usize, depth: usize, fanout: usize, steal: bool) -> SchedulerConfig {
    SchedulerConfig {
        np,
        consumers_per_buffer: cpb,
        depth,
        fanout,
        steal,
        ..Default::default()
    }
}

fn des_run(cfg: &SchedulerConfig, case: TestCase, n: usize, seed: u64) -> DesReport {
    let mut dcfg = DesConfig::new(cfg.np);
    dcfg.sched = cfg.clone();
    run_des(
        &dcfg,
        Box::new(TestCaseEngine::new(case, n, seed)),
        Box::new(SleepDurations),
    )
}

/// All ids 0..n present exactly once.
fn ids_complete(r: &DesReport, n: usize) -> bool {
    let mut ids: Vec<u64> = r.results.iter().map(|x| x.id).collect();
    ids.sort();
    ids.dedup();
    ids.len() == n && ids.last().copied() == Some(n as u64 - 1)
}

#[test]
fn random_trees_conserve_tasks_and_respect_credit_bounds() {
    check(
        "tree conserves tasks, bounds queues, shuts down every level",
        pair(pair(usize_in(1..48), usize_in(1..9)), pair(usize_in(1..4), usize_in(2..5))),
        |&((np, cpb), (depth, fanout))| {
            let steal = (np + depth) % 2 == 0;
            let cfg = shape(np, cpb, depth, fanout, steal);
            let n = (np * 4).max(3);
            let r = des_run(&cfg, TestCase::TC3, n, np as u64 + depth as u64);
            ids_complete(&r, n)
                && r.filling.overlap_violations() == 0
                && r.node_stats.iter().all(|s| s.max_queue <= s.credit_bound)
                && r.node_stats.iter().all(|s| s.saw_shutdown)
        },
    );
}

#[test]
fn stealing_never_duplicates_or_drops_under_imbalance() {
    // TC2's heavy tail plus tiny leaves maximizes sideways traffic.
    check(
        "stealing preserves exactly-once execution",
        pair(usize_in(2..40), usize_in(1..4)),
        |&(np, depth)| {
            let cfg = shape(np, 2, depth, 2, true);
            let n = np * 6;
            let r = des_run(&cfg, TestCase::TC2, n, 0xBEEF + np as u64);
            ids_complete(&r, n) && r.filling.overlap_violations() == 0
        },
    );
}

#[test]
fn depth_sweep_passes_full_suite() {
    // The acceptance sweep: depth ∈ {1, 2, 3} at a fixed realistic shape.
    for depth in 1..=3usize {
        for steal in [false, true] {
            let cfg = shape(96, 8, depth, 4, steal);
            let n = 96 * 20;
            let r = des_run(&cfg, TestCase::TC2, n, 11);
            assert!(ids_complete(&r, n), "depth={depth} steal={steal}");
            assert_eq!(r.filling.overlap_violations(), 0, "depth={depth}");
            assert!(
                r.node_stats.iter().all(|s| s.max_queue <= s.credit_bound),
                "depth={depth} steal={steal}: credit bound violated"
            );
            assert!(
                r.node_stats.iter().all(|s| s.saw_shutdown),
                "depth={depth} steal={steal}: shutdown missed a level"
            );
            let rate = r.rate(96);
            assert!(rate > 0.85, "depth={depth} steal={steal}: rate={rate}");
            assert_eq!(r.level_fill.len(), depth);
        }
    }
}

#[test]
fn shutdown_reaches_all_levels_even_with_no_work() {
    struct Nothing;
    impl caravan::tasklib::SearchEngine for Nothing {
        fn start(&mut self, _s: &mut dyn caravan::api::JobSink) {}
        fn on_done(
            &mut self,
            _r: &caravan::tasklib::TaskResult,
            _s: &mut dyn caravan::api::JobSink,
        ) {
        }
    }
    let mut dcfg = DesConfig::new(24);
    dcfg.sched = shape(24, 3, 3, 2, true);
    let r = run_des(&dcfg, Box::new(Nothing), Box::new(SleepDurations));
    assert!(r.results.is_empty());
    assert!(r.node_stats.iter().all(|s| s.saw_shutdown), "{:?}", r.node_stats);
}

#[test]
fn cancellation_conserves_task_counts() {
    // Every submitted task must yield exactly one result — executed or
    // cancelled — on any tree shape, so termination detection and the
    // conservation invariant survive cancellations. The engine cancels a
    // fixed block of ids as soon as the first result arrives; whatever is
    // still queued (at the producer or inside the tree) is dropped, and
    // anything already running completes normally.
    use caravan::api::{JobEngine, JobSpec, Jobs};
    use caravan::testutil::{check, pair, usize_in};

    struct CancelHalf {
        n: usize,
        ids: Vec<u64>,
        fired: bool,
    }
    impl JobEngine for CancelHalf {
        type Ctx = ();
        fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
            for i in 0..self.n {
                let id = jobs.submit(JobSpec::sleep(10.0 + i as f64), ());
                self.ids.push(id);
            }
        }
        fn on_done(&mut self, _r: &caravan::tasklib::TaskResult, _ctx: (), jobs: &mut Jobs<'_, ()>) {
            if !self.fired {
                self.fired = true;
                for &id in &self.ids[self.ids.len() / 2..] {
                    jobs.cancel(id);
                }
            }
        }
    }

    check(
        "cancellation conserves task counts",
        pair(pair(usize_in(1..24), usize_in(1..6)), usize_in(1..4)),
        |&((np, cpb), depth)| {
            let cfg = shape(np, cpb, depth, 2, np % 2 == 0);
            let n = (np * 5).max(4);
            let mut dcfg = DesConfig::new(cfg.np);
            dcfg.sched = cfg;
            let engine = CancelHalf { n, ids: Vec::new(), fired: false };
            let r = run_des(
                &dcfg,
                caravan::api::job_engine(engine),
                Box::new(SleepDurations),
            );
            // Exactly one result per id, cancelled ones flagged as such.
            let mut ids: Vec<u64> = r.results.iter().map(|x| x.id).collect();
            ids.sort();
            ids.dedup();
            let dropped_in_tree: u64 =
                r.node_stats.iter().map(|s| s.cancelled_dropped).sum();
            r.results.len() == n
                && ids.len() == n
                && r.filling.overlap_violations() == 0
                && dropped_in_tree as usize <= r.cancelled()
                && r.results.iter().all(|x| x.rc == 0 || x.cancelled())
        },
    );
}

#[test]
fn cancel_racing_a_steal_is_never_lost() {
    // Deterministic DES repro of the lost-cancellation race: two sibling
    // leaves; leaf A churns through short tasks and steals from leaf B's
    // queue of long ones exactly when the engine cancels the task being
    // stolen. Depending on the message latency, the cancel notice reaches
    // the thief before the loot (tombstone path), reaches the victim
    // before the grant leaves (queue-drop path), or finds the task
    // already dispatched (kill path) — in every interleaving the cancel
    // must be honoured: the 500-second task may never run to completion.
    use caravan::api::{JobEngine, JobSpec, Jobs};

    struct StealRace {
        trigger: u64,
    }
    impl JobEngine for StealRace {
        type Ctx = ();
        fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
            // Ids 0-3: short churn for leaf A. Ids 4-6: long work keeping
            // leaf B busy and queued. Id 7: the steal target (the back of
            // B's queue — what a steal takes first).
            for _ in 0..4 {
                jobs.submit(JobSpec::sleep(1.0), ());
            }
            for _ in 0..3 {
                jobs.submit(JobSpec::sleep(30.0), ());
            }
            jobs.submit(JobSpec::sleep(500.0), ());
        }
        fn on_done(&mut self, r: &caravan::tasklib::TaskResult, _c: (), jobs: &mut Jobs<'_, ()>) {
            if r.id == self.trigger {
                jobs.cancel(7);
            }
        }
    }

    // Sweep the cancel trigger and the network latency: together they
    // slide the broadcast across the steal's in-flight window, covering
    // before / during / after orderings deterministically.
    for trigger in [1u64, 2, 3] {
        for msg_latency in [0.25f64, 0.5] {
            let mut dcfg = DesConfig::new(2);
            dcfg.sched = shape(2, 1, 1, 2, true); // two sibling leaves
            dcfg.sched.credit_factor = 4;
            dcfg.sched.flush_every = 1;
            dcfg.lat.msg_latency = msg_latency;
            let r = run_des(
                &dcfg,
                caravan::api::job_engine(StealRace { trigger }),
                Box::new(SleepDurations),
            );
            let label = format!("trigger={trigger} lat={msg_latency}");
            assert_eq!(r.results.len(), 8, "{label}: conservation");
            assert!(ids_complete(&r, 8), "{label}: one result per id");
            let target = r.results.iter().find(|x| x.id == 7).unwrap();
            assert!(
                target.cancelled(),
                "{label}: the cancel was lost — task 7 ran to rc={}",
                target.rc
            );
            assert!(
                r.makespan < 200.0,
                "{label}: task 7's 500-second body must never complete (makespan={})",
                r.makespan
            );
            assert!(
                r.results.iter().filter(|x| x.id != 7).all(|x| x.ok()),
                "{label}: untargeted tasks unaffected"
            );
        }
    }
}

#[test]
fn priority_inversion_is_bounded_under_stealing() {
    // High-priority jobs submitted together with a crowd of low-priority
    // ones must start (almost) first: with priority queues at every level,
    // the only lows that may begin before the last high are those already
    // resident in node queues / on consumers when the highs were handed
    // out, plus sideways steal traffic. Bound: total queue credit + np +
    // tasks stolen.
    use caravan::api::{JobEngine, JobSpec, Jobs};

    const N_HIGH: usize = 30;
    const N_LOW: usize = 90;

    struct Mixed;
    impl JobEngine for Mixed {
        type Ctx = bool; // "is high priority"
        fn start(&mut self, jobs: &mut Jobs<'_, bool>) {
            // Lows first, so any priority respect comes from the queues,
            // not submission order.
            for _ in 0..N_LOW {
                jobs.submit(JobSpec::sleep(1.0), false);
            }
            for _ in 0..N_HIGH {
                jobs.submit(JobSpec::sleep(1.0).priority(9), true);
            }
        }
        fn on_done(
            &mut self,
            _r: &caravan::tasklib::TaskResult,
            _hi: bool,
            _jobs: &mut Jobs<'_, bool>,
        ) {
        }
    }

    for (np, cpb, depth) in [(8, 2, 1), (8, 2, 2), (12, 3, 1)] {
        let cfg = shape(np, cpb, depth, 2, true);
        let mut dcfg = DesConfig::new(cfg.np);
        dcfg.sched = cfg;
        let r = run_des(&dcfg, caravan::api::job_engine(Mixed), Box::new(SleepDurations));
        assert_eq!(r.results.len(), N_HIGH + N_LOW, "np={np} depth={depth}");
        // High ids are N_LOW..N_LOW+N_HIGH (submission order mints ids).
        let is_high = |id: u64| id >= N_LOW as u64;
        let last_high_begin = r
            .results
            .iter()
            .filter(|x| is_high(x.id))
            .map(|x| x.begin)
            .fold(f64::NEG_INFINITY, f64::max);
        let lows_before = r
            .results
            .iter()
            .filter(|x| !is_high(x.id) && x.begin < last_high_begin)
            .count();
        let credit: usize = r.node_stats.iter().map(|s| s.credit_bound).sum();
        let bound = credit + np + r.tasks_stolen() as usize;
        assert!(
            lows_before <= bound,
            "np={np} depth={depth}: {lows_before} low-priority tasks began before \
             the last high-priority one (bound {bound})"
        );
        // And the high tier must clearly lead on average.
        let mean = |hi: bool| {
            let xs: Vec<f64> = r
                .results
                .iter()
                .filter(|x| is_high(x.id) == hi)
                .map(|x| x.begin)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            mean(true) < mean(false),
            "np={np} depth={depth}: high-priority mean begin must precede low"
        );
    }
}

#[test]
fn threaded_runtime_and_des_agree_on_tasks_executed() {
    // The two runtimes drive the same state machines; on identical
    // workloads they must execute the same task set. Hand-rolled shape
    // sampling (the threaded runtime is wall-clock bound, so a handful of
    // shapes rather than the full 128-case harness sweep).
    let mut rng = Pcg64::new(2024);
    for trial in 0..6u64 {
        let np = 2 + rng.below(7) as usize; // 2..=8
        let cpb = 1 + rng.below(4) as usize;
        let depth = 1 + rng.below(3) as usize; // 1..=3
        let fanout = 2 + rng.below(2) as usize;
        let steal = trial % 2 == 0;
        let mut cfg = shape(np, cpb, depth, fanout, steal);
        cfg.time_scale = 0.001;
        cfg.flush_interval_ms = 2;
        let case = [TestCase::TC1, TestCase::TC2, TestCase::TC3][(trial % 3) as usize];
        let n = np * 3;

        let threaded = run_scheduler(
            &cfg,
            Box::new(TestCaseEngine::new(case, n, trial)),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        let des = des_run(&cfg, case, n, trial);

        assert_eq!(
            threaded.results.len(),
            des.results.len(),
            "trial {trial} (np={np} cpb={cpb} depth={depth} steal={steal})"
        );
        let mut t_ids: Vec<u64> = threaded.results.iter().map(|r| r.id).collect();
        let mut d_ids: Vec<u64> = des.results.iter().map(|r| r.id).collect();
        t_ids.sort();
        d_ids.sort();
        assert_eq!(t_ids, d_ids, "trial {trial}: executed task sets differ");
        assert!(threaded.node_stats.iter().all(|s| s.saw_shutdown));
        assert!(threaded.node_stats.iter().all(|s| s.max_queue <= s.credit_bound));
    }
}
