//! Protocol invariants of the N-level buffer tree, checked with the
//! in-repo `testutil` property harness across random tree shapes and task
//! counts:
//!
//! * **conservation** — no task is lost or duplicated, on any topology,
//!   with and without work stealing;
//! * **credit bound** — every node's queue stays within
//!   `credit_factor × subtree_consumers`;
//! * **shutdown** — the broadcast reaches every level of the tree;
//! * **runtime agreement** — the threaded runtime and the DES execute the
//!   same state machines, so they must agree on tasks-executed counts.

use std::sync::Arc;

use caravan::config::{Calibration, ReshapePolicy, SchedPolicy, SchedulerConfig, TreeShape};
use caravan::des::{run_des, DesConfig, DesReport, SleepDurations};
use caravan::scheduler::{run_scheduler, SleepExecutor};
use caravan::tasklib::TaskSink;
use caravan::testutil::{check, pair, usize_in};
use caravan::util::rng::Pcg64;
use caravan::workload::{TestCase, TestCaseEngine};

/// Random tree shape drawn from the property inputs.
fn shape(np: usize, cpb: usize, depth: usize, fanout: usize, steal: bool) -> SchedulerConfig {
    SchedulerConfig {
        np,
        consumers_per_buffer: cpb,
        depth,
        fanout: vec![fanout],
        steal,
        ..Default::default()
    }
}

fn des_run(cfg: &SchedulerConfig, case: TestCase, n: usize, seed: u64) -> DesReport {
    let mut dcfg = DesConfig::new(cfg.np);
    dcfg.sched = cfg.clone();
    run_des(
        &dcfg,
        Box::new(TestCaseEngine::new(case, n, seed)),
        Box::new(SleepDurations),
    )
}

/// All ids 0..n present exactly once.
fn ids_complete(r: &DesReport, n: usize) -> bool {
    let mut ids: Vec<u64> = r.results.iter().map(|x| x.id).collect();
    ids.sort();
    ids.dedup();
    ids.len() == n && ids.last().copied() == Some(n as u64 - 1)
}

#[test]
fn random_trees_conserve_tasks_and_respect_credit_bounds() {
    check(
        "tree conserves tasks, bounds queues, shuts down every level",
        pair(pair(usize_in(1..48), usize_in(1..9)), pair(usize_in(1..4), usize_in(2..5))),
        |&((np, cpb), (depth, fanout))| {
            let steal = (np + depth) % 2 == 0;
            let cfg = shape(np, cpb, depth, fanout, steal);
            let n = (np * 4).max(3);
            let r = des_run(&cfg, TestCase::TC3, n, np as u64 + depth as u64);
            ids_complete(&r, n)
                && r.filling.overlap_violations() == 0
                && r.node_stats.iter().all(|s| s.max_queue <= s.credit_bound)
                && r.node_stats.iter().all(|s| s.saw_shutdown)
        },
    );
}

#[test]
fn stealing_never_duplicates_or_drops_under_imbalance() {
    // TC2's heavy tail plus tiny leaves maximizes sideways traffic.
    check(
        "stealing preserves exactly-once execution",
        pair(usize_in(2..40), usize_in(1..4)),
        |&(np, depth)| {
            let cfg = shape(np, 2, depth, 2, true);
            let n = np * 6;
            let r = des_run(&cfg, TestCase::TC2, n, 0xBEEF + np as u64);
            ids_complete(&r, n) && r.filling.overlap_violations() == 0
        },
    );
}

#[test]
fn depth_sweep_passes_full_suite() {
    // The acceptance sweep: depth ∈ {1, 2, 3} at a fixed realistic shape.
    for depth in 1..=3usize {
        for steal in [false, true] {
            let cfg = shape(96, 8, depth, 4, steal);
            let n = 96 * 20;
            let r = des_run(&cfg, TestCase::TC2, n, 11);
            assert!(ids_complete(&r, n), "depth={depth} steal={steal}");
            assert_eq!(r.filling.overlap_violations(), 0, "depth={depth}");
            assert!(
                r.node_stats.iter().all(|s| s.max_queue <= s.credit_bound),
                "depth={depth} steal={steal}: credit bound violated"
            );
            assert!(
                r.node_stats.iter().all(|s| s.saw_shutdown),
                "depth={depth} steal={steal}: shutdown missed a level"
            );
            let rate = r.rate(96);
            assert!(rate > 0.85, "depth={depth} steal={steal}: rate={rate}");
            assert_eq!(r.level_fill.len(), depth);
        }
    }
}

#[test]
fn shutdown_reaches_all_levels_even_with_no_work() {
    struct Nothing;
    impl caravan::tasklib::SearchEngine for Nothing {
        fn start(&mut self, _s: &mut dyn caravan::api::JobSink) {}
        fn on_done(
            &mut self,
            _r: &caravan::tasklib::TaskResult,
            _s: &mut dyn caravan::api::JobSink,
        ) {
        }
    }
    let mut dcfg = DesConfig::new(24);
    dcfg.sched = shape(24, 3, 3, 2, true);
    let r = run_des(&dcfg, Box::new(Nothing), Box::new(SleepDurations));
    assert!(r.results.is_empty());
    assert!(r.node_stats.iter().all(|s| s.saw_shutdown), "{:?}", r.node_stats);
}

#[test]
fn cancellation_conserves_task_counts() {
    // Every submitted task must yield exactly one result — executed or
    // cancelled — on any tree shape, so termination detection and the
    // conservation invariant survive cancellations. The engine cancels a
    // fixed block of ids as soon as the first result arrives; whatever is
    // still queued (at the producer or inside the tree) is dropped, and
    // anything already running completes normally.
    use caravan::api::{JobEngine, JobSpec, Jobs};
    use caravan::testutil::{check, pair, usize_in};

    struct CancelHalf {
        n: usize,
        ids: Vec<u64>,
        fired: bool,
    }
    impl JobEngine for CancelHalf {
        type Ctx = ();
        fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
            for i in 0..self.n {
                let id = jobs.submit(JobSpec::sleep(10.0 + i as f64), ());
                self.ids.push(id);
            }
        }
        fn on_done(&mut self, _r: &caravan::tasklib::TaskResult, _ctx: (), jobs: &mut Jobs<'_, ()>) {
            if !self.fired {
                self.fired = true;
                for &id in &self.ids[self.ids.len() / 2..] {
                    jobs.cancel(id);
                }
            }
        }
    }

    check(
        "cancellation conserves task counts",
        pair(pair(usize_in(1..24), usize_in(1..6)), usize_in(1..4)),
        |&((np, cpb), depth)| {
            let cfg = shape(np, cpb, depth, 2, np % 2 == 0);
            let n = (np * 5).max(4);
            let mut dcfg = DesConfig::new(cfg.np);
            dcfg.sched = cfg;
            let engine = CancelHalf { n, ids: Vec::new(), fired: false };
            let r = run_des(
                &dcfg,
                caravan::api::job_engine(engine),
                Box::new(SleepDurations),
            );
            // Exactly one result per id, cancelled ones flagged as such.
            let mut ids: Vec<u64> = r.results.iter().map(|x| x.id).collect();
            ids.sort();
            ids.dedup();
            let dropped_in_tree: u64 =
                r.node_stats.iter().map(|s| s.cancelled_dropped).sum();
            r.results.len() == n
                && ids.len() == n
                && r.filling.overlap_violations() == 0
                && dropped_in_tree as usize <= r.cancelled()
                && r.results.iter().all(|x| x.rc == 0 || x.cancelled())
        },
    );
}

#[test]
fn cancel_racing_a_steal_is_never_lost() {
    // Deterministic DES repro of the lost-cancellation race: two sibling
    // leaves; leaf A churns through short tasks and steals from leaf B's
    // queue of long ones exactly when the engine cancels the task being
    // stolen. Depending on the message latency, the cancel notice reaches
    // the thief before the loot (tombstone path), reaches the victim
    // before the grant leaves (queue-drop path), or finds the task
    // already dispatched (kill path) — in every interleaving the cancel
    // must be honoured: the 500-second task may never run to completion.
    use caravan::api::{JobEngine, JobSpec, Jobs};

    struct StealRace {
        trigger: u64,
    }
    impl JobEngine for StealRace {
        type Ctx = ();
        fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
            // Ids 0-3: short churn for leaf A. Ids 4-6: long work keeping
            // leaf B busy and queued. Id 7: the steal target (the back of
            // B's queue — what a steal takes first).
            for _ in 0..4 {
                jobs.submit(JobSpec::sleep(1.0), ());
            }
            for _ in 0..3 {
                jobs.submit(JobSpec::sleep(30.0), ());
            }
            jobs.submit(JobSpec::sleep(500.0), ());
        }
        fn on_done(&mut self, r: &caravan::tasklib::TaskResult, _c: (), jobs: &mut Jobs<'_, ()>) {
            if r.id == self.trigger {
                jobs.cancel(7);
            }
        }
    }

    // Sweep the cancel trigger and the network latency: together they
    // slide the broadcast across the steal's in-flight window, covering
    // before / during / after orderings deterministically.
    for trigger in [1u64, 2, 3] {
        for msg_latency in [0.25f64, 0.5] {
            let mut dcfg = DesConfig::new(2);
            dcfg.sched = shape(2, 1, 1, 2, true); // two sibling leaves
            dcfg.sched.credit_factor = 4;
            dcfg.sched.flush_every = 1;
            dcfg.lat.msg_latency = msg_latency;
            let r = run_des(
                &dcfg,
                caravan::api::job_engine(StealRace { trigger }),
                Box::new(SleepDurations),
            );
            let label = format!("trigger={trigger} lat={msg_latency}");
            assert_eq!(r.results.len(), 8, "{label}: conservation");
            assert!(ids_complete(&r, 8), "{label}: one result per id");
            let target = r.results.iter().find(|x| x.id == 7).unwrap();
            assert!(
                target.cancelled(),
                "{label}: the cancel was lost — task 7 ran to rc={}",
                target.rc
            );
            assert!(
                r.makespan < 200.0,
                "{label}: task 7's 500-second body must never complete (makespan={})",
                r.makespan
            );
            assert!(
                r.results.iter().filter(|x| x.id != 7).all(|x| x.ok()),
                "{label}: untargeted tasks unaffected"
            );
        }
    }
}

#[test]
fn priority_inversion_is_bounded_under_stealing() {
    // High-priority jobs submitted together with a crowd of low-priority
    // ones must start (almost) first: with priority queues at every level,
    // the only lows that may begin before the last high are those already
    // resident in node queues / on consumers when the highs were handed
    // out, plus sideways steal traffic. Bound: total queue credit + np +
    // tasks stolen.
    use caravan::api::{JobEngine, JobSpec, Jobs};

    const N_HIGH: usize = 30;
    const N_LOW: usize = 90;

    struct Mixed;
    impl JobEngine for Mixed {
        type Ctx = bool; // "is high priority"
        fn start(&mut self, jobs: &mut Jobs<'_, bool>) {
            // Lows first, so any priority respect comes from the queues,
            // not submission order.
            for _ in 0..N_LOW {
                jobs.submit(JobSpec::sleep(1.0), false);
            }
            for _ in 0..N_HIGH {
                jobs.submit(JobSpec::sleep(1.0).priority(9), true);
            }
        }
        fn on_done(
            &mut self,
            _r: &caravan::tasklib::TaskResult,
            _hi: bool,
            _jobs: &mut Jobs<'_, bool>,
        ) {
        }
    }

    for (np, cpb, depth) in [(8, 2, 1), (8, 2, 2), (12, 3, 1)] {
        let cfg = shape(np, cpb, depth, 2, true);
        let mut dcfg = DesConfig::new(cfg.np);
        dcfg.sched = cfg;
        let r = run_des(&dcfg, caravan::api::job_engine(Mixed), Box::new(SleepDurations));
        assert_eq!(r.results.len(), N_HIGH + N_LOW, "np={np} depth={depth}");
        // High ids are N_LOW..N_LOW+N_HIGH (submission order mints ids).
        let is_high = |id: u64| id >= N_LOW as u64;
        let last_high_begin = r
            .results
            .iter()
            .filter(|x| is_high(x.id))
            .map(|x| x.begin)
            .fold(f64::NEG_INFINITY, f64::max);
        let lows_before = r
            .results
            .iter()
            .filter(|x| !is_high(x.id) && x.begin < last_high_begin)
            .count();
        let credit: usize = r.node_stats.iter().map(|s| s.credit_bound).sum();
        let bound = credit + np + r.tasks_stolen() as usize;
        assert!(
            lows_before <= bound,
            "np={np} depth={depth}: {lows_before} low-priority tasks began before \
             the last high-priority one (bound {bound})"
        );
        // And the high tier must clearly lead on average.
        let mean = |hi: bool| {
            let xs: Vec<f64> = r
                .results
                .iter()
                .filter(|x| is_high(x.id) == hi)
                .map(|x| x.begin)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            mean(true) < mean(false),
            "np={np} depth={depth}: high-priority mean begin must precede low"
        );
    }
}

/// Engine submitting `n` fixed-length sleeps up front (the shape the
/// calibration phase measures cleanly).
struct FixedSleeps {
    n: usize,
    secs: f64,
}

impl caravan::tasklib::SearchEngine for FixedSleeps {
    fn start(&mut self, sink: &mut dyn caravan::api::JobSink) {
        for _ in 0..self.n {
            sink.submit(caravan::tasklib::Payload::Sleep { seconds: self.secs });
        }
    }
    fn on_done(
        &mut self,
        _r: &caravan::tasklib::TaskResult,
        _s: &mut dyn caravan::api::JobSink,
    ) {
    }
}

#[test]
fn auto_shape_stays_flat_when_producer_lag_is_negligible() {
    // Satellite: deterministic DES calibration. Default latency model
    // (microsecond messages) against second-scale tasks: the controller
    // must keep the paper's flat layout — the user set no shape knob.
    let mut dcfg = DesConfig::new(2048);
    dcfg.sched.consumers_per_buffer = 128; // 16 leaves
    dcfg.sched.shape = TreeShape::Auto;
    let n = 2048 * 2;
    let r = run_des(&dcfg, Box::new(FixedSleeps { n, secs: 5.0 }), Box::new(SleepDurations));
    assert_eq!(r.depth, 1, "fast producer must keep the flat layout");
    assert_eq!(r.results.len(), n);
    assert!(r.rate(2048) > 0.9, "rate={}", r.rate(2048));
}

#[test]
fn auto_shape_deepens_when_producer_lag_dominates() {
    // Satellite: same workload, but the producer now takes 5 ms per
    // message against half-second tasks — its round trip dominates, so
    // the controller must insert relay levels (depth ≥ 2). Deterministic
    // in virtual time: calibration = latency model + duration samples.
    let mut dcfg = DesConfig::new(2048);
    dcfg.sched.consumers_per_buffer = 128;
    dcfg.sched.shape = TreeShape::Auto;
    dcfg.lat.producer_service = 5e-3;
    let n = 2048 * 2;
    let r = run_des(&dcfg, Box::new(FixedSleeps { n, secs: 0.5 }), Box::new(SleepDurations));
    assert!(r.depth >= 2, "lag-dominated producer must deepen: depth={}", r.depth);
    assert_eq!(r.results.len(), n, "auto shape must still conserve tasks");
    assert!(r.node_stats.iter().all(|s| s.saw_shutdown));
}

#[test]
fn auto_shape_matches_best_manual_depth_sweep() {
    // The acceptance sweep at test scale (the fig3_tree bench repeats it
    // at 10⁵ consumers): Auto must land within 5% filling of the best
    // manually-swept depth ∈ {1, 2, 3}.
    let run = |shape: TreeShape, depth: usize| {
        let mut dcfg = DesConfig::new(2048);
        dcfg.sched.consumers_per_buffer = 128;
        dcfg.sched.depth = depth;
        dcfg.sched.fanout = vec![4];
        dcfg.sched.shape = shape;
        let r = run_des(
            &dcfg,
            Box::new(TestCaseEngine::new(TestCase::TC2, 2048 * 4, 13)),
            Box::new(SleepDurations),
        );
        assert_eq!(r.results.len(), 2048 * 4);
        r.rate(2048)
    };
    let best = (1..=3)
        .map(|d| run(TreeShape::Manual, d))
        .fold(f64::NEG_INFINITY, f64::max);
    let auto = run(TreeShape::Auto, 1);
    assert!(
        auto >= best - 0.05,
        "auto filling {auto:.4} more than 5% below best manual {best:.4}"
    );
}

#[test]
fn threaded_and_des_select_identical_shape_from_shared_calibration() {
    // The controller is one pure function in the protocol layer: for the
    // same calibration inputs, the threaded runtime and the DES must
    // build the identical tree. This calibration forces a deep choice.
    let cal = Calibration { producer_rtt: 1.0, mean_task_s: 1.0 };
    let mut cfg = shape(8, 2, 1, 8, false);
    cfg.shape = TreeShape::Calibrated(cal);
    cfg.time_scale = 0.001;
    cfg.flush_interval_ms = 2;

    let threaded = run_scheduler(
        &cfg,
        Box::new(FixedSleeps { n: 16, secs: 1.0 }),
        Arc::new(SleepExecutor { time_scale: 0.001 }),
    );
    let mut dcfg = DesConfig::new(cfg.np);
    dcfg.sched = cfg.clone();
    let des = run_des(&dcfg, Box::new(FixedSleeps { n: 16, secs: 1.0 }), Box::new(SleepDurations));

    assert_eq!(
        (threaded.depth, threaded.fanout.clone()),
        (des.depth, des.fanout.clone()),
        "both runtimes must shape identically from the same calibration"
    );
    assert!(threaded.depth >= 2, "this calibration must force relay levels");
    assert_eq!(threaded.results.len(), 16);
    assert_eq!(des.results.len(), 16);
}

#[test]
fn threaded_auto_calibration_completes_and_conserves_tasks() {
    // TreeShape::Auto on the real runtime: the calibration phase executes
    // a couple of tasks inline — every task must still be accounted for
    // exactly once in the final report.
    let mut cfg = shape(4, 2, 1, 4, false);
    cfg.shape = TreeShape::Auto;
    cfg.time_scale = 0.001;
    cfg.flush_interval_ms = 2;
    let r = run_scheduler(
        &cfg,
        Box::new(FixedSleeps { n: 20, secs: 1.0 }),
        Arc::new(SleepExecutor { time_scale: 0.001 }),
    );
    assert_eq!(r.results.len(), 20);
    let mut ids: Vec<u64> = r.results.iter().map(|x| x.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 20, "calibration tasks must not duplicate or vanish");
    assert!(r.depth >= 1 && r.filling.overlap_violations() == 0);
}

#[test]
fn threaded_auto_calibration_honours_cancels_issued_in_start() {
    // A task cancelled inside SearchEngine::start must come back
    // RC_CANCELLED even under TreeShape::Auto — the calibration phase may
    // not pick it as an inline probe and run it to completion.
    struct CancelFirst;
    impl caravan::tasklib::SearchEngine for CancelFirst {
        fn start(&mut self, sink: &mut dyn caravan::api::JobSink) {
            let id = sink.submit(caravan::tasklib::Payload::Sleep { seconds: 1.0 });
            for _ in 0..7 {
                sink.submit(caravan::tasklib::Payload::Sleep { seconds: 1.0 });
            }
            sink.cancel(id);
        }
        fn on_done(
            &mut self,
            _r: &caravan::tasklib::TaskResult,
            _s: &mut dyn caravan::api::JobSink,
        ) {
        }
    }

    let mut cfg = shape(2, 2, 1, 4, false);
    cfg.shape = TreeShape::Auto;
    cfg.time_scale = 0.001;
    cfg.flush_interval_ms = 2;
    let r = run_scheduler(&cfg, Box::new(CancelFirst), Arc::new(SleepExecutor { time_scale: 0.001 }));
    assert_eq!(r.results.len(), 8);
    let first = r.results.iter().find(|x| x.id == 0).expect("one result per id");
    assert!(first.cancelled(), "cancelled-in-start task executed anyway: rc={}", first.rc);
    assert!(r.results.iter().filter(|x| x.id != 0).all(|x| x.ok()));
}

/// Engine whose workload shifts regimes mid-run: `n_long` slow tasks up
/// front, then — once every long task completed — a flood of `n_short`
/// fast ones. The shape chosen for the long phase is stale for the short
/// phase: short tasks multiply the producer's request/result traffic.
struct PhaseShift {
    n_long: usize,
    n_short: usize,
    long_s: f64,
    short_s: f64,
    long_done: usize,
    fired: bool,
}

impl caravan::tasklib::SearchEngine for PhaseShift {
    fn start(&mut self, sink: &mut dyn caravan::api::JobSink) {
        for _ in 0..self.n_long {
            sink.submit(caravan::tasklib::Payload::Sleep { seconds: self.long_s });
        }
    }
    fn on_done(
        &mut self,
        r: &caravan::tasklib::TaskResult,
        sink: &mut dyn caravan::api::JobSink,
    ) {
        if (r.id as usize) < self.n_long {
            self.long_done += 1;
        }
        if self.long_done == self.n_long && !self.fired {
            self.fired = true;
            for _ in 0..self.n_short {
                sink.submit(caravan::tasklib::Payload::Sleep { seconds: self.short_s });
            }
        }
    }
}

const PS_LONG: usize = 512;
const PS_SHORT: usize = 15_000;

fn phase_engine() -> Box<dyn caravan::tasklib::SearchEngine> {
    Box::new(PhaseShift {
        n_long: PS_LONG,
        n_short: PS_SHORT,
        long_s: 20.0,
        short_s: 0.2,
        long_done: 0,
        fired: false,
    })
}

/// The duration-shift scenario: 256 consumers over 32 leaves, a slow
/// producer (5 ms service), result flushes batched by 64. The initial
/// shape is pinned flat via a `Calibrated` preset that matches the long
/// phase; the short phase saturates rank 0 under that shape, so the
/// rolling calibration must drive a drain-and-graft to a deeper tree.
fn reshape_cfg(policy: SchedPolicy, reshape: bool) -> DesConfig {
    let mut dcfg = DesConfig::new(256);
    dcfg.sched.consumers_per_buffer = 8; // 32 leaves
    dcfg.sched.flush_every = 64;
    dcfg.sched.policy = policy;
    dcfg.sched.shape = TreeShape::Calibrated(Calibration {
        producer_rtt: 5.04e-3,
        mean_task_s: 20.0,
    });
    if reshape {
        dcfg.sched.reshape =
            Some(ReshapePolicy { window: 3.0, drift_threshold: 0.5, cooldown: 3.0 });
    }
    dcfg.lat.producer_service = 5e-3;
    dcfg
}

/// Σ wait-hist counts == popped at every node, including the nodes of
/// trees retired by drain-and-graft transitions.
fn hist_conserves(r: &DesReport) -> bool {
    r.node_stats
        .iter()
        .chain(r.retired_node_stats.iter())
        .all(|s| s.wait_hist.iter().map(|h| h.total()).sum::<u64>() == s.popped)
}

/// Completions per virtual second strictly after `t`.
fn throughput_after(r: &DesReport, t: f64) -> f64 {
    let finishes: Vec<f64> = r
        .results
        .iter()
        .filter(|x| !x.cancelled() && x.finish > t)
        .map(|x| x.finish)
        .collect();
    if finishes.is_empty() {
        return 0.0;
    }
    let last = finishes.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    finishes.len() as f64 / (last - t).max(1e-9)
}

#[test]
fn reshape_fires_and_conserves_across_policies() {
    // The tentpole acceptance sweep: on the duration-shifting workload a
    // transition fires, and conservation — one result per task, Σ
    // wait-hist counts == popped at every (current and retired) node —
    // holds across the transition for every SchedPolicy.
    for policy in [
        SchedPolicy::Strict,
        SchedPolicy::Deadline,
        SchedPolicy::Aging { step: 5.0 },
    ] {
        let r = run_des(&reshape_cfg(policy, true), phase_engine(), Box::new(SleepDurations));
        let n = PS_LONG + PS_SHORT;
        assert!(
            !r.reshapes.is_empty(),
            "{policy:?}: the duration shift must trigger a transition"
        );
        assert!(
            r.reshapes[0].to_depth >= 2,
            "{policy:?}: the stale flat shape must deepen: {:?}",
            r.reshapes
        );
        assert_eq!(r.results.len(), n, "{policy:?}: conservation across the graft");
        assert!(ids_complete(&r, n), "{policy:?}: exactly one result per id");
        assert_eq!(r.filling.overlap_violations(), 0, "{policy:?}");
        assert!(r.results.iter().all(|x| x.ok()), "{policy:?}: no task may fail");
        assert!(hist_conserves(&r), "{policy:?}: wait-hist/popped drifted across the graft");
        assert!(
            !r.retired_node_stats.is_empty(),
            "{policy:?}: the pre-transition tree must be retired"
        );
        assert_eq!(r.depth, r.reshapes.last().unwrap().to_depth, "{policy:?}: report shape");
    }
}

#[test]
fn reshape_beats_the_stale_shape_after_the_transition() {
    // Acceptance: with --reshape on the duration-shifting workload,
    // post-transition throughput must be at least the no-reshape
    // baseline's over the same interval (the stale flat shape keeps
    // rank 0 saturated; the grafted tree removes the request traffic).
    let reshaped =
        run_des(&reshape_cfg(SchedPolicy::Strict, true), phase_engine(), Box::new(SleepDurations));
    let stale =
        run_des(&reshape_cfg(SchedPolicy::Strict, false), phase_engine(), Box::new(SleepDurations));
    assert!(!reshaped.reshapes.is_empty());
    assert!(stale.reshapes.is_empty(), "baseline must not reshape");
    assert_eq!(stale.results.len(), PS_LONG + PS_SHORT);
    let t_star = reshaped.reshapes[0].t;
    let thr_reshaped = throughput_after(&reshaped, t_star);
    let thr_stale = throughput_after(&stale, t_star);
    assert!(
        thr_reshaped >= thr_stale,
        "post-transition throughput {thr_reshaped:.1}/s must beat the stale shape's \
         {thr_stale:.1}/s (transition at t={t_star:.1})"
    );
}

#[test]
fn reshape_transitions_are_deterministic_in_virtual_time() {
    // The controller is pure bookkeeping over the DES's deterministic
    // observation stream: two identical runs must execute identical
    // transitions and produce identical schedules.
    let a = run_des(&reshape_cfg(SchedPolicy::Strict, true), phase_engine(), Box::new(SleepDurations));
    let b = run_des(&reshape_cfg(SchedPolicy::Strict, true), phase_engine(), Box::new(SleepDurations));
    assert!(!a.reshapes.is_empty());
    assert_eq!(a.reshapes, b.reshapes, "transition times and shapes must be identical");
    assert_eq!(a.makespan, b.makespan, "virtual makespans must be bit-identical");
    let key = |r: &DesReport| {
        let mut k: Vec<(u64, u64)> =
            r.results.iter().map(|x| (x.id, x.finish.to_bits())).collect();
        k.sort();
        k
    };
    assert_eq!(key(&a), key(&b), "schedules must be bit-identical");
}

#[test]
fn threaded_reshape_conserves_under_steals_and_cancels() {
    // The real runtime's drain-and-graft: start from a deliberately deep
    // Calibrated shape, let the rolling measurement (real channel lag,
    // real durations) pull the tree toward the workload, and prove
    // conservation — exactly one result per id — with sibling stealing
    // on and cancellations racing the transition.
    use caravan::api::{JobEngine, JobSpec, Jobs};

    struct CancelBlock {
        n: usize,
        fired: bool,
    }
    impl JobEngine for CancelBlock {
        type Ctx = ();
        fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
            for _ in 0..self.n {
                jobs.submit(JobSpec::sleep(5.0), ());
            }
        }
        fn on_done(
            &mut self,
            _r: &caravan::tasklib::TaskResult,
            _ctx: (),
            jobs: &mut Jobs<'_, ()>,
        ) {
            if !self.fired {
                self.fired = true;
                for id in 40..52u64 {
                    jobs.cancel(id);
                }
            }
        }
    }

    let n = 64;
    let mut cfg = shape(8, 2, 1, 8, true); // 4 leaves, stealing on
    cfg.shape = TreeShape::Calibrated(Calibration { producer_rtt: 1.0, mean_task_s: 0.5 });
    cfg.reshape = Some(ReshapePolicy { window: 3.0, drift_threshold: 0.1, cooldown: 2.0 });
    cfg.time_scale = 0.01; // 1 virtual s = 10 ms wall
    cfg.flush_interval_ms = 2;
    let r = run_scheduler(
        &cfg,
        caravan::api::job_engine(CancelBlock { n, fired: false }),
        Arc::new(SleepExecutor { time_scale: 0.01 }),
    );
    assert_eq!(r.results.len(), n, "conservation across threaded transitions");
    let mut ids: Vec<u64> = r.results.iter().map(|x| x.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n, "exactly one result per id under reshape + steal + cancel");
    assert!(
        r.results.iter().all(|x| x.ok() || x.cancelled()),
        "every result is a success or an honoured cancellation"
    );
    assert!(
        !r.reshapes.is_empty(),
        "the drifted measurements must re-shape the deliberately deep tree"
    );
    assert_eq!(r.depth, r.reshapes.last().unwrap().to_depth);
    assert_eq!(r.filling.overlap_violations(), 0);
}

#[test]
fn wait_histograms_conserve_dispatches_across_policies_and_shapes() {
    // Satellite property: at every node, the per-band wait-time histogram
    // counts exactly the tasks popped for dispatch (Σ counts == popped),
    // and leaf-level pops sum to the task count — each task is dispatched
    // to a consumer exactly once (stealing moves tasks sideways but never
    // double-pops them; there are no retries in this workload).
    for policy in [
        SchedPolicy::Strict,
        SchedPolicy::Deadline,
        SchedPolicy::Aging { step: 5.0 },
    ] {
        for (depth, steal) in [(1, false), (2, true), (3, true)] {
            let mut cfg = shape(48, 4, depth, 3, steal);
            cfg.policy = policy;
            let n = 48 * 5;
            let r = des_run(&cfg, TestCase::TC2, n, 0xA11 + depth as u64);
            assert_eq!(r.results.len(), n);
            let mut leaf_pops = 0u64;
            for s in &r.node_stats {
                let hist_total: u64 = s.wait_hist.iter().map(|h| h.total()).sum();
                assert_eq!(
                    hist_total, s.popped,
                    "node {} ({:?}, depth {depth}): histogram must conserve pops",
                    s.node, policy
                );
                if s.level == depth {
                    leaf_pops += s.popped;
                }
            }
            assert_eq!(
                leaf_pops, n as u64,
                "{policy:?} depth {depth} steal {steal}: each task dispatched exactly once"
            );
        }
    }
}

#[test]
fn producer_lag_is_measured_at_every_level() {
    // The request→grant instrumentation that feeds adaptive shaping:
    // any node that requested and received work has a positive lag
    // sample (in the DES the minimum is the modelled round trip).
    let mut cfg = shape(64, 8, 2, 4, false);
    cfg.flush_every = 4;
    let r = des_run(&cfg, TestCase::TC1, 64 * 4, 3);
    assert_eq!(r.results.len(), 64 * 4);
    for s in &r.node_stats {
        assert!(s.req_lag_n > 0, "node {} never completed a request round trip", s.node);
        assert!(s.req_lag_mean > 0.0 && s.req_lag_max >= s.req_lag_mean);
    }
}

/// Engine dealing `n` sleeps round-robin over `n_classes` tenant classes.
struct ClassedSleeps {
    n: usize,
    n_classes: usize,
    secs: f64,
}

impl caravan::tasklib::SearchEngine for ClassedSleeps {
    fn start(&mut self, sink: &mut dyn caravan::api::JobSink) {
        for i in 0..self.n {
            sink.submit_job(
                caravan::api::JobSpec::sleep(self.secs).class((i % self.n_classes) as u8),
            );
        }
    }
    fn on_done(
        &mut self,
        _r: &caravan::tasklib::TaskResult,
        _s: &mut dyn caravan::api::JobSink,
    ) {
    }
}

/// Per-node tenancy conservation: per-class popped counts decompose the
/// node total exactly, and each class's wait histogram counts exactly its
/// own pops.
fn class_stats_conserve(stats: &[caravan::scheduler::NodeStats], label: &str) {
    for s in stats {
        let class_pop: u64 = s.class_stats.iter().map(|c| c.popped).sum();
        assert_eq!(
            class_pop, s.popped,
            "{label} node {}: per-class pops must sum to the node total",
            s.node
        );
        for c in &s.class_stats {
            let hist: u64 = c.wait_hist.iter().map(|h| h.total()).sum();
            assert_eq!(
                hist, c.popped,
                "{label} node {} class {}: wait-hist must conserve class pops",
                s.node, c.class
            );
        }
    }
}

#[test]
fn class_stats_conserve_dispatches_per_class_in_des() {
    // Satellite property: with two registered classes, at every node (and
    // every retired node) the per-class dispatch counters decompose the
    // totals exactly — across every SchedPolicy, with stealing on.
    use caravan::tenancy::JobClass;
    for policy in [
        SchedPolicy::Strict,
        SchedPolicy::Deadline,
        SchedPolicy::Aging { step: 5.0 },
    ] {
        let depth = 2;
        let mut cfg = shape(24, 4, depth, 3, true);
        cfg.policy = policy;
        cfg.classes = vec![
            JobClass::new("a", 3),
            JobClass::new("b", 1).policy(SchedPolicy::Deadline),
        ];
        let n = 24 * 5;
        let mut dcfg = DesConfig::new(cfg.np);
        dcfg.sched = cfg;
        let r = run_des(
            &dcfg,
            Box::new(ClassedSleeps { n, n_classes: 2, secs: 1.0 }),
            Box::new(SleepDurations),
        );
        let label = format!("{policy:?}");
        assert_eq!(r.results.len(), n, "{label}");
        class_stats_conserve(&r.node_stats, &label);
        class_stats_conserve(&r.retired_node_stats, &label);
        // Leaf-level per-class pops recover the submitted split exactly:
        // each task is dispatched once, in its own class's lane.
        for class in 0..2u8 {
            let leaf: u64 = r
                .node_stats
                .iter()
                .filter(|s| s.level == depth)
                .flat_map(|s| &s.class_stats)
                .filter(|c| c.class == class)
                .map(|c| c.popped)
                .sum();
            assert_eq!(
                leaf,
                n as u64 / 2,
                "{label} class {class}: each task dispatched exactly once"
            );
        }
    }
}

/// Order-insensitive fingerprint of a full DES report: makespan bits,
/// every result field bit-for-bit, and the per-node counters. Two runs
/// with equal fingerprints produced the same report.
fn report_fingerprint(r: &DesReport) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        h = (h ^ v).wrapping_mul(0x1000_0000_01b3);
    };
    mix(r.makespan.to_bits());
    let mut rows: Vec<&caravan::tasklib::TaskResult> = r.results.iter().collect();
    rows.sort_by_key(|x| x.id);
    for x in rows {
        mix(x.id);
        mix(x.consumer as u64);
        mix(x.begin.to_bits());
        mix(x.finish.to_bits());
        mix(x.rc as u64);
        mix(x.attempt as u64);
        mix(x.timed_out as u64);
        for v in &x.results {
            mix(v.to_bits());
        }
    }
    for s in &r.node_stats {
        mix(s.node as u64);
        mix(s.popped);
        mix(s.msgs_in);
        mix(s.msgs_out);
        mix(s.max_queue as u64);
        mix(s.dispatch_batches);
        mix(s.coalesced_flushes);
    }
    h
}

/// Outcome projection of a report: everything the *engine* can observe
/// about each task — id, exit status, final attempt index, and the
/// result values, bit-for-bit. Timing (begin/finish/makespan) and
/// placement (which consumer) are deliberately excluded: batching is a
/// transport optimisation and is allowed to move work in time and
/// space, but never to change what happened to a task.
fn outcome_projection(r: &DesReport) -> Vec<(u64, i32, u32, Vec<u64>)> {
    let mut k: Vec<(u64, i32, u32, Vec<u64>)> = r
        .results
        .iter()
        .map(|x| (x.id, x.rc, x.attempt, x.results.iter().map(|v| v.to_bits()).collect()))
        .collect();
    k.sort();
    k
}

#[test]
fn dispatch_batching_preserves_outcomes_bit_for_bit() {
    // Tentpole equivalence property (Issue 10): the batched hot path
    // (dispatch_batch > 1 + coalesced Flush ascent) and the pre-batching
    // protocol (dispatch_batch = 1, per-message ascent) are the *same
    // scheduler* as far as outcomes go. Each mode is deterministic —
    // repeat runs produce bit-identical full reports — and across modes
    // the sorted outcome projections are identical, for every
    // SchedPolicy and for a two-class tenant mix.
    use caravan::tenancy::JobClass;
    for policy in [
        SchedPolicy::Strict,
        SchedPolicy::Deadline,
        SchedPolicy::Aging { step: 30.0 },
    ] {
        for classed in [false, true] {
            let n = 24 * 6;
            let run = |batch: usize, coalesce: bool| {
                let mut cfg = shape(24, 4, 2, 3, true);
                cfg.policy = policy;
                cfg.flush_every = 4;
                cfg.dispatch_batch = batch;
                cfg.coalesce_flush = coalesce;
                if classed {
                    cfg.classes = vec![
                        JobClass::new("a", 3),
                        JobClass::new("b", 1).policy(SchedPolicy::Deadline),
                    ];
                }
                let mut dcfg = DesConfig::new(cfg.np);
                dcfg.sched = cfg;
                run_des(
                    &dcfg,
                    Box::new(ClassedSleeps { n, n_classes: if classed { 2 } else { 1 }, secs: 1.0 }),
                    Box::new(SleepDurations),
                )
            };
            let label = format!("{policy:?} classed={classed}");

            // Determinism within each mode: the whole report, bit-for-bit.
            let batched = run(4, true);
            assert_eq!(
                report_fingerprint(&batched),
                report_fingerprint(&run(4, true)),
                "{label}: batched runs must be bit-identical"
            );
            let unbatched = run(1, false);
            assert_eq!(
                report_fingerprint(&unbatched),
                report_fingerprint(&run(1, false)),
                "{label}: batch-size-1 runs must be bit-identical"
            );

            // Equivalence across modes: identical outcome projections.
            assert_eq!(
                outcome_projection(&batched),
                outcome_projection(&unbatched),
                "{label}: batching changed a task's outcome"
            );

            // Both modes complete every task exactly once, cleanly.
            for (mode, r) in [("batched", &batched), ("batch-1", &unbatched)] {
                assert_eq!(r.results.len(), n, "{label} {mode}");
                assert!(ids_complete(r, n), "{label} {mode}");
                assert_eq!(r.filling.overlap_violations(), 0, "{label} {mode}");
                if classed {
                    class_stats_conserve(&r.node_stats, &format!("{label} {mode}"));
                }
            }

            // The knobs actually engaged: the batched run coalesced, the
            // batch-1 run stayed on the one-message-per-event path.
            let batches = |r: &DesReport| -> u64 {
                r.node_stats.iter().map(|s| s.dispatch_batches).sum()
            };
            let coalesced = |r: &DesReport| -> u64 {
                r.node_stats.iter().map(|s| s.coalesced_flushes).sum()
            };
            assert!(batches(&batched) > 0, "{label}: no multi-task dispatch ever formed");
            assert!(coalesced(&batched) > 0, "{label}: no ascent frame was ever coalesced");
            assert_eq!(batches(&unbatched), 0, "{label}: batch-1 must never batch");
            assert_eq!(coalesced(&unbatched), 0, "{label}: coalescing was off");
        }
    }
}

/// Shared body for the large-scale DES soaks: `np` consumers, two
/// tenant classes, ~2 tasks per consumer, the batched hot path on. The
/// assertions are pure conservation — exactly one result per id, zero
/// overlap violations, per-class pops decomposing every node total, and
/// the leaf-level class split recovering the submitted mix — plus proof
/// that batching engaged at scale.
fn soak(np: usize) {
    use caravan::tenancy::JobClass;
    let mut cfg = shape(np, 384, 2, 64, false);
    cfg.classes = vec![JobClass::new("steady", 3), JobClass::new("burst", 1)];
    cfg.dispatch_batch = 8;
    cfg.coalesce_flush = true;
    cfg.flush_every = 16;
    let n = np * 2;
    let mut dcfg = DesConfig::new(cfg.np);
    dcfg.sched = cfg;
    let r = run_des(
        &dcfg,
        Box::new(ClassedSleeps { n, n_classes: 2, secs: 1.0 }),
        Box::new(SleepDurations),
    );
    assert_eq!(r.results.len(), n, "np={np}: every submitted task must report");
    assert!(ids_complete(&r, n), "np={np}: ids must be 0..n exactly once");
    assert_eq!(r.filling.overlap_violations(), 0, "np={np}");
    class_stats_conserve(&r.node_stats, &format!("soak np={np}"));
    for class in 0..2u8 {
        let leaf: u64 = r
            .node_stats
            .iter()
            .filter(|s| s.level == 2)
            .flat_map(|s| &s.class_stats)
            .filter(|c| c.class == class)
            .map(|c| c.popped)
            .sum();
        assert_eq!(leaf, n as u64 / 2, "np={np} class {class}: dispatched exactly once");
    }
    let batches: u64 = r.node_stats.iter().map(|s| s.dispatch_batches).sum();
    let coalesced: u64 = r.node_stats.iter().map(|s| s.coalesced_flushes).sum();
    assert!(batches > 0, "np={np}: batching never engaged");
    assert!(coalesced > 0, "np={np}: ascent coalescing never engaged");
}

#[test]
#[ignore = "full-scale soak (10^6 consumers, 2x10^6 tasks); run explicitly"]
fn soak_million_consumers_conserves_tasks() {
    soak(1_000_000);
}

#[test]
#[ignore = "large soak (10^5 consumers); run by the CI bench-smoke job via --ignored"]
fn soak_hundred_thousand_consumers_conserves_tasks() {
    soak(100_000);
}

#[test]
fn threaded_class_stats_conserve_dispatches() {
    // The same decomposition on the real runtime.
    use caravan::tenancy::JobClass;
    let mut cfg = shape(4, 2, 1, 4, false);
    cfg.classes = vec![JobClass::new("a", 2), JobClass::new("b", 1)];
    cfg.time_scale = 0.001;
    cfg.flush_interval_ms = 2;
    let n = 24;
    let r = run_scheduler(
        &cfg,
        Box::new(ClassedSleeps { n, n_classes: 2, secs: 1.0 }),
        Arc::new(SleepExecutor { time_scale: 0.001 }),
    );
    assert_eq!(r.results.len(), n);
    class_stats_conserve(&r.node_stats, "threaded");
    for class in 0..2u8 {
        let leaf: u64 = r
            .node_stats
            .iter()
            .filter(|s| s.level == 1)
            .flat_map(|s| &s.class_stats)
            .filter(|c| c.class == class)
            .map(|c| c.popped)
            .sum();
        assert_eq!(leaf, n as u64 / 2, "class {class}: dispatched exactly once");
    }
}

#[test]
fn threaded_runtime_and_des_agree_on_tasks_executed() {
    // The two runtimes drive the same state machines; on identical
    // workloads they must execute the same task set. Hand-rolled shape
    // sampling (the threaded runtime is wall-clock bound, so a handful of
    // shapes rather than the full 128-case harness sweep).
    let mut rng = Pcg64::new(2024);
    for trial in 0..6u64 {
        let np = 2 + rng.below(7) as usize; // 2..=8
        let cpb = 1 + rng.below(4) as usize;
        let depth = 1 + rng.below(3) as usize; // 1..=3
        let fanout = 2 + rng.below(2) as usize;
        let steal = trial % 2 == 0;
        let mut cfg = shape(np, cpb, depth, fanout, steal);
        cfg.time_scale = 0.001;
        cfg.flush_interval_ms = 2;
        let case = [TestCase::TC1, TestCase::TC2, TestCase::TC3][(trial % 3) as usize];
        let n = np * 3;

        let threaded = run_scheduler(
            &cfg,
            Box::new(TestCaseEngine::new(case, n, trial)),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        let des = des_run(&cfg, case, n, trial);

        assert_eq!(
            threaded.results.len(),
            des.results.len(),
            "trial {trial} (np={np} cpb={cpb} depth={depth} steal={steal})"
        );
        let mut t_ids: Vec<u64> = threaded.results.iter().map(|r| r.id).collect();
        let mut d_ids: Vec<u64> = des.results.iter().map(|r| r.id).collect();
        t_ids.sort();
        d_ids.sort();
        assert_eq!(t_ids, d_ids, "trial {trial}: executed task sets differ");
        assert!(threaded.node_stats.iter().all(|s| s.saw_shutdown));
        assert!(threaded.node_stats.iter().all(|s| s.max_queue <= s.credit_bound));
    }
}

// ------------------------------------------------ model-checker trace fixtures

/// The committed interleaving fixtures — steal+cancel+recall overlap on
/// flat2, a dead link landing mid-recall on deep4, and a cancel racing
/// a two-task RunBatch with coalesced ascent on batched2 — must replay
/// green through the model checker: every step-wise oracle holds along
/// the schedule. The replayer skip-repairs steps that drift out of
/// enabledness, so protocol-internal re-batching cannot break these; a
/// real conservation or quiescence regression still will.
#[test]
fn committed_check_traces_replay_green() {
    for (name, text) in [
        (
            "steal_cancel_recall_overlap",
            include_str!("fixtures/check/steal_cancel_recall_overlap.trace"),
        ),
        ("dead_link_during_recall", include_str!("fixtures/check/dead_link_during_recall.trace")),
        (
            "batched_dispatch_coalesced_ascent",
            include_str!("fixtures/check/batched_dispatch_coalesced_ascent.trace"),
        ),
    ] {
        let report = caravan::check::replay_trace_text(text)
            .unwrap_or_else(|e| panic!("fixture {name} failed to parse: {e}"));
        assert!(
            report.passed(),
            "fixture {name} tripped an oracle: {:?}",
            report.counterexample.map(|c| c.violation)
        );
    }
}
