//! Job API v2 semantics, exercised end-to-end in both runtimes.
//!
//! The DES drives the exact protocol state machines of the threaded
//! scheduler in virtual time, so retry, priority and cancellation are
//! asserted *deterministically* there; the threaded tests mirror each
//! semantic with timing-robust constructions (a single consumer serializes
//! dispatch order; a long head task pins the queue state).

use std::sync::Arc;

use caravan::api::{job_engine, JobEngine, JobSink, JobSpec, Jobs};
use caravan::config::{SchedPolicy, SchedulerConfig, StealPolicy};
use caravan::des::{run_des, DesConfig, DesReport, DurationModel, SleepDurations};
use caravan::scheduler::{run_scheduler, Executor, SleepExecutor};
use caravan::tasklib::{Payload, TaskResult, TaskSink, TaskSpec, RC_TIMEOUT};
use caravan::workload::{TestCase, TestCaseEngine};

/// Submits `n` sleep jobs with a fixed retry budget; records contexts.
struct NJobs {
    n: usize,
    retries: u32,
}

impl JobEngine for NJobs {
    type Ctx = usize;
    fn start(&mut self, jobs: &mut Jobs<'_, usize>) {
        for i in 0..self.n {
            jobs.submit(JobSpec::sleep(1.0).retries(self.retries), i);
        }
    }
    fn on_done(&mut self, _r: &TaskResult, _i: usize, _jobs: &mut Jobs<'_, usize>) {}
}

/// DES failure model: every attempt below `fail_attempts` exits 1. Purely
/// a function of `task.attempt`, so runs are deterministic.
struct FailFirst {
    fail_attempts: u32,
}

impl DurationModel for FailFirst {
    fn duration(&mut self, _t: &TaskSpec) -> f64 {
        1.0
    }
    fn results(&mut self, t: &TaskSpec) -> Vec<f64> {
        vec![t.id as f64]
    }
    fn rc(&mut self, t: &TaskSpec) -> i32 {
        if t.attempt < self.fail_attempts {
            1
        } else {
            0
        }
    }
}

// ---------------------------------------------------------------- retry

#[test]
fn retries_never_duplicate_results_property() {
    // For any (n, fail_attempts, retries): every task id yields exactly one
    // final result; its rc and attempt count follow from the retry budget.
    use caravan::testutil::{check, pair, usize_in};
    check(
        "retries never duplicate results for a task id",
        pair(pair(usize_in(1..40), usize_in(0..4)), pair(usize_in(0..4), usize_in(1..9))),
        |&((n, fail_attempts), (retries, np))| {
            let fail_attempts = fail_attempts as u32;
            let retries = retries as u32;
            let mut cfg = DesConfig::new(np);
            cfg.sched.consumers_per_buffer = 4;
            let r = run_des(
                &cfg,
                job_engine(NJobs { n, retries }),
                Box::new(FailFirst { fail_attempts }),
            );
            if r.results.len() != n {
                return false;
            }
            let mut ids: Vec<u64> = r.results.iter().map(|x| x.id).collect();
            ids.sort();
            ids.dedup();
            if ids.len() != n {
                return false;
            }
            let expected_attempt = fail_attempts.min(retries);
            r.results.iter().all(|x| {
                x.attempt == expected_attempt
                    && if fail_attempts <= retries { x.rc == 0 } else { x.rc == 1 }
            }) && r.retried() == (expected_attempt as u64) * n as u64
                && r.filling.overlap_violations() == 0
        },
    );
}

#[test]
fn des_retry_reports_attempts_in_deep_tree() {
    let mut cfg = DesConfig::new(16);
    cfg.sched.consumers_per_buffer = 4;
    cfg.sched.depth = 2;
    cfg.sched.fanout = vec![2];
    let r = run_des(&cfg, job_engine(NJobs { n: 64, retries: 2 }), Box::new(FailFirst {
        fail_attempts: 1,
    }));
    assert_eq!(r.results.len(), 64);
    assert!(r.results.iter().all(|x| x.ok() && x.attempt == 1));
    assert_eq!(r.retried(), 64);
}

#[test]
fn threaded_retry_succeeds_on_second_attempt() {
    // Executor failing every first attempt: with one retry allowed, every
    // task must come back ok with attempt == 1 — same semantics as the DES
    // test above, running on real threads.
    struct FlakyExec;
    impl Executor for FlakyExec {
        fn run(&self, task: &TaskSpec, _c: usize) -> (Vec<f64>, i32) {
            if task.attempt == 0 {
                (Vec::new(), 1)
            } else {
                (vec![task.id as f64], 0)
            }
        }
    }
    let cfg = SchedulerConfig {
        np: 4,
        consumers_per_buffer: 4,
        flush_interval_ms: 2,
        ..Default::default()
    };
    let report = run_scheduler(&cfg, job_engine(NJobs { n: 12, retries: 1 }), Arc::new(FlakyExec));
    assert_eq!(report.results.len(), 12);
    assert!(report.results.iter().all(|r| r.ok() && r.attempt == 1), "all succeed on retry");
    let mut ids: Vec<u64> = report.results.iter().map(|r| r.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 12, "no duplicated results under retry");
    let retried: u64 = report.node_stats.iter().map(|s| s.retried).sum();
    assert_eq!(retried, 12);
}

#[test]
fn threaded_retry_exhaustion_reports_failure() {
    struct AlwaysFail;
    impl Executor for AlwaysFail {
        fn run(&self, _t: &TaskSpec, _c: usize) -> (Vec<f64>, i32) {
            (Vec::new(), 7)
        }
    }
    let cfg = SchedulerConfig {
        np: 2,
        consumers_per_buffer: 2,
        flush_interval_ms: 2,
        ..Default::default()
    };
    let report = run_scheduler(&cfg, job_engine(NJobs { n: 6, retries: 2 }), Arc::new(AlwaysFail));
    assert_eq!(report.results.len(), 6);
    assert!(report.results.iter().all(|r| r.rc == 7 && r.attempt == 2));
}

// ---------------------------------------------------------------- timeout

#[test]
fn des_timeout_truncates_overrunning_attempts() {
    // Jobs whose nominal duration exceeds their budget are cut at the
    // budget with RC_TIMEOUT; with no retries the failure is final.
    struct TimedJobs;
    impl JobEngine for TimedJobs {
        type Ctx = ();
        fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
            for _ in 0..8 {
                jobs.submit(JobSpec::sleep(10.0).timeout(2.0), ());
            }
            for _ in 0..8 {
                jobs.submit(JobSpec::sleep(1.0).timeout(2.0), ());
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _c: (), _jobs: &mut Jobs<'_, ()>) {}
    }
    let cfg = DesConfig::new(4);
    let r = run_des(&cfg, job_engine(TimedJobs), Box::new(SleepDurations));
    assert_eq!(r.results.len(), 16);
    let timed_out: Vec<&TaskResult> = r.results.iter().filter(|x| x.rc == RC_TIMEOUT).collect();
    assert_eq!(timed_out.len(), 8);
    for t in &timed_out {
        assert!((t.duration() - 2.0).abs() < 1e-9, "attempt truncated at the budget");
        assert!(t.timed_out, "executor-enforced truncation must set the flag");
    }
    assert!(r.results.iter().filter(|x| x.ok()).count() == 8);
    assert!(r.results.iter().filter(|x| x.ok()).all(|x| !x.timed_out));
}

#[test]
fn threaded_timeout_truncates_sleep_attempts() {
    // Mirror of the DES truncation on real threads: SleepExecutor
    // enforces the per-attempt budget in virtual seconds (scaled like the
    // sleep itself), so the two runtimes agree on timeout semantics.
    struct TimedJobs;
    impl JobEngine for TimedJobs {
        type Ctx = ();
        fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
            for _ in 0..2 {
                jobs.submit(JobSpec::sleep(100.0).timeout(10.0), ()); // overruns
            }
            for _ in 0..2 {
                jobs.submit(JobSpec::sleep(1.0).timeout(10.0), ()); // inert budget
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _c: (), _jobs: &mut Jobs<'_, ()>) {}
    }
    let cfg = SchedulerConfig {
        np: 2,
        consumers_per_buffer: 2,
        flush_interval_ms: 2,
        time_scale: 0.002, // 100 virtual s = 200 ms real; budget = 20 ms
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let report = run_scheduler(
        &cfg,
        job_engine(TimedJobs),
        Arc::new(SleepExecutor { time_scale: 0.002 }),
    );
    assert_eq!(report.results.len(), 4);
    let timed: Vec<&TaskResult> =
        report.results.iter().filter(|x| x.rc == RC_TIMEOUT).collect();
    assert_eq!(timed.len(), 2, "both overrunning attempts must be cut at the budget");
    assert!(timed.iter().all(|x| x.timed_out && x.id < 2));
    assert!(report.results.iter().filter(|x| x.ok()).all(|x| x.id >= 2 && !x.timed_out));
    assert!(
        t0.elapsed() < std::time::Duration::from_millis(2000),
        "truncated attempts must not sleep their nominal 200 ms × retries"
    );
}

#[test]
fn user_exit_code_124_is_not_flagged_as_timeout() {
    // A simulated simulator that *returns* GNU timeout's exit code on its
    // own: the rc passes through as an ordinary failure, but `timed_out`
    // stays false — only executor-enforced budget kills set it — so the
    // job layer can tell the two apart (the codes collide by design).
    struct Exit124;
    impl DurationModel for Exit124 {
        fn duration(&mut self, _t: &TaskSpec) -> f64 {
            1.0
        }
        fn rc(&mut self, _t: &TaskSpec) -> i32 {
            124
        }
    }
    let cfg = DesConfig::new(2);
    let r = run_des(&cfg, job_engine(NJobs { n: 4, retries: 0 }), Box::new(Exit124));
    assert_eq!(r.results.len(), 4);
    for x in &r.results {
        assert_eq!(x.rc, RC_TIMEOUT, "the user's exit code is reported verbatim");
        assert!(!x.timed_out, "a legitimate exit 124 must not read as a framework timeout");
    }
}

// ---------------------------------------------------------------- policy

#[test]
fn deadline_policy_runs_least_slack_first() {
    // One consumer serializes execution. Jobs are submitted with budgets
    // in shuffled order; under SchedPolicy::Deadline they must start in
    // ascending-deadline order, with the budget-less job last (it has no
    // deadline pressure). Budgets are far above the actual waits, so
    // nothing really times out — only the *ordering* is under test.
    struct Tiers;
    impl JobEngine for Tiers {
        type Ctx = ();
        fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
            jobs.submit(JobSpec::sleep(1.0), ()); // id 0: no deadline
            jobs.submit(JobSpec::sleep(1.0).timeout(900.0), ()); // id 1
            jobs.submit(JobSpec::sleep(1.0).timeout(300.0), ()); // id 2
            jobs.submit(JobSpec::sleep(1.0).timeout(600.0), ()); // id 3
        }
        fn on_done(&mut self, _r: &TaskResult, _c: (), _jobs: &mut Jobs<'_, ()>) {}
    }
    let mut cfg = DesConfig::new(1);
    cfg.sched.consumers_per_buffer = 1;
    cfg.sched.policy = SchedPolicy::Deadline;
    let r = run_des(&cfg, job_engine(Tiers), Box::new(SleepDurations));
    assert_eq!(r.results.len(), 4);
    let begin = |id: u64| r.results.iter().find(|x| x.id == id).unwrap().begin;
    assert!(
        begin(2) < begin(3) && begin(3) < begin(1) && begin(1) < begin(0),
        "least slack first, no-deadline last: {:?}",
        (begin(0), begin(1), begin(2), begin(3))
    );
}

/// Sustained priority-9 stream: each completion spawns the next hi job
/// until `total` were created; a single priority-0 job rides along.
struct SustainedStream {
    total: usize,
    created: usize,
}

impl JobEngine for SustainedStream {
    type Ctx = ();
    fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
        jobs.submit(JobSpec::sleep(1.0), ()); // id 0: the priority-0 probe
        // A deep initial burst keeps the producer's pending queue stocked
        // with priority-9 work for the whole run, so under Strict the
        // probe can never slip out through a momentarily-empty band.
        for _ in 0..30 {
            jobs.submit(JobSpec::sleep(1.0).priority(9), ());
            self.created += 1;
        }
    }
    fn on_done(&mut self, _r: &TaskResult, _c: (), jobs: &mut Jobs<'_, ()>) {
        if self.created < self.total {
            jobs.submit(JobSpec::sleep(1.0).priority(9), ());
            self.created += 1;
        }
    }
}

fn stream_run(policy: SchedPolicy, total: usize) -> DesReport {
    let mut cfg = DesConfig::new(2);
    cfg.sched.consumers_per_buffer = 2;
    cfg.sched.policy = policy;
    run_des(&cfg, job_engine(SustainedStream { total, created: 0 }), Box::new(SleepDurations))
}

#[test]
fn aging_bounds_priority_zero_wait_under_sustained_high_stream() {
    // The bounded-wait property (deterministic in the DES): under Strict,
    // the priority-0 probe starves until the priority-9 stream dries up
    // (~150 virtual seconds: 300 one-second tasks on 2 consumers). With
    // Aging{step: 3}, the probe's effective priority climbs one level per
    // 3 s; the stream's effective priority is 9 plus the boost of its own
    // backlog head (≈ 26 queued tasks / 2 per second ≈ 13 s of wait), so
    // the probe overtakes it after roughly (9 + 13/3 + 1) × 3 ≈ 43 s —
    // several times earlier than Strict, and bounded by the formula, not
    // by the stream length.
    const TOTAL: usize = 300;
    let probe_begin = |r: &DesReport| {
        r.results.iter().find(|x| x.id == 0).expect("probe completed").begin
    };

    let strict = stream_run(SchedPolicy::Strict, TOTAL);
    assert_eq!(strict.results.len(), TOTAL + 1);
    let strict_begin = probe_begin(&strict);
    assert!(
        strict_begin > 120.0,
        "under Strict the probe must starve behind the stream (begin={strict_begin})"
    );

    let aging = stream_run(SchedPolicy::Aging { step: 3.0 }, TOTAL);
    assert_eq!(aging.results.len(), TOTAL + 1);
    let aging_begin = probe_begin(&aging);
    assert!(
        aging_begin < 80.0,
        "aging must bound the probe's wait to ~(9 + backlog/step + 1)*step (begin={aging_begin})"
    );
    assert!(aging_begin < strict_begin / 2.0, "{aging_begin} vs {strict_begin}");
    // The stream itself is barely disturbed: one probe task out of 300.
    assert!(aging.rate(2) > 0.9, "rate={}", aging.rate(2));
}

// ---------------------------------------------------------------- priority

#[test]
fn des_priority_orders_starts_exactly_on_single_leaf() {
    // Single leaf, everything submitted up front: with priority queues at
    // the producer and the leaf, no low-priority task may begin before any
    // high-priority one (ties at identical virtual times allowed).
    struct TwoTiers;
    impl JobEngine for TwoTiers {
        type Ctx = bool;
        fn start(&mut self, jobs: &mut Jobs<'_, bool>) {
            // Lows submitted first on purpose.
            for _ in 0..20 {
                jobs.submit(JobSpec::sleep(1.0), false);
            }
            for _ in 0..20 {
                jobs.submit(JobSpec::sleep(1.0).priority(9), true);
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _hi: bool, _jobs: &mut Jobs<'_, bool>) {}
    }
    let mut cfg = DesConfig::new(4);
    cfg.sched.consumers_per_buffer = 4; // one leaf
    let r = run_des(&cfg, job_engine(TwoTiers), Box::new(SleepDurations));
    assert_eq!(r.results.len(), 40);
    let max_high_begin = r
        .results
        .iter()
        .filter(|x| x.id >= 20)
        .map(|x| x.begin)
        .fold(f64::NEG_INFINITY, f64::max);
    let min_low_begin = r
        .results
        .iter()
        .filter(|x| x.id < 20)
        .map(|x| x.begin)
        .fold(f64::INFINITY, f64::min);
    assert!(
        max_high_begin <= min_low_begin + 1e-9,
        "every high-priority start ({max_high_begin}) must precede every low start ({min_low_begin})"
    );
}

#[test]
fn threaded_priority_orders_single_consumer() {
    // One consumer serializes execution; a long head task keeps the rest
    // queued while they are submitted. The high-priority tier must run
    // before the low tier regardless of submission order.
    struct HeadThenTiers;
    impl JobEngine for HeadThenTiers {
        type Ctx = u8;
        fn start(&mut self, jobs: &mut Jobs<'_, u8>) {
            jobs.submit(JobSpec::sleep(5.0).priority(10), 2);
            for _ in 0..3 {
                jobs.submit(JobSpec::sleep(1.0), 0);
            }
            for _ in 0..3 {
                jobs.submit(JobSpec::sleep(1.0).priority(5), 1);
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _tier: u8, _jobs: &mut Jobs<'_, u8>) {}
    }
    let cfg = SchedulerConfig {
        np: 1,
        consumers_per_buffer: 1,
        flush_interval_ms: 2,
        time_scale: 0.002,
        ..Default::default()
    };
    let report = run_scheduler(
        &cfg,
        job_engine(HeadThenTiers),
        Arc::new(caravan::scheduler::SleepExecutor { time_scale: 0.002 }),
    );
    assert_eq!(report.results.len(), 7);
    // ids: 0 = head, 1..=3 low, 4..=6 high.
    let begin_of = |id: u64| report.results.iter().find(|r| r.id == id).unwrap().begin;
    let max_high = (4..=6).map(begin_of).fold(f64::NEG_INFINITY, f64::max);
    let min_low = (1..=3).map(begin_of).fold(f64::INFINITY, f64::min);
    assert!(
        max_high < min_low,
        "high tier (last begin {max_high}) must fully precede low tier (first begin {min_low})"
    );
}

// ---------------------------------------------------------------- cancel

#[test]
fn des_cancel_drops_exactly_the_queued_targets() {
    // Single leaf, long distinct durations, flush_every = 1 so the first
    // completion reaches the engine while the queue state is still known
    // exactly: ids 0-3 running, 4 dispatched on completion of 0, 5-7
    // queued at the leaf, 8+ pending at the producer.
    struct CancelSome {
        fired: bool,
    }
    impl JobEngine for CancelSome {
        type Ctx = ();
        fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
            for i in 0..40 {
                jobs.submit(JobSpec::sleep(10.0 + i as f64), ());
            }
        }
        fn on_done(&mut self, r: &TaskResult, _c: (), jobs: &mut Jobs<'_, ()>) {
            if !self.fired {
                self.fired = true;
                assert_eq!(r.id, 0, "shortest task completes first");
                // 5 and 6 are queued at the leaf; 20..30 pending at the
                // producer; 1 is *running* — its attempt gets killed.
                jobs.cancel(5);
                jobs.cancel(6);
                for id in 20..30 {
                    jobs.cancel(id);
                }
                jobs.cancel(1);
            }
        }
    }
    let mut cfg = DesConfig::new(4);
    cfg.sched.consumers_per_buffer = 4;
    cfg.sched.flush_every = 1;
    let r = run_des(&cfg, job_engine(CancelSome { fired: false }), Box::new(SleepDurations));
    // Conservation: one result per id.
    assert_eq!(r.results.len(), 40);
    let mut ids: Vec<u64> = r.results.iter().map(|x| x.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 40);
    // Exactly the targets were cancelled: the queued ones dropped, the
    // running one (id 1) killed mid-attempt; everything never targeted
    // completed normally.
    let cancelled: Vec<u64> = {
        let mut v: Vec<u64> =
            r.results.iter().filter(|x| x.cancelled()).map(|x| x.id).collect();
        v.sort();
        v
    };
    let expected: Vec<u64> = [1u64, 5, 6].iter().copied().chain(20..30).collect();
    assert_eq!(cancelled, expected);
    // The killed attempt died long before its nominal 11-second duration.
    let killed = r.results.iter().find(|x| x.id == 1).unwrap();
    assert!(killed.finish - killed.begin < 11.0, "attempt truncated by the kill");
    // The two leaf-queued drops are visible in NodeStats; the producer
    // drops are not node drops; the kill is counted separately.
    let dropped_in_tree: u64 = r.node_stats.iter().map(|s| s.cancelled_dropped).sum();
    assert_eq!(dropped_in_tree, 2);
    assert_eq!(r.cancelled_killed(), 1);
    assert_eq!(r.cancelled(), 13);
}

/// Cancels the long job (id 0) as soon as the short one (id 1) completes —
/// at which point id 0 is certainly *running*, so the cancellation must
/// kill the attempt rather than find a queue entry.
struct CancelTheRunningOne {
    fired: bool,
}

impl JobEngine for CancelTheRunningOne {
    type Ctx = ();
    fn start(&mut self, jobs: &mut Jobs<'_, ()>) {
        jobs.submit(JobSpec::sleep(3000.0).retries(3), ()); // id 0
        jobs.submit(JobSpec::sleep(1.0), ()); // id 1
    }
    fn on_done(&mut self, r: &TaskResult, _c: (), jobs: &mut Jobs<'_, ()>) {
        if !self.fired && r.id == 1 {
            self.fired = true;
            jobs.cancel(0);
        }
    }
}

#[test]
fn des_cancel_kills_running_task_within_poll_interval() {
    // Two consumers: id 0 (3000 virtual seconds) runs on one, id 1 on the
    // other. The kill must land one cancellation poll after the notice
    // reaches the leaf — not at id 0's natural finish — and must not
    // consume a retry.
    let mut cfg = DesConfig::new(2);
    cfg.sched.consumers_per_buffer = 2;
    let r = run_des(
        &cfg,
        job_engine(CancelTheRunningOne { fired: false }),
        Box::new(SleepDurations),
    );
    assert_eq!(r.results.len(), 2);
    let killed = r.results.iter().find(|x| x.id == 0).expect("one result per id");
    assert!(killed.cancelled(), "running attempt must report RC_CANCELLED");
    assert_eq!(killed.attempt, 0, "kill-on-cancel must not consume a retry");
    assert!(
        killed.finish < 5.0,
        "killed within the poll interval of the notice, not at 3000 s (finish={})",
        killed.finish
    );
    assert_eq!(r.cancelled_killed(), 1);
    assert_eq!(r.cancelled(), 1);
    assert!(r.results.iter().find(|x| x.id == 1).unwrap().ok());
}

#[test]
fn threaded_cancel_kills_running_task_within_poll_interval() {
    // Real-thread mirror: at time_scale 0.001 the long job holds its
    // consumer for ~3 s unless the kill lands; the whole run finishing in
    // well under that proves the child was killed, and the stats show the
    // leaf requested exactly one kill.
    let cfg = SchedulerConfig {
        np: 2,
        consumers_per_buffer: 2,
        flush_interval_ms: 2,
        time_scale: 0.001,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let report = run_scheduler(
        &cfg,
        job_engine(CancelTheRunningOne { fired: false }),
        Arc::new(SleepExecutor { time_scale: 0.001 }),
    );
    assert_eq!(report.results.len(), 2);
    let killed = report.results.iter().find(|x| x.id == 0).expect("one result per id");
    assert!(killed.cancelled(), "running attempt must report RC_CANCELLED");
    assert_eq!(killed.attempt, 0, "kill-on-cancel must not consume a retry");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(2),
        "the 3 s attempt must be killed, not awaited"
    );
    let killed_stat: u64 = report.node_stats.iter().map(|s| s.cancelled_killed).sum();
    assert_eq!(killed_stat, 1);
}

// ---------------------------------------------------------- steal victims

fn steal_run(policy: StealPolicy, seed: u64) -> DesReport {
    let mut cfg = DesConfig::new(8);
    cfg.sched.consumers_per_buffer = 2; // 4 leaves
    cfg.sched.steal = true;
    cfg.sched.steal_policy = policy;
    run_des(
        &cfg,
        Box::new(TestCaseEngine::new(TestCase::TC2, 8 * 50, seed)),
        Box::new(SleepDurations),
    )
}

#[test]
fn deepest_queue_victims_fail_no_more_than_round_robin() {
    // Identical heavy-tailed workload under both victim-selection
    // policies: depth-aware selection must not produce *more* failed
    // (empty-grant) steal attempts, and typically produces fewer.
    let mut rr_failed = 0u64;
    let mut dq_failed = 0u64;
    for seed in [3u64, 11, 42] {
        let rr = steal_run(StealPolicy::RoundRobin, seed);
        let dq = steal_run(StealPolicy::DeepestQueue, seed);
        // Same workload completes under both policies.
        assert_eq!(rr.results.len(), 400, "seed {seed}");
        assert_eq!(dq.results.len(), 400, "seed {seed}");
        assert_eq!(rr.filling.overlap_violations(), 0);
        assert_eq!(dq.filling.overlap_violations(), 0);
        rr_failed += rr.steals_failed();
        dq_failed += dq.steals_failed();
    }
    println!("failed steal attempts: round-robin {rr_failed}, deepest-queue {dq_failed}");
    assert!(
        dq_failed <= rr_failed,
        "deepest-queue victim selection must not fail more often \
         (round-robin {rr_failed} vs deepest-queue {dq_failed})"
    );
}

// -------------------------------------------------- legacy sink adapter

#[test]
fn legacy_task_sink_path_still_works_through_job_sink() {
    // Old-style engines call `sink.submit(payload)` (the v1 TaskSink
    // method); it must behave exactly like a default JobSpec submission.
    struct Legacy;
    impl caravan::tasklib::SearchEngine for Legacy {
        fn start(&mut self, sink: &mut dyn JobSink) {
            for _ in 0..5 {
                sink.submit(Payload::Sleep { seconds: 1.0 });
            }
            sink.submit_job(JobSpec::sleep(1.0).priority(3));
        }
        fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
    }
    let r = run_des(&DesConfig::new(2), Box::new(Legacy), Box::new(SleepDurations));
    assert_eq!(r.results.len(), 6);
    assert!(r.results.iter().all(|x| x.ok() && x.attempt == 0));
}
