//! End-to-end distributed runs with the real `caravan` binary: a root
//! process (`caravan run --listen`) and `caravan worker` processes joined
//! over Unix-domain sockets. These are the process-boundary counterparts
//! of the in-crate `scheduler::net` tests — same protocol, real
//! `fork`/`exec`, real sockets, real crashes.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_caravan")
}

fn sock_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("caravan_{tag}_{}.sock", std::process::id()))
}

/// Wait for the root to bind its listening socket (it is created by
/// `Listener::bind` before `accept`, so existence means workers may dial).
fn wait_for_socket(sock: &PathBuf) {
    let t0 = Instant::now();
    while !sock.exists() {
        assert!(t0.elapsed() < Duration::from_secs(30), "root never bound {}", sock.display());
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn spawn_worker(sock: &PathBuf) -> Child {
    Command::new(bin())
        .args(["worker", &format!("uds:{}", sock.display())])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn caravan worker")
}

#[test]
fn uds_two_worker_sweep_completes_end_to_end() {
    let sock = sock_path("dist");
    let _ = std::fs::remove_file(&sock);
    let root = Command::new(bin())
        .args([
            "run",
            "sh -c 'true'",
            "--n",
            "24",
            "--np",
            "4",
            "--listen",
            &format!("uds:{}", sock.display()),
            "--workers",
            "2",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn caravan run --listen");
    wait_for_socket(&sock);
    let workers = [spawn_worker(&sock), spawn_worker(&sock)];

    let out = root.wait_with_output().expect("wait root");
    assert!(
        out.status.success(),
        "root failed: status {:?}\nstdout: {}\nstderr: {}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("24 tasks, 0 failures"),
        "unexpected root summary:\n{stdout}"
    );
    // Both links carried traffic and made it into the summary.
    assert_eq!(stdout.matches("link slot").count(), 2, "summary:\n{stdout}");

    for w in workers {
        let o = w.wait_with_output().expect("wait worker");
        let wout = String::from_utf8_lossy(&o.stdout);
        assert!(
            o.status.success(),
            "worker failed: {}\n{}",
            wout,
            String::from_utf8_lossy(&o.stderr)
        );
        assert!(wout.contains("worker slot"), "unexpected worker output:\n{wout}");
    }
    let _ = std::fs::remove_file(&sock);
}

#[test]
fn uds_run_survives_sigkilled_worker() {
    // The acceptance criterion of the dead-link design, at the process
    // level: SIGKILL one of three workers mid-run; the root must re-grant
    // that subtree's tasks over the surviving links and still report every
    // task completed. Timing is best-effort — if the kill lands after the
    // run drained, the test degenerates to the happy path and still holds.
    let sock = sock_path("kill");
    let _ = std::fs::remove_file(&sock);
    let root = Command::new(bin())
        .args([
            "run",
            "sh -c 'sleep 0.1'",
            "--n",
            "40",
            "--np",
            "6",
            "--listen",
            &format!("uds:{}", sock.display()),
            "--workers",
            "3",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn caravan run --listen");
    wait_for_socket(&sock);
    let survivor_a = spawn_worker(&sock);
    let survivor_b = spawn_worker(&sock);
    let mut victim = spawn_worker(&sock);

    // Let the victim handshake and take some grants, then kill -9 it.
    std::thread::sleep(Duration::from_millis(600));
    victim.kill().expect("SIGKILL victim");
    let _ = victim.wait();

    let out = root.wait_with_output().expect("wait root");
    assert!(
        out.status.success(),
        "root failed after worker kill: status {:?}\nstdout: {}\nstderr: {}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("40 tasks, 0 failures"),
        "killed worker lost tasks:\n{stdout}"
    );

    for w in [survivor_a, survivor_b] {
        let o = w.wait_with_output().expect("wait worker");
        assert!(
            o.status.success(),
            "surviving worker failed: {}\n{}",
            String::from_utf8_lossy(&o.stdout),
            String::from_utf8_lossy(&o.stderr)
        );
    }
    let _ = std::fs::remove_file(&sock);
}

#[test]
fn worker_refuses_bad_address() {
    let out = Command::new(bin())
        .args(["worker", "not-an-endpoint:::"])
        .output()
        .expect("run caravan worker");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("worker:"), "stderr should explain the parse failure: {err}");
}
