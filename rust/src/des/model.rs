//! Duration/result models for simulated task execution.
//!
//! In the DES a task does not actually run — a [`DurationModel`] decides
//! how long it takes in virtual time and what result vector it produces.
//! `Sleep` payloads carry their own duration; `Eval` payloads are resolved
//! by a model (e.g. random objectives for scheduler-behaviour studies, or
//! an actual in-process simulator for end-to-end DES optimization runs).

use crate::tasklib::{Payload, TaskSpec};
use crate::util::rng::Pcg64;

/// Decides virtual duration, results and exit status of a simulated task.
pub trait DurationModel: Send {
    fn duration(&mut self, task: &TaskSpec) -> f64;
    fn results(&mut self, task: &TaskSpec) -> Vec<f64> {
        let _ = task;
        Vec::new()
    }
    /// Exit status of the attempt (default 0 = success). The attempt index
    /// is visible as `task.attempt`, so failure-injection models can make
    /// the scheduler-side retry path deterministic.
    fn rc(&mut self, task: &TaskSpec) -> i32 {
        let _ = task;
        0
    }
}

/// `Sleep` tasks take exactly their nominal seconds; `Eval`/`Command`
/// payloads are rejected (use a model that understands them).
pub struct SleepDurations;

impl DurationModel for SleepDurations {
    fn duration(&mut self, task: &TaskSpec) -> f64 {
        match &task.payload {
            Payload::Sleep { seconds } => *seconds,
            other => panic!("SleepDurations cannot time {other:?}"),
        }
    }

    fn results(&mut self, task: &TaskSpec) -> Vec<f64> {
        match &task.payload {
            Payload::Sleep { seconds } => vec![*seconds],
            _ => Vec::new(),
        }
    }
}

/// Evaluation tasks take a random duration from `[lo, hi]` (uniform or the
/// paper's 30–50 min band) and produce `k` pseudo-random objective values
/// derived from the input point — used by the sync-vs-async NSGA-II
/// ablation, where only the *schedule* matters, not optimization progress.
pub struct ConstResults {
    pub lo: f64,
    pub hi: f64,
    pub k: usize,
    rng: Pcg64,
}

impl ConstResults {
    pub fn new(lo: f64, hi: f64, k: usize, seed: u64) -> Self {
        Self { lo, hi, k, rng: Pcg64::new(seed) }
    }
}

impl DurationModel for ConstResults {
    fn duration(&mut self, task: &TaskSpec) -> f64 {
        match &task.payload {
            Payload::Sleep { seconds } => *seconds,
            _ => self.rng.range_f64(self.lo, self.hi),
        }
    }

    fn results(&mut self, task: &TaskSpec) -> Vec<f64> {
        match &task.payload {
            Payload::Eval { input, seed } => {
                // Deterministic pseudo-objectives: hash of (input, seed).
                let mut h = *seed ^ 0x5851_F42D_4C95_7F2D;
                for x in input {
                    h ^= x.to_bits().rotate_left(17);
                    crate::util::rng::splitmix64(&mut h);
                }
                let mut r = Pcg64::new(h);
                (0..self.k).map(|_| r.uniform()).collect()
            }
            Payload::Sleep { seconds } => vec![*seconds],
            Payload::Command { .. } => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::TaskSpec;

    #[test]
    fn sleep_durations_pass_through() {
        let mut m = SleepDurations;
        let t = TaskSpec::new(0, Payload::Sleep { seconds: 42.5 });
        assert_eq!(m.duration(&t), 42.5);
        assert_eq!(m.results(&t), vec![42.5]);
    }

    #[test]
    #[should_panic(expected = "cannot time")]
    fn sleep_durations_reject_eval() {
        let mut m = SleepDurations;
        let t = TaskSpec::new(0, Payload::Eval { input: vec![], seed: 0 });
        m.duration(&t);
    }

    #[test]
    fn const_results_deterministic_per_input() {
        let mut m = ConstResults::new(1.0, 2.0, 3, 0);
        let t1 = TaskSpec::new(0, Payload::Eval { input: vec![0.5, 0.25], seed: 7 });
        let t2 = TaskSpec::new(9, Payload::Eval { input: vec![0.5, 0.25], seed: 7 });
        assert_eq!(m.results(&t1), m.results(&t2));
        let t3 = TaskSpec::new(9, Payload::Eval { input: vec![0.5, 0.25], seed: 8 });
        assert_ne!(m.results(&t1), m.results(&t3));
        assert_eq!(m.results(&t1).len(), 3);
    }

    #[test]
    fn const_results_duration_in_band() {
        let mut m = ConstResults::new(30.0, 50.0, 3, 1);
        let t = TaskSpec::new(0, Payload::Eval { input: vec![0.1], seed: 0 });
        for _ in 0..100 {
            let d = m.duration(&t);
            assert!((30.0..=50.0).contains(&d));
        }
    }
}
