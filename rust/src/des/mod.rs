//! Discrete-event simulation of the hierarchical scheduler in virtual time.
//!
//! The paper evaluates the scheduler on the K computer with up to 16 384
//! MPI processes and 1.6 million tasks (Fig. 3). This host has one core, so
//! we reproduce those experiments by *simulating the scheduler itself*: the
//! DES drives the exact protocol state machines of
//! [`crate::scheduler::protocol`] — the same code the threaded runtime
//! executes — with an explicit latency/overhead model
//! ([`crate::config::DesLatencyConfig`]):
//!
//! * every point-to-point message takes `msg_latency` to arrive — unless
//!   the edge it crosses has a per-edge override in
//!   [`DesLatencyConfig::link_latency`] (root-down, like
//!   [`SchedulerConfig::fanout`]), which models multi-host trees where
//!   e.g. the producer↔root edge is a WAN link to a `caravan worker`;
//! * the producer and each buffer-tree node are serial servers: handling a
//!   message occupies them for `producer_service` / `buffer_service`
//!   virtual seconds (messages queue while the entity is busy — this is
//!   what breaks a single-master design at scale, §3);
//! * starting a task costs `task_overhead` on the consumer (temp dir +
//!   `fork`/`exec` + result parsing, §3's reason sub-second tasks are out
//!   of scope);
//! * a batched dispatch ([`SchedulerConfig::dispatch_batch`] > 1) pays
//!   the message latency **once per batch** each way: the tasks run back
//!   to back (each still charged `task_overhead`), and all their results
//!   ride one `DoneBatch` event — so the throughput win of batching is
//!   modelled honestly and `choose_shape` calibration stays truthful.
//!   Likewise a coalesced `Flush` (credit request + result ascent,
//!   [`SchedulerConfig::coalesce_flush`]) is one message, not two.
//!
//! The buffer layer is an N-level tree ([`SchedulerConfig::depth`]): relay
//! nodes hold credit against their parent, batch results upstream, and may
//! steal queued tasks from a sibling — all driven here in virtual time, so
//! a depth-3 tree over 10⁵ simulated consumers runs in seconds of wall
//! clock and the resulting job filling rate (Eq. 1) is exact, not sampled.
//!
//! Because the DES runs the identical state machines, the Job API v2
//! semantics — priority ordering, transparent retry (a failed attempt's
//! `rc` comes from [`DurationModel::rc`]), per-attempt timeouts and
//! cancellation — are all testable deterministically here.
//!
//! Online re-shaping ([`SchedulerConfig::reshape`]) runs here too: a
//! periodic virtual-time tick feeds the shared reshape controller the
//! roots' live request→grant lag and the observed task durations; a
//! transition recalls the tree (drain), rebuilds it at the new shape
//! (graft) and re-grants the recalled tasks — all in virtual time, so
//! reshape runs are exactly reproducible.

mod model;

pub use model::{ConstResults, DurationModel, SleepDurations};

use std::cmp::Reverse;
// BTreeMap/BTreeSet, not HashMap/HashSet: the DES promises bit-identical
// replay, so every collection on an event path iterates in a fixed order
// (the `hash-iter` lint rule enforces this for the whole module).
use std::collections::{BTreeMap, BinaryHeap};

use crate::api::{JobSink, JobSpec};
use crate::config::{
    Calibration, DesLatencyConfig, SchedulerConfig, TreeNodeKind, TreeShape, TreeTopology,
};
use crate::scheduler::metrics::{FillingRate, LevelFill, NodeStats};
use crate::scheduler::protocol::{
    resolve_shape, BufferAction, BufferState, ProducerAction, ProducerState,
};
use crate::scheduler::reshape::{ReshapeController, ReshapeEvent};
use crate::tasklib::{
    Payload, SearchEngine, TaskId, TaskResult, TaskSink, TaskSpec, RC_CANCELLED, RC_TIMEOUT,
};

/// Virtual-time event payloads. `node` indexes the buffer tree.
#[derive(Debug)]
enum Ev {
    /// A root-level node asked the producer for tasks.
    ProdRequest { slot: usize, amount: usize },
    /// A root-level node flushed results to the producer.
    ProdResults { results: Vec<TaskResult> },
    /// Tasks arrive at a node (from its parent or the producer).
    NodeAssign { node: usize, tasks: Vec<TaskSpec> },
    /// Leaf consumer finished its whole dispatched batch; one `DoneBatch`
    /// arrives at its leaf node carrying every result. `epoch` matches
    /// the batch's [`RunningBatch::epoch`] — a kill-on-cancel truncates
    /// the batch, bumps the epoch and re-schedules this event, so a stale
    /// completion (the pre-kill schedule) is recognised and skipped.
    NodeDoneBatch { node: usize, consumer: usize, epoch: u64 },
    /// Coalesced credit request + result flush from child slot `child`
    /// arrives at its parent `node`.
    NodeFlush { node: usize, child: usize, amount: usize, results: Vec<TaskResult> },
    /// Coalesced credit request + result flush from root slot `slot`
    /// arrives at the producer.
    ProdFlush { slot: usize, amount: usize, results: Vec<TaskResult> },
    /// Interior child (slot `child`) asks its parent `node` for tasks.
    NodeRequest { node: usize, child: usize, amount: usize },
    /// Interior child flushes results to its parent `node`.
    NodeResults { node: usize, results: Vec<TaskResult> },
    /// Steal request from node id `thief` (sibling slot `thief_slot`)
    /// arrives at `node`.
    NodeSteal { node: usize, thief: usize, thief_slot: usize, amount: usize },
    /// Steal reply (possibly empty) arrives back at `node`, carrying the
    /// victim's pending cancellation notices alongside the loot.
    NodeStolen {
        node: usize,
        from_slot: usize,
        left: usize,
        cancels: Vec<TaskId>,
        tasks: Vec<TaskSpec>,
    },
    /// Cancellation notice arrives at a node.
    NodeCancel { node: usize, id: TaskId },
    /// Shutdown notice arrives at a node.
    NodeShutdown { node: usize },
    /// Recall notice (drain-and-graft transition) arrives at a node.
    NodeRecall { node: usize },
    /// Recalled tasks arrive at interior `node` from one of its children.
    NodeReturned { node: usize, tasks: Vec<TaskSpec> },
    /// Child slot `child` acked the recall to interior `node`.
    NodeRecallAck { node: usize, child: usize },
    /// Recalled tasks arrive back at the producer.
    ProdReturned { tasks: Vec<TaskSpec> },
    /// Root slot `slot` acked the recall to the producer.
    ProdRecallAck { slot: usize },
    /// Periodic reshape-controller wake-up (only with `--reshape`).
    ReshapeTick,
}

struct Scheduled {
    time: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // total_cmp, not partial_cmp().unwrap(): event times are never
        // NaN today, but the heap's total order must not depend on that.
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// DES run configuration.
#[derive(Clone, Debug)]
pub struct DesConfig {
    pub sched: SchedulerConfig,
    pub lat: DesLatencyConfig,
    /// Naive single-master mode (the §3 motivation ablation): the buffer
    /// logic runs *on the producer*, so every per-task message consumes
    /// producer service time and there is no batching layer between the
    /// master and the consumers.
    pub direct: bool,
}

impl DesConfig {
    pub fn new(np: usize) -> Self {
        Self {
            sched: SchedulerConfig { np, ..Default::default() },
            lat: DesLatencyConfig::default(),
            direct: false,
        }
    }
}

/// Outcome of a DES run (virtual-time analogue of `scheduler::Report`).
pub struct DesReport {
    pub results: Vec<TaskResult>,
    pub filling: FillingRate,
    /// Virtual makespan (first begin → last finish).
    pub makespan: f64,
    pub events_processed: u64,
    pub producer_msgs_in: u64,
    pub producer_msgs_out: u64,
    /// Peak queueing delay observed at the producer's serial server — the
    /// saturation indicator for the naive ablation.
    pub max_producer_lag: f64,
    /// Per-node counters of the buffer tree (indexed like
    /// [`TreeTopology::nodes`]) — of the *final* tree when online
    /// re-shaping replaced it mid-run.
    pub node_stats: Vec<NodeStats>,
    /// Counter snapshots of trees retired by drain-and-graft transitions
    /// (in retirement order; empty without `--reshape`). Conservation
    /// properties (Σ wait-hist counts == popped) hold per retired node.
    pub retired_node_stats: Vec<NodeStats>,
    /// Per-level filling statistics (mean/min subtree rate).
    pub level_fill: Vec<LevelFill>,
    /// Effective tree depth at the end of the run (resolved from
    /// [`crate::config::TreeShape`] — the auto controller's choice when
    /// shaping adaptively, possibly revised by `--reshape`).
    pub depth: usize,
    /// Effective per-level interior fanout at the end of the run
    /// (root-down; empty for the flat layout).
    pub fanout: Vec<usize>,
    /// Drain-and-graft transitions executed by the reshape controller.
    pub reshapes: Vec<ReshapeEvent>,
}

impl DesReport {
    pub fn rate(&self, np: usize) -> f64 {
        self.filling.rate(np)
    }

    /// Total sibling-steal traffic (tasks moved sideways).
    pub fn tasks_stolen(&self) -> u64 {
        self.node_stats.iter().map(|s| s.steals_received).sum()
    }

    /// Steal attempts that came back empty, tree-wide.
    pub fn steals_failed(&self) -> u64 {
        self.node_stats.iter().map(|s| s.steals_failed).sum()
    }

    /// Results that were cancelled before running.
    pub fn cancelled(&self) -> usize {
        self.results.iter().filter(|r| r.cancelled()).count()
    }

    /// Failed attempts transparently retried, tree-wide.
    pub fn retried(&self) -> u64 {
        self.node_stats.iter().map(|s| s.retried).sum()
    }

    /// Kill requests issued for running attempts, tree-wide (a request
    /// may lose the race to the attempt's natural completion).
    pub fn cancelled_killed(&self) -> u64 {
        self.node_stats.iter().map(|s| s.cancelled_killed).sum()
    }
}

struct MintSink<'a> {
    next_id: &'a mut u64,
    staged: &'a mut Vec<TaskSpec>,
    cancels: &'a mut Vec<TaskId>,
}

impl TaskSink for MintSink<'_> {
    fn submit(&mut self, payload: Payload) -> u64 {
        self.submit_job(JobSpec::new(payload))
    }
}

impl JobSink for MintSink<'_> {
    fn submit_job(&mut self, spec: JobSpec) -> u64 {
        let id = *self.next_id;
        *self.next_id += 1;
        self.staged.push(spec.into_task(id));
        id
    }

    fn cancel(&mut self, id: TaskId) {
        self.cancels.push(id);
    }
}

/// The mutable state threaded through the event loop.
struct Des<'a> {
    cfg: &'a DesConfig,
    topo: TreeTopology,
    producer: ProducerState,
    nodes: Vec<BufferState>,
    heap: BinaryHeap<Reverse<Scheduled>>,
    seq: u64,
    prod_free: f64,
    node_free: Vec<f64>,
    max_producer_lag: f64,
    next_id: u64,
    staged: Vec<TaskSpec>,
    pending_cancels: Vec<TaskId>,
    filling: FillingRate,
    all_results: Vec<TaskResult>,
    events: u64,
    engine: Box<dyn SearchEngine>,
    durations: Box<dyn DurationModel>,
    /// Online re-shaping (only with [`SchedulerConfig::reshape`]).
    controller: Option<ReshapeController>,
    /// Stats of trees retired by drain-and-graft transitions.
    retired_stats: Vec<NodeStats>,
    /// `(node, consumer)` → the batch of attempts currently dispatched
    /// there, in execution order — the state kill-on-cancel needs to
    /// truncate an in-flight (or skip a still-queued) execution.
    running: BTreeMap<(usize, usize), RunningBatch>,
    /// Monotonic counter minting [`RunningBatch::epoch`] values.
    next_epoch: u64,
}

/// One consumer's dispatched batch: the pre-computed outcome of every
/// attempt, executed back to back in virtual time.
struct RunningBatch {
    /// Guard against stale [`Ev::NodeDoneBatch`] events: bumped whenever a
    /// kill re-times the batch.
    epoch: u64,
    items: Vec<BatchItem>,
}

/// Pre-computed outcome of one attempt inside a [`RunningBatch`].
struct BatchItem {
    id: TaskId,
    attempt: u32,
    begin: f64,
    finish: f64,
    rc: i32,
    results: Vec<f64>,
    timed_out: bool,
}

impl<'a> Des<'a> {
    fn push(&mut self, time: f64, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { time, seq: self.seq, ev }));
    }

    /// Serial-server timing for the producer: message arriving at `arrival`
    /// is handled when the producer is free, occupying it for the service
    /// time. Returns the handling-complete time.
    fn producer_serve(&mut self, arrival: f64) -> f64 {
        let t = self.prod_free.max(arrival) + self.cfg.lat.producer_service;
        self.max_producer_lag = self.max_producer_lag.max(t - arrival);
        self.prod_free = t;
        t
    }

    /// Serial-server timing for node `n`; in direct mode buffer work runs
    /// on the producer's server (single-master ablation).
    fn node_serve(&mut self, n: usize, arrival: f64) -> f64 {
        if self.cfg.direct {
            self.producer_serve(arrival)
        } else {
            let t = self.node_free[n].max(arrival) + self.cfg.lat.buffer_service;
            self.node_free[n] = t;
            t
        }
    }

    fn perform_producer(&mut self, acts: Vec<ProducerAction>, t: f64) {
        // Everything the producer sends travels the producer↔root edge.
        let lat = self.cfg.lat.edge_latency(1);
        for act in acts {
            match act {
                ProducerAction::SendTasks { buffer, tasks } => {
                    let node = self.topo.roots[buffer];
                    self.push(t + lat, Ev::NodeAssign { node, tasks });
                }
                ProducerAction::BroadcastCancel { id } => {
                    let roots = self.topo.roots.clone();
                    for node in roots {
                        self.push(t + lat, Ev::NodeCancel { node, id });
                    }
                }
                ProducerAction::BroadcastRecall => {
                    let roots = self.topo.roots.clone();
                    for node in roots {
                        self.push(t + lat, Ev::NodeRecall { node });
                    }
                }
                ProducerAction::BroadcastShutdown => {
                    let roots = self.topo.roots.clone();
                    for node in roots {
                        self.push(t + lat, Ev::NodeShutdown { node });
                    }
                }
            }
        }
    }

    fn perform_node(&mut self, n: usize, acts: Vec<BufferAction>, t: f64) {
        // Three distinct links meet at a tree node: the edge up to its
        // parent (which siblings also share for steal traffic), the edges
        // down to its children, and — for leaves — the consumer-facing
        // edge. Consumers are co-located with their leaf, so that last one
        // always costs the baseline `msg_latency`; the tree edges take the
        // per-edge override so a multi-host shape is visible to the model.
        let level = self.topo.nodes[n].level;
        let up = self.cfg.lat.edge_latency(level);
        let down = self.cfg.lat.edge_latency(level + 1);
        let lat = self.cfg.lat.msg_latency;
        let overhead = self.cfg.lat.task_overhead;
        let parent = self.topo.nodes[n].parent;
        let slot = self.topo.nodes[n].slot;
        for act in acts {
            match act {
                BufferAction::RunBatch { consumer, tasks } => {
                    // The batch pays the dispatch latency once; tasks then
                    // run back to back, each charged `task_overhead` — the
                    // honestly-modelled win of batched dispatch. One
                    // `NodeDoneBatch` rides back after the last finish.
                    let mut begin = t + lat + overhead;
                    let mut items = Vec::with_capacity(tasks.len());
                    for task in tasks {
                        let mut dur = self.durations.duration(&task);
                        let mut rc = self.durations.rc(&task);
                        let mut results =
                            if rc == 0 { self.durations.results(&task) } else { Vec::new() };
                        // Per-attempt budget: the attempt is cut short and
                        // reported as a timeout failure (retryable like any
                        // other failure). Only this executor-side truncation
                        // sets `timed_out` — a duration model returning
                        // RC_TIMEOUT of its own accord simulates a user
                        // simulator that happens to exit 124.
                        let mut timed_out = false;
                        if let Some(to) = task.timeout_s {
                            if dur > to {
                                dur = to;
                                rc = RC_TIMEOUT;
                                results = Vec::new();
                                timed_out = true;
                            }
                        }
                        let finish = begin + dur;
                        items.push(BatchItem {
                            id: task.id,
                            attempt: task.attempt,
                            begin,
                            finish,
                            rc,
                            results,
                            timed_out,
                        });
                        begin = finish + overhead;
                    }
                    let Some(last_finish) = items.last().map(|it| it.finish) else { continue };
                    self.next_epoch += 1;
                    let epoch = self.next_epoch;
                    self.running.insert((n, consumer), RunningBatch { epoch, items });
                    self.push(last_finish + lat, Ev::NodeDoneBatch { node: n, consumer, epoch });
                }
                BufferAction::SendToChild { child, tasks } => {
                    let child_id = self.topo.children_of(n)[child];
                    self.push(t + down, Ev::NodeAssign { node: child_id, tasks });
                }
                BufferAction::RequestTasks { amount } => match parent {
                    None => self.push(t + up, Ev::ProdRequest { slot, amount }),
                    Some(p) => {
                        self.push(t + up, Ev::NodeRequest { node: p, child: slot, amount })
                    }
                },
                BufferAction::FlushResults(results) => {
                    if !results.is_empty() {
                        match parent {
                            None => self.push(t + up, Ev::ProdResults { results }),
                            Some(p) => self.push(t + up, Ev::NodeResults { node: p, results }),
                        }
                    }
                }
                BufferAction::Flush { amount, results } => match parent {
                    None => self.push(t + up, Ev::ProdFlush { slot, amount, results }),
                    Some(p) => {
                        self.push(t + up, Ev::NodeFlush { node: p, child: slot, amount, results })
                    }
                },
                BufferAction::StealRequest { victim, amount } => {
                    // Sideways traffic rides the shared parent-facing link.
                    let victim_id = match parent {
                        None => self.topo.roots[victim],
                        Some(p) => self.topo.children_of(p)[victim],
                    };
                    self.push(
                        t + up,
                        Ev::NodeSteal { node: victim_id, thief: n, thief_slot: slot, amount },
                    );
                }
                BufferAction::StealGrant { thief, from_slot, left, cancels, tasks } => {
                    self.push(
                        t + up,
                        Ev::NodeStolen { node: thief, from_slot, left, cancels, tasks },
                    );
                }
                BufferAction::CancelRunning { consumer, id } => {
                    // Kill-on-cancel in virtual time: if the targeted
                    // attempt is still in flight once the cancellation
                    // poll fires, truncate it to a RC_CANCELLED outcome
                    // at the poll instant; if it is still *queued* inside
                    // the batch, it is skipped at its turn (zero-duration
                    // cancelled result — the consumer-side pre-run check
                    // of the threaded runtime). Later items shift earlier
                    // by the time saved, the epoch is bumped and the
                    // batch completion re-scheduled; the stale one is
                    // skipped on arrival. A kill arriving after the
                    // natural finish loses the race — the attempt
                    // completes normally, exactly as in the threaded
                    // runtime.
                    let kill_t = t + self.cfg.lat.cancel_poll;
                    if let Some(batch) = self.running.get_mut(&(n, consumer)) {
                        let Some(pos) = batch.items.iter().position(|it| it.id == id) else {
                            continue;
                        };
                        if kill_t >= batch.items[pos].finish {
                            continue; // lost the race to the natural finish
                        }
                        {
                            let it = &mut batch.items[pos];
                            it.finish = kill_t.max(it.begin);
                            it.rc = RC_CANCELLED;
                            it.results = Vec::new();
                            it.timed_out = false;
                        }
                        let mut begin = batch.items[pos].finish + overhead;
                        for it in batch.items.iter_mut().skip(pos + 1) {
                            let dur = it.finish - it.begin;
                            it.begin = begin;
                            it.finish = begin + dur;
                            begin = it.finish + overhead;
                        }
                        self.next_epoch += 1;
                        batch.epoch = self.next_epoch;
                        let epoch = batch.epoch;
                        let last_finish =
                            batch.items.last().map(|it| it.finish).unwrap_or(kill_t);
                        self.push(
                            last_finish + lat,
                            Ev::NodeDoneBatch { node: n, consumer, epoch },
                        );
                    }
                }
                BufferAction::CancelChildren { id } => {
                    let children = self.topo.children_of(n).to_vec();
                    for child_id in children {
                        self.push(t + down, Ev::NodeCancel { node: child_id, id });
                    }
                }
                BufferAction::ShutdownConsumers => {
                    // Consumers are passive in the DES; nothing to schedule.
                }
                BufferAction::ShutdownChildren => {
                    let children = self.topo.children_of(n).to_vec();
                    for child_id in children {
                        self.push(t + down, Ev::NodeShutdown { node: child_id });
                    }
                }
                BufferAction::ReturnTasks(tasks) => match parent {
                    None => self.push(t + up, Ev::ProdReturned { tasks }),
                    Some(p) => self.push(t + up, Ev::NodeReturned { node: p, tasks }),
                },
                BufferAction::RecallChildren => {
                    let children = self.topo.children_of(n).to_vec();
                    for child_id in children {
                        self.push(t + down, Ev::NodeRecall { node: child_id });
                    }
                }
                BufferAction::AckRecall => match parent {
                    None => self.push(t + up, Ev::ProdRecallAck { slot }),
                    Some(p) => self.push(t + up, Ev::NodeRecallAck { node: p, child: slot }),
                },
            }
        }
    }

    /// Flush engine-staged submissions and cancellations into the producer
    /// state machine, then re-check termination. Cancellations that drop a
    /// still-pending task synthesize their `RC_CANCELLED` result here and
    /// feed it straight back to the engine, which may stage more work —
    /// hence the loop.
    fn pump_engine(&mut self, t: f64) {
        while !self.staged.is_empty() || !self.pending_cancels.is_empty() {
            let acts = self.producer.push_tasks(std::mem::take(&mut self.staged));
            self.perform_producer(acts, t);
            for id in std::mem::take(&mut self.pending_cancels) {
                let (dropped, acts) = self.producer.on_cancel(id);
                self.perform_producer(acts, t);
                if let Some(spec) = dropped {
                    let r = TaskResult::cancelled_for(&spec);
                    {
                        let mut sink = MintSink {
                            next_id: &mut self.next_id,
                            staged: &mut self.staged,
                            cancels: &mut self.pending_cancels,
                        };
                        self.engine.on_done(&r, &mut sink);
                    }
                    self.all_results.push(r);
                }
            }
        }
        let sd = self.producer.maybe_shutdown();
        self.perform_producer(sd, t);
    }

    /// Run engine callbacks for a result batch, then hand any newly staged
    /// tasks to the producer.
    fn producer_ingest(&mut self, results: Vec<TaskResult>, t: f64) {
        self.producer.on_results(results.len());
        self.ingest_results(results, t);
    }

    /// Engine-side half of result ingestion — the producer state machine
    /// has already accounted for the message (`on_results` or `on_flush`).
    fn ingest_results(&mut self, results: Vec<TaskResult>, t: f64) {
        if let Some(ctrl) = self.controller.as_mut() {
            for r in &results {
                ctrl.observe_result(r);
            }
        }
        {
            let mut sink = MintSink {
                next_id: &mut self.next_id,
                staged: &mut self.staged,
                cancels: &mut self.pending_cancels,
            };
            for r in &results {
                // Cancelled tasks never ran: keep them out of the trace.
                if !r.cancelled() {
                    self.filling.record(r);
                }
                self.engine.on_done(r, &mut sink);
            }
        }
        self.all_results.extend(results);
        self.pump_engine(t);
    }

    /// All roots acked the recall: the old tree is empty. Retire its
    /// stats, rebuild at the controller's shape, rewire the producer and
    /// prime the new nodes — the drain-and-graft "graft" half.
    fn graft(&mut self, t: f64) {
        let shape = match &self.controller {
            Some(c) => c.shape().clone(),
            None => return,
        };
        if self.producer.shutdown_sent() {
            // The run finished while the drain completed: nothing to graft.
            return;
        }
        let retiring: Vec<NodeStats> = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, s)| s.stats(i, self.topo.nodes[i].level))
            .collect();
        self.retired_stats.extend(retiring);
        let (depth, fans) = shape;
        self.topo = TreeTopology::build(
            self.cfg.sched.np,
            self.cfg.sched.consumers_per_buffer,
            depth,
            &fans,
        );
        let n_nodes = self.topo.n_nodes();
        self.nodes =
            (0..n_nodes).map(|i| BufferState::for_tree_node(&self.topo, i, &self.cfg.sched)).collect();
        self.node_free = vec![0.0; n_nodes];
        self.producer.rewire(self.topo.roots.len());
        if let Some(c) = self.controller.as_mut() {
            c.grafted(t);
        }
        for n in 0..n_nodes {
            self.nodes[n].set_now(t);
            let acts = self.nodes[n].on_start();
            self.perform_node(n, acts, t);
        }
        // The producer may already be quiescent (everything completed
        // while draining): re-check so the new tree still shuts down.
        let sd = self.producer.maybe_shutdown();
        self.perform_producer(sd, t);
    }
}

/// Duration-model samples the DES calibration takes from the engine's
/// first staged tasks.
const CAL_SAMPLE: usize = 32;

/// The DES side of the [`crate::config::TreeShape::Auto`] calibration
/// phase, exact and deterministic in virtual time: the latency model gives
/// the unloaded producer round trip (two hops + one service), and the mean
/// task duration is sampled from the duration model over the engine's
/// first staged tasks. (Sampling advances stochastic duration models by up
/// to [`CAL_SAMPLE`] draws; runs remain fully deterministic.)
fn des_calibration(
    lat: &DesLatencyConfig,
    staged: &[TaskSpec],
    durations: &mut dyn DurationModel,
) -> Calibration {
    // The round trip crosses the producer↔root edge twice, so a slow root
    // link (a remote worker host) raises the RTT and `choose_shape` buys
    // more batching depth — the calibration sees the multi-host topology.
    let producer_rtt = 2.0 * lat.edge_latency(1) + lat.producer_service;
    let sample: Vec<f64> = staged.iter().take(CAL_SAMPLE).map(|t| durations.duration(t)).collect();
    let mean_task_s = if sample.is_empty() {
        Calibration::fallback().mean_task_s
    } else {
        sample.iter().sum::<f64>() / sample.len() as f64
    };
    Calibration { producer_rtt, mean_task_s }
}

/// Run `engine`'s workload through the simulated scheduler.
pub fn run_des(
    cfg: &DesConfig,
    mut engine: Box<dyn SearchEngine>,
    mut durations: Box<dyn DurationModel>,
) -> DesReport {
    let np = cfg.sched.np;
    // Stage the engine's initial submissions up front: adaptive shaping
    // samples their durations during its calibration phase.
    let mut next_id = 0u64;
    let mut staged: Vec<TaskSpec> = Vec::new();
    let mut pending_cancels: Vec<TaskId> = Vec::new();
    {
        let mut sink = MintSink {
            next_id: &mut next_id,
            staged: &mut staged,
            cancels: &mut pending_cancels,
        };
        engine.start(&mut sink);
    }
    // Direct mode: a single leaf holding every consumer, with its message
    // handling charged to the producer's serial server.
    let (topo, shape, measured) = if cfg.direct {
        (
            TreeTopology::build(np, np, 1, &cfg.sched.fanout),
            (1, Vec::new()),
            Calibration::fallback(),
        )
    } else {
        // Only TreeShape::Auto pays for a measurement (sampling advances
        // stochastic duration models); Manual and Calibrated resolve from
        // the config alone.
        let measured = if matches!(cfg.sched.shape, TreeShape::Auto) {
            des_calibration(&cfg.lat, &staged, durations.as_mut())
        } else {
            Calibration::fallback()
        };
        let (depth, fans) = resolve_shape(&cfg.sched, measured);
        let topo = TreeTopology::build(np, cfg.sched.consumers_per_buffer, depth, &fans);
        (topo, (depth, fans), measured)
    };
    let n_nodes = topo.n_nodes();

    // Online re-shaping: the controller's drift reference is whatever
    // calibration chose the initial shape. Direct mode pins the topology
    // (single-master ablation), so re-shaping is disabled there.
    let reference_cal = match cfg.sched.shape {
        TreeShape::Calibrated(c) => c,
        _ => measured,
    };
    let controller = match (&cfg.sched.reshape, cfg.direct) {
        (Some(p), false) => {
            Some(ReshapeController::new(&cfg.sched, *p, shape.clone(), reference_cal, 0.0))
        }
        _ => None,
    };

    let mut des = Des {
        cfg,
        producer: ProducerState::new(topo.roots.len())
            .with_policy(cfg.sched.policy)
            .with_classes(cfg.sched.class_table()),
        nodes: (0..n_nodes).map(|i| BufferState::for_tree_node(&topo, i, &cfg.sched)).collect(),
        topo,
        heap: BinaryHeap::new(),
        seq: 0,
        prod_free: 0.0,
        node_free: vec![0.0; n_nodes],
        max_producer_lag: 0.0,
        next_id,
        staged,
        pending_cancels,
        filling: FillingRate::new(),
        all_results: Vec::new(),
        events: 0,
        engine,
        durations,
        controller,
        retired_stats: Vec::new(),
        running: BTreeMap::new(),
        next_epoch: 0,
    };

    // Bootstrap: producer intake, buffer credit requests.
    des.producer.set_engine_done(true);
    // Also covers the degenerate case of an engine submitting nothing.
    des.pump_engine(0.0);
    for n in 0..n_nodes {
        let acts = des.nodes[n].on_start();
        des.perform_node(n, acts, 0.0);
    }
    if des.controller.is_some() {
        let window = cfg.sched.reshape.as_ref().map(|p| p.window).unwrap_or(1.0).max(1e-9);
        des.push(window, Ev::ReshapeTick);
    }

    // Main loop.
    while let Some(Reverse(Scheduled { time, ev, .. })) = des.heap.pop() {
        des.events += 1;
        match ev {
            Ev::ProdRequest { slot, amount } => {
                let t = des.producer_serve(time);
                des.producer.set_now(t);
                let acts = des.producer.on_request(slot, amount);
                des.perform_producer(acts, t);
                let sd = des.producer.maybe_shutdown();
                des.perform_producer(sd, t);
            }
            Ev::ProdResults { results } => {
                let t = des.producer_serve(time);
                des.producer.set_now(t);
                des.producer_ingest(results, t);
            }
            Ev::NodeAssign { node, tasks } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_assign(tasks);
                des.perform_node(node, acts, t);
            }
            Ev::NodeDoneBatch { node, consumer, epoch } => {
                // A completion re-timed by kill-on-cancel: the bumped
                // epoch identifies the live schedule; stale events (the
                // pre-kill timing) are skipped here.
                match des.running.get(&(node, consumer)) {
                    Some(b) if b.epoch == epoch => {}
                    _ => continue,
                }
                let Some(batch) = des.running.remove(&(node, consumer)) else { continue };
                let rank_base = match &des.topo.nodes[node].kind {
                    TreeNodeKind::Leaf { rank_base, .. } => *rank_base,
                    TreeNodeKind::Interior { .. } => unreachable!("DoneBatch at interior"),
                };
                let results: Vec<TaskResult> = batch
                    .items
                    .into_iter()
                    .map(|it| TaskResult {
                        id: it.id,
                        consumer: rank_base + consumer,
                        results: it.results,
                        begin: it.begin,
                        finish: it.finish,
                        rc: it.rc,
                        attempt: it.attempt,
                        timed_out: it.timed_out,
                    })
                    .collect();
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_done_batch(consumer, results);
                des.perform_node(node, acts, t);
            }
            Ev::NodeFlush { node, child, amount, results } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_child_flush(child, amount, results);
                des.perform_node(node, acts, t);
            }
            Ev::ProdFlush { slot, amount, results } => {
                let t = des.producer_serve(time);
                des.producer.set_now(t);
                let acts = des.producer.on_flush(slot, amount, results.len());
                des.perform_producer(acts, t);
                des.ingest_results(results, t);
            }
            Ev::NodeRequest { node, child, amount } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_child_request(child, amount);
                des.perform_node(node, acts, t);
            }
            Ev::NodeResults { node, results } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_child_results(results);
                des.perform_node(node, acts, t);
            }
            Ev::NodeSteal { node, thief, thief_slot, amount } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_steal_request(thief, thief_slot, amount);
                des.perform_node(node, acts, t);
            }
            Ev::NodeStolen { node, from_slot, left, cancels, tasks } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_steal_grant(from_slot, left, cancels, tasks);
                des.perform_node(node, acts, t);
            }
            Ev::NodeCancel { node, id } => {
                // A cancel broadcast can race a drain-and-graft: notices
                // addressed to a retired tree die with it (cancellation
                // stays best-effort; the task is back at the producer).
                if node >= des.nodes.len() {
                    continue;
                }
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_cancel(id);
                des.perform_node(node, acts, t);
            }
            Ev::NodeShutdown { node } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_shutdown();
                des.perform_node(node, acts, t);
            }
            Ev::NodeRecall { node } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_recall();
                des.perform_node(node, acts, t);
            }
            Ev::NodeReturned { node, tasks } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_child_returned(tasks);
                des.perform_node(node, acts, t);
            }
            Ev::NodeRecallAck { node, child } => {
                let t = des.node_serve(node, time);
                des.nodes[node].set_now(t);
                let acts = des.nodes[node].on_child_recall_ack(child);
                des.perform_node(node, acts, t);
            }
            Ev::ProdReturned { tasks } => {
                let t = des.producer_serve(time);
                des.producer.set_now(t);
                des.producer.on_returned(tasks);
            }
            Ev::ProdRecallAck { slot } => {
                let t = des.producer_serve(time);
                des.producer.set_now(t);
                if des.producer.on_recall_ack(slot) {
                    des.graft(t);
                }
            }
            Ev::ReshapeTick => {
                // Pure bookkeeping at rank 0: no server time is charged,
                // and the cadence is fixed, so runs stay deterministic.
                if des.heap.is_empty() {
                    // Nothing else can ever happen: the run is over.
                    continue;
                }
                let window =
                    des.cfg.sched.reshape.as_ref().map(|p| p.window).unwrap_or(1.0).max(1e-9);
                des.push(time + window, Ev::ReshapeTick);
                if des.producer.is_recalling() || des.producer.shutdown_sent() {
                    continue;
                }
                let (mut lag_n, mut lag_sum) = (0u64, 0.0f64);
                for &r in &des.topo.roots {
                    let (n, s) = des.nodes[r].req_lag_totals();
                    lag_n += n;
                    lag_sum += s;
                }
                let fire = match des.controller.as_mut() {
                    Some(ctrl) => {
                        ctrl.observe_root_lag(lag_n, lag_sum);
                        ctrl.observe_class_mix(&des.producer.class_stats());
                        ctrl.maybe_reshape(time).is_some()
                    }
                    None => false,
                };
                if fire {
                    let acts = des.producer.begin_recall();
                    des.perform_producer(acts, time);
                }
            }
        }
    }
    des.engine.finish();

    let makespan = des.filling.makespan();
    let node_stats: Vec<NodeStats> = des
        .nodes
        .iter()
        .enumerate()
        .map(|(i, s)| s.stats(i, des.topo.nodes[i].level))
        .collect();
    let level_fill = des.filling.level_fill(&des.topo);
    let (depth, fanout) = match &des.controller {
        Some(c) => c.shape().clone(),
        None => shape,
    };
    let reshapes = des.controller.as_ref().map(|c| c.events().to_vec()).unwrap_or_default();
    DesReport {
        results: des.all_results,
        filling: des.filling,
        makespan,
        events_processed: des.events,
        producer_msgs_in: des.producer.msgs_in,
        producer_msgs_out: des.producer.msgs_out,
        max_producer_lag: des.max_producer_lag,
        node_stats,
        retired_node_stats: des.retired_stats,
        level_fill,
        depth,
        fanout,
        reshapes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{TestCase, TestCaseEngine};

    fn des(np: usize, case: TestCase, n: usize) -> DesReport {
        let cfg = DesConfig::new(np);
        run_des(&cfg, Box::new(TestCaseEngine::new(case, n, 7)), Box::new(SleepDurations))
    }

    #[test]
    fn tc1_small_runs_all_tasks_with_high_filling() {
        let r = des(16, TestCase::TC1, 1600);
        assert_eq!(r.results.len(), 1600);
        assert_eq!(r.filling.overlap_violations(), 0);
        let rate = r.rate(16);
        assert!(rate > 0.95, "rate={rate}");
        assert!(rate <= 1.0 + 1e-9);
    }

    #[test]
    fn tc2_heavy_tail_still_fills_well() {
        let r = des(16, TestCase::TC2, 1600);
        assert_eq!(r.results.len(), 1600);
        let rate = r.rate(16);
        assert!(rate > 0.90, "rate={rate}");
    }

    #[test]
    fn tc3_dynamic_generation_completes_exactly_n() {
        let r = des(16, TestCase::TC3, 1600);
        assert_eq!(r.results.len(), 1600);
        let rate = r.rate(16);
        assert!(rate > 0.85, "rate={rate}");
    }

    #[test]
    fn empty_engine_terminates_cleanly() {
        let cfg = DesConfig::new(4);
        let r = des_empty(&cfg);
        assert!(r.results.is_empty());
        assert_eq!(r.makespan, 0.0);
    }

    fn des_empty(cfg: &DesConfig) -> DesReport {
        struct Nothing;
        impl SearchEngine for Nothing {
            fn start(&mut self, _s: &mut dyn JobSink) {}
            fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
        }
        run_des(cfg, Box::new(Nothing), Box::new(SleepDurations))
    }

    #[test]
    fn task_ids_unique_and_complete() {
        let r = des(8, TestCase::TC3, 400);
        let mut ids: Vec<u64> = r.results.iter().map(|x| x.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 400);
        assert_eq!(*ids.last().unwrap(), 399);
    }

    #[test]
    fn consumer_ranks_span_np() {
        let r = des(12, TestCase::TC1, 240);
        let max_rank = r.results.iter().map(|x| x.consumer).max().unwrap();
        assert!(max_rank < 12);
        let mut used: Vec<usize> = r.results.iter().map(|x| x.consumer).collect();
        used.sort();
        used.dedup();
        assert_eq!(used.len(), 12, "every consumer busy on a balanced load");
    }

    #[test]
    fn multi_buffer_topology_works() {
        let mut cfg = DesConfig::new(64);
        cfg.sched.consumers_per_buffer = 16; // 4 buffers
        let r = run_des(
            &cfg,
            Box::new(TestCaseEngine::new(TestCase::TC2, 6400, 3)),
            Box::new(SleepDurations),
        );
        assert_eq!(r.results.len(), 6400);
        assert!(r.rate(64) > 0.9, "rate={}", r.rate(64));
        assert_eq!(r.filling.overlap_violations(), 0);
    }

    #[test]
    fn depth2_tree_completes_and_fills() {
        let mut cfg = DesConfig::new(64);
        cfg.sched.consumers_per_buffer = 8; // 8 leaves
        cfg.sched.depth = 2;
        cfg.sched.fanout = vec![4]; // 2 relays above them
        let r = run_des(
            &cfg,
            Box::new(TestCaseEngine::new(TestCase::TC2, 6400, 3)),
            Box::new(SleepDurations),
        );
        assert_eq!(r.results.len(), 6400);
        assert!(r.rate(64) > 0.9, "rate={}", r.rate(64));
        assert_eq!(r.filling.overlap_violations(), 0);
        // Tree bookkeeping: 8 leaves + 2 relays, shutdown reached them all,
        // and no queue overran its credit bound.
        assert_eq!(r.node_stats.len(), 10);
        assert!(r.node_stats.iter().all(|s| s.saw_shutdown));
        assert!(r.node_stats.iter().all(|s| s.max_queue <= s.credit_bound));
        assert_eq!(r.level_fill.len(), 2);
        assert!(r.level_fill.iter().all(|l| l.mean_rate > 0.85));
    }

    #[test]
    fn depth3_tree_with_stealing_completes() {
        let mut cfg = DesConfig::new(128);
        cfg.sched.consumers_per_buffer = 8; // 16 leaves
        cfg.sched.depth = 3;
        cfg.sched.fanout = vec![4]; // 4 relays, then 1 root relay
        cfg.sched.steal = true;
        let r = run_des(
            &cfg,
            Box::new(TestCaseEngine::new(TestCase::TC3, 12800, 5)),
            Box::new(SleepDurations),
        );
        assert_eq!(r.results.len(), 12800);
        assert!(r.rate(128) > 0.8, "rate={}", r.rate(128));
        assert_eq!(r.node_stats.len(), 16 + 4 + 1);
        assert!(r.node_stats.iter().all(|s| s.saw_shutdown));
        assert!(r.node_stats.iter().all(|s| s.max_queue <= s.credit_bound));
        // Rank 0 talks to exactly one child: its message counts stay tiny
        // relative to a flat layout (16 leaves × constant chatter).
        assert_eq!(r.level_fill.len(), 3);
    }

    #[test]
    fn slow_root_edge_deepens_auto_shape_deterministically() {
        // Per-edge link latency is how the DES models a multi-host tree:
        // a 50 ms producer↔root link (a remote `caravan worker` over a
        // WAN) blows up the calibrated round trip, so `choose_shape`
        // must buy more depth than the uniform-20 µs in-host baseline —
        // and, being driven purely by virtual time, do so identically on
        // every run. (At ~18 producer msgs/s for this workload, a 50 ms
        // per-message cost predicts ~90 % utilization at depth 1 — well
        // past the 50 % target; 20 µs predicts well under 1 %.)
        let mk = |link: Vec<f64>| {
            let mut cfg = DesConfig::new(4096);
            cfg.sched.consumers_per_buffer = 384; // the paper's 1:384
            cfg.sched.shape = TreeShape::Auto;
            cfg.lat.link_latency = link;
            run_des(
                &cfg,
                Box::new(TestCaseEngine::new(TestCase::TC2, 4096 * 4, 7)),
                Box::new(SleepDurations),
            )
        };
        let uniform = mk(Vec::new());
        let slow = mk(vec![50e-3]);
        assert_eq!(uniform.results.len(), 4096 * 4);
        assert_eq!(slow.results.len(), 4096 * 4);
        assert!(
            slow.depth > uniform.depth,
            "50 ms root edge must deepen the auto shape: {} vs {}",
            slow.depth,
            uniform.depth
        );
        // Exact determinism: same config twice → bit-identical outcome.
        let again = mk(vec![50e-3]);
        assert_eq!(slow.depth, again.depth);
        assert_eq!(slow.fanout, again.fanout);
        assert_eq!(slow.makespan, again.makespan, "virtual time must be exactly reproducible");
        assert_eq!(slow.events_processed, again.events_processed);
    }

    #[test]
    fn direct_mode_matches_buffered_at_tiny_scale() {
        let mut cfg = DesConfig::new(8);
        cfg.direct = true;
        let r = run_des(
            &cfg,
            Box::new(TestCaseEngine::new(TestCase::TC1, 160, 1)),
            Box::new(SleepDurations),
        );
        assert_eq!(r.results.len(), 160);
        assert!(r.rate(8) > 0.95, "rate={}", r.rate(8));
    }

    #[test]
    fn direct_mode_saturates_with_short_tasks_at_scale() {
        // Short tasks + many consumers: the single master melts (§3), the
        // buffered layer does not.
        struct ShortTasks(usize);
        impl SearchEngine for ShortTasks {
            fn start(&mut self, sink: &mut dyn JobSink) {
                for _ in 0..self.0 {
                    sink.submit(Payload::Sleep { seconds: 0.5 });
                }
            }
            fn on_done(&mut self, _: &TaskResult, _: &mut dyn JobSink) {}
        }
        // 16384 consumers completing a 0.5-s task each 0.5 s generate
        // ≈ 33 000 Done messages/s; at 50 µs service the single master can
        // only handle 20 000/s → saturation. The paper's 1:384 buffer layer
        // spreads that load over 43 buffers and batches results upward.
        let np = 16384;
        let n = np * 20;
        let mut direct = DesConfig::new(np);
        direct.direct = true;
        let rd = run_des(&direct, Box::new(ShortTasks(n)), Box::new(SleepDurations));
        let buffered = DesConfig::new(np);
        let rb = run_des(&buffered, Box::new(ShortTasks(n)), Box::new(SleepDurations));
        assert!(
            rb.rate(np) > rd.rate(np) + 0.2,
            "buffered {} vs direct {}",
            rb.rate(np),
            rd.rate(np)
        );
        assert!(rd.max_producer_lag > rb.max_producer_lag);
    }

    #[test]
    fn makespan_lower_bound_respected() {
        let r = des(4, TestCase::TC1, 64);
        let total: f64 = r.results.iter().map(|x| x.finish - x.begin).sum();
        assert!(r.makespan >= total / 4.0 - 1e-6);
    }

    #[test]
    fn des_scaling_mirror_of_threaded_runtime() {
        // Cross-validation promised in DESIGN.md: the DES and the threaded
        // runtime execute the same protocol; on the same workload both must
        // complete all tasks with high filling rate.
        use crate::scheduler::{run_scheduler, SleepExecutor};
        use std::sync::Arc;
        let cfg = crate::config::SchedulerConfig {
            np: 8,
            consumers_per_buffer: 4,
            time_scale: 0.002,
            flush_interval_ms: 5,
            ..Default::default()
        };
        let threaded = run_scheduler(
            &cfg,
            Box::new(TestCaseEngine::new(TestCase::TC2, 200, 11)),
            Arc::new(SleepExecutor { time_scale: 0.002 }),
        );
        let mut dcfg = DesConfig::new(8);
        dcfg.sched.consumers_per_buffer = 4;
        let desr = run_des(
            &dcfg,
            Box::new(TestCaseEngine::new(TestCase::TC2, 200, 11)),
            Box::new(SleepDurations),
        );
        assert_eq!(threaded.results.len(), desr.results.len());
        let (rt, rd) = (threaded.rate(8), desr.rate(8));
        assert!(rt > 0.8 && rd > 0.8, "threaded {rt} vs des {rd}");
        assert!((rt - rd).abs() < 0.15, "threaded {rt} vs des {rd}");
    }
}
