//! Multi-tenant serving: job classes, weighted fair-share, admission.
//!
//! CARAVAN's premise is *many users* driving dynamic parameter-space
//! exploration on one shared machine, but through v6 the scheduler served
//! exactly one sweep at a time — policy, priority and shape were per-run
//! globals. This module introduces the tenancy vocabulary the rest of the
//! stack speaks:
//!
//! * [`JobClass`] — a named tenant class: its default
//!   [`SchedPolicy`], its fair-share `weight`, and an optional
//!   `quota` bounding how many of its jobs may be in flight at once.
//!   The registry lives in [`crate::config::SchedulerConfig::classes`];
//!   jobs and tasks carry a [`ClassId`] index into it
//!   ([`crate::api::JobSpec::class`], [`crate::tasklib::TaskSpec::class`]).
//! * [`ClassTable`] — the compact `(weight, policy)` view of the registry
//!   every [`crate::scheduler::protocol::PrioQueue`] keeps, so each queue
//!   lane orders by its class's policy and the deficit-round-robin pop
//!   rule interleaves lanes proportionally to weight.
//! * [`Admission`] + [`AdmissionController`] — the typed backpressure
//!   signal at the [`crate::engine::Session`] boundary: a submission
//!   beyond a class's quota is *queued* (held back, released as the
//!   class's in-flight count drops) and, beyond a bounded backlog,
//!   *rejected* — never buffered without bound.
//!
//! Everything here is pure bookkeeping: no clocks, no I/O, no
//! randomness — so the DES multi-tenant scenarios stay bit-identically
//! reproducible.

#![warn(missing_docs)]

use std::collections::VecDeque;

use crate::config::SchedPolicy;

/// Index of a job's class in [`crate::config::SchedulerConfig::classes`].
/// Class 0 is the default class: a run with an empty registry behaves
/// exactly like the single-tenant scheduler (one lane, run-level policy,
/// weight 1, no quota).
pub type ClassId = u8;

/// The default class every unclassed job belongs to.
pub const DEFAULT_CLASS: ClassId = 0;

/// One tenant class in the registry: who it is and how it is served.
#[derive(Clone, Debug, PartialEq)]
pub struct JobClass {
    /// Human-readable class name (CLI `--class NAME=...`, reports).
    pub name: String,
    /// Queue-ordering policy for this class's lane at every tree level.
    pub policy: SchedPolicy,
    /// Fair-share weight: pops interleave proportionally to weight
    /// across non-empty lanes (clamped to ≥ 1).
    pub weight: u32,
    /// Max jobs in flight at the session boundary (`None` = unbounded).
    /// Submissions beyond it are queued; beyond a backlog of the same
    /// size again, rejected.
    pub quota: Option<usize>,
}

impl JobClass {
    /// A class with the given name and weight, [`SchedPolicy::Strict`]
    /// ordering and no quota.
    pub fn new(name: impl Into<String>, weight: u32) -> Self {
        Self { name: name.into(), policy: SchedPolicy::Strict, weight, quota: None }
    }

    /// Set the class's queue-ordering policy (builder).
    pub fn policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Set the class's in-flight quota (builder); 0 means unbounded.
    pub fn quota(mut self, quota: usize) -> Self {
        self.quota = if quota == 0 { None } else { Some(quota) };
        self
    }

    /// Parse one CLI class spec `NAME=WEIGHT:POLICY:QUOTA`.
    ///
    /// `POLICY` is any [`SchedPolicy::parse`] token — including
    /// `aging:SECONDS`, which is why the spec is parsed from the *ends*:
    /// the first `:`-field is the weight, the last is the quota, and
    /// everything between is the policy. `QUOTA` may be omitted
    /// (`NAME=WEIGHT:POLICY`) or 0, both meaning unbounded.
    ///
    /// ```
    /// use caravan::tenancy::JobClass;
    /// use caravan::config::SchedPolicy;
    ///
    /// let c = JobClass::parse_spec("burst=4:aging:30:256").unwrap();
    /// assert_eq!(c.name, "burst");
    /// assert_eq!(c.weight, 4);
    /// assert_eq!(c.policy, SchedPolicy::Aging { step: 30.0 });
    /// assert_eq!(c.quota, Some(256));
    /// assert!(JobClass::parse_spec("x=1:bogus:0").is_err());
    /// ```
    pub fn parse_spec(spec: &str) -> Result<JobClass, String> {
        let (name, rest) = spec
            .split_once('=')
            .ok_or_else(|| format!("class spec '{spec}' is not NAME=WEIGHT:POLICY:QUOTA"))?;
        if name.is_empty() {
            return Err(format!("class spec '{spec}' has an empty name"));
        }
        let fields: Vec<&str> = rest.split(':').collect();
        let Some((&weight_str, policy_fields)) = fields.split_first() else {
            return Err(format!(
                "class spec '{spec}' needs at least WEIGHT:POLICY after '{name}='"
            ));
        };
        if policy_fields.is_empty() {
            return Err(format!(
                "class spec '{spec}' needs at least WEIGHT:POLICY after '{name}='"
            ));
        }
        let weight: u32 = weight_str
            .parse()
            .map_err(|_| format!("class '{name}': bad weight '{weight_str}'"))?;
        // Try the longest policy first (everything after the weight —
        // quota omitted), then shrink by one trailing field which must
        // then be the quota. This keeps `aging:30` unambiguous: in
        // `b=1:aging:30:64` the policy is `aging:30` and the quota 64; in
        // `b=1:aging:30` the policy is `aging:30` with no quota.
        let all = policy_fields.join(":");
        if let Some(policy) = SchedPolicy::parse(&all) {
            return Ok(JobClass::new(name, weight).policy(policy));
        }
        if let Some((&quota_str, policy_head)) = policy_fields.split_last() {
            if !policy_head.is_empty() {
                let policy_str = policy_head.join(":");
                if let Some(policy) = SchedPolicy::parse(&policy_str) {
                    let quota: usize = quota_str
                        .parse()
                        .map_err(|_| format!("class '{name}': bad quota '{quota_str}'"))?;
                    return Ok(JobClass::new(name, weight).policy(policy).quota(quota));
                }
            }
        }
        Err(format!(
            "class '{name}': unknown policy '{all}' (strict, deadline, aging[:SECONDS])"
        ))
    }

    /// Parse a comma-separated list of class specs (the `--class` flag
    /// value). Class N in the list gets [`ClassId`] N.
    pub fn parse_list(specs: &str) -> Result<Vec<JobClass>, String> {
        let classes: Vec<JobClass> = specs
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| JobClass::parse_spec(s.trim()))
            .collect::<Result<_, _>>()?;
        if classes.len() > ClassId::MAX as usize + 1 {
            return Err(format!("at most {} classes supported", ClassId::MAX as usize + 1));
        }
        Ok(classes)
    }
}

/// Parse a policy token for the named CLI flag, yielding an error message
/// that names both the flag and the bad token — the fallible counterpart
/// of the old "unknown policy silently falls back" path.
pub fn parse_policy_flag(flag: &str, token: &str) -> Result<SchedPolicy, String> {
    SchedPolicy::parse(token).ok_or_else(|| {
        format!("{flag}: unknown policy '{token}' (expected strict, deadline, aging[:SECONDS])")
    })
}

/// The compact per-class `(weight, policy)` view of a registry that every
/// scheduler queue keeps: cheap to clone per tree node, total over any
/// [`ClassId`] (ids beyond the registry fall back to weight 1 and the
/// run-level default policy).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClassTable {
    rows: Vec<(u64, SchedPolicy)>,
}

impl ClassTable {
    /// Build from a registry. An empty registry yields an empty table:
    /// every class falls back to weight 1 + the queue's default policy,
    /// which is exactly the single-tenant behaviour.
    pub fn from_registry(classes: &[JobClass]) -> Self {
        Self { rows: classes.iter().map(|c| (c.weight.max(1) as u64, c.policy)).collect() }
    }

    /// True when no classes are registered (single-tenant run).
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Number of registered classes.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when `class` has its own registry row (its lane keeps the
    /// registered policy across [`SchedPolicy`] changes to the default).
    pub fn is_registered(&self, class: ClassId) -> bool {
        (class as usize) < self.rows.len()
    }

    /// Fair-share weight of `class` (≥ 1; unregistered ids weigh 1).
    pub fn weight(&self, class: ClassId) -> u64 {
        self.rows.get(class as usize).map_or(1, |&(w, _)| w)
    }

    /// Queue policy of `class`, or `default` for unregistered ids.
    pub fn policy_or(&self, class: ClassId, default: SchedPolicy) -> SchedPolicy {
        self.rows.get(class as usize).map_or(default, |&(_, p)| p)
    }
}

/// Typed admission signal returned with every session submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// The job entered the scheduler immediately (under quota).
    Accepted,
    /// The class is at quota: the job is held at the session boundary and
    /// released automatically as earlier jobs of the class finish.
    Queued,
    /// The class's bounded backlog is also full: the job was **not**
    /// submitted. The caller owns retry/shed policy.
    Rejected,
}

/// Per-class bounded admission: at most `quota` jobs in flight, at most
/// `quota` more held back, everything beyond rejected. Generic over the
/// held-back payload so the session can park its full submission record.
///
/// Pure state machine — the owner decides when [`Self::offer`] /
/// [`Self::complete`] fire, making it usable from the threaded session
/// (under a mutex) and from deterministic DES engines alike.
#[derive(Debug)]
pub struct AdmissionController<T> {
    lanes: Vec<AdmissionLane<T>>,
}

#[derive(Debug)]
struct AdmissionLane<T> {
    quota: Option<usize>,
    in_flight: usize,
    waiting: VecDeque<T>,
}

impl<T> AdmissionController<T> {
    /// A controller for the given registry. An empty registry means one
    /// unbounded default lane; unregistered [`ClassId`]s are unbounded
    /// too (they grow lanes on demand).
    pub fn new(classes: &[JobClass]) -> Self {
        let mut lanes: Vec<AdmissionLane<T>> = classes
            .iter()
            .map(|c| AdmissionLane { quota: c.quota, in_flight: 0, waiting: VecDeque::new() })
            .collect();
        if lanes.is_empty() {
            lanes.push(AdmissionLane { quota: None, in_flight: 0, waiting: VecDeque::new() });
        }
        Self { lanes }
    }

    fn lane(&mut self, class: ClassId) -> &mut AdmissionLane<T> {
        let idx = class as usize;
        while self.lanes.len() <= idx {
            self.lanes.push(AdmissionLane { quota: None, in_flight: 0, waiting: VecDeque::new() });
        }
        // lint:allow(panic-path) -- the loop above just grew lanes past idx
        &mut self.lanes[idx]
    }

    /// Offer a submission. Returns the admission decision and, for
    /// [`Admission::Accepted`], the item back (submit it now); a queued
    /// item is parked until [`Self::complete`] releases it; a rejected
    /// item is returned so the caller can dispose of it.
    pub fn offer(&mut self, class: ClassId, item: T) -> (Admission, Option<T>) {
        let lane = self.lane(class);
        match lane.quota {
            Some(q) if lane.in_flight >= q => {
                if lane.waiting.len() >= q {
                    (Admission::Rejected, Some(item))
                } else {
                    lane.waiting.push_back(item);
                    (Admission::Queued, None)
                }
            }
            _ => {
                lane.in_flight += 1;
                (Admission::Accepted, Some(item))
            }
        }
    }

    /// Force a submission in regardless of quota (the compatibility path
    /// behind the admission-unaware `submit`): it is queued if the class
    /// is at quota — never rejected — so legacy callers keep their
    /// fire-and-forget semantics while still being metered.
    pub fn offer_unbounded(&mut self, class: ClassId, item: T) -> (Admission, Option<T>) {
        let lane = self.lane(class);
        match lane.quota {
            Some(q) if lane.in_flight >= q => {
                lane.waiting.push_back(item);
                (Admission::Queued, None)
            }
            _ => {
                lane.in_flight += 1;
                (Admission::Accepted, Some(item))
            }
        }
    }

    /// A job of `class` reached its final result. Decrements the class's
    /// in-flight count and, if a held-back submission can now enter,
    /// returns it (already counted in flight) for the caller to submit.
    pub fn complete(&mut self, class: ClassId) -> Option<T> {
        let lane = self.lane(class);
        lane.in_flight = lane.in_flight.saturating_sub(1);
        let below = lane.quota.map_or(true, |q| lane.in_flight < q);
        if below {
            if let Some(item) = lane.waiting.pop_front() {
                lane.in_flight += 1;
                return Some(item);
            }
        }
        None
    }

    /// Jobs of `class` currently in flight (admitted, not yet finished).
    pub fn in_flight(&self, class: ClassId) -> usize {
        self.lanes.get(class as usize).map_or(0, |l| l.in_flight)
    }

    /// Submissions of `class` held back at the boundary.
    pub fn queued(&self, class: ClassId) -> usize {
        self.lanes.get(class as usize).map_or(0, |l| l.waiting.len())
    }

    /// True when any lane still holds back submissions — the session must
    /// keep polling even if its control channel is drained.
    pub fn any_waiting(&self) -> bool {
        self.lanes.iter().any(|l| !l.waiting.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_full_and_partial_arity() {
        let c = JobClass::parse_spec("steady=2:strict:64").unwrap();
        assert_eq!(
            c,
            JobClass {
                name: "steady".into(),
                weight: 2,
                policy: SchedPolicy::Strict,
                quota: Some(64)
            }
        );
        // Quota omitted.
        let c = JobClass::parse_spec("bg=1:deadline").unwrap();
        assert_eq!(c.quota, None);
        assert_eq!(c.policy, SchedPolicy::Deadline);
        // Quota 0 = unbounded.
        let c = JobClass::parse_spec("bg=1:strict:0").unwrap();
        assert_eq!(c.quota, None);
    }

    #[test]
    fn parse_spec_aging_colon_is_unambiguous() {
        // Trailing number binds to aging when there is no quota field...
        let c = JobClass::parse_spec("b=1:aging:30").unwrap();
        assert_eq!(c.policy, SchedPolicy::Aging { step: 30.0 });
        assert_eq!(c.quota, None);
        // ...and to the quota when there is one.
        let c = JobClass::parse_spec("b=1:aging:30:64").unwrap();
        assert_eq!(c.policy, SchedPolicy::Aging { step: 30.0 });
        assert_eq!(c.quota, Some(64));
        // Bare `aging` keeps its default step.
        let c = JobClass::parse_spec("b=1:aging:64").unwrap();
        assert_eq!(c.policy, SchedPolicy::Aging { step: 64.0 }, "longest-policy-first");
    }

    #[test]
    fn parse_spec_errors_name_the_problem() {
        for (spec, needle) in [
            ("noequals", "NAME=WEIGHT"),
            ("=1:strict", "empty name"),
            ("x=1", "WEIGHT:POLICY"),
            ("x=abc:strict", "bad weight"),
            ("x=1:bogus", "unknown policy 'bogus'"),
            ("x=1:bogus:10", "unknown policy"),
            ("x=1:strict:notanum", "unknown policy"),
        ] {
            let err = JobClass::parse_spec(spec).unwrap_err();
            assert!(err.contains(needle), "spec {spec:?}: error {err:?} missing {needle:?}");
        }
    }

    #[test]
    fn parse_list_splits_on_commas() {
        let cs = JobClass::parse_list("steady=2:strict:64, burst=4:deadline:256").unwrap();
        assert_eq!(cs.len(), 2);
        assert_eq!(cs[0].name, "steady");
        assert_eq!(cs[1].name, "burst");
        assert_eq!(cs[1].quota, Some(256));
        assert!(JobClass::parse_list("a=1:strict,b=1:nope").is_err());
        assert!(JobClass::parse_list("").unwrap().is_empty());
    }

    #[test]
    fn parse_policy_flag_names_flag_and_token() {
        assert_eq!(parse_policy_flag("--policy", "deadline"), Ok(SchedPolicy::Deadline));
        let err = parse_policy_flag("--policy", "wrong").unwrap_err();
        assert!(err.contains("--policy") && err.contains("'wrong'"), "{err}");
    }

    #[test]
    fn class_table_falls_back_for_unregistered_ids() {
        let t = ClassTable::from_registry(&[
            JobClass::new("a", 3).policy(SchedPolicy::Deadline),
            JobClass::new("b", 0), // weight clamps to 1
        ]);
        assert_eq!(t.weight(0), 3);
        assert_eq!(t.weight(1), 1);
        assert_eq!(t.weight(9), 1);
        assert_eq!(t.policy_or(0, SchedPolicy::Strict), SchedPolicy::Deadline);
        assert_eq!(t.policy_or(9, SchedPolicy::Strict), SchedPolicy::Strict);
        assert!(ClassTable::from_registry(&[]).is_empty());
    }

    #[test]
    fn admission_bounds_in_flight_and_backlog() {
        let reg = [JobClass::new("q", 1).quota(2)];
        let mut adm: AdmissionController<u32> = AdmissionController::new(&reg);
        // Quota 2: two accepted, two queued, rest rejected.
        assert_eq!(adm.offer(0, 10), (Admission::Accepted, Some(10)));
        assert_eq!(adm.offer(0, 11), (Admission::Accepted, Some(11)));
        assert_eq!(adm.offer(0, 12), (Admission::Queued, None));
        assert_eq!(adm.offer(0, 13), (Admission::Queued, None));
        assert_eq!(adm.offer(0, 14), (Admission::Rejected, Some(14)));
        assert_eq!(adm.in_flight(0), 2);
        assert_eq!(adm.queued(0), 2);
        assert!(adm.any_waiting());
        // Completions release the backlog FIFO, never exceeding quota.
        assert_eq!(adm.complete(0), Some(12));
        assert_eq!(adm.in_flight(0), 2);
        assert_eq!(adm.complete(0), Some(13));
        assert_eq!(adm.complete(0), None);
        assert_eq!(adm.in_flight(0), 1);
        assert!(!adm.any_waiting());
    }

    #[test]
    fn admission_unbounded_classes_always_accept() {
        let mut adm: AdmissionController<u32> = AdmissionController::new(&[]);
        for i in 0..1000 {
            assert_eq!(adm.offer(0, i).0, Admission::Accepted);
        }
        assert_eq!(adm.in_flight(0), 1000);
        // Unregistered class ids are unbounded too.
        assert_eq!(adm.offer(7, 0).0, Admission::Accepted);
        assert_eq!(adm.in_flight(7), 1);
    }

    #[test]
    fn offer_unbounded_queues_but_never_rejects() {
        let reg = [JobClass::new("q", 1).quota(1)];
        let mut adm: AdmissionController<u32> = AdmissionController::new(&reg);
        assert_eq!(adm.offer_unbounded(0, 1), (Admission::Accepted, Some(1)));
        for i in 2..20 {
            assert_eq!(adm.offer_unbounded(0, i), (Admission::Queued, None));
        }
        assert_eq!(adm.queued(0), 18);
        assert_eq!(adm.in_flight(0), 1);
    }
}
