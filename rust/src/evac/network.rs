//! Road networks for the evacuation substrate.
//!
//! CrowdWalk (the paper's simulator) represents a city as one-dimensional
//! roads: a directed graph of nodes and links on which agents move — "this
//! design is advantageous for making simulations sufficiently fast to
//! manage a large number of agents" (§4.3). We reproduce that model class.
//!
//! The paper's Yodogawa-ward map (2 933 nodes, 8 924 links) is not
//! redistributable, so [`grid_city`] generates synthetic street grids with
//! perturbed geometry and random street removals — the same structural
//! family (mostly-planar, low-degree, strongly connected).

use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Node {
    pub x: f64,
    pub y: f64,
}

/// A directed road segment. Every undirected street contributes two links.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Link {
    pub from: usize,
    pub to: usize,
    /// Metres.
    pub length: f32,
}

#[derive(Clone, Debug, Default)]
pub struct RoadNetwork {
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    /// Outgoing link indices per node.
    pub out_links: Vec<Vec<usize>>,
    /// Incoming link indices per node.
    pub in_links: Vec<Vec<usize>>,
}

impl RoadNetwork {
    pub fn new(nodes: Vec<Node>) -> Self {
        let n = nodes.len();
        Self { nodes, links: Vec::new(), out_links: vec![Vec::new(); n], in_links: vec![Vec::new(); n] }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    /// Add a directed link; length defaults to Euclidean distance.
    pub fn add_link(&mut self, from: usize, to: usize, length: Option<f32>) -> usize {
        assert!(from < self.n_nodes() && to < self.n_nodes() && from != to);
        let length = length.unwrap_or_else(|| {
            let (a, b) = (&self.nodes[from], &self.nodes[to]);
            (((a.x - b.x).powi(2) + (a.y - b.y).powi(2)).sqrt() as f32).max(1.0)
        });
        let id = self.links.len();
        self.links.push(Link { from, to, length });
        self.out_links[from].push(id);
        self.in_links[to].push(id);
        id
    }

    /// Add both directions of an undirected street.
    pub fn add_street(&mut self, a: usize, b: usize) -> (usize, usize) {
        (self.add_link(a, b, None), self.add_link(b, a, None))
    }

    /// Nodes reachable from `start` following directed links.
    pub fn reachable_from(&self, start: usize) -> Vec<bool> {
        let mut seen = vec![false; self.n_nodes()];
        let mut stack = vec![start];
        seen[start] = true;
        while let Some(u) = stack.pop() {
            for &l in &self.out_links[u] {
                let v = self.links[l].to;
                if !seen[v] {
                    seen[v] = true;
                    stack.push(v);
                }
            }
        }
        seen
    }

    /// True when every node can reach every other (needed so every agent
    /// can reach every shelter).
    pub fn strongly_connected(&self) -> bool {
        if self.n_nodes() == 0 {
            return true;
        }
        if !self.reachable_from(0).iter().all(|&b| b) {
            return false;
        }
        // Reverse reachability via in_links.
        let mut seen = vec![false; self.n_nodes()];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(u) = stack.pop() {
            for &l in &self.in_links[u] {
                let v = self.links[l].from;
                if !seen[v] {
                    seen[v] = true;
                    stack.push(v);
                }
            }
        }
        seen.iter().all(|&b| b)
    }
}

/// Parameters for the synthetic street grid.
#[derive(Clone, Debug)]
pub struct GridCityParams {
    /// Grid dimensions (intersections).
    pub width: usize,
    pub height: usize,
    /// Block edge length in metres.
    pub spacing: f64,
    /// Random positional jitter as a fraction of spacing.
    pub jitter: f64,
    /// Probability of removing a street (kept only if removal preserves
    /// strong connectivity).
    pub removal: f64,
}

impl Default for GridCityParams {
    fn default() -> Self {
        Self { width: 16, height: 16, spacing: 80.0, jitter: 0.25, removal: 0.12 }
    }
}

/// Generate a perturbed street grid. Guaranteed strongly connected.
pub fn grid_city(p: &GridCityParams, seed: u64) -> RoadNetwork {
    let mut rng = Pcg64::new(seed);
    let (w, h) = (p.width, p.height);
    assert!(w >= 2 && h >= 2);
    let mut nodes = Vec::with_capacity(w * h);
    for j in 0..h {
        for i in 0..w {
            let jx = rng.range_f64(-p.jitter, p.jitter) * p.spacing;
            let jy = rng.range_f64(-p.jitter, p.jitter) * p.spacing;
            nodes.push(Node { x: i as f64 * p.spacing + jx, y: j as f64 * p.spacing + jy });
        }
    }
    let mut net = RoadNetwork::new(nodes);
    let idx = |i: usize, j: usize| j * w + i;
    // Candidate streets: all grid edges.
    let mut streets = Vec::new();
    for j in 0..h {
        for i in 0..w {
            if i + 1 < w {
                streets.push((idx(i, j), idx(i + 1, j)));
            }
            if j + 1 < h {
                streets.push((idx(i, j), idx(i, j + 1)));
            }
        }
    }
    for &(a, b) in &streets {
        net.add_street(a, b);
    }
    // Random removals, keeping strong connectivity.
    let mut order: Vec<usize> = (0..streets.len()).collect();
    rng.shuffle(&mut order);
    let target = (streets.len() as f64 * p.removal) as usize;
    let mut removed = 0;
    for &s in &order {
        if removed >= target {
            break;
        }
        let (a, b) = streets[s];
        // Tentatively remove both directions and test connectivity.
        let saved = net.clone();
        net.links.retain(|l| !((l.from == a && l.to == b) || (l.from == b && l.to == a)));
        rebuild_adjacency(&mut net);
        if net.strongly_connected() {
            removed += 1;
        } else {
            net = saved;
        }
    }
    net
}

fn rebuild_adjacency(net: &mut RoadNetwork) {
    let n = net.n_nodes();
    net.out_links = vec![Vec::new(); n];
    net.in_links = vec![Vec::new(); n];
    for (i, l) in net.links.iter().enumerate() {
        net.out_links[l.from].push(i);
        net.in_links[l.to].push(i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_link_computes_euclidean_length() {
        let mut net = RoadNetwork::new(vec![Node { x: 0.0, y: 0.0 }, Node { x: 3.0, y: 4.0 }]);
        let l = net.add_link(0, 1, None);
        assert!((net.links[l].length - 5.0).abs() < 1e-6);
        assert_eq!(net.out_links[0], vec![l]);
        assert_eq!(net.in_links[1], vec![l]);
    }

    #[test]
    fn grid_city_is_strongly_connected_and_sized() {
        let p = GridCityParams { width: 8, height: 6, ..Default::default() };
        let net = grid_city(&p, 42);
        assert_eq!(net.n_nodes(), 48);
        assert!(net.strongly_connected());
        // Full grid would have 2*(7*6 + 8*5) = 164 directed links; removal
        // strips some but never below a spanning structure.
        assert!(net.n_links() > 100 && net.n_links() <= 164);
        // All lengths positive and near the spacing scale.
        assert!(net.links.iter().all(|l| l.length > 1.0 && l.length < 300.0));
    }

    #[test]
    fn grid_city_deterministic_per_seed() {
        let p = GridCityParams::default();
        let a = grid_city(&p, 7);
        let b = grid_city(&p, 7);
        let c = grid_city(&p, 8);
        assert_eq!(a.links, b.links);
        assert!(a.links != c.links || a.nodes != c.nodes);
    }

    #[test]
    fn disconnected_graph_detected() {
        let mut net = RoadNetwork::new(vec![
            Node { x: 0.0, y: 0.0 },
            Node { x: 1.0, y: 0.0 },
            Node { x: 2.0, y: 0.0 },
        ]);
        net.add_street(0, 1);
        assert!(!net.strongly_connected());
        net.add_street(1, 2);
        assert!(net.strongly_connected());
    }

    #[test]
    fn one_way_cycle_is_strongly_connected() {
        let mut net = RoadNetwork::new(vec![
            Node { x: 0.0, y: 0.0 },
            Node { x: 1.0, y: 0.0 },
            Node { x: 0.5, y: 1.0 },
        ]);
        net.add_link(0, 1, None);
        net.add_link(1, 2, None);
        net.add_link(2, 0, None);
        assert!(net.strongly_connected());
        assert_eq!(net.reachable_from(1), vec![true, true, true]);
    }
}
