//! The evacuation substrate — everything §4 of the paper needs: road
//! networks ([`network`]), shortest-path routing ([`routing`]), the
//! CrowdWalk-like pedestrian-flow simulator ([`sim`]), scenario generation
//! ([`scenario`]), plan encoding + objectives ([`plan`]) and the evaluator
//! gluing it to the scheduler ([`evaluator`]).

pub mod evaluator;
pub mod network;
pub mod plan;
pub mod routing;
pub mod scenario;
pub mod sim;

pub use evaluator::{EvacEvaluator, RustSimBackend, SimBackend};
pub use network::{grid_city, GridCityParams, RoadNetwork};
pub use plan::{f2_complexity, f3_excess, init_agents, Plan, PlanCodec};
pub use routing::RoutingTable;
pub use scenario::{build_scenario, Scenario, ScenarioParams};
pub use sim::{AgentState, SimArrays, SimOutput, SimParams};
