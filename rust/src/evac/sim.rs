//! The CrowdWalk-like 1-D pedestrian-flow simulator — **canonical model**.
//!
//! Agents move along links; each step every link's density sets a shared
//! speed (mean-field congestion: `v = v_free · clip(1 − ρ/ρ_jam, v_min_frac, 1)`),
//! agents advance, transition to the next link of their shortest path at
//! link ends, and arrive when the link end is their destination shelter.
//!
//! This file defines the *reference semantics* in f32 arithmetic. The
//! AOT-compiled JAX/Pallas model (`python/compile/model.py`) implements the
//! identical update; `rust/tests/` cross-checks the two step by step. Keep
//! the two in lock-step when changing either.
//!
//! Conventions shared with the compiled model:
//! * arrived agents carry `link == L` (the sentinel row of the padded
//!   per-link arrays: `length[L] = BIG`, `to[L] = 0`);
//! * one link transition per step (time steps are small relative to link
//!   traversal, so multi-hop steps cannot occur);
//! * `next_link` is consulted only when the reached node is not the
//!   destination shelter; its `NO_ROUTE` entries are exported as 0 and
//!   never read.

/// Large finite stand-in for "never transitions" on the sentinel row
/// (finite so f32 arithmetic stays NaN-free).
pub const SENTINEL_LENGTH: f32 = 1e9;

/// Simulation parameters — baked as constants into the compiled model, so
/// changing them requires `make artifacts`.
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    /// Time step (seconds).
    pub dt: f32,
    /// Free walking speed (m/s); 1.4 is the standard pedestrian value.
    pub v_free: f32,
    /// Jam density (agents/metre of 1-D road).
    pub rho_jam: f32,
    /// Speed floor as a fraction of `v_free` (jams creep, never freeze —
    /// also keeps the model deadlock-free).
    pub v_min_frac: f32,
    /// Simulated steps T (fixed shape in the compiled model).
    pub max_steps: usize,
    /// f1 penalty (seconds) per agent still en route at T.
    pub penalty: f32,
}

impl Default for SimParams {
    fn default() -> Self {
        // rho_jam 4 agents/m models a ~2 m-wide street at 2 persons/m^2;
        // v_min 10% keeps saturated links draining (CrowdWalk's queued
        // agents also keep inching forward).
        Self { dt: 2.0, v_free: 1.4, rho_jam: 4.0, v_min_frac: 0.10, max_steps: 512, penalty: 600.0 }
    }
}

/// Per-link arrays padded with the sentinel row; flattened routing table.
/// These are exactly the host-provided inputs of the compiled model.
#[derive(Clone, Debug)]
pub struct SimArrays {
    /// `L + 1` entries; `length[L] = SENTINEL_LENGTH`.
    pub length: Vec<f32>,
    /// `L + 1` entries; `to[L] = 0`.
    pub to: Vec<i32>,
    /// `n_nodes × n_shelters`, NO_ROUTE exported as 0.
    pub next_link: Vec<i32>,
    pub shelter_node: Vec<i32>,
    pub n_links: usize,
    pub n_shelters: usize,
}

/// Mutable agent state (f32/i32 to match the compiled model exactly).
#[derive(Clone, Debug, PartialEq)]
pub struct AgentState {
    /// Current link id, or `n_links` when arrived.
    pub link: Vec<i32>,
    /// Position along the link (metres).
    pub pos: Vec<f32>,
    /// Destination shelter index.
    pub dest: Vec<i32>,
}

impl AgentState {
    pub fn n_agents(&self) -> usize {
        self.link.len()
    }

    pub fn arrived_count(&self, n_links: usize) -> usize {
        self.link.iter().filter(|&&l| l as usize >= n_links).count()
    }
}

/// Output of a full simulation run.
#[derive(Clone, Debug)]
pub struct SimOutput {
    /// f1: seconds until complete evacuation, incl. the penalty term when
    /// the horizon was hit.
    pub evac_time: f64,
    /// Agents still en route at T.
    pub remaining: usize,
    /// Cumulative arrivals after each step (length T).
    pub arrivals: Vec<u32>,
    /// Steps actually needed (≤ T when everyone arrived).
    pub steps_used: usize,
}

/// One canonical step, in place. Returns the number of arrived agents
/// after the step.
pub fn step(arrays: &SimArrays, params: &SimParams, st: &mut AgentState, density: &mut [f32]) -> usize {
    let nl = arrays.n_links;
    let s = arrays.n_shelters;
    debug_assert_eq!(density.len(), nl + 1);
    // 1. per-link agent counts → densities.
    density.fill(0.0);
    for &l in &st.link {
        density[l as usize] += 1.0;
    }
    // 2. per-link speeds (sentinel row harmless: density/SENTINEL ≈ 0).
    // Reuse `density` as the speed array to avoid a second buffer.
    for l in 0..=nl {
        let rho = density[l] / arrays.length[l];
        let factor = (1.0 - rho / params.rho_jam).clamp(params.v_min_frac, 1.0);
        density[l] = params.v_free * factor;
    }
    // 3.–5. advance, transition, arrive.
    let mut arrived = 0usize;
    for a in 0..st.link.len() {
        let l = st.link[a] as usize;
        if l >= nl {
            arrived += 1;
            continue;
        }
        let mut p = st.pos[a] + density[l] * params.dt;
        let len = arrays.length[l];
        if p >= len {
            let node = arrays.to[l];
            let dest = st.dest[a] as usize;
            if node == arrays.shelter_node[dest] {
                st.link[a] = nl as i32;
                st.pos[a] = 0.0;
                arrived += 1;
                continue;
            }
            let nxt = arrays.next_link[node as usize * s + dest];
            st.link[a] = nxt;
            p -= len;
        }
        st.pos[a] = p;
    }
    arrived
}

/// Run the full horizon; the reference implementation of the compiled
/// model's scan.
pub fn run(arrays: &SimArrays, params: &SimParams, mut st: AgentState) -> SimOutput {
    let n = st.n_agents();
    let mut density = vec![0.0f32; arrays.n_links + 1];
    let mut arrivals = Vec::with_capacity(params.max_steps);
    let mut steps_not_done = 0usize;
    let mut steps_used = params.max_steps;
    for t in 0..params.max_steps {
        let arrived = step(arrays, params, &mut st, &mut density);
        arrivals.push(arrived as u32);
        if arrived < n {
            steps_not_done += 1;
        } else {
            // Early exit (perf pass): once everyone arrived the state is a
            // fixed point — pad the curve and stop. Outputs are identical
            // to the compiled model, which (fixed shapes) keeps scanning
            // and records `n` for the remaining steps.
            if steps_used == params.max_steps {
                steps_used = t + 1;
            }
            arrivals.resize(params.max_steps, n as u32);
            break;
        }
    }
    let remaining = n - *arrivals.last().unwrap_or(&0) as usize;
    let evac_time =
        params.dt as f64 * steps_not_done as f64 + params.penalty as f64 * remaining as f64;
    SimOutput { evac_time, remaining, arrivals, steps_used }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two links in a line: node0 --(100m)--> node1 --(100m)--> node2(shelter).
    fn line_arrays() -> SimArrays {
        SimArrays {
            length: vec![100.0, 100.0, SENTINEL_LENGTH],
            to: vec![1, 2, 0],
            // next_link[node*1 + 0]: from node0 take link0, node1 link1.
            next_link: vec![0, 1, 0],
            shelter_node: vec![2],
            n_links: 2,
            n_shelters: 1,
        }
    }

    fn params(max_steps: usize) -> SimParams {
        SimParams { dt: 1.0, v_free: 1.0, rho_jam: 10.0, v_min_frac: 0.05, max_steps, penalty: 1000.0 }
    }

    #[test]
    fn single_agent_walks_the_line_and_arrives() {
        let arrays = line_arrays();
        let p = params(400);
        let st = AgentState { link: vec![0], pos: vec![0.0], dest: vec![0] };
        let out = run(&arrays, &p, st);
        assert_eq!(out.remaining, 0);
        // 200 m at ~1 m/s (alone: rho=0.01 ⇒ v≈0.999): ~201 steps.
        assert!((out.evac_time - 201.0).abs() <= 2.0, "evac_time {}", out.evac_time);
        assert_eq!(*out.arrivals.last().unwrap(), 1);
    }

    #[test]
    fn congestion_slows_evacuation() {
        let arrays = line_arrays();
        // Jam density 2.0: 150 agents on a 100 m link give rho = 1.5 and
        // the speed factor drops to 0.25 — ~4× slower than a lone agent.
        let mut p = params(3000);
        p.rho_jam = 2.0;
        let lone = run(&arrays, &p, AgentState { link: vec![0], pos: vec![0.0], dest: vec![0] });
        let crowd_n = 150;
        let crowd = run(
            &arrays,
            &p,
            AgentState {
                link: vec![0; crowd_n],
                pos: vec![0.0; crowd_n],
                dest: vec![0; crowd_n],
            },
        );
        assert_eq!(crowd.remaining, 0);
        assert!(
            crowd.evac_time > lone.evac_time * 1.5,
            "crowd {} vs lone {}",
            crowd.evac_time,
            lone.evac_time
        );
    }

    #[test]
    fn horizon_hit_applies_penalty() {
        let arrays = line_arrays();
        let p = params(50); // not enough for 200 m.
        let out = run(&arrays, &p, AgentState { link: vec![0], pos: vec![0.0], dest: vec![0] });
        assert_eq!(out.remaining, 1);
        assert!((out.evac_time - (50.0 + 1000.0)).abs() < 1e-6);
    }

    #[test]
    fn agent_already_arrived_stays_arrived() {
        let arrays = line_arrays();
        let p = params(10);
        let st = AgentState { link: vec![2], pos: vec![0.0], dest: vec![0] };
        let out = run(&arrays, &p, st.clone());
        assert_eq!(out.remaining, 0);
        assert_eq!(out.evac_time, 0.0);
        assert_eq!(out.steps_used, 10usize.min(1).max(1)); // arrived from step 1
    }

    #[test]
    fn speed_floor_prevents_deadlock() {
        // Extreme overcrowding: 1000 agents on a 100 m link (ρ = 10 = ρ_jam
        // of 10 ⇒ factor clamps to v_min_frac). They still creep forward.
        let arrays = line_arrays();
        let mut p = params(10);
        p.rho_jam = 2.0;
        let mut st = AgentState {
            link: vec![0; 1000],
            pos: vec![0.0; 1000],
            dest: vec![0; 1000],
        };
        let mut density = vec![0.0; 3];
        let before = st.pos.clone();
        step(&arrays, &p, &mut st, &mut density);
        for a in 0..1000 {
            assert!(st.pos[a] > before[a], "agent {a} frozen");
            assert!((st.pos[a] - p.v_free * p.v_min_frac * p.dt).abs() < 1e-5);
        }
    }

    #[test]
    fn one_transition_per_step_even_past_link_end() {
        // Fast agent overshooting a short link: exactly one transition,
        // residual carried over.
        let arrays = SimArrays {
            length: vec![0.5, 100.0, SENTINEL_LENGTH],
            to: vec![1, 2, 0],
            next_link: vec![0, 1, 0],
            shelter_node: vec![2],
            n_links: 2,
            n_shelters: 1,
        };
        let p = params(1);
        let mut st = AgentState { link: vec![0], pos: vec![0.0], dest: vec![0] };
        let mut density = vec![0.0; 3];
        step(&arrays, &p, &mut st, &mut density);
        assert_eq!(st.link[0], 1);
        // One agent on the 0.5 m link: rho = 2 ⇒ factor 0.8 ⇒ advance 0.8 m,
        // transition once, carry over 0.3 m onto the next link.
        assert!((st.pos[0] - 0.3).abs() < 1e-5, "carry-over 0.8 - 0.5, got {}", st.pos[0]);
    }

    #[test]
    fn mass_conservation_property() {
        // Property: at every step, #active + #arrived == n.
        use crate::testutil::{check, usize_in};
        check("agents conserved", usize_in(1..60), |&n| {
            let arrays = line_arrays();
            let p = params(64);
            let mut st = AgentState {
                link: vec![0; n],
                pos: (0..n).map(|i| (i % 90) as f32).collect(),
                dest: vec![0; n],
            };
            let mut density = vec![0.0; 3];
            for _ in 0..p.max_steps {
                let arrived = step(&arrays, &p, &mut st, &mut density);
                let active = st.link.iter().filter(|&&l| (l as usize) < 2).count();
                if active + arrived != n {
                    return false;
                }
            }
            true
        });
    }
}
