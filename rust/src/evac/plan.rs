//! Evacuation-plan encoding and the three objective functions (§4.3).
//!
//! A plan splits each sub-area's residents into two groups with ratio
//! `r_i : 1−r_i` and assigns each group a destination shelter — 3 decision
//! variables per sub-area (`r_i`, `dest_a_i`, `dest_b_i`), 1 599 in the
//! paper's 533-sub-area case.
//!
//! Objectives (all minimized):
//! * **f1** — time to complete the evacuation: from the simulation.
//! * **f2** — plan complexity: the information entropy of the split,
//!   `f2 = −Σᵢ (rᵢ·ln rᵢ + (1−rᵢ)·ln(1−rᵢ))` ≥ 0. The paper prints the
//!   expression without the leading minus but describes *smaller entropy =
//!   simpler plan* and minimizes it; we use the positive-entropy
//!   convention so that minimizing f2 favours unsplit (simple) plans, as
//!   described.
//! * **f3** — excess evacuees: `Σ_s max(0, assigned(s) − capacity(s))`,
//!   computed from the real population numbers.

use super::scenario::{apportion, Scenario};
use super::sim::AgentState;
use crate::util::rng::Pcg64;

/// Decoded plan.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub r: Vec<f64>,
    pub dest_a: Vec<usize>,
    pub dest_b: Vec<usize>,
}

/// Encodes/decodes plans to flat `Vec<f64>` genomes (the optimizer's
/// decision vector) and computes the analytic objectives.
#[derive(Clone, Copy, Debug)]
pub struct PlanCodec {
    pub n_subareas: usize,
    pub n_shelters: usize,
}

impl PlanCodec {
    pub fn for_scenario(sc: &Scenario) -> Self {
        Self { n_subareas: sc.subareas.len(), n_shelters: sc.shelters.len() }
    }

    /// Genome length: 3 variables per sub-area (the paper's 1 599 for 533).
    pub fn dim(&self) -> usize {
        3 * self.n_subareas
    }

    /// Optimizer bounds: `r ∈ [0,1]`, destinations as continuous indices in
    /// `[0, n_shelters)` (floored at decode — standard integer handling
    /// under SBX/polynomial-mutation).
    pub fn bounds(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::with_capacity(self.dim());
        let s_hi = self.n_shelters as f64 - 1e-9;
        for _ in 0..self.n_subareas {
            out.push((0.0, 1.0));
            out.push((0.0, s_hi));
            out.push((0.0, s_hi));
        }
        out
    }

    /// Layout: `[r_0, destA_0, destB_0, r_1, …]`.
    pub fn decode(&self, genome: &[f64]) -> Plan {
        assert_eq!(genome.len(), self.dim(), "genome length");
        let mut plan = Plan {
            r: Vec::with_capacity(self.n_subareas),
            dest_a: Vec::with_capacity(self.n_subareas),
            dest_b: Vec::with_capacity(self.n_subareas),
        };
        let hi = self.n_shelters - 1;
        for i in 0..self.n_subareas {
            plan.r.push(genome[3 * i].clamp(0.0, 1.0));
            plan.dest_a.push((genome[3 * i + 1].max(0.0) as usize).min(hi));
            plan.dest_b.push((genome[3 * i + 2].max(0.0) as usize).min(hi));
        }
        plan
    }

    pub fn encode(&self, plan: &Plan) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.dim());
        for i in 0..self.n_subareas {
            out.push(plan.r[i]);
            out.push(plan.dest_a[i] as f64 + 0.5);
            out.push(plan.dest_b[i] as f64 + 0.5);
        }
        out
    }
}

/// f2: plan complexity (positive entropy; nats).
pub fn f2_complexity(plan: &Plan) -> f64 {
    let mut h = 0.0;
    for &r in &plan.r {
        // Effective split: identical destinations mean no real split.
        if r > 0.0 && r < 1.0 {
            h -= r * r.ln() + (1.0 - r) * (1.0 - r).ln();
        }
    }
    h
}

/// f3: excess evacuees over shelter capacities (persons).
pub fn f3_excess(plan: &Plan, sc: &Scenario) -> f64 {
    let mut assigned = vec![0.0f64; sc.shelters.len()];
    for (i, sub) in sc.subareas.iter().enumerate() {
        assigned[plan.dest_a[i]] += plan.r[i] * sub.population;
        assigned[plan.dest_b[i]] += (1.0 - plan.r[i]) * sub.population;
    }
    assigned
        .iter()
        .zip(&sc.shelters)
        .map(|(&a, s)| (a - s.capacity).max(0.0))
        .sum()
}

/// Build the initial agent state for a plan (the host-side input of both
/// the Rust reference simulator and the compiled model).
///
/// Per sub-area: its agent allotment is split `r : 1−r` (largest
/// remainder), start nodes cycle through the sub-area's nodes in a
/// seed-shuffled order, and each agent starts on the first link of its
/// shortest path with a small seeded position jitter — this is where the
/// paper's "five independent runs with different random seeds" enter.
pub fn init_agents(sc: &Scenario, plan: &Plan, seed: u64) -> AgentState {
    let mut rng = Pcg64::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x1A17);
    // The arrived-sentinel is the *padded* link budget — the sentinel row
    // of the exported arrays — not the real link count.
    let nl = sc.padded_links();
    let mut st = AgentState {
        link: Vec::with_capacity(sc.n_agents),
        pos: Vec::with_capacity(sc.n_agents),
        dest: Vec::with_capacity(sc.n_agents),
    };
    for (i, sub) in sc.subareas.iter().enumerate() {
        let k = sc.agents_per_subarea[i];
        if k == 0 {
            continue;
        }
        let split = apportion(k, &[plan.r[i].max(1e-12), (1.0 - plan.r[i]).max(1e-12)]);
        let mut nodes = sub.nodes.clone();
        rng.shuffle(&mut nodes);
        let mut node_cursor = 0usize;
        for (g, &count) in split.iter().enumerate() {
            let dest = if g == 0 { plan.dest_a[i] } else { plan.dest_b[i] };
            for _ in 0..count {
                let node = nodes[node_cursor % nodes.len()];
                node_cursor += 1;
                if node == sc.shelters[dest].node {
                    // Already at the shelter: arrived from the start.
                    st.link.push(nl as i32);
                    st.pos.push(0.0);
                } else {
                    let l = sc.routing.next_link(node, dest);
                    debug_assert!(l >= 0);
                    let len = sc.net.links[l as usize].length;
                    let jitter = (rng.uniform() as f32) * (len * 0.25).min(10.0);
                    st.link.push(l);
                    st.pos.push(jitter);
                }
                st.dest.push(dest as i32);
            }
        }
    }
    debug_assert_eq!(st.link.len(), sc.n_agents);
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evac::scenario::{build_scenario, ScenarioParams};

    fn tiny() -> Scenario {
        build_scenario(&ScenarioParams::tiny(), 3)
    }

    #[test]
    fn codec_roundtrip_and_bounds() {
        let sc = tiny();
        let codec = PlanCodec::for_scenario(&sc);
        assert_eq!(codec.dim(), 18);
        let bounds = codec.bounds();
        assert_eq!(bounds.len(), 18);
        assert_eq!(bounds[0], (0.0, 1.0));
        assert!(bounds[1].1 < 3.0 && bounds[1].1 > 2.9);
        let plan = Plan {
            r: vec![0.25; 6],
            dest_a: vec![0, 1, 2, 0, 1, 2],
            dest_b: vec![2, 2, 1, 0, 0, 1],
        };
        let decoded = codec.decode(&codec.encode(&plan));
        assert_eq!(decoded, plan);
    }

    #[test]
    fn decode_clamps_out_of_range() {
        let sc = tiny();
        let codec = PlanCodec::for_scenario(&sc);
        let mut genome = vec![0.0; codec.dim()];
        genome[0] = 1.7; // r > 1
        genome[1] = 99.0; // dest too large
        genome[2] = -3.0; // dest negative
        let plan = codec.decode(&genome);
        assert_eq!(plan.r[0], 1.0);
        assert_eq!(plan.dest_a[0], 2);
        assert_eq!(plan.dest_b[0], 0);
    }

    #[test]
    fn f2_zero_for_unsplit_max_at_half() {
        let mk = |r: f64| Plan { r: vec![r; 4], dest_a: vec![0; 4], dest_b: vec![1; 4] };
        assert_eq!(f2_complexity(&mk(0.0)), 0.0);
        assert_eq!(f2_complexity(&mk(1.0)), 0.0);
        let half = f2_complexity(&mk(0.5));
        assert!((half - 4.0 * std::f64::consts::LN_2).abs() < 1e-12);
        assert!(f2_complexity(&mk(0.2)) < half);
    }

    #[test]
    fn f3_counts_only_excess() {
        let sc = tiny();
        // Everyone to shelter 0: assigned = 3000, capacity₀ < 3000 ⇒ excess.
        let all_to_0 = Plan {
            r: vec![1.0; 6],
            dest_a: vec![0; 6],
            dest_b: vec![0; 6],
        };
        let excess = f3_excess(&all_to_0, &sc);
        let cap0 = sc.shelters[0].capacity;
        assert!((excess - (3000.0 - cap0)).abs() < 1e-6);
        // Perfectly proportional split ⇒ some excess may remain only if a
        // shelter is over-subscribed; a spread plan reduces f3.
        let spread = Plan {
            r: vec![0.5; 6],
            dest_a: vec![0, 1, 2, 0, 1, 2],
            dest_b: vec![1, 2, 0, 2, 0, 1],
        };
        assert!(f3_excess(&spread, &sc) < excess);
    }

    #[test]
    fn init_agents_counts_and_split() {
        let sc = tiny();
        let codec = PlanCodec::for_scenario(&sc);
        let genome: Vec<f64> = codec
            .bounds()
            .iter()
            .enumerate()
            .map(|(k, &(lo, hi))| lo + (hi - lo) * ((k % 3) as f64 / 3.0 + 0.1))
            .collect();
        let plan = codec.decode(&genome);
        let st = init_agents(&sc, &plan, 0);
        assert_eq!(st.n_agents(), sc.n_agents);
        // All destinations valid; links are real or the padded sentinel.
        let real = sc.net.n_links() as i32;
        let sentinel = sc.padded_links() as i32;
        assert!(st.dest.iter().all(|&d| (d as usize) < sc.shelters.len()));
        assert!(st.link.iter().all(|&l| (l >= 0 && l < real) || l == sentinel));
        assert!(st.pos.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn init_agents_seed_dependent_but_deterministic() {
        let sc = tiny();
        let plan = Plan {
            r: vec![0.5; 6],
            dest_a: vec![0, 1, 2, 0, 1, 2],
            dest_b: vec![1, 2, 0, 2, 0, 1],
        };
        let a = init_agents(&sc, &plan, 1);
        let b = init_agents(&sc, &plan, 1);
        let c = init_agents(&sc, &plan, 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // Group sizes respect r: with r=0.5, dests split roughly evenly.
        let to_a = a.dest.iter().filter(|&&d| d == 0).count();
        assert!(to_a > 0 && to_a < sc.n_agents);
    }
}
