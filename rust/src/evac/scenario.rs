//! Evacuation scenarios: a road network + shelters with capacities +
//! populated sub-areas, plus the precomputed routing arrays.
//!
//! The paper's case study (§4.3): Yodogawa ward, 2 933 nodes / 8 924 links,
//! 49 726 evacuees, 86 shelters, 533 sub-areas. That census/map data is not
//! redistributable, so scenarios here are generated synthetically on
//! [`grid_city`](crate::evac::network::grid_city) street grids with the
//! same structure: sub-areas tile the city, each holds a population, each
//! shelter has a capacity, and the *simulated* agent count is a scaled-down
//! sample of the population (the plan objectives f2/f3 use the real
//! population numbers; the simulation uses agents).

use super::network::{grid_city, GridCityParams, RoadNetwork};
use super::routing::RoutingTable;
use super::sim::{SimArrays, SimParams, SENTINEL_LENGTH};
use crate::util::rng::Pcg64;
use crate::util::stats::nan_worst;

#[derive(Clone, Debug)]
pub struct Shelter {
    pub node: usize,
    /// Capacity in *persons* (population units, not simulated agents).
    pub capacity: f64,
}

#[derive(Clone, Debug)]
pub struct Subarea {
    /// Nodes belonging to this sub-area (agents start at these).
    pub nodes: Vec<usize>,
    /// Resident population (persons).
    pub population: f64,
}

#[derive(Clone, Debug)]
pub struct Scenario {
    pub net: RoadNetwork,
    pub shelters: Vec<Shelter>,
    pub subareas: Vec<Subarea>,
    pub routing: RoutingTable,
    pub params: SimParams,
    /// Simulated agents (fixed shape of the compiled model).
    pub n_agents: usize,
    /// Agents allotted per sub-area (largest-remainder apportionment of
    /// `n_agents` by population; sums to `n_agents`).
    pub agents_per_subarea: Vec<usize>,
    /// Fixed link budget (full-grid link count) for AOT shape stability.
    pub pad_links: usize,
}

impl Scenario {
    /// Flattened per-link / routing arrays (the compiled model's inputs).
    ///
    /// Links are padded up to [`Scenario::padded_links`] so the array
    /// shapes depend only on the scenario *class* (grid dimensions), not on
    /// the seed-dependent street removals — the AOT-compiled model bakes
    /// these shapes. Padded rows behave like the sentinel row (no agent is
    /// ever placed on them).
    pub fn sim_arrays(&self) -> SimArrays {
        let nl = self.padded_links();
        let real = self.net.n_links();
        assert!(real <= nl, "network exceeds padded link budget");
        let s = self.shelters.len();
        let mut length: Vec<f32> = self.net.links.iter().map(|l| l.length).collect();
        length.resize(nl + 1, SENTINEL_LENGTH);
        let mut to: Vec<i32> = self.net.links.iter().map(|l| l.to as i32).collect();
        to.resize(nl + 1, 0);
        // NO_ROUTE (−1) exported as 0: never consulted (see sim.rs header).
        let next_link: Vec<i32> =
            self.routing.next.iter().map(|&x| if x < 0 { 0 } else { x }).collect();
        let shelter_node: Vec<i32> = self.shelters.iter().map(|sh| sh.node as i32).collect();
        SimArrays { length, to, next_link, shelter_node, n_links: nl, n_shelters: s }
    }

    /// Fixed link budget of the scenario class: the unperturbed full grid
    /// (removals only shrink the real count).
    pub fn padded_links(&self) -> usize {
        self.pad_links
    }

    pub fn total_population(&self) -> f64 {
        self.subareas.iter().map(|a| a.population).sum()
    }

    pub fn total_capacity(&self) -> f64 {
        self.shelters.iter().map(|s| s.capacity).sum()
    }

    /// Persons represented by one simulated agent.
    pub fn persons_per_agent(&self) -> f64 {
        self.total_population() / self.n_agents as f64
    }
}

/// Generation knobs for synthetic scenarios.
#[derive(Clone, Debug)]
pub struct ScenarioParams {
    pub grid: GridCityParams,
    pub n_shelters: usize,
    /// Sub-area tiling: the city is cut into `sub_w × sub_h` tiles.
    pub sub_w: usize,
    pub sub_h: usize,
    pub total_population: f64,
    /// Total shelter capacity as a fraction of the population (≤ 1 makes
    /// f3 a real constraint, as in a dense ward).
    pub capacity_ratio: f64,
    pub n_agents: usize,
    pub sim: SimParams,
}

impl ScenarioParams {
    /// Small scenario for tests: ~30 nodes, 3 shelters, 6 sub-areas.
    pub fn tiny() -> Self {
        Self {
            grid: GridCityParams { width: 6, height: 5, removal: 0.05, ..Default::default() },
            n_shelters: 3,
            sub_w: 3,
            sub_h: 2,
            total_population: 3000.0,
            capacity_ratio: 0.9,
            n_agents: 256,
            sim: SimParams { max_steps: 512, ..Default::default() },
        }
    }

    /// The default application scenario ("yodogawa-mini", DESIGN.md):
    /// 20×20 grid ≈ 400 nodes / ~1300 links, 12 shelters, 64 sub-areas,
    /// 49 726 persons represented by 4 096 agents.
    pub fn yodogawa_mini() -> Self {
        Self {
            grid: GridCityParams { width: 20, height: 20, ..Default::default() },
            n_shelters: 12,
            sub_w: 8,
            sub_h: 8,
            total_population: 49_726.0,
            capacity_ratio: 0.85,
            n_agents: 4096,
            sim: SimParams { max_steps: 1024, ..Default::default() },
        }
    }
}

/// Build a scenario deterministically from `seed`.
pub fn build_scenario(p: &ScenarioParams, seed: u64) -> Scenario {
    let mut rng = Pcg64::new(seed ^ EVAC_SEED_SALT);
    let net = grid_city(&p.grid, rng.next_u64());
    let n = net.n_nodes();
    // Shelters: distinct random nodes, roughly spread by rejection on
    // minimum pairwise grid distance.
    let mut shelter_nodes: Vec<usize> = Vec::new();
    let min_sep = ((p.grid.width.min(p.grid.height)) as f64 / (p.n_shelters as f64).sqrt()
        * p.grid.spacing
        * 0.5)
        .max(p.grid.spacing);
    let mut attempts = 0;
    while shelter_nodes.len() < p.n_shelters {
        attempts += 1;
        let cand = rng.below(n as u64) as usize;
        let ok = shelter_nodes.iter().all(|&s| {
            let (a, b) = (&net.nodes[s], &net.nodes[cand]);
            let d = ((a.x - b.x).powi(2) + (a.y - b.y).powi(2)).sqrt();
            d >= min_sep || attempts > 50 * p.n_shelters
        });
        if ok && !shelter_nodes.contains(&cand) {
            shelter_nodes.push(cand);
        }
    }
    // Capacities: Dirichlet-ish random split of capacity_ratio × population.
    let total_cap = p.total_population * p.capacity_ratio;
    let mut weights: Vec<f64> = (0..p.n_shelters).map(|_| rng.range_f64(0.5, 2.0)).collect();
    let wsum: f64 = weights.iter().sum();
    for w in &mut weights {
        *w *= total_cap / wsum;
    }
    let shelters: Vec<Shelter> = shelter_nodes
        .iter()
        .zip(&weights)
        .map(|(&node, &capacity)| Shelter { node, capacity })
        .collect();

    // Sub-areas: tile the grid into sub_w × sub_h buckets by node index
    // position (nodes are laid out row-major by grid_city).
    let n_sub = p.sub_w * p.sub_h;
    let mut nodes_per_sub: Vec<Vec<usize>> = vec![Vec::new(); n_sub];
    for node in 0..n {
        let (i, j) = (node % p.grid.width, node / p.grid.width);
        let si = (i * p.sub_w / p.grid.width).min(p.sub_w - 1);
        let sj = (j * p.sub_h / p.grid.height).min(p.sub_h - 1);
        nodes_per_sub[sj * p.sub_w + si].push(node);
    }
    // Populations: random weights (heavier variance than capacities —
    // residential density varies block to block).
    let mut pops: Vec<f64> = (0..n_sub).map(|_| rng.range_f64(0.2, 3.0)).collect();
    let psum: f64 = pops.iter().sum();
    for q in &mut pops {
        *q *= p.total_population / psum;
    }
    let subareas: Vec<Subarea> = nodes_per_sub
        .into_iter()
        .zip(&pops)
        .map(|(nodes, &population)| Subarea { nodes, population })
        .collect();
    assert!(subareas.iter().all(|a| !a.nodes.is_empty()), "empty sub-area tile");

    // Apportion simulated agents by population (largest remainder).
    let agents_per_subarea = apportion(p.n_agents, &pops);

    let routing = RoutingTable::build(&net, &shelter_nodes);
    // Full-grid directed link count: every interior street in both
    // directions — the upper bound regardless of removals.
    let pad_links = 2 * (p.grid.width * (p.grid.height - 1) + p.grid.height * (p.grid.width - 1));
    Scenario {
        net,
        shelters,
        subareas,
        routing,
        params: p.sim,
        n_agents: p.n_agents,
        agents_per_subarea,
        pad_links,
    }
}

/// Salt so scenario seeds don't collide with other subsystem seeds.
const EVAC_SEED_SALT: u64 = 0xE7AC_5EED;

/// Largest-remainder apportionment of `total` items by `weights`.
pub fn apportion(total: usize, weights: &[f64]) -> Vec<usize> {
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0);
    let quotas: Vec<f64> = weights.iter().map(|w| w / wsum * total as f64).collect();
    let mut out: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let assigned: usize = out.iter().sum();
    let mut rema: Vec<(f64, usize)> =
        quotas.iter().enumerate().map(|(i, q)| (q - q.floor(), i)).collect();
    // Descending by remainder with NaN quotas last (negating flips the
    // finite order while NaN stays NaN): an infinite weight turns its own
    // quota into NaN — it must neither panic the sort (the old
    // `partial_cmp().unwrap()`) nor soak up the leftover items first.
    rema.sort_by(|a, b| nan_worst(-a.0, -b.0));
    for k in 0..(total - assigned) {
        out[rema[k % rema.len()].1] += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apportion_sums_and_tracks_weights() {
        let out = apportion(100, &[1.0, 1.0, 2.0]);
        assert_eq!(out.iter().sum::<usize>(), 100);
        assert_eq!(out, vec![25, 25, 50]);
        let out = apportion(7, &[0.5, 0.5]);
        assert_eq!(out.iter().sum::<usize>(), 7);
    }

    #[test]
    fn apportion_survives_infinite_weight_nan_quota() {
        // An infinite weight makes wsum infinite, so its own quota is
        // inf/inf = NaN while every finite weight's quota collapses to 0.
        // Regression: the remainder sort used `partial_cmp().unwrap()`
        // and panicked here. Now the NaN ranks last, every item is still
        // handed out, and nothing lands on the poisoned entry first.
        let out = apportion(10, &[1.0, f64::INFINITY]);
        assert_eq!(out.iter().sum::<usize>(), 10, "largest-remainder must conserve the total");
        let out = apportion(3, &[f64::INFINITY, 2.0, 2.0]);
        assert_eq!(out.iter().sum::<usize>(), 3);
        assert!(out[1] >= 1 && out[2] >= 1, "finite weights are served before the NaN quota");
    }

    #[test]
    fn tiny_scenario_well_formed() {
        let sc = build_scenario(&ScenarioParams::tiny(), 1);
        assert_eq!(sc.shelters.len(), 3);
        assert_eq!(sc.subareas.len(), 6);
        assert_eq!(sc.n_agents, 256);
        assert_eq!(sc.agents_per_subarea.iter().sum::<usize>(), 256);
        assert!((sc.total_population() - 3000.0).abs() < 1e-6);
        assert!((sc.total_capacity() - 2700.0).abs() < 1e-6);
        // Every node appears in exactly one sub-area.
        let mut all: Vec<usize> = sc.subareas.iter().flat_map(|a| a.nodes.clone()).collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), sc.net.n_nodes());
        // Routing reaches every shelter from every node.
        for v in 0..sc.net.n_nodes() {
            for s in 0..sc.shelters.len() {
                assert!(sc.routing.distance(v, s).is_finite());
            }
        }
    }

    #[test]
    fn scenario_deterministic() {
        let a = build_scenario(&ScenarioParams::tiny(), 5);
        let b = build_scenario(&ScenarioParams::tiny(), 5);
        assert_eq!(a.agents_per_subarea, b.agents_per_subarea);
        assert_eq!(a.shelters.len(), b.shelters.len());
        assert_eq!(a.net.links, b.net.links);
    }

    #[test]
    fn sim_arrays_shapes_and_sentinel() {
        let sc = build_scenario(&ScenarioParams::tiny(), 2);
        let arr = sc.sim_arrays();
        assert_eq!(arr.length.len(), sc.padded_links() + 1);
        assert_eq!(arr.to.len(), sc.padded_links() + 1);
        assert!(sc.padded_links() >= sc.net.n_links());
        // Padded rows and the sentinel behave identically.
        for l in sc.net.n_links()..=sc.padded_links() {
            assert_eq!(arr.length[l], SENTINEL_LENGTH);
            assert_eq!(arr.to[l], 0);
        }
        assert_eq!(arr.next_link.len(), sc.net.n_nodes() * 3);
        assert!(arr.next_link.iter().all(|&x| x >= 0 && (x as usize) < sc.net.n_links()));
        // tiny: 6×5 grid ⇒ 2·(6·4 + 5·5) = 98 padded links.
        assert_eq!(sc.padded_links(), 98);
    }

    #[test]
    fn yodogawa_mini_scale() {
        let p = ScenarioParams::yodogawa_mini();
        let sc = build_scenario(&p, 0);
        assert_eq!(sc.net.n_nodes(), 400);
        assert!(sc.net.n_links() > 1000, "links {}", sc.net.n_links());
        assert_eq!(sc.subareas.len(), 64);
        assert_eq!(sc.shelters.len(), 12);
        assert_eq!(sc.n_agents, 4096);
    }
}
