//! Plan evaluation: genome → `[f1, f2, f3]`.
//!
//! The simulation backend is pluggable: [`RustSimBackend`] runs the
//! reference simulator of [`super::sim`]; the PJRT backend in
//! [`crate::runtime`] executes the AOT-compiled JAX/Pallas model. Both
//! implement [`SimBackend`] so the optimizer, examples and benches switch
//! between them with a flag — and the cross-check tests assert they agree.

use std::sync::Arc;

use super::plan::{f2_complexity, f3_excess, init_agents, PlanCodec};
use super::scenario::Scenario;
use super::sim::{run, AgentState, SimArrays, SimOutput};
use crate::scheduler::threads::Executor;
use crate::tasklib::{Payload, TaskSpec};

/// A simulation backend: maps an initial agent state to the sim outputs.
pub trait SimBackend: Send + Sync {
    fn run(&self, init: AgentState) -> SimOutput;
    /// Short name for logs/benches.
    fn name(&self) -> &'static str;
}

/// Pure-Rust reference backend.
pub struct RustSimBackend {
    pub arrays: SimArrays,
    pub params: super::sim::SimParams,
}

impl RustSimBackend {
    pub fn for_scenario(sc: &Scenario) -> Self {
        Self { arrays: sc.sim_arrays(), params: sc.params }
    }
}

impl SimBackend for RustSimBackend {
    fn run(&self, init: AgentState) -> SimOutput {
        run(&self.arrays, &self.params, init)
    }

    fn name(&self) -> &'static str {
        "rust-ref"
    }
}

/// Evaluates plan genomes against a scenario through a backend.
///
/// Implements [`Executor`], so it plugs directly into the threaded
/// scheduler as the consumer-side payload runner for `Payload::Eval`.
pub struct EvacEvaluator {
    pub scenario: Arc<Scenario>,
    pub codec: PlanCodec,
    pub backend: Arc<dyn SimBackend>,
    /// f1 is reported in *minutes* (the paper quotes 30–50 min runs);
    /// scale factor from simulated seconds.
    pub f1_scale: f64,
}

impl EvacEvaluator {
    pub fn new(scenario: Arc<Scenario>, backend: Arc<dyn SimBackend>) -> Self {
        let codec = PlanCodec::for_scenario(&scenario);
        Self { scenario, codec, backend, f1_scale: 1.0 / 60.0 }
    }

    /// Evaluate one genome with one seed → `[f1, f2, f3]`.
    pub fn evaluate(&self, genome: &[f64], seed: u64) -> [f64; 3] {
        let plan = self.codec.decode(genome);
        let f2 = f2_complexity(&plan);
        // f3 uses the real population numbers (persons), independent of the
        // simulated agent count.
        let f3 = f3_excess(&plan, &self.scenario);
        let init = init_agents(&self.scenario, &plan, seed);
        let out = self.backend.run(init);
        let f1 = out.evac_time * self.f1_scale;
        [f1, f2, f3]
    }

    pub fn bounds(&self) -> Vec<(f64, f64)> {
        self.codec.bounds()
    }
}

impl Executor for EvacEvaluator {
    fn run(&self, task: &TaskSpec, _consumer: usize) -> (Vec<f64>, i32) {
        match &task.payload {
            Payload::Eval { input, seed } => {
                let [f1, f2, f3] = self.evaluate(input, *seed);
                (vec![f1, f2, f3], 0)
            }
            other => panic!("EvacEvaluator got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evac::plan::Plan;
    use crate::evac::scenario::{build_scenario, ScenarioParams};

    fn evaluator() -> EvacEvaluator {
        let sc = Arc::new(build_scenario(&ScenarioParams::tiny(), 3));
        let backend = Arc::new(RustSimBackend::for_scenario(&sc));
        EvacEvaluator::new(sc, backend)
    }

    #[test]
    fn evaluation_returns_three_finite_objectives() {
        let ev = evaluator();
        let genome: Vec<f64> = ev.bounds().iter().map(|&(lo, hi)| 0.5 * (lo + hi)).collect();
        let [f1, f2, f3] = ev.evaluate(&genome, 0);
        assert!(f1.is_finite() && f1 > 0.0, "f1={f1}");
        assert!(f2.is_finite() && f2 >= 0.0);
        assert!(f3.is_finite() && f3 >= 0.0);
    }

    #[test]
    fn seeds_change_f1_not_f2_f3() {
        let ev = evaluator();
        let genome: Vec<f64> = ev.bounds().iter().map(|&(lo, hi)| 0.4 * (hi - lo) + lo).collect();
        let a = ev.evaluate(&genome, 1);
        let b = ev.evaluate(&genome, 2);
        assert_eq!(a[1], b[1]);
        assert_eq!(a[2], b[2]);
        // f1 is seed-sensitive (different initial placements) but close.
        assert!((a[0] - b[0]).abs() / a[0] < 0.5, "{} vs {}", a[0], b[0]);
    }

    #[test]
    fn splitting_to_two_shelters_reduces_f1_demonstrating_tradeoff() {
        // The paper's core trade-off: sending everyone to one shelter jams
        // the roads (large f1, zero f2); splitting across shelters cuts f1
        // at the cost of entropy. Compare the two plan archetypes.
        let ev = evaluator();
        let n_sub = ev.codec.n_subareas;
        let single = Plan {
            r: vec![1.0; n_sub],
            dest_a: vec![0; n_sub],
            dest_b: vec![0; n_sub],
        };
        // Split plan: each sub-area sends half to its two nearest shelters.
        let sc = &ev.scenario;
        let mut split = Plan { r: vec![0.5; n_sub], dest_a: vec![0; n_sub], dest_b: vec![0; n_sub] };
        for (i, sub) in sc.subareas.iter().enumerate() {
            let node = sub.nodes[0];
            let mut order: Vec<usize> = (0..sc.shelters.len()).collect();
            order.sort_by(|&a, &b| {
                // nan_worst, not partial_cmp().unwrap(): an unreachable
                // shelter column must not panic the sort.
                crate::util::stats::nan_worst_f32(
                    sc.routing.distance(node, a),
                    sc.routing.distance(node, b),
                )
            });
            split.dest_a[i] = order[0];
            split.dest_b[i] = order[1];
        }
        let g_single = ev.codec.encode(&single);
        let g_split = ev.codec.encode(&split);
        let o_single = ev.evaluate(&g_single, 0);
        let o_split = ev.evaluate(&g_split, 0);
        assert!(
            o_split[0] < o_single[0],
            "split f1 {} should beat single-shelter f1 {}",
            o_split[0],
            o_single[0]
        );
        assert!(o_split[1] > o_single[1], "split is more complex");
    }

    #[test]
    fn executor_contract() {
        let ev = evaluator();
        let genome: Vec<f64> = ev.bounds().iter().map(|&(lo, hi)| 0.3 * (hi - lo) + lo).collect();
        let task = TaskSpec::new(0, Payload::Eval { input: genome, seed: 5 });
        let (results, rc) = ev.run(&task, 0);
        assert_eq!(rc, 0);
        assert_eq!(results.len(), 3);
    }
}
