//! Shortest-path routing to shelters.
//!
//! Agents follow precomputed shortest paths: for every (node, shelter)
//! pair, [`RoutingTable`] stores the outgoing link to take. Computed with
//! one Dijkstra per shelter over the *reverse* graph (single-destination
//! shortest paths), so building the table costs `S · (E log V)`.
//!
//! The flattened `next_link` array is also the routing input of the
//! AOT-compiled JAX simulator — one compiled executable serves every plan
//! on a given network (DESIGN.md, key decision 6).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::network::RoadNetwork;
use crate::util::stats::nan_worst_f32;

/// `next[node * n_shelters + s]` = outgoing link index leading toward
/// shelter `s`, or `NO_ROUTE` when unreachable / already at the shelter.
#[derive(Clone, Debug)]
pub struct RoutingTable {
    pub n_shelters: usize,
    pub next: Vec<i32>,
    /// Shortest distance (metres) from each node to each shelter.
    pub dist: Vec<f32>,
}

pub const NO_ROUTE: i32 = -1;

impl RoutingTable {
    /// Build the table for `shelter_nodes`.
    pub fn build(net: &RoadNetwork, shelter_nodes: &[usize]) -> Self {
        let n = net.n_nodes();
        let s_count = shelter_nodes.len();
        let mut next = vec![NO_ROUTE; n * s_count];
        let mut dist_all = vec![f32::INFINITY; n * s_count];
        for (s, &shelter) in shelter_nodes.iter().enumerate() {
            let (dist, via) = reverse_dijkstra(net, shelter);
            for v in 0..n {
                dist_all[v * s_count + s] = dist[v] as f32;
                if let Some(link) = via[v] {
                    next[v * s_count + s] = link as i32;
                }
            }
        }
        Self { n_shelters: s_count, next, dist: dist_all }
    }

    #[inline]
    pub fn next_link(&self, node: usize, shelter: usize) -> i32 {
        self.next[node * self.n_shelters + shelter]
    }

    #[inline]
    pub fn distance(&self, node: usize, shelter: usize) -> f32 {
        self.dist[node * self.n_shelters + shelter]
    }

    /// Index of the nearest shelter from `node`. A NaN distance (a
    /// poisoned table — e.g. loaded from a corrupt artifact) ranks worst
    /// rather than panicking the comparator, so some reachable shelter
    /// still wins whenever one exists.
    pub fn nearest_shelter(&self, node: usize) -> usize {
        (0..self.n_shelters)
            .min_by(|&a, &b| nan_worst_f32(self.distance(node, a), self.distance(node, b)))
            .unwrap()
    }
}

/// Dijkstra from `target` over reversed links. Returns, per node, the
/// distance to the target and the *forward* link to take from that node.
fn reverse_dijkstra(net: &RoadNetwork, target: usize) -> (Vec<f64>, Vec<Option<usize>>) {
    let n = net.n_nodes();
    let mut dist = vec![f64::INFINITY; n];
    let mut via: Vec<Option<usize>> = vec![None; n];
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    // f64 distances ordered via their bit pattern (all non-negative).
    let key = |d: f64| d.to_bits();
    dist[target] = 0.0;
    heap.push(Reverse((key(0.0), target)));
    while let Some(Reverse((k, u))) = heap.pop() {
        if k > key(dist[u]) {
            continue;
        }
        // Relax reverse edges: forward link v --l--> u.
        for &l in &net.in_links[u] {
            let link = &net.links[l];
            let v = link.from;
            let nd = dist[u] + link.length as f64;
            if nd < dist[v] {
                dist[v] = nd;
                via[v] = Some(l);
                heap.push(Reverse((key(nd), v)));
            }
        }
    }
    (dist, via)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evac::network::{grid_city, GridCityParams, Node, RoadNetwork};

    fn line_net() -> RoadNetwork {
        // 0 — 1 — 2 — 3 in a line, bidirectional.
        let mut net = RoadNetwork::new(
            (0..4).map(|i| Node { x: i as f64 * 100.0, y: 0.0 }).collect(),
        );
        for i in 0..3 {
            net.add_street(i, i + 1);
        }
        net
    }

    #[test]
    fn line_routes_point_toward_shelter() {
        let net = line_net();
        let rt = RoutingTable::build(&net, &[3]);
        // From node 0 the next link must head to node 1, etc.
        for v in 0..3 {
            let l = rt.next_link(v, 0);
            assert!(l >= 0);
            let link = net.links[l as usize];
            assert_eq!(link.from, v);
            assert_eq!(link.to, v + 1);
        }
        // At the shelter itself: no route needed.
        assert_eq!(rt.next_link(3, 0), NO_ROUTE);
        assert!((rt.distance(0, 0) - 300.0).abs() < 1e-3);
        assert_eq!(rt.distance(3, 0), 0.0);
    }

    #[test]
    fn multiple_shelters_nearest_is_correct() {
        let net = line_net();
        let rt = RoutingTable::build(&net, &[0, 3]);
        assert_eq!(rt.nearest_shelter(1), 0);
        assert_eq!(rt.nearest_shelter(2), 1);
    }

    #[test]
    fn nearest_shelter_survives_nan_distances() {
        // Regression: this used to be `partial_cmp().unwrap()`, which
        // panics on the first NaN. Poison one shelter's distance column
        // and the other (finite) shelter must still win.
        let net = line_net();
        let mut rt = RoutingTable::build(&net, &[0, 3]);
        for node in 0..4 {
            rt.dist[node * rt.n_shelters] = f32::NAN; // shelter 0 poisoned
        }
        for node in 0..4 {
            assert_eq!(rt.nearest_shelter(node), 1, "NaN must rank worst, not win or panic");
        }
        // All-NaN row still returns *some* index without panicking.
        rt.dist[rt.n_shelters + 1] = f32::NAN; // node 1, shelter 1
        assert!(rt.nearest_shelter(1) < 2);
    }

    #[test]
    fn following_next_links_always_reaches_the_shelter() {
        // Property over random city graphs: from every node, walking the
        // table reaches the shelter within n_links steps, and the walked
        // distance equals the table's distance.
        let p = GridCityParams { width: 7, height: 5, ..Default::default() };
        for seed in 0..4u64 {
            let net = grid_city(&p, seed);
            let shelters = [0usize, net.n_nodes() / 2, net.n_nodes() - 1];
            let rt = RoutingTable::build(&net, &shelters);
            for (s, &shelter) in shelters.iter().enumerate() {
                for start in 0..net.n_nodes() {
                    let mut node = start;
                    let mut walked = 0.0f64;
                    let mut hops = 0;
                    while node != shelter {
                        let l = rt.next_link(node, s);
                        assert!(l >= 0, "no route {start}->{shelter}");
                        let link = net.links[l as usize];
                        assert_eq!(link.from, node);
                        walked += link.length as f64;
                        node = link.to;
                        hops += 1;
                        assert!(hops <= net.n_links(), "routing loop");
                    }
                    assert!(
                        (walked - rt.distance(start, s) as f64).abs() < 0.5,
                        "distance mismatch at {start}"
                    );
                }
            }
        }
    }

    #[test]
    fn shortest_distances_satisfy_triangle_relaxation() {
        let p = GridCityParams { width: 6, height: 6, ..Default::default() };
        let net = grid_city(&p, 9);
        let rt = RoutingTable::build(&net, &[10]);
        // For every link (u→v): dist(u) ≤ length + dist(v) (optimality).
        for link in &net.links {
            let du = rt.distance(link.from, 0);
            let dv = rt.distance(link.to, 0);
            assert!(du <= link.length + dv + 1e-3, "suboptimal at {}→{}", link.from, link.to);
        }
    }
}
