//! External-process simulators — the §2.2 contract.
//!
//! A user simulator is *any* executable that
//!
//! 1. accepts its parameters as command-line arguments,
//! 2. writes its outputs into the current directory (the scheduler runs it
//!    in a fresh per-task temporary directory), and
//! 3. optionally writes a `_results.txt` file with whitespace/comma
//!    separated floating-point values, which are parsed and sent back to
//!    the search engine.
//!
//! [`CommandExecutor`] implements that contract for
//! [`Payload::Command`](crate::tasklib::Payload::Command) tasks.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::scheduler::threads::{CancelSet, ExecOutcome, Executor};
use crate::tasklib::{Payload, TaskSpec, RC_CANCELLED, RC_TIMEOUT};

/// Name of the results file per §2.2.
pub const RESULTS_FILE: &str = "_results.txt";

/// Executes `Payload::Command` tasks as child processes in per-task
/// temporary directories and parses `_results.txt`.
pub struct CommandExecutor {
    /// Root under which per-task work dirs are created.
    pub work_root: PathBuf,
    /// Remove each task's directory after the run (default true).
    pub cleanup: bool,
    counter: AtomicU64,
}

impl CommandExecutor {
    pub fn new(work_root: impl Into<PathBuf>) -> Self {
        Self { work_root: work_root.into(), cleanup: true, counter: AtomicU64::new(0) }
    }

    /// Keep work directories for debugging.
    pub fn keep_dirs(mut self) -> Self {
        self.cleanup = false;
        self
    }

    fn task_dir(&self, task: &TaskSpec) -> PathBuf {
        let uniq = self.counter.fetch_add(1, Ordering::Relaxed);
        self.work_root.join(format!("task_{}_{}", task.id, uniq))
    }
}

/// Split a command line into argv. Supports single/double quotes and
/// backslash escapes — enough for §2.3-style command strings.
pub fn split_cmdline(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut chars = s.chars().peekable();
    let mut in_word = false;
    while let Some(c) = chars.next() {
        match c {
            ' ' | '\t' => {
                if in_word {
                    out.push(std::mem::take(&mut cur));
                    in_word = false;
                }
            }
            '\'' => {
                in_word = true;
                for q in chars.by_ref() {
                    if q == '\'' {
                        break;
                    }
                    cur.push(q);
                }
            }
            '"' => {
                in_word = true;
                while let Some(q) = chars.next() {
                    match q {
                        '"' => break,
                        '\\' => {
                            if let Some(e) = chars.next() {
                                cur.push(e);
                            }
                        }
                        _ => cur.push(q),
                    }
                }
            }
            '\\' => {
                in_word = true;
                if let Some(e) = chars.next() {
                    cur.push(e);
                }
            }
            _ => {
                in_word = true;
                cur.push(c);
            }
        }
    }
    if in_word {
        out.push(cur);
    }
    out
}

/// Why a present `_results.txt` could not be used.
#[derive(Clone, Debug, PartialEq)]
pub enum ResultsError {
    /// The file exists but could not be read.
    Unreadable(String),
    /// A token was not a floating-point number (1-based line number).
    BadToken { line: usize, token: String },
    /// A token parsed as a float but is not finite (`nan`, `inf`, …).
    /// `str::parse::<f64>` accepts these spellings, but a non-finite
    /// objective silently poisons every engine downstream (NSGA-II
    /// ranking, histograms, means), so they are rejected at the boundary.
    NonFinite { line: usize, token: String },
}

impl std::fmt::Display for ResultsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResultsError::Unreadable(e) => write!(f, "{RESULTS_FILE} unreadable: {e}"),
            ResultsError::BadToken { line, token } => {
                write!(f, "{RESULTS_FILE}:{line}: not a number: {token:?}")
            }
            ResultsError::NonFinite { line, token } => {
                write!(f, "{RESULTS_FILE}:{line}: non-finite value: {token:?}")
            }
        }
    }
}

/// Exit code reported when the simulator exited 0 but wrote a malformed
/// `_results.txt` (BSD `EX_DATAERR`). A silently-dropped garbage token
/// would otherwise feed a *shorter* result vector to the search engine,
/// which misindexes objectives — so malformed output is a task failure.
pub const RC_BAD_RESULTS: i32 = 65;

/// Strictly parse a `_results.txt` body: *finite* floats separated by
/// whitespace, commas or newlines; `#`-comments ignored; anything else —
/// including the `nan`/`inf`/`-inf` spellings `str::parse` would accept —
/// is an error ([`RC_BAD_RESULTS`] at the executor).
pub fn try_parse_results(body: &str) -> Result<Vec<f64>, ResultsError> {
    let mut out = Vec::new();
    for (idx, line) in body.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("");
        for tok in line.split(|c: char| c.is_whitespace() || c == ',') {
            if tok.is_empty() {
                continue;
            }
            match tok.parse::<f64>() {
                Ok(v) if v.is_finite() => out.push(v),
                Ok(_) => {
                    return Err(ResultsError::NonFinite { line: idx + 1, token: tok.to_string() })
                }
                Err(_) => {
                    return Err(ResultsError::BadToken { line: idx + 1, token: tok.to_string() })
                }
            }
        }
    }
    Ok(out)
}

/// Read and strictly parse `_results.txt` from `dir`. A missing file is
/// `Ok(empty)` — the file is optional per §2.2; a present-but-broken file
/// is an error.
pub fn read_results_checked(dir: &Path) -> Result<Vec<f64>, ResultsError> {
    let path = dir.join(RESULTS_FILE);
    match std::fs::read_to_string(&path) {
        Ok(body) => try_parse_results(&body),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Vec::new()),
        Err(e) => Err(ResultsError::Unreadable(e.to_string())),
    }
}

/// Cancellation + timeout poll period for running children.
const CHILD_POLL: Duration = Duration::from_millis(2);

/// Run the child to completion, polling every [`CHILD_POLL`] for two kill
/// conditions: the per-attempt timeout from
/// [`crate::api::JobSpec::timeout`] (killed, reported `(RC_TIMEOUT,
/// timed_out = true)` — the executor-side flag is what distinguishes a
/// framework kill from a simulator that happens to exit 124), and a
/// [`CancelSet`] kill request (killed, reported [`RC_CANCELLED`], which
/// the scheduler exempts from retry). Timed-out attempts consume a
/// scheduler-side retry like any other failure.
fn run_child(
    argv: &[String],
    dir: &Path,
    timeout_s: Option<f64>,
    task_id: u64,
    cancel: &CancelSet,
) -> (i32, bool) {
    let mut cmd = Command::new(&argv[0]);
    cmd.args(&argv[1..]).current_dir(dir);
    let mut child = match cmd.spawn() {
        Ok(c) => c,
        Err(_) => return (127, false),
    };
    let deadline = timeout_s.map(|s| Instant::now() + Duration::from_secs_f64(s.max(0.0)));
    loop {
        match child.try_wait() {
            Ok(Some(status)) => return (status.code().unwrap_or(-1), false),
            Ok(None) => {
                if cancel.is_cancelled(task_id) {
                    let _ = child.kill();
                    let _ = child.wait();
                    return (RC_CANCELLED, false);
                }
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    let _ = child.kill();
                    let _ = child.wait();
                    return (RC_TIMEOUT, true);
                }
                std::thread::sleep(CHILD_POLL);
            }
            Err(_) => return (127, false),
        }
    }
}

impl Executor for CommandExecutor {
    fn run(&self, task: &TaskSpec, consumer: usize) -> (Vec<f64>, i32) {
        let out = self.run_cancellable(task, consumer, &CancelSet::new());
        (out.results, out.rc)
    }

    fn run_cancellable(&self, task: &TaskSpec, _consumer: usize, cancel: &CancelSet) -> ExecOutcome {
        let Payload::Command { cmdline } = &task.payload else {
            panic!("CommandExecutor got {:?}", task.payload);
        };
        let argv = split_cmdline(cmdline);
        if argv.is_empty() {
            return ExecOutcome { results: Vec::new(), rc: 127, timed_out: false };
        }
        let dir = self.task_dir(task);
        if std::fs::create_dir_all(&dir).is_err() {
            return ExecOutcome { results: Vec::new(), rc: 126, timed_out: false };
        }
        let (rc, timed_out) = run_child(&argv, &dir, task.timeout_s, task.id, cancel);
        let (results, rc) = if rc == RC_CANCELLED {
            // Killed mid-flight: whatever the child wrote is partial.
            (Vec::new(), rc)
        } else {
            match read_results_checked(&dir) {
                Ok(results) => (results, rc),
                Err(e) => {
                    crate::warnln!("task {}: {e}", task.id);
                    // The child's own failure code wins; otherwise flag the
                    // malformed results file.
                    (Vec::new(), if rc != 0 { rc } else { RC_BAD_RESULTS })
                }
            }
        };
        if self.cleanup {
            let _ = std::fs::remove_dir_all(&dir);
        }
        ExecOutcome { results, rc, timed_out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::TaskSpec;

    #[test]
    fn split_handles_quotes_and_escapes() {
        assert_eq!(split_cmdline("echo hello world"), vec!["echo", "hello", "world"]);
        assert_eq!(split_cmdline("sh -c 'echo a b'"), vec!["sh", "-c", "echo a b"]);
        assert_eq!(split_cmdline(r#"prog "two words" x\ y"#), vec!["prog", "two words", "x y"]);
        assert!(split_cmdline("   ").is_empty());
    }

    #[test]
    fn parse_results_formats() {
        assert_eq!(try_parse_results("1.5 2.5\n3"), Ok(vec![1.5, 2.5, 3.0]));
        assert_eq!(try_parse_results("1,2,3"), Ok(vec![1.0, 2.0, 3.0]));
        assert_eq!(try_parse_results("# comment\n4 # five\n"), Ok(vec![4.0]));
        assert_eq!(try_parse_results(""), Ok(vec![]));
    }

    #[test]
    fn strict_parse_accepts_all_legal_separator_mixes() {
        // Comma vs whitespace vs newline separators, in any combination.
        assert_eq!(try_parse_results("1.5 2.5\n3"), Ok(vec![1.5, 2.5, 3.0]));
        assert_eq!(try_parse_results("1,2,3"), Ok(vec![1.0, 2.0, 3.0]));
        assert_eq!(try_parse_results("1, 2,\t3 ,4"), Ok(vec![1.0, 2.0, 3.0, 4.0]));
        assert_eq!(try_parse_results("1e-3,2.5E2 -7"), Ok(vec![1e-3, 250.0, -7.0]));
        // Trailing newline(s), CRLF, and trailing separators are all fine.
        assert_eq!(try_parse_results("1 2\n"), Ok(vec![1.0, 2.0]));
        assert_eq!(try_parse_results("1\r\n2\r\n"), Ok(vec![1.0, 2.0]));
        assert_eq!(try_parse_results("5,\n"), Ok(vec![5.0]));
        // Empty and comment-only bodies are legal (the file is optional
        // anyway, so an empty one must not be an error).
        assert_eq!(try_parse_results(""), Ok(vec![]));
        assert_eq!(try_parse_results("\n\n"), Ok(vec![]));
        assert_eq!(try_parse_results("# nothing\n  # here\n"), Ok(vec![]));
    }

    #[test]
    fn strict_parse_rejects_non_finite_values_with_location() {
        // `str::parse::<f64>` accepts every spelling below; the contract
        // does not — a NaN objective must become RC_BAD_RESULTS, not a
        // value inside the engines.
        for tok in ["nan", "NaN", "-nan", "inf", "Inf", "-inf", "infinity", "-Infinity"] {
            match try_parse_results(&format!("1.0\n2.0 {tok}")) {
                Err(ResultsError::NonFinite { line, token }) => {
                    assert_eq!(line, 2, "{tok}");
                    assert_eq!(token, tok);
                }
                other => panic!("{tok:?}: expected NonFinite, got {other:?}"),
            }
        }
        // Large-but-finite still parses; overflow to infinity does not.
        assert_eq!(try_parse_results("1e308"), Ok(vec![1e308]));
        assert!(matches!(
            try_parse_results("1e309"),
            Err(ResultsError::NonFinite { .. })
        ));
    }

    #[test]
    fn executor_flags_nan_results_as_failure() {
        // A simulator exiting 0 but writing `nan` fails gracefully with
        // RC_BAD_RESULTS — the acceptance case for the NaN result path.
        let root = std::env::temp_dir().join(format!("caravan_nan_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let task = TaskSpec::new(
            0,
            Payload::Command { cmdline: "sh -c 'echo 1.5 nan > _results.txt'".into() },
        );
        let (results, rc) = exec.run(&task, 0);
        assert_eq!(rc, RC_BAD_RESULTS);
        assert!(results.is_empty());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn strict_parse_rejects_non_numeric_tokens_with_location() {
        match try_parse_results("1.0\nbanana 2.0") {
            Err(ResultsError::BadToken { line, token }) => {
                assert_eq!(line, 2);
                assert_eq!(token, "banana");
            }
            other => panic!("expected BadToken, got {other:?}"),
        }
        assert!(try_parse_results("1.0.0").is_err());
        assert!(try_parse_results("0x10").is_err());
    }

    #[test]
    fn read_results_checked_missing_file_is_ok_empty() {
        let dir = std::env::temp_dir().join(format!("caravan_absent_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        assert_eq!(read_results_checked(&dir), Ok(vec![]));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn executor_flags_malformed_results_as_failure() {
        // Simulator exits 0 but writes garbage → RC_BAD_RESULTS, no values.
        let root = std::env::temp_dir().join(format!("caravan_bad_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let task = TaskSpec::new(
            0,
            Payload::Command { cmdline: "sh -c 'echo 1.5 oops > _results.txt'".into() },
        );
        let (results, rc) = exec.run(&task, 0);
        assert_eq!(rc, RC_BAD_RESULTS);
        assert!(results.is_empty());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn child_failure_code_wins_over_parse_failure() {
        let root = std::env::temp_dir().join(format!("caravan_badrc_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let task = TaskSpec::new(
            0,
            Payload::Command { cmdline: "sh -c 'echo junk > _results.txt; exit 4'".into() },
        );
        let (results, rc) = exec.run(&task, 0);
        assert_eq!(rc, 4);
        assert!(results.is_empty());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn executor_empty_results_file_is_success() {
        let root = std::env::temp_dir().join(format!("caravan_empty_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let task =
            TaskSpec::new(0, Payload::Command { cmdline: "sh -c ': > _results.txt'".into() });
        let (results, rc) = exec.run(&task, 0);
        assert_eq!(rc, 0);
        assert!(results.is_empty());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn runs_command_in_temp_dir_and_parses_results() {
        let root = std::env::temp_dir().join(format!("caravan_test_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        // sh -c "echo 42.5 1e3 > _results.txt"
        let task = TaskSpec::new(
            7,
            Payload::Command { cmdline: "sh -c 'echo 42.5 1e3 > _results.txt'".into() },
        );
        let (results, rc) = exec.run(&task, 0);
        assert_eq!(rc, 0);
        assert_eq!(results, vec![42.5, 1000.0]);
        // Cleanup removed the per-task dir.
        let leftovers = std::fs::read_dir(&root).map(|d| d.count()).unwrap_or(0);
        assert_eq!(leftovers, 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn nonzero_exit_code_reported() {
        let root = std::env::temp_dir().join(format!("caravan_test_rc_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let task = TaskSpec::new(0, Payload::Command { cmdline: "sh -c 'exit 3'".into() });
        let (_results, rc) = exec.run(&task, 0);
        assert_eq!(rc, 3);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn timeout_kills_runaway_child() {
        let root = std::env::temp_dir().join(format!("caravan_test_to_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let mut task = TaskSpec::new(0, Payload::Command { cmdline: "sleep 30".into() });
        task.timeout_s = Some(0.1);
        let t0 = Instant::now();
        let out = exec.run_cancellable(&task, 0, &CancelSet::new());
        assert_eq!(out.rc, RC_TIMEOUT);
        assert!(out.timed_out, "executor-enforced budget must set the flag");
        assert!(out.results.is_empty());
        assert!(t0.elapsed() < Duration::from_secs(10), "child must be killed, not awaited");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn legitimate_exit_124_is_not_flagged_as_timeout() {
        // A simulator that exits with GNU timeout's code on its own: the
        // rc passes through but `timed_out` stays false, so the job layer
        // can tell it apart from a framework kill.
        let root = std::env::temp_dir().join(format!("caravan_test_124_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let mut task = TaskSpec::new(0, Payload::Command { cmdline: "sh -c 'exit 124'".into() });
        task.timeout_s = Some(30.0);
        let out = exec.run_cancellable(&task, 0, &CancelSet::new());
        assert_eq!(out.rc, RC_TIMEOUT);
        assert!(!out.timed_out, "user exit code 124 must not read as a timeout");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn cancel_kills_running_child_within_poll_interval() {
        let root = std::env::temp_dir().join(format!("caravan_test_kill_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let task = TaskSpec::new(7, Payload::Command { cmdline: "sleep 30".into() });
        let cancel = std::sync::Arc::new(CancelSet::new());
        let killer = std::sync::Arc::clone(&cancel);
        let arm = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            killer.request(7);
        });
        let t0 = Instant::now();
        let out = exec.run_cancellable(&task, 0, &cancel);
        arm.join().unwrap();
        assert_eq!(out.rc, RC_CANCELLED);
        assert!(!out.timed_out);
        assert!(out.results.is_empty());
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "child must die within the cancellation poll interval, not run 30 s"
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn timeout_is_inert_for_fast_children() {
        let root = std::env::temp_dir().join(format!("caravan_test_tof_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let mut task = TaskSpec::new(
            0,
            Payload::Command { cmdline: "sh -c 'echo 7 > _results.txt'".into() },
        );
        task.timeout_s = Some(30.0);
        let (results, rc) = exec.run(&task, 0);
        assert_eq!(rc, 0);
        assert_eq!(results, vec![7.0]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn missing_binary_is_127() {
        let root = std::env::temp_dir().join(format!("caravan_test_nf_{}", std::process::id()));
        let exec = CommandExecutor::new(&root);
        let task = TaskSpec::new(
            0,
            Payload::Command { cmdline: "/definitely/not/a/binary arg".into() },
        );
        let (_results, rc) = exec.run(&task, 0);
        assert_eq!(rc, 127);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn outputs_stay_in_task_dir() {
        // §2.2: the simulator writes to its *current directory*; verify the
        // framework isolates tasks from each other and from the CWD.
        let root = std::env::temp_dir().join(format!("caravan_test_iso_{}", std::process::id()));
        let exec = CommandExecutor::new(&root).keep_dirs();
        let t1 = TaskSpec::new(1, Payload::Command { cmdline: "sh -c 'echo 1 > _results.txt; echo x > out.dat'".into() });
        let t2 = TaskSpec::new(2, Payload::Command { cmdline: "sh -c 'echo 2 > _results.txt'".into() });
        let (r1, _) = exec.run(&t1, 0);
        let (r2, _) = exec.run(&t2, 0);
        assert_eq!(r1, vec![1.0]);
        assert_eq!(r2, vec![2.0]);
        // Two distinct directories remain (keep_dirs).
        let dirs = std::fs::read_dir(&root).unwrap().count();
        assert_eq!(dirs, 2);
        let _ = std::fs::remove_dir_all(&root);
    }
}
