//! A lightweight Rust tokenizer for the lint pass.
//!
//! This is *not* a full lexer: it produces just enough structure for the
//! token-pattern rules in [`super::rules`] — identifiers, numbers and
//! single-character punctuation, with comments, string/char literals and
//! lifetimes correctly skipped so a `partial_cmp` inside a doc comment or
//! a `"HashMap"` inside a string literal can never trip a rule.
//!
//! Two extras ride on top of raw tokenization:
//!
//! * comments are collected separately (the `lint:allow` escape hatch
//!   lives in them), and
//! * every token is tagged `in_test` when it sits inside a `#[test]` fn
//!   or `#[cfg(test)]` module, so rules scoped to production code can
//!   skip test regions without parsing items.

/// One lexed token: its 1-based source line, its text, and whether it is
/// inside a `#[test]` / `#[cfg(test)]` region.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tok {
    /// 1-based line the token starts on.
    pub line: u32,
    /// Token text (identifier, number, or a single punctuation char).
    pub text: String,
    /// True when the token sits inside a `#[test]` or `#[cfg(test)]`
    /// brace region.
    pub in_test: bool,
}

/// One comment (line or block), with the 1-based line it starts on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: u32,
    /// Full comment text including the `//` / `/*` introducer.
    pub text: String,
}

/// The result of lexing one source file.
#[derive(Clone, Debug, Default)]
pub struct Lexed {
    /// Code tokens in source order.
    pub tokens: Vec<Tok>,
    /// Comments in source order (not part of `tokens`).
    pub comments: Vec<Comment>,
}

/// Tokenize `src`. Never fails: unterminated literals simply consume to
/// end-of-file, which is good enough for a lint that runs on code the
/// compiler already accepted.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut tokens: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also `///` and `//!` doc comments).
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            comments.push(Comment { line, text: b[start..i].iter().collect() });
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut text = String::from("/*");
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    text.push_str("/*");
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    text.push_str("*/");
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    text.push(b[i]);
                    i += 1;
                }
            }
            comments.push(Comment { line: start_line, text });
            continue;
        }
        // Raw / byte / byte-raw strings: r"..", r#".."#, b"..", br#".."#.
        if c == 'r' || c == 'b' {
            if let Some(next_i) = skip_raw_or_byte_string(&b, i, &mut line) {
                i = next_i;
                continue;
            }
        }
        // Plain string literal.
        if c == '"' {
            i = skip_string(&b, i, &mut line);
            continue;
        }
        // Char literal or lifetime.
        if c == '\'' {
            i = skip_char_or_lifetime(&b, i, &mut line);
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            tokens.push(Tok { line, text: b[start..i].iter().collect(), in_test: false });
            continue;
        }
        // Number (loose: handles 0x1f, 1_000, 1.5e3; splitting oddities
        // like `1e-3` into two tokens is harmless for our rules).
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            if i + 1 < n && b[i] == '.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
            }
            tokens.push(Tok { line, text: b[start..i].iter().collect(), in_test: false });
            continue;
        }
        // Everything else: single-character punctuation token.
        tokens.push(Tok { line, text: c.to_string(), in_test: false });
        i += 1;
    }

    mark_test_regions(&mut tokens);
    Lexed { tokens, comments }
}

/// Skip a `"..."` literal starting at `i` (which holds the opening
/// quote); returns the index one past the closing quote.
fn skip_string(b: &[char], mut i: usize, line: &mut u32) -> usize {
    let n = b.len();
    i += 1; // opening quote
    while i < n {
        match b[i] {
            '\\' => i += 2,
            '"' => return i + 1,
            c => {
                if c == '\n' {
                    *line += 1;
                }
                i += 1;
            }
        }
    }
    i
}

/// Try to skip a raw string `r#".."#`, byte string `b".."` or byte-raw
/// string `br#".."#` starting at `i`. Returns `None` when the characters
/// at `i` are not actually a string introducer (e.g. the identifier `r`
/// or `b` used as a variable name), in which case the caller falls
/// through to identifier lexing.
fn skip_raw_or_byte_string(b: &[char], start: usize, line: &mut u32) -> Option<usize> {
    let n = b.len();
    let mut i = start;
    if b[i] == 'b' {
        i += 1;
        if i < n && b[i] == 'r' {
            i += 1;
        } else if i < n && b[i] == '"' {
            return Some(skip_string(b, i, line)); // b"..." — escapes as usual
        } else {
            return None;
        }
    } else {
        i += 1; // the 'r'
    }
    let mut hashes = 0usize;
    while i < n && b[i] == '#' {
        hashes += 1;
        i += 1;
    }
    if i >= n || b[i] != '"' {
        return None; // `r` / `br` was an identifier after all
    }
    i += 1; // opening quote; raw strings have no escapes
    while i < n {
        if b[i] == '\n' {
            *line += 1;
            i += 1;
            continue;
        }
        if b[i] == '"' {
            let mut j = i + 1;
            let mut seen = 0usize;
            while j < n && b[j] == '#' && seen < hashes {
                seen += 1;
                j += 1;
            }
            if seen == hashes {
                return Some(j);
            }
        }
        i += 1;
    }
    Some(i)
}

/// Skip a char literal (`'x'`, `'\n'`) or a lifetime (`'a`, `'static`)
/// starting at the `'` at `i`; returns the index one past it.
fn skip_char_or_lifetime(b: &[char], i: usize, line: &mut u32) -> usize {
    let n = b.len();
    if i + 1 < n && b[i + 1] == '\\' {
        // Escaped char literal: consume to the closing quote.
        let mut j = i + 2;
        while j < n {
            match b[j] {
                '\\' => j += 2,
                '\'' => return j + 1,
                c => {
                    if c == '\n' {
                        *line += 1;
                    }
                    j += 1;
                }
            }
        }
        return j;
    }
    if i + 2 < n && b[i + 2] == '\'' {
        return i + 3; // 'x'
    }
    // Lifetime: consume the quote plus the identifier.
    let mut j = i + 1;
    while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
        j += 1;
    }
    j
}

/// Tag tokens inside `#[test]` fns and `#[cfg(test)]` modules.
///
/// Heuristic, not a parser: after an attribute whose tokens are `test` or
/// `cfg(.. test ..)`, the next `{`-balanced region is a test region. A
/// `;` before the `{` cancels (e.g. `#[cfg(test)] use foo;`). Regions
/// nest; brace depth is tracked globally.
fn mark_test_regions(tokens: &mut [Tok]) {
    let mut depth: i64 = 0;
    // Depths at which currently-open test regions were entered.
    let mut open_regions: Vec<i64> = Vec::new();
    let mut pending_test_attr = false;
    let mut i = 0usize;
    while i < tokens.len() {
        let is_attr_start = tokens[i].text == "#"
            && tokens.get(i + 1).map_or(false, |t| t.text == "[");
        if is_attr_start {
            // Scan to the matching ']'.
            let mut j = i + 2;
            let mut bd = 1i64;
            let mut first_ident: Option<String> = None;
            let mut mentions_test = false;
            while j < tokens.len() && bd > 0 {
                let t = tokens[j].text.as_str();
                if t == "[" {
                    bd += 1;
                } else if t == "]" {
                    bd -= 1;
                } else {
                    if first_ident.is_none() && t.chars().all(|c| c.is_alphanumeric() || c == '_')
                    {
                        first_ident = Some(t.to_string());
                    }
                    if t == "test" {
                        mentions_test = true;
                    }
                }
                if !open_regions.is_empty() {
                    tokens[j].in_test = true;
                }
                j += 1;
            }
            if mentions_test
                && matches!(first_ident.as_deref(), Some("test") | Some("cfg"))
            {
                pending_test_attr = true;
            }
            i = j;
            continue;
        }
        match tokens[i].text.as_str() {
            "{" => {
                depth += 1;
                if pending_test_attr {
                    open_regions.push(depth);
                    pending_test_attr = false;
                }
            }
            "}" => {
                if open_regions.last() == Some(&depth) {
                    open_regions.pop();
                }
                depth -= 1;
            }
            ";" => pending_test_attr = false,
            _ => {}
        }
        if !open_regions.is_empty() {
            tokens[i].in_test = true;
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).tokens.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_and_strings_are_not_tokens() {
        let src = r##"
// partial_cmp in a line comment
/* HashMap in /* a nested */ block */
let s = "Instant::now() in a string";
let r = r#"SystemTime in a raw "string""#;
let c = 'x';
let nl = '\n';
"##;
        let t = texts(src);
        assert!(!t.iter().any(|x| x == "partial_cmp"));
        assert!(!t.iter().any(|x| x == "HashMap"));
        assert!(!t.iter().any(|x| x == "Instant"));
        assert!(!t.iter().any(|x| x == "SystemTime"));
        assert!(t.iter().any(|x| x == "let"));
        let lexed = lex(src);
        assert_eq!(lexed.comments.len(), 2);
        assert!(lexed.comments[0].text.contains("partial_cmp"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let t = texts("fn f<'a>(x: &'a str, y: &'static str) -> char { 'q' }");
        assert!(t.iter().any(|x| x == "str"));
        assert!(t.iter().any(|x| x == "char"));
        // The 'q' literal is skipped, the lifetime names are skipped.
        assert!(!t.iter().any(|x| x == "q"));
    }

    #[test]
    fn line_numbers_track_newlines_inside_literals() {
        let src = "let a = \"two\nlines\";\nlet target = 1;\n";
        let lexed = lex(src);
        let tok = lexed.tokens.iter().find(|t| t.text == "target").expect("target token");
        assert_eq!(tok.line, 3);
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "
fn prod() { hot(); }
#[cfg(test)]
mod tests {
    #[test]
    fn t() { cold(); }
}
fn prod2() { hot2(); }
";
        let lexed = lex(src);
        let find = |name: &str| lexed.tokens.iter().find(|t| t.text == name).expect("token");
        assert!(!find("hot").in_test);
        assert!(find("cold").in_test);
        assert!(!find("hot2").in_test);
    }

    #[test]
    fn cfg_test_on_use_item_does_not_poison_the_next_brace() {
        let src = "
#[cfg(test)]
use std::fmt;
fn prod() { hot(); }
";
        let lexed = lex(src);
        let hot = lexed.tokens.iter().find(|t| t.text == "hot").expect("token");
        assert!(!hot.in_test);
    }

    #[test]
    fn test_attr_on_fn_marks_only_its_body() {
        let src = "
#[test]
fn t() { cold(); }
fn prod() { hot(); }
";
        let lexed = lex(src);
        let find = |name: &str| lexed.tokens.iter().find(|t| t.text == name).expect("token");
        assert!(find("cold").in_test);
        assert!(!find("hot").in_test);
    }
}
