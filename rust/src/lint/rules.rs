//! The five lint rules, each a token-pattern visitor over a lexed file.
//!
//! Every rule here is grounded in a bug class this repo has actually
//! fixed by hand at least once (see `docs/ARCHITECTURE.md`, "Determinism
//! invariants & lint rules"): the rules exist so the next regression is
//! caught at lint time, not in a panic trace from a 10^5-consumer run.

use super::lexer::Lexed;

/// One rule violation: the rule name, the 1-based line, a message, and a
/// `--fix-hints` suggestion.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Rule identifier, e.g. `float-ord` (also the `lint:allow` key).
    pub rule: &'static str,
    /// 1-based source line of the offending token.
    pub line: u32,
    /// Human-readable description of what was matched.
    pub msg: String,
    /// Suggested fix, printed under `--fix-hints`.
    pub hint: &'static str,
}

/// A lint rule: a name, a path scope, and a token-level check.
pub trait Rule {
    /// Stable rule identifier (used in output and in `lint:allow(...)`).
    fn name(&self) -> &'static str;
    /// Whether the rule runs on this file at all (path scoping).
    fn applies(&self, path: &str) -> bool;
    /// Scan a lexed file and return violations (unsuppressed; the engine
    /// applies `lint:allow` afterwards).
    fn check(&self, path: &str, lexed: &Lexed) -> Vec<Violation>;
}

/// The full rule registry, in reporting order.
pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(FloatOrd),
        Box::new(WallClock),
        Box::new(HashIter),
        Box::new(UnwrapBudget),
        Box::new(PanicPath),
        Box::new(NoUnsafe),
    ]
}

/// True for integration-test and bench sources, which are wall-clock and
/// panic-happy by nature; production-only rules skip them wholesale.
pub fn is_test_path(path: &str) -> bool {
    path.starts_with("tests/")
        || path.contains("/tests/")
        || path.starts_with("benches/")
        || path.contains("/benches/")
}

fn path_in(path: &str, needles: &[&str]) -> bool {
    needles.iter().any(|n| path.contains(n) || path.ends_with(n.trim_end_matches('/')))
}

/// Skip a balanced `(..)` group; `open` indexes the `(`. Returns the
/// index one past the matching `)`.
fn skip_paren_group(toks: &[super::lexer::Tok], open: usize) -> usize {
    let mut j = open + 1;
    let mut depth = 1i64;
    while j < toks.len() && depth > 0 {
        match toks[j].text.as_str() {
            "(" => depth += 1,
            ")" => depth -= 1,
            _ => {}
        }
        j += 1;
    }
    j
}

/// **float-ord** — the NaN-panic rule.
///
/// Flags `partial_cmp(..).unwrap()` / `.expect(..)` chains anywhere
/// (tests included: both live sites fixed in this PR were in test mods),
/// plus any `partial_cmp` used inside a `sort_by` / `min_by` / `max_by`
/// comparator, where a NaN either panics the comparator or silently
/// breaks the total order the sort relies on.
pub struct FloatOrd;

const COMPARATOR_SINKS: &[&str] =
    &["sort_by", "sort_unstable_by", "min_by", "max_by", "binary_search_by"];

impl Rule for FloatOrd {
    fn name(&self) -> &'static str {
        "float-ord"
    }
    fn applies(&self, _path: &str) -> bool {
        true
    }
    fn check(&self, _path: &str, lexed: &Lexed) -> Vec<Violation> {
        let toks = &lexed.tokens;
        let mut lines: Vec<u32> = Vec::new();
        for i in 0..toks.len() {
            let t = toks[i].text.as_str();
            if t == "partial_cmp" {
                // `fn partial_cmp` is the PartialOrd impl itself, not a use.
                if i > 0 && toks[i - 1].text == "fn" {
                    continue;
                }
                if toks.get(i + 1).map_or(true, |n| n.text != "(") {
                    continue;
                }
                let after = skip_paren_group(toks, i + 1);
                let dot = toks.get(after).map_or(false, |n| n.text == ".");
                let panics = toks
                    .get(after + 1)
                    .map_or(false, |n| n.text == "unwrap" || n.text == "expect");
                if dot && panics {
                    lines.push(toks[i].line);
                }
            } else if COMPARATOR_SINKS.contains(&t)
                && toks.get(i + 1).map_or(false, |n| n.text == "(")
            {
                let end = skip_paren_group(toks, i + 1);
                for tok in &toks[i + 2..end.min(toks.len())] {
                    if tok.text == "partial_cmp" {
                        lines.push(tok.line);
                    }
                }
            }
        }
        lines.sort_unstable();
        lines.dedup();
        lines
            .into_iter()
            .map(|line| Violation {
                rule: self.name(),
                line,
                msg: "float comparison that panics or loses totality on NaN (partial_cmp in a \
                      sort/min/max comparator or followed by unwrap/expect)"
                    .into(),
                hint: "order floats with f64::total_cmp, util::stats::nan_worst / \
                       nan_worst_slice, or sort by a non-float key",
            })
            .collect()
    }
}

/// **wall-clock** — the virtual-time determinism rule.
///
/// `Instant::now` / `SystemTime` reads are only meaningful in the
/// real-I/O shell of the system. Inside the DES, the protocol state
/// machines, the reshape controller or the engines they silently couple
/// results to host timing and break bit-identical replay.
pub struct WallClock;

/// Modules allowed to read the wall clock: the external-process runner,
/// the socket serving loop, the threaded runtime (real time *is* its
/// clock), and log timestamping. Everything else gets time handed to it
/// via `set_now`.
pub const WALL_CLOCK_ALLOWLIST: &[&str] =
    &["src/extproc/", "src/scheduler/net.rs", "src/scheduler/threads.rs", "src/util/log.rs"];

impl Rule for WallClock {
    fn name(&self) -> &'static str {
        "wall-clock"
    }
    fn applies(&self, path: &str) -> bool {
        !is_test_path(path) && !path_in(path, WALL_CLOCK_ALLOWLIST)
    }
    fn check(&self, _path: &str, lexed: &Lexed) -> Vec<Violation> {
        let toks = &lexed.tokens;
        let mut out = Vec::new();
        for i in 0..toks.len() {
            if toks[i].in_test {
                continue;
            }
            let t = toks[i].text.as_str();
            let instant_now = t == "Instant"
                && toks.get(i + 1).map_or(false, |n| n.text == ":")
                && toks.get(i + 2).map_or(false, |n| n.text == ":")
                && toks.get(i + 3).map_or(false, |n| n.text == "now");
            if instant_now || t == "SystemTime" {
                out.push(Violation {
                    rule: self.name(),
                    line: toks[i].line,
                    msg: format!(
                        "wall-clock read ({}) outside the I/O allowlist breaks virtual-time \
                         determinism",
                        if instant_now { "Instant::now" } else { "SystemTime" }
                    ),
                    hint: "take time from the scheduler clock (set_now / DES virtual time) or \
                           move the code into an allowlisted I/O module",
                });
            }
        }
        out
    }
}

/// **hash-iter** — the iteration-order determinism rule.
///
/// `HashMap`/`HashSet` iteration order varies per process, so any use in
/// a path that feeds DES event order or report output is a
/// nondeterminism seed. The scoped files must use `BTreeMap`/`BTreeSet`
/// (or justify a lookup-only map with `lint:allow`).
pub struct HashIter;

/// Deterministic-output paths: the DES, metrics/report building, the
/// session status surface, and the model checker (whose state counts and
/// visited-set pruning must be bit-identical run to run).
pub const HASH_ITER_SCOPE: &[&str] =
    &["src/des/", "src/scheduler/metrics.rs", "src/engine/session.rs", "src/check/"];

impl Rule for HashIter {
    fn name(&self) -> &'static str {
        "hash-iter"
    }
    fn applies(&self, path: &str) -> bool {
        !is_test_path(path) && path_in(path, HASH_ITER_SCOPE)
    }
    fn check(&self, _path: &str, lexed: &Lexed) -> Vec<Violation> {
        lexed
            .tokens
            .iter()
            .filter(|t| !t.in_test && (t.text == "HashMap" || t.text == "HashSet"))
            .map(|t| Violation {
                rule: self.name(),
                line: t.line,
                msg: format!(
                    "{} in a deterministic-output path: its iteration order is randomized per \
                     process",
                    t.text
                ),
                hint: "use BTreeMap/BTreeSet, or collect and sort before iterating",
            })
            .collect()
    }
}

/// **unwrap-budget** — the no-panic-in-the-tree rule.
///
/// A panic in the protocol state machines, the wire codec or the tenancy
/// layer tears down a whole subtree and loses every queued task in it.
/// Non-test code there must bubble errors (`?`, `let .. else`, `match`)
/// instead of `unwrap()`/`expect(..)`.
pub struct UnwrapBudget;

/// Panic-free zones: protocol state machines, transport, tenancy.
pub const UNWRAP_BUDGET_SCOPE: &[&str] =
    &["src/scheduler/protocol.rs", "src/transport/", "src/tenancy/"];

impl Rule for UnwrapBudget {
    fn name(&self) -> &'static str {
        "unwrap-budget"
    }
    fn applies(&self, path: &str) -> bool {
        !is_test_path(path) && path_in(path, UNWRAP_BUDGET_SCOPE)
    }
    fn check(&self, _path: &str, lexed: &Lexed) -> Vec<Violation> {
        let toks = &lexed.tokens;
        let mut out = Vec::new();
        for i in 1..toks.len() {
            if toks[i].in_test {
                continue;
            }
            let t = toks[i].text.as_str();
            if (t == "unwrap" || t == "expect")
                && toks[i - 1].text == "."
                && toks.get(i + 1).map_or(false, |n| n.text == "(")
            {
                out.push(Violation {
                    rule: self.name(),
                    line: toks[i].line,
                    msg: format!(".{t}() in panic-free scheduler/transport/tenancy code"),
                    hint: "bubble the error with `?`, `let .. else`, Option::filter or a match \
                           — a panic here tears down the subtree and drops its queue",
                });
            }
        }
        out
    }
}

/// **panic-path** — the no-panicking-construct rule.
///
/// Complements `unwrap-budget` in the same panic-free zones: `panic!`,
/// `unreachable!`, the `assert!` family and direct `expr[index]`
/// indexing all abort the thread on bad input, and in the buffer tree a
/// thread abort drops every queued task in its subtree. Non-test code in
/// the scoped paths must bubble errors and use `.get(..)`-style access
/// (or waive a structurally-safe site with `lint:allow(panic-path)`).
pub struct PanicPath;

const PANIC_MACROS: &[&str] =
    &["panic", "unreachable", "assert", "assert_eq", "assert_ne", "todo", "unimplemented"];

/// Identifier-shaped keywords after which a `[` opens a slice/array
/// literal, pattern or type — not an indexing expression.
const NON_INDEX_KEYWORDS: &[&str] = &[
    "as", "box", "break", "const", "continue", "dyn", "else", "fn", "for", "if", "impl", "in",
    "let", "loop", "match", "mod", "move", "mut", "pub", "ref", "return", "static", "struct",
    "trait", "type", "use", "where", "while",
];

fn is_ident_like(t: &str) -> bool {
    t.chars().next().map_or(false, |c| c.is_alphabetic() || c == '_')
}

impl Rule for PanicPath {
    fn name(&self) -> &'static str {
        "panic-path"
    }
    fn applies(&self, path: &str) -> bool {
        !is_test_path(path) && path_in(path, UNWRAP_BUDGET_SCOPE)
    }
    fn check(&self, _path: &str, lexed: &Lexed) -> Vec<Violation> {
        let toks = &lexed.tokens;
        let mut out = Vec::new();
        for i in 0..toks.len() {
            if toks[i].in_test {
                continue;
            }
            let t = toks[i].text.as_str();
            if PANIC_MACROS.contains(&t) && toks.get(i + 1).map_or(false, |n| n.text == "!") {
                out.push(Violation {
                    rule: self.name(),
                    line: toks[i].line,
                    msg: format!("{t}! in panic-free scheduler/transport/tenancy code"),
                    hint: "return an error or a safe default instead — a panic here tears down \
                           the subtree and drops its queue",
                });
                continue;
            }
            // `expr[index]`: a `[` directly after a call/index result or a
            // plain identifier is an indexing expression; after `#`, `!`,
            // punctuation or a slice-position keyword it is an attribute,
            // macro-bracket, literal, pattern or type.
            if t == "[" && i > 0 {
                let p = toks[i - 1].text.as_str();
                let indexes =
                    p == ")" || p == "]" || (is_ident_like(p) && !NON_INDEX_KEYWORDS.contains(&p));
                if indexes {
                    out.push(Violation {
                        rule: self.name(),
                        line: toks[i].line,
                        msg: "direct `expr[index]` in panic-free code (out-of-range panics)"
                            .into(),
                        hint: "use .get(..) / .get_mut(..) and handle the None, or \
                               split_first / split_last / iterators for structural access",
                    });
                }
            }
        }
        out
    }
}

/// **no-unsafe** — the memory-safety lock-in rule.
///
/// The crate is 100% safe Rust today; this keeps it that way by flagging
/// any `unsafe` token and requiring `#![forbid(unsafe_code)]` in the
/// crate root so the compiler enforces the same invariant.
pub struct NoUnsafe;

impl Rule for NoUnsafe {
    fn name(&self) -> &'static str {
        "no-unsafe"
    }
    fn applies(&self, _path: &str) -> bool {
        true
    }
    fn check(&self, path: &str, lexed: &Lexed) -> Vec<Violation> {
        let mut out = Vec::new();
        for t in &lexed.tokens {
            if t.text == "unsafe" {
                out.push(Violation {
                    rule: self.name(),
                    line: t.line,
                    msg: "`unsafe` in a crate that forbids unsafe_code".into(),
                    hint: "find a safe formulation; the crate root sets #![forbid(unsafe_code)]",
                });
            }
        }
        if path.ends_with("src/lib.rs") {
            let toks = &lexed.tokens;
            let has_forbid = (0..toks.len()).any(|i| {
                toks[i].text == "forbid"
                    && toks.get(i + 1).map_or(false, |n| n.text == "(")
                    && toks.get(i + 2).map_or(false, |n| n.text == "unsafe_code")
            });
            if !has_forbid {
                out.push(Violation {
                    rule: self.name(),
                    line: 1,
                    msg: "crate root is missing #![forbid(unsafe_code)]".into(),
                    hint: "add `#![forbid(unsafe_code)]` at the top of src/lib.rs",
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn run(rule: &dyn Rule, path: &str, src: &str) -> Vec<Violation> {
        if !rule.applies(path) {
            return Vec::new();
        }
        rule.check(path, &lex(src))
    }

    #[test]
    fn float_ord_flags_partial_cmp_unwrap_and_comparator_use() {
        let bad = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
        let got = run(&FloatOrd, "src/engine/x.rs", bad);
        assert_eq!(got.len(), 1, "{got:?}");
        // partial_cmp inside a comparator is flagged even without unwrap.
        let sneaky =
            "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)); }";
        assert_eq!(run(&FloatOrd, "src/engine/x.rs", sneaky).len(), 1);
        let clean = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.total_cmp(b)); }";
        assert!(run(&FloatOrd, "src/engine/x.rs", clean).is_empty());
        // The PartialOrd impl itself is not a use.
        let imp = "impl PartialOrd for X { fn partial_cmp(&self, o: &X) -> Option<Ordering> { Some(self.cmp(o)) } }";
        assert!(run(&FloatOrd, "src/x.rs", imp).is_empty());
        // Applies inside test mods too: that is where both live sites were.
        let in_test =
            "#[cfg(test)] mod tests { fn t() { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); } }";
        assert_eq!(run(&FloatOrd, "src/x.rs", in_test).len(), 1);
    }

    #[test]
    fn wall_clock_respects_allowlist_and_test_code() {
        let bad = "fn f() { let t = Instant::now(); }";
        assert_eq!(run(&WallClock, "src/des/mod.rs", bad).len(), 1);
        assert_eq!(run(&WallClock, "src/scheduler/protocol.rs", bad).len(), 1);
        assert!(run(&WallClock, "src/scheduler/threads.rs", bad).is_empty());
        assert!(run(&WallClock, "src/util/log.rs", bad).is_empty());
        assert!(run(&WallClock, "tests/integration.rs", bad).is_empty());
        assert!(run(&WallClock, "benches/overhead.rs", bad).is_empty());
        let in_test = "#[cfg(test)] mod tests { fn t() { let t = Instant::now(); } }";
        assert!(run(&WallClock, "src/des/mod.rs", in_test).is_empty());
        let sys = "fn f() { let t = SystemTime::now(); }";
        assert_eq!(run(&WallClock, "src/engine/sweep.rs", sys).len(), 1);
    }

    #[test]
    fn hash_iter_is_scoped_to_deterministic_paths() {
        let bad = "use std::collections::HashMap; struct S { m: HashMap<u32, u32> }";
        assert_eq!(run(&HashIter, "src/des/mod.rs", bad).len(), 2);
        assert_eq!(run(&HashIter, "src/scheduler/metrics.rs", bad).len(), 2);
        assert_eq!(run(&HashIter, "src/engine/session.rs", bad).len(), 2);
        // Out of scope: fine.
        assert!(run(&HashIter, "src/engine/nsga2.rs", bad).is_empty());
        let clean = "use std::collections::BTreeMap; struct S { m: BTreeMap<u32, u32> }";
        assert!(run(&HashIter, "src/des/mod.rs", clean).is_empty());
    }

    #[test]
    fn unwrap_budget_skips_tests_and_other_modules() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(run(&UnwrapBudget, "src/scheduler/protocol.rs", bad).len(), 1);
        assert_eq!(run(&UnwrapBudget, "src/transport/wire.rs", bad).len(), 1);
        assert_eq!(run(&UnwrapBudget, "src/tenancy/mod.rs", bad).len(), 1);
        assert!(run(&UnwrapBudget, "src/engine/sweep.rs", bad).is_empty());
        let in_test = "#[cfg(test)] mod tests { fn t() { x.unwrap(); y.expect(\"msg\"); } }";
        assert!(run(&UnwrapBudget, "src/scheduler/protocol.rs", in_test).is_empty());
        // unwrap_or and friends are fine.
        let ok = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }";
        assert!(run(&UnwrapBudget, "src/scheduler/protocol.rs", ok).is_empty());
        let exp = "fn f(x: Option<u32>) -> u32 { x.expect(\"always\") }";
        assert_eq!(run(&UnwrapBudget, "src/scheduler/protocol.rs", exp).len(), 1);
    }

    #[test]
    fn panic_path_flags_macros_and_indexing_in_scope() {
        for bad in [
            "fn f() { panic!(\"boom\"); }",
            "fn f(x: u32) { if x > 3 { unreachable!() } }",
            "fn f(a: usize, b: usize) { assert_eq!(a, b); }",
            "fn f(v: &[u32], i: usize) -> u32 { v[i] }",
            "fn f(v: &[u32]) -> &[u32] { &v[1..] }",
            "fn f(m: &M, i: usize) -> u32 { m.cells()[i] }",
        ] {
            assert_eq!(run(&PanicPath, "src/scheduler/protocol.rs", bad).len(), 1, "{bad}");
            assert_eq!(run(&PanicPath, "src/transport/wire.rs", bad).len(), 1, "{bad}");
        }
        // Chained indexing flags each `[`.
        let twice = "fn f(g: &[Vec<u32>], i: usize, j: usize) -> u32 { g[i][j] }";
        assert_eq!(run(&PanicPath, "src/tenancy/mod.rs", twice).len(), 2);
        // Out of scope and test code are exempt.
        let bad = "fn f(v: &[u32], i: usize) -> u32 { v[i] }";
        assert!(run(&PanicPath, "src/engine/sweep.rs", bad).is_empty());
        assert!(run(&PanicPath, "tests/check.rs", bad).is_empty());
        let in_test = "#[cfg(test)] mod tests { fn t(v: &[u32]) -> u32 { assert!(true); v[0] } }";
        assert!(run(&PanicPath, "src/scheduler/protocol.rs", in_test).is_empty());
    }

    #[test]
    fn panic_path_ignores_non_indexing_brackets() {
        for clean in [
            "#[derive(Clone, Debug)] struct S { v: Vec<u32> }",
            "fn f() -> [u8; 4] { [0, 1, 2, 3] }",
            "fn f(v: &[u8]) -> Vec<u8> { vec![0; v.len()] }",
            "fn f(x: &[u8]) -> Option<u8> { x.get(0).copied() }",
            "fn f() { let pair = [1, 2]; let _ = pair.iter().sum::<u32>(); }",
            "fn f(x: &[u8]) -> bool { matches!(x, [1, ..]) }",
            "fn f(a: u8) -> [u8; 1] { return [a]; }",
            "fn f(v: &mut [u8]) -> Option<&mut u8> { v.get_mut(0) }",
        ] {
            assert!(run(&PanicPath, "src/scheduler/protocol.rs", clean).is_empty(), "{clean}");
        }
        // debug_assert is its own identifier, not part of the macro list.
        let dbg = "fn f(a: usize) { debug_assert_ne(a, 0); }";
        assert!(run(&PanicPath, "src/scheduler/protocol.rs", dbg).is_empty());
    }

    #[test]
    fn no_unsafe_flags_blocks_and_missing_forbid() {
        let bad = "fn f() { unsafe { std::hint::unreachable_unchecked() } }";
        assert_eq!(run(&NoUnsafe, "src/util/rng.rs", bad).len(), 1);
        // A lib.rs without the forbid attribute is itself a violation.
        let plain_lib = "pub mod util;";
        let got = run(&NoUnsafe, "src/lib.rs", plain_lib);
        assert_eq!(got.len(), 1);
        assert!(got[0].msg.contains("forbid"));
        let good_lib = "#![forbid(unsafe_code)]\npub mod util;";
        assert!(run(&NoUnsafe, "src/lib.rs", good_lib).is_empty());
        // `unsafe_code` inside the attribute is not the `unsafe` keyword.
    }
}
