//! `caravan lint` — a dependency-free static-analysis pass over the
//! crate's own sources, enforcing the determinism and NaN-safety
//! invariants the rest of the system is built on.
//!
//! The repo's correctness story (bit-identical DES replay, NaN-hardened
//! result paths, a panic-free buffer tree) kept regressing through the
//! same bug classes: `partial_cmp().unwrap()` NaN panics were hand-fixed
//! in two separate PRs, wall-clock reads crept toward virtual-time code,
//! and `HashMap` iteration orders leaked into reports. This module turns
//! those one-off fixes into enforced invariants:
//!
//! | rule | what it forbids |
//! |------|-----------------|
//! | `float-ord` | `partial_cmp(..).unwrap()` and `partial_cmp` inside sort/min/max comparators |
//! | `wall-clock` | `Instant::now` / `SystemTime` outside the I/O allowlist |
//! | `hash-iter` | `HashMap`/`HashSet` in deterministic-output paths |
//! | `unwrap-budget` | `.unwrap()` / `.expect()` in protocol/transport/tenancy non-test code |
//! | `panic-path` | `panic!` / `unreachable!` / `assert!`-family / `expr[index]` in the same panic-free zones |
//! | `no-unsafe` | any `unsafe`, plus a missing `#![forbid(unsafe_code)]` in the crate root |
//!
//! A violation can be waived in place with an escape hatch that *must*
//! carry a justification:
//!
//! ```text
//! // lint:allow(wall-clock) -- socket read deadline: real I/O, not sim time
//! let deadline = Instant::now() + timeout;
//! ```
//!
//! The directive suppresses matching diagnostics on its own line and the
//! line directly below it; an allow without justification text after
//! `--` is itself reported (rule `lint-allow`), as is an unknown rule
//! name. Run `caravan lint [--fix-hints] [PATHS]` — exit 0 on a clean
//! tree, 1 on violations, 2 on usage/IO errors.

pub mod lexer;
pub mod rules;

use std::collections::BTreeSet;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{all_rules, Rule, Violation};

/// An in-source `// lint:allow(rule, ...) -- justification` directive.
#[derive(Clone, Debug)]
struct Allow {
    line: u32,
    rules: Vec<String>,
    justified: bool,
}

/// Parse every `lint:allow` directive out of a file's comments. Returns
/// the directives plus hygiene violations (missing justification,
/// unknown rule names) — an unjustified allow does *not* suppress.
fn parse_directives(comments: &[lexer::Comment], known: &[&'static str]) -> (Vec<Allow>, Vec<Violation>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    for c in comments {
        let Some(at) = c.text.find("lint:allow(") else { continue };
        let rest = &c.text[at + "lint:allow(".len()..];
        let Some(close) = rest.find(')') else {
            bad.push(Violation {
                rule: "lint-allow",
                line: c.line,
                msg: "malformed lint:allow directive (missing `)`)".into(),
                hint: "write `// lint:allow(rule) -- justification`",
            });
            continue;
        };
        let names: Vec<String> = rest[..close]
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        for n in &names {
            if !known.contains(&n.as_str()) {
                bad.push(Violation {
                    rule: "lint-allow",
                    line: c.line,
                    msg: format!("lint:allow names unknown rule {n:?}"),
                    hint: "valid rules: float-ord, wall-clock, hash-iter, unwrap-budget, \
                           panic-path, no-unsafe",
                });
            }
        }
        let justification = rest[close + 1..]
            .split_once("--")
            .map(|(_, j)| j.trim())
            .unwrap_or("");
        let justified = !justification.is_empty();
        if !justified {
            bad.push(Violation {
                rule: "lint-allow",
                line: c.line,
                msg: "lint:allow without a justification".into(),
                hint: "append ` -- <why this exception is sound>` to the directive",
            });
        }
        allows.push(Allow { line: c.line, rules: names, justified });
    }
    (allows, bad)
}

/// Lint one source file given its path label (used for rule scoping —
/// pass paths like `src/des/mod.rs`) and contents. Returns the
/// unsuppressed violations, sorted by line then rule.
pub fn lint_source(path_label: &str, src: &str) -> Vec<Violation> {
    let path = path_label.replace('\\', "/");
    let lexed = lexer::lex(src);
    let rules = all_rules();
    let known: Vec<&'static str> = rules.iter().map(|r| r.name()).collect();
    let (allows, mut out) = parse_directives(&lexed.comments, &known);
    for rule in &rules {
        if !rule.applies(&path) {
            continue;
        }
        for v in rule.check(&path, &lexed) {
            let suppressed = allows.iter().any(|a| {
                a.justified
                    && a.rules.iter().any(|r| r == v.rule)
                    && (v.line == a.line || v.line == a.line + 1)
            });
            if !suppressed {
                out.push(v);
            }
        }
    }
    out.sort_by(|a, b| a.line.cmp(&b.line).then(a.rule.cmp(b.rule)));
    out
}

/// The outcome of linting a set of paths.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// `(path, violation)` pairs, sorted by path then line.
    pub violations: Vec<(String, Violation)>,
}

impl LintReport {
    /// True when no violation was found.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Number of distinct files with at least one violation.
    pub fn files_with_violations(&self) -> usize {
        self.violations.iter().map(|(p, _)| p.as_str()).collect::<BTreeSet<_>>().len()
    }
}

/// Recursively collect `.rs` files under `root` (or `root` itself when
/// it is a file), sorted by path so output and exit codes are
/// deterministic. `target/` and dot-directories are skipped.
pub fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if root.is_file() {
        if root.extension().map_or(false, |e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(root)?.map(|e| e.map(|e| e.path())).collect::<Result<_, _>>()?;
    entries.sort();
    for path in entries {
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if path.is_dir() {
            if name == "target" || name.starts_with('.') {
                continue;
            }
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under the given paths. Errors (missing path,
/// unreadable file) surface as `Err` — the CLI maps them to exit 2.
pub fn lint_paths(paths: &[PathBuf]) -> io::Result<LintReport> {
    let mut files = Vec::new();
    for p in paths {
        if !p.exists() {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("no such path: {}", p.display()),
            ));
        }
        collect_rs_files(p, &mut files)?;
    }
    let mut report = LintReport::default();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let label = f.to_string_lossy().replace('\\', "/");
        report.files_scanned += 1;
        for v in lint_source(&label, &src) {
            report.violations.push((label.clone(), v));
        }
    }
    report.violations.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.line.cmp(&b.1.line)));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_with_justification_suppresses_same_and_next_line() {
        let src = "
// lint:allow(wall-clock) -- CLI elapsed-time print, outermost shell
let t0 = Instant::now();
let t1 = Instant::now(); // lint:allow(wall-clock) -- same-line form
let t2 = Instant::now();
";
        let got = lint_source("src/des/mod.rs", src);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].line, 5);
    }

    #[test]
    fn allow_without_justification_is_itself_flagged_and_does_not_suppress() {
        let src = "
// lint:allow(wall-clock)
let t0 = Instant::now();
";
        let got = lint_source("src/des/mod.rs", src);
        let rules: Vec<&str> = got.iter().map(|v| v.rule).collect();
        assert!(rules.contains(&"lint-allow"), "{got:?}");
        assert!(rules.contains(&"wall-clock"), "unjustified allow must not suppress: {got:?}");
    }

    #[test]
    fn allow_with_unknown_rule_is_flagged() {
        let src = "// lint:allow(no-such-rule) -- oops\n";
        let got = lint_source("src/x.rs", src);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].rule, "lint-allow");
        assert!(got[0].msg.contains("no-such-rule"));
    }

    #[test]
    fn violations_are_sorted_and_multi_rule() {
        let src = "
use std::collections::HashMap;
fn f(v: &mut Vec<f64>) {
    let t = Instant::now();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
";
        let got = lint_source("src/des/mod.rs", src);
        let rules: Vec<&str> = got.iter().map(|v| v.rule).collect();
        assert_eq!(rules, vec!["hash-iter", "wall-clock", "float-ord"]);
        let lines: Vec<u32> = got.iter().map(|v| v.line).collect();
        assert!(lines.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn clean_source_is_clean() {
        let src = "
use std::collections::BTreeMap;
fn f(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.total_cmp(b));
}
";
        assert!(lint_source("src/des/mod.rs", src).is_empty());
    }
}
