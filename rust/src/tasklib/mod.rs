//! The task model — CARAVAN's unit of work.
//!
//! A *task* (§2.1) is a single execution of a user's simulator. The search
//! engine creates tasks; the scheduler distributes them to consumer
//! processes; consumers run them and send back a [`TaskResult`] whose
//! `results` vector is what the simulator wrote to `_results.txt` (§2.2) —
//! or, for in-process simulators, the objective values returned directly.
//!
//! [`ParameterSet`] / [`Run`] mirror the convenience classes of the Python
//! API used for Monte-Carlo averaging: one parameter point, several runs
//! with distinct random seeds, aggregated results.

pub mod pset;

pub use pset::{ParameterSet, PsetStore, Run};

/// Globally unique task identifier (minted by the scheduler-side sink).
pub type TaskId = u64;

/// What a consumer should do for this task.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Dummy task: occupy the consumer for `seconds` (§3's test cases).
    /// In the threaded runtime the duration is scaled by the configured
    /// time-compression factor; in the DES it elapses in virtual time.
    Sleep { seconds: f64 },
    /// External simulator (§2.2): executed as a child process in a fresh
    /// per-task temporary directory; `argv[0]` is the program.
    Command { cmdline: String },
    /// In-process simulator evaluation: `input` is the parameter point
    /// handed to the registered simulator backend (PJRT-compiled model or
    /// the pure-Rust reference simulator). `seed` selects the RNG stream.
    Eval { input: Vec<f64>, seed: u64 },
}

impl Payload {
    /// Human-readable one-liner for logs.
    pub fn describe(&self) -> String {
        match self {
            Payload::Sleep { seconds } => format!("sleep {seconds:.3}s"),
            Payload::Command { cmdline } => format!("cmd {cmdline}"),
            Payload::Eval { input, seed } => {
                format!("eval dim={} seed={seed}", input.len())
            }
        }
    }
}

/// A schedulable task: id + payload.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskSpec {
    pub id: TaskId,
    pub payload: Payload,
}

impl TaskSpec {
    pub fn new(id: TaskId, payload: Payload) -> Self {
        Self { id, payload }
    }
}

/// Completion record sent back to the search engine.
///
/// `begin`/`finish` are seconds since scheduler start — wall-clock in the
/// threaded runtime, virtual time in the DES. They feed the job-filling-rate
/// metric (Eq. 1).
#[derive(Clone, Debug, PartialEq)]
pub struct TaskResult {
    pub id: TaskId,
    /// Rank of the consumer that executed the task.
    pub consumer: usize,
    /// Values parsed from `_results.txt` / returned by the in-process
    /// simulator. Possibly empty (the file is optional in §2.2).
    pub results: Vec<f64>,
    pub begin: f64,
    pub finish: f64,
    /// Exit status: 0 = success. Non-zero marks a failed simulator run;
    /// search engines decide whether to resubmit or drop.
    pub rc: i32,
}

impl TaskResult {
    pub fn duration(&self) -> f64 {
        self.finish - self.begin
    }

    pub fn ok(&self) -> bool {
        self.rc == 0
    }
}

/// Where search engines hand new tasks to the scheduler. Mints ids so that
/// every engine (grid sweep, NSGA-II, MCMC, the await-style session) gets
/// globally unique, monotonically increasing task ids.
pub trait TaskSink {
    fn submit(&mut self, payload: Payload) -> TaskId;
}

/// A sink recording submissions locally — the building block used by the
/// DES and the threaded runtime, and handy in unit tests.
#[derive(Default, Debug)]
pub struct VecSink {
    pub next_id: TaskId,
    pub submitted: Vec<TaskSpec>,
}

impl VecSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn drain(&mut self) -> Vec<TaskSpec> {
        std::mem::take(&mut self.submitted)
    }
}

impl TaskSink for VecSink {
    fn submit(&mut self, payload: Payload) -> TaskId {
        let id = self.next_id;
        self.next_id += 1;
        self.submitted.push(TaskSpec::new(id, payload));
        id
    }
}

/// A search engine decides *which* tasks to run — the paper's third module.
///
/// `start` is called once before scheduling begins; `on_done` every time a
/// task completes (the analogue of the Python `add_callback`). Both may
/// submit new tasks through the sink, which is how TC3-style and
/// optimization workloads dynamically extend the task stream.
pub trait SearchEngine: Send {
    fn start(&mut self, sink: &mut dyn TaskSink);
    fn on_done(&mut self, result: &TaskResult, sink: &mut dyn TaskSink);
    /// Polled periodically by the threaded runtime between events. Lets an
    /// engine pull in work from outside (the await-style [`crate::engine::Session`]
    /// API). Returns `false` while the engine may still produce tasks
    /// spontaneously — the scheduler will not shut down while `false`.
    /// Default: `true` (everything happens in `start`/`on_done`).
    fn poll(&mut self, sink: &mut dyn TaskSink) -> bool {
        let _ = sink;
        true
    }
    /// Called once when the scheduler drained all tasks; engines may use it
    /// to report summaries. Default: no-op.
    fn finish(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_sink_mints_sequential_ids() {
        let mut s = VecSink::new();
        let a = s.submit(Payload::Sleep { seconds: 1.0 });
        let b = s.submit(Payload::Sleep { seconds: 2.0 });
        assert_eq!((a, b), (0, 1));
        assert_eq!(s.submitted.len(), 2);
        let drained = s.drain();
        assert_eq!(drained.len(), 2);
        assert!(s.submitted.is_empty());
        assert_eq!(s.submit(Payload::Sleep { seconds: 0.0 }), 2);
    }

    #[test]
    fn result_duration_and_ok() {
        let r = TaskResult { id: 1, consumer: 3, results: vec![1.5], begin: 2.0, finish: 5.5, rc: 0 };
        assert!((r.duration() - 3.5).abs() < 1e-12);
        assert!(r.ok());
        let bad = TaskResult { rc: 1, ..r.clone() };
        assert!(!bad.ok());
    }

    #[test]
    fn payload_describe() {
        assert_eq!(Payload::Sleep { seconds: 1.0 }.describe(), "sleep 1.000s");
        assert!(Payload::Command { cmdline: "echo hi".into() }.describe().contains("echo"));
        assert!(Payload::Eval { input: vec![0.0; 4], seed: 9 }.describe().contains("dim=4"));
    }
}
