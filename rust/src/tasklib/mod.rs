//! The task model — CARAVAN's unit of work.
//!
//! A *task* (§2.1) is a single execution of a user's simulator. The search
//! engine creates tasks; the scheduler distributes them to consumer
//! processes; consumers run them and send back a [`TaskResult`] whose
//! `results` vector is what the simulator wrote to `_results.txt` (§2.2) —
//! or, for in-process simulators, the objective values returned directly.
//!
//! Since the Job API v2 redesign ([`crate::api`]), a [`TaskSpec`] carries
//! scheduling metadata alongside the payload: a priority, a retry budget
//! (consumed transparently by the scheduler when an attempt fails), an
//! optional per-attempt timeout and an optional tag. Engines normally
//! build these through [`crate::api::JobSpec`]'s builder.
//!
//! [`ParameterSet`] / [`Run`] mirror the convenience classes of the Python
//! API used for Monte-Carlo averaging: one parameter point, several runs
//! with distinct random seeds, aggregated results.

pub mod pset;

pub use pset::{ParameterSet, PsetStore, Run};

use crate::api::{JobSink, JobSpec};

/// Globally unique task identifier (minted by the scheduler-side sink).
pub type TaskId = u64;

/// `rc` reported for a task dropped by a cancellation before it ran.
/// `i32::MIN` is unreachable by any real exit status (the external-process
/// executor maps signal-killed children to -1), so a crashed simulator can
/// never be mistaken for a user-requested cancellation — which matters
/// because cancelled results are exempt from retry and from the
/// filling-rate trace.
pub const RC_CANCELLED: i32 = i32::MIN;

/// `rc` reported for an attempt that exceeded its `timeout_s` budget
/// (mirrors GNU `timeout`'s exit code). This is a *reporting convention
/// only*: a user simulator may legitimately exit 124, so executors that
/// actually enforced the budget additionally set
/// [`TaskResult::timed_out`] — that flag, not the exit code, is the
/// authoritative signal that the framework cut the attempt short.
pub const RC_TIMEOUT: i32 = 124;

/// What a consumer should do for this task.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Dummy task: occupy the consumer for `seconds` (§3's test cases).
    /// In the threaded runtime the duration is scaled by the configured
    /// time-compression factor; in the DES it elapses in virtual time.
    Sleep { seconds: f64 },
    /// External simulator (§2.2): executed as a child process in a fresh
    /// per-task temporary directory; `argv[0]` is the program.
    Command { cmdline: String },
    /// In-process simulator evaluation: `input` is the parameter point
    /// handed to the registered simulator backend (PJRT-compiled model or
    /// the pure-Rust reference simulator). `seed` selects the RNG stream.
    Eval { input: Vec<f64>, seed: u64 },
}

impl Payload {
    /// Human-readable one-liner for logs.
    pub fn describe(&self) -> String {
        match self {
            Payload::Sleep { seconds } => format!("sleep {seconds:.3}s"),
            Payload::Command { cmdline } => format!("cmd {cmdline}"),
            Payload::Eval { input, seed } => {
                format!("eval dim={} seed={seed}", input.len())
            }
        }
    }
}

/// A schedulable task: id + payload + scheduling metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskSpec {
    pub id: TaskId,
    pub payload: Payload,
    /// Higher runs first; FIFO within a priority level.
    pub priority: u8,
    /// Remaining transparent resubmissions after a failed attempt.
    pub max_retries: u32,
    /// Attempt index: 0 on first execution, incremented per retry.
    pub attempt: u32,
    /// Per-attempt execution budget (executor-enforced; see
    /// [`RC_TIMEOUT`]).
    pub timeout_s: Option<f64>,
    /// Free-form label from [`JobSpec::tag`].
    pub tag: Option<String>,
    /// Tenant class ([`crate::tenancy::ClassId`]) — the index into
    /// [`crate::config::SchedulerConfig::classes`] that selects the
    /// task's queue lane (per-class policy, fair-share weight) at every
    /// tree level. 0 = default class.
    pub class: crate::tenancy::ClassId,
    /// When the task first entered a scheduler queue, in *virtual*
    /// seconds since run start — the unit `timeout_s` and aging steps are
    /// expressed in (the threaded runtime divides wall time by its
    /// `time_scale`; the DES uses virtual time directly). Stamped by the
    /// first queue the task lands in and carried across node hops, steals
    /// and retries, so deadline slack and priority aging measure the
    /// *total* time in the system.
    pub enqueued_t: Option<f64>,
}

impl TaskSpec {
    /// A plain task with default scheduling metadata (priority 0, no
    /// retries, no timeout).
    pub fn new(id: TaskId, payload: Payload) -> Self {
        Self {
            id,
            payload,
            priority: 0,
            max_retries: 0,
            attempt: 0,
            timeout_s: None,
            tag: None,
            class: crate::tenancy::DEFAULT_CLASS,
            enqueued_t: None,
        }
    }

    /// Effective deadline under [`crate::config::SchedPolicy::Deadline`]:
    /// first-enqueue time plus the per-attempt budget. Tasks without a
    /// timeout (or not yet enqueued) have no deadline pressure and sort
    /// after every deadlined task in their priority band.
    pub fn deadline(&self) -> f64 {
        match (self.enqueued_t, self.timeout_s) {
            (Some(t), Some(budget)) => t + budget,
            _ => f64::INFINITY,
        }
    }
}

/// Completion record sent back to the search engine.
///
/// `begin`/`finish` are seconds since scheduler start — wall-clock in the
/// threaded runtime, virtual time in the DES. They feed the job-filling-rate
/// metric (Eq. 1).
#[derive(Clone, Debug, PartialEq)]
pub struct TaskResult {
    pub id: TaskId,
    /// Rank of the consumer that executed the task (`usize::MAX` for a
    /// task cancelled before it ever reached a consumer).
    pub consumer: usize,
    /// Values parsed from `_results.txt` / returned by the in-process
    /// simulator. Possibly empty (the file is optional in §2.2).
    pub results: Vec<f64>,
    pub begin: f64,
    pub finish: f64,
    /// Exit status of the final attempt: 0 = success, [`RC_CANCELLED`] =
    /// dropped (or killed) by cancellation, [`RC_TIMEOUT`] = budget
    /// exceeded (by convention — check [`Self::timed_out`]). The
    /// scheduler retries failed attempts transparently while the task has
    /// retries left; engines only ever see the final attempt.
    pub rc: i32,
    /// Attempt index of this (final) execution: 0 = succeeded first try.
    pub attempt: u32,
    /// True iff the *executor* cut this attempt short at its `timeout_s`
    /// budget. A simulator that happens to exit with status 124 leaves
    /// this false, so it is retried/reported as an ordinary failure
    /// rather than misdiagnosed as a timeout.
    pub timed_out: bool,
}

impl TaskResult {
    pub fn duration(&self) -> f64 {
        self.finish - self.begin
    }

    pub fn ok(&self) -> bool {
        self.rc == 0
    }

    pub fn cancelled(&self) -> bool {
        self.rc == RC_CANCELLED
    }

    /// Synthesized completion for a task dropped by cancellation.
    pub fn cancelled_for(spec: &TaskSpec) -> Self {
        Self {
            id: spec.id,
            consumer: usize::MAX,
            results: Vec::new(),
            begin: 0.0,
            finish: 0.0,
            rc: RC_CANCELLED,
            attempt: spec.attempt,
            timed_out: false,
        }
    }
}

/// Legacy submission surface (v1): payload in, task id out. Still fully
/// supported — [`JobSink`] extends it, so `sink.submit(payload)` works on
/// any v2 sink and is equivalent to submitting a default [`JobSpec`].
pub trait TaskSink {
    fn submit(&mut self, payload: Payload) -> TaskId;
}

/// A sink recording submissions locally — the building block used by the
/// DES and the threaded runtime, and handy in unit tests.
#[derive(Default, Debug)]
pub struct VecSink {
    pub next_id: TaskId,
    pub submitted: Vec<TaskSpec>,
    /// Ids whose cancellation was requested through [`JobSink::cancel`].
    pub cancelled: Vec<TaskId>,
}

impl VecSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn drain(&mut self) -> Vec<TaskSpec> {
        std::mem::take(&mut self.submitted)
    }
}

impl TaskSink for VecSink {
    fn submit(&mut self, payload: Payload) -> TaskId {
        self.submit_job(JobSpec::new(payload))
    }
}

impl JobSink for VecSink {
    fn submit_job(&mut self, spec: JobSpec) -> TaskId {
        let id = self.next_id;
        self.next_id += 1;
        self.submitted.push(spec.into_task(id));
        id
    }

    fn cancel(&mut self, id: TaskId) {
        self.cancelled.push(id);
    }
}

/// A search engine decides *which* tasks to run — the paper's third module.
///
/// This is the object-safe trait both runtimes drive. Engines written
/// against the typed v2 API implement [`crate::api::JobEngine`] instead
/// and run through [`crate::api::JobAdapter`]; hand-rolled engines (the §3
/// workloads, tests, benches) implement this directly. The sink is a
/// [`JobSink`], so plain `sink.submit(payload)` (v1) and
/// `sink.submit_job(spec)` / `sink.cancel(id)` (v2) are both available.
///
/// `start` is called once before scheduling begins; `on_done` every time a
/// task completes (the analogue of the Python `add_callback`). Both may
/// submit new tasks through the sink, which is how TC3-style and
/// optimization workloads dynamically extend the task stream.
pub trait SearchEngine: Send {
    fn start(&mut self, sink: &mut dyn JobSink);
    fn on_done(&mut self, result: &TaskResult, sink: &mut dyn JobSink);
    /// Polled periodically by the threaded runtime between events. Lets an
    /// engine pull in work from outside (the await-style [`crate::engine::Session`]
    /// API). Returns `false` while the engine may still produce tasks
    /// spontaneously — the scheduler will not shut down while `false`.
    /// Default: `true` (everything happens in `start`/`on_done`).
    fn poll(&mut self, sink: &mut dyn JobSink) -> bool {
        let _ = sink;
        true
    }
    /// Called once when the scheduler drained all tasks; engines may use it
    /// to report summaries. Default: no-op.
    fn finish(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_sink_mints_sequential_ids() {
        let mut s = VecSink::new();
        let a = s.submit(Payload::Sleep { seconds: 1.0 });
        let b = s.submit(Payload::Sleep { seconds: 2.0 });
        assert_eq!((a, b), (0, 1));
        assert_eq!(s.submitted.len(), 2);
        let drained = s.drain();
        assert_eq!(drained.len(), 2);
        assert!(s.submitted.is_empty());
        assert_eq!(s.submit(Payload::Sleep { seconds: 0.0 }), 2);
    }

    #[test]
    fn vec_sink_records_job_specs_and_cancels() {
        let mut s = VecSink::new();
        let id = s.submit_job(JobSpec::sleep(1.0).priority(7).retries(2));
        assert_eq!(s.submitted[0].priority, 7);
        assert_eq!(s.submitted[0].max_retries, 2);
        s.cancel(id);
        assert_eq!(s.cancelled, vec![id]);
    }

    #[test]
    fn result_duration_and_ok() {
        let r = TaskResult {
            id: 1,
            consumer: 3,
            results: vec![1.5],
            begin: 2.0,
            finish: 5.5,
            rc: 0,
            attempt: 0,
            timed_out: false,
        };
        assert!((r.duration() - 3.5).abs() < 1e-12);
        assert!(r.ok());
        let bad = TaskResult { rc: 1, ..r.clone() };
        assert!(!bad.ok());
        let cancelled = TaskResult { rc: RC_CANCELLED, ..r };
        assert!(cancelled.cancelled() && !cancelled.ok());
    }

    #[test]
    fn cancelled_result_carries_attempt() {
        let mut spec = TaskSpec::new(4, Payload::Sleep { seconds: 1.0 });
        spec.attempt = 2;
        let r = TaskResult::cancelled_for(&spec);
        assert_eq!(r.id, 4);
        assert_eq!(r.attempt, 2);
        assert!(r.cancelled());
    }

    #[test]
    fn deadline_requires_enqueue_stamp_and_budget() {
        let mut spec = TaskSpec::new(0, Payload::Sleep { seconds: 1.0 });
        assert_eq!(spec.deadline(), f64::INFINITY);
        spec.timeout_s = Some(30.0);
        assert_eq!(spec.deadline(), f64::INFINITY, "unstamped task has no deadline yet");
        spec.enqueued_t = Some(5.0);
        assert!((spec.deadline() - 35.0).abs() < 1e-12);
        spec.timeout_s = None;
        assert_eq!(spec.deadline(), f64::INFINITY);
    }

    #[test]
    fn payload_describe() {
        assert_eq!(Payload::Sleep { seconds: 1.0 }.describe(), "sleep 1.000s");
        assert!(Payload::Command { cmdline: "echo hi".into() }.describe().contains("echo"));
        assert!(Payload::Eval { input: vec![0.0; 4], seed: 9 }.describe().contains("dim=4"));
    }
}
