//! `ParameterSet` / `Run` — Monte-Carlo grouping (paper §2.3).
//!
//! The paper's application averages each individual's objectives over five
//! runs with different random seeds. `PsetStore` tracks which task ids
//! belong to which parameter set and aggregates their results when all runs
//! of a set are in.

use std::collections::HashMap;

use super::{Payload, TaskId, TaskSink};

/// One run (task) of a parameter set.
#[derive(Clone, Debug)]
pub struct Run {
    pub task_id: TaskId,
    pub seed: u64,
    pub results: Option<Vec<f64>>,
}

/// A parameter point with several seeded runs.
#[derive(Clone, Debug)]
pub struct ParameterSet {
    pub id: u64,
    pub point: Vec<f64>,
    pub runs: Vec<Run>,
}

impl ParameterSet {
    pub fn completed_runs(&self) -> usize {
        self.runs.iter().filter(|r| r.results.is_some()).count()
    }

    pub fn is_complete(&self) -> bool {
        self.completed_runs() == self.runs.len()
    }

    /// Element-wise mean over the result vectors of the completed runs.
    /// Empty result vectors (failed simulator runs) are skipped; of the
    /// rest, runs whose width differs from the first usable run are
    /// ignored. Returns an empty vector only when *every* run failed.
    pub fn mean_results(&self) -> Vec<f64> {
        let vecs: Vec<&Vec<f64>> = self
            .runs
            .iter()
            .filter_map(|r| r.results.as_ref())
            .filter(|v| !v.is_empty())
            .collect();
        let Some(first) = vecs.first() else {
            return Vec::new();
        };
        let width = first.len();
        let good: Vec<&&Vec<f64>> = vecs.iter().filter(|v| v.len() == width).collect();
        let mut out = vec![0.0; width];
        for v in &good {
            for (o, x) in out.iter_mut().zip(v.iter()) {
                *o += x;
            }
        }
        let n = good.len() as f64;
        for o in &mut out {
            *o /= n;
        }
        out
    }
}

/// Bookkeeping for in-flight parameter sets.
#[derive(Default)]
pub struct PsetStore {
    next_pset_id: u64,
    by_task: HashMap<TaskId, u64>,
    sets: HashMap<u64, ParameterSet>,
}

impl PsetStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a parameter set and submit `n_runs` `Payload::Eval` tasks
    /// with seeds `seed0 .. seed0 + n_runs`.
    pub fn create(
        &mut self,
        point: Vec<f64>,
        n_runs: usize,
        seed0: u64,
        sink: &mut dyn TaskSink,
    ) -> u64 {
        let pid = self.next_pset_id;
        self.next_pset_id += 1;
        let mut runs = Vec::with_capacity(n_runs);
        for k in 0..n_runs {
            let seed = seed0 + k as u64;
            let tid = sink.submit(Payload::Eval { input: point.clone(), seed });
            self.by_task.insert(tid, pid);
            runs.push(Run { task_id: tid, seed, results: None });
        }
        self.sets.insert(pid, ParameterSet { id: pid, point, runs });
        pid
    }

    /// Record a completed task. Returns the parameter set if this result
    /// completed it (the set is removed from the store — ownership moves to
    /// the caller, typically an optimizer archiving the individual).
    pub fn record(&mut self, task_id: TaskId, results: Vec<f64>) -> Option<ParameterSet> {
        let pid = self.by_task.remove(&task_id)?;
        let set = self.sets.get_mut(&pid)?;
        for run in &mut set.runs {
            if run.task_id == task_id {
                run.results = Some(results);
                break;
            }
        }
        if set.is_complete() {
            self.sets.remove(&pid)
        } else {
            None
        }
    }

    pub fn in_flight(&self) -> usize {
        self.sets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::VecSink;

    #[test]
    fn create_submits_n_runs_with_distinct_seeds() {
        let mut store = PsetStore::new();
        let mut sink = VecSink::new();
        let pid = store.create(vec![0.5, 0.25], 5, 100, &mut sink);
        assert_eq!(pid, 0);
        assert_eq!(sink.submitted.len(), 5);
        let seeds: Vec<u64> = sink
            .submitted
            .iter()
            .map(|t| match &t.payload {
                Payload::Eval { seed, .. } => *seed,
                _ => panic!(),
            })
            .collect();
        assert_eq!(seeds, vec![100, 101, 102, 103, 104]);
        assert_eq!(store.in_flight(), 1);
    }

    #[test]
    fn record_completes_only_when_all_runs_done() {
        let mut store = PsetStore::new();
        let mut sink = VecSink::new();
        store.create(vec![1.0], 3, 0, &mut sink);
        let ids: Vec<TaskId> = sink.submitted.iter().map(|t| t.id).collect();
        assert!(store.record(ids[0], vec![2.0]).is_none());
        assert!(store.record(ids[1], vec![4.0]).is_none());
        let done = store.record(ids[2], vec![6.0]).expect("complete");
        assert!(done.is_complete());
        assert_eq!(done.mean_results(), vec![4.0]);
        assert_eq!(store.in_flight(), 0);
    }

    #[test]
    fn record_unknown_task_is_none() {
        let mut store = PsetStore::new();
        assert!(store.record(99, vec![]).is_none());
    }

    #[test]
    fn mean_skips_mismatched_widths() {
        let ps = ParameterSet {
            id: 0,
            point: vec![],
            runs: vec![
                Run { task_id: 0, seed: 0, results: Some(vec![1.0, 3.0]) },
                Run { task_id: 1, seed: 1, results: Some(vec![]) },
                Run { task_id: 2, seed: 2, results: Some(vec![3.0, 5.0]) },
            ],
        };
        assert_eq!(ps.mean_results(), vec![2.0, 4.0]);
    }
}
