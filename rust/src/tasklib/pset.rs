//! `ParameterSet` / `Run` — Monte-Carlo grouping (paper §2.3).
//!
//! The paper's application averages each individual's objectives over five
//! runs with different random seeds. `PsetStore` tracks in-flight
//! parameter sets and aggregates their run results when all runs of a set
//! are in.
//!
//! Since the Job API v2 redesign the store no longer maps task ids to
//! sets: the submitting engine attaches `(pset_id, run_index)` as the job
//! context (see [`crate::api::JobEngine`]) and records completions with
//! [`PsetStore::record_run`]. The framework owns the id bookkeeping.

use std::collections::HashMap;

/// One run of a parameter set.
#[derive(Clone, Debug)]
pub struct Run {
    pub seed: u64,
    pub results: Option<Vec<f64>>,
}

/// A parameter point with several seeded runs.
#[derive(Clone, Debug)]
pub struct ParameterSet {
    pub id: u64,
    pub point: Vec<f64>,
    pub runs: Vec<Run>,
}

impl ParameterSet {
    pub fn completed_runs(&self) -> usize {
        self.runs.iter().filter(|r| r.results.is_some()).count()
    }

    pub fn is_complete(&self) -> bool {
        self.completed_runs() == self.runs.len()
    }

    /// Element-wise mean over the result vectors of the completed runs.
    /// Empty result vectors (failed simulator runs) are skipped; of the
    /// rest, runs whose width differs from the first usable run are
    /// ignored. Returns an empty vector only when *every* run failed.
    pub fn mean_results(&self) -> Vec<f64> {
        let vecs: Vec<&Vec<f64>> = self
            .runs
            .iter()
            .filter_map(|r| r.results.as_ref())
            .filter(|v| !v.is_empty())
            .collect();
        let Some(first) = vecs.first() else {
            return Vec::new();
        };
        let width = first.len();
        let good: Vec<&&Vec<f64>> = vecs.iter().filter(|v| v.len() == width).collect();
        let mut out = vec![0.0; width];
        for v in &good {
            for (o, x) in out.iter_mut().zip(v.iter()) {
                *o += x;
            }
        }
        let n = good.len() as f64;
        for o in &mut out {
            *o /= n;
        }
        out
    }
}

/// Bookkeeping for in-flight parameter sets.
#[derive(Default)]
pub struct PsetStore {
    next_pset_id: u64,
    sets: HashMap<u64, ParameterSet>,
}

impl PsetStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a parameter set of `n_runs` runs seeded `seed0 .. seed0 +
    /// n_runs` and return its id. The caller submits the actual jobs
    /// (typically `JobSpec::eval(point).seed(seed0 + k)` with context
    /// `(pset_id, k)`).
    pub fn create_set(&mut self, point: Vec<f64>, n_runs: usize, seed0: u64) -> u64 {
        let pid = self.next_pset_id;
        self.next_pset_id += 1;
        let runs = (0..n_runs).map(|k| Run { seed: seed0 + k as u64, results: None }).collect();
        self.sets.insert(pid, ParameterSet { id: pid, point, runs });
        pid
    }

    /// Record run `run` of set `pset`. Returns the parameter set if this
    /// result completed it (the set is removed from the store — ownership
    /// moves to the caller, typically an optimizer archiving the
    /// individual). Unknown sets or out-of-range run indices are ignored.
    pub fn record_run(
        &mut self,
        pset: u64,
        run: usize,
        results: Vec<f64>,
    ) -> Option<ParameterSet> {
        let set = self.sets.get_mut(&pset)?;
        let slot = set.runs.get_mut(run)?;
        slot.results = Some(results);
        if set.is_complete() {
            self.sets.remove(&pset)
        } else {
            None
        }
    }

    pub fn in_flight(&self) -> usize {
        self.sets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_registers_n_runs_with_distinct_seeds() {
        let mut store = PsetStore::new();
        let pid = store.create_set(vec![0.5, 0.25], 5, 100);
        assert_eq!(pid, 0);
        assert_eq!(store.in_flight(), 1);
        // Completing all runs returns the set with its seeds intact.
        for k in 0..4 {
            assert!(store.record_run(pid, k, vec![1.0]).is_none());
        }
        let done = store.record_run(pid, 4, vec![1.0]).expect("complete");
        let seeds: Vec<u64> = done.runs.iter().map(|r| r.seed).collect();
        assert_eq!(seeds, vec![100, 101, 102, 103, 104]);
        assert_eq!(store.in_flight(), 0);
    }

    #[test]
    fn record_completes_only_when_all_runs_done() {
        let mut store = PsetStore::new();
        let pid = store.create_set(vec![1.0], 3, 0);
        assert!(store.record_run(pid, 0, vec![2.0]).is_none());
        assert!(store.record_run(pid, 1, vec![4.0]).is_none());
        let done = store.record_run(pid, 2, vec![6.0]).expect("complete");
        assert!(done.is_complete());
        assert_eq!(done.mean_results(), vec![4.0]);
        assert_eq!(store.in_flight(), 0);
    }

    #[test]
    fn record_unknown_set_or_run_is_none() {
        let mut store = PsetStore::new();
        assert!(store.record_run(99, 0, vec![]).is_none());
        let pid = store.create_set(vec![1.0], 2, 0);
        assert!(store.record_run(pid, 7, vec![]).is_none());
        assert_eq!(store.in_flight(), 1);
    }

    #[test]
    fn mean_skips_mismatched_widths() {
        let ps = ParameterSet {
            id: 0,
            point: vec![],
            runs: vec![
                Run { seed: 0, results: Some(vec![1.0, 3.0]) },
                Run { seed: 1, results: Some(vec![]) },
                Run { seed: 2, results: Some(vec![3.0, 5.0]) },
            ],
        };
        assert_eq!(ps.mean_results(), vec![2.0, 4.0]);
    }
}
