//! The model harness and state-space exploration: a [`Model`] holds the
//! pure protocol state machines plus per-directed-edge message FIFOs,
//! [`Event`]s advance it one atomic step at a time, and [`dfs`]/[`fuzz`]
//! drive the interleavings.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::rc::Rc;

use crate::config::{SchedulerConfig, TreeNodeKind, TreeTopology};
use crate::scheduler::protocol::{
    route_buffer_actions, route_producer_actions, BufferState, LocalEffect, ProducerState,
    Party, ProtoMsg,
};
use crate::tasklib::{Payload, TaskId, TaskResult, TaskSpec, RC_CANCELLED};

use super::{oracle, FaultSet, Fnv64, SeededBug, Violation};

/// One atomic model step. Deliveries pop the head of a per-directed-edge
/// FIFO — the model preserves per-channel ordering exactly like the
/// threaded runtime's channels and the DES's latency-ordered events, but
/// lets distinct edges interleave arbitrarily.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Event {
    /// Deliver the oldest in-flight message on edge `from → to`.
    Deliver {
        /// Sending party.
        from: Party,
        /// Receiving party.
        to: Party,
    },
    /// A running consumer attempt completes (success, or `RC_CANCELLED`
    /// if a kill reached it first).
    Finish {
        /// Leaf node id.
        node: usize,
        /// Local consumer index on that leaf.
        consumer: usize,
    },
    /// The engine cancels task `id` (fault event, budgeted).
    Cancel {
        /// Task to cancel.
        id: TaskId,
    },
    /// Root subtree at producer slot `slot` dies — link and all (fault
    /// event, budgeted).
    Kill {
        /// Producer-level child slot to kill.
        slot: usize,
    },
    /// The runtime begins a drain-and-graft recall (fault event,
    /// budgeted).
    Recall,
}

/// The whole protocol model: producer + buffer tree + in-flight
/// messages + the harness's own ground-truth bookkeeping the oracles
/// compare the protocol against.
#[derive(Clone)]
pub struct Model {
    pub(crate) topo: Rc<TreeTopology>,
    pub(crate) cfg: SchedulerConfig,
    pub(crate) n_tasks: usize,
    pub(crate) faults: FaultSet,
    pub(crate) bug: Option<SeededBug>,
    pub(crate) producer: ProducerState,
    /// `None` = the node (and its link) is dead.
    pub(crate) nodes: Vec<Option<BufferState>>,
    /// Per-directed-edge FIFO of in-flight messages.
    pub(crate) edges: BTreeMap<(Party, Party), VecDeque<ProtoMsg>>,
    /// Ground truth of running attempts: `running[node][consumer]` is
    /// the consumer's dispatched batch in execution order, each task
    /// paired with a killed flag (kill ⇒ `RC_CANCELLED`). With
    /// `dispatch_batch = 1` every queue holds at most one item — the
    /// pre-batching model, unchanged.
    pub(crate) running: Vec<Vec<VecDeque<(TaskSpec, bool)>>>,
    /// Tasks granted through each producer slot and not yet accounted
    /// back — what a dead link must re-feed (dead-link zero-loss).
    pub(crate) granted_root: Vec<BTreeMap<TaskId, TaskSpec>>,
    /// Every task currently granted below the producer (double-grant
    /// oracle).
    pub(crate) granted_live: BTreeSet<TaskId>,
    /// Engine-visible results per task (duplicate-result oracle).
    pub(crate) results_seen: BTreeMap<TaskId, u32>,
    /// `Returned` batches delivered so far (drives [`SeededBug`]).
    pub(crate) returned_seen: u32,
    pub(crate) cancels_left: u32,
    pub(crate) kills_left: u32,
    pub(crate) recalls_left: u32,
    /// The single task the budgeted cancel fault targets.
    pub(crate) cancel_candidate: TaskId,
}

impl Model {
    /// Build the initial model state: tree constructed, every node
    /// started (initial credit requests in flight), all `n_tasks`
    /// submitted, engine marked done.
    pub fn new(
        cfg: &SchedulerConfig,
        n_tasks: usize,
        faults: FaultSet,
        bug: Option<SeededBug>,
    ) -> Result<Model, Violation> {
        let topo = Rc::new(cfg.tree());
        let n_roots = topo.roots.len();
        let mut producer = ProducerState::new(n_roots).with_policy(cfg.policy);
        producer.set_engine_done(true);
        let mut m = Model {
            topo,
            cfg: cfg.clone(),
            n_tasks,
            faults,
            bug,
            producer,
            nodes: Vec::new(),
            edges: BTreeMap::new(),
            running: Vec::new(),
            granted_root: vec![BTreeMap::new(); n_roots],
            granted_live: BTreeSet::new(),
            results_seen: BTreeMap::new(),
            returned_seen: 0,
            cancels_left: u32::from(faults.cancel),
            kills_left: u32::from(faults.kill),
            recalls_left: u32::from(faults.recall),
            cancel_candidate: (n_tasks / 2) as TaskId,
        };
        m.build_nodes()?;
        let tasks: Vec<TaskSpec> = (0..n_tasks as TaskId)
            .map(|id| TaskSpec::new(id, Payload::Sleep { seconds: 1.0 }))
            .collect();
        let acts = m.producer.push_tasks(tasks);
        let steps = route_producer_actions(&m.topo, acts);
        m.send(steps)?;
        Ok(m)
    }

    /// (Re)build every buffer node fresh and start it. Used at init and
    /// at graft time (when a recall completes, the old tree is torn down
    /// and the new one started — reviving any killed subtree, exactly
    /// like the runtimes' drain-and-graft).
    fn build_nodes(&mut self) -> Result<(), Violation> {
        // When the kill fault is armed, producer-level subtrees model
        // separate worker processes: no root-level stealing (the
        // distributed runtime has no worker→worker steal links, and a
        // sideways task move across a dying link would genuinely lose
        // the dead-link re-feed accounting).
        let mut nosteal = self.cfg.clone();
        nosteal.steal = false;
        let topo = self.topo.clone();
        self.nodes.clear();
        self.running.clear();
        let mut all_steps = Vec::new();
        for id in 0..topo.nodes.len() {
            let is_root = topo.roots.contains(&id);
            let node_cfg = if self.faults.kill && is_root { &nosteal } else { &self.cfg };
            let mut st = BufferState::for_tree_node(&topo, id, node_cfg);
            self.running.push(vec![VecDeque::new(); st.n_consumers()]);
            let acts = st.on_start();
            self.nodes.push(Some(st));
            let (steps, effects) = route_buffer_actions(&topo, id, acts);
            self.apply_effects(id, effects)?;
            all_steps.extend(steps);
        }
        self.send(all_steps)
    }

    fn alive(&self, p: Party) -> bool {
        match p {
            Party::Producer => true,
            Party::Node(id) => self.nodes.get(id).is_some_and(|n| n.is_some()),
        }
    }

    /// Producer slot of direct-child node `id` (`None` for non-roots).
    fn root_slot(&self, p: Party) -> Option<usize> {
        match p {
            Party::Node(id) => {
                let n = self.topo.nodes.get(id)?;
                n.parent.is_none().then_some(n.slot)
            }
            Party::Producer => None,
        }
    }

    /// Enqueue routed steps onto the edge FIFOs. Traffic to or from a
    /// dead node is dropped (the link is gone). Producer grants feed the
    /// double-grant oracle and the per-slot dead-link ledger.
    fn send(&mut self, steps: Vec<crate::scheduler::protocol::ModelStep>) -> Result<(), Violation> {
        for s in steps {
            if !self.alive(s.from) || !self.alive(s.to) {
                continue;
            }
            if s.from == Party::Producer {
                if let ProtoMsg::Assign(ts) = &s.msg {
                    let slot = self.root_slot(s.to);
                    for t in ts {
                        if !self.granted_live.insert(t.id) {
                            return Err(Violation::new(
                                "double-grant",
                                format!(
                                    "producer granted task {} while an earlier grant of it \
                                     is still live in the tree",
                                    t.id
                                ),
                            ));
                        }
                        if let Some(gr) = slot.and_then(|sl| self.granted_root.get_mut(sl)) {
                            gr.insert(t.id, t.clone());
                        }
                    }
                }
            }
            self.edges.entry((s.from, s.to)).or_default().push_back(s.msg);
        }
        Ok(())
    }

    /// Absorb node-local side effects into the harness's running-attempt
    /// ground truth (this is where double-dispatch would show).
    fn apply_effects(&mut self, id: usize, effects: Vec<LocalEffect>) -> Result<(), Violation> {
        for e in effects {
            match e {
                LocalEffect::RunBatch { consumer, tasks } => {
                    let first = tasks.first().map(|t| t.id).unwrap_or_default();
                    match self.running.get_mut(id).and_then(|r| r.get_mut(consumer)) {
                        Some(q) => {
                            if !q.is_empty() {
                                return Err(Violation::new(
                                    "double-dispatch",
                                    format!(
                                        "node n{id} dispatched a batch (first task {first}) \
                                         onto consumer {consumer} which is already running \
                                         a batch"
                                    ),
                                ));
                            }
                            q.extend(tasks.into_iter().map(|t| (t, false)));
                        }
                        None => {
                            return Err(Violation::new(
                                "double-dispatch",
                                format!(
                                    "node n{id} dispatched a batch (first task {first}) to \
                                     nonexistent consumer {consumer}"
                                ),
                            ));
                        }
                    }
                }
                LocalEffect::CancelRunning { consumer, id: tid } => {
                    // The kill may land on the running attempt or a
                    // not-yet-started item queued behind it in the batch;
                    // either way that attempt reports RC_CANCELLED.
                    if let Some(q) = self.running.get_mut(id).and_then(|r| r.get_mut(consumer)) {
                        for (t, killed) in q.iter_mut() {
                            if t.id == tid {
                                *killed = true;
                            }
                        }
                    }
                }
                LocalEffect::ShutdownConsumers => {}
            }
        }
        Ok(())
    }

    /// All events enabled in this state. With `por` set, when no fault
    /// event is pending and no recall is draining, a partial-order
    /// reduction keeps only the events targeting the smallest party:
    /// deliveries to (and completions at) distinct parties commute, so
    /// exploring one canonical target first covers the same reachable
    /// states. The reduction is heuristic (it is what makes the
    /// exhaustive phase tractable); the fuzz phase samples the full
    /// event set with no reduction to compensate.
    pub fn enabled_events(&self, por: bool) -> Vec<Event> {
        let mut evs = Vec::new();
        for (&(from, to), q) in &self.edges {
            if !q.is_empty() {
                evs.push(Event::Deliver { from, to });
            }
        }
        for (node, slots) in self.running.iter().enumerate() {
            if !self.alive(Party::Node(node)) {
                continue;
            }
            for (consumer, q) in slots.iter().enumerate() {
                if !q.is_empty() {
                    evs.push(Event::Finish { node, consumer });
                }
            }
        }
        let mut fault_evs = Vec::new();
        if self.cancels_left > 0 && !self.producer.shutdown_sent() {
            fault_evs.push(Event::Cancel { id: self.cancel_candidate });
        }
        if self.kills_left > 0
            && !self.producer.shutdown_sent()
            && self.topo.roots.len() > 1
            && self.topo.roots.get(1).is_some_and(|&r| self.alive(Party::Node(r)))
        {
            fault_evs.push(Event::Kill { slot: 1 });
        }
        if self.recalls_left > 0 && !self.producer.is_recalling() && !self.producer.shutdown_sent()
        {
            fault_evs.push(Event::Recall);
        }
        if por && fault_evs.is_empty() && !self.producer.is_recalling() {
            if let Some(min_target) = evs.iter().map(Self::target).min() {
                evs.retain(|e| Self::target(e) == min_target);
            }
            return evs;
        }
        evs.extend(fault_evs);
        evs
    }

    /// The party an event acts on (the POR equivalence key).
    fn target(e: &Event) -> Party {
        match *e {
            Event::Deliver { to, .. } => to,
            Event::Finish { node, .. } => Party::Node(node),
            Event::Cancel { .. } | Event::Kill { .. } | Event::Recall => Party::Producer,
        }
    }

    /// Whether `ev` can fire right now. Used by trace replay to
    /// skip-repair steps that drifted out of enabledness; deliberately
    /// looser than what [`Self::enabled_events`] generates (any task id
    /// may be cancelled, any live root slot killed).
    pub fn is_enabled(&self, ev: Event) -> bool {
        match ev {
            Event::Deliver { from, to } => {
                self.edges.get(&(from, to)).is_some_and(|q| !q.is_empty())
            }
            Event::Finish { node, consumer } => {
                self.alive(Party::Node(node))
                    && self
                        .running
                        .get(node)
                        .and_then(|r| r.get(consumer))
                        .is_some_and(|q| !q.is_empty())
            }
            Event::Cancel { .. } => self.cancels_left > 0 && !self.producer.shutdown_sent(),
            Event::Kill { slot } => {
                self.kills_left > 0
                    && !self.producer.shutdown_sent()
                    && self.topo.roots.len() > 1
                    && self.topo.roots.get(slot).is_some_and(|&r| self.alive(Party::Node(r)))
            }
            Event::Recall => {
                self.recalls_left > 0
                    && !self.producer.is_recalling()
                    && !self.producer.shutdown_sent()
            }
        }
    }

    /// Apply one event. `Err` = an oracle with an inline detection point
    /// fired (double-grant, double-dispatch, duplicate-result,
    /// recall-quiescence); the step-wise oracles run separately via
    /// [`Self::check_invariants`].
    pub fn apply(&mut self, ev: Event) -> Result<(), Violation> {
        match ev {
            Event::Deliver { from, to } => self.deliver(from, to),
            Event::Finish { node, consumer } => self.finish(node, consumer),
            Event::Cancel { id } => self.cancel(id),
            Event::Kill { slot } => self.kill(slot),
            Event::Recall => self.recall(),
        }
    }

    /// Step-wise invariants: task conservation and the credit bound.
    pub fn check_invariants(&self) -> Option<Violation> {
        oracle::conservation(self).or_else(|| oracle::credit_bound(self))
    }

    /// End-state oracle, valid only when no event is enabled: either the
    /// run shut down with every task completed exactly once, or this is
    /// a deadlock / lost-task terminal state.
    pub fn check_terminal(&self) -> Option<Violation> {
        oracle::terminal(self)
    }

    fn deliver(&mut self, from: Party, to: Party) -> Result<(), Violation> {
        let msg = {
            let Some(q) = self.edges.get_mut(&(from, to)) else { return Ok(()) };
            let msg = q.pop_front();
            if q.is_empty() {
                self.edges.remove(&(from, to));
            }
            match msg {
                Some(m) => m,
                None => return Ok(()),
            }
        };
        match to {
            Party::Producer => self.deliver_to_producer(from, msg),
            Party::Node(id) => self.deliver_to_node(id, from, msg),
        }
    }

    fn deliver_to_producer(&mut self, from: Party, msg: ProtoMsg) -> Result<(), Violation> {
        let slot = self.root_slot(from).unwrap_or(0);
        let mut steps = Vec::new();
        match msg {
            ProtoMsg::Request { amount } => {
                steps.extend(route_producer_actions(
                    &self.topo,
                    self.producer.on_request(slot, amount),
                ));
            }
            ProtoMsg::Results(rs) => {
                for r in &rs {
                    let n = self.results_seen.entry(r.id).or_insert(0);
                    *n += 1;
                    if *n > 1 {
                        return Err(Violation::new(
                            "duplicate-result",
                            format!("the engine received {n} results for task {}", r.id),
                        ));
                    }
                    self.granted_live.remove(&r.id);
                    if let Some(gr) = self.granted_root.get_mut(slot) {
                        gr.remove(&r.id);
                    }
                }
                self.producer.on_results(rs.len());
            }
            ProtoMsg::Flush { amount, results } => {
                // The coalesced uplink carries both halves: the results get
                // the same per-result duplicate/ledger treatment as a
                // Results frame, the amount the same grant matching as a
                // Request frame.
                for r in &results {
                    let n = self.results_seen.entry(r.id).or_insert(0);
                    *n += 1;
                    if *n > 1 {
                        return Err(Violation::new(
                            "duplicate-result",
                            format!(
                                "the engine received {n} results for task {} (via Flush)",
                                r.id
                            ),
                        ));
                    }
                    self.granted_live.remove(&r.id);
                    if let Some(gr) = self.granted_root.get_mut(slot) {
                        gr.remove(&r.id);
                    }
                }
                let n_results = results.len();
                steps.extend(route_producer_actions(
                    &self.topo,
                    self.producer.on_flush(slot, amount, n_results),
                ));
            }
            ProtoMsg::Returned(ts) => {
                self.returned_seen += 1;
                let swallowed = matches!(
                    self.bug,
                    Some(SeededBug::DropReturned { nth }) if nth == self.returned_seen
                );
                if swallowed {
                    // Seeded bug: the batch vanishes — the ledgers are
                    // deliberately left stale too, exactly as a missing
                    // on_returned call would leave the real producer.
                } else {
                    for t in &ts {
                        self.granted_live.remove(&t.id);
                        if let Some(gr) = self.granted_root.get_mut(slot) {
                            gr.remove(&t.id);
                        }
                    }
                    self.producer.on_returned(ts);
                }
            }
            ProtoMsg::RecallAck => {
                if self.producer.on_recall_ack(slot) {
                    return self.graft();
                }
            }
            other => {
                return Err(Violation::new(
                    "bad-route",
                    format!("producer received unroutable message {other:?}"),
                ));
            }
        }
        steps.extend(route_producer_actions(&self.topo, self.producer.maybe_shutdown()));
        self.send(steps)
    }

    fn deliver_to_node(&mut self, id: usize, from: Party, msg: ProtoMsg) -> Result<(), Violation> {
        let from_slot = match from {
            Party::Node(f) => self.topo.nodes.get(f).map_or(0, |n| n.slot),
            Party::Producer => 0,
        };
        let Some(node) = self.nodes.get_mut(id).and_then(|n| n.as_mut()) else {
            return Ok(());
        };
        let acts = match msg {
            ProtoMsg::Assign(ts) => node.on_assign(ts),
            ProtoMsg::Cancel { id: tid } => node.on_cancel(tid),
            ProtoMsg::Recall => node.on_recall(),
            ProtoMsg::Shutdown => node.on_shutdown(),
            ProtoMsg::Request { amount } => node.on_child_request(from_slot, amount),
            ProtoMsg::Results(rs) => node.on_child_results(rs),
            ProtoMsg::Flush { amount, results } => node.on_child_flush(from_slot, amount, results),
            ProtoMsg::Returned(ts) => node.on_child_returned(ts),
            ProtoMsg::RecallAck => node.on_child_recall_ack(from_slot),
            ProtoMsg::StealRequest { thief, thief_slot, amount } => {
                node.on_steal_request(thief, thief_slot, amount)
            }
            ProtoMsg::StealGrant { from_slot: fs, left, cancels, tasks } => {
                node.on_steal_grant(fs, left, cancels, tasks)
            }
        };
        let (steps, effects) = route_buffer_actions(&self.topo, id, acts);
        self.apply_effects(id, effects)?;
        self.send(steps)
    }

    fn finish(&mut self, node: usize, consumer: usize) -> Result<(), Violation> {
        // The consumer runs its whole dispatched batch back to back and
        // reports once — Finish drains the queue into one on_done_batch,
        // mirroring the threaded consumer's single DoneBatch send.
        let batch: Vec<(TaskSpec, bool)> =
            match self.running.get_mut(node).and_then(|r| r.get_mut(consumer)) {
                Some(q) if !q.is_empty() => q.drain(..).collect(),
                _ => return Ok(()),
            };
        let results: Vec<TaskResult> = batch
            .into_iter()
            .map(|(task, killed)| TaskResult {
                id: task.id,
                consumer,
                results: Vec::new(),
                begin: 0.0,
                finish: 0.0,
                rc: if killed { RC_CANCELLED } else { 0 },
                attempt: task.attempt,
                timed_out: false,
            })
            .collect();
        let Some(st) = self.nodes.get_mut(node).and_then(|n| n.as_mut()) else {
            return Ok(());
        };
        let acts = st.on_done_batch(consumer, results);
        let (steps, effects) = route_buffer_actions(&self.topo, node, acts);
        self.apply_effects(node, effects)?;
        self.send(steps)
    }

    fn cancel(&mut self, id: TaskId) -> Result<(), Violation> {
        if self.cancels_left == 0 {
            return Ok(());
        }
        self.cancels_left -= 1;
        let (dropped, acts) = self.producer.on_cancel(id);
        if dropped.is_some() {
            // Pending hit: the producer completed the task as cancelled
            // and the runtime synthesizes the engine's RC_CANCELLED
            // result on the spot — exactly one engine-visible result.
            let n = self.results_seen.entry(id).or_insert(0);
            *n += 1;
            if *n > 1 {
                return Err(Violation::new(
                    "duplicate-result",
                    format!("cancel of pending task {id} synthesized a second result"),
                ));
            }
        }
        let mut steps = route_producer_actions(&self.topo, acts);
        steps.extend(route_producer_actions(&self.topo, self.producer.maybe_shutdown()));
        self.send(steps)
    }

    fn kill(&mut self, slot: usize) -> Result<(), Violation> {
        if self.kills_left == 0 {
            return Ok(());
        }
        let Some(&root) = self.topo.roots.get(slot) else { return Ok(()) };
        if !self.alive(Party::Node(root)) {
            return Ok(());
        }
        self.kills_left -= 1;
        // The whole worker subtree dies with its link.
        let mut dead = vec![root];
        let mut i = 0;
        while i < dead.len() {
            if let Some(TreeNodeKind::Interior { children }) =
                self.topo.nodes.get(dead[i]).map(|n| &n.kind)
            {
                dead.extend(children.iter().copied());
            }
            i += 1;
        }
        let dead_set: BTreeSet<usize> = dead.iter().copied().collect();
        for &d in &dead {
            if let Some(n) = self.nodes.get_mut(d) {
                *n = None;
            }
            if let Some(r) = self.running.get_mut(d) {
                for q in r.iter_mut() {
                    q.clear();
                }
            }
        }
        // Everything in flight on a dead link is lost with it. In-flight
        // results from the dead subtree were never counted by the
        // producer, so their ids are still in the slot ledger and get
        // re-fed below — exactly-once survives the crash.
        let touches_dead = |p: Party| matches!(p, Party::Node(n) if dead_set.contains(&n));
        self.edges.retain(|&(f, t), _| !touches_dead(f) && !touches_dead(t));
        self.producer.on_child_dead(slot);
        let outstanding: Vec<TaskSpec> = self
            .granted_root
            .get_mut(slot)
            .map(std::mem::take)
            .unwrap_or_default()
            .into_values()
            .collect();
        for t in &outstanding {
            self.granted_live.remove(&t.id);
        }
        self.producer.on_returned(outstanding);
        if self.producer.recall_complete() {
            // The dead link supplied the final implicit recall ack.
            return self.graft();
        }
        let mut steps = route_producer_actions(&self.topo, self.producer.push_tasks(Vec::new()));
        steps.extend(route_producer_actions(&self.topo, self.producer.maybe_shutdown()));
        self.send(steps)
    }

    fn recall(&mut self) -> Result<(), Violation> {
        if self.recalls_left == 0 || self.producer.is_recalling() || self.producer.shutdown_sent()
        {
            return Ok(());
        }
        self.recalls_left -= 1;
        let steps = route_producer_actions(&self.topo, self.producer.begin_recall());
        self.send(steps)?;
        // A dead link can never ack; mark it immediately, as the serve
        // loop does for links it already knows are down.
        let dead_slots: Vec<usize> = self
            .topo
            .roots
            .iter()
            .enumerate()
            .filter(|&(_, &r)| !self.alive(Party::Node(r)))
            .map(|(slot, _)| slot)
            .collect();
        for slot in dead_slots {
            self.producer.on_child_dead(slot);
        }
        if self.producer.recall_complete() {
            return self.graft();
        }
        Ok(())
    }

    /// All recall acks are in: verify quiescence, then tear down the old
    /// tree and start a fresh one (same shape; a dead subtree revives —
    /// the model's stand-in for the runtimes' graft / worker restart).
    fn graft(&mut self) -> Result<(), Violation> {
        if let Some(v) = oracle::recall_quiescence(self) {
            return Err(v);
        }
        self.producer.rewire(self.topo.roots.len());
        self.edges.clear();
        self.granted_root = vec![BTreeMap::new(); self.topo.roots.len()];
        self.build_nodes()?;
        let mut steps = route_producer_actions(&self.topo, self.producer.push_tasks(Vec::new()));
        steps.extend(route_producer_actions(&self.topo, self.producer.maybe_shutdown()));
        self.send(steps)
    }

    /// Deterministic fingerprint of the protocol-visible state (FNV-1a
    /// over the producer, every node, every in-flight message, the
    /// running ground truth and the fault budgets). Drives the DFS
    /// visited set.
    pub fn state_hash(&self) -> u64 {
        use std::hash::Hasher;
        fn hash_party(p: Party, h: &mut Fnv64) {
            match p {
                Party::Producer => h.write_u8(0),
                Party::Node(id) => {
                    h.write_u8(1);
                    h.write_usize(id);
                }
            }
        }
        let mut h = Fnv64::new();
        self.producer.model_hash(&mut h);
        for (id, n) in self.nodes.iter().enumerate() {
            h.write_usize(id);
            match n {
                Some(st) => {
                    h.write_u8(1);
                    st.model_hash(&mut h);
                }
                None => h.write_u8(0),
            }
        }
        for ((from, to), q) in &self.edges {
            hash_party(*from, &mut h);
            hash_party(*to, &mut h);
            h.write_usize(q.len());
            for m in q {
                m.model_hash(&mut h);
            }
        }
        for (node, slots) in self.running.iter().enumerate() {
            for (consumer, q) in slots.iter().enumerate() {
                if q.is_empty() {
                    continue;
                }
                h.write_usize(node);
                h.write_usize(consumer);
                h.write_usize(q.len());
                for (t, killed) in q {
                    h.write_u64(t.id);
                    h.write_u8(u8::from(*killed));
                }
            }
        }
        h.write_u32(self.cancels_left);
        h.write_u32(self.kills_left);
        h.write_u32(self.recalls_left);
        h.write_u32(self.returned_seen);
        for (&id, &n) in &self.results_seen {
            h.write_u64(id);
            h.write_u32(n);
        }
        h.finish()
    }
}

/// Linked trace cell: the DFS shares schedule prefixes across branches.
struct TraceNode {
    ev: Event,
    prev: Option<Rc<TraceNode>>,
}

fn unwind(mut t: Option<Rc<TraceNode>>) -> Vec<Event> {
    let mut out = Vec::new();
    while let Some(n) = t {
        out.push(n.ev);
        t = n.prev.clone();
    }
    out.reverse();
    out
}

/// Result of the exhaustive phase.
pub(crate) struct DfsOutcome {
    pub(crate) states: u64,
    pub(crate) exhausted: bool,
    pub(crate) depth_pruned: u64,
    pub(crate) violation: Option<(Violation, Vec<Event>)>,
}

/// Depth-first exploration with a visited set over [`Model::state_hash`]
/// and the partial-order reduction of [`Model::enabled_events`]. Stops
/// at the first violation (schedule returned for shrinking) or when the
/// frontier drains / the state budget is hit.
pub(crate) fn dfs(init: &Model, max_depth: usize, max_states: u64) -> DfsOutcome {
    let mut visited: BTreeSet<u64> = BTreeSet::new();
    let mut states: u64 = 0;
    let mut depth_pruned: u64 = 0;
    let mut budget_hit = false;
    // Entries carry a parent model plus the event to apply on pop, so
    // memory stays O(frontier) models, not O(stack) models.
    type Entry = (Rc<Model>, Option<Rc<TraceNode>>, usize, Option<Event>);
    let mut stack: Vec<Entry> = vec![(Rc::new(init.clone()), None, 0, None)];
    while let Some((base, trace, depth, ev)) = stack.pop() {
        let (m, trace) = match ev {
            None => ((*base).clone(), trace),
            Some(ev) => {
                let mut m = (*base).clone();
                let trace = Some(Rc::new(TraceNode { ev, prev: trace }));
                if let Some(v) = m.apply(ev).err().or_else(|| m.check_invariants()) {
                    return DfsOutcome {
                        states,
                        exhausted: false,
                        depth_pruned,
                        violation: Some((v, unwind(trace))),
                    };
                }
                (m, trace)
            }
        };
        if !visited.insert(m.state_hash()) {
            continue;
        }
        states += 1;
        if states >= max_states {
            budget_hit = true;
            break;
        }
        let evs = m.enabled_events(true);
        if evs.is_empty() {
            if let Some(v) = m.check_terminal() {
                return DfsOutcome {
                    states,
                    exhausted: false,
                    depth_pruned,
                    violation: Some((v, unwind(trace))),
                };
            }
            continue;
        }
        if depth >= max_depth {
            depth_pruned += 1;
            continue;
        }
        let base = Rc::new(m);
        for ev in evs.into_iter().rev() {
            stack.push((base.clone(), trace.clone(), depth + 1, Some(ev)));
        }
    }
    DfsOutcome { states, exhausted: !budget_hit, depth_pruned, violation: None }
}

/// Result of the fuzz phase.
pub(crate) struct FuzzOutcome {
    pub(crate) schedules: u64,
    pub(crate) violation: Option<(Violation, Vec<Event>)>,
}

fn lcg(x: u64) -> u64 {
    x.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(1_442_695_040_888_963_407)
}

/// Seeded random-schedule sampling over the *full* (unreduced) event
/// set — the backstop for interleavings the POR heuristic prunes and
/// for budgets the exhaustive phase cannot reach. Deterministic: seed
/// `k` always replays the same schedule.
pub(crate) fn fuzz(init: &Model, seeds: u64, max_steps: usize) -> FuzzOutcome {
    for seed in 0..seeds {
        let mut x = lcg(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1));
        let mut m = init.clone();
        let mut schedule = Vec::new();
        for _ in 0..max_steps {
            let evs = m.enabled_events(false);
            if evs.is_empty() {
                if let Some(v) = m.check_terminal() {
                    return FuzzOutcome { schedules: seed + 1, violation: Some((v, schedule)) };
                }
                break;
            }
            x = lcg(x);
            let pick = evs[(x >> 33) as usize % evs.len()];
            schedule.push(pick);
            if let Some(v) = m.apply(pick).err().or_else(|| m.check_invariants()) {
                return FuzzOutcome { schedules: seed + 1, violation: Some((v, schedule)) };
            }
        }
    }
    FuzzOutcome { schedules: seeds, violation: None }
}

#[cfg(test)]
mod tests {
    use super::super::{scenario, CheckConfig, FaultSet};
    use super::*;

    fn flat2_model(n_tasks: usize, faults: FaultSet) -> Model {
        let sc = scenario("flat2").expect("flat2 registered");
        Model::new(&sc.cfg, n_tasks, faults, None).expect("clean init")
    }

    #[test]
    fn init_satisfies_invariants() {
        let m = flat2_model(3, FaultSet::default());
        assert!(m.check_invariants().is_none());
        assert!(m.producer.pending_len() == 3);
        // Both leaves sent their initial credit request.
        assert!(m.enabled_events(false).len() >= 2);
    }

    #[test]
    fn state_hash_is_deterministic_and_step_sensitive() {
        let m1 = flat2_model(2, FaultSet::default());
        let m2 = flat2_model(2, FaultSet::default());
        assert_eq!(m1.state_hash(), m2.state_hash());
        let mut m3 = m2.clone();
        let ev = *m3.enabled_events(false).first().expect("events at init");
        m3.apply(ev).expect("clean step");
        assert_ne!(m1.state_hash(), m3.state_hash());
    }

    #[test]
    fn faultless_flat2_runs_to_clean_termination() {
        let m = flat2_model(2, FaultSet::default());
        let out = dfs(&m, 400, 200_000);
        assert!(out.violation.is_none(), "violation: {:?}", out.violation);
        assert!(out.exhausted);
        assert!(out.states > 10);
    }

    #[test]
    fn recall_and_cancel_flat2_explores_clean() {
        let faults = FaultSet { steal: true, cancel: true, recall: true, kill: false };
        let m = flat2_model(2, faults);
        let out = dfs(&m, 400, CheckConfig::default().max_states);
        assert!(out.violation.is_none(), "violation: {:?}", out.violation);
    }

    #[test]
    fn kill_during_recall_on_deep4_is_lossless() {
        let sc = scenario("deep4").expect("deep4 registered");
        let faults = FaultSet { steal: true, cancel: false, recall: true, kill: true };
        let m = Model::new(&sc.cfg, 2, faults, None).expect("clean init");
        let out = dfs(&m, 400, 150_000);
        assert!(out.violation.is_none(), "violation: {:?}", out.violation);
    }

    #[test]
    fn fuzz_is_deterministic() {
        let faults = FaultSet { steal: true, cancel: true, recall: true, kill: false };
        let m = flat2_model(3, faults);
        let a = fuzz(&m, 16, 5_000);
        let b = fuzz(&m, 16, 5_000);
        assert_eq!(a.schedules, b.schedules);
        assert!(a.violation.is_none(), "violation: {:?}", a.violation);
    }
}
