//! Replayable trace artifacts and counterexample minimization.
//!
//! Trace format v1 — line-oriented, self-describing, diff-friendly:
//!
//! ```text
//! # caravan check trace v1
//! scenario flat2
//! faults steal,cancel,recall
//! tasks 3
//! bug drop-returned:1        (only when a seeded bug was armed)
//! step deliver producer->n0
//! step finish n1 0
//! step cancel 1
//! step kill 1
//! step recall
//! end
//! ```
//!
//! A `deliver` step names only the edge — it delivers whatever message
//! is at that edge's FIFO head — so traces stay replayable across
//! protocol-internal changes that re-batch or re-order payloads.
//! Replay skip-repairs: a step that is not enabled in the replayed
//! state is skipped, not fatal.

use crate::scheduler::protocol::Party;

use super::{Event, FaultSet, Model, SeededBug, Violation};

/// Header comment of format v1 (also the version sentinel on parse).
pub const TRACE_HEADER: &str = "# caravan check trace v1";

/// A parsed trace artifact: the model coordinates plus the schedule.
#[derive(Clone, Debug)]
pub struct ParsedTrace {
    /// Scenario name the trace was recorded against.
    pub scenario: String,
    /// Faults that were armed.
    pub faults: FaultSet,
    /// Tasks the model engine submits.
    pub n_tasks: usize,
    /// Seeded bug to re-arm, if any.
    pub bug: Option<SeededBug>,
    /// The event schedule.
    pub events: Vec<Event>,
}

fn fmt_event(ev: &Event) -> String {
    match *ev {
        Event::Deliver { from, to } => format!("deliver {from}->{to}"),
        Event::Finish { node, consumer } => format!("finish n{node} {consumer}"),
        Event::Cancel { id } => format!("cancel {id}"),
        Event::Kill { slot } => format!("kill {slot}"),
        Event::Recall => "recall".to_string(),
    }
}

/// Render a schedule as a replayable trace artifact.
pub fn format_trace(
    scenario: &str,
    faults: FaultSet,
    n_tasks: usize,
    bug: Option<SeededBug>,
    events: &[Event],
) -> String {
    let mut out = String::new();
    out.push_str(TRACE_HEADER);
    out.push('\n');
    out.push_str(&format!("scenario {scenario}\n"));
    out.push_str(&format!("faults {faults}\n"));
    out.push_str(&format!("tasks {n_tasks}\n"));
    if let Some(b) = bug {
        out.push_str(&format!("bug {b}\n"));
    }
    for ev in events {
        out.push_str(&format!("step {}\n", fmt_event(ev)));
    }
    out.push_str("end\n");
    out
}

fn parse_party(s: &str) -> Result<Party, String> {
    if s == "producer" {
        return Ok(Party::Producer);
    }
    match s.strip_prefix('n').and_then(|n| n.parse::<usize>().ok()) {
        Some(id) => Ok(Party::Node(id)),
        None => Err(format!("bad party '{s}' (expected 'producer' or 'nID')")),
    }
}

fn parse_step(rest: &str) -> Result<Event, String> {
    let mut toks = rest.split_whitespace();
    let kind = toks.next().ok_or_else(|| "empty step".to_string())?;
    let ev = match kind {
        "deliver" => {
            let edge = toks.next().ok_or_else(|| "deliver needs FROM->TO".to_string())?;
            let (from, to) = edge
                .split_once("->")
                .ok_or_else(|| format!("bad deliver edge '{edge}' (expected FROM->TO)"))?;
            Event::Deliver { from: parse_party(from)?, to: parse_party(to)? }
        }
        "finish" => {
            let node = toks.next().ok_or_else(|| "finish needs a node".to_string())?;
            let Party::Node(node) = parse_party(node)? else {
                return Err("finish needs a buffer node, not the producer".to_string());
            };
            let consumer = toks
                .next()
                .and_then(|c| c.parse::<usize>().ok())
                .ok_or_else(|| "finish needs a consumer index".to_string())?;
            Event::Finish { node, consumer }
        }
        "cancel" => {
            let id = toks
                .next()
                .and_then(|t| t.parse::<u64>().ok())
                .ok_or_else(|| "cancel needs a task id".to_string())?;
            Event::Cancel { id }
        }
        "kill" => {
            let slot = toks
                .next()
                .and_then(|t| t.parse::<usize>().ok())
                .ok_or_else(|| "kill needs a root slot".to_string())?;
            Event::Kill { slot }
        }
        "recall" => Event::Recall,
        other => return Err(format!("unknown step kind '{other}'")),
    };
    if let Some(extra) = toks.next() {
        return Err(format!("trailing token '{extra}' after {kind} step"));
    }
    Ok(ev)
}

/// Parse a trace artifact (inverse of [`format_trace`]).
pub fn parse_trace(text: &str) -> Result<ParsedTrace, String> {
    let mut scenario: Option<String> = None;
    let mut faults: Option<FaultSet> = None;
    let mut n_tasks: Option<usize> = None;
    let mut bug: Option<SeededBug> = None;
    let mut events = Vec::new();
    let mut saw_end = false;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let at = |e: String| format!("trace line {}: {e}", lineno + 1);
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if saw_end {
            return Err(at(format!("content after 'end': '{line}'")));
        }
        let (key, rest) = match line.split_once(' ') {
            Some((k, r)) => (k, r.trim()),
            None => (line, ""),
        };
        match key {
            "scenario" => scenario = Some(rest.to_string()),
            "faults" => faults = Some(FaultSet::parse(rest).map_err(at)?),
            "tasks" => {
                n_tasks =
                    Some(rest.parse::<usize>().map_err(|e| at(format!("bad task count: {e}")))?);
            }
            "bug" => bug = Some(SeededBug::parse(rest).map_err(at)?),
            "step" => events.push(parse_step(rest).map_err(at)?),
            "end" => saw_end = true,
            other => return Err(at(format!("unknown directive '{other}'"))),
        }
    }
    if !saw_end {
        return Err("trace is missing its 'end' line (truncated?)".to_string());
    }
    Ok(ParsedTrace {
        scenario: scenario.ok_or("trace is missing a 'scenario' line")?,
        faults: faults.ok_or("trace is missing a 'faults' line")?,
        n_tasks: n_tasks.ok_or("trace is missing a 'tasks' line")?,
        bug,
        events,
    })
}

/// Replay a schedule from `init`, skip-repairing steps that are not
/// enabled. Returns the first oracle violation, including — when the
/// schedule runs to a state with nothing enabled — the terminal oracle.
pub(crate) fn replay(init: &Model, events: &[Event]) -> Option<Violation> {
    let mut m = init.clone();
    for &ev in events {
        if !m.is_enabled(ev) {
            continue;
        }
        if let Some(v) = m.apply(ev).err().or_else(|| m.check_invariants()) {
            return Some(v);
        }
    }
    if m.enabled_events(false).is_empty() {
        return m.check_terminal();
    }
    None
}

/// Delta-debugging (ddmin) shrink: remove event chunks at doubling
/// granularity while the shortened schedule still reproduces *a*
/// violation under [`replay`]. Returns a 1-minimal schedule — removing
/// any single remaining event loses the violation.
pub(crate) fn shrink(init: &Model, events: Vec<Event>) -> Vec<Event> {
    if replay(init, &events).is_none() {
        // Not reproducible from a cold replay (should not happen — the
        // schedule came from this very model); return it unshrunk.
        return events;
    }
    let mut cur = events;
    let mut n: usize = 2;
    while cur.len() >= 2 {
        let chunk = cur.len().div_ceil(n);
        let mut reduced = false;
        let mut start = 0;
        while start < cur.len() {
            let end = (start + chunk).min(cur.len());
            let mut cand = Vec::with_capacity(cur.len() - (end - start));
            cand.extend_from_slice(&cur[..start]);
            cand.extend_from_slice(&cur[end..]);
            if !cand.is_empty() && replay(init, &cand).is_some() {
                cur = cand;
                n = (n - 1).max(2);
                reduced = true;
                break;
            }
            start = end;
        }
        if !reduced {
            if chunk == 1 {
                break;
            }
            n = (n * 2).min(cur.len());
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::super::{scenario, SeededBug};
    use super::*;

    fn events() -> Vec<Event> {
        vec![
            Event::Deliver { from: Party::Node(0), to: Party::Producer },
            Event::Deliver { from: Party::Producer, to: Party::Node(0) },
            Event::Finish { node: 0, consumer: 0 },
            Event::Cancel { id: 1 },
            Event::Kill { slot: 1 },
            Event::Recall,
        ]
    }

    #[test]
    fn trace_round_trips() {
        let faults = FaultSet { steal: true, cancel: true, recall: true, kill: true };
        let text = format_trace(
            "deep4",
            faults,
            3,
            Some(SeededBug::DropReturned { nth: 2 }),
            &events(),
        );
        assert!(text.starts_with(TRACE_HEADER));
        assert!(text.ends_with("end\n"));
        let parsed = parse_trace(&text).expect("round trip");
        assert_eq!(parsed.scenario, "deep4");
        assert_eq!(parsed.faults, faults);
        assert_eq!(parsed.n_tasks, 3);
        assert_eq!(parsed.bug, Some(SeededBug::DropReturned { nth: 2 }));
        assert_eq!(parsed.events, events());
    }

    #[test]
    fn parse_rejects_malformed_traces() {
        assert!(parse_trace("").is_err());
        assert!(parse_trace("scenario flat2\nfaults none\ntasks 2\n").is_err());
        assert!(parse_trace("scenario flat2\nfaults none\ntasks 2\nstep levitate\nend\n").is_err());
        assert!(
            parse_trace("scenario flat2\nfaults none\ntasks 2\nstep deliver producer\nend\n")
                .is_err()
        );
        assert!(parse_trace("scenario flat2\nfaults bogus\ntasks 2\nend\n").is_err());
        assert!(parse_trace("scenario flat2\nfaults none\ntasks 2\nend\nstep recall\n").is_err());
    }

    #[test]
    fn replay_skip_repairs_disabled_steps() {
        let sc = scenario("flat2").expect("flat2 registered");
        let init = Model::new(&sc.cfg, 2, FaultSet::default(), None).expect("clean init");
        // A schedule of entirely disabled steps: nothing fires, nothing
        // terminal — replay is green.
        let bogus = vec![
            Event::Finish { node: 0, consumer: 0 },
            Event::Deliver { from: Party::Node(7), to: Party::Node(9) },
            Event::Recall,
            Event::Kill { slot: 1 },
        ];
        assert!(replay(&init, &bogus).is_none());
    }

    #[test]
    fn shrink_produces_a_minimal_reproducing_schedule() {
        let sc = scenario("flat2").expect("flat2 registered");
        let faults = FaultSet { steal: true, cancel: false, recall: true, kill: false };
        let init = Model::new(&sc.cfg, 2, faults, Some(SeededBug::DropReturned { nth: 1 }))
            .expect("clean init");
        // Find a violating schedule via the fuzzer, then shrink it.
        let out = super::super::explore::fuzz(&init, 64, 5_000);
        let (_, schedule) = out.violation.expect("seeded bug must be caught by fuzzing");
        let min = shrink(&init, schedule.clone());
        assert!(!min.is_empty());
        assert!(min.len() <= schedule.len());
        assert!(replay(&init, &min).is_some(), "minimized schedule must still reproduce");
        // 1-minimality: dropping any single event loses the violation.
        for i in 0..min.len() {
            let mut cand = min.clone();
            cand.remove(i);
            if !cand.is_empty() {
                assert!(
                    replay(&init, &cand).is_none(),
                    "schedule not 1-minimal: event {i} is removable"
                );
            }
        }
    }
}
