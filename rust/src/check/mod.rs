//! `caravan check` — a bounded model checker for the credit/steal/cancel/
//! recall protocol in [`crate::scheduler::protocol`].
//!
//! The checker drives the *pure* [`ProducerState`] and [`BufferState`]
//! handlers — the exact state machines both runtimes execute — through a
//! small model harness ([`Model`]): N tasks, a small tree, and one
//! per-directed-edge FIFO of in-flight [`ProtoMsg`]s. Every pending
//! delivery (plus consumer completions and injected fault events) is an
//! explorable [`Event`]; DFS over the event interleavings with a
//! partial-order reduction and a state-hash visited set enumerates the
//! reachable protocol states up to a budget, and a seeded LCG schedule
//! fuzzer (no `rand`) samples beyond it.
//!
//! After every step the invariant oracles in [`oracle`] run:
//!
//! | oracle              | property                                            |
//! |---------------------|-----------------------------------------------------|
//! | `conservation`      | pending + queued + running + in-flight + done == N  |
//! | `double-grant`      | a `TaskId` is never granted while a grant is live   |
//! | `duplicate-result`  | the engine sees at most one result per task         |
//! | `double-dispatch`   | a consumer is never handed two concurrent attempts  |
//! | `credit-bound`      | no queue exceeds `credit_factor × subtree_consumers`|
//! | `recall-quiescence` | at graft time nothing is stranded below the recall  |
//! | `deadlock`          | no enabled event implies shutdown was broadcast     |
//! | `termination`       | at quiescence every task completed exactly once     |
//!
//! On a violation the offending schedule is shrunk with delta debugging
//! ([`trace`]) to a minimal event list and printed as a replayable
//! artifact (`caravan check --replay FILE`).
//!
//! [`ProducerState`]: crate::scheduler::protocol::ProducerState
//! [`BufferState`]: crate::scheduler::protocol::BufferState
//! [`ProtoMsg`]: crate::scheduler::protocol::ProtoMsg

pub mod explore;
pub mod oracle;
pub mod trace;

pub use explore::{Event, Model};
pub use trace::{format_trace, parse_trace, ParsedTrace};

use crate::config::SchedulerConfig;

/// Which fault events the exploration may inject on top of ordinary
/// message deliveries and completions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct FaultSet {
    /// Sibling work stealing enabled in the scenario tree.
    pub steal: bool,
    /// One engine-driven cancellation of a mid-range task.
    pub cancel: bool,
    /// One drain-and-graft recall.
    pub recall: bool,
    /// One dead link: a root subtree is killed mid-run.
    pub kill: bool,
}

impl FaultSet {
    /// Parse a comma-separated fault list (`steal,cancel,recall,kill`;
    /// `none` or the empty string = no faults).
    pub fn parse(s: &str) -> Result<FaultSet, String> {
        let mut f = FaultSet::default();
        let s = s.trim();
        if s.is_empty() || s == "none" {
            return Ok(f);
        }
        for tok in s.split(',') {
            match tok.trim() {
                "steal" => f.steal = true,
                "cancel" => f.cancel = true,
                "recall" => f.recall = true,
                "kill" => f.kill = true,
                other => {
                    return Err(format!(
                        "unknown fault '{other}' (valid: steal, cancel, recall, kill)"
                    ))
                }
            }
        }
        Ok(f)
    }
}

impl std::fmt::Display for FaultSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut toks = Vec::new();
        if self.steal {
            toks.push("steal");
        }
        if self.cancel {
            toks.push("cancel");
        }
        if self.recall {
            toks.push("recall");
        }
        if self.kill {
            toks.push("kill");
        }
        if toks.is_empty() {
            write!(f, "none")
        } else {
            write!(f, "{}", toks.join(","))
        }
    }
}

/// A deliberately seeded protocol fault, used to prove the oracles can
/// catch real bugs (and in CI, that a red check stays red).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeededBug {
    /// Silently drop the `nth` (1-based) `Returned` batch at the
    /// producer instead of re-queueing it — the exact bug a missing
    /// `on_returned` call would be. Conservation breaks on any schedule
    /// where a recall (or dead link) sends tasks upstream.
    DropReturned {
        /// Which `Returned` delivery (1-based) to swallow.
        nth: u32,
    },
}

impl SeededBug {
    /// Parse a bug spec: `drop-returned` or `drop-returned:N`.
    pub fn parse(s: &str) -> Result<SeededBug, String> {
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        match kind.trim() {
            "drop-returned" => {
                let nth = match arg {
                    None => 1,
                    Some(a) => a
                        .trim()
                        .parse::<u32>()
                        .map_err(|_| format!("bad drop-returned index '{a}'"))?,
                };
                if nth == 0 {
                    return Err("drop-returned index is 1-based".to_string());
                }
                Ok(SeededBug::DropReturned { nth })
            }
            other => Err(format!("unknown bug '{other}' (valid: drop-returned[:N])")),
        }
    }
}

impl std::fmt::Display for SeededBug {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SeededBug::DropReturned { nth } => write!(f, "drop-returned:{nth}"),
        }
    }
}

/// One invariant-oracle violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Which oracle fired (stable machine-readable name).
    pub oracle: &'static str,
    /// Human-readable description of what went wrong.
    pub detail: String,
}

impl Violation {
    pub(crate) fn new(oracle: &'static str, detail: String) -> Violation {
        Violation { oracle, detail }
    }
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.oracle, self.detail)
    }
}

/// A violating schedule, shrunk to a (locally) minimal event list.
#[derive(Clone, Debug)]
pub struct Counterexample {
    /// The oracle violation the minimized schedule reproduces.
    pub violation: Violation,
    /// Minimized event schedule (replayable via [`replay_trace_text`]).
    pub events: Vec<Event>,
    /// Length of the schedule before delta-debugging shrank it.
    pub original_len: usize,
}

/// A named model topology the checker can explore.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Registry name (`--scenario NAME`).
    pub name: &'static str,
    /// One-line description for `--help`-style listings.
    pub summary: &'static str,
    /// Whether the `kill` fault is meaningful here: killing a root
    /// subtree is only modelled for trees with ≥ 2 roots and no
    /// root-level stealing (matching the distributed runtime, where
    /// root subtrees live in separate worker processes that cannot
    /// steal from each other).
    pub kill_ok: bool,
    /// Scheduler configuration the model tree is built from.
    pub cfg: SchedulerConfig,
}

/// Every registered scenario.
pub fn scenarios() -> Vec<Scenario> {
    let flat2 = Scenario {
        name: "flat2",
        summary: "2 leaf buffers under the producer, 1 consumer each, stealing siblings",
        kill_ok: false,
        cfg: SchedulerConfig {
            np: 2,
            consumers_per_buffer: 1,
            depth: 1,
            fanout: vec![2],
            steal: true,
            credit_factor: 2,
            flush_every: 2,
            ..SchedulerConfig::default()
        },
    };
    let batched2 = Scenario {
        name: "batched2",
        summary: "flat2 with dispatch_batch=2 and ascent coalescing: the batched hot path",
        kill_ok: false,
        cfg: SchedulerConfig {
            np: 2,
            consumers_per_buffer: 1,
            depth: 1,
            fanout: vec![2],
            steal: true,
            credit_factor: 2,
            flush_every: 2,
            dispatch_batch: 2,
            coalesce_flush: true,
            ..SchedulerConfig::default()
        },
    };
    let deep4 = Scenario {
        name: "deep4",
        summary: "2 interior roots x 2 leaves, 1 consumer each; kill-capable",
        kill_ok: true,
        cfg: SchedulerConfig {
            np: 4,
            consumers_per_buffer: 1,
            depth: 2,
            fanout: vec![2],
            steal: true,
            credit_factor: 2,
            flush_every: 2,
            ..SchedulerConfig::default()
        },
    };
    vec![flat2, batched2, deep4]
}

/// Look up a scenario by name.
pub fn scenario(name: &str) -> Option<Scenario> {
    scenarios().into_iter().find(|s| s.name == name)
}

/// Checker run parameters (the `caravan check` CLI surface).
#[derive(Clone, Debug)]
pub struct CheckConfig {
    /// Scenario name (see [`scenarios`]).
    pub scenario: String,
    /// Tasks the model engine submits (`--max-tasks`).
    pub n_tasks: usize,
    /// DFS depth bound; deeper schedules are pruned (`--max-depth`).
    pub max_depth: usize,
    /// Unique-state budget for the exhaustive phase (`--max-states`).
    pub max_states: u64,
    /// Fuzz schedules after a clean exhaustive phase; 0 disables
    /// (`--seeds`).
    pub seeds: u64,
    /// Per-schedule event cap for the fuzzer (`--fuzz-steps`).
    pub fuzz_steps: usize,
    /// Fault events to inject (`--faults`).
    pub faults: FaultSet,
    /// Deliberately seeded bug, if any (`--inject-bug`).
    pub bug: Option<SeededBug>,
}

impl Default for CheckConfig {
    fn default() -> Self {
        CheckConfig {
            scenario: "flat2".to_string(),
            n_tasks: 3,
            max_depth: 400,
            max_states: 200_000,
            seeds: 64,
            fuzz_steps: 5_000,
            faults: FaultSet { steal: true, cancel: true, recall: true, kill: false },
            bug: None,
        }
    }
}

/// Outcome of one checker run (exhaustive phase + optional fuzz phase,
/// or a single trace replay).
#[derive(Clone, Debug)]
pub struct CheckReport {
    /// Scenario explored.
    pub scenario: String,
    /// Faults injected.
    pub faults: FaultSet,
    /// Tasks submitted to the model.
    pub n_tasks: usize,
    /// Seeded bug, if one was armed.
    pub bug: Option<SeededBug>,
    /// Unique states visited by the exhaustive phase.
    pub states: u64,
    /// True when DFS drained the whole (depth-bounded) state space
    /// without hitting the state budget.
    pub exhausted: bool,
    /// Schedules pruned at the depth bound (0 ⇒ the bound never bit).
    pub depth_pruned: u64,
    /// Fuzz schedules executed after the exhaustive phase.
    pub fuzz_schedules: u64,
    /// The minimized violating schedule, if any oracle fired.
    pub counterexample: Option<Counterexample>,
}

impl CheckReport {
    /// True when every oracle held on every explored schedule.
    pub fn passed(&self) -> bool {
        self.counterexample.is_none()
    }

    /// The minimized counterexample as a replayable trace artifact.
    pub fn counterexample_trace(&self) -> Option<String> {
        self.counterexample
            .as_ref()
            .map(|c| format_trace(&self.scenario, self.faults, self.n_tasks, self.bug, &c.events))
    }
}

/// FNV-1a 64 — a fixed-key hasher for the visited-state set. `std`'s
/// default hasher is seeded per process, which would make visited-set
/// pruning (and therefore state counts) nondeterministic across runs.
pub(crate) struct Fnv64(u64);

impl Fnv64 {
    pub(crate) fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }
}

impl std::hash::Hasher for Fnv64 {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
}

/// Run the checker: exhaustive DFS up to the budgets, then (when clean
/// and `seeds > 0`) seeded schedule fuzzing. `Err` is a usage error
/// (unknown scenario, bad bounds) — distinct from an oracle violation,
/// which comes back inside the report.
pub fn run_check(cfg: &CheckConfig) -> Result<CheckReport, String> {
    let sc = scenario(&cfg.scenario).ok_or_else(|| {
        let names: Vec<&str> = scenarios().iter().map(|s| s.name).collect();
        format!("unknown scenario '{}' (known: {})", cfg.scenario, names.join(", "))
    })?;
    if cfg.faults.kill && !sc.kill_ok {
        return Err(format!(
            "scenario '{}' cannot model the kill fault (needs >= 2 producer-level \
             subtrees with no root-level stealing; try --scenario deep4)",
            sc.name
        ));
    }
    if cfg.n_tasks == 0 || cfg.n_tasks > 16 {
        return Err(format!("--max-tasks must be in 1..=16, got {}", cfg.n_tasks));
    }
    if cfg.max_depth == 0 {
        return Err("--max-depth must be positive".to_string());
    }

    let mut report = CheckReport {
        scenario: sc.name.to_string(),
        faults: cfg.faults,
        n_tasks: cfg.n_tasks,
        bug: cfg.bug,
        states: 0,
        exhausted: false,
        depth_pruned: 0,
        fuzz_schedules: 0,
        counterexample: None,
    };

    let init = match Model::new(&sc.cfg, cfg.n_tasks, cfg.faults, cfg.bug) {
        Ok(m) => m,
        Err(v) => {
            report.counterexample =
                Some(Counterexample { violation: v, events: Vec::new(), original_len: 0 });
            return Ok(report);
        }
    };

    let dfs = explore::dfs(&init, cfg.max_depth, cfg.max_states);
    report.states = dfs.states;
    report.exhausted = dfs.exhausted;
    report.depth_pruned = dfs.depth_pruned;
    if let Some((violation, events)) = dfs.violation {
        report.counterexample = Some(minimize(&init, violation, events));
        return Ok(report);
    }

    if cfg.seeds > 0 {
        let fz = explore::fuzz(&init, cfg.seeds, cfg.fuzz_steps);
        report.fuzz_schedules = fz.schedules;
        if let Some((violation, events)) = fz.violation {
            report.counterexample = Some(minimize(&init, violation, events));
        }
    }
    Ok(report)
}

/// Shrink a violating schedule with ddmin and re-derive the violation
/// the minimized schedule actually reproduces (shrinking may surface an
/// earlier — sometimes different — oracle on the shorter schedule).
fn minimize(init: &Model, violation: Violation, events: Vec<Event>) -> Counterexample {
    let original_len = events.len();
    let min = trace::shrink(init, events);
    let violation = trace::replay(init, &min).unwrap_or(violation);
    Counterexample { violation, events: min, original_len }
}

/// Parse and replay a trace artifact (`caravan check --replay FILE`).
/// The report's counterexample is `Some` iff the replay violates an
/// oracle; traces are skip-repaired, so steps that are not enabled in
/// the replayed state (e.g. after a protocol change reorders messages)
/// are ignored rather than fatal.
pub fn replay_trace_text(text: &str) -> Result<CheckReport, String> {
    let parsed = parse_trace(text)?;
    let sc = scenario(&parsed.scenario).ok_or_else(|| {
        format!("trace names unknown scenario '{}'", parsed.scenario)
    })?;
    if parsed.n_tasks == 0 || parsed.n_tasks > 16 {
        return Err(format!("trace task count {} out of range 1..=16", parsed.n_tasks));
    }
    let mut report = CheckReport {
        scenario: parsed.scenario.clone(),
        faults: parsed.faults,
        n_tasks: parsed.n_tasks,
        bug: parsed.bug,
        states: 0,
        exhausted: false,
        depth_pruned: 0,
        fuzz_schedules: 0,
        counterexample: None,
    };
    let init = match Model::new(&sc.cfg, parsed.n_tasks, parsed.faults, parsed.bug) {
        Ok(m) => m,
        Err(v) => {
            report.counterexample =
                Some(Counterexample { violation: v, events: Vec::new(), original_len: 0 });
            return Ok(report);
        }
    };
    let original_len = parsed.events.len();
    if let Some(violation) = trace::replay(&init, &parsed.events) {
        report.counterexample =
            Some(Counterexample { violation, events: parsed.events, original_len });
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_set_parses_and_displays() {
        let f = FaultSet::parse("steal,recall").unwrap();
        assert!(f.steal && f.recall && !f.cancel && !f.kill);
        assert_eq!(f.to_string(), "steal,recall");
        assert_eq!(FaultSet::parse("none").unwrap(), FaultSet::default());
        assert_eq!(FaultSet::default().to_string(), "none");
        assert_eq!(
            FaultSet::parse(&FaultSet::parse("kill,cancel").unwrap().to_string()).unwrap(),
            FaultSet::parse("cancel,kill").unwrap()
        );
        assert!(FaultSet::parse("explode").is_err());
    }

    #[test]
    fn seeded_bug_parses() {
        assert_eq!(SeededBug::parse("drop-returned").unwrap(), SeededBug::DropReturned { nth: 1 });
        assert_eq!(
            SeededBug::parse("drop-returned:3").unwrap(),
            SeededBug::DropReturned { nth: 3 }
        );
        assert!(SeededBug::parse("drop-returned:0").is_err());
        assert!(SeededBug::parse("segfault").is_err());
    }

    #[test]
    fn scenario_registry_resolves() {
        assert!(scenario("flat2").is_some());
        let deep = scenario("deep4").unwrap();
        assert!(deep.kill_ok);
        assert_eq!(deep.cfg.tree().roots.len(), 2);
        assert!(scenario("nope").is_none());
    }

    #[test]
    fn run_check_rejects_bad_usage() {
        let mut cfg = CheckConfig { scenario: "nope".to_string(), ..CheckConfig::default() };
        assert!(run_check(&cfg).is_err());
        cfg.scenario = "flat2".to_string();
        cfg.faults.kill = true;
        assert!(run_check(&cfg).is_err());
        cfg.faults.kill = false;
        cfg.n_tasks = 0;
        assert!(run_check(&cfg).is_err());
    }

    #[test]
    fn clean_flat2_exhausts_without_violation() {
        let cfg = CheckConfig {
            n_tasks: 2,
            seeds: 8,
            ..CheckConfig::default()
        };
        let report = run_check(&cfg).unwrap();
        assert!(report.passed(), "unexpected violation: {:?}", report.counterexample);
        assert!(report.exhausted, "state budget hit at {} states", report.states);
        assert!(report.states > 0);
        assert_eq!(report.fuzz_schedules, 8);
    }

    #[test]
    fn batched_hot_path_explores_clean() {
        // The batched2 scenario routes every dispatch through RunBatch
        // with dispatch_batch=2 and every ascent through the coalesced
        // Flush frame — the oracles must hold across all interleavings.
        let cfg = CheckConfig {
            scenario: "batched2".to_string(),
            n_tasks: 2,
            seeds: 8,
            ..CheckConfig::default()
        };
        let report = run_check(&cfg).unwrap();
        assert!(report.passed(), "unexpected violation: {:?}", report.counterexample);
        assert!(report.exhausted, "state budget hit at {} states", report.states);
        assert!(report.states > 0);
    }

    #[test]
    fn seeded_drop_returned_is_caught_and_minimized() {
        let cfg = CheckConfig {
            n_tasks: 2,
            faults: FaultSet { steal: true, cancel: false, recall: true, kill: false },
            bug: Some(SeededBug::DropReturned { nth: 1 }),
            seeds: 8,
            ..CheckConfig::default()
        };
        let report = run_check(&cfg).unwrap();
        let cex = report.counterexample.expect("seeded bug must be caught");
        assert_eq!(cex.violation.oracle, "conservation");
        assert!(!cex.events.is_empty());
        assert!(cex.events.len() <= cex.original_len);
        // The artifact round-trips and still reproduces on replay.
        let text = report.counterexample_trace().unwrap();
        let replayed = replay_trace_text(&text).unwrap();
        let rv = replayed.counterexample.expect("replay must reproduce");
        assert_eq!(rv.violation.oracle, "conservation");
    }

    #[test]
    fn fnv64_is_stable() {
        use std::hash::Hasher;
        let mut h = Fnv64::new();
        h.write(b"caravan");
        let a = h.finish();
        let mut h2 = Fnv64::new();
        h2.write(b"caravan");
        assert_eq!(a, h2.finish());
        let mut h3 = Fnv64::new();
        h3.write(b"caravan!");
        assert_ne!(a, h3.finish());
    }
}
