//! Invariant oracles over a [`Model`] snapshot. The step-wise oracles
//! ([`conservation`], [`credit_bound`]) run after every event; the
//! [`recall_quiescence`] oracle runs at graft time; [`terminal`] runs
//! when no event is enabled. The remaining oracles (double-grant,
//! duplicate-result, double-dispatch) have natural single detection
//! points and live inline in [`Model`]'s apply paths.

use crate::scheduler::protocol::ProtoMsg;

use super::{Model, Violation};

/// Tasks a message carries (`Results` count as their tasks: a result is
/// the task's terminal form travelling up to the engine).
fn msg_task_count(msg: &ProtoMsg) -> usize {
    match msg {
        ProtoMsg::Assign(ts) | ProtoMsg::Returned(ts) => ts.len(),
        ProtoMsg::Results(rs) => rs.len(),
        ProtoMsg::Flush { results, .. } => results.len(),
        ProtoMsg::StealGrant { tasks, .. } => tasks.len(),
        _ => 0,
    }
}

/// Task conservation: every submitted task is in exactly one place —
/// completed at the producer, pending at the producer, queued or stored
/// in a live node, running on a consumer, or inside an in-flight
/// message. Σ(all places) must equal the number submitted. This is the
/// paper's "no task is ever lost" claim, and the oracle that catches a
/// missing `on_returned` (dropped recall batch) or a dead link leaking
/// its outstanding grants.
pub(crate) fn conservation(m: &Model) -> Option<Violation> {
    let mut acc: u64 = m.producer.completed() + m.producer.pending_len() as u64;
    let mut queued: u64 = 0;
    let mut stored: u64 = 0;
    for st in m.nodes.iter().flatten() {
        queued += st.queue_len() as u64;
        stored += st.store_len() as u64;
    }
    acc += queued + stored;
    let mut running: u64 = 0;
    for slots in &m.running {
        running += slots.iter().map(|q| q.len() as u64).sum::<u64>();
    }
    acc += running;
    let mut in_flight: u64 = 0;
    for q in m.edges.values() {
        for msg in q {
            in_flight += msg_task_count(msg) as u64;
        }
    }
    acc += in_flight;
    if acc != m.n_tasks as u64 {
        Some(Violation::new(
            "conservation",
            format!(
                "accounted {acc} tasks but {} were submitted (completed {} + pending {} + \
                 queued {queued} + stored {stored} + running {running} + in-flight {in_flight})",
                m.n_tasks,
                m.producer.completed(),
                m.producer.pending_len(),
            ),
        ))
    } else {
        None
    }
}

/// Credit bound: no node's queue may exceed `credit_factor ×
/// subtree_consumers` — the flow-control property that keeps memory
/// bounded at every tree level (request amounts are `bound − level`, so
/// a correct protocol can never overshoot; the model runs with zero
/// retries, which is the only sanctioned source of transient overshoot
/// in the runtimes).
pub(crate) fn credit_bound(m: &Model) -> Option<Violation> {
    for (id, st) in m.nodes.iter().enumerate() {
        let Some(st) = st else { continue };
        if st.queue_len() > st.credit_bound() {
            return Some(Violation::new(
                "credit-bound",
                format!(
                    "node n{id} queued {} tasks, over its credit bound {}",
                    st.queue_len(),
                    st.credit_bound()
                ),
            ));
        }
    }
    None
}

/// Recall quiescence, checked at the all-acks moment (graft time): the
/// producer holds every root's ack, so the old tree must be provably
/// empty — nothing queued, stored or running at any live node, no task
/// or result still in flight, and no grant unaccounted for. A task
/// found here would be stranded below the recall root and silently lost
/// by the graft.
pub(crate) fn recall_quiescence(m: &Model) -> Option<Violation> {
    for (id, st) in m.nodes.iter().enumerate() {
        let Some(st) = st else { continue };
        if st.queue_len() > 0 || st.store_len() > 0 {
            return Some(Violation::new(
                "recall-quiescence",
                format!(
                    "all recall acks held, but node n{id} still has {} queued / {} stored",
                    st.queue_len(),
                    st.store_len()
                ),
            ));
        }
    }
    for (node, slots) in m.running.iter().enumerate() {
        for (consumer, q) in slots.iter().enumerate() {
            if let Some((t, _)) = q.front() {
                return Some(Violation::new(
                    "recall-quiescence",
                    format!(
                        "all recall acks held, but task {} is still running on \
                         n{node}/consumer {consumer}",
                        t.id
                    ),
                ));
            }
        }
    }
    for ((from, to), q) in &m.edges {
        for msg in q {
            if msg_task_count(msg) > 0 {
                return Some(Violation::new(
                    "recall-quiescence",
                    format!(
                        "all recall acks held, but {} task(s) are still in flight \
                         {from} -> {to}",
                        msg_task_count(msg)
                    ),
                ));
            }
        }
    }
    if let Some(&id) = m.granted_live.iter().next() {
        return Some(Violation::new(
            "recall-quiescence",
            format!("all recall acks held, but granted task {id} was never accounted back"),
        ));
    }
    None
}

/// End-state oracle, meaningful only when no event is enabled: the run
/// must have reached orderly shutdown with every task completed exactly
/// once. Anything else is a deadlock (progress wedged) or a lost /
/// multiplied task.
pub(crate) fn terminal(m: &Model) -> Option<Violation> {
    if !m.producer.shutdown_sent() {
        return Some(Violation::new(
            "deadlock",
            format!(
                "no event is enabled but shutdown never happened (completed {}/{}, \
                 pending {}, in-flight {})",
                m.producer.completed(),
                m.n_tasks,
                m.producer.pending_len(),
                m.producer.in_flight(),
            ),
        ));
    }
    if m.producer.completed() != m.n_tasks as u64 {
        return Some(Violation::new(
            "termination",
            format!(
                "run shut down with {} of {} tasks completed",
                m.producer.completed(),
                m.n_tasks
            ),
        ));
    }
    if m.results_seen.len() != m.n_tasks {
        return Some(Violation::new(
            "termination",
            format!(
                "run shut down but the engine saw results for {} of {} tasks",
                m.results_seen.len(),
                m.n_tasks
            ),
        ));
    }
    if let Some(&id) = m.granted_live.iter().next() {
        return Some(Violation::new(
            "termination",
            format!("run shut down with task {id} still granted into the tree"),
        ));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::super::{scenario, FaultSet};
    use super::*;
    use crate::scheduler::protocol::Party;
    use crate::tasklib::{Payload, TaskSpec};

    fn model() -> Model {
        let sc = scenario("flat2").expect("flat2 registered");
        Model::new(&sc.cfg, 2, FaultSet::default(), None).expect("clean init")
    }

    #[test]
    fn clean_init_passes_stepwise_oracles() {
        let m = model();
        assert!(conservation(&m).is_none());
        assert!(credit_bound(&m).is_none());
        assert!(recall_quiescence(&m).is_none());
    }

    #[test]
    fn conservation_catches_a_lost_task() {
        let mut m = model();
        // Pretend a third task was submitted that no ledger holds.
        m.n_tasks += 1;
        let v = conservation(&m).expect("must fire");
        assert_eq!(v.oracle, "conservation");
    }

    #[test]
    fn credit_bound_catches_an_overflowed_queue() {
        let mut m = model();
        // Forge an oversized grant straight onto the wire (bypassing the
        // producer), bumping n_tasks so conservation stays neutral and
        // the credit oracle is what fires.
        let extra: Vec<TaskSpec> = (100..110)
            .map(|id| TaskSpec::new(id, Payload::Sleep { seconds: 1.0 }))
            .collect();
        m.n_tasks += extra.len();
        let to = Party::Node(m.topo.roots[0]);
        m.edges
            .entry((Party::Producer, to))
            .or_default()
            .push_back(ProtoMsg::Assign(extra));
        assert!(conservation(&m).is_none());
        let ev = super::super::Event::Deliver { from: Party::Producer, to };
        m.apply(ev).expect("delivery itself is clean");
        let v = credit_bound(&m).expect("must fire");
        assert_eq!(v.oracle, "credit-bound");
    }

    #[test]
    fn quiescence_catches_an_in_flight_task() {
        let mut m = model();
        let to = Party::Node(m.topo.roots[0]);
        m.edges.entry((Party::Producer, to)).or_default().push_back(ProtoMsg::Assign(vec![
            TaskSpec::new(0, Payload::Sleep { seconds: 1.0 }),
        ]));
        let v = recall_quiescence(&m).expect("must fire");
        assert_eq!(v.oracle, "recall-quiescence");
    }

    #[test]
    fn terminal_on_unfinished_state_is_a_deadlock() {
        let m = model();
        let v = terminal(&m).expect("init is far from done");
        assert_eq!(v.oracle, "deadlock");
    }
}
