//! The hierarchical scheduler (the paper's Fig. 2, generalized to an
//! N-level buffer tree): a producer (rank 0), one or more buffer levels,
//! and consumer processes, realized as pure protocol state machines
//! ([`protocol`]) plus a threaded runtime ([`threads`]) that executes them
//! for real. The DES in [`crate::des`] runs the *same* protocol in virtual
//! time for K-computer-scale experiments, and [`net`] carries it across
//! real process boundaries: a serve loop on the producer side, remote
//! worker subtrees over TCP / Unix-domain links, and dead-link handling
//! that reuses the recall machinery.

pub mod metrics;
pub mod net;
pub mod protocol;
pub mod reshape;
pub mod threads;

pub use metrics::{
    BandWaitHist, ClassNodeStats, FillingRate, LevelFill, NodeStats, N_WAIT_BINS,
    WAIT_BUCKET_EDGES,
};
pub use net::{connect_worker, run_worker, serve_scheduler, ServeOptions, WorkerReport};
pub use protocol::{
    choose_shape, resolve_shape, route_buffer_actions, route_producer_actions, shaped_fanouts,
    LocalEffect, ModelStep, Party, PrioQueue, ProtoMsg, MAX_AUTO_DEPTH,
};
pub use reshape::{ReshapeController, ReshapeEvent};
pub use threads::{run_scheduler, CancelSet, ExecOutcome, Executor, Report, SleepExecutor};
