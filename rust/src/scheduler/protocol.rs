//! The scheduling protocol — pure state machines for the producer and
//! buffer roles (Fig. 2 of the paper).
//!
//! CARAVAN's scheduler is a producer–consumer pattern with a *buffered
//! layer*: the rank-0 producer talks only to a few hundred buffer
//! processes; each buffer owns a task queue and feeds its own set of
//! consumers "gradually", and batches results on the way back so the
//! producer is never overwhelmed.
//!
//! The state machines here are *execution-agnostic*: the threaded runtime
//! ([`super::threads`]) drives them with real channels, and the
//! discrete-event simulator ([`crate::des`]) drives them in virtual time.
//! Every statement the benchmarks make about scaling is therefore a
//! statement about this exact code path.
//!
//! Flow control is demand-driven on both levels:
//!
//! * a buffer requests work from the producer whenever its queue (plus the
//!   in-flight request) drops below its consumer count, asking for enough
//!   to restore `credit_factor ×` its consumer count;
//! * a consumer implicitly requests work by reporting `Done`; the buffer
//!   replies with the next queued task or marks it idle.
//!
//! Results are buffered per the paper: a buffer flushes its result store to
//! the producer when it reaches `flush_every`, or immediately when the
//! buffer has nothing queued (so dynamically-generated workloads — TC3,
//! optimization loops — never stall waiting for a batch to fill).

use crate::tasklib::{TaskResult, TaskSpec};
use std::collections::VecDeque;

/// Actions the producer asks its runtime to carry out.
#[derive(Clone, Debug, PartialEq)]
pub enum ProducerAction {
    /// Send these tasks to buffer `buffer`.
    SendTasks { buffer: usize, tasks: Vec<TaskSpec> },
    /// All work is done: tell every buffer to shut down.
    BroadcastShutdown,
}

/// Actions a buffer asks its runtime to carry out.
#[derive(Clone, Debug, PartialEq)]
pub enum BufferAction {
    /// Start `task` on local consumer index `consumer`.
    RunOn { consumer: usize, task: TaskSpec },
    /// Ask the producer for up to `amount` more tasks.
    RequestTasks { amount: usize },
    /// Ship these results back to the producer.
    FlushResults(Vec<TaskResult>),
    /// Tell all local consumers to stop.
    ShutdownConsumers,
}

/// Producer (rank 0) state: the global pending-task queue plus which
/// buffers are waiting for work.
#[derive(Debug)]
pub struct ProducerState {
    pending: VecDeque<TaskSpec>,
    /// `deficit[b]` = number of tasks buffer `b` asked for but hasn't received.
    deficit: Vec<usize>,
    /// Round-robin cursor so replenishment is fair across buffers.
    cursor: usize,
    submitted: u64,
    completed: u64,
    engine_done: bool,
    shutdown_sent: bool,
    /// Message-count instrumentation (drives the buffered-layer ablation).
    pub msgs_in: u64,
    pub msgs_out: u64,
}

impl ProducerState {
    pub fn new(num_buffers: usize) -> Self {
        assert!(num_buffers > 0);
        Self {
            pending: VecDeque::new(),
            deficit: vec![0; num_buffers],
            cursor: 0,
            submitted: 0,
            completed: 0,
            engine_done: false,
            shutdown_sent: false,
            msgs_in: 0,
            msgs_out: 0,
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn in_flight(&self) -> u64 {
        self.submitted - self.completed
    }

    /// Engine submitted new tasks: enqueue and satisfy outstanding deficits.
    pub fn push_tasks(&mut self, tasks: Vec<TaskSpec>) -> Vec<ProducerAction> {
        self.submitted += tasks.len() as u64;
        self.pending.extend(tasks);
        self.satisfy_deficits()
    }

    /// A buffer asked for `amount` more tasks.
    pub fn on_request(&mut self, buffer: usize, amount: usize) -> Vec<ProducerAction> {
        self.msgs_in += 1;
        self.deficit[buffer] = self.deficit[buffer].saturating_add(amount);
        self.satisfy_deficits()
    }

    /// A buffer flushed `n_results` results (the runtime hands the actual
    /// values to the engine); tracked here for termination detection.
    pub fn on_results(&mut self, n_results: usize) {
        self.msgs_in += 1;
        self.completed += n_results as u64;
    }

    /// The engine has no further unprompted tasks. (It may still create
    /// tasks from completion callbacks — termination triggers only when
    /// nothing is pending or in flight.)
    pub fn set_engine_done(&mut self, done: bool) {
        self.engine_done = done;
    }

    /// True once every submitted task completed and nothing is pending.
    pub fn is_quiescent(&self) -> bool {
        self.engine_done && self.pending.is_empty() && self.in_flight() == 0
    }

    /// Emit the shutdown broadcast exactly once, when quiescent.
    pub fn maybe_shutdown(&mut self) -> Vec<ProducerAction> {
        if self.is_quiescent() && !self.shutdown_sent {
            self.shutdown_sent = true;
            self.msgs_out += self.deficit.len() as u64;
            vec![ProducerAction::BroadcastShutdown]
        } else {
            Vec::new()
        }
    }

    fn satisfy_deficits(&mut self) -> Vec<ProducerAction> {
        // Fairness under scarcity: when fewer tasks are pending than the
        // total outstanding deficit, granting each buffer its full credit
        // first-come-first-served would leave later buffers (and their
        // hundreds of consumers) starved. Grant in bounded chunks, round-
        // robin, until tasks or deficits run out — the paper's "repeatedly
        // send them to their consumers gradually", applied one level up.
        const GRANT_CHUNK: usize = 32;
        let nb = self.deficit.len();
        let mut granted: Vec<Vec<TaskSpec>> = vec![Vec::new(); nb];
        let mut scanned = 0;
        while !self.pending.is_empty() && scanned < nb {
            let b = self.cursor;
            self.cursor = (self.cursor + 1) % nb;
            scanned += 1;
            if self.deficit[b] == 0 {
                continue;
            }
            let take = self.deficit[b].min(GRANT_CHUNK).min(self.pending.len());
            granted[b].extend(self.pending.drain(..take));
            self.deficit[b] -= take;
            scanned = 0; // keep scanning while anyone still has deficit
        }
        let mut out = Vec::new();
        for (b, tasks) in granted.into_iter().enumerate() {
            if !tasks.is_empty() {
                self.msgs_out += 1;
                out.push(ProducerAction::SendTasks { buffer: b, tasks });
            }
        }
        out
    }
}

/// Buffer state: local task queue, idle-consumer list, result store.
#[derive(Debug)]
pub struct BufferState {
    n_consumers: usize,
    queue: VecDeque<TaskSpec>,
    idle: VecDeque<usize>,
    store: Vec<TaskResult>,
    /// Tasks requested from the producer but not yet received.
    outstanding_request: usize,
    credit_factor: usize,
    flush_every: usize,
    shutting_down: bool,
    pub msgs_in: u64,
    pub msgs_out: u64,
}

impl BufferState {
    pub fn new(n_consumers: usize, credit_factor: usize, flush_every: usize) -> Self {
        assert!(n_consumers > 0);
        Self {
            n_consumers,
            queue: VecDeque::new(),
            idle: (0..n_consumers).collect(),
            store: Vec::new(),
            outstanding_request: 0,
            credit_factor: credit_factor.max(1),
            flush_every: flush_every.max(1),
            shutting_down: false,
            msgs_in: 0,
            msgs_out: 0,
        }
    }

    pub fn n_consumers(&self) -> usize {
        self.n_consumers
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn idle_count(&self) -> usize {
        self.idle.len()
    }

    pub fn busy_count(&self) -> usize {
        self.n_consumers - self.idle.len()
    }

    pub fn store_len(&self) -> usize {
        self.store.len()
    }

    /// Startup: prime the pump by requesting a full credit of tasks.
    pub fn on_start(&mut self) -> Vec<BufferAction> {
        self.request_if_low()
    }

    /// Tasks arrived from the producer.
    pub fn on_assign(&mut self, tasks: Vec<TaskSpec>) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.outstanding_request = self.outstanding_request.saturating_sub(tasks.len().max(1));
        self.queue.extend(tasks);
        let mut out = self.dispatch_idle();
        out.extend(self.request_if_low());
        out
    }

    /// A local consumer finished a task (and is implicitly asking for more).
    pub fn on_done(&mut self, consumer: usize, result: TaskResult) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.store.push(result);
        let mut out = Vec::new();
        if let Some(task) = self.queue.pop_front() {
            self.msgs_out += 1;
            out.push(BufferAction::RunOn { consumer, task });
        } else {
            self.idle.push_back(consumer);
        }
        out.extend(self.request_if_low());
        out.extend(self.flush_if_due());
        if self.shutting_down && self.busy_count() == 0 {
            out.extend(self.final_flush());
        }
        out
    }

    /// Producer announced shutdown. Consumers still running finish first;
    /// the final flush happens when the last one reports in.
    pub fn on_shutdown(&mut self) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.shutting_down = true;
        if self.busy_count() == 0 {
            self.final_flush()
        } else {
            Vec::new()
        }
    }

    /// Periodic tick from the runtime (threaded mode): flush any results
    /// that have been sitting in the store.
    pub fn on_tick(&mut self) -> Vec<BufferAction> {
        if self.store.is_empty() {
            Vec::new()
        } else {
            self.flush_now()
        }
    }

    fn dispatch_idle(&mut self) -> Vec<BufferAction> {
        let mut out = Vec::new();
        while !self.queue.is_empty() && !self.idle.is_empty() {
            let consumer = self.idle.pop_front().unwrap();
            let task = self.queue.pop_front().unwrap();
            self.msgs_out += 1;
            out.push(BufferAction::RunOn { consumer, task });
        }
        out
    }

    fn request_if_low(&mut self) -> Vec<BufferAction> {
        if self.shutting_down {
            return Vec::new();
        }
        let level = self.queue.len() + self.outstanding_request;
        if level < self.n_consumers {
            let target = self.credit_factor * self.n_consumers;
            let amount = target - level;
            self.outstanding_request += amount;
            self.msgs_out += 1;
            vec![BufferAction::RequestTasks { amount }]
        } else {
            Vec::new()
        }
    }

    fn flush_if_due(&mut self) -> Vec<BufferAction> {
        // Flush on batch-full, or as soon as there is nothing queued locally
        // (dynamic workloads need results to reach the engine promptly).
        if self.store.len() >= self.flush_every || (self.queue.is_empty() && !self.store.is_empty())
        {
            self.flush_now()
        } else {
            Vec::new()
        }
    }

    fn flush_now(&mut self) -> Vec<BufferAction> {
        self.msgs_out += 1;
        vec![BufferAction::FlushResults(std::mem::take(&mut self.store))]
    }

    fn final_flush(&mut self) -> Vec<BufferAction> {
        let mut out = Vec::new();
        if !self.store.is_empty() {
            out.extend(self.flush_now());
        }
        self.msgs_out += 1;
        out.push(BufferAction::ShutdownConsumers);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::Payload;

    fn task(id: u64) -> TaskSpec {
        TaskSpec::new(id, Payload::Sleep { seconds: 1.0 })
    }

    fn result(id: u64, consumer: usize) -> TaskResult {
        TaskResult { id, consumer, results: vec![], begin: 0.0, finish: 1.0, rc: 0 }
    }

    #[test]
    fn producer_satisfies_requests_in_round_robin() {
        let mut p = ProducerState::new(2);
        assert!(p.on_request(0, 3).is_empty()); // nothing pending yet
        assert!(p.on_request(1, 3).is_empty());
        let acts = p.push_tasks((0..4).map(task).collect());
        // 4 tasks split across the two deficits, fairness via round-robin.
        let mut granted = [0usize; 2];
        for a in &acts {
            if let ProducerAction::SendTasks { buffer, tasks } = a {
                granted[*buffer] += tasks.len();
            }
        }
        assert_eq!(granted[0] + granted[1], 4);
        assert!(granted[0] > 0 && granted[1] > 0, "{granted:?}");
        assert_eq!(p.pending_len(), 0);
        assert_eq!(p.in_flight(), 4);
    }

    #[test]
    fn producer_queues_tasks_without_deficit() {
        let mut p = ProducerState::new(1);
        let acts = p.push_tasks(vec![task(0)]);
        assert!(acts.is_empty());
        assert_eq!(p.pending_len(), 1);
        let acts = p.on_request(0, 10);
        assert_eq!(acts.len(), 1);
        assert_eq!(p.pending_len(), 0);
    }

    #[test]
    fn producer_shutdown_only_when_quiescent_and_once() {
        let mut p = ProducerState::new(1);
        p.push_tasks(vec![task(0)]);
        p.set_engine_done(true);
        assert!(p.maybe_shutdown().is_empty()); // pending
        p.on_request(0, 1);
        assert!(p.maybe_shutdown().is_empty()); // in flight
        p.on_results(1);
        assert_eq!(p.maybe_shutdown(), vec![ProducerAction::BroadcastShutdown]);
        assert!(p.maybe_shutdown().is_empty()); // idempotent
    }

    #[test]
    fn buffer_requests_on_start_and_dispatches_on_assign() {
        let mut b = BufferState::new(4, 2, 100);
        let acts = b.on_start();
        assert_eq!(acts, vec![BufferAction::RequestTasks { amount: 8 }]);
        let acts = b.on_assign((0..8).map(task).collect());
        let runs = acts
            .iter()
            .filter(|a| matches!(a, BufferAction::RunOn { .. }))
            .count();
        assert_eq!(runs, 4); // all four consumers started
        assert_eq!(b.queue_len(), 4);
        assert_eq!(b.idle_count(), 0);
    }

    #[test]
    fn buffer_done_feeds_next_task_and_requests_when_low() {
        let mut b = BufferState::new(2, 2, 100);
        b.on_start();
        b.on_assign(vec![task(0), task(1), task(2)]);
        // queue=1, outstanding=1 (asked 4, got 3): level 2 == n_consumers, no request.
        let acts = b.on_done(0, result(0, 0));
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RunOn { consumer: 0, .. })));
        // After dispatch queue=0, level=1 < 2 → request to restore credit 4.
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RequestTasks { amount: 3 })));
        // Queue empty → results flush immediately.
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 1)));
    }

    #[test]
    fn buffer_batches_results_while_queue_nonempty() {
        let mut b = BufferState::new(1, 8, 3);
        b.on_start();
        b.on_assign((0..8).map(task).collect());
        // Two completions: queue still nonempty, store below flush_every → no flush.
        let a1 = b.on_done(0, result(0, 0));
        assert!(!a1.iter().any(|a| matches!(a, BufferAction::FlushResults(_))));
        let a2 = b.on_done(0, result(1, 0));
        assert!(!a2.iter().any(|a| matches!(a, BufferAction::FlushResults(_))));
        // Third completion hits flush_every = 3.
        let a3 = b.on_done(0, result(2, 0));
        assert!(a3
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 3)));
    }

    #[test]
    fn buffer_shutdown_waits_for_running_consumers() {
        let mut b = BufferState::new(2, 1, 100);
        b.on_start();
        b.on_assign(vec![task(0), task(1)]);
        let acts = b.on_shutdown();
        assert!(acts.is_empty(), "must wait for busy consumers");
        b.on_done(0, result(0, 0));
        let acts = b.on_done(1, result(1, 1));
        assert!(acts.iter().any(|a| matches!(a, BufferAction::ShutdownConsumers)));
        // All results eventually flushed.
        let flushed: usize = acts
            .iter()
            .filter_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.len()),
                _ => None,
            })
            .sum();
        assert!(flushed >= 1);
    }

    #[test]
    fn buffer_tick_flushes_stale_results() {
        let mut b = BufferState::new(1, 4, 100);
        b.on_start();
        b.on_assign((0..4).map(task).collect());
        b.on_done(0, result(0, 0));
        assert_eq!(b.store_len(), 1);
        let acts = b.on_tick();
        assert!(acts.iter().any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 1)));
        assert_eq!(b.store_len(), 0);
        assert!(b.on_tick().is_empty());
    }

    #[test]
    fn no_task_lost_or_duplicated_through_buffer() {
        // Property-style: drive a buffer with random assign/done interleavings
        // and check conservation: every assigned task is run exactly once.
        use crate::testutil::{check, pair, usize_in, u64_in};
        check(
            "buffer conserves tasks",
            pair(usize_in(1..6), u64_in(1..40)),
            |&(nc, n_tasks)| {
                let mut b = BufferState::new(nc, 2, 5);
                b.on_start();
                let mut running: Vec<(usize, u64)> = Vec::new();
                let mut ran: Vec<u64> = Vec::new();
                let mut next = 0u64;
                let mut actions = b.on_assign((0..n_tasks.min(7)).map(task).collect());
                next += n_tasks.min(7);
                loop {
                    for a in actions.drain(..) {
                        if let BufferAction::RunOn { consumer, task } = a {
                            running.push((consumer, task.id));
                        }
                    }
                    if let Some((c, id)) = running.pop() {
                        ran.push(id);
                        actions = b.on_done(c, result(id, c));
                        if next < n_tasks {
                            let push = (n_tasks - next).min(3);
                            let mut more = b.on_assign((next..next + push).map(task).collect());
                            next += push;
                            actions.append(&mut more);
                        }
                    } else if next < n_tasks {
                        let push = (n_tasks - next).min(3);
                        actions = b.on_assign((next..next + push).map(task).collect());
                        next += push;
                    } else {
                        break;
                    }
                }
                ran.sort();
                ran.dedup();
                ran.len() as u64 == n_tasks
            },
        );
    }
}
