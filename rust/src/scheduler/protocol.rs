//! The scheduling protocol — pure state machines for the producer and
//! buffer roles (Fig. 2 of the paper), generalized to an N-level tree.
//!
//! CARAVAN's scheduler is a producer–consumer pattern with a *buffered
//! layer*: the rank-0 producer talks only to a few hundred buffer
//! processes; each buffer owns a task queue and feeds its own set of
//! consumers "gradually", and batches results on the way back so the
//! producer is never overwhelmed.
//!
//! The seed reproduced the paper's fixed two-party shape; this module
//! generalizes the buffer role so a buffer's children may be *consumers*
//! (a leaf, the original role) or *other buffers* (an interior relay).
//! Stacking relay levels bounds the fan-in at every node — the producer
//! talks to `O(fanout)` children instead of to every buffer, which is what
//! keeps rank 0 off the critical path at 10⁴–10⁵ consumers.
//!
//! The state machines here are *execution-agnostic*: the threaded runtime
//! ([`super::threads`]) drives them with real channels, and the
//! discrete-event simulator ([`crate::des`]) drives them in virtual time.
//! Every statement the benchmarks make about scaling is therefore a
//! statement about this exact code path.
//!
//! Flow control is demand-driven at every level:
//!
//! * a buffer node requests work from its parent whenever its local level
//!   (queue + outstanding requests) drops below its subtree's consumer
//!   count, asking for enough to restore `credit_factor ×` that count;
//! * a consumer implicitly requests work by reporting `Done`; an interior
//!   child explicitly requests with `on_child_request`;
//! * optionally, a starved node first tries to *steal* queued tasks from a
//!   sibling (victim per [`StealPolicy`]; the victim surrenders up to half
//!   its queue) and only escalates to the parent when the steal comes back
//!   empty — sideways moves are invisible to the parent's accounting.
//!
//! Results are buffered per the paper: a node flushes its result store to
//! its parent when it reaches `flush_every`, or immediately when the node
//! has nothing queued (so dynamically-generated workloads — TC3,
//! optimization loops — never stall waiting for a batch to fill).
//!
//! Job API v2 semantics live here so both runtimes inherit them:
//!
//! * every queue ([`PrioQueue`]) is **priority-ordered** — higher
//!   [`TaskSpec::priority`] first, FIFO within a level, and steals take
//!   the lowest-priority (coldest) tasks from the victim's back;
//! * **retry**: a leaf remembers which spec each consumer is running; an
//!   attempt finishing with `rc != 0` while retries remain is re-queued
//!   transparently (the producer never sees the failed attempt), and the
//!   final [`TaskResult`] carries the attempt index;
//! * **cancellation**: `on_cancel` drops the task from the local queue if
//!   present — synthesizing an `RC_CANCELLED` result that flows upstream
//!   like any other, so conservation and termination detection are
//!   untouched — and otherwise forwards the notice toward the leaves.

use super::metrics::NodeStats;
use crate::config::{SchedulerConfig, StealPolicy, TreeNodeKind, TreeTopology};
use crate::tasklib::{TaskId, TaskResult, TaskSpec, RC_CANCELLED};
use std::cmp::Reverse;
use std::collections::{BTreeMap, VecDeque};

/// A priority-ordered task queue: pop returns the highest-priority,
/// earliest-submitted task; the "back" (what sibling steals take) is the
/// lowest-priority, latest-submitted end.
#[derive(Debug, Default)]
pub struct PrioQueue {
    map: BTreeMap<(Reverse<u8>, u64), TaskSpec>,
    seq: u64,
}

impl PrioQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn push(&mut self, task: TaskSpec) {
        self.seq += 1;
        self.map.insert((Reverse(task.priority), self.seq), task);
    }

    pub fn extend(&mut self, tasks: Vec<TaskSpec>) {
        for t in tasks {
            self.push(t);
        }
    }

    /// Highest priority, FIFO within a priority level.
    pub fn pop(&mut self) -> Option<TaskSpec> {
        self.map.pop_first().map(|(_, t)| t)
    }

    /// Up to `n` tasks off the front (priority order).
    pub fn pop_n(&mut self, n: usize) -> Vec<TaskSpec> {
        let mut out = Vec::with_capacity(n.min(self.map.len()));
        for _ in 0..n {
            match self.map.pop_first() {
                Some((_, t)) => out.push(t),
                None => break,
            }
        }
        out
    }

    /// Up to `n` tasks off the back — the coldest work, surrendered to
    /// sibling steals.
    pub fn take_back(&mut self, n: usize) -> Vec<TaskSpec> {
        let mut out = Vec::with_capacity(n.min(self.map.len()));
        for _ in 0..n {
            match self.map.pop_last() {
                Some((_, t)) => out.push(t),
                None => break,
            }
        }
        out.reverse();
        out
    }

    /// Remove the task with the given id, if queued here.
    pub fn remove(&mut self, id: TaskId) -> Option<TaskSpec> {
        let key = self.map.iter().find(|(_, t)| t.id == id).map(|(k, _)| *k)?;
        self.map.remove(&key)
    }
}

/// Actions the producer asks its runtime to carry out.
#[derive(Clone, Debug, PartialEq)]
pub enum ProducerAction {
    /// Send these tasks to child `buffer` (slot index among the producer's
    /// direct children — the level-1 nodes of the tree).
    SendTasks { buffer: usize, tasks: Vec<TaskSpec> },
    /// Forward a cancellation notice to every child (the producer does not
    /// know where — or whether — the task is queued).
    BroadcastCancel { id: TaskId },
    /// All work is done: tell every child to shut down.
    BroadcastShutdown,
}

/// Actions a buffer node asks its runtime to carry out.
#[derive(Clone, Debug, PartialEq)]
pub enum BufferAction {
    /// Leaf: start `task` on local consumer index `consumer`.
    RunOn { consumer: usize, task: TaskSpec },
    /// Interior: forward these tasks to child slot `child`.
    SendToChild { child: usize, tasks: Vec<TaskSpec> },
    /// Ask the parent for up to `amount` more tasks.
    RequestTasks { amount: usize },
    /// Ship these results to the parent.
    FlushResults(Vec<TaskResult>),
    /// Ask sibling slot `victim` (within the shared parent) for queued
    /// tasks. `thief` in the reply is an opaque token echoed back by the
    /// victim — the runtime chooses what it routes by.
    StealRequest { victim: usize, amount: usize },
    /// Reply to a steal request; `tasks` may be empty. `from_slot` is the
    /// victim's own slot and `left` its remaining queue depth — the thief
    /// uses them to maintain its victim-selection estimates.
    StealGrant { thief: usize, from_slot: usize, left: usize, tasks: Vec<TaskSpec> },
    /// Interior: forward a cancellation notice to all children.
    CancelChildren { id: TaskId },
    /// Leaf: tell all local consumers to stop.
    ShutdownConsumers,
    /// Interior: forward the shutdown notice to all children.
    ShutdownChildren,
}

/// Producer (rank 0) state: the global pending-task queue plus which
/// children are waiting for work.
#[derive(Debug)]
pub struct ProducerState {
    pending: PrioQueue,
    /// `deficit[b]` = number of tasks child `b` asked for but hasn't received.
    deficit: Vec<usize>,
    /// Round-robin cursor so replenishment is fair across children.
    cursor: usize,
    submitted: u64,
    completed: u64,
    cancelled: u64,
    engine_done: bool,
    shutdown_sent: bool,
    /// Message-count instrumentation (drives the buffered-layer ablation).
    pub msgs_in: u64,
    pub msgs_out: u64,
}

impl ProducerState {
    pub fn new(num_buffers: usize) -> Self {
        assert!(num_buffers > 0);
        Self {
            pending: PrioQueue::new(),
            deficit: vec![0; num_buffers],
            cursor: 0,
            submitted: 0,
            completed: 0,
            cancelled: 0,
            engine_done: false,
            shutdown_sent: false,
            msgs_in: 0,
            msgs_out: 0,
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Tasks dropped by cancellation while still pending at the producer.
    pub fn cancelled_pending(&self) -> u64 {
        self.cancelled
    }

    pub fn in_flight(&self) -> u64 {
        self.submitted - self.completed
    }

    /// Engine submitted new tasks: enqueue and satisfy outstanding deficits.
    pub fn push_tasks(&mut self, tasks: Vec<TaskSpec>) -> Vec<ProducerAction> {
        self.submitted += tasks.len() as u64;
        self.pending.extend(tasks);
        self.satisfy_deficits()
    }

    /// A child asked for `amount` more tasks.
    pub fn on_request(&mut self, buffer: usize, amount: usize) -> Vec<ProducerAction> {
        self.msgs_in += 1;
        self.deficit[buffer] = self.deficit[buffer].saturating_add(amount);
        self.satisfy_deficits()
    }

    /// A child flushed `n_results` results (the runtime hands the actual
    /// values to the engine); tracked here for termination detection.
    /// Cancelled tasks dropped inside the tree arrive through this same
    /// path, so conservation is untouched.
    pub fn on_results(&mut self, n_results: usize) {
        self.msgs_in += 1;
        self.completed += n_results as u64;
    }

    /// The engine asked to cancel `id`. If the task is still pending here
    /// it is dropped and returned — the runtime synthesizes the
    /// `RC_CANCELLED` result for the engine; the drop already counts as a
    /// completion. Otherwise the notice is broadcast down the tree.
    pub fn on_cancel(&mut self, id: TaskId) -> (Option<TaskSpec>, Vec<ProducerAction>) {
        if let Some(spec) = self.pending.remove(id) {
            self.completed += 1;
            self.cancelled += 1;
            (Some(spec), Vec::new())
        } else {
            self.msgs_out += self.deficit.len() as u64;
            (None, vec![ProducerAction::BroadcastCancel { id }])
        }
    }

    /// The engine has no further unprompted tasks. (It may still create
    /// tasks from completion callbacks — termination triggers only when
    /// nothing is pending or in flight.)
    pub fn set_engine_done(&mut self, done: bool) {
        self.engine_done = done;
    }

    /// True once every submitted task completed and nothing is pending.
    pub fn is_quiescent(&self) -> bool {
        self.engine_done && self.pending.is_empty() && self.in_flight() == 0
    }

    /// Emit the shutdown broadcast exactly once, when quiescent.
    pub fn maybe_shutdown(&mut self) -> Vec<ProducerAction> {
        if self.is_quiescent() && !self.shutdown_sent {
            self.shutdown_sent = true;
            self.msgs_out += self.deficit.len() as u64;
            vec![ProducerAction::BroadcastShutdown]
        } else {
            Vec::new()
        }
    }

    fn satisfy_deficits(&mut self) -> Vec<ProducerAction> {
        // Fairness under scarcity: when fewer tasks are pending than the
        // total outstanding deficit, granting each child its full credit
        // first-come-first-served would leave later children (and their
        // hundreds of consumers) starved. Grant in bounded chunks, round-
        // robin, until tasks or deficits run out — the paper's "repeatedly
        // send them to their consumers gradually", applied one level up.
        // Grants pop the pending queue in priority order, so the highest-
        // priority work reaches the tree first.
        const GRANT_CHUNK: usize = 32;
        let nb = self.deficit.len();
        let mut granted: Vec<Vec<TaskSpec>> = vec![Vec::new(); nb];
        let mut scanned = 0;
        while !self.pending.is_empty() && scanned < nb {
            let b = self.cursor;
            self.cursor = (self.cursor + 1) % nb;
            scanned += 1;
            if self.deficit[b] == 0 {
                continue;
            }
            let take = self.deficit[b].min(GRANT_CHUNK).min(self.pending.len());
            granted[b].extend(self.pending.pop_n(take));
            self.deficit[b] -= take;
            scanned = 0; // keep scanning while anyone still has deficit
        }
        let mut out = Vec::new();
        for (b, tasks) in granted.into_iter().enumerate() {
            if !tasks.is_empty() {
                self.msgs_out += 1;
                out.push(ProducerAction::SendTasks { buffer: b, tasks });
            }
        }
        out
    }
}

/// What a buffer node feeds: consumers (leaf) or child buffers (interior).
/// A leaf remembers which spec each consumer is executing so failed
/// attempts can be retried transparently.
#[derive(Debug)]
enum Children {
    Consumers { n: usize, idle: VecDeque<usize>, running: Vec<Option<TaskSpec>> },
    Buffers { deficit: Vec<usize>, cursor: usize, subtree: usize },
}

/// Buffer-node state: local task queue, children, result store, and the
/// demand-driven credit held against the parent.
#[derive(Debug)]
pub struct BufferState {
    children: Children,
    queue: PrioQueue,
    store: Vec<TaskResult>,
    /// Tasks requested from the parent but not yet received.
    outstanding_request: usize,
    /// Tasks requested from a sibling (steal) but not yet answered.
    steal_outstanding: usize,
    /// True after an unanswered-or-failed steal attempt; cleared whenever
    /// new tasks arrive. Starts true so startup credit goes to the parent.
    steal_tried: bool,
    steal_enabled: bool,
    steal_policy: StealPolicy,
    /// Last known queue depth per sibling slot (`usize::MAX` = unknown),
    /// maintained from steal replies and incoming steal requests.
    sibling_depth: Vec<usize>,
    my_slot: usize,
    n_siblings: usize,
    steal_cursor: usize,
    credit_factor: usize,
    flush_every: usize,
    shutting_down: bool,
    max_queue: usize,
    pub steals_attempted: u64,
    /// Steal attempts answered with an empty grant.
    pub steals_failed: u64,
    /// Tasks gained from siblings.
    pub steals_received: u64,
    /// Tasks surrendered to siblings.
    pub steals_given: u64,
    /// Queued tasks dropped here by cancellation.
    pub cancelled_dropped: u64,
    /// Failed attempts transparently re-queued here.
    pub retried: u64,
    pub msgs_in: u64,
    pub msgs_out: u64,
}

impl BufferState {
    /// A leaf buffer feeding `n_consumers` consumers (stealing disabled) —
    /// the original two-level role.
    pub fn new(n_consumers: usize, credit_factor: usize, flush_every: usize) -> Self {
        assert!(n_consumers > 0);
        Self {
            children: Children::Consumers {
                n: n_consumers,
                idle: (0..n_consumers).collect(),
                running: vec![None; n_consumers],
            },
            queue: PrioQueue::new(),
            store: Vec::new(),
            outstanding_request: 0,
            steal_outstanding: 0,
            steal_tried: true,
            steal_enabled: false,
            steal_policy: StealPolicy::DeepestQueue,
            sibling_depth: Vec::new(),
            my_slot: 0,
            n_siblings: 0,
            steal_cursor: 0,
            credit_factor: credit_factor.max(1),
            flush_every: flush_every.max(1),
            shutting_down: false,
            max_queue: 0,
            steals_attempted: 0,
            steals_failed: 0,
            steals_received: 0,
            steals_given: 0,
            cancelled_dropped: 0,
            retried: 0,
            msgs_in: 0,
            msgs_out: 0,
        }
    }

    /// An interior relay node with `n_children` child buffers covering
    /// `subtree_consumers` consumers in total.
    pub fn interior(
        n_children: usize,
        subtree_consumers: usize,
        credit_factor: usize,
        flush_every: usize,
    ) -> Self {
        assert!(n_children > 0 && subtree_consumers > 0);
        Self {
            children: Children::Buffers {
                deficit: vec![0; n_children],
                cursor: 0,
                subtree: subtree_consumers,
            },
            queue: PrioQueue::new(),
            store: Vec::new(),
            outstanding_request: 0,
            steal_outstanding: 0,
            steal_tried: true,
            steal_enabled: false,
            steal_policy: StealPolicy::DeepestQueue,
            sibling_depth: Vec::new(),
            my_slot: 0,
            n_siblings: 0,
            steal_cursor: 0,
            credit_factor: credit_factor.max(1),
            flush_every: flush_every.max(1),
            shutting_down: false,
            max_queue: 0,
            steals_attempted: 0,
            steals_failed: 0,
            steals_received: 0,
            steals_given: 0,
            cancelled_dropped: 0,
            retried: 0,
            msgs_in: 0,
            msgs_out: 0,
        }
    }

    /// Enable sibling work stealing. `my_slot` is this node's index among
    /// its parent's `n_siblings + 1` children.
    pub fn with_stealing(mut self, my_slot: usize, n_siblings: usize, policy: StealPolicy) -> Self {
        self.steal_enabled = n_siblings > 0;
        self.steal_policy = policy;
        self.my_slot = my_slot;
        self.n_siblings = n_siblings;
        self.steal_cursor = my_slot;
        self.sibling_depth = vec![usize::MAX; n_siblings + 1];
        self
    }

    /// Build the protocol state for tree node `id` — the single
    /// constructor both runtimes (threads, DES) use, so they can never
    /// disagree on a node's role, credit, or steal wiring.
    pub fn for_tree_node(topo: &TreeTopology, id: usize, cfg: &SchedulerConfig) -> Self {
        let n = &topo.nodes[id];
        let state = match &n.kind {
            TreeNodeKind::Leaf { n_consumers, .. } => {
                BufferState::new(*n_consumers, cfg.credit_factor, cfg.flush_every)
            }
            TreeNodeKind::Interior { children } => BufferState::interior(
                children.len(),
                n.subtree_consumers,
                cfg.credit_factor,
                cfg.flush_every,
            ),
        };
        if cfg.steal {
            state.with_stealing(n.slot, n.n_siblings, cfg.steal_policy)
        } else {
            state
        }
    }

    pub fn is_leaf(&self) -> bool {
        matches!(self.children, Children::Consumers { .. })
    }

    /// Local consumers (0 for interior nodes).
    pub fn n_consumers(&self) -> usize {
        match &self.children {
            Children::Consumers { n, .. } => *n,
            Children::Buffers { .. } => 0,
        }
    }

    /// Consumers in this node's subtree — the unit its credit is sized in.
    pub fn subtree_consumers(&self) -> usize {
        match &self.children {
            Children::Consumers { n, .. } => *n,
            Children::Buffers { subtree, .. } => *subtree,
        }
    }

    /// Upper bound the local queue is allowed to reach.
    pub fn credit_bound(&self) -> usize {
        self.credit_factor * self.subtree_consumers()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    pub fn idle_count(&self) -> usize {
        match &self.children {
            Children::Consumers { idle, .. } => idle.len(),
            Children::Buffers { .. } => 0,
        }
    }

    pub fn busy_count(&self) -> usize {
        match &self.children {
            Children::Consumers { n, idle, .. } => n - idle.len(),
            Children::Buffers { .. } => 0,
        }
    }

    pub fn store_len(&self) -> usize {
        self.store.len()
    }

    pub fn is_shutting_down(&self) -> bool {
        self.shutting_down
    }

    /// Counter snapshot for reports (`node`/`level`/`saw_shutdown` are
    /// caller-supplied context).
    pub fn stats(&self, node: usize, level: usize) -> NodeStats {
        NodeStats {
            node,
            level,
            subtree_consumers: self.subtree_consumers(),
            credit_bound: self.credit_bound(),
            max_queue: self.max_queue,
            msgs_in: self.msgs_in,
            msgs_out: self.msgs_out,
            steals_attempted: self.steals_attempted,
            steals_failed: self.steals_failed,
            steals_received: self.steals_received,
            steals_given: self.steals_given,
            cancelled_dropped: self.cancelled_dropped,
            retried: self.retried,
            saw_shutdown: self.shutting_down,
        }
    }

    /// Startup: prime the pump by requesting a full credit of tasks from
    /// the parent (stealing is skipped — nobody has work yet).
    pub fn on_start(&mut self) -> Vec<BufferAction> {
        self.request_if_low()
    }

    /// Tasks arrived from the parent.
    pub fn on_assign(&mut self, tasks: Vec<TaskSpec>) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.outstanding_request = self.outstanding_request.saturating_sub(tasks.len().max(1));
        self.accept(tasks);
        let mut out = self.deliver();
        out.extend(self.request_if_low());
        out
    }

    /// Leaf: a local consumer finished a task (and is implicitly asking
    /// for more). A failed attempt with retries left is re-queued here —
    /// transparently to everything upstream.
    pub fn on_done(&mut self, consumer: usize, mut result: TaskResult) -> Vec<BufferAction> {
        self.msgs_in += 1;
        let spec = match &mut self.children {
            Children::Consumers { running, .. } => {
                running.get_mut(consumer).and_then(|slot| slot.take())
            }
            Children::Buffers { .. } => panic!("on_done called on an interior buffer node"),
        };
        match spec {
            Some(mut spec) => {
                result.attempt = spec.attempt;
                if result.rc != 0 && result.rc != RC_CANCELLED && spec.attempt < spec.max_retries {
                    spec.attempt += 1;
                    self.retried += 1;
                    self.queue.push(spec);
                    self.max_queue = self.max_queue.max(self.queue.len());
                } else {
                    self.store.push(result);
                }
            }
            // No tracked spec: the task had no retry budget (the common
            // case — dispatch skips the clone then), so the result passes
            // through unchanged with the attempt the consumer stamped.
            None => self.store.push(result),
        }
        let mut out = Vec::new();
        let next = self.queue.pop();
        match &mut self.children {
            Children::Consumers { idle, running, .. } => {
                if let Some(task) = next {
                    // Track the spec only when retry bookkeeping can fire —
                    // the runtimes stamp `attempt` on the result themselves,
                    // so retry-less tasks skip the payload clone.
                    running[consumer] =
                        if task.max_retries > 0 { Some(task.clone()) } else { None };
                    self.msgs_out += 1;
                    out.push(BufferAction::RunOn { consumer, task });
                } else {
                    idle.push_back(consumer);
                }
            }
            Children::Buffers { .. } => unreachable!(),
        }
        out.extend(self.request_if_low());
        out.extend(self.flush_if_due());
        if self.shutting_down && self.busy_count() == 0 {
            out.extend(self.final_flush());
        }
        out
    }

    /// Interior: child slot `child` asked for `amount` more tasks.
    pub fn on_child_request(&mut self, child: usize, amount: usize) -> Vec<BufferAction> {
        self.msgs_in += 1;
        match &mut self.children {
            Children::Buffers { deficit, .. } => {
                deficit[child] = deficit[child].saturating_add(amount);
            }
            Children::Consumers { .. } => {
                panic!("on_child_request called on a leaf buffer node")
            }
        }
        let mut out = self.deliver();
        out.extend(self.request_if_low());
        out
    }

    /// Interior: a child flushed results; batch them toward the parent.
    pub fn on_child_results(&mut self, results: Vec<TaskResult>) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.store.extend(results);
        if self.shutting_down {
            self.flush_now()
        } else {
            self.flush_if_due()
        }
    }

    /// A cancellation notice arrived. If the task is queued here, drop it
    /// and emit an `RC_CANCELLED` result through the normal result path;
    /// otherwise forward the notice toward the leaves (an interior node
    /// does not know which child — if any — holds the task). A leaf that
    /// does not hold the task ignores the notice: the task is either
    /// already running (cancellation is best-effort) or finished.
    pub fn on_cancel(&mut self, id: TaskId) -> Vec<BufferAction> {
        self.msgs_in += 1;
        if let Some(spec) = self.queue.remove(id) {
            self.cancelled_dropped += 1;
            self.store.push(TaskResult::cancelled_for(&spec));
            let mut out = self.flush_if_due();
            // Losing queue depth may put us below the low-water mark.
            out.extend(self.request_if_low());
            out
        } else if let Children::Buffers { deficit, .. } = &self.children {
            self.msgs_out += deficit.len() as u64;
            vec![BufferAction::CancelChildren { id }]
        } else {
            Vec::new()
        }
    }

    /// A sibling asked to steal up to `amount` queued tasks. Surrender at
    /// most half the queue (taken from the back — the coldest,
    /// lowest-priority tasks); the grant is sent even when empty so the
    /// thief can escalate. `thief` is the runtime's opaque routing token
    /// (echoed in the grant); `thief_slot` is the thief's sibling slot —
    /// it is evidently starved, so its depth estimate drops to zero.
    pub fn on_steal_request(
        &mut self,
        thief: usize,
        thief_slot: usize,
        amount: usize,
    ) -> Vec<BufferAction> {
        self.msgs_in += 1;
        if let Some(d) = self.sibling_depth.get_mut(thief_slot) {
            *d = 0;
        }
        let give = if self.shutting_down { 0 } else { amount.min(self.queue.len() / 2) };
        let tasks = self.queue.take_back(give);
        self.steals_given += tasks.len() as u64;
        self.msgs_out += 1;
        let mut out = vec![BufferAction::StealGrant {
            thief,
            from_slot: self.my_slot,
            left: self.queue.len(),
            tasks,
        }];
        // Losing queue depth may put us below the low-water mark.
        out.extend(self.request_if_low());
        out
    }

    /// The answer to our steal request arrived (possibly empty), reporting
    /// the victim's remaining queue depth.
    pub fn on_steal_grant(
        &mut self,
        from_slot: usize,
        left: usize,
        tasks: Vec<TaskSpec>,
    ) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.steal_outstanding = 0;
        if let Some(d) = self.sibling_depth.get_mut(from_slot) {
            *d = left;
        }
        if tasks.is_empty() {
            self.steals_failed += 1;
        } else {
            self.steals_received += tasks.len() as u64;
            self.steal_tried = false;
        }
        self.accept(tasks);
        let mut out = self.deliver();
        // An empty grant leaves steal_tried set, so this escalates upstream.
        out.extend(self.request_if_low());
        out
    }

    /// Parent announced shutdown. A leaf waits for running consumers; an
    /// interior node flushes and forwards immediately (the producer only
    /// broadcasts at quiescence, so no results are in flight below us).
    pub fn on_shutdown(&mut self) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.shutting_down = true;
        if self.is_leaf() {
            if self.busy_count() == 0 {
                self.final_flush()
            } else {
                Vec::new()
            }
        } else {
            let mut out = Vec::new();
            if !self.store.is_empty() {
                out.extend(self.flush_now());
            }
            self.msgs_out += 1;
            out.push(BufferAction::ShutdownChildren);
            out
        }
    }

    /// Periodic tick from the runtime (threaded mode): flush any results
    /// that have been sitting in the store.
    pub fn on_tick(&mut self) -> Vec<BufferAction> {
        if self.store.is_empty() {
            Vec::new()
        } else {
            self.flush_now()
        }
    }

    /// Take tasks into the local queue (common to assigns and steals).
    fn accept(&mut self, tasks: Vec<TaskSpec>) {
        if !tasks.is_empty() {
            self.steal_tried = false;
        }
        self.queue.extend(tasks);
        self.max_queue = self.max_queue.max(self.queue.len());
    }

    /// Move queued tasks to whoever is asking below us.
    fn deliver(&mut self) -> Vec<BufferAction> {
        match &mut self.children {
            Children::Consumers { idle, running, .. } => {
                let mut out = Vec::new();
                while !self.queue.is_empty() && !idle.is_empty() {
                    let consumer = idle.pop_front().unwrap();
                    let task = self.queue.pop().unwrap();
                    running[consumer] =
                        if task.max_retries > 0 { Some(task.clone()) } else { None };
                    self.msgs_out += 1;
                    out.push(BufferAction::RunOn { consumer, task });
                }
                out
            }
            Children::Buffers { deficit, cursor, .. } => {
                // Same bounded round-robin as the producer, one level down.
                const GRANT_CHUNK: usize = 32;
                let nb = deficit.len();
                let mut granted: Vec<Vec<TaskSpec>> = vec![Vec::new(); nb];
                let mut scanned = 0;
                while !self.queue.is_empty() && scanned < nb {
                    let b = *cursor;
                    *cursor = (*cursor + 1) % nb;
                    scanned += 1;
                    if deficit[b] == 0 {
                        continue;
                    }
                    let take = deficit[b].min(GRANT_CHUNK).min(self.queue.len());
                    granted[b].extend(self.queue.pop_n(take));
                    deficit[b] -= take;
                    scanned = 0;
                }
                let mut out = Vec::new();
                for (b, tasks) in granted.into_iter().enumerate() {
                    if !tasks.is_empty() {
                        self.msgs_out += 1;
                        out.push(BufferAction::SendToChild { child: b, tasks });
                    }
                }
                out
            }
        }
    }

    fn request_if_low(&mut self) -> Vec<BufferAction> {
        if self.shutting_down {
            return Vec::new();
        }
        let low = self.subtree_consumers();
        let level = self.queue.len() + self.outstanding_request + self.steal_outstanding;
        if level >= low {
            return Vec::new();
        }
        let amount = self.credit_bound() - level;
        if self.steal_enabled && !self.steal_tried && self.steal_outstanding == 0 {
            self.steal_tried = true;
            self.steal_outstanding = amount;
            self.steals_attempted += 1;
            let victim = self.next_victim();
            self.msgs_out += 1;
            vec![BufferAction::StealRequest { victim, amount }]
        } else {
            self.outstanding_request += amount;
            self.msgs_out += 1;
            vec![BufferAction::RequestTasks { amount }]
        }
    }

    /// Pick the steal victim: blind rotation (`RoundRobin`) or the sibling
    /// with the deepest known queue (`DeepestQueue`; unknown = deepest, so
    /// early attempts explore in rotation before exploiting estimates).
    fn next_victim(&mut self) -> usize {
        let total = self.n_siblings + 1;
        match self.steal_policy {
            StealPolicy::RoundRobin => {
                self.steal_cursor = (self.steal_cursor + 1) % total;
                if self.steal_cursor == self.my_slot {
                    self.steal_cursor = (self.steal_cursor + 1) % total;
                }
                self.steal_cursor
            }
            StealPolicy::DeepestQueue => {
                let mut best: Option<usize> = None;
                let mut best_depth = 0usize;
                for off in 1..=total {
                    let slot = (self.steal_cursor + off) % total;
                    if slot == self.my_slot {
                        continue;
                    }
                    let d = self.sibling_depth.get(slot).copied().unwrap_or(usize::MAX);
                    if best.is_none() || d > best_depth {
                        best = Some(slot);
                        best_depth = d;
                    }
                }
                let victim = best.expect("stealing enabled implies at least one sibling");
                self.steal_cursor = victim;
                victim
            }
        }
    }

    fn flush_if_due(&mut self) -> Vec<BufferAction> {
        // Flush on batch-full, or as soon as there is nothing queued locally
        // (dynamic workloads need results to reach the engine promptly).
        if self.store.len() >= self.flush_every || (self.queue.is_empty() && !self.store.is_empty())
        {
            self.flush_now()
        } else {
            Vec::new()
        }
    }

    fn flush_now(&mut self) -> Vec<BufferAction> {
        self.msgs_out += 1;
        vec![BufferAction::FlushResults(std::mem::take(&mut self.store))]
    }

    fn final_flush(&mut self) -> Vec<BufferAction> {
        let mut out = Vec::new();
        if !self.store.is_empty() {
            out.extend(self.flush_now());
        }
        self.msgs_out += 1;
        out.push(BufferAction::ShutdownConsumers);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::Payload;

    fn task(id: u64) -> TaskSpec {
        TaskSpec::new(id, Payload::Sleep { seconds: 1.0 })
    }

    fn prio_task(id: u64, priority: u8) -> TaskSpec {
        let mut t = task(id);
        t.priority = priority;
        t
    }

    fn result(id: u64, consumer: usize) -> TaskResult {
        TaskResult {
            id,
            consumer,
            results: vec![],
            begin: 0.0,
            finish: 1.0,
            rc: 0,
            attempt: 0,
        }
    }

    fn failed(id: u64, consumer: usize) -> TaskResult {
        TaskResult { rc: 1, ..result(id, consumer) }
    }

    #[test]
    fn prio_queue_orders_by_priority_then_fifo() {
        let mut q = PrioQueue::new();
        q.push(prio_task(0, 1));
        q.push(prio_task(1, 5));
        q.push(prio_task(2, 1));
        q.push(prio_task(3, 5));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|t| t.id).collect();
        assert_eq!(order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn prio_queue_back_is_coldest_and_remove_by_id() {
        let mut q = PrioQueue::new();
        for (id, p) in [(0u64, 9u8), (1, 0), (2, 0), (3, 9)] {
            q.push(prio_task(id, p));
        }
        assert!(q.remove(2).is_some());
        assert!(q.remove(2).is_none());
        // Back = lowest priority, latest first; take_back returns them in
        // (reversed) queue order.
        let back = q.take_back(1);
        assert_eq!(back.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().id, 0);
    }

    #[test]
    fn producer_satisfies_requests_in_round_robin() {
        let mut p = ProducerState::new(2);
        assert!(p.on_request(0, 3).is_empty()); // nothing pending yet
        assert!(p.on_request(1, 3).is_empty());
        let acts = p.push_tasks((0..4).map(task).collect());
        // 4 tasks split across the two deficits, fairness via round-robin.
        let mut granted = [0usize; 2];
        for a in &acts {
            if let ProducerAction::SendTasks { buffer, tasks } = a {
                granted[*buffer] += tasks.len();
            }
        }
        assert_eq!(granted[0] + granted[1], 4);
        assert!(granted[0] > 0 && granted[1] > 0, "{granted:?}");
        assert_eq!(p.pending_len(), 0);
        assert_eq!(p.in_flight(), 4);
    }

    #[test]
    fn producer_grants_highest_priority_first() {
        let mut p = ProducerState::new(1);
        p.push_tasks(vec![prio_task(0, 0), prio_task(1, 9), prio_task(2, 5)]);
        let acts = p.on_request(0, 2);
        let ids: Vec<u64> = acts
            .iter()
            .flat_map(|a| match a {
                ProducerAction::SendTasks { tasks, .. } => {
                    tasks.iter().map(|t| t.id).collect::<Vec<_>>()
                }
                _ => Vec::new(),
            })
            .collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(p.pending_len(), 1);
    }

    #[test]
    fn producer_queues_tasks_without_deficit() {
        let mut p = ProducerState::new(1);
        let acts = p.push_tasks(vec![task(0)]);
        assert!(acts.is_empty());
        assert_eq!(p.pending_len(), 1);
        let acts = p.on_request(0, 10);
        assert_eq!(acts.len(), 1);
        assert_eq!(p.pending_len(), 0);
    }

    #[test]
    fn producer_cancel_drops_pending_or_broadcasts() {
        let mut p = ProducerState::new(2);
        p.push_tasks(vec![task(0), task(1)]);
        p.set_engine_done(true);
        // Task 1 is still pending: dropped locally, counts as completed.
        let (dropped, acts) = p.on_cancel(1);
        assert_eq!(dropped.unwrap().id, 1);
        assert!(acts.is_empty());
        assert_eq!(p.cancelled_pending(), 1);
        assert_eq!(p.in_flight(), 1);
        // Task 0 leaves the producer; a later cancel becomes a broadcast.
        p.on_request(0, 1);
        let (dropped, acts) = p.on_cancel(0);
        assert!(dropped.is_none());
        assert_eq!(acts, vec![ProducerAction::BroadcastCancel { id: 0 }]);
        // The cancelled-at-a-node result flows back like any other.
        p.on_results(1);
        assert_eq!(p.maybe_shutdown(), vec![ProducerAction::BroadcastShutdown]);
    }

    #[test]
    fn producer_shutdown_only_when_quiescent_and_once() {
        let mut p = ProducerState::new(1);
        p.push_tasks(vec![task(0)]);
        p.set_engine_done(true);
        assert!(p.maybe_shutdown().is_empty()); // pending
        p.on_request(0, 1);
        assert!(p.maybe_shutdown().is_empty()); // in flight
        p.on_results(1);
        assert_eq!(p.maybe_shutdown(), vec![ProducerAction::BroadcastShutdown]);
        assert!(p.maybe_shutdown().is_empty()); // idempotent
    }

    #[test]
    fn buffer_requests_on_start_and_dispatches_on_assign() {
        let mut b = BufferState::new(4, 2, 100);
        let acts = b.on_start();
        assert_eq!(acts, vec![BufferAction::RequestTasks { amount: 8 }]);
        let acts = b.on_assign((0..8).map(task).collect());
        let runs = acts
            .iter()
            .filter(|a| matches!(a, BufferAction::RunOn { .. }))
            .count();
        assert_eq!(runs, 4); // all four consumers started
        assert_eq!(b.queue_len(), 4);
        assert_eq!(b.idle_count(), 0);
    }

    #[test]
    fn buffer_done_feeds_next_task_and_requests_when_low() {
        let mut b = BufferState::new(2, 2, 100);
        b.on_start();
        b.on_assign(vec![task(0), task(1), task(2)]);
        // queue=1, outstanding=1 (asked 4, got 3): level 2 == n_consumers, no request.
        let acts = b.on_done(0, result(0, 0));
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RunOn { consumer: 0, .. })));
        // After dispatch queue=0, level=1 < 2 → request to restore credit 4.
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RequestTasks { amount: 3 })));
        // Queue empty → results flush immediately.
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 1)));
    }

    #[test]
    fn buffer_dispatches_high_priority_first() {
        let mut b = BufferState::new(1, 4, 100);
        b.on_start();
        let acts = b.on_assign(vec![prio_task(0, 0), prio_task(1, 7), prio_task(2, 3)]);
        // The single consumer gets the priority-7 task first.
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::RunOn { consumer: 0, task } if task.id == 1)));
        let acts = b.on_done(0, result(1, 0));
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::RunOn { consumer: 0, task } if task.id == 2)));
    }

    #[test]
    fn failed_attempt_with_retries_is_requeued_transparently() {
        let mut b = BufferState::new(1, 2, 1);
        b.on_start();
        let mut t = task(0);
        t.max_retries = 2;
        b.on_assign(vec![t]);
        // Attempt 0 fails: re-queued (attempt 1) and re-dispatched; nothing
        // is flushed upstream.
        let acts = b.on_done(0, failed(0, 0));
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::FlushResults(_))), "{acts:?}");
        let redisp = acts.iter().find_map(|a| match a {
            BufferAction::RunOn { task, .. } => Some(task.clone()),
            _ => None,
        });
        assert_eq!(redisp.as_ref().map(|t| t.attempt), Some(1));
        assert_eq!(b.retried, 1);
        // Attempt 1 fails: one retry left.
        let acts = b.on_done(0, failed(0, 0));
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RunOn { task, .. } if task.attempt == 2)));
        // Attempt 2 fails: retries exhausted → the failure is flushed with
        // the attempt count on it.
        let acts = b.on_done(0, failed(0, 0));
        let flushed = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.clone()),
                _ => None,
            })
            .expect("final failure must flush");
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].rc, 1);
        assert_eq!(flushed[0].attempt, 2);
        assert_eq!(b.retried, 2);
    }

    #[test]
    fn successful_retry_reports_attempt_index() {
        let mut b = BufferState::new(1, 2, 1);
        b.on_start();
        let mut t = task(7);
        t.max_retries = 3;
        b.on_assign(vec![t]);
        b.on_done(0, failed(7, 0));
        let acts = b.on_done(0, result(7, 0));
        let flushed = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.clone()),
                _ => None,
            })
            .expect("success must flush");
        assert_eq!(flushed[0].rc, 0);
        assert_eq!(flushed[0].attempt, 1);
    }

    #[test]
    fn cancel_drops_queued_task_and_reports_it() {
        let mut b = BufferState::new(1, 4, 1);
        b.on_start();
        b.on_assign(vec![task(0), task(1), task(2)]);
        // Task 0 runs; 1 and 2 are queued. Cancel 2: dropped, reported.
        let acts = b.on_cancel(2);
        let flushed = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.clone()),
                _ => None,
            })
            .expect("cancellation must flush a result");
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].id, 2);
        assert!(flushed[0].cancelled());
        assert_eq!(b.cancelled_dropped, 1);
        assert_eq!(b.queue_len(), 1);
        // Cancelling the *running* task is a no-op at a leaf.
        let acts = b.on_cancel(0);
        assert!(acts.is_empty(), "{acts:?}");
        assert_eq!(b.cancelled_dropped, 1);
    }

    #[test]
    fn interior_cancel_forwards_when_not_queued_here() {
        let mut r = BufferState::interior(3, 6, 2, 16);
        r.on_start();
        let acts = r.on_cancel(42);
        assert_eq!(acts, vec![BufferAction::CancelChildren { id: 42 }]);
        // But a task queued at the relay is dropped right here.
        r.on_assign(vec![task(5)]);
        let acts = r.on_cancel(5);
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs[0].cancelled())));
        assert_eq!(r.cancelled_dropped, 1);
    }

    #[test]
    fn buffer_batches_results_while_queue_nonempty() {
        let mut b = BufferState::new(1, 8, 3);
        b.on_start();
        b.on_assign((0..8).map(task).collect());
        // Two completions: queue still nonempty, store below flush_every → no flush.
        let a1 = b.on_done(0, result(0, 0));
        assert!(!a1.iter().any(|a| matches!(a, BufferAction::FlushResults(_))));
        let a2 = b.on_done(0, result(1, 0));
        assert!(!a2.iter().any(|a| matches!(a, BufferAction::FlushResults(_))));
        // Third completion hits flush_every = 3.
        let a3 = b.on_done(0, result(2, 0));
        assert!(a3
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 3)));
    }

    #[test]
    fn buffer_shutdown_waits_for_running_consumers() {
        let mut b = BufferState::new(2, 1, 100);
        b.on_start();
        b.on_assign(vec![task(0), task(1)]);
        let acts = b.on_shutdown();
        assert!(acts.is_empty(), "must wait for busy consumers");
        b.on_done(0, result(0, 0));
        let acts = b.on_done(1, result(1, 1));
        assert!(acts.iter().any(|a| matches!(a, BufferAction::ShutdownConsumers)));
        // All results eventually flushed.
        let flushed: usize = acts
            .iter()
            .filter_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.len()),
                _ => None,
            })
            .sum();
        assert!(flushed >= 1);
    }

    #[test]
    fn buffer_tick_flushes_stale_results() {
        let mut b = BufferState::new(1, 4, 100);
        b.on_start();
        b.on_assign((0..4).map(task).collect());
        b.on_done(0, result(0, 0));
        assert_eq!(b.store_len(), 1);
        let acts = b.on_tick();
        assert!(acts.iter().any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 1)));
        assert_eq!(b.store_len(), 0);
        assert!(b.on_tick().is_empty());
    }

    #[test]
    fn interior_node_relays_demand_and_results() {
        // A relay over two children covering 4 consumers each.
        let mut r = BufferState::interior(2, 8, 2, 4);
        let acts = r.on_start();
        assert_eq!(acts, vec![BufferAction::RequestTasks { amount: 16 }]);
        // Child 1 asks for 6; nothing queued yet, and the relay already has
        // a full outstanding credit, so no duplicate upstream request.
        let acts = r.on_child_request(1, 6);
        assert!(acts.is_empty(), "{acts:?}");
        // Parent delivers 10: 6 go straight to child 1, 4 stay queued.
        let acts = r.on_assign((0..10).map(task).collect());
        let sent: usize = acts
            .iter()
            .filter_map(|a| match a {
                BufferAction::SendToChild { child: 1, tasks } => Some(tasks.len()),
                _ => None,
            })
            .sum();
        assert_eq!(sent, 6);
        assert_eq!(r.queue_len(), 4);
        // Child 0 asks for 2 → served from the local queue, no upstream hop.
        let acts = r.on_child_request(0, 2);
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::SendToChild { child: 0, tasks } if tasks.len() == 2)));
        // Results batch until flush_every (4) — queue still holds 2 tasks.
        let rs: Vec<TaskResult> = (0..3).map(|i| result(i, 0)).collect();
        let acts = r.on_child_results(rs);
        assert!(acts.is_empty(), "{acts:?}");
        let acts = r.on_child_results(vec![result(3, 1)]);
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 4)));
    }

    #[test]
    fn interior_shutdown_forwards_to_children() {
        let mut r = BufferState::interior(3, 12, 2, 16);
        r.on_start();
        let acts = r.on_shutdown();
        assert!(acts.iter().any(|a| matches!(a, BufferAction::ShutdownChildren)));
        assert!(r.is_shutting_down());
        // After shutdown a node no longer requests work.
        assert!(r.on_child_request(0, 5).is_empty());
    }

    #[test]
    fn starved_node_steals_before_escalating() {
        let mut thief = BufferState::new(2, 2, 100).with_stealing(0, 1, StealPolicy::RoundRobin);
        let mut victim = BufferState::new(2, 2, 100).with_stealing(1, 1, StealPolicy::RoundRobin);
        // Startup requests go upstream, not sideways.
        assert_eq!(thief.on_start(), vec![BufferAction::RequestTasks { amount: 4 }]);
        victim.on_start();
        // Both receive their full credit; the victim's consumers are slow.
        victim.on_assign((0..8).map(task).collect()); // 2 dispatched, queue = 6
        thief.on_assign((100..104).map(task).collect()); // 2 dispatched, queue = 2
        // First completion: queue drops to 1 < n_consumers → steal attempt
        // at sibling slot 1, not an upstream request.
        let acts = thief.on_done(0, result(100, 0));
        let steal = acts.iter().find_map(|a| match a {
            BufferAction::StealRequest { victim, amount } => Some((*victim, *amount)),
            _ => None,
        });
        assert!(steal.is_some(), "{acts:?}");
        let (vslot, amount) = steal.unwrap();
        assert_eq!(vslot, 1);
        assert_eq!(amount, 3); // restore credit 4 from level 1
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::RequestTasks { .. })));
        // Victim surrenders up to half its queue (queue = 6 → gives 3) and
        // reports what it has left.
        let acts = victim.on_steal_request(0, 0, amount);
        let (granted, left) = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::StealGrant { thief: 0, from_slot: 1, left, tasks } => {
                    Some((tasks.clone(), *left))
                }
                _ => None,
            })
            .expect("victim must reply");
        assert_eq!(granted.len(), 3);
        assert_eq!(left, 3);
        assert_eq!(victim.queue_len(), 3);
        // Thief drains its queue; consumer 1 goes idle before the loot lands.
        thief.on_done(0, result(102, 0));
        thief.on_done(1, result(101, 1));
        let acts = thief.on_steal_grant(1, left, granted);
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RunOn { .. })), "{acts:?}");
        assert_eq!(thief.steals_received, 3);
        assert_eq!(thief.steals_failed, 0);
        assert_eq!(victim.steals_given, 3);
    }

    #[test]
    fn empty_steal_grant_escalates_upstream() {
        let mut thief = BufferState::new(2, 1, 100).with_stealing(0, 2, StealPolicy::RoundRobin);
        thief.on_start(); // upstream request for 2 (outstanding = 2)
        // Full credit arrives but dispatch drains the queue to 0, which is
        // below the low-water mark → a steal attempt, not an upstream request.
        let acts = thief.on_assign(vec![task(0), task(1)]);
        assert!(acts.iter().any(|a| matches!(a, BufferAction::StealRequest { .. })), "{acts:?}");
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::RequestTasks { .. })));
        // The sibling had nothing.
        let acts = thief.on_steal_grant(1, 0, Vec::new());
        let req = acts.iter().find_map(|a| match a {
            BufferAction::RequestTasks { amount } => Some(*amount),
            _ => None,
        });
        assert!(req.is_some(), "empty grant must escalate to the parent: {acts:?}");
        // No second steal until new tasks arrive.
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::StealRequest { .. })));
        assert_eq!(thief.steals_failed, 1);
    }

    #[test]
    fn steal_victim_rotates_round_robin_skipping_self() {
        let mut b = BufferState::new(1, 1, 100).with_stealing(1, 3, StealPolicy::RoundRobin);
        let mut seen = Vec::new();
        for _ in 0..6 {
            seen.push(b.next_victim());
        }
        assert!(!seen.contains(&1), "{seen:?}");
        assert_eq!(seen, vec![2, 3, 0, 2, 3, 0]);
    }

    #[test]
    fn deepest_queue_explores_then_picks_deepest_known() {
        let mut b = BufferState::new(1, 1, 100).with_stealing(1, 3, StealPolicy::DeepestQueue);
        // All unknown: explores in rotation, skipping self.
        assert_eq!(b.next_victim(), 2);
        assert_eq!(b.next_victim(), 3);
        assert_eq!(b.next_victim(), 0);
        // Learn depths from grants: slot 2 empty, slot 0 deep, slot 3 shallow.
        b.on_steal_grant(2, 0, Vec::new());
        b.on_steal_grant(0, 4, vec![task(90)]);
        b.on_steal_grant(3, 1, vec![task(91)]);
        assert_eq!(b.next_victim(), 0);
        assert_eq!(b.next_victim(), 0, "sticks to the deepest known sibling");
        // An incoming steal request marks that thief as starved.
        b.on_steal_request(0, 0, 1);
        assert_eq!(b.next_victim(), 3);
    }

    #[test]
    fn queue_never_exceeds_credit_bound() {
        let mut b = BufferState::new(3, 2, 5);
        b.on_start();
        b.on_assign((0..6).map(task).collect());
        assert!(b.max_queue() <= b.credit_bound());
        // Work through everything; the bound must hold throughout.
        let mut next_id = 6u64;
        for round in 0..20u64 {
            let acts = b.on_done(round as usize % 3, result(round, round as usize % 3));
            for a in acts {
                if let BufferAction::RequestTasks { amount } = a {
                    let grant: Vec<TaskSpec> =
                        (next_id..next_id + amount as u64).map(task).collect();
                    next_id += amount as u64;
                    b.on_assign(grant);
                }
            }
            assert!(b.max_queue() <= b.credit_bound(), "round {round}: {b:?}");
        }
    }

    #[test]
    fn no_task_lost_or_duplicated_through_buffer() {
        // Property-style: drive a buffer with random assign/done interleavings
        // and check conservation: every assigned task is run exactly once.
        use crate::testutil::{check, pair, usize_in, u64_in};
        check(
            "buffer conserves tasks",
            pair(usize_in(1..6), u64_in(1..40)),
            |&(nc, n_tasks)| {
                let mut b = BufferState::new(nc, 2, 5);
                b.on_start();
                let mut running: Vec<(usize, u64)> = Vec::new();
                let mut ran: Vec<u64> = Vec::new();
                let mut next = 0u64;
                let mut actions = b.on_assign((0..n_tasks.min(7)).map(task).collect());
                next += n_tasks.min(7);
                loop {
                    for a in actions.drain(..) {
                        if let BufferAction::RunOn { consumer, task } = a {
                            running.push((consumer, task.id));
                        }
                    }
                    if let Some((c, id)) = running.pop() {
                        ran.push(id);
                        actions = b.on_done(c, result(id, c));
                        if next < n_tasks {
                            let push = (n_tasks - next).min(3);
                            let mut more = b.on_assign((next..next + push).map(task).collect());
                            next += push;
                            actions.append(&mut more);
                        }
                    } else if next < n_tasks {
                        let push = (n_tasks - next).min(3);
                        actions = b.on_assign((next..next + push).map(task).collect());
                        next += push;
                    } else {
                        break;
                    }
                }
                ran.sort();
                ran.dedup();
                ran.len() as u64 == n_tasks
            },
        );
    }
}
