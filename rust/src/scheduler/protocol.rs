//! The scheduling protocol — pure state machines for the producer and
//! buffer roles (Fig. 2 of the paper), generalized to an N-level tree.
//!
//! CARAVAN's scheduler is a producer–consumer pattern with a *buffered
//! layer*: the rank-0 producer talks only to a few hundred buffer
//! processes; each buffer owns a task queue and feeds its own set of
//! consumers "gradually", and batches results on the way back so the
//! producer is never overwhelmed.
//!
//! The seed reproduced the paper's fixed two-party shape; this module
//! generalizes the buffer role so a buffer's children may be *consumers*
//! (a leaf, the original role) or *other buffers* (an interior relay).
//! Stacking relay levels bounds the fan-in at every node — the producer
//! talks to `O(fanout)` children instead of to every buffer, which is what
//! keeps rank 0 off the critical path at 10⁴–10⁵ consumers.
//!
//! The state machines here are *execution-agnostic*: the threaded runtime
//! ([`super::threads`]) drives them with real channels, and the
//! discrete-event simulator ([`crate::des`]) drives them in virtual time.
//! Every statement the benchmarks make about scaling is therefore a
//! statement about this exact code path.
//!
//! Flow control is demand-driven at every level:
//!
//! * a buffer node requests work from its parent whenever its local level
//!   (queue + outstanding requests) drops below its subtree's consumer
//!   count, asking for enough to restore `credit_factor ×` that count;
//! * a consumer implicitly requests work by reporting `Done`; an interior
//!   child explicitly requests with `on_child_request`;
//! * optionally, a starved node first tries to *steal* queued tasks from a
//!   sibling (victim per [`StealPolicy`]; the victim surrenders up to half
//!   its queue) and only escalates to the parent when the steal comes back
//!   empty — sideways moves are invisible to the parent's accounting.
//!
//! Results are buffered per the paper: a node flushes its result store to
//! its parent when it reaches `flush_every`, or immediately when the node
//! has nothing queued (so dynamically-generated workloads — TC3,
//! optimization loops — never stall waiting for a batch to fill).
//!
//! Job API semantics live here so both runtimes inherit them:
//!
//! * every queue ([`PrioQueue`]) is ordered by the configured
//!   [`SchedPolicy`] — strict priority bands with FIFO within a band
//!   (`Strict`), least deadline slack within a band (`Deadline`), or
//!   slack ordering plus **priority aging** (`Aging`), where a band's
//!   effective priority rises with the wait of its head task so a
//!   sustained high-priority stream cannot starve priority-0 work;
//!   steals always take the coldest tasks from the victim's back;
//! * **retry**: a leaf remembers what each consumer is running; an
//!   attempt finishing with `rc != 0` while retries remain is re-queued
//!   transparently (the producer never sees the failed attempt), and the
//!   final [`TaskResult`] carries the attempt index;
//! * **cancellation**: `on_cancel` drops the task from the local queue if
//!   present — synthesizing an `RC_CANCELLED` result that flows upstream
//!   like any other, so conservation and termination detection are
//!   untouched. A task *running* on a leaf consumer is killed through
//!   [`BufferAction::CancelRunning`] (the executor reports
//!   `RC_CANCELLED`, exempt from retry); a notice that finds no local
//!   target is kept as a tombstone and forwarded with steal grants, so a
//!   cancel racing a sideways task move is applied when the task lands;
//! * **recall** (drain-and-graft re-shaping): on
//!   [`ProducerState::begin_recall`] the whole tree quiesces — grants are
//!   withheld, every node returns its queued tasks upstream with
//!   `enqueued_t` preserved and acks once its subtree is drained — so
//!   the runtime can rebuild the tree at a new depth/fanout and re-grant
//!   the recalled work without losing, duplicating, or re-ordering (per
//!   [`SchedPolicy`]) a single task.

use super::metrics::{wait_bin, BandWaitHist, ClassNodeStats, NodeStats, N_WAIT_BINS};
use crate::config::{
    Calibration, SchedPolicy, SchedulerConfig, StealPolicy, TreeNodeKind, TreeTopology,
};
use crate::tasklib::{TaskId, TaskResult, TaskSpec, RC_CANCELLED};
use crate::tenancy::{ClassId, ClassTable, DEFAULT_CLASS};
use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Total order over f64 deadline keys (NaN-free by construction).
#[derive(Clone, Copy, Debug, PartialEq)]
struct OrdF64(f64);

impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Within-band position: deadline (constant 0 under [`SchedPolicy::Strict`],
/// so pure FIFO), then arrival sequence to break exact-deadline ties FIFO.
type BandKey = (OrdF64, u64);

/// The policy-driven task queue used at *every* level of the scheduler —
/// the producer's pending queue and each buffer-tree node's local queue.
///
/// Tasks live in priority *bands* (the base [`TaskSpec::priority`]); the
/// configured [`SchedPolicy`] decides both the within-band order (FIFO, or
/// least deadline slack first — slack ordering at a common "now" equals
/// absolute-deadline ordering, so keys stay static) and which band pops
/// next (highest base priority, or highest *effective* priority under
/// aging, where a band gains one level per `step` seconds its head task
/// has waited). The "back" — what sibling steals take — is always the
/// coldest end: lowest band, loosest deadline, latest arrival.
///
/// The queue stamps [`TaskSpec::enqueued_t`] on first entry using the
/// clock its owner advances via [`PrioQueue::set_now`] (wall-clock in the
/// threaded runtime, virtual time in the DES), so both runtimes age and
/// order tasks identically.
#[derive(Clone, Debug)]
pub struct PrioQueue {
    /// One lane per tenant class that has ever queued here, keyed by
    /// [`ClassId`]. Lanes are created on demand; a single-tenant run only
    /// ever materializes the [`DEFAULT_CLASS`] lane, whose behaviour is
    /// bit-identical to the pre-tenancy queue.
    lanes: BTreeMap<ClassId, Lane>,
    seq: u64,
    len: usize,
    /// Ordering policy for lanes whose class is not in the registry.
    default_policy: SchedPolicy,
    /// Per-class weight/policy view (empty = single-tenant fallback).
    classes: ClassTable,
    now: f64,
    /// Deficit-round-robin state: the lane currently being served…
    cursor: Option<ClassId>,
    /// …and how many pops it has left before the rotor advances. A lane
    /// earns `weight` pops per visit, so over any busy interval classes
    /// share dispatches proportionally to weight.
    quantum: u64,
}

/// One tenant class's slice of a [`PrioQueue`]: its own priority bands,
/// ordering policy and dispatch counters. All invariants of the old
/// single-tenant queue (FIFO-within-band, Σ wait-hist counts == popped)
/// hold *per lane*, so they also hold for the aggregated view.
#[derive(Clone, Debug)]
struct Lane {
    bands: BTreeMap<Reverse<u8>, BTreeMap<BandKey, TaskSpec>>,
    len: usize,
    policy: SchedPolicy,
    /// Tasks popped for dispatch (front pops only — steal surrenders and
    /// cancellation removals are not dispatches).
    popped: u64,
    /// Per-band queue-wait histogram: every front pop records
    /// `now − enqueued_t` for the popped task's base priority band, so
    /// Σ counts == `popped` by construction.
    wait_hist: BTreeMap<u8, [u64; N_WAIT_BINS]>,
}

impl Lane {
    fn new(policy: SchedPolicy) -> Self {
        Self { bands: BTreeMap::new(), len: 0, policy, popped: 0, wait_hist: BTreeMap::new() }
    }

    fn band_key(&self, task: &TaskSpec, seq: u64) -> BandKey {
        match self.policy {
            SchedPolicy::Strict => (OrdF64(0.0), seq),
            SchedPolicy::Deadline | SchedPolicy::Aging { .. } => (OrdF64(task.deadline()), seq),
        }
    }

    fn push(&mut self, task: TaskSpec, seq: u64) {
        let key = self.band_key(&task, seq);
        self.bands.entry(Reverse(task.priority)).or_default().insert(key, task);
        self.len += 1;
    }

    /// The band the next pop comes from: the highest base priority, or —
    /// under aging — the highest *effective* priority, where a band gains
    /// one level per `step` seconds its head task has been queued. Ties go
    /// to the higher base band (iteration order), keeping aging a strict
    /// generalization of the static policies.
    fn pop_band(&self, now: f64) -> Option<Reverse<u8>> {
        match self.policy {
            SchedPolicy::Strict | SchedPolicy::Deadline => self.bands.keys().next().copied(),
            SchedPolicy::Aging { step } => {
                let mut best: Option<(u64, Reverse<u8>)> = None;
                for (band, sub) in &self.bands {
                    let Some(head) = sub.values().next() else { continue };
                    let wait = (now - head.enqueued_t.unwrap_or(now)).max(0.0);
                    let boost =
                        if step > 0.0 { ((wait / step) as u64).min(u8::MAX as u64) } else { 0 };
                    let eff = band.0 as u64 + boost;
                    if best.map_or(true, |(b, _)| eff > b) {
                        best = Some((eff, *band));
                    }
                }
                best.map(|(_, b)| b)
            }
        }
    }

    fn pop_front(&mut self, now: f64) -> Option<TaskSpec> {
        let band = self.pop_band(now)?;
        let sub = self.bands.get_mut(&band)?;
        let (_, task) = sub.pop_first()?;
        if sub.is_empty() {
            self.bands.remove(&band);
        }
        self.len -= 1;
        self.popped += 1;
        let wait = (now - task.enqueued_t.unwrap_or(now)).max(0.0);
        let hist = self.wait_hist.entry(task.priority).or_insert([0; N_WAIT_BINS]);
        if let Some(slot) = hist.get_mut(wait_bin(wait)) {
            *slot += 1;
        }
        Some(task)
    }

    /// One task off the coldest end (no dispatch accounting).
    fn take_back_one(&mut self) -> Option<TaskSpec> {
        let band = *self.bands.keys().next_back()?;
        let sub = self.bands.get_mut(&band)?;
        let (_, t) = sub.pop_last()?;
        if sub.is_empty() {
            self.bands.remove(&band);
        }
        self.len -= 1;
        Some(t)
    }

    fn remove(&mut self, id: TaskId) -> Option<TaskSpec> {
        let mut hit: Option<(Reverse<u8>, BandKey)> = None;
        'scan: for (band, sub) in &self.bands {
            for (key, t) in sub {
                if t.id == id {
                    hit = Some((*band, *key));
                    break 'scan;
                }
            }
        }
        let (band, key) = hit?;
        let sub = self.bands.get_mut(&band)?;
        let task = sub.remove(&key);
        if sub.is_empty() {
            self.bands.remove(&band);
        }
        if task.is_some() {
            self.len -= 1;
        }
        task
    }

    fn wait_hist(&self) -> Vec<BandWaitHist> {
        self.wait_hist.iter().map(|(&band, &counts)| BandWaitHist { band, counts }).collect()
    }
}

impl Default for PrioQueue {
    fn default() -> Self {
        Self {
            lanes: BTreeMap::new(),
            seq: 0,
            len: 0,
            default_policy: SchedPolicy::Strict,
            classes: ClassTable::default(),
            now: 0.0,
            cursor: None,
            quantum: 0,
        }
    }
}

impl PrioQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_policy(policy: SchedPolicy) -> Self {
        Self { default_policy: policy, ..Self::default() }
    }

    /// Attach the per-class weight/policy table (builder). Lanes created
    /// afterwards order by their class's registered policy; the
    /// deficit-round-robin pop rule uses the registered weights.
    pub fn with_classes(mut self, classes: ClassTable) -> Self {
        self.classes = classes;
        let default = self.default_policy;
        for (&class, lane) in self.lanes.iter_mut() {
            lane.policy = self.classes.policy_or(class, default);
        }
        self
    }

    /// Switch the default ordering policy (only sensible while empty —
    /// existing keys are not rebuilt). Lanes of *registered* classes keep
    /// their class policy; unregistered lanes follow the default.
    pub fn set_policy(&mut self, policy: SchedPolicy) {
        self.default_policy = policy;
        for (&class, lane) in self.lanes.iter_mut() {
            if !self.classes.is_registered(class) {
                lane.policy = policy;
            }
        }
    }

    pub fn policy(&self) -> SchedPolicy {
        self.default_policy
    }

    /// Advance the queue's clock (drives enqueue stamps, slack and aging).
    pub fn set_now(&mut self, now: f64) {
        self.now = now;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn push(&mut self, mut task: TaskSpec) {
        self.seq += 1;
        if task.enqueued_t.is_none() {
            task.enqueued_t = Some(self.now);
        }
        let lane = match self.lanes.entry(task.class) {
            std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::btree_map::Entry::Vacant(v) => {
                let policy = self.classes.policy_or(task.class, self.default_policy);
                v.insert(Lane::new(policy))
            }
        };
        lane.push(task, self.seq);
        self.len += 1;
    }

    pub fn extend(&mut self, tasks: Vec<TaskSpec>) {
        for t in tasks {
            self.push(t);
        }
    }

    /// Tasks popped for dispatch so far (the wait histograms' total).
    pub fn popped(&self) -> u64 {
        self.lanes.values().map(|l| l.popped).sum()
    }

    /// Per-band queue-wait histograms, ascending band order, merged across
    /// all class lanes.
    pub fn wait_hist(&self) -> Vec<BandWaitHist> {
        let mut merged: BTreeMap<u8, [u64; N_WAIT_BINS]> = BTreeMap::new();
        for lane in self.lanes.values() {
            for (&band, counts) in &lane.wait_hist {
                let m = merged.entry(band).or_insert([0; N_WAIT_BINS]);
                for (slot, c) in m.iter_mut().zip(counts.iter()) {
                    *slot += c;
                }
            }
        }
        merged.iter().map(|(&band, &counts)| BandWaitHist { band, counts }).collect()
    }

    /// Per-class dispatch counters, ascending class order — the exact
    /// decomposition of [`PrioQueue::popped`] / [`PrioQueue::wait_hist`].
    /// Empty for a single-tenant queue (no registry, only the default
    /// lane), so pre-tenancy reports stay unchanged.
    pub fn class_stats(&self) -> Vec<ClassNodeStats> {
        let single_tenant =
            self.classes.is_empty() && self.lanes.keys().all(|&c| c == DEFAULT_CLASS);
        if single_tenant {
            return Vec::new();
        }
        self.lanes
            .iter()
            .map(|(&class, lane)| ClassNodeStats {
                class,
                popped: lane.popped,
                wait_hist: lane.wait_hist(),
            })
            .collect()
    }

    /// The next non-empty lane strictly after `cur` in ascending class
    /// order, wrapping around (`cur` itself is eligible again on wrap).
    fn next_nonempty(&self, cur: Option<ClassId>) -> Option<ClassId> {
        use std::ops::Bound::{Excluded, Unbounded};
        let first = || self.lanes.iter().find(|(_, l)| l.len > 0).map(|(&c, _)| c);
        match cur {
            None => first(),
            Some(c) => self
                .lanes
                .range((Excluded(c), Unbounded))
                .find(|(_, l)| l.len > 0)
                .map(|(&c2, _)| c2)
                .or_else(first),
        }
    }

    /// Next task: deficit round-robin across class lanes — the serving
    /// lane pops until its quantum (= fair-share weight) or its backlog is
    /// exhausted, then the rotor advances to the next non-empty lane in
    /// ascending class order. Within a lane, the class's [`SchedPolicy`]
    /// picks the band exactly as the single-tenant queue did. With one
    /// lane this degenerates to the pre-tenancy behaviour.
    pub fn pop(&mut self) -> Option<TaskSpec> {
        if self.len == 0 {
            return None;
        }
        let serving = self
            .cursor
            .filter(|c| self.quantum > 0 && self.lanes.get(c).map_or(false, |l| l.len > 0));
        let class = match serving {
            Some(c) => c,
            None => {
                let c = self.next_nonempty(self.cursor)?;
                self.cursor = Some(c);
                self.quantum = self.classes.weight(c);
                c
            }
        };
        self.quantum -= 1;
        let lane = self.lanes.get_mut(&class)?;
        let task = lane.pop_front(self.now)?;
        self.len -= 1;
        Some(task)
    }

    /// Up to `n` tasks off the front (fair-share + policy order).
    pub fn pop_n(&mut self, n: usize) -> Vec<TaskSpec> {
        let mut out = Vec::with_capacity(n.min(self.len));
        for _ in 0..n {
            match self.pop() {
                Some(t) => out.push(t),
                None => break,
            }
        }
        out
    }

    /// Up to `n` tasks off the back — the coldest work, surrendered to
    /// sibling steals. Per step the victim lane is the longest backlog
    /// (ties to the higher class id), and within it the coldest task
    /// (lowest band, loosest deadline, latest arrival) — the multi-tenant
    /// generalization of the single-class coldest-end rule.
    pub fn take_back(&mut self, n: usize) -> Vec<TaskSpec> {
        let mut out = Vec::with_capacity(n.min(self.len));
        for _ in 0..n {
            let victim = self
                .lanes
                .iter()
                .filter(|(_, l)| l.len > 0)
                .max_by(|(ca, la), (cb, lb)| la.len.cmp(&lb.len).then(ca.cmp(cb)))
                .map(|(&c, _)| c);
            let class = match victim {
                Some(c) => c,
                None => break,
            };
            let Some(lane) = self.lanes.get_mut(&class) else { break };
            let Some(t) = lane.take_back_one() else { break };
            self.len -= 1;
            out.push(t);
        }
        out.reverse();
        out
    }

    /// Every queued task, in deterministic pop-order-compatible iteration
    /// order (class lane, then priority band, then band key). Part of the
    /// model-checker seam: [`crate::check`] uses it for its conservation
    /// oracle and state fingerprints.
    pub fn iter_tasks(&self) -> impl Iterator<Item = &TaskSpec> + '_ {
        self.lanes.values().flat_map(|l| l.bands.values().flat_map(|sub| sub.values()))
    }

    /// Feed the scheduling-relevant queue state into `h` (model-checker
    /// seam). Instrumentation — pop counters, wait histograms — and the
    /// absolute arrival sequence are excluded, so states differing only
    /// in metrics or in when (not in what order) tasks arrived collapse
    /// to one fingerprint in the checker's visited set.
    pub fn model_hash(&self, h: &mut impl std::hash::Hasher) {
        h.write_usize(self.len);
        h.write_u8(u8::from(self.cursor.is_some()));
        h.write_u8(self.cursor.unwrap_or(0));
        h.write_u64(self.quantum);
        for (&class, lane) in &self.lanes {
            h.write_u8(class);
            h.write_usize(lane.len);
            for sub in lane.bands.values() {
                for t in sub.values() {
                    hash_task(t, h);
                }
            }
        }
    }

    /// Remove the task with the given id, if queued here.
    pub fn remove(&mut self, id: TaskId) -> Option<TaskSpec> {
        for lane in self.lanes.values_mut() {
            if let Some(t) = lane.remove(id) {
                self.len -= 1;
                return Some(t);
            }
        }
        None
    }
}

/// Hash the scheduling-relevant fields of one task (model-checker seam).
/// The payload is skipped: two model states whose queues hold the same
/// ids in the same order behave identically regardless of payload bytes.
fn hash_task(t: &TaskSpec, h: &mut impl std::hash::Hasher) {
    h.write_u64(t.id);
    h.write_u8(t.priority);
    h.write_u32(t.attempt);
    h.write_u32(t.max_retries);
    h.write_u8(t.class);
    h.write_u8(u8::from(t.timeout_s.is_some()));
    h.write_u64(t.timeout_s.map_or(0, f64::to_bits));
    h.write_u8(u8::from(t.enqueued_t.is_some()));
    h.write_u64(t.enqueued_t.map_or(0, f64::to_bits));
}

/// Hash the protocol-relevant fields of one result (model-checker seam).
fn hash_result(r: &TaskResult, h: &mut impl std::hash::Hasher) {
    h.write_u64(r.id);
    h.write_i32(r.rc);
    h.write_u32(r.attempt);
    h.write_usize(r.consumer);
}

/// Deepest tree the auto-shaping controller will pick. Each level adds a
/// message hop of latency to every task, so the controller deepens only
/// while it predicts a producer benefit.
pub const MAX_AUTO_DEPTH: usize = 3;

/// Predicted producer busy-fraction the controller shapes for: the
/// shallowest tree whose predicted utilization clears this target wins.
const TARGET_PRODUCER_UTIL: f64 = 0.5;

/// Per-level fanout plan for `nb` leaves over `depth` buffer levels:
/// **wide near the leaves, narrow at the root**. The returned vector is
/// ordered root-down ([`SchedulerConfig::fanout`] convention, length
/// `depth − 1`; empty for the flat layout).
///
/// Every grouping stage below the top uses the full width `max_fanout` —
/// leaf-side fan-in is cheap because results batch upward and leaf
/// requests are low-rate. The top stage then picks the smallest fanout
/// `f` that still bounds the producer's own fan-in (`⌈m / f⌉ ≤ f` for the
/// `m` nodes left to group), so both the root count (which the request
/// traffic scales with) and the level-1 fan-in stay small where the
/// traffic concentrates.
pub fn shaped_fanouts(nb: usize, depth: usize, max_fanout: usize) -> Vec<usize> {
    if depth <= 1 {
        return Vec::new();
    }
    let fmax = max_fanout.max(2);
    // Nodes left to group after the wide lower stages.
    let mut m = nb;
    for _ in 0..depth - 2 {
        m = m.div_ceil(fmax);
    }
    let f_top = (2..fmax).find(|&f| m.div_ceil(f) <= f).unwrap_or(fmax);
    let mut fans = vec![fmax; depth - 1];
    if let Some(top) = fans.first_mut() {
        *top = f_top;
    }
    fans
}

/// Producer direct children for `nb` leaves under a root-down per-level
/// fanout plan (applied leaf-side first, exactly as
/// [`crate::config::TreeTopology::build`] groups).
pub fn root_count(nb: usize, fanouts: &[usize]) -> usize {
    let mut m = nb;
    for &f in fanouts.iter().rev() {
        m = m.div_ceil(f.max(1));
    }
    m.max(1)
}

/// The adaptive tree-shaping controller: pick `(depth, per-level fanout)`
/// for the configured scale from a [`Calibration`] measurement. Pure and
/// deterministic — both runtimes call this one function, so the same
/// calibration inputs always select the same shape (and the DES choice is
/// deterministic in virtual time).
///
/// Cost model, from the protocol's own flow control:
///
/// * a leaf with `C` consumers drains `C / mean_task_s` tasks/s; result
///   flushes reach the producer batched by `flush_every` at *every* depth
///   (interior nodes re-batch to the same size), so the result-message
///   rate `np / (mean_task_s · flush_every)` is depth-independent;
/// * each direct child of the producer refills its credit once per
///   `(credit_factor − 1) × mean_task_s` window (one request + one grant
///   message), so the request traffic is `2 · roots / window` — this is
///   the term a deeper tree shrinks, by cutting `roots`;
/// * the per-message producer cost is approximated as half the measured
///   request→grant round trip (the other half being the two wire hops).
///
/// The controller walks depth 1 → [`MAX_AUTO_DEPTH`], each with its
/// [`shaped_fanouts`] plan (wide at the leaves, narrow at the root), and
/// returns the first shape whose predicted producer utilization is at
/// most the target — or the deepest candidate when the producer lag
/// dominates so hard that no shape clears it (utilization still strictly
/// improves with every level until the root count hits 1).
///
/// With `coalesce_flush` on (the v10 default) a request and a result
/// flush emitted in the same step ride one message, so the modelled
/// `result_rate + request_rate` load is an *upper bound* on what rank 0
/// actually serves. The formula is deliberately left uncoalesced: a
/// conservative producer-load estimate can only deepen the tree a step
/// early, never leave the producer saturated.
pub fn choose_shape(cfg: &SchedulerConfig, cal: &Calibration) -> (usize, Vec<usize>) {
    let nb = cfg.num_buffers();
    if nb <= 1 {
        // A single leaf: no layer to restructure.
        return (1, Vec::new());
    }
    let fmax = cfg.max_fanout();
    let tau = cal.mean_task_s.max(1e-9);
    let per_msg_cost = (cal.producer_rtt / 2.0).max(0.0);
    let refill_window = (cfg.credit_factor.max(2) - 1) as f64 * tau;
    let result_rate = cfg.np as f64 / (tau * cfg.flush_every.max(1) as f64);
    let mut chosen = (1, Vec::new());
    for depth in 1..=MAX_AUTO_DEPTH {
        let fans = shaped_fanouts(nb, depth, fmax);
        let roots = root_count(nb, &fans);
        let request_rate = 2.0 * roots as f64 / refill_window;
        let util = per_msg_cost * (result_rate + request_rate);
        chosen = (depth, fans);
        if util <= TARGET_PRODUCER_UTIL || roots == 1 {
            break;
        }
    }
    chosen
}

/// Resolve a config's effective `(depth, per-level fanout)`: manual knobs
/// pass through (the per-level plan expanded to `depth − 1` entries);
/// auto modes consult [`choose_shape`] with the given calibration (the
/// runtime's own measurement for [`crate::config::TreeShape::Auto`], the
/// preset for [`crate::config::TreeShape::Calibrated`]).
pub fn resolve_shape(cfg: &SchedulerConfig, measured: Calibration) -> (usize, Vec<usize>) {
    use crate::config::TreeShape;
    match cfg.shape {
        TreeShape::Manual => {
            let fans = (1..cfg.depth.max(1)).map(|l| cfg.fanout_at(l)).collect();
            (cfg.depth.max(1), fans)
        }
        TreeShape::Auto => choose_shape(cfg, &measured),
        TreeShape::Calibrated(cal) => choose_shape(cfg, &cal),
    }
}

/// Actions the producer asks its runtime to carry out.
#[derive(Clone, Debug, PartialEq)]
pub enum ProducerAction {
    /// Send these tasks to child `buffer` (slot index among the producer's
    /// direct children — the level-1 nodes of the tree).
    SendTasks { buffer: usize, tasks: Vec<TaskSpec> },
    /// Forward a cancellation notice to every child (the producer does not
    /// know where — or whether — the task is queued).
    BroadcastCancel { id: TaskId },
    /// Begin a drain-and-graft transition: tell every child to stop
    /// requesting work, return its queued tasks upstream, and ack once
    /// its subtree is drained (see [`BufferState::on_recall`]).
    BroadcastRecall,
    /// All work is done: tell every child to shut down.
    BroadcastShutdown,
}

/// Actions a buffer node asks its runtime to carry out.
#[derive(Clone, Debug, PartialEq)]
pub enum BufferAction {
    /// Leaf: start `tasks` on local consumer index `consumer`, in order.
    /// The consumer executes them back to back and reports one batched
    /// completion — N tasks ride one message each way. A single-element
    /// batch is the pre-v10 per-task dispatch.
    RunBatch { consumer: usize, tasks: Vec<TaskSpec> },
    /// Interior: forward these tasks to child slot `child`.
    SendToChild { child: usize, tasks: Vec<TaskSpec> },
    /// Ask the parent for up to `amount` more tasks.
    RequestTasks { amount: usize },
    /// Ship these results to the parent.
    FlushResults(Vec<TaskResult>),
    /// Coalesced ascent: a credit request for `amount` more tasks *and* a
    /// result flush riding one upstream send (emitted instead of separate
    /// `RequestTasks` + `FlushResults` when the node's `coalesce_flush`
    /// knob is on and one protocol step produced both).
    Flush { amount: usize, results: Vec<TaskResult> },
    /// Ask sibling slot `victim` (within the shared parent) for queued
    /// tasks. `thief` in the reply is an opaque token echoed back by the
    /// victim — the runtime chooses what it routes by.
    StealRequest { victim: usize, amount: usize },
    /// Reply to a steal request; `tasks` may be empty. `from_slot` is the
    /// victim's own slot and `left` its remaining queue depth — the thief
    /// uses them to maintain its victim-selection estimates. `cancels`
    /// are the victim's pending (unmatched) cancellation notices,
    /// forwarded so a cancel racing a sideways task move can never be
    /// lost (the thief merges them before accepting the loot).
    StealGrant {
        thief: usize,
        from_slot: usize,
        left: usize,
        cancels: Vec<TaskId>,
        tasks: Vec<TaskSpec>,
    },
    /// Leaf: the cancelled task is *running* (or queued behind the
    /// running attempt in a dispatched batch) on local consumer index
    /// `consumer` — the runtime must kill or skip the attempt; the
    /// consumer then reports `RC_CANCELLED` in the task's batch position
    /// through the ordinary `Done` path (which is exempt from retry).
    CancelRunning { consumer: usize, id: TaskId },
    /// Interior: forward a cancellation notice to all children.
    CancelChildren { id: TaskId },
    /// Leaf: tell all local consumers to stop.
    ShutdownConsumers,
    /// Interior: forward the shutdown notice to all children.
    ShutdownChildren,
    /// Recall: send these drained (or returned-by-a-child) tasks to the
    /// parent, `enqueued_t` stamps intact, for re-enqueue at the producer.
    ReturnTasks(Vec<TaskSpec>),
    /// Interior: forward the recall notice to all children.
    RecallChildren,
    /// Tell the parent this node's subtree is drained: no queued tasks,
    /// no running attempts, no outstanding steal, all children acked.
    AckRecall,
}

/// Producer (rank 0) state: the global pending-task queue plus which
/// children are waiting for work.
#[derive(Clone, Debug)]
pub struct ProducerState {
    pending: PrioQueue,
    /// `deficit[b]` = number of tasks child `b` asked for but hasn't received.
    deficit: Vec<usize>,
    /// Round-robin cursor so replenishment is fair across children.
    cursor: usize,
    submitted: u64,
    completed: u64,
    cancelled: u64,
    engine_done: bool,
    shutdown_sent: bool,
    /// True while a drain-and-graft transition is in flight: grants are
    /// withheld so the old tree can empty out.
    recalling: bool,
    /// Which direct children have acked the recall (drained subtrees).
    recall_acks: Vec<bool>,
    /// Message-count instrumentation (drives the buffered-layer ablation).
    pub msgs_in: u64,
    pub msgs_out: u64,
}

impl ProducerState {
    pub fn new(num_buffers: usize) -> Self {
        // Clamp rather than assert: a zero-child producer is a caller bug,
        // but panicking here would tear down the whole run.
        let num_buffers = num_buffers.max(1);
        Self {
            pending: PrioQueue::new(),
            deficit: vec![0; num_buffers],
            cursor: 0,
            submitted: 0,
            completed: 0,
            cancelled: 0,
            engine_done: false,
            shutdown_sent: false,
            recalling: false,
            recall_acks: vec![false; num_buffers],
            msgs_in: 0,
            msgs_out: 0,
        }
    }

    /// Use `policy` for the pending queue (builder; call before any push).
    pub fn with_policy(mut self, policy: SchedPolicy) -> Self {
        self.pending.set_policy(policy);
        self
    }

    /// Attach the tenant-class table to the pending queue (builder): class
    /// lanes order by their registered policy and grants interleave by
    /// fair-share weight.
    pub fn with_classes(mut self, classes: crate::tenancy::ClassTable) -> Self {
        self.pending = std::mem::take(&mut self.pending).with_classes(classes);
        self
    }

    /// Per-class grant counters of the pending queue (how many tasks of
    /// each class the producer has granted downstream) — the live class
    /// mix fed to the reshape controller. Empty for single-tenant runs.
    pub fn class_stats(&self) -> Vec<ClassNodeStats> {
        self.pending.class_stats()
    }

    /// Advance the producer's clock: newly pushed tasks are stamped with
    /// this time and policy ordering (slack, aging) is evaluated at it.
    pub fn set_now(&mut self, now: f64) {
        self.pending.set_now(now);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Tasks dropped by cancellation while still pending at the producer.
    pub fn cancelled_pending(&self) -> u64 {
        self.cancelled
    }

    pub fn in_flight(&self) -> u64 {
        self.submitted - self.completed
    }

    /// Engine submitted new tasks: enqueue and satisfy outstanding deficits.
    pub fn push_tasks(&mut self, tasks: Vec<TaskSpec>) -> Vec<ProducerAction> {
        self.submitted += tasks.len() as u64;
        self.pending.extend(tasks);
        self.satisfy_deficits()
    }

    /// A child asked for `amount` more tasks.
    pub fn on_request(&mut self, buffer: usize, amount: usize) -> Vec<ProducerAction> {
        self.msgs_in += 1;
        if let Some(d) = self.deficit.get_mut(buffer) {
            *d = d.saturating_add(amount);
        }
        self.satisfy_deficits()
    }

    /// A child flushed `n_results` results (the runtime hands the actual
    /// values to the engine); tracked here for termination detection.
    /// Cancelled tasks dropped inside the tree arrive through this same
    /// path, so conservation is untouched.
    pub fn on_results(&mut self, n_results: usize) {
        self.msgs_in += 1;
        self.completed += n_results as u64;
    }

    /// A child's coalesced ascent arrived: a credit request for `amount`
    /// more tasks and `n_results` flushed results in one message (see
    /// [`BufferAction::Flush`]). Exactly `on_results` followed by
    /// `on_request`, but counted as the single message it travelled as.
    pub fn on_flush(&mut self, buffer: usize, amount: usize, n_results: usize) -> Vec<ProducerAction> {
        self.msgs_in += 1;
        self.completed += n_results as u64;
        if let Some(d) = self.deficit.get_mut(buffer) {
            *d = d.saturating_add(amount);
        }
        self.satisfy_deficits()
    }

    /// The engine asked to cancel `id`. If the task is still pending here
    /// it is dropped and returned — the runtime synthesizes the
    /// `RC_CANCELLED` result for the engine; the drop already counts as a
    /// completion. Otherwise the notice is broadcast down the tree.
    pub fn on_cancel(&mut self, id: TaskId) -> (Option<TaskSpec>, Vec<ProducerAction>) {
        if let Some(spec) = self.pending.remove(id) {
            self.completed += 1;
            self.cancelled += 1;
            (Some(spec), Vec::new())
        } else {
            self.msgs_out += self.deficit.len() as u64;
            (None, vec![ProducerAction::BroadcastCancel { id }])
        }
    }

    /// The engine has no further unprompted tasks. (It may still create
    /// tasks from completion callbacks — termination triggers only when
    /// nothing is pending or in flight.)
    pub fn set_engine_done(&mut self, done: bool) {
        self.engine_done = done;
    }

    /// True once every submitted task completed and nothing is pending.
    pub fn is_quiescent(&self) -> bool {
        self.engine_done && self.pending.is_empty() && self.in_flight() == 0
    }

    /// Emit the shutdown broadcast exactly once, when quiescent.
    pub fn maybe_shutdown(&mut self) -> Vec<ProducerAction> {
        if self.is_quiescent() && !self.shutdown_sent {
            self.shutdown_sent = true;
            self.msgs_out += self.deficit.len() as u64;
            vec![ProducerAction::BroadcastShutdown]
        } else {
            Vec::new()
        }
    }

    /// True once the shutdown broadcast went out.
    pub fn shutdown_sent(&self) -> bool {
        self.shutdown_sent
    }

    /// Begin a drain-and-graft transition: withhold further grants and
    /// tell every direct child to drain its subtree and ack. No-op when a
    /// recall is already in flight or the run is shutting down.
    pub fn begin_recall(&mut self) -> Vec<ProducerAction> {
        if self.recalling || self.shutdown_sent {
            return Vec::new();
        }
        self.recalling = true;
        for a in self.recall_acks.iter_mut() {
            *a = false;
        }
        self.msgs_out += self.deficit.len() as u64;
        vec![ProducerAction::BroadcastRecall]
    }

    /// True while a drain-and-graft transition is in flight.
    pub fn is_recalling(&self) -> bool {
        self.recalling
    }

    /// Recalled tasks arrive back from the tree. They re-enter the
    /// pending queue with their original `enqueued_t` stamps (the queue
    /// preserves existing stamps), so deadline slack and aging — and
    /// therefore the [`SchedPolicy`] order — survive the transition.
    /// Accounting is untouched: a recalled task was already counted
    /// `submitted` and is simply pending again, so `in_flight` and the
    /// Σcounts == popped conservation both hold across the graft.
    pub fn on_returned(&mut self, tasks: Vec<TaskSpec>) {
        self.msgs_in += 1;
        self.pending.extend(tasks);
    }

    /// Direct child `slot` reports its subtree drained. Returns true once
    /// every child has acked — the moment the runtime may tear down the
    /// old tree and graft the new shape.
    pub fn on_recall_ack(&mut self, slot: usize) -> bool {
        self.msgs_in += 1;
        if let Some(a) = self.recall_acks.get_mut(slot) {
            *a = true;
        }
        self.recalling && self.recall_acks.iter().all(|&a| a)
    }

    /// Direct child `slot`'s link died (remote worker crash or timeout).
    /// The child is treated as a recall that can never ack on its own:
    /// its outstanding credit is withdrawn so no further grants land on a
    /// dead link, and any in-flight recall is considered acked for this
    /// slot. The runtime re-queues whatever the child still held via
    /// [`Self::on_returned`], so conservation (`submitted` vs `completed`)
    /// is untouched — the lost tasks are simply pending again.
    pub fn on_child_dead(&mut self, slot: usize) {
        if let Some(d) = self.deficit.get_mut(slot) {
            *d = 0;
        }
        if let Some(a) = self.recall_acks.get_mut(slot) {
            *a = true;
        }
    }

    /// Attach the producer to a rebuilt tree with `num_buffers` direct
    /// children: deficits and the recall state reset, the pending queue
    /// and the submitted/completed accounting carry over.
    pub fn rewire(&mut self, num_buffers: usize) {
        let num_buffers = num_buffers.max(1);
        self.recalling = false;
        self.deficit = vec![0; num_buffers];
        self.recall_acks = vec![false; num_buffers];
        self.cursor = 0;
    }

    /// Every pending task (model-checker seam: conservation oracle and
    /// state fingerprints; see [`crate::check`]).
    pub fn iter_pending(&self) -> impl Iterator<Item = &TaskSpec> + '_ {
        self.pending.iter_tasks()
    }

    /// True when a recall is in flight and every direct child has acked —
    /// the all-acks moment [`Self::on_recall_ack`] reports, queryable
    /// after the fact (e.g. when [`Self::on_child_dead`] supplies the
    /// final implicit ack).
    pub fn recall_complete(&self) -> bool {
        self.recalling && self.recall_acks.iter().all(|&a| a)
    }

    /// Feed the protocol-visible producer state into `h` (model-checker
    /// seam). Message counters are excluded; everything that determines
    /// future behaviour — the pending queue, per-child deficits, the
    /// grant cursor, accounting, and the recall/shutdown flags — is in.
    pub fn model_hash(&self, h: &mut impl std::hash::Hasher) {
        self.pending.model_hash(h);
        for &d in &self.deficit {
            h.write_usize(d);
        }
        h.write_usize(self.cursor);
        h.write_u64(self.submitted);
        h.write_u64(self.completed);
        h.write_u8(u8::from(self.engine_done));
        h.write_u8(u8::from(self.shutdown_sent));
        h.write_u8(u8::from(self.recalling));
        for &a in &self.recall_acks {
            h.write_u8(u8::from(a));
        }
    }

    fn satisfy_deficits(&mut self) -> Vec<ProducerAction> {
        if self.recalling {
            // Credit withdrawal: grants resume once the graft completes.
            return Vec::new();
        }
        // Fairness under scarcity: when fewer tasks are pending than the
        // total outstanding deficit, granting each child its full credit
        // first-come-first-served would leave later children (and their
        // hundreds of consumers) starved. Grant in bounded chunks, round-
        // robin, until tasks or deficits run out — the paper's "repeatedly
        // send them to their consumers gradually", applied one level up.
        // Grants pop the pending queue in priority order, so the highest-
        // priority work reaches the tree first.
        const GRANT_CHUNK: usize = 32;
        let nb = self.deficit.len();
        let mut granted: Vec<Vec<TaskSpec>> = vec![Vec::new(); nb];
        let mut scanned = 0;
        while !self.pending.is_empty() && scanned < nb {
            let b = self.cursor;
            self.cursor = (self.cursor + 1) % nb;
            scanned += 1;
            // `b < nb` by the modulus above; Option::zip keeps that fact
            // local (no indexing, no task ever popped without a home).
            let Some((d, g)) = self.deficit.get_mut(b).zip(granted.get_mut(b)) else { break };
            if *d == 0 {
                continue;
            }
            let take = (*d).min(GRANT_CHUNK).min(self.pending.len());
            g.extend(self.pending.pop_n(take));
            *d -= take;
            scanned = 0; // keep scanning while anyone still has deficit
        }
        let mut out = Vec::new();
        for (b, tasks) in granted.into_iter().enumerate() {
            if !tasks.is_empty() {
                self.msgs_out += 1;
                out.push(ProducerAction::SendTasks { buffer: b, tasks });
            }
        }
        out
    }
}

/// What one leaf consumer is currently executing. The id/attempt pair is
/// always tracked (it drives attempt stamping and kill-on-cancel); the
/// full spec is kept only when a retry could fire, so retry-less dispatch
/// still skips the payload clone.
#[derive(Clone, Debug)]
struct RunningTask {
    id: TaskId,
    attempt: u32,
    spec: Option<TaskSpec>,
}

/// What a buffer node feeds: consumers (leaf) or child buffers (interior).
/// A leaf remembers what each consumer is executing so failed attempts can
/// be retried transparently and running attempts can be cancelled. Each
/// consumer holds a *queue* of dispatched attempts (front = executing,
/// the rest run-ahead work granted in the same `RunBatch`); with
/// `dispatch_batch == 1` the queue never exceeds one entry.
#[derive(Clone, Debug)]
enum Children {
    Consumers { n: usize, idle: VecDeque<usize>, running: Vec<VecDeque<RunningTask>> },
    Buffers { deficit: Vec<usize>, cursor: usize, subtree: usize },
}

impl RunningTask {
    fn track(task: &TaskSpec) -> Self {
        RunningTask {
            id: task.id,
            attempt: task.attempt,
            spec: if task.max_retries > 0 { Some(task.clone()) } else { None },
        }
    }
}

/// Buffer-node state: local task queue, children, result store, and the
/// demand-driven credit held against the parent.
#[derive(Clone, Debug)]
pub struct BufferState {
    children: Children,
    queue: PrioQueue,
    store: Vec<TaskResult>,
    /// Tasks requested from the parent but not yet received.
    outstanding_request: usize,
    /// Tasks requested from a sibling (steal) but not yet answered.
    steal_outstanding: usize,
    /// True after an unanswered-or-failed steal attempt; cleared whenever
    /// new tasks arrive. Starts true so startup credit goes to the parent.
    steal_tried: bool,
    steal_enabled: bool,
    steal_policy: StealPolicy,
    /// Last known queue depth per sibling slot (`usize::MAX` = unknown),
    /// maintained from steal replies and incoming steal requests.
    sibling_depth: Vec<usize>,
    my_slot: usize,
    n_siblings: usize,
    steal_cursor: usize,
    credit_factor: usize,
    flush_every: usize,
    /// Run-ahead dispatch depth: max tasks per `RunBatch` to one consumer
    /// (1 = pre-v10 per-task dispatch; see `SchedulerConfig::dispatch_batch`).
    dispatch_batch: usize,
    /// Merge a same-step credit request + result flush into one upstream
    /// `Flush` message (see `SchedulerConfig::coalesce_flush`).
    coalesce_flush: bool,
    shutting_down: bool,
    /// True after a recall notice: the node stops requesting and
    /// dispatching, drains its queue upstream, and acks when empty.
    recalling: bool,
    /// The recall ack went out (guards against double-acks when late
    /// steal traffic drains through an already-empty node).
    recall_acked: bool,
    /// Interior: which children have acked the recall.
    children_acked: Vec<bool>,
    max_queue: usize,
    pub steals_attempted: u64,
    /// Steal attempts answered with an empty grant.
    pub steals_failed: u64,
    /// Tasks gained from siblings.
    pub steals_received: u64,
    /// Tasks surrendered to siblings.
    pub steals_given: u64,
    /// Queued tasks dropped here by cancellation.
    pub cancelled_dropped: u64,
    /// Kill requests this leaf issued for a running attempt. A request
    /// may still lose the race to the attempt's natural completion, so
    /// this counts kills *asked for*, not kills that landed.
    pub cancelled_killed: u64,
    /// Failed attempts transparently re-queued here.
    pub retried: u64,
    /// Multi-task `RunBatch` dispatches sent (batches of ≥ 2 tasks; a
    /// batch of 1 is ordinary per-task dispatch and is not counted).
    pub dispatch_batches: u64,
    /// Upstream sends saved by coalescing a credit request and a result
    /// flush into one `Flush` message.
    pub coalesced_flushes: u64,
    /// Pending cancellation notices: ids cancelled while not locally
    /// queued — the task may be in flight *sideways* (inside a steal
    /// grant), so a later arrival is dropped on sight, or *running* here,
    /// so the final `Done` consumes the notice (suppressing any retry).
    /// Most such notices target tasks that already finished elsewhere
    /// (ids are never reused within a run), so the set is bounded: beyond
    /// [`TOMBSTONE_CAP`] the oldest notice is evicted — cancellation
    /// stays best-effort. Ordered so steal grants ship it
    /// deterministically.
    tombstones: BTreeSet<TaskId>,
    /// Insertion order of `tombstones`, for capped eviction.
    tombstone_order: VecDeque<TaskId>,
    /// This node's clock (mirrors the queue's; see [`BufferState::set_now`]).
    now: f64,
    /// When the oldest unanswered upstream request was sent — the start of
    /// the request→grant round trip being measured.
    request_sent_t: Option<f64>,
    /// Producer-lag accumulators: completed request→first-grant round
    /// trips (count / total / worst), per node, in (virtual) seconds.
    req_lag_n: u64,
    req_lag_sum: f64,
    req_lag_max: f64,
    pub msgs_in: u64,
    pub msgs_out: u64,
}

/// Upper bound on remembered unmatched cancellation notices per node.
const TOMBSTONE_CAP: usize = 1024;

impl BufferState {
    /// A leaf buffer feeding `n_consumers` consumers (stealing disabled) —
    /// the original two-level role.
    pub fn new(n_consumers: usize, credit_factor: usize, flush_every: usize) -> Self {
        // Clamp rather than assert (see ProducerState::new).
        let n_consumers = n_consumers.max(1);
        Self {
            children: Children::Consumers {
                n: n_consumers,
                idle: (0..n_consumers).collect(),
                running: vec![VecDeque::new(); n_consumers],
            },
            queue: PrioQueue::new(),
            store: Vec::new(),
            outstanding_request: 0,
            steal_outstanding: 0,
            steal_tried: true,
            steal_enabled: false,
            steal_policy: StealPolicy::DeepestQueue,
            sibling_depth: Vec::new(),
            my_slot: 0,
            n_siblings: 0,
            steal_cursor: 0,
            credit_factor: credit_factor.max(1),
            flush_every: flush_every.max(1),
            dispatch_batch: 1,
            coalesce_flush: false,
            shutting_down: false,
            recalling: false,
            recall_acked: false,
            children_acked: Vec::new(),
            max_queue: 0,
            steals_attempted: 0,
            steals_failed: 0,
            steals_received: 0,
            steals_given: 0,
            cancelled_dropped: 0,
            cancelled_killed: 0,
            retried: 0,
            dispatch_batches: 0,
            coalesced_flushes: 0,
            tombstones: BTreeSet::new(),
            tombstone_order: VecDeque::new(),
            now: 0.0,
            request_sent_t: None,
            req_lag_n: 0,
            req_lag_sum: 0.0,
            req_lag_max: 0.0,
            msgs_in: 0,
            msgs_out: 0,
        }
    }

    /// An interior relay node with `n_children` child buffers covering
    /// `subtree_consumers` consumers in total.
    pub fn interior(
        n_children: usize,
        subtree_consumers: usize,
        credit_factor: usize,
        flush_every: usize,
    ) -> Self {
        // Clamp rather than assert (see ProducerState::new).
        let n_children = n_children.max(1);
        let subtree_consumers = subtree_consumers.max(1);
        Self {
            children: Children::Buffers {
                deficit: vec![0; n_children],
                cursor: 0,
                subtree: subtree_consumers,
            },
            queue: PrioQueue::new(),
            store: Vec::new(),
            outstanding_request: 0,
            steal_outstanding: 0,
            steal_tried: true,
            steal_enabled: false,
            steal_policy: StealPolicy::DeepestQueue,
            sibling_depth: Vec::new(),
            my_slot: 0,
            n_siblings: 0,
            steal_cursor: 0,
            credit_factor: credit_factor.max(1),
            flush_every: flush_every.max(1),
            dispatch_batch: 1,
            coalesce_flush: false,
            shutting_down: false,
            recalling: false,
            recall_acked: false,
            children_acked: vec![false; n_children],
            max_queue: 0,
            steals_attempted: 0,
            steals_failed: 0,
            steals_received: 0,
            steals_given: 0,
            cancelled_dropped: 0,
            cancelled_killed: 0,
            retried: 0,
            dispatch_batches: 0,
            coalesced_flushes: 0,
            tombstones: BTreeSet::new(),
            tombstone_order: VecDeque::new(),
            now: 0.0,
            request_sent_t: None,
            req_lag_n: 0,
            req_lag_sum: 0.0,
            req_lag_max: 0.0,
            msgs_in: 0,
            msgs_out: 0,
        }
    }

    /// Use `policy` for the local queue (builder; call before any push).
    pub fn with_policy(mut self, policy: SchedPolicy) -> Self {
        self.queue.set_policy(policy);
        self
    }

    /// Attach the tenant-class table to the local queue (builder): class
    /// lanes order by their registered policy and pops interleave by
    /// fair-share weight at this node like everywhere else in the tree.
    pub fn with_classes(mut self, classes: crate::tenancy::ClassTable) -> Self {
        self.queue = std::mem::take(&mut self.queue).with_classes(classes);
        self
    }

    /// Advance this node's clock (forwarded to the local queue: enqueue
    /// stamps, deadline slack, aging, and the request→grant lag
    /// measurement are all evaluated against it).
    pub fn set_now(&mut self, now: f64) {
        self.now = now;
        self.queue.set_now(now);
    }

    /// Configure the hot-path batching knobs (builder): `dispatch_batch`
    /// run-ahead tasks per consumer dispatch (clamped to ≥ 1; 1 = per-task
    /// dispatch) and whether same-step request + flush pairs coalesce into
    /// one upstream `Flush` send. The raw constructors default to
    /// `(1, false)` — the pre-v10 message economy — so unit tests driving
    /// handlers directly see the historical per-action behaviour unless
    /// they opt in.
    pub fn with_batching(mut self, dispatch_batch: usize, coalesce_flush: bool) -> Self {
        self.dispatch_batch = dispatch_batch.max(1);
        self.coalesce_flush = coalesce_flush;
        self
    }

    /// Enable sibling work stealing. `my_slot` is this node's index among
    /// its parent's `n_siblings + 1` children.
    pub fn with_stealing(mut self, my_slot: usize, n_siblings: usize, policy: StealPolicy) -> Self {
        self.steal_enabled = n_siblings > 0;
        self.steal_policy = policy;
        self.my_slot = my_slot;
        self.n_siblings = n_siblings;
        self.steal_cursor = my_slot;
        self.sibling_depth = vec![usize::MAX; n_siblings + 1];
        self
    }

    /// Build the protocol state for tree node `id` — the single
    /// constructor both runtimes (threads, DES) use, so they can never
    /// disagree on a node's role, credit, or steal wiring.
    pub fn for_tree_node(topo: &TreeTopology, id: usize, cfg: &SchedulerConfig) -> Self {
        let Some(n) = topo.nodes.get(id) else {
            // Out-of-range id is a caller bug; degrade to a 1-consumer
            // leaf rather than panicking the tree down.
            return BufferState::new(1, cfg.credit_factor, cfg.flush_every)
                .with_batching(cfg.dispatch_batch, cfg.coalesce_flush)
                .with_policy(cfg.policy)
                .with_classes(cfg.class_table());
        };
        let state = match &n.kind {
            TreeNodeKind::Leaf { n_consumers, .. } => {
                BufferState::new(*n_consumers, cfg.credit_factor, cfg.flush_every)
            }
            TreeNodeKind::Interior { children } => BufferState::interior(
                children.len(),
                n.subtree_consumers,
                cfg.credit_factor,
                cfg.flush_every,
            ),
        };
        let state = state
            .with_batching(cfg.dispatch_batch, cfg.coalesce_flush)
            .with_policy(cfg.policy)
            .with_classes(cfg.class_table());
        if cfg.steal {
            state.with_stealing(n.slot, n.n_siblings, cfg.steal_policy)
        } else {
            state
        }
    }

    pub fn is_leaf(&self) -> bool {
        matches!(self.children, Children::Consumers { .. })
    }

    /// Local consumers (0 for interior nodes).
    pub fn n_consumers(&self) -> usize {
        match &self.children {
            Children::Consumers { n, .. } => *n,
            Children::Buffers { .. } => 0,
        }
    }

    /// Consumers in this node's subtree — the unit its credit is sized in.
    pub fn subtree_consumers(&self) -> usize {
        match &self.children {
            Children::Consumers { n, .. } => *n,
            Children::Buffers { subtree, .. } => *subtree,
        }
    }

    /// Upper bound the local queue is allowed to reach.
    pub fn credit_bound(&self) -> usize {
        self.credit_factor * self.subtree_consumers()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    pub fn idle_count(&self) -> usize {
        match &self.children {
            Children::Consumers { idle, .. } => idle.len(),
            Children::Buffers { .. } => 0,
        }
    }

    pub fn busy_count(&self) -> usize {
        match &self.children {
            Children::Consumers { n, idle, .. } => n - idle.len(),
            Children::Buffers { .. } => 0,
        }
    }

    pub fn store_len(&self) -> usize {
        self.store.len()
    }

    pub fn is_shutting_down(&self) -> bool {
        self.shutting_down
    }

    /// Counter snapshot for reports (`node`/`level`/`saw_shutdown` are
    /// caller-supplied context).
    pub fn stats(&self, node: usize, level: usize) -> NodeStats {
        NodeStats {
            node,
            level,
            subtree_consumers: self.subtree_consumers(),
            credit_bound: self.credit_bound(),
            max_queue: self.max_queue,
            msgs_in: self.msgs_in,
            msgs_out: self.msgs_out,
            steals_attempted: self.steals_attempted,
            steals_failed: self.steals_failed,
            steals_received: self.steals_received,
            steals_given: self.steals_given,
            cancelled_dropped: self.cancelled_dropped,
            cancelled_killed: self.cancelled_killed,
            retried: self.retried,
            dispatch_batches: self.dispatch_batches,
            coalesced_flushes: self.coalesced_flushes,
            popped: self.queue.popped(),
            wait_hist: self.queue.wait_hist(),
            class_stats: self.queue.class_stats(),
            req_lag_n: self.req_lag_n,
            req_lag_mean: if self.req_lag_n == 0 {
                0.0
            } else {
                self.req_lag_sum / self.req_lag_n as f64
            },
            req_lag_max: self.req_lag_max,
            saw_shutdown: self.shutting_down,
            // Link-layer traffic is accounted where the link lives (the
            // transport gateway), not in the protocol state machine.
            wire_msgs_in: 0,
            wire_msgs_out: 0,
            wire_bytes_in: 0,
            wire_bytes_out: 0,
        }
    }

    /// Startup: prime the pump by requesting a full credit of tasks from
    /// the parent (stealing is skipped — nobody has work yet).
    pub fn on_start(&mut self) -> Vec<BufferAction> {
        self.request_if_low()
    }

    /// Tasks arrived from the parent.
    pub fn on_assign(&mut self, tasks: Vec<TaskSpec>) -> Vec<BufferAction> {
        self.msgs_in += 1;
        // Close the request→grant round trip: the oldest unanswered
        // upstream request is now answered. This is the per-node producer
        // (parent) lag that drives adaptive tree shaping.
        if let Some(t0) = self.request_sent_t.take() {
            let lag = (self.now - t0).max(0.0);
            self.req_lag_n += 1;
            self.req_lag_sum += lag;
            self.req_lag_max = self.req_lag_max.max(lag);
        }
        self.outstanding_request = self.outstanding_request.saturating_sub(tasks.len().max(1));
        self.accept(tasks);
        if self.recalling {
            // A grant racing the recall notice: bounce the tasks straight
            // back upstream (stamps intact) instead of dispatching.
            let mut out = self.drain_queue_upstream();
            out.extend(self.flush_if_due());
            out.extend(self.maybe_ack_recall());
            return out;
        }
        let mut out = self.deliver();
        out.extend(self.request_if_low());
        // Tombstoned arrivals synthesize results straight into the store.
        out.extend(self.flush_if_due());
        self.seal(out)
    }

    /// Leaf: a local consumer finished a task (and is implicitly asking
    /// for more). A failed attempt with retries left is re-queued here —
    /// transparently to everything upstream. Single-result wrapper around
    /// [`Self::on_done_batch`].
    pub fn on_done(&mut self, consumer: usize, result: TaskResult) -> Vec<BufferAction> {
        self.on_done_batch(consumer, vec![result])
    }

    /// Leaf: a local consumer finished the whole batch it was dispatched
    /// (one result per task, in dispatch order) and is implicitly asking
    /// for more. One message carries every completion, so the per-message
    /// cost is paid once per batch. Each result is processed exactly as a
    /// per-task `Done` would be: retry/tombstone decisions are per result.
    pub fn on_done_batch(&mut self, consumer: usize, results: Vec<TaskResult>) -> Vec<BufferAction> {
        if !self.is_leaf() {
            // A mis-routed Done at an interior node (no local consumers)
            // degrades to a child flush instead of a panic — the results
            // still flow upstream, so conservation holds.
            return self.on_child_results(results);
        }
        self.msgs_in += 1;
        for mut result in results {
            let slot = match &mut self.children {
                Children::Consumers { running, .. } => {
                    running.get_mut(consumer).and_then(|q| q.pop_front())
                }
                Children::Buffers { .. } => None,
            };
            // A pending cancel for this id (kill requested while the
            // attempt raced to completion) is consumed by the final Done:
            // it must suppress any retry, and is moot once a result is in.
            let cancel_pending = self.consume_tombstone(result.id);
            match slot {
                Some(slot) => {
                    result.attempt = slot.attempt;
                    // Cancelled (killed) attempts are exempt from retry.
                    // `retry_spec` is Some exactly when the attempt failed
                    // *and* the tracked spec still has retry budget.
                    let failed = result.rc != 0 && result.rc != RC_CANCELLED;
                    let retry_spec = slot.spec.filter(|s| failed && s.attempt < s.max_retries);
                    match retry_spec {
                        Some(spec) if cancel_pending => {
                            // The attempt failed naturally while a cancel
                            // was pending: honour the cancel instead of
                            // burning a retry on a dead task.
                            self.cancelled_dropped += 1;
                            self.store.push(TaskResult::cancelled_for(&spec));
                        }
                        Some(mut spec) => {
                            spec.attempt += 1;
                            self.retried += 1;
                            self.queue.push(spec);
                            self.max_queue = self.max_queue.max(self.queue.len());
                        }
                        None => self.store.push(result),
                    }
                }
                // No tracked slot (e.g. a unit test driving Done directly):
                // the result passes through with the consumer-stamped attempt.
                None => self.store.push(result),
            }
        }
        let mut out = Vec::new();
        // While recalling, nothing is dispatched: the consumer goes idle
        // and anything queued (e.g. a retry re-queued just above) drains
        // back upstream for re-dispatch after the graft. A consumer still
        // holding run-ahead work (partial completions never happen — the
        // batch reports as one message — but unit tests may drive this)
        // stays busy rather than idling.
        let next = if self.recalling {
            Vec::new()
        } else {
            let want = self.dispatch_batch.min(self.queue.len());
            self.queue.pop_n(want)
        };
        if let Children::Consumers { idle, running, .. } = &mut self.children {
            let backlog = running.get(consumer).map_or(0, |q| q.len());
            if !next.is_empty() {
                if let Some(q) = running.get_mut(consumer) {
                    q.extend(next.iter().map(RunningTask::track));
                }
                self.msgs_out += 1;
                if next.len() > 1 {
                    self.dispatch_batches += 1;
                }
                out.push(BufferAction::RunBatch { consumer, tasks: next });
            } else if backlog == 0 {
                idle.push_back(consumer);
            }
        }
        if self.recalling {
            out.extend(self.drain_queue_upstream());
        }
        out.extend(self.request_if_low());
        out.extend(self.flush_if_due());
        if self.shutting_down && self.busy_count() == 0 {
            out.extend(self.final_flush());
        }
        out.extend(self.maybe_ack_recall());
        self.seal(out)
    }

    /// Interior: child slot `child` asked for `amount` more tasks.
    pub fn on_child_request(&mut self, child: usize, amount: usize) -> Vec<BufferAction> {
        self.msgs_in += 1;
        match &mut self.children {
            Children::Buffers { deficit, .. } => {
                if let Some(d) = deficit.get_mut(child) {
                    *d = d.saturating_add(amount);
                }
            }
            // A leaf has no child buffers: drop the stray request rather
            // than panic (nothing was promised, so nothing is lost).
            Children::Consumers { .. } => return Vec::new(),
        }
        if self.recalling {
            // Demand is remembered but not served: the child drains next.
            return Vec::new();
        }
        let mut out = self.deliver();
        out.extend(self.request_if_low());
        out
    }

    /// Interior: a child flushed results; batch them toward the parent.
    pub fn on_child_results(&mut self, results: Vec<TaskResult>) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.store.extend(results);
        if self.shutting_down {
            self.flush_now()
        } else {
            self.flush_if_due()
        }
    }

    /// Interior: child slot `child`'s coalesced ascent arrived — a credit
    /// request for `amount` more tasks plus flushed results in one message
    /// (see [`BufferAction::Flush`]). Semantically `on_child_results`
    /// followed by `on_child_request`, counted as the single message it
    /// travelled as; the store extension and the deficit registration are
    /// applied atomically before any downstream delivery.
    pub fn on_child_flush(
        &mut self,
        child: usize,
        amount: usize,
        results: Vec<TaskResult>,
    ) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.store.extend(results);
        if let Children::Buffers { deficit, .. } = &mut self.children {
            if let Some(d) = deficit.get_mut(child) {
                *d = d.saturating_add(amount);
            }
        }
        let mut out = Vec::new();
        if !self.recalling {
            // Demand is served immediately unless we are draining — a
            // recalling node remembers the deficit for after the graft,
            // exactly as `on_child_request` does.
            out = self.deliver();
            out.extend(self.request_if_low());
        }
        if self.shutting_down {
            out.extend(self.flush_now());
        } else {
            out.extend(self.flush_if_due());
        }
        self.seal(out)
    }

    /// A cancellation notice arrived. If the task is queued here, drop it
    /// and emit an `RC_CANCELLED` result through the normal result path.
    /// If it is *running* on a local consumer, ask the runtime to kill the
    /// attempt ([`BufferAction::CancelRunning`]); the consumer reports
    /// `RC_CANCELLED` through the ordinary `Done` path without consuming
    /// a retry. Otherwise remember the id as a tombstone — the task may
    /// be in flight sideways in a steal grant and is dropped on arrival —
    /// and (at an interior node) keep fanning the notice toward the
    /// leaves.
    pub fn on_cancel(&mut self, id: TaskId) -> Vec<BufferAction> {
        self.msgs_in += 1;
        if let Some(spec) = self.queue.remove(id) {
            self.cancelled_dropped += 1;
            self.store.push(TaskResult::cancelled_for(&spec));
            let mut out = self.flush_if_due();
            // Losing queue depth may put us below the low-water mark.
            out.extend(self.request_if_low());
            return self.seal(out);
        }
        if let Children::Consumers { running, .. } = &self.children {
            // The target may be mid-execution *or* run-ahead work queued
            // behind it in a dispatched batch — either way the runtime
            // kills/skips it and reports RC_CANCELLED in its position.
            if let Some(consumer) =
                running.iter().position(|q| q.iter().any(|r| r.id == id))
            {
                self.cancelled_killed += 1;
                self.msgs_out += 1;
                // Persist the notice: if the attempt beats the kill with a
                // natural *failure*, the pending cancel must suppress the
                // transparent retry (a success keeps its real result).
                self.remember_tombstone(id);
                return vec![BufferAction::CancelRunning { consumer, id }];
            }
        }
        self.remember_tombstone(id);
        if let Children::Buffers { deficit, .. } = &self.children {
            self.msgs_out += deficit.len() as u64;
            vec![BufferAction::CancelChildren { id }]
        } else {
            Vec::new()
        }
    }

    /// A sibling asked to steal up to `amount` queued tasks. Surrender at
    /// most half the queue (taken from the back — the coldest,
    /// lowest-priority tasks); the grant is sent even when empty so the
    /// thief can escalate. `thief` is the runtime's opaque routing token
    /// (echoed in the grant); `thief_slot` is the thief's sibling slot —
    /// it is evidently starved, so its depth estimate drops to zero.
    pub fn on_steal_request(
        &mut self,
        thief: usize,
        thief_slot: usize,
        amount: usize,
    ) -> Vec<BufferAction> {
        self.msgs_in += 1;
        if let Some(d) = self.sibling_depth.get_mut(thief_slot) {
            *d = 0;
        }
        let give = if self.shutting_down || self.recalling {
            0
        } else {
            amount.min(self.queue.len() / 2)
        };
        let tasks = self.queue.take_back(give);
        self.steals_given += tasks.len() as u64;
        self.msgs_out += 1;
        // Ship our pending (unmatched) cancellation notices with the
        // grant: if one of them targets a task currently moving sideways,
        // the thief must learn about it (BTreeSet order is deterministic).
        let cancels: Vec<TaskId> = self.tombstones.iter().copied().collect();
        let mut out = vec![BufferAction::StealGrant {
            thief,
            from_slot: self.my_slot,
            left: self.queue.len(),
            cancels,
            tasks,
        }];
        // Losing queue depth may put us below the low-water mark.
        out.extend(self.request_if_low());
        out
    }

    /// The answer to our steal request arrived (possibly empty), reporting
    /// the victim's remaining queue depth and carrying the victim's
    /// pending cancellation notices (merged before the loot is accepted,
    /// so a cancel racing the sideways move cannot be lost).
    pub fn on_steal_grant(
        &mut self,
        from_slot: usize,
        left: usize,
        cancels: Vec<TaskId>,
        tasks: Vec<TaskSpec>,
    ) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.steal_outstanding = 0;
        for id in cancels {
            self.remember_tombstone(id);
        }
        if let Some(d) = self.sibling_depth.get_mut(from_slot) {
            *d = left;
        }
        if tasks.is_empty() {
            self.steals_failed += 1;
        } else {
            self.steals_received += tasks.len() as u64;
            self.steal_tried = false;
        }
        self.accept(tasks);
        if self.recalling {
            // Loot racing the recall: bounce it upstream and — with the
            // last outstanding steal now answered — possibly ack.
            let mut out = self.drain_queue_upstream();
            out.extend(self.flush_if_due());
            out.extend(self.maybe_ack_recall());
            return out;
        }
        let mut out = self.deliver();
        // An empty grant leaves steal_tried set, so this escalates upstream.
        out.extend(self.request_if_low());
        // Tombstoned loot synthesizes results straight into the store.
        out.extend(self.flush_if_due());
        self.seal(out)
    }

    /// Parent announced shutdown. A leaf waits for running consumers; an
    /// interior node flushes and forwards immediately (the producer only
    /// broadcasts at quiescence, so no results are in flight below us).
    pub fn on_shutdown(&mut self) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.shutting_down = true;
        if self.is_leaf() {
            if self.busy_count() == 0 {
                self.final_flush()
            } else {
                Vec::new()
            }
        } else {
            let mut out = Vec::new();
            if !self.store.is_empty() {
                out.extend(self.flush_now());
            }
            self.msgs_out += 1;
            out.push(BufferAction::ShutdownChildren);
            out
        }
    }

    /// Periodic tick from the runtime (threaded mode): flush any results
    /// that have been sitting in the store.
    pub fn on_tick(&mut self) -> Vec<BufferAction> {
        if self.store.is_empty() {
            Vec::new()
        } else {
            self.flush_now()
        }
    }

    /// A recall notice arrived (drain-and-graft transition, see
    /// [`ProducerState::begin_recall`]). The node stops requesting and
    /// dispatching, returns its queued tasks upstream with `enqueued_t`
    /// preserved, forwards the notice to child buffers, and acks once its
    /// subtree is drained: a leaf waits for running attempts (their
    /// results flow up the ordinary path) and any outstanding steal
    /// reply; an interior node waits for every child's ack. Per-channel
    /// FIFO (threads) / latency-ordered delivery (DES) guarantee that a
    /// node's returned tasks and result flushes arrive at its parent
    /// before its ack, so when the producer holds every root's ack the
    /// old tree is provably empty.
    pub fn on_recall(&mut self) -> Vec<BufferAction> {
        self.msgs_in += 1;
        self.recalling = true;
        let mut out = self.drain_queue_upstream();
        if let Children::Buffers { deficit, .. } = &mut self.children {
            for d in deficit.iter_mut() {
                *d = 0;
            }
            self.msgs_out += self.children_acked.len() as u64;
            out.push(BufferAction::RecallChildren);
        }
        out.extend(self.flush_if_due());
        out.extend(self.maybe_ack_recall());
        out
    }

    /// Interior: a child returned recalled tasks. Tasks with a pending
    /// cancellation notice here are dropped and reported cancelled (the
    /// same conservation path as a tombstoned steal arrival); the rest
    /// are forwarded upstream untouched.
    pub fn on_child_returned(&mut self, tasks: Vec<TaskSpec>) -> Vec<BufferAction> {
        self.msgs_in += 1;
        let mut keep = Vec::with_capacity(tasks.len());
        for t in tasks {
            if self.consume_tombstone(t.id) {
                self.cancelled_dropped += 1;
                self.store.push(TaskResult::cancelled_for(&t));
            } else {
                keep.push(t);
            }
        }
        let mut out = Vec::new();
        if !keep.is_empty() {
            self.msgs_out += 1;
            out.push(BufferAction::ReturnTasks(keep));
        }
        out.extend(self.flush_if_due());
        out
    }

    /// Interior: child slot `child` acked the recall.
    pub fn on_child_recall_ack(&mut self, child: usize) -> Vec<BufferAction> {
        self.msgs_in += 1;
        if let Some(a) = self.children_acked.get_mut(child) {
            *a = true;
        }
        self.maybe_ack_recall()
    }

    /// True after a recall notice was received (the node is draining).
    pub fn is_recalling(&self) -> bool {
        self.recalling
    }

    /// Cumulative request→grant lag totals `(count, sum of seconds)` —
    /// the live signal the reshape controller rebuilds its rolling
    /// [`Calibration`] from (summed over the producer's direct children).
    pub fn req_lag_totals(&self) -> (u64, f64) {
        (self.req_lag_n, self.req_lag_sum)
    }

    /// Every locally queued task (model-checker seam).
    pub fn iter_queue(&self) -> impl Iterator<Item = &TaskSpec> + '_ {
        self.queue.iter_tasks()
    }

    /// Every result buffered in the local store (model-checker seam).
    pub fn iter_store(&self) -> impl Iterator<Item = &TaskResult> + '_ {
        self.store.iter()
    }

    /// `(consumer, id, attempt)` for every attempt dispatched to this
    /// leaf's consumers — the executing front plus any run-ahead batch
    /// tail, in execution order (empty for interior nodes). Model-checker
    /// seam: the uniqueness and conservation oracles count dispatched
    /// attempts through this.
    pub fn running_tasks(&self) -> Vec<(usize, TaskId, u32)> {
        match &self.children {
            Children::Consumers { running, .. } => running
                .iter()
                .enumerate()
                .flat_map(|(c, q)| q.iter().map(move |r| (c, r.id, r.attempt)))
                .collect(),
            Children::Buffers { .. } => Vec::new(),
        }
    }

    /// Feed the protocol-visible node state into `h` (model-checker
    /// seam). Pure instrumentation (message/steal/cancel counters,
    /// `max_queue`, request-lag accumulators) is excluded so states that
    /// differ only in metrics share a fingerprint.
    pub fn model_hash(&self, h: &mut impl std::hash::Hasher) {
        match &self.children {
            Children::Consumers { n, idle, running } => {
                h.write_u8(0);
                h.write_usize(*n);
                for &c in idle {
                    h.write_usize(c);
                }
                for q in running {
                    h.write_usize(q.len());
                    for r in q {
                        h.write_u64(r.id);
                        h.write_u32(r.attempt);
                        h.write_u8(u8::from(r.spec.is_some()));
                    }
                }
            }
            Children::Buffers { deficit, cursor, subtree } => {
                h.write_u8(1);
                for &d in deficit {
                    h.write_usize(d);
                }
                h.write_usize(*cursor);
                h.write_usize(*subtree);
            }
        }
        self.queue.model_hash(h);
        for r in &self.store {
            hash_result(r, h);
        }
        h.write_usize(self.outstanding_request);
        h.write_usize(self.steal_outstanding);
        h.write_u8(u8::from(self.steal_tried));
        for &d in &self.sibling_depth {
            h.write_usize(d);
        }
        h.write_usize(self.steal_cursor);
        h.write_u8(u8::from(self.shutting_down));
        h.write_u8(u8::from(self.recalling));
        h.write_u8(u8::from(self.recall_acked));
        for &a in &self.children_acked {
            h.write_u8(u8::from(a));
        }
        for &t in &self.tombstones {
            h.write_u64(t);
        }
    }

    /// Move the entire local queue upstream (recall drain). Uses
    /// `take_back`, not pops, so the per-band wait histograms keep
    /// counting *dispatches* only and Σcounts == popped conservation
    /// holds across the transition.
    fn drain_queue_upstream(&mut self) -> Vec<BufferAction> {
        let drained = self.queue.take_back(self.queue.len());
        if drained.is_empty() {
            return Vec::new();
        }
        self.msgs_out += 1;
        vec![BufferAction::ReturnTasks(drained)]
    }

    /// Emit the recall ack exactly once, when this subtree is drained.
    fn maybe_ack_recall(&mut self) -> Vec<BufferAction> {
        if !self.recalling || self.recall_acked || self.steal_outstanding > 0 {
            return Vec::new();
        }
        let drained = match &self.children {
            Children::Consumers { n, idle, .. } => idle.len() == *n,
            Children::Buffers { .. } => self.children_acked.iter().all(|&a| a),
        };
        if !drained || !self.queue.is_empty() {
            return Vec::new();
        }
        self.recall_acked = true;
        let mut out = Vec::new();
        if !self.store.is_empty() {
            out.extend(self.flush_now());
        }
        self.msgs_out += 1;
        out.push(BufferAction::AckRecall);
        out
    }

    /// Remember an unmatched cancellation notice, evicting the oldest
    /// once the capped set is full (ids are unique per run, so eviction
    /// can only downgrade an exotic late cancel back to best-effort).
    fn remember_tombstone(&mut self, id: TaskId) {
        if self.tombstones.insert(id) {
            self.tombstone_order.push_back(id);
            if self.tombstone_order.len() > TOMBSTONE_CAP {
                if let Some(old) = self.tombstone_order.pop_front() {
                    self.tombstones.remove(&old);
                }
            }
        }
    }

    /// Consume a pending cancellation notice, keeping the eviction order
    /// free of stale entries so the cap bounds *live* notices.
    fn consume_tombstone(&mut self, id: TaskId) -> bool {
        if self.tombstones.remove(&id) {
            if let Some(pos) = self.tombstone_order.iter().position(|&x| x == id) {
                self.tombstone_order.remove(pos);
            }
            true
        } else {
            false
        }
    }

    /// Take tasks into the local queue (common to assigns and steals). A
    /// task whose cancellation notice already passed through here was
    /// moving sideways when the cancel fired: drop it on arrival and
    /// report it cancelled through the normal result path.
    fn accept(&mut self, tasks: Vec<TaskSpec>) {
        if !tasks.is_empty() {
            self.steal_tried = false;
        }
        for task in tasks {
            if self.consume_tombstone(task.id) {
                self.cancelled_dropped += 1;
                self.store.push(TaskResult::cancelled_for(&task));
            } else {
                self.queue.push(task);
            }
        }
        self.max_queue = self.max_queue.max(self.queue.len());
    }

    /// Move queued tasks to whoever is asking below us.
    fn deliver(&mut self) -> Vec<BufferAction> {
        match &mut self.children {
            Children::Consumers { idle, running, .. } => {
                // Batched dispatch with a fairness floor: never give one
                // consumer more run-ahead than an even split of the
                // current queue over the currently idle consumers would
                // (`fair = ceil(q0/m)`), so batching cannot starve idle
                // siblings of a short queue. With `dispatch_batch == 1`
                // this is exactly the historical one-task-per-idler loop.
                let q0 = self.queue.len();
                let m = idle.len();
                if q0 == 0 || m == 0 {
                    return Vec::new();
                }
                let fair = q0.div_ceil(m);
                let k = self.dispatch_batch.min(fair).max(1);
                let mut out = Vec::new();
                while !self.queue.is_empty() {
                    let Some(consumer) = idle.pop_front() else { break };
                    let tasks = self.queue.pop_n(k.min(self.queue.len()));
                    if tasks.is_empty() {
                        idle.push_front(consumer);
                        break;
                    }
                    if let Some(q) = running.get_mut(consumer) {
                        q.extend(tasks.iter().map(RunningTask::track));
                    }
                    self.msgs_out += 1;
                    if tasks.len() > 1 {
                        self.dispatch_batches += 1;
                    }
                    out.push(BufferAction::RunBatch { consumer, tasks });
                }
                out
            }
            Children::Buffers { deficit, cursor, .. } => {
                // Same bounded round-robin as the producer, one level down.
                const GRANT_CHUNK: usize = 32;
                let nb = deficit.len();
                let mut granted: Vec<Vec<TaskSpec>> = vec![Vec::new(); nb];
                let mut scanned = 0;
                while !self.queue.is_empty() && scanned < nb {
                    let b = *cursor;
                    *cursor = (*cursor + 1) % nb;
                    scanned += 1;
                    // `b < nb` by the modulus above (see satisfy_deficits).
                    let Some((d, g)) = deficit.get_mut(b).zip(granted.get_mut(b)) else { break };
                    if *d == 0 {
                        continue;
                    }
                    let take = (*d).min(GRANT_CHUNK).min(self.queue.len());
                    g.extend(self.queue.pop_n(take));
                    *d -= take;
                    scanned = 0;
                }
                let mut out = Vec::new();
                for (b, tasks) in granted.into_iter().enumerate() {
                    if !tasks.is_empty() {
                        self.msgs_out += 1;
                        out.push(BufferAction::SendToChild { child: b, tasks });
                    }
                }
                out
            }
        }
    }

    fn request_if_low(&mut self) -> Vec<BufferAction> {
        if self.shutting_down || self.recalling {
            return Vec::new();
        }
        let low = self.subtree_consumers();
        let level = self.queue.len() + self.outstanding_request + self.steal_outstanding;
        if level >= low {
            return Vec::new();
        }
        let amount = self.credit_bound() - level;
        if self.steal_enabled && !self.steal_tried && self.steal_outstanding == 0 {
            // One steal probe per low-water episode; with no sibling to
            // rob (next_victim None) fall through to a parent request.
            self.steal_tried = true;
            if let Some(victim) = self.next_victim() {
                self.steal_outstanding = amount;
                self.steals_attempted += 1;
                self.msgs_out += 1;
                return vec![BufferAction::StealRequest { victim, amount }];
            }
        }
        self.outstanding_request += amount;
        self.msgs_out += 1;
        // Stamp the start of the (oldest outstanding) round trip.
        if self.request_sent_t.is_none() {
            self.request_sent_t = Some(self.now);
        }
        vec![BufferAction::RequestTasks { amount }]
    }

    /// Pick the steal victim: blind rotation (`RoundRobin`) or the sibling
    /// with the deepest known queue (`DeepestQueue`; unknown = deepest, so
    /// early attempts explore in rotation before exploiting estimates).
    /// `None` when the node has no sibling to rob.
    fn next_victim(&mut self) -> Option<usize> {
        if self.n_siblings == 0 {
            return None;
        }
        let total = self.n_siblings + 1;
        match self.steal_policy {
            StealPolicy::RoundRobin => {
                self.steal_cursor = (self.steal_cursor + 1) % total;
                if self.steal_cursor == self.my_slot {
                    self.steal_cursor = (self.steal_cursor + 1) % total;
                }
                Some(self.steal_cursor)
            }
            StealPolicy::DeepestQueue => {
                let mut best: Option<usize> = None;
                let mut best_depth = 0usize;
                for off in 1..=total {
                    let slot = (self.steal_cursor + off) % total;
                    if slot == self.my_slot {
                        continue;
                    }
                    let d = self.sibling_depth.get(slot).copied().unwrap_or(usize::MAX);
                    if best.is_none() || d > best_depth {
                        best = Some(slot);
                        best_depth = d;
                    }
                }
                let victim = best?;
                self.steal_cursor = victim;
                Some(victim)
            }
        }
    }

    fn flush_if_due(&mut self) -> Vec<BufferAction> {
        // Flush on batch-full, or as soon as there is nothing queued locally
        // (dynamic workloads need results to reach the engine promptly).
        if self.store.len() >= self.flush_every || (self.queue.is_empty() && !self.store.is_empty())
        {
            self.flush_now()
        } else {
            Vec::new()
        }
    }

    fn flush_now(&mut self) -> Vec<BufferAction> {
        self.msgs_out += 1;
        vec![BufferAction::FlushResults(std::mem::take(&mut self.store))]
    }

    /// Coalesce one same-step `RequestTasks` + non-empty `FlushResults`
    /// pair into a single [`BufferAction::Flush`] at the earlier action's
    /// position (handlers emit the pair in either order). Both halves
    /// still travel upstream and the receiver applies them atomically, so
    /// this changes only the message economy — one send instead of two —
    /// never the protocol outcome. No-op unless `coalesce_flush` is on.
    fn seal(&mut self, out: Vec<BufferAction>) -> Vec<BufferAction> {
        if !self.coalesce_flush {
            return out;
        }
        let req = out.iter().position(|a| matches!(a, BufferAction::RequestTasks { .. }));
        let flush = out
            .iter()
            .position(|a| matches!(a, BufferAction::FlushResults(rs) if !rs.is_empty()));
        let (Some(ri), Some(fi)) = (req, flush) else { return out };
        let mut amount = 0;
        let mut results = Vec::new();
        let mut sealed = Vec::with_capacity(out.len() - 1);
        for (i, a) in out.into_iter().enumerate() {
            match a {
                BufferAction::RequestTasks { amount: x } if i == ri => amount = x,
                BufferAction::FlushResults(rs) if i == fi => results = rs,
                other => sealed.push(other),
            }
        }
        sealed.insert(ri.min(fi), BufferAction::Flush { amount, results });
        self.msgs_out -= 1;
        self.coalesced_flushes += 1;
        sealed
    }

    fn final_flush(&mut self) -> Vec<BufferAction> {
        let mut out = Vec::new();
        if !self.store.is_empty() {
            out.extend(self.flush_now());
        }
        self.msgs_out += 1;
        out.push(BufferAction::ShutdownConsumers);
        out
    }
}

// --- model-checker seam (`caravan check`) --------------------------------
//
// The bounded model checker in [`crate::check`] drives ProducerState and
// BufferState directly, one message delivery at a time. The types and
// routing functions below are pure data plumbing — addressed protocol
// messages plus the action→message routing both runtimes already perform
// implicitly — and change no behaviour.

/// A protocol party: the rank-0 producer, or buffer-tree node `id`
/// (an index into [`TreeTopology::nodes`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Party {
    /// The rank-0 producer.
    Producer,
    /// Buffer-tree node by topology index.
    Node(usize),
}

impl std::fmt::Display for Party {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Party::Producer => write!(f, "producer"),
            Party::Node(id) => write!(f, "n{id}"),
        }
    }
}

/// A protocol-level message in flight between two parties — the payload
/// of one [`ModelStep`]. `Assign`/`Cancel`/`Recall`/`Shutdown` travel
/// parent→child, `Request`/`Results`/`Returned`/`RecallAck` child→parent,
/// and the steal pair sideways between siblings. This mirrors
/// [`crate::transport::wire::WireMsg`] one-to-one where the link protocol
/// overlaps.
#[derive(Clone, Debug, PartialEq)]
pub enum ProtoMsg {
    /// Parent → child: task grant.
    Assign(Vec<TaskSpec>),
    /// Parent → child: cancellation notice fanning toward the leaves.
    Cancel {
        /// Task to drop (queued), kill (running) or tombstone.
        id: TaskId,
    },
    /// Parent → child: drain-and-graft recall notice.
    Recall,
    /// Parent → child: orderly shutdown after quiescence.
    Shutdown,
    /// Child → parent: credit request.
    Request {
        /// Tasks wanted to refill the subtree's credit.
        amount: usize,
    },
    /// Child → parent: batched results.
    Results(Vec<TaskResult>),
    /// Child → parent: coalesced ascent — a credit request for `amount`
    /// more tasks and a result flush riding one message (wire v3; see
    /// [`BufferAction::Flush`]).
    Flush {
        /// Tasks wanted to refill the subtree's credit.
        amount: usize,
        /// The flushed results.
        results: Vec<TaskResult>,
    },
    /// Child → parent: recalled tasks returned upstream, stamps intact.
    Returned(Vec<TaskSpec>),
    /// Child → parent: the subtree is drained.
    RecallAck,
    /// Sibling → sibling: steal probe. `thief` is the requesting node's
    /// topology id (the routing token echoed back in the grant).
    StealRequest {
        /// Topology id of the requesting node.
        thief: usize,
        /// The thief's slot among the shared parent's children.
        thief_slot: usize,
        /// Upper bound on tasks wanted.
        amount: usize,
    },
    /// Sibling → sibling: steal reply (possibly empty).
    StealGrant {
        /// The victim's own slot.
        from_slot: usize,
        /// The victim's remaining queue depth.
        left: usize,
        /// The victim's pending cancellation notices, forwarded.
        cancels: Vec<TaskId>,
        /// The surrendered tasks.
        tasks: Vec<TaskSpec>,
    },
}

impl ProtoMsg {
    /// Feed this message's protocol-relevant content into `h` (a variant
    /// tag plus per-variant fields; payload bytes excluded, like
    /// [`PrioQueue::model_hash`]). The checker's visited-state fingerprint
    /// covers every in-flight message through this.
    pub fn model_hash(&self, h: &mut impl std::hash::Hasher) {
        match self {
            ProtoMsg::Assign(ts) => {
                h.write_u8(1);
                h.write_usize(ts.len());
                for t in ts {
                    hash_task(t, h);
                }
            }
            ProtoMsg::Cancel { id } => {
                h.write_u8(2);
                h.write_u64(*id);
            }
            ProtoMsg::Recall => h.write_u8(3),
            ProtoMsg::Shutdown => h.write_u8(4),
            ProtoMsg::Request { amount } => {
                h.write_u8(5);
                h.write_usize(*amount);
            }
            ProtoMsg::Results(rs) => {
                h.write_u8(6);
                h.write_usize(rs.len());
                for r in rs {
                    hash_result(r, h);
                }
            }
            ProtoMsg::Returned(ts) => {
                h.write_u8(7);
                h.write_usize(ts.len());
                for t in ts {
                    hash_task(t, h);
                }
            }
            ProtoMsg::RecallAck => h.write_u8(8),
            ProtoMsg::StealRequest { thief, thief_slot, amount } => {
                h.write_u8(9);
                h.write_usize(*thief);
                h.write_usize(*thief_slot);
                h.write_usize(*amount);
            }
            ProtoMsg::Flush { amount, results } => {
                h.write_u8(11);
                h.write_usize(*amount);
                h.write_usize(results.len());
                for r in results {
                    hash_result(r, h);
                }
            }
            ProtoMsg::StealGrant { from_slot, left, cancels, tasks } => {
                h.write_u8(10);
                h.write_usize(*from_slot);
                h.write_usize(*left);
                h.write_usize(cancels.len());
                for c in cancels {
                    h.write_u64(*c);
                }
                h.write_usize(tasks.len());
                for t in tasks {
                    hash_task(t, h);
                }
            }
        }
    }
}

/// One addressed protocol message: `msg` travelling `from → to`. The
/// model checker's unit of scheduling — each in-flight `ModelStep` sits
/// in a per-directed-edge FIFO, exactly like a channel (threads) or a
/// latency-ordered event (DES).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelStep {
    /// Sending party.
    pub from: Party,
    /// Receiving party.
    pub to: Party,
    /// The protocol payload.
    pub msg: ProtoMsg,
}

/// A node-local side effect of a [`BufferAction`] that does not travel
/// between tree parties: consumer dispatch and teardown at a leaf. The
/// model harness absorbs these into its own running-attempt bookkeeping;
/// the real runtimes act on the original actions.
#[derive(Clone, Debug, PartialEq)]
pub enum LocalEffect {
    /// Start `tasks` on local consumer `consumer`, back to back.
    RunBatch {
        /// Local consumer index.
        consumer: usize,
        /// The dispatched tasks, in execution order.
        tasks: Vec<TaskSpec>,
    },
    /// Kill (or skip, if still queued in its batch) the attempt dispatched
    /// to `consumer`; it reports `RC_CANCELLED` in its batch position.
    CancelRunning {
        /// Local consumer index.
        consumer: usize,
        /// The cancelled task's id.
        id: TaskId,
    },
    /// Stop all local consumers.
    ShutdownConsumers,
}

/// Node id of `parent`'s child at `slot` (`None` if out of range or the
/// party has no children).
fn child_of(topo: &TreeTopology, parent: Party, slot: usize) -> Option<usize> {
    match parent {
        Party::Producer => topo.roots.get(slot).copied(),
        Party::Node(id) => match &topo.nodes.get(id)?.kind {
            TreeNodeKind::Interior { children } => children.get(slot).copied(),
            TreeNodeKind::Leaf { .. } => None,
        },
    }
}

/// Node `id`'s parent as a party (the producer for level-1 nodes).
fn parent_of(topo: &TreeTopology, id: usize) -> Party {
    match topo.nodes.get(id).and_then(|n| n.parent) {
        Some(p) => Party::Node(p),
        None => Party::Producer,
    }
}

/// Child node ids of interior node `id` (empty for leaves).
fn children_of(topo: &TreeTopology, id: usize) -> &[usize] {
    match topo.nodes.get(id).map(|n| &n.kind) {
        Some(TreeNodeKind::Interior { children }) => children,
        _ => &[],
    }
}

/// Translate [`ProducerAction`]s into addressed [`ModelStep`]s for the
/// given topology. Broadcasts fan out to every direct child in slot
/// order, exactly as both runtimes route them.
pub fn route_producer_actions(topo: &TreeTopology, actions: Vec<ProducerAction>) -> Vec<ModelStep> {
    let mut out = Vec::new();
    let mut bcast = |out: &mut Vec<ModelStep>, msg: ProtoMsg| {
        for &r in &topo.roots {
            out.push(ModelStep { from: Party::Producer, to: Party::Node(r), msg: msg.clone() });
        }
    };
    for a in actions {
        match a {
            ProducerAction::SendTasks { buffer, tasks } => {
                if let Some(dst) = child_of(topo, Party::Producer, buffer) {
                    out.push(ModelStep {
                        from: Party::Producer,
                        to: Party::Node(dst),
                        msg: ProtoMsg::Assign(tasks),
                    });
                }
            }
            ProducerAction::BroadcastCancel { id } => bcast(&mut out, ProtoMsg::Cancel { id }),
            ProducerAction::BroadcastRecall => bcast(&mut out, ProtoMsg::Recall),
            ProducerAction::BroadcastShutdown => bcast(&mut out, ProtoMsg::Shutdown),
        }
    }
    out
}

/// Translate node `id`'s [`BufferAction`]s into addressed [`ModelStep`]s
/// plus leaf-local [`LocalEffect`]s for the given topology. Sideways
/// steal traffic resolves sibling slots through the shared parent; the
/// steal-grant reply routes by the `thief` token (the requesting node's
/// topology id, stamped by this function on the way out).
pub fn route_buffer_actions(
    topo: &TreeTopology,
    id: usize,
    actions: Vec<BufferAction>,
) -> (Vec<ModelStep>, Vec<LocalEffect>) {
    let me = Party::Node(id);
    let parent = parent_of(topo, id);
    let my_slot = topo.nodes.get(id).map_or(0, |n| n.slot);
    let mut steps = Vec::new();
    let mut effects = Vec::new();
    for a in actions {
        match a {
            BufferAction::RunBatch { consumer, tasks } => {
                effects.push(LocalEffect::RunBatch { consumer, tasks });
            }
            BufferAction::CancelRunning { consumer, id } => {
                effects.push(LocalEffect::CancelRunning { consumer, id });
            }
            BufferAction::ShutdownConsumers => effects.push(LocalEffect::ShutdownConsumers),
            BufferAction::SendToChild { child, tasks } => {
                if let Some(dst) = child_of(topo, me, child) {
                    steps.push(ModelStep {
                        from: me,
                        to: Party::Node(dst),
                        msg: ProtoMsg::Assign(tasks),
                    });
                }
            }
            BufferAction::RequestTasks { amount } => {
                steps.push(ModelStep { from: me, to: parent, msg: ProtoMsg::Request { amount } });
            }
            BufferAction::FlushResults(results) => {
                steps.push(ModelStep { from: me, to: parent, msg: ProtoMsg::Results(results) });
            }
            BufferAction::Flush { amount, results } => {
                steps.push(ModelStep {
                    from: me,
                    to: parent,
                    msg: ProtoMsg::Flush { amount, results },
                });
            }
            BufferAction::ReturnTasks(tasks) => {
                steps.push(ModelStep { from: me, to: parent, msg: ProtoMsg::Returned(tasks) });
            }
            BufferAction::AckRecall => {
                steps.push(ModelStep { from: me, to: parent, msg: ProtoMsg::RecallAck });
            }
            BufferAction::CancelChildren { id: cid } => {
                for &c in children_of(topo, id) {
                    steps.push(ModelStep {
                        from: me,
                        to: Party::Node(c),
                        msg: ProtoMsg::Cancel { id: cid },
                    });
                }
            }
            BufferAction::RecallChildren => {
                for &c in children_of(topo, id) {
                    steps.push(ModelStep { from: me, to: Party::Node(c), msg: ProtoMsg::Recall });
                }
            }
            BufferAction::ShutdownChildren => {
                for &c in children_of(topo, id) {
                    steps.push(ModelStep { from: me, to: Party::Node(c), msg: ProtoMsg::Shutdown });
                }
            }
            BufferAction::StealRequest { victim, amount } => {
                if let Some(dst) = child_of(topo, parent, victim) {
                    steps.push(ModelStep {
                        from: me,
                        to: Party::Node(dst),
                        msg: ProtoMsg::StealRequest { thief: id, thief_slot: my_slot, amount },
                    });
                }
            }
            BufferAction::StealGrant { thief, from_slot, left, cancels, tasks } => {
                steps.push(ModelStep {
                    from: me,
                    to: Party::Node(thief),
                    msg: ProtoMsg::StealGrant { from_slot, left, cancels, tasks },
                });
            }
        }
    }
    (steps, effects)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::Payload;

    fn task(id: u64) -> TaskSpec {
        TaskSpec::new(id, Payload::Sleep { seconds: 1.0 })
    }

    fn prio_task(id: u64, priority: u8) -> TaskSpec {
        let mut t = task(id);
        t.priority = priority;
        t
    }

    fn result(id: u64, consumer: usize) -> TaskResult {
        TaskResult {
            id,
            consumer,
            results: vec![],
            begin: 0.0,
            finish: 1.0,
            rc: 0,
            attempt: 0,
            timed_out: false,
        }
    }

    fn failed(id: u64, consumer: usize) -> TaskResult {
        TaskResult { rc: 1, ..result(id, consumer) }
    }

    #[test]
    fn prio_queue_orders_by_priority_then_fifo() {
        let mut q = PrioQueue::new();
        q.push(prio_task(0, 1));
        q.push(prio_task(1, 5));
        q.push(prio_task(2, 1));
        q.push(prio_task(3, 5));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|t| t.id).collect();
        assert_eq!(order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn prio_queue_back_is_coldest_and_remove_by_id() {
        let mut q = PrioQueue::new();
        for (id, p) in [(0u64, 9u8), (1, 0), (2, 0), (3, 9)] {
            q.push(prio_task(id, p));
        }
        assert!(q.remove(2).is_some());
        assert!(q.remove(2).is_none());
        // Back = lowest priority, latest first; take_back returns them in
        // (reversed) queue order.
        let back = q.take_back(1);
        assert_eq!(back.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().id, 0);
    }

    /// A task with a deadline: enqueued at `t`, budget `timeout` seconds.
    fn deadline_task(id: u64, priority: u8, t: f64, timeout: f64) -> TaskSpec {
        let mut task = prio_task(id, priority);
        task.enqueued_t = Some(t);
        task.timeout_s = Some(timeout);
        task
    }

    #[test]
    fn deadline_policy_pops_least_slack_within_a_band() {
        let mut q = PrioQueue::with_policy(SchedPolicy::Deadline);
        q.push(deadline_task(0, 0, 0.0, 100.0)); // deadline 100
        q.push(deadline_task(1, 0, 0.0, 10.0)); // deadline 10
        q.push(prio_task(2, 0)); // no deadline: sorts last in the band
        q.push(deadline_task(3, 0, 5.0, 20.0)); // deadline 25
        q.push(deadline_task(4, 9, 0.0, 500.0)); // higher band still wins
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|t| t.id).collect();
        assert_eq!(order, vec![4, 1, 3, 0, 2]);
    }

    #[test]
    fn deadline_policy_back_is_loosest_deadline() {
        let mut q = PrioQueue::with_policy(SchedPolicy::Deadline);
        q.push(deadline_task(0, 0, 0.0, 10.0));
        q.push(deadline_task(1, 0, 0.0, 99.0));
        q.push(deadline_task(2, 5, 0.0, 1.0));
        // Steals take the cold end: lowest band, loosest deadline.
        let back = q.take_back(1);
        assert_eq!(back.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn aging_promotes_starved_band_after_step_waits() {
        // The sustained-stream shape: fresh priority-3 tasks keep
        // arriving (each with a new enqueue stamp, so their band's boost
        // stays 0), while the priority-0 probe from t = 0 waits. The
        // probe's boost grows with its wait and wins once it clears the
        // stream's *effective* priority.
        let mut q = PrioQueue::with_policy(SchedPolicy::Aging { step: 10.0 });
        q.set_now(0.0);
        q.push(prio_task(0, 0)); // the probe
        q.push(prio_task(100, 3));
        assert_eq!(q.pop().unwrap().id, 100, "no boost yet: base bands rule");
        // t = 35: probe boost = 3 → effective 3; a fresh priority-3 task
        // also sits at effective 3 — ties go to the higher base band.
        q.set_now(35.0);
        q.push(prio_task(101, 3));
        assert_eq!(q.pop().unwrap().id, 101);
        // t = 41: probe boost = 4 → effective 4 beats any fresh band-3.
        q.set_now(41.0);
        q.push(prio_task(102, 3));
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 102);
        assert!(q.is_empty());
    }

    #[test]
    fn aging_zero_or_negative_step_degrades_to_deadline_order() {
        let mut q = PrioQueue::with_policy(SchedPolicy::Aging { step: 0.0 });
        q.set_now(100.0);
        q.push(prio_task(0, 0));
        q.push(prio_task(1, 7));
        assert_eq!(q.pop().unwrap().id, 1, "no boost when step is 0");
    }

    #[test]
    fn queue_stamps_enqueue_time_once() {
        let mut q = PrioQueue::new();
        q.set_now(7.5);
        q.push(task(0));
        let mut t = task(1);
        t.enqueued_t = Some(2.0); // already stamped upstream: preserved
        q.push(t);
        let a = q.pop().unwrap();
        let b = q.pop().unwrap();
        assert_eq!(a.enqueued_t, Some(7.5));
        assert_eq!(b.enqueued_t, Some(2.0));
    }

    /// A task in the given tenant class.
    fn class_task(id: u64, class: ClassId) -> TaskSpec {
        let mut t = task(id);
        t.class = class;
        t
    }

    fn two_classes(wa: u32, wb: u32) -> ClassTable {
        use crate::tenancy::JobClass;
        ClassTable::from_registry(&[JobClass::new("a", wa), JobClass::new("b", wb)])
    }

    #[test]
    fn fair_share_interleaves_pops_by_weight() {
        // Weights 2:1 — over any busy interval class 0 gets two pops per
        // class-1 pop, and the rotor skips drained lanes.
        let mut q = PrioQueue::new().with_classes(two_classes(2, 1));
        for i in 0..6 {
            q.push(class_task(i, 0));
        }
        for i in 10..16 {
            q.push(class_task(i, 1));
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|t| t.id).collect();
        assert_eq!(order, vec![0, 1, 10, 2, 3, 11, 4, 5, 12, 13, 14, 15]);
    }

    #[test]
    fn class_stats_decompose_dispatch_counters() {
        let mut q = PrioQueue::new().with_classes(two_classes(1, 1));
        for i in 0..4 {
            q.push(class_task(i, 0));
        }
        for i in 10..13 {
            q.push(class_task(i, 1));
        }
        while q.pop().is_some() {}
        let stats = q.class_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats.iter().map(|s| s.popped).sum::<u64>(), q.popped());
        for s in &stats {
            let hist: u64 = s.wait_hist.iter().flat_map(|h| h.counts.iter()).sum();
            assert_eq!(hist, s.popped, "class {} wait-hist must cover its pops", s.class);
        }
        assert_eq!(stats[0].popped, 4);
        assert_eq!(stats[1].popped, 3);
    }

    #[test]
    fn single_tenant_queue_reports_no_class_stats() {
        let mut q = PrioQueue::new();
        q.push(task(0));
        q.pop();
        assert!(q.class_stats().is_empty(), "pre-tenancy reports must not grow class rows");
    }

    #[test]
    fn take_back_surrenders_from_the_longest_lane() {
        let mut q = PrioQueue::new().with_classes(two_classes(1, 1));
        for i in 0..3 {
            q.push(class_task(i, 0));
        }
        q.push(class_task(10, 1));
        // Lane 0 holds the most backlog, so steals drain its cold end
        // first; the short lane keeps its work.
        let back = q.take_back(2);
        assert_eq!(back.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().id, 0);
    }

    #[test]
    fn registered_class_policy_survives_set_policy() {
        use crate::tenancy::JobClass;
        let classes = ClassTable::from_registry(&[
            JobClass::new("s", 1),
            JobClass::new("d", 1).policy(SchedPolicy::Deadline),
        ]);
        let mut q = PrioQueue::new().with_classes(classes);
        q.push(class_task(0, 0));
        q.push(class_task(1, 1));
        q.push(class_task(2, 7)); // unregistered: follows the default
        q.set_policy(SchedPolicy::Aging { step: 5.0 });
        assert_eq!(q.lanes[&0].policy, SchedPolicy::Strict);
        assert_eq!(q.lanes[&1].policy, SchedPolicy::Deadline);
        assert_eq!(q.lanes[&7].policy, SchedPolicy::Aging { step: 5.0 });
    }

    #[test]
    fn every_policy_is_fifo_within_an_equal_priority_equal_slack_band() {
        // Satellite property: same-priority, same-deadline jobs may never
        // be reordered — FIFO within a band under every policy, so the
        // two runtimes cannot disagree on tie order.
        use crate::testutil::{check, pair, u64_in, usize_in, vec_of};
        check(
            "PrioQueue is FIFO within an equal-priority/equal-slack band",
            pair(vec_of(pair(usize_in(0..3), usize_in(0..3)), 1..40), u64_in(0..3)),
            |case: &(Vec<(usize, usize)>, u64)| {
                let (jobs, policy_idx) = case;
                let policy = [
                    SchedPolicy::Strict,
                    SchedPolicy::Deadline,
                    SchedPolicy::Aging { step: 5.0 },
                ][*policy_idx as usize];
                let mut q = PrioQueue::with_policy(policy);
                q.set_now(0.0);
                // Priority from the generator; deadline class fixed per
                // (priority, class) pair so bands contain exact ties.
                for (id, &(prio, class)) in jobs.iter().enumerate() {
                    let mut t = prio_task(id as u64, prio as u8);
                    t.enqueued_t = Some(0.0);
                    t.timeout_s = Some(10.0 * (class as f64 + 1.0));
                    q.push(t);
                }
                q.set_now(1.0);
                let popped: Vec<TaskSpec> = std::iter::from_fn(|| q.pop()).collect();
                if popped.len() != jobs.len() {
                    return false;
                }
                // Within every (priority, deadline) class, ids must come
                // out in submission (= id) order.
                for (prio, class) in
                    popped.iter().map(|t| (t.priority, t.timeout_s.unwrap() as u64))
                {
                    let ids: Vec<u64> = popped
                        .iter()
                        .filter(|t| t.priority == prio && t.timeout_s.unwrap() as u64 == class)
                        .map(|t| t.id)
                        .collect();
                    if ids.windows(2).any(|w| w[0] > w[1]) {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn producer_satisfies_requests_in_round_robin() {
        let mut p = ProducerState::new(2);
        assert!(p.on_request(0, 3).is_empty()); // nothing pending yet
        assert!(p.on_request(1, 3).is_empty());
        let acts = p.push_tasks((0..4).map(task).collect());
        // 4 tasks split across the two deficits, fairness via round-robin.
        let mut granted = [0usize; 2];
        for a in &acts {
            if let ProducerAction::SendTasks { buffer, tasks } = a {
                granted[*buffer] += tasks.len();
            }
        }
        assert_eq!(granted[0] + granted[1], 4);
        assert!(granted[0] > 0 && granted[1] > 0, "{granted:?}");
        assert_eq!(p.pending_len(), 0);
        assert_eq!(p.in_flight(), 4);
    }

    #[test]
    fn producer_grants_highest_priority_first() {
        let mut p = ProducerState::new(1);
        p.push_tasks(vec![prio_task(0, 0), prio_task(1, 9), prio_task(2, 5)]);
        let acts = p.on_request(0, 2);
        let ids: Vec<u64> = acts
            .iter()
            .flat_map(|a| match a {
                ProducerAction::SendTasks { tasks, .. } => {
                    tasks.iter().map(|t| t.id).collect::<Vec<_>>()
                }
                _ => Vec::new(),
            })
            .collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(p.pending_len(), 1);
    }

    #[test]
    fn producer_queues_tasks_without_deficit() {
        let mut p = ProducerState::new(1);
        let acts = p.push_tasks(vec![task(0)]);
        assert!(acts.is_empty());
        assert_eq!(p.pending_len(), 1);
        let acts = p.on_request(0, 10);
        assert_eq!(acts.len(), 1);
        assert_eq!(p.pending_len(), 0);
    }

    #[test]
    fn producer_cancel_drops_pending_or_broadcasts() {
        let mut p = ProducerState::new(2);
        p.push_tasks(vec![task(0), task(1)]);
        p.set_engine_done(true);
        // Task 1 is still pending: dropped locally, counts as completed.
        let (dropped, acts) = p.on_cancel(1);
        assert_eq!(dropped.unwrap().id, 1);
        assert!(acts.is_empty());
        assert_eq!(p.cancelled_pending(), 1);
        assert_eq!(p.in_flight(), 1);
        // Task 0 leaves the producer; a later cancel becomes a broadcast.
        p.on_request(0, 1);
        let (dropped, acts) = p.on_cancel(0);
        assert!(dropped.is_none());
        assert_eq!(acts, vec![ProducerAction::BroadcastCancel { id: 0 }]);
        // The cancelled-at-a-node result flows back like any other.
        p.on_results(1);
        assert_eq!(p.maybe_shutdown(), vec![ProducerAction::BroadcastShutdown]);
    }

    #[test]
    fn producer_shutdown_only_when_quiescent_and_once() {
        let mut p = ProducerState::new(1);
        p.push_tasks(vec![task(0)]);
        p.set_engine_done(true);
        assert!(p.maybe_shutdown().is_empty()); // pending
        p.on_request(0, 1);
        assert!(p.maybe_shutdown().is_empty()); // in flight
        p.on_results(1);
        assert_eq!(p.maybe_shutdown(), vec![ProducerAction::BroadcastShutdown]);
        assert!(p.maybe_shutdown().is_empty()); // idempotent
    }

    #[test]
    fn buffer_requests_on_start_and_dispatches_on_assign() {
        let mut b = BufferState::new(4, 2, 100);
        let acts = b.on_start();
        assert_eq!(acts, vec![BufferAction::RequestTasks { amount: 8 }]);
        let acts = b.on_assign((0..8).map(task).collect());
        let runs = acts
            .iter()
            .filter(|a| matches!(a, BufferAction::RunBatch { .. }))
            .count();
        assert_eq!(runs, 4); // all four consumers started
        assert_eq!(b.queue_len(), 4);
        assert_eq!(b.idle_count(), 0);
    }

    #[test]
    fn buffer_done_feeds_next_task_and_requests_when_low() {
        let mut b = BufferState::new(2, 2, 100);
        b.on_start();
        b.on_assign(vec![task(0), task(1), task(2)]);
        // queue=1, outstanding=1 (asked 4, got 3): level 2 == n_consumers, no request.
        let acts = b.on_done(0, result(0, 0));
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RunBatch { consumer: 0, .. })));
        // After dispatch queue=0, level=1 < 2 → request to restore credit 4.
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RequestTasks { amount: 3 })));
        // Queue empty → results flush immediately.
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 1)));
    }

    #[test]
    fn buffer_dispatches_high_priority_first() {
        let mut b = BufferState::new(1, 4, 100);
        b.on_start();
        let acts = b.on_assign(vec![prio_task(0, 0), prio_task(1, 7), prio_task(2, 3)]);
        // The single consumer gets the priority-7 task first.
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::RunBatch { consumer: 0, tasks } if tasks.iter().any(|t| t.id == 1))));
        let acts = b.on_done(0, result(1, 0));
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::RunBatch { consumer: 0, tasks } if tasks.iter().any(|t| t.id == 2))));
    }

    #[test]
    fn failed_attempt_with_retries_is_requeued_transparently() {
        let mut b = BufferState::new(1, 2, 1);
        b.on_start();
        let mut t = task(0);
        t.max_retries = 2;
        b.on_assign(vec![t]);
        // Attempt 0 fails: re-queued (attempt 1) and re-dispatched; nothing
        // is flushed upstream.
        let acts = b.on_done(0, failed(0, 0));
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::FlushResults(_))), "{acts:?}");
        let redisp = acts.iter().find_map(|a| match a {
            BufferAction::RunBatch { tasks, .. } => tasks.first().cloned(),
            _ => None,
        });
        assert_eq!(redisp.as_ref().map(|t| t.attempt), Some(1));
        assert_eq!(b.retried, 1);
        // Attempt 1 fails: one retry left.
        let acts = b.on_done(0, failed(0, 0));
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RunBatch { tasks, .. } if tasks.iter().any(|t| t.attempt == 2))));
        // Attempt 2 fails: retries exhausted → the failure is flushed with
        // the attempt count on it.
        let acts = b.on_done(0, failed(0, 0));
        let flushed = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.clone()),
                _ => None,
            })
            .expect("final failure must flush");
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].rc, 1);
        assert_eq!(flushed[0].attempt, 2);
        assert_eq!(b.retried, 2);
    }

    #[test]
    fn successful_retry_reports_attempt_index() {
        let mut b = BufferState::new(1, 2, 1);
        b.on_start();
        let mut t = task(7);
        t.max_retries = 3;
        b.on_assign(vec![t]);
        b.on_done(0, failed(7, 0));
        let acts = b.on_done(0, result(7, 0));
        let flushed = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.clone()),
                _ => None,
            })
            .expect("success must flush");
        assert_eq!(flushed[0].rc, 0);
        assert_eq!(flushed[0].attempt, 1);
    }

    #[test]
    fn cancel_drops_queued_task_and_reports_it() {
        let mut b = BufferState::new(1, 4, 1);
        b.on_start();
        b.on_assign(vec![task(0), task(1), task(2)]);
        // Task 0 runs; 1 and 2 are queued. Cancel 2: dropped, reported.
        let acts = b.on_cancel(2);
        let flushed = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.clone()),
                _ => None,
            })
            .expect("cancellation must flush a result");
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].id, 2);
        assert!(flushed[0].cancelled());
        assert_eq!(b.cancelled_dropped, 1);
        assert_eq!(b.queue_len(), 1);
        // Cancelling the *running* task asks the runtime to kill it.
        let acts = b.on_cancel(0);
        assert_eq!(acts, vec![BufferAction::CancelRunning { consumer: 0, id: 0 }]);
        assert_eq!(b.cancelled_dropped, 1);
        assert_eq!(b.cancelled_killed, 1);
        // The killed attempt reports RC_CANCELLED through the normal Done
        // path and must not be retried even with budget left.
        let killed = TaskResult { rc: RC_CANCELLED, ..result(0, 0) };
        let acts = b.on_done(0, killed);
        assert!(
            acts.iter().any(
                |a| matches!(a, BufferAction::FlushResults(rs) if rs.iter().any(|r| r.id == 0 && r.cancelled()))
            ),
            "{acts:?}"
        );
        assert_eq!(b.retried, 0);
    }

    #[test]
    fn cancel_pending_on_running_task_suppresses_retry_on_natural_failure() {
        let mut b = BufferState::new(1, 2, 1);
        b.on_start();
        let mut t = task(3);
        t.max_retries = 5;
        b.on_assign(vec![t]);
        // Cancel while running: kill requested, the notice is kept.
        let acts = b.on_cancel(3);
        assert_eq!(acts, vec![BufferAction::CancelRunning { consumer: 0, id: 3 }]);
        // The attempt fails naturally before the kill lands: the pending
        // cancel wins — no retry is burned, a cancelled result flows.
        let acts = b.on_done(0, failed(3, 0));
        let flushed = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.clone()),
                _ => None,
            })
            .expect("must flush");
        assert!(flushed[0].cancelled(), "{flushed:?}");
        assert_eq!(b.retried, 0);
        // A success beating the kill keeps its real result.
        let mut t = task(4);
        t.max_retries = 5;
        b.on_assign(vec![t]);
        let acts = b.on_cancel(4);
        assert!(
            acts.iter().any(|a| matches!(a, BufferAction::CancelRunning { .. })),
            "{acts:?}"
        );
        let acts = b.on_done(0, result(4, 0));
        let flushed = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.clone()),
                _ => None,
            })
            .expect("must flush");
        assert!(flushed[0].ok(), "{flushed:?}");
    }

    #[test]
    fn cancel_for_unknown_task_leaves_tombstone_that_drops_later_arrival() {
        // Satellite repro: a cancel racing a sideways steal. The thief
        // receives the cancel notice *before* the stolen task arrives; the
        // tombstone must drop the task on arrival instead of running it.
        let mut thief = BufferState::new(1, 4, 1).with_stealing(0, 1, StealPolicy::RoundRobin);
        thief.on_start();
        thief.on_assign(vec![task(0)]); // consumer busy with task 0
        let acts = thief.on_cancel(42); // not queued, not running here
        assert!(acts.is_empty(), "{acts:?}");
        // The stolen task lands afterwards: dropped, reported cancelled.
        let acts = thief.on_steal_grant(1, 0, Vec::new(), vec![task(42), task(43)]);
        assert!(
            acts.iter().any(
                |a| matches!(a, BufferAction::FlushResults(rs) if rs.iter().any(|r| r.id == 42 && r.cancelled()))
            ),
            "{acts:?}"
        );
        assert_eq!(thief.cancelled_dropped, 1);
        assert_eq!(thief.queue_len(), 1, "the untargeted loot is queued");
        // A second grant with the same id cannot double-report: the
        // tombstone was consumed (ids are unique per run anyway).
        let acts = thief.on_steal_grant(1, 0, Vec::new(), vec![task(44)]);
        assert!(
            !acts.iter().any(
                |a| matches!(a, BufferAction::FlushResults(rs) if rs.iter().any(|r| r.cancelled()))
            ),
            "{acts:?}"
        );
    }

    #[test]
    fn steal_grant_forwards_victims_pending_cancels() {
        // The other ordering of the race: the victim hears the cancel
        // while the steal is in flight and must forward the notice with
        // the grant so the thief can apply it.
        let mut victim = BufferState::new(1, 8, 100).with_stealing(1, 1, StealPolicy::RoundRobin);
        victim.on_start();
        victim.on_assign((0..6).map(task).collect()); // task 0 runs, 1-5 queued
        // Cancel for a task the victim does not hold → tombstoned.
        victim.on_cancel(99);
        let acts = victim.on_steal_request(0, 0, 2);
        let (cancels, tasks) = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::StealGrant { cancels, tasks, .. } => {
                    Some((cancels.clone(), tasks.clone()))
                }
                _ => None,
            })
            .expect("victim must reply");
        assert_eq!(cancels, vec![99]);
        assert_eq!(tasks.len(), 2);
        // The thief merges the forwarded notice: when task 99 later
        // reaches it (e.g. via a relayed assign), it is dropped on sight.
        let mut thief = BufferState::new(1, 8, 1).with_stealing(0, 1, StealPolicy::RoundRobin);
        thief.on_start();
        thief.on_assign(vec![task(50)]); // keep the consumer busy
        thief.on_steal_grant(1, 4, cancels, tasks);
        let acts = thief.on_assign(vec![task(99)]);
        assert!(
            acts.iter().any(
                |a| matches!(a, BufferAction::FlushResults(rs) if rs.iter().any(|r| r.id == 99 && r.cancelled()))
            ),
            "{acts:?}"
        );
        assert_eq!(thief.cancelled_dropped, 1);
    }

    #[test]
    fn interior_cancel_forwards_when_not_queued_here() {
        let mut r = BufferState::interior(3, 6, 2, 16);
        r.on_start();
        let acts = r.on_cancel(42);
        assert_eq!(acts, vec![BufferAction::CancelChildren { id: 42 }]);
        // But a task queued at the relay is dropped right here.
        r.on_assign(vec![task(5)]);
        let acts = r.on_cancel(5);
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs[0].cancelled())));
        assert_eq!(r.cancelled_dropped, 1);
    }

    #[test]
    fn buffer_batches_results_while_queue_nonempty() {
        let mut b = BufferState::new(1, 8, 3);
        b.on_start();
        b.on_assign((0..8).map(task).collect());
        // Two completions: queue still nonempty, store below flush_every → no flush.
        let a1 = b.on_done(0, result(0, 0));
        assert!(!a1.iter().any(|a| matches!(a, BufferAction::FlushResults(_))));
        let a2 = b.on_done(0, result(1, 0));
        assert!(!a2.iter().any(|a| matches!(a, BufferAction::FlushResults(_))));
        // Third completion hits flush_every = 3.
        let a3 = b.on_done(0, result(2, 0));
        assert!(a3
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 3)));
    }

    #[test]
    fn buffer_shutdown_waits_for_running_consumers() {
        let mut b = BufferState::new(2, 1, 100);
        b.on_start();
        b.on_assign(vec![task(0), task(1)]);
        let acts = b.on_shutdown();
        assert!(acts.is_empty(), "must wait for busy consumers");
        b.on_done(0, result(0, 0));
        let acts = b.on_done(1, result(1, 1));
        assert!(acts.iter().any(|a| matches!(a, BufferAction::ShutdownConsumers)));
        // All results eventually flushed.
        let flushed: usize = acts
            .iter()
            .filter_map(|a| match a {
                BufferAction::FlushResults(rs) => Some(rs.len()),
                _ => None,
            })
            .sum();
        assert!(flushed >= 1);
    }

    #[test]
    fn buffer_tick_flushes_stale_results() {
        let mut b = BufferState::new(1, 4, 100);
        b.on_start();
        b.on_assign((0..4).map(task).collect());
        b.on_done(0, result(0, 0));
        assert_eq!(b.store_len(), 1);
        let acts = b.on_tick();
        assert!(acts.iter().any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 1)));
        assert_eq!(b.store_len(), 0);
        assert!(b.on_tick().is_empty());
    }

    #[test]
    fn interior_node_relays_demand_and_results() {
        // A relay over two children covering 4 consumers each.
        let mut r = BufferState::interior(2, 8, 2, 4);
        let acts = r.on_start();
        assert_eq!(acts, vec![BufferAction::RequestTasks { amount: 16 }]);
        // Child 1 asks for 6; nothing queued yet, and the relay already has
        // a full outstanding credit, so no duplicate upstream request.
        let acts = r.on_child_request(1, 6);
        assert!(acts.is_empty(), "{acts:?}");
        // Parent delivers 10: 6 go straight to child 1, 4 stay queued.
        let acts = r.on_assign((0..10).map(task).collect());
        let sent: usize = acts
            .iter()
            .filter_map(|a| match a {
                BufferAction::SendToChild { child: 1, tasks } => Some(tasks.len()),
                _ => None,
            })
            .sum();
        assert_eq!(sent, 6);
        assert_eq!(r.queue_len(), 4);
        // Child 0 asks for 2 → served from the local queue, no upstream hop.
        let acts = r.on_child_request(0, 2);
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::SendToChild { child: 0, tasks } if tasks.len() == 2)));
        // Results batch until flush_every (4) — queue still holds 2 tasks.
        let rs: Vec<TaskResult> = (0..3).map(|i| result(i, 0)).collect();
        let acts = r.on_child_results(rs);
        assert!(acts.is_empty(), "{acts:?}");
        let acts = r.on_child_results(vec![result(3, 1)]);
        assert!(acts
            .iter()
            .any(|a| matches!(a, BufferAction::FlushResults(rs) if rs.len() == 4)));
    }

    #[test]
    fn interior_shutdown_forwards_to_children() {
        let mut r = BufferState::interior(3, 12, 2, 16);
        r.on_start();
        let acts = r.on_shutdown();
        assert!(acts.iter().any(|a| matches!(a, BufferAction::ShutdownChildren)));
        assert!(r.is_shutting_down());
        // After shutdown a node no longer requests work.
        assert!(r.on_child_request(0, 5).is_empty());
    }

    #[test]
    fn starved_node_steals_before_escalating() {
        let mut thief = BufferState::new(2, 2, 100).with_stealing(0, 1, StealPolicy::RoundRobin);
        let mut victim = BufferState::new(2, 2, 100).with_stealing(1, 1, StealPolicy::RoundRobin);
        // Startup requests go upstream, not sideways.
        assert_eq!(thief.on_start(), vec![BufferAction::RequestTasks { amount: 4 }]);
        victim.on_start();
        // Both receive their full credit; the victim's consumers are slow.
        victim.on_assign((0..8).map(task).collect()); // 2 dispatched, queue = 6
        thief.on_assign((100..104).map(task).collect()); // 2 dispatched, queue = 2
        // First completion: queue drops to 1 < n_consumers → steal attempt
        // at sibling slot 1, not an upstream request.
        let acts = thief.on_done(0, result(100, 0));
        let steal = acts.iter().find_map(|a| match a {
            BufferAction::StealRequest { victim, amount } => Some((*victim, *amount)),
            _ => None,
        });
        assert!(steal.is_some(), "{acts:?}");
        let (vslot, amount) = steal.unwrap();
        assert_eq!(vslot, 1);
        assert_eq!(amount, 3); // restore credit 4 from level 1
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::RequestTasks { .. })));
        // Victim surrenders up to half its queue (queue = 6 → gives 3) and
        // reports what it has left.
        let acts = victim.on_steal_request(0, 0, amount);
        let (granted, left) = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::StealGrant { thief: 0, from_slot: 1, left, tasks, .. } => {
                    Some((tasks.clone(), *left))
                }
                _ => None,
            })
            .expect("victim must reply");
        assert_eq!(granted.len(), 3);
        assert_eq!(left, 3);
        assert_eq!(victim.queue_len(), 3);
        // Thief drains its queue; consumer 1 goes idle before the loot lands.
        thief.on_done(0, result(102, 0));
        thief.on_done(1, result(101, 1));
        let acts = thief.on_steal_grant(1, left, Vec::new(), granted);
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RunBatch { .. })), "{acts:?}");
        assert_eq!(thief.steals_received, 3);
        assert_eq!(thief.steals_failed, 0);
        assert_eq!(victim.steals_given, 3);
    }

    #[test]
    fn empty_steal_grant_escalates_upstream() {
        let mut thief = BufferState::new(2, 1, 100).with_stealing(0, 2, StealPolicy::RoundRobin);
        thief.on_start(); // upstream request for 2 (outstanding = 2)
        // Full credit arrives but dispatch drains the queue to 0, which is
        // below the low-water mark → a steal attempt, not an upstream request.
        let acts = thief.on_assign(vec![task(0), task(1)]);
        assert!(acts.iter().any(|a| matches!(a, BufferAction::StealRequest { .. })), "{acts:?}");
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::RequestTasks { .. })));
        // The sibling had nothing.
        let acts = thief.on_steal_grant(1, 0, Vec::new(), Vec::new());
        let req = acts.iter().find_map(|a| match a {
            BufferAction::RequestTasks { amount } => Some(*amount),
            _ => None,
        });
        assert!(req.is_some(), "empty grant must escalate to the parent: {acts:?}");
        // No second steal until new tasks arrive.
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::StealRequest { .. })));
        assert_eq!(thief.steals_failed, 1);
    }

    #[test]
    fn steal_victim_rotates_round_robin_skipping_self() {
        let mut b = BufferState::new(1, 1, 100).with_stealing(1, 3, StealPolicy::RoundRobin);
        let mut seen = Vec::new();
        for _ in 0..6 {
            seen.push(b.next_victim().expect("3 siblings"));
        }
        assert!(!seen.contains(&1), "{seen:?}");
        assert_eq!(seen, vec![2, 3, 0, 2, 3, 0]);
    }

    #[test]
    fn deepest_queue_explores_then_picks_deepest_known() {
        let mut b = BufferState::new(1, 1, 100).with_stealing(1, 3, StealPolicy::DeepestQueue);
        // All unknown: explores in rotation, skipping self.
        assert_eq!(b.next_victim(), Some(2));
        assert_eq!(b.next_victim(), Some(3));
        assert_eq!(b.next_victim(), Some(0));
        // Learn depths from grants: slot 2 empty, slot 0 deep, slot 3 shallow.
        b.on_steal_grant(2, 0, Vec::new(), Vec::new());
        b.on_steal_grant(0, 4, Vec::new(), vec![task(90)]);
        b.on_steal_grant(3, 1, Vec::new(), vec![task(91)]);
        assert_eq!(b.next_victim(), Some(0));
        assert_eq!(b.next_victim(), Some(0), "sticks to the deepest known sibling");
        // An incoming steal request marks that thief as starved.
        b.on_steal_request(0, 0, 1);
        assert_eq!(b.next_victim(), Some(3));
    }

    #[test]
    fn queue_never_exceeds_credit_bound() {
        let mut b = BufferState::new(3, 2, 5);
        b.on_start();
        b.on_assign((0..6).map(task).collect());
        assert!(b.max_queue() <= b.credit_bound());
        // Work through everything; the bound must hold throughout.
        let mut next_id = 6u64;
        for round in 0..20u64 {
            let acts = b.on_done(round as usize % 3, result(round, round as usize % 3));
            for a in acts {
                if let BufferAction::RequestTasks { amount } = a {
                    let grant: Vec<TaskSpec> =
                        (next_id..next_id + amount as u64).map(task).collect();
                    next_id += amount as u64;
                    b.on_assign(grant);
                }
            }
            assert!(b.max_queue() <= b.credit_bound(), "round {round}: {b:?}");
        }
    }

    #[test]
    fn no_task_lost_or_duplicated_through_buffer() {
        // Property-style: drive a buffer with random assign/done interleavings
        // and check conservation: every assigned task is run exactly once.
        use crate::testutil::{check, pair, usize_in, u64_in};
        check(
            "buffer conserves tasks",
            pair(usize_in(1..6), u64_in(1..40)),
            |&(nc, n_tasks)| {
                let mut b = BufferState::new(nc, 2, 5);
                b.on_start();
                let mut running: Vec<(usize, u64)> = Vec::new();
                let mut ran: Vec<u64> = Vec::new();
                let mut next = 0u64;
                let mut actions = b.on_assign((0..n_tasks.min(7)).map(task).collect());
                next += n_tasks.min(7);
                loop {
                    for a in actions.drain(..) {
                        if let BufferAction::RunBatch { consumer, tasks } = a {
                            for t in tasks {
                                running.push((consumer, t.id));
                            }
                        }
                    }
                    if let Some((c, id)) = running.pop() {
                        ran.push(id);
                        actions = b.on_done(c, result(id, c));
                        if next < n_tasks {
                            let push = (n_tasks - next).min(3);
                            let mut more = b.on_assign((next..next + push).map(task).collect());
                            next += push;
                            actions.append(&mut more);
                        }
                    } else if next < n_tasks {
                        let push = (n_tasks - next).min(3);
                        actions = b.on_assign((next..next + push).map(task).collect());
                        next += push;
                    } else {
                        break;
                    }
                }
                ran.sort();
                ran.dedup();
                ran.len() as u64 == n_tasks
            },
        );
    }

    fn cal(rtt: f64, task_s: f64) -> Calibration {
        Calibration { producer_rtt: rtt, mean_task_s: task_s }
    }

    fn shape_cfg(np: usize, cpb: usize) -> SchedulerConfig {
        SchedulerConfig { np, consumers_per_buffer: cpb, ..Default::default() }
    }

    #[test]
    fn choose_shape_stays_flat_when_producer_is_fast() {
        // Default-latency regime: microsecond round trips against
        // second-scale tasks — the paper's flat layout is optimal and
        // auto keeps it, at the K-computer ceiling and at mid scale.
        let cfg = shape_cfg(100_000, 384);
        assert_eq!(choose_shape(&cfg, &cal(1e-4, 5.0)).0, 1);
        let cfg = shape_cfg(4096, 64);
        assert_eq!(choose_shape(&cfg, &cal(1e-4, 0.5)).0, 1);
    }

    #[test]
    fn choose_shape_deepens_when_producer_lag_dominates() {
        // Millisecond producer round trips against sub-second tasks: the
        // flat layout's request traffic saturates rank 0, so the
        // controller must insert relay levels.
        let cfg = shape_cfg(4096, 64);
        let (depth, fans) = choose_shape(&cfg, &cal(5e-3, 0.5));
        assert!(depth >= 2, "depth={depth}");
        assert_eq!(fans.len(), depth - 1);
        // The top fanout bounds the producer's own fan-in too.
        assert!(root_count(cfg.num_buffers(), &fans) <= fans[0]);
    }

    #[test]
    fn shaped_fanouts_are_wide_at_leaves_narrow_at_root() {
        // 261 leaves (the 10⁵-consumer scale) over 3 levels, bound 8:
        // the lower stage takes the full width, the top stage shrinks to
        // the smallest fanout that still bounds the producer's fan-in.
        let fans = shaped_fanouts(261, 3, 8);
        assert_eq!(fans.len(), 2);
        assert!(fans[0] <= fans[1], "root level must not be wider: {fans:?}");
        assert_eq!(fans[1], 8, "leaf-adjacent stage uses the full width");
        let roots = root_count(261, &fans);
        assert!(roots <= fans[0], "roots {roots} exceed top fan-in {}", fans[0]);
        // Depth 1 has no interior level to plan.
        assert!(shaped_fanouts(261, 1, 8).is_empty());
        // Property: the plan always covers the leaves and keeps the
        // narrow-at-root ordering, and root_count matches the grouping
        // the topology builder performs.
        use crate::config::TreeTopology;
        use crate::testutil::{check, pair, usize_in};
        check(
            "shaped fanouts cover leaves, stay monotone, match the topology",
            pair(usize_in(2..400), pair(usize_in(2..4), usize_in(2..17))),
            |&(nb, (depth, fmax))| {
                let fans = shaped_fanouts(nb, depth, fmax);
                if fans.len() != depth - 1 {
                    return false;
                }
                if fans.windows(2).any(|w| w[0] > w[1]) {
                    return false;
                }
                let topo = TreeTopology::build(nb, 1, depth, &fans);
                topo.roots.len() == root_count(nb, &fans)
            },
        );
    }

    #[test]
    fn choose_shape_single_leaf_is_always_flat() {
        let cfg = shape_cfg(64, 384);
        assert_eq!(choose_shape(&cfg, &cal(10.0, 0.01)).0, 1);
    }

    #[test]
    fn choose_shape_depth_is_monotone_in_producer_lag() {
        // Utilization is linear in the per-message cost, so a slower
        // producer can never yield a *shallower* tree.
        use crate::testutil::{check, pair, u64_in, usize_in};
        check(
            "auto depth is monotone in producer rtt",
            pair(pair(usize_in(64..5000), usize_in(1..65)), u64_in(1..1000)),
            |&((np, cpb), rtt_us)| {
                let cfg = shape_cfg(np, cpb);
                let c = cal(rtt_us as f64 * 1e-5, 0.5);
                let slower = cal(rtt_us as f64 * 1e-5 * 4.0, 0.5);
                choose_shape(&cfg, &c).0 <= choose_shape(&cfg, &slower).0
            },
        );
    }

    #[test]
    fn resolve_shape_manual_passes_through_and_calibrated_chooses() {
        use crate::config::TreeShape;
        let mut cfg = shape_cfg(4096, 64);
        cfg.depth = 2;
        cfg.fanout = vec![4];
        assert_eq!(resolve_shape(&cfg, Calibration::fallback()), (2, vec![4]));
        // A manual per-level plan expands to depth − 1 effective entries.
        cfg.depth = 3;
        cfg.fanout = vec![4, 8];
        assert_eq!(resolve_shape(&cfg, Calibration::fallback()), (3, vec![4, 8]));
        cfg.fanout = vec![4];
        assert_eq!(resolve_shape(&cfg, Calibration::fallback()), (3, vec![4, 4]));
        cfg.shape = TreeShape::Calibrated(cal(1e-4, 5.0));
        // The preset wins over whatever the runtime measured.
        assert_eq!(resolve_shape(&cfg, cal(10.0, 0.01)).0, 1);
    }

    #[test]
    fn request_grant_lag_is_measured_per_round_trip() {
        let mut b = BufferState::new(2, 2, 100);
        b.set_now(1.0);
        b.on_start(); // request at t = 1
        b.set_now(1.5);
        b.on_assign(vec![task(0), task(1), task(2), task(3)]); // grant at t = 1.5
        let s = b.stats(0, 1);
        assert_eq!(s.req_lag_n, 1);
        assert!((s.req_lag_mean - 0.5).abs() < 1e-12, "{}", s.req_lag_mean);
        assert!((s.req_lag_max - 0.5).abs() < 1e-12);
        // Dispatch both consumers, drain: the refill request opens a new
        // round trip; a second assign closes it with a larger lag.
        b.set_now(2.0);
        b.on_done(0, result(0, 0));
        b.on_done(1, result(1, 1));
        b.set_now(4.0);
        b.on_assign(vec![task(4)]);
        let s = b.stats(0, 1);
        assert_eq!(s.req_lag_n, 2);
        assert!((s.req_lag_max - 2.0).abs() < 1e-12, "{}", s.req_lag_max);
        assert!((s.req_lag_mean - 1.25).abs() < 1e-12);
    }

    #[test]
    fn wait_hist_counts_conserve_pops_across_policies() {
        // The satellite property at queue level: under every SchedPolicy,
        // front pops are exactly what the per-band histograms count —
        // steal surrenders (take_back) and cancellations (remove) are not.
        use crate::testutil::{check, pair, u64_in, usize_in, vec_of};
        check(
            "Σ wait-hist counts == pops under every policy",
            pair(vec_of(pair(usize_in(0..6), usize_in(0..4)), 1..60), u64_in(0..3)),
            |case: &(Vec<(usize, usize)>, u64)| {
                let (ops, policy_idx) = case;
                let policy = [
                    SchedPolicy::Strict,
                    SchedPolicy::Deadline,
                    SchedPolicy::Aging { step: 2.0 },
                ][*policy_idx as usize];
                let mut q = PrioQueue::with_policy(policy);
                let mut pops = 0u64;
                for (i, &(op, prio)) in ops.iter().enumerate() {
                    q.set_now(i as f64 * 0.7);
                    match op {
                        // Weight pushes so queues actually fill.
                        0 | 1 | 2 => q.push(prio_task(i as u64, prio as u8)),
                        3 => pops += u64::from(q.pop().is_some()),
                        4 => pops += q.pop_n(2).len() as u64,
                        _ => {
                            // Not dispatches: must not inflate the hist.
                            q.take_back(1);
                            q.remove(i as u64 / 2);
                        }
                    }
                }
                pops += q.pop_n(usize::MAX >> 1).len() as u64;
                let hist_total: u64 =
                    q.wait_hist().iter().map(|h| h.total()).sum();
                q.popped() == pops && hist_total == pops
            },
        );
    }

    /// Collect the task ids inside every `ReturnTasks` action.
    fn returned_ids(acts: &[BufferAction]) -> Vec<u64> {
        acts.iter()
            .flat_map(|a| match a {
                BufferAction::ReturnTasks(ts) => ts.iter().map(|t| t.id).collect::<Vec<_>>(),
                _ => Vec::new(),
            })
            .collect()
    }

    #[test]
    fn leaf_recall_drains_queue_and_acks_after_running_finish() {
        let mut b = BufferState::new(2, 4, 100);
        b.set_now(1.0);
        b.on_start();
        b.on_assign((0..6).map(task).collect()); // 2 running, 4 queued
        let acts = b.on_recall();
        // The queue drains upstream with enqueue stamps preserved…
        assert_eq!(returned_ids(&acts), vec![2, 3, 4, 5]);
        assert!(
            acts.iter().all(|a| !matches!(a, BufferAction::AckRecall)),
            "busy consumers: ack must wait ({acts:?})"
        );
        assert_eq!(b.queue_len(), 0);
        // …and a grant racing the recall bounces straight back.
        let acts = b.on_assign(vec![task(9)]);
        assert_eq!(returned_ids(&acts), vec![9]);
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::RunBatch { .. })));
        // Completions flow normally; nothing new is dispatched; the ack
        // fires with the last running attempt.
        let acts = b.on_done(0, result(0, 0));
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::RunBatch { .. })));
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::AckRecall)));
        let acts = b.on_done(1, result(1, 1));
        assert!(
            acts.iter().any(|a| matches!(a, BufferAction::FlushResults(_))),
            "results drain before the ack: {acts:?}"
        );
        assert_eq!(
            acts.last(),
            Some(&BufferAction::AckRecall),
            "ack must be the node's last upstream message"
        );
        // Idempotent: nothing re-acks.
        assert!(b.on_tick().iter().all(|a| !matches!(a, BufferAction::AckRecall)));
    }

    #[test]
    fn recall_preserves_enqueue_stamps_through_producer_reenqueue() {
        // Buffer side: the drain ships tasks with their original stamps.
        let mut b = BufferState::new(1, 8, 100).with_policy(SchedPolicy::Deadline);
        b.set_now(0.0);
        b.on_start();
        b.on_assign(vec![task(99), deadline_task(7, 0, 0.0, 50.0)]); // 7 runs (least slack)
        let acts = b.on_recall();
        for a in &acts {
            if let BufferAction::ReturnTasks(ts) = a {
                assert_eq!(ts.len(), 1);
                assert_eq!(ts[0].enqueued_t, Some(0.0), "stamp preserved through drain");
            }
        }
        // Producer side: returned batches arrive in arbitrary per-leaf
        // order, but the preserved stamps/deadlines — not arrival order —
        // decide the re-grant sequence after the graft.
        let mut p = ProducerState::new(2).with_policy(SchedPolicy::Deadline);
        p.set_now(5.0);
        p.on_returned(vec![deadline_task(1, 0, 0.0, 99.0)]);
        p.on_returned(vec![deadline_task(2, 0, 0.0, 10.0), deadline_task(0, 0, 0.0, 50.0)]);
        p.rewire(1);
        let acts = p.on_request(0, 3);
        let ids: Vec<u64> = acts
            .iter()
            .flat_map(|a| match a {
                ProducerAction::SendTasks { tasks, .. } => {
                    tasks.iter().map(|t| t.id).collect::<Vec<_>>()
                }
                _ => Vec::new(),
            })
            .collect();
        assert_eq!(ids, vec![2, 0, 1], "SchedPolicy order survives the graft");
    }

    #[test]
    fn interior_recall_forwards_returns_and_aggregates_acks() {
        let mut r = BufferState::interior(2, 8, 2, 100);
        r.on_start();
        r.on_assign((0..3).map(task).collect()); // nothing requested below yet
        let acts = r.on_recall();
        assert_eq!(returned_ids(&acts), vec![0, 1, 2]);
        assert!(acts.iter().any(|a| matches!(a, BufferAction::RecallChildren)));
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::AckRecall)));
        // A child's returned tasks are relayed upstream…
        let acts = r.on_child_returned(vec![task(10), task(11)]);
        assert_eq!(returned_ids(&acts), vec![10, 11]);
        // …unless a tombstone pends here: then the task dies with a
        // cancelled result instead of travelling on.
        r.on_cancel(12);
        let acts = r.on_child_returned(vec![task(12), task(13)]);
        assert_eq!(returned_ids(&acts), vec![13]);
        assert!(
            acts.iter().any(
                |a| matches!(a, BufferAction::FlushResults(rs) if rs.iter().any(|x| x.id == 12 && x.cancelled()))
            ),
            "{acts:?}"
        );
        // The ack fires only once both children acked.
        assert!(r.on_child_recall_ack(0).is_empty());
        let acts = r.on_child_recall_ack(1);
        assert_eq!(acts.last(), Some(&BufferAction::AckRecall));
    }

    #[test]
    fn recall_bounces_steal_loot_and_victim_grants_nothing() {
        // Thief recalls while a steal reply is in flight: the ack waits
        // for the grant, and the loot is returned, not dispatched.
        let mut thief = BufferState::new(1, 1, 100).with_stealing(0, 1, StealPolicy::RoundRobin);
        thief.on_start();
        thief.on_assign(vec![task(0), task(1)]); // dispatch 0, queue 1
        thief.on_done(0, result(0, 0)); // dispatch 1, queue empty → steal
        assert_eq!(thief.steals_attempted, 1);
        let acts = thief.on_recall();
        assert!(
            !acts.iter().any(|a| matches!(a, BufferAction::AckRecall)),
            "outstanding steal: ack must wait ({acts:?})"
        );
        thief.on_done(0, result(1, 0)); // consumer idle, still no ack
        let acts = thief.on_steal_grant(1, 0, Vec::new(), vec![task(50)]);
        assert_eq!(returned_ids(&acts), vec![50], "loot bounces upstream");
        assert!(!acts.iter().any(|a| matches!(a, BufferAction::RunBatch { .. })));
        assert_eq!(acts.last(), Some(&BufferAction::AckRecall));
        // A recalling victim surrenders nothing.
        let mut victim = BufferState::new(1, 8, 100).with_stealing(1, 1, StealPolicy::RoundRobin);
        victim.on_start();
        victim.on_assign((0..6).map(task).collect());
        victim.on_recall();
        let acts = victim.on_steal_request(0, 0, 3);
        let granted = acts
            .iter()
            .find_map(|a| match a {
                BufferAction::StealGrant { tasks, .. } => Some(tasks.len()),
                _ => None,
            })
            .expect("victim still replies so the thief can escalate");
        assert_eq!(granted, 0);
    }

    #[test]
    fn producer_recall_cycle_withholds_grants_then_rewires() {
        let mut p = ProducerState::new(2);
        p.push_tasks((0..8).map(task).collect());
        p.on_request(0, 4); // 4 granted
        assert_eq!(p.in_flight(), 8);
        assert_eq!(p.pending_len(), 4);
        let acts = p.begin_recall();
        assert_eq!(acts, vec![ProducerAction::BroadcastRecall]);
        assert!(p.is_recalling());
        assert!(p.begin_recall().is_empty(), "recall is single-flight");
        // Requests during the drain accumulate but are not served.
        assert!(p.on_request(1, 4).is_empty());
        // The granted-but-unstarted tasks come back; accounting holds.
        p.on_returned((0..4).map(task).collect());
        assert_eq!(p.pending_len(), 8);
        assert_eq!(p.in_flight(), 8, "recalled tasks still count in flight");
        assert!(!p.on_recall_ack(0), "one ack is not enough");
        assert!(p.on_recall_ack(1), "all roots acked → graft may proceed");
        // Graft onto a 3-root tree: grants flow again, fairly.
        p.rewire(3);
        assert!(!p.is_recalling());
        let acts = p.on_request(2, 8);
        let granted: usize = acts
            .iter()
            .map(|a| match a {
                ProducerAction::SendTasks { tasks, .. } => tasks.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(granted, 8);
        assert_eq!(p.pending_len(), 0);
        // Conservation end to end: completions drain in_flight to zero.
        p.set_engine_done(true);
        p.on_results(8);
        assert_eq!(p.maybe_shutdown(), vec![ProducerAction::BroadcastShutdown]);
    }

    #[test]
    fn wait_hist_bins_by_wait_and_band() {
        let mut q = PrioQueue::new();
        q.set_now(0.0);
        q.push(prio_task(0, 3)); // will wait 5 s → the (1, 10] bin
        q.push(prio_task(1, 0)); // will wait 5 s too, other band
        q.set_now(5.0);
        q.push(prio_task(2, 0)); // popped immediately → first bin
        assert_eq!(q.pop().unwrap().id, 0);
        q.pop();
        q.pop();
        let hist = q.wait_hist();
        assert_eq!(hist.len(), 2);
        let b0 = hist.iter().find(|h| h.band == 0).unwrap();
        let b3 = hist.iter().find(|h| h.band == 3).unwrap();
        assert_eq!(b3.counts[wait_bin(5.0)], 1);
        assert_eq!(b0.counts[wait_bin(5.0)], 1);
        assert_eq!(b0.counts[wait_bin(0.0)], 1);
        assert_eq!(b0.total() + b3.total(), 3);
    }
}
