//! Scheduling metrics — most importantly the paper's job filling rate,
//! Eq. (1):
//!
//! ```text
//!         Σᵢ (tᵢ_end − tᵢ_begin)
//!   r  =  ──────────────────────            T = max tᵢ_end − min tᵢ_begin
//!               T · N_p
//! ```
//!
//! `r` ≈ 1 means the consumers were busy for the whole makespan — ideal
//! load balancing with negligible communication cost.

use crate::tasklib::TaskResult;

/// Upper edges (seconds) of the queue-wait histogram buckets; the last
/// bin is open-ended. Log-spaced so sub-millisecond queue hops and
/// kilosecond starvation land in distinct bins, in both virtual (DES) and
/// scaled wall time (threaded runtime).
pub const WAIT_BUCKET_EDGES: [f64; 7] = [1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0, 1000.0];

/// Number of wait-histogram bins: one per edge plus the open tail.
pub const N_WAIT_BINS: usize = WAIT_BUCKET_EDGES.len() + 1;

/// Bin index for a queue wait of `wait` seconds.
pub fn wait_bin(wait: f64) -> usize {
    WAIT_BUCKET_EDGES.iter().position(|&e| wait <= e).unwrap_or(WAIT_BUCKET_EDGES.len())
}

/// Queue-wait histogram of one priority band at one node: how long tasks
/// of that band sat in the local queue before being popped for dispatch.
/// Counts conserve pops — Σ counts over all bands equals the node's
/// `popped` counter — so the histograms are an exact decomposition of the
/// queue traffic, not a sample.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BandWaitHist {
    /// Base priority band ([`crate::tasklib::TaskSpec::priority`]).
    pub band: u8,
    pub counts: [u64; N_WAIT_BINS],
}

impl BandWaitHist {
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// Per-tenant-class slice of one node's queue counters: how many tasks of
/// the class this node popped for dispatch and their per-band wait
/// histograms. Exact decomposition of the node totals — Σ over classes of
/// `popped` equals [`NodeStats::popped`], and within each class Σ of all
/// histogram counts equals the class's `popped` — so tenant isolation is
/// observable (and conservation-checkable) at every tree level.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClassNodeStats {
    /// Tenant class this slice counts ([`crate::tenancy::ClassId`]).
    pub class: crate::tenancy::ClassId,
    /// Tasks of this class popped from the node's queue for dispatch.
    pub popped: u64,
    /// Per-band queue-wait histograms of this class, ascending band
    /// order. Σ of all counts equals `popped`.
    pub wait_hist: Vec<BandWaitHist>,
}

/// Counter snapshot of one buffer-tree node after a run (threaded runtime
/// or DES). `node` indexes [`crate::config::TreeTopology::nodes`].
#[derive(Clone, Debug)]
pub struct NodeStats {
    pub node: usize,
    /// Buffer level: 1 = directly under the producer.
    pub level: usize,
    pub subtree_consumers: usize,
    /// `credit_factor × subtree_consumers` — the queue's allowed maximum.
    pub credit_bound: usize,
    /// Largest local queue observed; the protocol guarantees
    /// `max_queue ≤ credit_bound`.
    pub max_queue: usize,
    pub msgs_in: u64,
    pub msgs_out: u64,
    pub steals_attempted: u64,
    /// Steal attempts answered with an empty grant.
    pub steals_failed: u64,
    pub steals_received: u64,
    pub steals_given: u64,
    /// Queued tasks dropped at this node by a cancellation.
    pub cancelled_dropped: u64,
    /// Kill requests this (leaf) node issued for running attempts on a
    /// cancellation notice. A request can lose the race to the attempt's
    /// natural completion, so this counts kills asked for, not landed.
    pub cancelled_killed: u64,
    /// Failed attempts transparently re-queued at this node (leafs only).
    pub retried: u64,
    /// Tasks popped from this node's local queue for dispatch — the unit
    /// the wait histograms count.
    pub popped: u64,
    /// Multi-task `RunBatch` dispatches sent to consumers (batches of
    /// length ≥ 2; single-task sends are not counted).
    pub dispatch_batches: u64,
    /// Credit-request/result-flush pairs merged into one upstream `Flush`
    /// message by ascent coalescing.
    pub coalesced_flushes: u64,
    /// Per-band queue-wait histograms, ascending band order. Σ of all
    /// counts equals `popped`.
    pub wait_hist: Vec<BandWaitHist>,
    /// Per-tenant-class decomposition of `popped` / `wait_hist`, ascending
    /// class order. Empty when the node only ever saw the default class.
    pub class_stats: Vec<ClassNodeStats>,
    /// Completed parent-request→first-grant round trips observed here —
    /// the per-node producer-lag measurement driving adaptive shaping.
    pub req_lag_n: u64,
    /// Mean request→grant lag in (virtual) seconds; 0 when `req_lag_n` is 0.
    pub req_lag_mean: f64,
    /// Worst request→grant lag observed.
    pub req_lag_max: f64,
    /// Whether the shutdown broadcast reached this node.
    pub saw_shutdown: bool,
    /// Frames received over a transport link feeding this node — zero for
    /// in-process nodes; the root side of a `caravan worker` connection
    /// reports its per-edge link traffic here.
    pub wire_msgs_in: u64,
    /// Frames sent over the node's transport link (zero in-process).
    pub wire_msgs_out: u64,
    /// Encoded bytes received over the node's transport link.
    pub wire_bytes_in: u64,
    /// Encoded bytes sent over the node's transport link.
    pub wire_bytes_out: u64,
}

/// Filling-rate summary of one buffer level (see [`FillingRate::level_fill`]).
#[derive(Clone, Copy, Debug)]
pub struct LevelFill {
    pub level: usize,
    pub n_nodes: usize,
    pub mean_rate: f64,
    pub min_rate: f64,
}

/// Per-task execution interval (the schedule trace).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval {
    pub task_id: u64,
    pub consumer: usize,
    pub begin: f64,
    pub finish: f64,
}

/// Accumulates the schedule trace and computes Eq. (1).
#[derive(Clone, Debug, Default)]
pub struct FillingRate {
    intervals: Vec<Interval>,
}

impl FillingRate {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, r: &TaskResult) {
        self.intervals.push(Interval {
            task_id: r.id,
            consumer: r.consumer,
            begin: r.begin,
            finish: r.finish,
        });
    }

    pub fn record_all<'a>(&mut self, rs: impl IntoIterator<Item = &'a TaskResult>) {
        for r in rs {
            self.record(r);
        }
    }

    pub fn n_tasks(&self) -> usize {
        self.intervals.len()
    }

    pub fn intervals(&self) -> &[Interval] {
        &self.intervals
    }

    /// Total busy time Σ(end−begin).
    pub fn busy_time(&self) -> f64 {
        self.intervals.iter().map(|iv| iv.finish - iv.begin).sum()
    }

    /// Makespan T = max end − min begin (0 if no tasks).
    pub fn makespan(&self) -> f64 {
        if self.intervals.is_empty() {
            return 0.0;
        }
        let t0 = self.intervals.iter().map(|iv| iv.begin).fold(f64::INFINITY, f64::min);
        let t1 = self.intervals.iter().map(|iv| iv.finish).fold(f64::NEG_INFINITY, f64::max);
        t1 - t0
    }

    /// Job filling rate r for `np` consumer processes.
    pub fn rate(&self, np: usize) -> f64 {
        let t = self.makespan();
        if t <= 0.0 || np == 0 {
            return 0.0;
        }
        self.busy_time() / (t * np as f64)
    }

    /// Filling rate of the consumer-rank range `[lo, hi)` against the
    /// *global* makespan — the per-subtree view used for per-level rates
    /// in the buffer tree (subtree ranks are contiguous by construction).
    pub fn rate_for_range(&self, lo: usize, hi: usize) -> f64 {
        let t = self.makespan();
        if t <= 0.0 || hi <= lo {
            return 0.0;
        }
        let busy: f64 = self
            .intervals
            .iter()
            .filter(|iv| (lo..hi).contains(&iv.consumer))
            .map(|iv| iv.finish - iv.begin)
            .sum();
        busy / (t * (hi - lo) as f64)
    }

    /// Per-level filling statistics for a buffer tree: for each level, the
    /// unweighted mean and the minimum of the subtree rates. (The weighted
    /// mean is just the global rate, so mean/min is what exposes imbalance.)
    ///
    /// Single pass over the trace: per-rank busy time is accumulated once
    /// and each subtree is a contiguous rank slice.
    pub fn level_fill(&self, topo: &crate::config::TreeTopology) -> Vec<LevelFill> {
        let t = self.makespan();
        let mut busy = vec![0.0f64; topo.np];
        for iv in &self.intervals {
            if iv.consumer < topo.np {
                busy[iv.consumer] += iv.finish - iv.begin;
            }
        }
        (1..=topo.depth)
            .map(|level| {
                let groups = topo.level_groups(level);
                let rates: Vec<f64> = groups
                    .iter()
                    .map(|&(lo, n)| {
                        if t <= 0.0 || n == 0 {
                            0.0
                        } else {
                            busy[lo..lo + n].iter().sum::<f64>() / (t * n as f64)
                        }
                    })
                    .collect();
                let n_nodes = rates.len();
                let mean = if n_nodes == 0 {
                    0.0
                } else {
                    rates.iter().sum::<f64>() / n_nodes as f64
                };
                let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
                LevelFill {
                    level,
                    n_nodes,
                    mean_rate: mean,
                    min_rate: if min.is_finite() { min } else { 0.0 },
                }
            })
            .collect()
    }

    /// Sanity check used by tests and the DES: no two intervals on the same
    /// consumer may overlap (a consumer runs one task at a time).
    /// Returns the number of violations.
    pub fn overlap_violations(&self) -> usize {
        // BTreeMap so the scan order (and any future tie-broken output)
        // is deterministic — this module builds report data.
        let mut by_consumer: std::collections::BTreeMap<usize, Vec<(f64, f64)>> =
            std::collections::BTreeMap::new();
        for iv in &self.intervals {
            by_consumer.entry(iv.consumer).or_default().push((iv.begin, iv.finish));
        }
        let mut violations = 0;
        for (_, mut ivs) in by_consumer {
            ivs.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in ivs.windows(2) {
                // Strict overlap; touching endpoints are fine.
                if w[1].0 < w[0].1 - 1e-9 {
                    violations += 1;
                }
            }
        }
        violations
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(id: u64, consumer: usize, begin: f64, finish: f64) -> TaskResult {
        TaskResult {
            id,
            consumer,
            results: vec![],
            begin,
            finish,
            rc: 0,
            attempt: 0,
            timed_out: false,
        }
    }

    #[test]
    fn perfect_filling_is_one() {
        let mut f = FillingRate::new();
        // Two consumers, each busy [0,10] with two back-to-back tasks.
        f.record(&res(0, 0, 0.0, 5.0));
        f.record(&res(1, 0, 5.0, 10.0));
        f.record(&res(2, 1, 0.0, 7.0));
        f.record(&res(3, 1, 7.0, 10.0));
        assert!((f.rate(2) - 1.0).abs() < 1e-12);
        assert_eq!(f.overlap_violations(), 0);
        assert!((f.makespan() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn idle_consumer_halves_rate() {
        let mut f = FillingRate::new();
        f.record(&res(0, 0, 0.0, 10.0));
        // Consumer 1 exists but never works.
        assert!((f.rate(2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_rate_zero() {
        let f = FillingRate::new();
        assert_eq!(f.rate(16), 0.0);
        assert_eq!(f.makespan(), 0.0);
    }

    #[test]
    fn overlap_detection() {
        let mut f = FillingRate::new();
        f.record(&res(0, 0, 0.0, 5.0));
        f.record(&res(1, 0, 4.0, 6.0)); // overlaps on consumer 0
        f.record(&res(2, 1, 4.0, 6.0)); // different consumer: fine
        assert_eq!(f.overlap_violations(), 1);
    }

    #[test]
    fn per_range_rates_expose_imbalance() {
        let mut f = FillingRate::new();
        // Ranks 0–1 fully busy over [0,10]; ranks 2–3 idle half the time.
        f.record(&res(0, 0, 0.0, 10.0));
        f.record(&res(1, 1, 0.0, 10.0));
        f.record(&res(2, 2, 0.0, 5.0));
        f.record(&res(3, 3, 5.0, 10.0));
        assert!((f.rate_for_range(0, 2) - 1.0).abs() < 1e-12);
        assert!((f.rate_for_range(2, 4) - 0.5).abs() < 1e-12);
        assert!((f.rate(4) - 0.75).abs() < 1e-12);
        let topo = crate::config::TreeTopology::build(4, 2, 2, &[2]);
        let lf = f.level_fill(&topo);
        assert_eq!(lf.len(), 2);
        // Leaf level (2 leaves of 2 ranks): mean (1.0 + 0.5)/2, min 0.5.
        let leaf = lf.iter().find(|l| l.level == 2).unwrap();
        assert_eq!(leaf.n_nodes, 2);
        assert!((leaf.mean_rate - 0.75).abs() < 1e-12);
        assert!((leaf.min_rate - 0.5).abs() < 1e-12);
        // Level 1 is one relay spanning everything → the global rate.
        let top = lf.iter().find(|l| l.level == 1).unwrap();
        assert_eq!(top.n_nodes, 1);
        assert!((top.mean_rate - 0.75).abs() < 1e-12);
    }

    #[test]
    fn rate_never_exceeds_one_property() {
        use crate::testutil::{check, f64_in, pair, vec_of};
        check(
            "filling rate ≤ 1 for serial-per-consumer traces",
            vec_of(pair(f64_in(0.0, 100.0), f64_in(0.01, 10.0)), 1..50),
            |spans| {
                // Build a serialized schedule on one consumer from (gap, dur) pairs.
                let mut f = FillingRate::new();
                let mut t = 0.0;
                for (i, (gap, dur)) in spans.iter().enumerate() {
                    t += gap;
                    f.record(&res(i as u64, 0, t, t + dur));
                    t += dur;
                }
                f.rate(1) <= 1.0 + 1e-9 && f.overlap_violations() == 0
            },
        );
    }
}
