//! Online buffer-tree re-shaping under lag drift.
//!
//! PR 4's `TreeShape::Auto` picks a shape **once**, from a startup
//! calibration — which goes stale exactly when the workload gets
//! interesting (an MOEA shifting from cheap to expensive generations, a
//! sweep whose parameter ranges change task cost by orders of
//! magnitude). This module closes the loop the PR 4 instrumentation
//! opened: the [`ReshapeController`] rebuilds a **rolling
//! [`Calibration`]** from live measurements —
//!
//! * *producer round trip* — the request→grant lag the producer's direct
//!   children measure (`NodeStats::req_lag_*`, fed here as cumulative
//!   totals and differenced per window), which inflates exactly when
//!   rank 0 saturates;
//! * *mean task duration* — the `begin → finish` span of every completed
//!   result the producer ingests;
//!
//! — re-runs the same pure [`choose_shape`] controller both runtimes
//! already share, and, when the chosen shape diverges and the inputs
//! drifted beyond [`ReshapePolicy::drift_threshold`], asks the runtime
//! to execute a **drain-and-graft transition** (see
//! [`super::protocol::ProducerState::begin_recall`]): credit is
//! withdrawn, every queued task returns to the producer with its
//! `enqueued_t` stamp preserved, the tree is rebuilt at the new shape,
//! and the recalled tasks are re-granted. Conservation (`Σcounts ==
//! popped`, one result per task) and `SchedPolicy` ordering survive the
//! transition by construction.
//!
//! The controller is pure bookkeeping over the observation stream: fed
//! the same observations at the same (virtual) times, it makes the same
//! decisions — which is how the threaded runtime and the DES resolve
//! transitions identically, and why DES reshape runs are deterministic
//! in virtual time (property-tested in `tests/tree_protocol.rs`).

use std::collections::BTreeMap;

use crate::config::{Calibration, ReshapePolicy, SchedulerConfig};
use crate::tasklib::TaskResult;
use crate::tenancy::ClassId;

use super::metrics::ClassNodeStats;
use super::protocol::choose_shape;

/// One executed drain-and-graft transition, for reports and benches.
#[derive(Clone, Debug, PartialEq)]
pub struct ReshapeEvent {
    /// (Virtual) time the transition was decided.
    pub t: f64,
    /// Shape before the transition.
    pub from_depth: usize,
    /// Per-level fanout before the transition (root-down).
    pub from_fanout: Vec<usize>,
    /// Shape after the transition.
    pub to_depth: usize,
    /// Per-level fanout after the transition (root-down).
    pub to_fanout: Vec<usize>,
    /// The rolling calibration that triggered the change.
    pub cal: Calibration,
}

/// Decides *when* to re-shape; the runtimes decide *how* (recall → drain
/// → graft). Owned by whoever drives the producer state machine.
#[derive(Debug)]
pub struct ReshapeController {
    policy: ReshapePolicy,
    cfg: SchedulerConfig,
    /// The shape currently grafted: `(depth, per-level fanout)`.
    shape: (usize, Vec<usize>),
    /// The calibration the current shape was chosen from — the reference
    /// the drift threshold compares against.
    shape_cal: Calibration,
    window_start: f64,
    last_transition: f64,
    /// Task-duration accumulator for the current window.
    dur_sum: f64,
    dur_n: u64,
    /// Root-lag totals at the previous window boundary (the baseline the
    /// cumulative totals are differenced against).
    lag_base: (u64, f64),
    /// Most recent cumulative root-lag totals observed.
    lag_latest: (u64, f64),
    /// Most recent cumulative per-class grant counts from the producer's
    /// pending queue (empty for single-tenant runs).
    mix_latest: BTreeMap<ClassId, u64>,
    /// Per-class grant counts at the previous window boundary.
    mix_base: BTreeMap<ClassId, u64>,
    /// The per-class *share* vector the current reference was adopted
    /// under; `None` until a window with multi-tenant traffic closes.
    mix_ref: Option<BTreeMap<ClassId, f64>>,
    events: Vec<ReshapeEvent>,
}

impl ReshapeController {
    /// A controller for a run that started `now` with `shape` chosen
    /// from `cal`. `cfg` supplies the scale/flow-control constants the
    /// shape model needs (`np`, buffers, credit, flush batching, the
    /// fanout upper bound).
    pub fn new(
        cfg: &SchedulerConfig,
        policy: ReshapePolicy,
        shape: (usize, Vec<usize>),
        cal: Calibration,
        now: f64,
    ) -> Self {
        Self {
            policy,
            cfg: cfg.clone(),
            shape,
            shape_cal: cal,
            window_start: now,
            last_transition: f64::NEG_INFINITY,
            dur_sum: 0.0,
            dur_n: 0,
            lag_base: (0, 0.0),
            lag_latest: (0, 0.0),
            mix_latest: BTreeMap::new(),
            mix_base: BTreeMap::new(),
            mix_ref: None,
            events: Vec::new(),
        }
    }

    /// The currently grafted `(depth, per-level fanout)`.
    pub fn shape(&self) -> &(usize, Vec<usize>) {
        &self.shape
    }

    /// Every transition executed so far, in order.
    pub fn events(&self) -> &[ReshapeEvent] {
        &self.events
    }

    /// Feed one final result the producer ingested. Cancelled results
    /// never ran and carry no duration; non-finite spans (a defensive
    /// guard — both runtimes stamp finite clocks) are ignored too.
    pub fn observe_result(&mut self, r: &TaskResult) {
        if r.cancelled() {
            return;
        }
        let d = r.finish - r.begin;
        if d.is_finite() && d >= 0.0 {
            self.dur_sum += d;
            self.dur_n += 1;
        }
    }

    /// Feed the **cumulative** request→grant lag totals summed over the
    /// current tree's root nodes (`Σ req_lag_n`, `Σ req_lag_sum`). The
    /// controller differences consecutive snapshots itself, so callers
    /// just report whatever the live `NodeStats` say.
    pub fn observe_root_lag(&mut self, total_n: u64, total_sum: f64) {
        self.lag_latest = (total_n, total_sum);
    }

    /// Feed the **cumulative** per-class grant counters of the producer's
    /// pending queue (its `class_stats()`). Like the lag totals, the
    /// controller differences consecutive snapshots per window and treats
    /// a shift of the class *mix* — total-variation distance of the
    /// windowed share vector against the reference mix ≥
    /// [`ReshapePolicy::drift_threshold`] — as calibration drift: a new
    /// tenant arriving (or one going quiet) changes the effective task
    /// profile, so the shape decision deserves a re-check. Single-tenant
    /// runs feed nothing and are unaffected.
    pub fn observe_class_mix(&mut self, stats: &[ClassNodeStats]) {
        for s in stats {
            self.mix_latest.insert(s.class, s.popped);
        }
    }

    /// The windowed class-share vector (`None`: fewer than two classes or
    /// no grants this window — no mix signal).
    fn window_mix(&self) -> Option<BTreeMap<ClassId, f64>> {
        let deltas: BTreeMap<ClassId, u64> = self
            .mix_latest
            .iter()
            .map(|(&c, &n)| (c, n.saturating_sub(self.mix_base.get(&c).copied().unwrap_or(0))))
            .collect();
        let total: u64 = deltas.values().sum();
        if total == 0 || self.mix_latest.len() < 2 {
            return None;
        }
        Some(deltas.into_iter().map(|(c, n)| (c, n as f64 / total as f64)).collect())
    }

    /// Total-variation distance between two share vectors (½ Σ |a − b|,
    /// in `[0, 1]`; absent classes count as share 0).
    fn mix_distance(a: &BTreeMap<ClassId, f64>, b: &BTreeMap<ClassId, f64>) -> f64 {
        let keys: std::collections::BTreeSet<ClassId> =
            a.keys().chain(b.keys()).copied().collect();
        0.5 * keys
            .into_iter()
            .map(|k| {
                (a.get(&k).copied().unwrap_or(0.0) - b.get(&k).copied().unwrap_or(0.0)).abs()
            })
            .sum::<f64>()
    }

    /// The runtime finished a drain-and-graft: the old tree's counters
    /// are gone, so the lag baseline and the measurement window restart.
    pub fn grafted(&mut self, now: f64) {
        self.lag_base = (0, 0.0);
        self.lag_latest = (0, 0.0);
        // The producer (and its cumulative per-class counters) survives a
        // graft — only the window restarts, from the latest snapshot.
        self.mix_base = self.mix_latest.clone();
        self.window_start = now;
        self.dur_sum = 0.0;
        self.dur_n = 0;
    }

    /// Close the rolling window if it is due and decide whether to
    /// re-shape. Returns the new `(depth, per-level fanout)` when a
    /// transition should fire — the caller then runs the recall protocol
    /// and calls [`ReshapeController::grafted`] once the new tree is up.
    ///
    /// A transition fires only when **all** hold:
    /// 1. a full [`ReshapePolicy::window`] elapsed since the last check,
    /// 2. a calibration input drifted ≥ `drift_threshold` (relative)
    ///    against the calibration that chose the current shape,
    /// 3. the pure [`choose_shape`] controller picks a different shape
    ///    from the rolling calibration, and
    /// 4. the previous transition is at least `cooldown` old.
    ///
    /// Windows with no fresh measurement of an input fall back to the
    /// current reference value for that input (no spurious drift).
    pub fn maybe_reshape(&mut self, now: f64) -> Option<(usize, Vec<usize>)> {
        if now - self.window_start < self.policy.window {
            return None;
        }
        let dn = self.lag_latest.0.saturating_sub(self.lag_base.0);
        let dsum = (self.lag_latest.1 - self.lag_base.1).max(0.0);
        let cal = Calibration {
            producer_rtt: if dn > 0 { dsum / dn as f64 } else { self.shape_cal.producer_rtt },
            mean_task_s: if self.dur_n > 0 {
                (self.dur_sum / self.dur_n as f64).max(1e-9)
            } else {
                self.shape_cal.mean_task_s
            },
        };
        let mix = self.window_mix();
        // The window rolls regardless of the decision below.
        self.window_start = now;
        self.lag_base = self.lag_latest;
        self.mix_base = self.mix_latest.clone();
        self.dur_sum = 0.0;
        self.dur_n = 0;

        let rel = |new: f64, old: f64| (new - old).abs() / old.abs().max(1e-12);
        let cal_drift = rel(cal.producer_rtt, self.shape_cal.producer_rtt)
            .max(rel(cal.mean_task_s, self.shape_cal.mean_task_s));
        // Tenant-mix drift: the first multi-tenant window just sets the
        // reference; later windows compare against it.
        let mix_drift = match (&mix, &self.mix_ref) {
            (Some(m), Some(r)) => Self::mix_distance(m, r),
            _ => 0.0,
        };
        if mix.is_some() && self.mix_ref.is_none() {
            self.mix_ref = mix.clone();
        }
        if cal_drift.max(mix_drift) < self.policy.drift_threshold {
            return None;
        }
        let new = choose_shape(&self.cfg, &cal);
        if new == self.shape {
            // The drifted inputs still select the current shape: adopt
            // them as the new reference, so a regime that drifted once
            // and then stabilized cannot fire a late transition.
            self.shape_cal = cal;
            if mix.is_some() {
                self.mix_ref = mix;
            }
            return None;
        }
        if now - self.last_transition < self.policy.cooldown {
            return None;
        }
        self.events.push(ReshapeEvent {
            t: now,
            from_depth: self.shape.0,
            from_fanout: self.shape.1.clone(),
            to_depth: new.0,
            to_fanout: new.1.clone(),
            cal,
        });
        self.shape = new.clone();
        self.shape_cal = cal;
        if mix.is_some() {
            self.mix_ref = mix;
        }
        self.last_transition = now;
        Some(new)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::{Payload, TaskSpec, RC_CANCELLED};

    fn cfg(np: usize, cpb: usize) -> SchedulerConfig {
        SchedulerConfig { np, consumers_per_buffer: cpb, ..Default::default() }
    }

    fn policy(window: f64, drift: f64, cooldown: f64) -> ReshapePolicy {
        ReshapePolicy { window, drift_threshold: drift, cooldown }
    }

    fn done(begin: f64, finish: f64) -> TaskResult {
        TaskResult {
            id: 0,
            consumer: 0,
            results: vec![],
            begin,
            finish,
            rc: 0,
            attempt: 0,
            timed_out: false,
        }
    }

    /// The long-task regime: a fast producer keeps the flat layout.
    fn flat_cal() -> Calibration {
        Calibration { producer_rtt: 1e-4, mean_task_s: 20.0 }
    }

    #[test]
    fn no_transition_before_the_window_closes() {
        let c = cfg(1024, 32);
        let shape = choose_shape(&c, &flat_cal());
        let mut ctrl = ReshapeController::new(&c, policy(10.0, 0.25, 0.0), shape, flat_cal(), 0.0);
        ctrl.observe_result(&done(0.0, 0.01));
        ctrl.observe_root_lag(100, 50.0);
        assert_eq!(ctrl.maybe_reshape(9.9), None, "window not closed yet");
    }

    #[test]
    fn duration_and_lag_drift_trigger_a_deeper_shape() {
        let c = cfg(1024, 32); // 32 leaves
        let shape = choose_shape(&c, &flat_cal());
        assert_eq!(shape.0, 1, "long tasks + fast producer start flat");
        let mut ctrl =
            ReshapeController::new(&c, policy(10.0, 0.25, 0.0), shape.clone(), flat_cal(), 0.0);
        // The workload shifts: 0.1-second tasks, and the producer's
        // request→grant lag balloons to ~5 ms per round trip.
        for i in 0..50 {
            ctrl.observe_result(&done(i as f64, i as f64 + 0.1));
        }
        ctrl.observe_root_lag(200, 1.0);
        let new = ctrl.maybe_reshape(10.0).expect("drifted inputs must re-shape");
        assert!(new.0 >= 2, "short tasks + slow producer must deepen: {new:?}");
        assert_eq!(ctrl.shape(), &new);
        assert_eq!(ctrl.events().len(), 1);
        let ev = &ctrl.events()[0];
        assert_eq!((ev.from_depth, ev.to_depth), (1, new.0));
        assert!((ev.cal.mean_task_s - 0.1).abs() < 1e-9);
        assert!((ev.cal.producer_rtt - 5e-3).abs() < 1e-9);
    }

    #[test]
    fn drift_below_threshold_never_fires() {
        let c = cfg(1024, 32);
        let shape = choose_shape(&c, &flat_cal());
        let mut ctrl =
            ReshapeController::new(&c, policy(10.0, 0.5, 0.0), shape, flat_cal(), 0.0);
        // 10% duration drift — under the 50% threshold.
        for i in 0..10 {
            ctrl.observe_result(&done(i as f64, i as f64 + 22.0));
        }
        assert_eq!(ctrl.maybe_reshape(10.0), None);
        // An empty window falls back to the reference: zero drift.
        assert_eq!(ctrl.maybe_reshape(20.0), None);
    }

    #[test]
    fn cooldown_blocks_back_to_back_transitions() {
        let c = cfg(1024, 32);
        let shape = choose_shape(&c, &flat_cal());
        let mut ctrl =
            ReshapeController::new(&c, policy(10.0, 0.25, 100.0), shape, flat_cal(), 0.0);
        for i in 0..20 {
            ctrl.observe_result(&done(i as f64, i as f64 + 0.1));
        }
        ctrl.observe_root_lag(200, 1.0);
        assert!(ctrl.maybe_reshape(10.0).is_some(), "first transition is free");
        ctrl.grafted(10.0);
        // Drift back toward long tasks immediately: shape would change,
        // but the cooldown gates it.
        for i in 0..20 {
            ctrl.observe_result(&done(i as f64, i as f64 + 20.0));
        }
        assert_eq!(ctrl.maybe_reshape(20.0), None, "cooldown must hold");
        for i in 0..20 {
            ctrl.observe_result(&done(i as f64, i as f64 + 20.0));
        }
        assert!(ctrl.maybe_reshape(115.0).is_some(), "cooldown expired");
        assert_eq!(ctrl.events().len(), 2);
    }

    #[test]
    fn lag_totals_are_differenced_per_window() {
        let c = cfg(1024, 32);
        let shape = choose_shape(&c, &flat_cal());
        let mut ctrl =
            ReshapeController::new(&c, policy(10.0, 0.25, 0.0), shape, flat_cal(), 0.0);
        // Window 1: cumulative (100, 0.01) → mean 1e-4, no drift.
        ctrl.observe_root_lag(100, 0.01);
        assert_eq!(ctrl.maybe_reshape(10.0), None);
        // Window 2: cumulative (200, 1.01) → the *delta* is 100 trips
        // worth 1.0 s → mean 10 ms, a 100× drift.
        for i in 0..20 {
            ctrl.observe_result(&done(i as f64, i as f64 + 0.1));
        }
        ctrl.observe_root_lag(200, 1.01);
        let new = ctrl.maybe_reshape(20.0).expect("windowed delta must drive the decision");
        assert!((ctrl.events()[0].cal.producer_rtt - 10e-3).abs() < 1e-9);
        assert!(new.0 >= 2);
    }

    #[test]
    fn cancelled_results_carry_no_duration_signal() {
        let c = cfg(1024, 32);
        let shape = choose_shape(&c, &flat_cal());
        let mut ctrl =
            ReshapeController::new(&c, policy(10.0, 0.25, 0.0), shape, flat_cal(), 0.0);
        let spec = TaskSpec::new(0, Payload::Sleep { seconds: 1.0 });
        let mut cancelled = TaskResult::cancelled_for(&spec);
        cancelled.rc = RC_CANCELLED;
        for _ in 0..50 {
            ctrl.observe_result(&cancelled);
        }
        // Only cancellations observed → duration falls back to the
        // reference → no drift → no transition.
        assert_eq!(ctrl.maybe_reshape(10.0), None);
    }

    #[test]
    fn mix_distance_is_total_variation() {
        let a: BTreeMap<ClassId, f64> = [(0u8, 0.5), (1u8, 0.5)].into_iter().collect();
        let b: BTreeMap<ClassId, f64> = [(0u8, 1.0)].into_iter().collect();
        assert!((ReshapeController::mix_distance(&a, &b) - 0.5).abs() < 1e-12);
        assert_eq!(ReshapeController::mix_distance(&a, &a), 0.0);
        let c: BTreeMap<ClassId, f64> = [(1u8, 1.0)].into_iter().collect();
        assert!((ReshapeController::mix_distance(&b, &c) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn class_mix_shift_opens_the_drift_gate() {
        let c = cfg(1024, 32);
        let shape = choose_shape(&c, &flat_cal());
        let mut ctrl =
            ReshapeController::new(&c, policy(10.0, 0.25, 0.0), shape, flat_cal(), 0.0);
        let mix = |a: u64, b: u64| {
            vec![
                ClassNodeStats { class: 0, popped: a, wait_hist: vec![] },
                ClassNodeStats { class: 1, popped: b, wait_hist: vec![] },
            ]
        };
        // Window 1: all grants to class 0 — just sets the reference mix.
        ctrl.observe_class_mix(&mix(100, 0));
        assert_eq!(ctrl.maybe_reshape(10.0), None);
        assert_eq!(ctrl.mix_ref.as_ref().and_then(|r| r.get(&0)).copied(), Some(1.0));
        // Window 2: the windowed mix flips entirely to class 1 (total
        // variation 1.0 ≥ 0.25). The calibration inputs are untouched, so
        // the forced re-check keeps the current shape and the absorb
        // branch adopts the new mix as reference — observable proof the
        // gate opened, with no spurious transition.
        ctrl.observe_class_mix(&mix(100, 300));
        assert_eq!(ctrl.maybe_reshape(20.0), None);
        assert!(ctrl.events().is_empty());
        assert_eq!(ctrl.mix_ref.as_ref().and_then(|r| r.get(&1)).copied(), Some(1.0));
        // Window 3: the same mix again — distance 0, the gate stays shut
        // and the reference is untouched.
        ctrl.observe_class_mix(&mix(100, 600));
        assert_eq!(ctrl.maybe_reshape(30.0), None);
        assert_eq!(ctrl.mix_ref.as_ref().and_then(|r| r.get(&1)).copied(), Some(1.0));
    }

    #[test]
    fn single_tenant_runs_feed_no_mix_signal() {
        let c = cfg(1024, 32);
        let shape = choose_shape(&c, &flat_cal());
        let mut ctrl =
            ReshapeController::new(&c, policy(10.0, 0.25, 0.0), shape, flat_cal(), 0.0);
        // One class (or none at all) can never produce a share *shift*.
        ctrl.observe_class_mix(&[]);
        assert_eq!(ctrl.maybe_reshape(10.0), None);
        ctrl.observe_class_mix(&[ClassNodeStats { class: 0, popped: 500, wait_hist: vec![] }]);
        assert_eq!(ctrl.maybe_reshape(20.0), None);
        assert!(ctrl.mix_ref.is_none());
    }

    #[test]
    fn stabilized_drift_updates_the_reference_without_firing() {
        // Inputs drift but choose_shape still picks the current shape:
        // the reference follows, so the same inputs next window show no
        // drift and can never fire a late transition.
        let c = cfg(64, 32); // 2 leaves: every calibration stays flat
        let shape = choose_shape(&c, &flat_cal());
        let mut ctrl =
            ReshapeController::new(&c, policy(10.0, 0.25, 0.0), shape.clone(), flat_cal(), 0.0);
        for i in 0..10 {
            ctrl.observe_result(&done(i as f64, i as f64 + 1.0)); // 20× drift
        }
        assert_eq!(ctrl.maybe_reshape(10.0), None);
        for i in 0..10 {
            ctrl.observe_result(&done(i as f64, i as f64 + 1.0)); // same regime
        }
        assert_eq!(ctrl.maybe_reshape(20.0), None, "reference absorbed the drift");
        assert!(ctrl.events().is_empty());
    }
}
