//! The distributed scheduler runtime: producer and buffer tree on
//! opposite ends of a [`crate::transport`] link.
//!
//! The paper runs CARAVAN's roles across a massive parallel machine; this
//! module is that split for real processes. The **root** side
//! ([`serve_scheduler`] / [`serve_links`]) runs the search engine and the
//! [`ProducerState`] machine, accepting one link per worker; each link is
//! one direct child (one "root slot") of the producer. The **worker**
//! side ([`run_worker`], the `caravan worker` subcommand) connects,
//! handshakes, and grafts a locally-threaded buffer tree
//! (`threads::spawn_tree`) under a *gateway* [`BufferState`]
//! whose parent is the socket instead of a channel.
//!
//! ## Handshake
//!
//! ```text
//! worker                          root
//!   | -- Hello{version, np} ------> |   (version gate)
//!   | <-- Welcome{slot, cfg} ------ |   (SchedulerConfig slice +
//!   |                               |    level / rank_base assignment)
//!   | -- Request{amount} ---------> |   gateway primes its credit
//!   | <-- Assign[tasks] ----------- |
//! ```
//!
//! ## Dead link = a recall that never acks
//!
//! The failure path reuses the drain-and-graft recall machinery
//! (PR 5): when a link times out past the liveness budget or closes, the
//! root treats the worker as recalled — [`ProducerState::on_child_dead`]
//! withdraws its credit, and every task the root had granted to that
//! worker and not yet seen complete is re-queued via
//! [`ProducerState::on_returned`], stamps intact, to be re-granted to the
//! surviving workers. Conservation holds: `submitted` and `completed`
//! are untouched by a crash; the lost tasks are simply *pending* again.
//! Duplicate results cannot arise because a worker's results are only
//! ever read by its own (now dead) reader thread, and a task is only
//! re-granted while absent from the set of results already processed.
//!
//! Workers heartbeat ([`WireMsg::Ping`]) so an idle-but-healthy link
//! never trips the liveness budget.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use super::metrics::{FillingRate, NodeStats};
use super::protocol::{BufferAction, BufferState, ProducerAction, ProducerState};
use super::threads::{spawn_tree, Executor, ParentLink, ProducerSink, Report, ToBuffer};
use crate::config::SchedulerConfig;
use crate::tasklib::{SearchEngine, TaskId, TaskSpec};
use crate::transport::wire::{WireConfig, WireMsg, PROTO_VERSION};
use crate::transport::{Endpoint, LinkStats, Listener, Transport, TransportError};

/// How long a worker may stay silent before the root declares its link
/// dead. Workers ping at [`PING_EVERY`], so a healthy idle link shows
/// traffic well inside this budget.
pub const DEFAULT_LIVENESS: Duration = Duration::from_secs(10);

/// Worker heartbeat cadence (must be comfortably under the liveness
/// budget).
pub const PING_EVERY: Duration = Duration::from_secs(2);

/// How long each side waits for the other's half of the handshake.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);

/// Root-side knobs for a distributed run.
pub struct ServeOptions {
    /// Worker links to accept before the run starts.
    pub workers: usize,
    /// Silence budget per link before dead-link handling fires.
    pub liveness: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { workers: 1, liveness: DEFAULT_LIVENESS }
    }
}

/// One accepted worker link, root-side.
struct WorkerLink {
    /// Send half; `None` once the link died.
    tx: Option<Box<dyn Transport>>,
    /// Tasks granted to this worker whose results the root has not seen.
    /// Drained back into the pending queue when the link dies.
    outstanding: HashMap<TaskId, TaskSpec>,
    /// Consumer processes this worker runs.
    np: usize,
    /// First global consumer rank of the worker's share.
    rank_base: usize,
    /// Peer label for logs.
    peer: String,
    /// Link counters, snapshotted at death or shutdown.
    final_stats: LinkStats,
    /// Whether the orderly shutdown notice reached this link.
    saw_shutdown: bool,
    dead: bool,
}

/// What the per-link reader threads feed the root loop.
enum Up {
    Msg { slot: usize, msg: WireMsg },
    Dead { slot: usize, why: String },
}

/// Accept `opts.workers` links on `listener`, then run the engine's
/// workload across them. Blocks until every task completed (or until no
/// live worker remains to complete them).
pub fn serve_scheduler(
    cfg: &SchedulerConfig,
    engine: Box<dyn SearchEngine>,
    listener: &Listener,
    opts: &ServeOptions,
) -> Result<Report, String> {
    let mut links = Vec::with_capacity(opts.workers);
    for _ in 0..opts.workers {
        let (t, peer) = listener.accept().map_err(|e| format!("accept: {e}"))?;
        crate::info!("worker connected from {peer}");
        links.push((t, peer));
    }
    serve_links(cfg, engine, links, opts)
}

/// Run the engine's workload across pre-established links (the
/// socket-free entry used by tests via
/// [`crate::transport::ChannelTransport`]). Each link must speak the
/// worker handshake: `Hello` in, `Welcome` out.
pub fn serve_links(
    cfg: &SchedulerConfig,
    mut engine: Box<dyn SearchEngine>,
    links: Vec<(Box<dyn Transport>, String)>,
    opts: &ServeOptions,
) -> Result<Report, String> {
    if links.is_empty() {
        return Err("serve_links: no worker links".into());
    }
    let n_workers = links.len();
    let t0 = Instant::now();
    let clock_scale = 1.0 / cfg.time_scale.max(1e-9);
    let poll = Duration::from_millis(cfg.flush_interval_ms.max(1));

    // --- handshake: Hello in, Welcome (config slice) out ---
    let base = cfg.np / n_workers;
    let rem = cfg.np % n_workers;
    let mut workers: Vec<WorkerLink> = Vec::with_capacity(n_workers);
    let mut readers = Vec::with_capacity(n_workers);
    let (up_tx, up_rx) = channel::<Up>();
    let mut rank_base = 0usize;
    for (slot, (mut t, peer)) in links.into_iter().enumerate() {
        let hello = t
            .recv_timeout(HANDSHAKE_TIMEOUT)
            .map_err(|e| format!("handshake with {peer}: {e}"))?;
        let requested = match hello {
            WireMsg::Hello { version, requested_np } => {
                if version != PROTO_VERSION {
                    return Err(format!(
                        "worker {peer} speaks protocol v{version}, expected v{PROTO_VERSION}"
                    ));
                }
                requested_np as usize
            }
            other => return Err(format!("worker {peer} sent {other:?} instead of Hello")),
        };
        // Share: an explicit worker offer wins; otherwise an even split of
        // the configured np (earlier slots absorb the remainder).
        let share = if requested > 0 { requested } else { base + usize::from(slot < rem) }.max(1);
        let wire_cfg = WireConfig::from_scheduler(cfg, share, 1, rank_base);
        t.send(&WireMsg::Welcome { slot: slot as u64, cfg: wire_cfg })
            .map_err(|e| format!("handshake with {peer}: {e}"))?;
        let (tx_half, mut rx_half) = t.split().map_err(|e| format!("split {peer}: {e}"))?;
        let up = up_tx.clone();
        let liveness = opts.liveness;
        readers.push(
            thread::Builder::new()
                .name(format!("link-reader-{slot}"))
                .spawn(move || loop {
                    match rx_half.recv_timeout(liveness) {
                        Ok(WireMsg::Ping) => continue, // liveness only
                        Ok(msg) => {
                            if up.send(Up::Msg { slot, msg }).is_err() {
                                break;
                            }
                        }
                        Err(TransportError::Timeout) => {
                            let _ = up.send(Up::Dead { slot, why: "liveness timeout".into() });
                            break;
                        }
                        Err(TransportError::Closed(why)) => {
                            let _ = up.send(Up::Dead { slot, why });
                            break;
                        }
                    }
                })
                .expect("spawn link reader"),
        );
        workers.push(WorkerLink {
            tx: Some(tx_half),
            outstanding: HashMap::new(),
            np: share,
            rank_base,
            peer,
            final_stats: LinkStats::default(),
            saw_shutdown: false,
            dead: false,
        });
        rank_base += share;
    }
    drop(up_tx); // readers hold the only clones
    let np_total = rank_base;

    // --- producer loop ---
    let mut state =
        ProducerState::new(n_workers).with_policy(cfg.policy).with_classes(cfg.class_table());
    let mut sink = ProducerSink { next_id: 0, staged: Vec::new(), cancels: Vec::new() };
    let mut filling = FillingRate::new();
    let mut all_results = Vec::new();
    engine.start(&mut sink);

    state.set_now(t0.elapsed().as_secs_f64() * clock_scale);
    drain_engine_net(&mut state, &mut sink, &mut *engine, &mut workers, &mut all_results);
    let done = engine.poll(&mut sink);
    drain_engine_net(&mut state, &mut sink, &mut *engine, &mut workers, &mut all_results);
    state.set_engine_done(done);

    let mut newly_dead: Vec<usize> = Vec::new();
    loop {
        state.set_now(t0.elapsed().as_secs_f64() * clock_scale);

        // Bury links that died since the last iteration: withdraw credit,
        // re-queue everything they still held, and re-grant it against
        // the surviving workers' outstanding requests.
        while let Some(slot) = newly_dead.pop() {
            let w = &mut workers[slot];
            if w.dead {
                continue;
            }
            w.dead = true;
            if let Some(tx) = w.tx.take() {
                w.final_stats = tx.stats();
            }
            let lost: Vec<TaskSpec> = w.outstanding.drain().map(|(_, t)| t).collect();
            crate::warnln!(
                "worker {} (slot {slot}) died; re-queueing {} in-flight tasks",
                w.peer,
                lost.len()
            );
            state.on_child_dead(slot);
            if !lost.is_empty() {
                state.on_returned(lost);
            }
            // `push_tasks` with nothing new re-runs grant matching, so the
            // recovered tasks flow out against already-recorded deficits.
            let acts = state.push_tasks(Vec::new());
            perform_wire(acts, &mut workers, &mut newly_dead);
        }

        if workers.iter().all(|w| w.dead) && !state.is_quiescent() {
            return Err(format!(
                "all {n_workers} worker links died with {} tasks unfinished",
                state.in_flight()
            ));
        }

        let shutdown_acts = state.maybe_shutdown();
        if perform_wire(shutdown_acts, &mut workers, &mut newly_dead) {
            break;
        }

        let msg = match up_rx.recv_timeout(poll) {
            Ok(m) => m,
            Err(RecvTimeoutError::Timeout) => {
                let done = engine.poll(&mut sink);
                drain_engine_net(&mut state, &mut sink, &mut *engine, &mut workers, &mut all_results);
                state.set_engine_done(done);
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Every reader exited; their Dead notices (already drained
                // from the channel) decide quiescence on the next pass.
                newly_dead.extend(workers.iter().enumerate().filter(|(_, w)| !w.dead).map(|(i, _)| i));
                if newly_dead.is_empty() && state.is_quiescent() {
                    break;
                }
                continue;
            }
        };
        state.set_now(t0.elapsed().as_secs_f64() * clock_scale);
        match msg {
            Up::Msg { slot, msg } => match msg {
                WireMsg::Request { amount } => {
                    let acts = state.on_request(slot, amount as usize);
                    perform_wire(acts, &mut workers, &mut newly_dead);
                }
                WireMsg::Results(results) => {
                    for r in &results {
                        workers[slot].outstanding.remove(&r.id);
                    }
                    state.on_results(results.len());
                    for r in &results {
                        if !r.cancelled() {
                            filling.record(r);
                        }
                        engine.on_done(r, &mut sink);
                    }
                    all_results.extend(results);
                    drain_engine_net(
                        &mut state,
                        &mut sink,
                        &mut *engine,
                        &mut workers,
                        &mut all_results,
                    );
                }
                WireMsg::Flush { amount, results } => {
                    // The coalesced uplink: one frame carrying a credit
                    // request and a result batch. Ledger and engine see the
                    // same per-result effects as separate frames would.
                    for r in &results {
                        workers[slot].outstanding.remove(&r.id);
                    }
                    let acts = state.on_flush(slot, amount as usize, results.len());
                    perform_wire(acts, &mut workers, &mut newly_dead);
                    for r in &results {
                        if !r.cancelled() {
                            filling.record(r);
                        }
                        engine.on_done(r, &mut sink);
                    }
                    all_results.extend(results);
                    drain_engine_net(
                        &mut state,
                        &mut sink,
                        &mut *engine,
                        &mut workers,
                        &mut all_results,
                    );
                }
                WireMsg::Returned(tasks) => {
                    for t in &tasks {
                        workers[slot].outstanding.remove(&t.id);
                    }
                    state.on_returned(tasks);
                    let acts = state.push_tasks(Vec::new());
                    perform_wire(acts, &mut workers, &mut newly_dead);
                }
                WireMsg::RecallAck => {
                    let _ = state.on_recall_ack(slot);
                }
                // Root-bound links never legitimately carry these.
                WireMsg::Hello { .. }
                | WireMsg::Welcome { .. }
                | WireMsg::Assign(_)
                | WireMsg::Cancel { .. }
                | WireMsg::Recall
                | WireMsg::Shutdown
                | WireMsg::Ping => {}
            },
            Up::Dead { slot, why } => {
                crate::warnln!("link to worker slot {slot} failed: {why}");
                newly_dead.push(slot);
            }
        }
    }
    engine.finish();

    // Snapshot surviving links and synthesize the per-worker stats rows:
    // one row per root slot, link traffic in the wire_* counters.
    for w in workers.iter_mut() {
        if let Some(tx) = w.tx.take() {
            w.final_stats = tx.stats();
        }
    }
    let node_stats: Vec<NodeStats> = workers
        .iter()
        .enumerate()
        .map(|(slot, w)| NodeStats {
            node: slot,
            level: 1,
            subtree_consumers: w.np,
            credit_bound: cfg.credit_factor * w.np,
            max_queue: 0,
            msgs_in: w.final_stats.msgs_in,
            msgs_out: w.final_stats.msgs_out,
            steals_attempted: 0,
            steals_failed: 0,
            steals_received: 0,
            steals_given: 0,
            cancelled_dropped: 0,
            cancelled_killed: 0,
            retried: 0,
            popped: 0,
            dispatch_batches: 0,
            coalesced_flushes: 0,
            wait_hist: Vec::new(),
            class_stats: Vec::new(),
            req_lag_n: 0,
            req_lag_mean: 0.0,
            req_lag_max: 0.0,
            saw_shutdown: w.saw_shutdown,
            wire_msgs_in: w.final_stats.msgs_in,
            wire_msgs_out: w.final_stats.msgs_out,
            wire_bytes_in: w.final_stats.bytes_in,
            wire_bytes_out: w.final_stats.bytes_out,
        })
        .collect();

    // Level fill against the equivalent single-host topology (worker
    // shares are contiguous rank ranges, so per-level aggregation is
    // meaningful even though the physical split differs).
    let mut eq_cfg = cfg.clone();
    eq_cfg.np = np_total.max(1);
    let topo = eq_cfg.tree();
    let level_fill = filling.level_fill(&topo);
    Ok(Report {
        results: all_results,
        filling,
        wall_secs: t0.elapsed().as_secs_f64(),
        producer_msgs_in: state.msgs_in,
        producer_msgs_out: state.msgs_out,
        node_stats,
        level_fill,
        // The global tree is one level of worker gateways over each
        // worker's local `cfg.depth` levels.
        depth: cfg.depth + 1,
        fanout: cfg.fanout.clone(),
        reshapes: Vec::new(),
    })
}

/// Flush engine submissions and cancellations into the producer state,
/// routing the resulting grants/broadcasts over the wire (the
/// `threads::drain_engine` shape, transported).
fn drain_engine_net(
    state: &mut ProducerState,
    sink: &mut ProducerSink,
    engine: &mut dyn SearchEngine,
    workers: &mut [WorkerLink],
    all_results: &mut Vec<crate::tasklib::TaskResult>,
) {
    let mut newly_dead = Vec::new();
    while !sink.staged.is_empty() || !sink.cancels.is_empty() {
        let acts = state.push_tasks(std::mem::take(&mut sink.staged));
        perform_wire(acts, workers, &mut newly_dead);
        for id in std::mem::take(&mut sink.cancels) {
            let (dropped, acts) = state.on_cancel(id);
            perform_wire(acts, workers, &mut newly_dead);
            if let Some(spec) = dropped {
                let r = crate::tasklib::TaskResult::cancelled_for(&spec);
                engine.on_done(&r, sink);
                all_results.push(r);
            }
        }
    }
    // Deaths noticed while sending are handled by the main loop; just
    // mark them so no further sends target the corpse.
    for slot in newly_dead {
        if let Some(w) = workers.get_mut(slot) {
            if !w.dead {
                w.dead = true;
                if let Some(tx) = w.tx.take() {
                    w.final_stats = tx.stats();
                }
                let lost: Vec<TaskSpec> = w.outstanding.drain().map(|(_, t)| t).collect();
                state.on_child_dead(slot);
                if !lost.is_empty() {
                    state.on_returned(lost);
                }
            }
        }
    }
}

/// Route producer actions over the worker links; send failures queue the
/// slot in `newly_dead`. Returns true when shutdown was broadcast.
fn perform_wire(
    actions: Vec<ProducerAction>,
    workers: &mut [WorkerLink],
    newly_dead: &mut Vec<usize>,
) -> bool {
    let mut shutdown = false;
    let mut send_to = |w: &mut WorkerLink, slot: usize, msg: &WireMsg, dead: &mut Vec<usize>| {
        if w.dead {
            return;
        }
        if let Some(tx) = w.tx.as_mut() {
            if tx.send(msg).is_err() {
                dead.push(slot);
            }
        }
    };
    for act in actions {
        match act {
            ProducerAction::SendTasks { buffer, tasks } => {
                let w = &mut workers[buffer];
                for t in &tasks {
                    w.outstanding.insert(t.id, t.clone());
                }
                send_to(w, buffer, &WireMsg::Assign(tasks), newly_dead);
            }
            ProducerAction::BroadcastCancel { id } => {
                for (slot, w) in workers.iter_mut().enumerate() {
                    send_to(w, slot, &WireMsg::Cancel { id }, newly_dead);
                }
            }
            ProducerAction::BroadcastRecall => {
                for (slot, w) in workers.iter_mut().enumerate() {
                    send_to(w, slot, &WireMsg::Recall, newly_dead);
                }
            }
            ProducerAction::BroadcastShutdown => {
                for (slot, w) in workers.iter_mut().enumerate() {
                    if !w.dead {
                        w.saw_shutdown = true;
                    }
                    send_to(w, slot, &WireMsg::Shutdown, newly_dead);
                }
                shutdown = true;
            }
        }
    }
    shutdown
}

/// What a worker run amounted to, for logs and tests.
pub struct WorkerReport {
    /// Root slot this worker occupied.
    pub slot: usize,
    /// Consumer processes run locally.
    pub np: usize,
    /// Results flushed upstream (cancelled drops included).
    pub tasks_run: usize,
    /// Link traffic counters.
    pub link: LinkStats,
}

/// Connect to `endpoint` and serve as a remote subtree until the root
/// shuts the run down (the `caravan worker` subcommand).
pub fn connect_worker(
    endpoint: &Endpoint,
    executor: Arc<dyn Executor>,
    requested_np: usize,
) -> Result<WorkerReport, String> {
    let t = endpoint.connect().map_err(|e| format!("connect {endpoint}: {e}"))?;
    run_worker(t, executor, requested_np)
}

/// Serve as a remote subtree over an established link: handshake, build
/// the local buffer tree from the `Welcome` config slice, and pump the
/// gateway until the root's shutdown (or the link's death) tears it down.
pub fn run_worker(
    transport: Box<dyn Transport>,
    executor: Arc<dyn Executor>,
    requested_np: usize,
) -> Result<WorkerReport, String> {
    let mut t = transport;
    t.send(&WireMsg::Hello { version: PROTO_VERSION, requested_np: requested_np as u64 })
        .map_err(|e| format!("hello: {e}"))?;
    let (slot, wire_cfg) = match t.recv_timeout(HANDSHAKE_TIMEOUT) {
        Ok(WireMsg::Welcome { slot, cfg }) => (slot as usize, cfg),
        Ok(other) => return Err(format!("expected Welcome, got {other:?}")),
        Err(e) => return Err(format!("welcome: {e}")),
    };
    let cfg = wire_cfg.to_scheduler();
    let rank_base = wire_cfg.rank_base as usize;
    let topo = cfg.tree();
    crate::info!(
        "worker slot {slot}: np={} depth={} ranks {}..{}",
        cfg.np,
        cfg.depth,
        rank_base,
        rank_base + cfg.np
    );

    let t0 = Instant::now();
    let clock_scale = 1.0 / cfg.time_scale.max(1e-9);
    let (gw_tx, gw_rx) = channel::<ToBuffer>();
    let reader_tx = gw_tx.clone();
    let tree = spawn_tree(&topo, &cfg, &executor, &ParentLink::Buffer(gw_tx), t0, clock_scale, false);

    let (mut wire_tx, mut wire_rx) =
        t.split().map_err(|e| format!("split: {e}"))?;
    let done = Arc::new(AtomicBool::new(false));
    let reader_done = Arc::clone(&done);
    let reader = thread::Builder::new()
        .name("worker-link-reader".into())
        .spawn(move || {
            link_reader(&mut *wire_rx, &reader_tx, &reader_done);
        })
        .expect("spawn worker link reader");

    // --- gateway loop: a BufferState whose parent is the wire ---
    let mut gw = BufferState::interior(
        topo.roots.len(),
        cfg.np,
        cfg.credit_factor,
        cfg.flush_every,
    )
    .with_policy(cfg.policy)
    .with_classes(cfg.class_table())
    .with_batching(cfg.dispatch_batch, cfg.coalesce_flush);
    let flush_interval = Duration::from_millis(cfg.flush_interval_ms.max(1));
    let mut tasks_run = 0usize;
    let mut stopping = false;
    let mut last_ping = Instant::now();
    gw.set_now(t0.elapsed().as_secs_f64() * clock_scale);
    let acts = gw.on_start();
    stopping |= route_gateway(acts, &mut wire_tx, &tree.root_txs, rank_base, &mut tasks_run);
    while !stopping {
        let msg = gw_rx.recv_timeout(flush_interval);
        gw.set_now(t0.elapsed().as_secs_f64() * clock_scale);
        if last_ping.elapsed() >= PING_EVERY {
            if wire_tx.send(&WireMsg::Ping).is_err() {
                break; // root is gone: tear the local tree down
            }
            last_ping = Instant::now();
        }
        let acts = match msg {
            Ok(ToBuffer::Assign(tasks)) => gw.on_assign(tasks),
            Ok(ToBuffer::ChildRequest { child, amount }) => gw.on_child_request(child, amount),
            Ok(ToBuffer::ChildResults(rs)) => gw.on_child_results(rs),
            Ok(ToBuffer::ChildFlush { child, amount, results }) => {
                gw.on_child_flush(child, amount, results)
            }
            Ok(ToBuffer::Cancel { id }) => gw.on_cancel(id),
            Ok(ToBuffer::Recall) => gw.on_recall(),
            Ok(ToBuffer::ChildReturned(tasks)) => gw.on_child_returned(tasks),
            Ok(ToBuffer::ChildRecallAck { child }) => gw.on_child_recall_ack(child),
            Ok(ToBuffer::Shutdown) => gw.on_shutdown(),
            // Consumer-facing and sideways traffic never reaches the
            // gateway (it has buffer children and no siblings).
            Ok(_) => Vec::new(),
            Err(RecvTimeoutError::Timeout) => gw.on_tick(),
            Err(RecvTimeoutError::Disconnected) => break,
        };
        stopping |= route_gateway(acts, &mut wire_tx, &tree.root_txs, rank_base, &mut tasks_run);
    }
    tree.join();
    done.store(true, Ordering::Relaxed);
    let link = wire_tx.stats();
    drop(wire_tx); // close our half so the root's reader unblocks promptly
    let _ = reader.join();
    Ok(WorkerReport { slot, np: cfg.np, tasks_run, link })
}

/// Pump the worker's receive half into the gateway channel. Root silence
/// is tolerated (the root only speaks when granting); a closed link
/// injects `Shutdown` so the local tree drains and the worker exits.
fn link_reader(rx: &mut dyn Transport, gw: &Sender<ToBuffer>, done: &AtomicBool) {
    loop {
        match rx.recv_timeout(Duration::from_millis(500)) {
            Ok(msg) => {
                let fwd = match msg {
                    WireMsg::Assign(tasks) => Some(ToBuffer::Assign(tasks)),
                    WireMsg::Cancel { id } => Some(ToBuffer::Cancel { id }),
                    WireMsg::Recall => Some(ToBuffer::Recall),
                    WireMsg::Shutdown => Some(ToBuffer::Shutdown),
                    // Pings need no reply; anything else is not
                    // worker-bound traffic.
                    _ => None,
                };
                if let Some(m) = fwd {
                    let shutdown = matches!(m, ToBuffer::Shutdown);
                    if gw.send(m).is_err() || shutdown {
                        break;
                    }
                }
            }
            Err(TransportError::Timeout) => {
                if done.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(TransportError::Closed(_)) => {
                let _ = gw.send(ToBuffer::Shutdown);
                break;
            }
        }
    }
}

/// Route gateway actions: grants and fan-out notices go down the local
/// root channels; requests, result flushes (consumer ranks globalized),
/// returns and acks go up the wire. Returns true when the gateway
/// initiated its own stop.
fn route_gateway(
    acts: Vec<BufferAction>,
    wire: &mut dyn Transport,
    root_txs: &[Sender<ToBuffer>],
    rank_base: usize,
    tasks_run: &mut usize,
) -> bool {
    let mut stopping = false;
    for act in acts {
        match act {
            BufferAction::SendToChild { child, tasks } => {
                let _ = root_txs[child].send(ToBuffer::Assign(tasks));
            }
            BufferAction::RequestTasks { amount } => {
                if wire.send(&WireMsg::Request { amount: amount as u64 }).is_err() {
                    stopping = true;
                }
            }
            BufferAction::FlushResults(mut rs) => {
                if rs.is_empty() {
                    continue;
                }
                for r in rs.iter_mut() {
                    // Globalize consumer ranks; the synthesized rank of a
                    // cancelled-before-running result stays sentinel.
                    if r.consumer != usize::MAX {
                        r.consumer += rank_base;
                    }
                }
                *tasks_run += rs.len();
                if wire.send(&WireMsg::Results(rs)).is_err() {
                    stopping = true;
                }
            }
            BufferAction::Flush { amount, results } => {
                let mut rs = results;
                for r in rs.iter_mut() {
                    if r.consumer != usize::MAX {
                        r.consumer += rank_base;
                    }
                }
                *tasks_run += rs.len();
                if wire.send(&WireMsg::Flush { amount: amount as u64, results: rs }).is_err() {
                    stopping = true;
                }
            }
            BufferAction::CancelChildren { id } => {
                for tx in root_txs {
                    let _ = tx.send(ToBuffer::Cancel { id });
                }
            }
            BufferAction::ShutdownChildren => {
                for tx in root_txs {
                    let _ = tx.send(ToBuffer::Shutdown);
                }
                stopping = true;
            }
            BufferAction::ReturnTasks(tasks) => {
                if wire.send(&WireMsg::Returned(tasks)).is_err() {
                    stopping = true;
                }
            }
            BufferAction::RecallChildren => {
                for tx in root_txs {
                    let _ = tx.send(ToBuffer::Recall);
                }
            }
            BufferAction::AckRecall => {
                if wire.send(&WireMsg::RecallAck).is_err() {
                    stopping = true;
                }
            }
            // The gateway has buffer children, no local consumers and no
            // siblings: these actions cannot be emitted for it.
            BufferAction::RunBatch { .. }
            | BufferAction::StealRequest { .. }
            | BufferAction::StealGrant { .. }
            | BufferAction::CancelRunning { .. }
            | BufferAction::ShutdownConsumers => {}
        }
    }
    stopping
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::JobSink;
    use crate::scheduler::SleepExecutor;
    use crate::tasklib::{Payload, TaskResult};

    struct Sleeps(usize);
    impl SearchEngine for Sleeps {
        fn start(&mut self, sink: &mut dyn JobSink) {
            for _ in 0..self.0 {
                sink.submit(Payload::Sleep { seconds: 1.0 });
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
    }

    fn quick(np: usize) -> SchedulerConfig {
        SchedulerConfig {
            np,
            consumers_per_buffer: 4,
            flush_interval_ms: 2,
            time_scale: 0.001,
            ..Default::default()
        }
    }

    /// Two in-process workers over channel transports: the full
    /// distributed loop without sockets.
    #[test]
    fn serve_two_channel_workers_end_to_end() {
        use crate::transport::ChannelTransport;
        let (a_root, a_worker) = ChannelTransport::pair();
        let (b_root, b_worker) = ChannelTransport::pair();
        let workers: Vec<_> = [a_worker, b_worker]
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    run_worker(
                        Box::new(t),
                        Arc::new(SleepExecutor { time_scale: 0.001 }),
                        0,
                    )
                })
            })
            .collect();
        let report = serve_links(
            &quick(8),
            Box::new(Sleeps(60)),
            vec![
                (Box::new(a_root) as Box<dyn Transport>, "a".into()),
                (Box::new(b_root) as Box<dyn Transport>, "b".into()),
            ],
            &ServeOptions { workers: 2, ..Default::default() },
        )
        .expect("distributed run");
        assert_eq!(report.results.len(), 60);
        let mut ids: Vec<u64> = report.results.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 60, "every task exactly once");
        assert_eq!(report.node_stats.len(), 2);
        assert!(report.node_stats.iter().all(|s| s.saw_shutdown));
        // Each worker ran its share: ranks 0..4 and 4..8 both appear.
        let ranks: std::collections::HashSet<usize> =
            report.results.iter().map(|r| r.consumer).collect();
        assert!(ranks.iter().any(|&r| r < 4) && ranks.iter().any(|&r| (4..8).contains(&r)));
        for w in workers {
            let wr = w.join().unwrap().expect("worker ok");
            assert_eq!(wr.np, 4);
            assert!(wr.tasks_run > 0);
        }
    }

    /// Killing a worker's link mid-run must lose nothing: its tasks are
    /// re-granted to the survivor (dead link = recall that never acks).
    #[test]
    fn dead_link_regrants_outstanding_tasks() {
        use crate::transport::ChannelTransport;
        let (a_root, a_worker) = ChannelTransport::pair();
        let (b_root, b_worker) = ChannelTransport::pair();
        let survivor = thread::spawn(move || {
            run_worker(Box::new(a_worker), Arc::new(SleepExecutor { time_scale: 0.001 }), 0)
        });
        // Victim: handshake manually, accept one grant, then vanish
        // without returning anything.
        let victim = thread::spawn(move || {
            let mut t: Box<dyn Transport> = Box::new(b_worker);
            t.send(&WireMsg::Hello { version: PROTO_VERSION, requested_np: 0 }).unwrap();
            let Ok(WireMsg::Welcome { .. }) = t.recv_timeout(Duration::from_secs(10)) else {
                panic!("no welcome");
            };
            t.send(&WireMsg::Request { amount: 8 }).unwrap();
            // Wait for at least one grant so tasks are genuinely lost.
            loop {
                match t.recv_timeout(Duration::from_secs(10)) {
                    Ok(WireMsg::Assign(tasks)) if !tasks.is_empty() => break,
                    Ok(_) => continue,
                    Err(e) => panic!("victim link: {e}"),
                }
            }
            // Drop the transport: the root's reader sees Closed.
        });
        let report = serve_links(
            &quick(8),
            Box::new(Sleeps(40)),
            vec![
                (Box::new(a_root) as Box<dyn Transport>, "survivor".into()),
                (Box::new(b_root) as Box<dyn Transport>, "victim".into()),
            ],
            &ServeOptions { workers: 2, ..Default::default() },
        )
        .expect("run survives a dead worker");
        victim.join().unwrap();
        let _ = survivor.join().unwrap();
        assert_eq!(report.results.len(), 40, "conservation across the crash");
        let mut ids: Vec<u64> = report.results.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40, "no duplicate completions");
        // The dead slot's row survives with its link traffic accounted.
        assert_eq!(report.node_stats.len(), 2);
        assert!(report.node_stats[1].wire_msgs_out > 0);
    }

    /// A worker whose root disappears tears itself down instead of
    /// hanging.
    #[test]
    fn worker_exits_when_root_vanishes() {
        use crate::transport::ChannelTransport;
        let (root_end, worker_end) = ChannelTransport::pair();
        let worker = thread::spawn(move || {
            run_worker(Box::new(worker_end), Arc::new(SleepExecutor { time_scale: 0.001 }), 0)
        });
        let mut t: Box<dyn Transport> = Box::new(root_end);
        let hello = t.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(matches!(hello, WireMsg::Hello { .. }));
        t.send(&WireMsg::Welcome {
            slot: 0,
            cfg: WireConfig::from_scheduler(&quick(4), 4, 1, 0),
        })
        .unwrap();
        // Answer the first credit request with one grant, then vanish.
        loop {
            match t.recv_timeout(Duration::from_secs(10)).unwrap() {
                WireMsg::Request { .. } => break,
                _ => continue,
            }
        }
        drop(t);
        let wr = worker.join().unwrap().expect("worker exits cleanly");
        assert_eq!(wr.slot, 0);
    }
}
