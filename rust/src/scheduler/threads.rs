//! The threaded scheduler runtime.
//!
//! Executes the protocol of [`super::protocol`] with real OS threads and
//! channels: one producer thread (≈ MPI rank 0), one thread per buffer-tree
//! node (leaf and interior), one thread per consumer process. The search
//! engine runs inside the producer thread, exactly as CARAVAN runs the
//! Python search engine attached to rank 0; consumers execute task
//! payloads through a user-supplied [`Executor`].
//!
//! The buffer layer is the N-level tree described by
//! [`SchedulerConfig::depth`]: interior nodes relay demand-driven credit
//! downward and batched results upward, and (with
//! [`SchedulerConfig::steal`]) siblings exchange queued tasks directly
//! through their own channels — the producer never sees sideways moves.
//!
//! Job API v2 semantics (priority, transparent retry, cancellation) live
//! in the protocol state machines; this runtime only routes the extra
//! messages: `Cancel` notices fan out from the producer toward the
//! leaves, and cancelled-task results flow back through the ordinary
//! result path.
//!
//! With [`SchedulerConfig::reshape`] the runtime runs in *epochs*: the
//! reshape controller (fed live `NodeStats` lag counters and observed
//! task durations) may at any window boundary trigger a drain-and-graft
//! — recall the tree, join its threads, rebuild at the new shape — while
//! the producer state (pending queue, accounting) carries across. See
//! [`run_scheduler`].
//!
//! On a small host this is concurrency rather than parallelism, which is
//! fine for the framework's own behaviour (dummy `Sleep` tasks idle, and
//! in-process evaluations are serialized by the PJRT executor anyway).

use std::collections::HashSet;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use super::metrics::{FillingRate, LevelFill, NodeStats};
use super::protocol::{resolve_shape, BufferAction, BufferState, ProducerAction, ProducerState};
use super::reshape::{ReshapeController, ReshapeEvent};
use crate::api::{JobSink, JobSpec};
use crate::config::{Calibration, SchedulerConfig, TreeNodeKind, TreeShape, TreeTopology};
use crate::tasklib::{
    Payload, SearchEngine, TaskId, TaskResult, TaskSink, TaskSpec, RC_CANCELLED, RC_TIMEOUT,
};

/// Shard count of [`CancelSet`]. Eight spreads the consumers of even the
/// widest leaf across enough locks that the per-slice `is_cancelled`
/// polls of busy executors stop serializing on one mutex.
const CANCEL_SHARDS: u64 = 8;

/// Kill switch shared between a leaf node and its consumers: ids whose
/// *running* attempt should be aborted. The leaf's node thread marks an
/// id when the protocol emits [`BufferAction::CancelRunning`]; executors
/// poll [`CancelSet::is_cancelled`] from their wait loops and report
/// [`RC_CANCELLED`] when it fires. Executors that never poll simply let
/// the attempt finish — cancellation stays best-effort for them.
///
/// This set — not the task queue, which is owned by its node thread — is
/// the leaf's only cross-thread hot-path lock: every polling executor
/// hits it once per wait slice. It is therefore sharded by `id %
/// CANCEL_SHARDS` under reader/writer locks, so concurrent polls (the
/// overwhelmingly common case) never contend with each other, only with
/// the rare mark/clear writes to the same shard.
#[derive(Default)]
pub struct CancelSet([RwLock<HashSet<TaskId>>; CANCEL_SHARDS as usize]);

impl CancelSet {
    pub fn new() -> Self {
        Self::default()
    }

    fn shard(&self, id: TaskId) -> &RwLock<HashSet<TaskId>> {
        &self.0[(id % CANCEL_SHARDS) as usize]
    }

    /// Mark `id`: its running attempt should be killed.
    pub fn request(&self, id: TaskId) {
        self.shard(id).write().unwrap().insert(id);
    }

    pub fn is_cancelled(&self, id: TaskId) -> bool {
        self.shard(id).read().unwrap().contains(&id)
    }

    /// Retire the mark once the attempt finished (killed or not).
    pub fn clear(&self, id: TaskId) {
        self.shard(id).write().unwrap().remove(&id);
    }
}

/// What one attempt produced, as reported by an [`Executor`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ExecOutcome {
    pub results: Vec<f64>,
    pub rc: i32,
    /// True iff the executor cut the attempt short at its `timeout_s`
    /// budget — the authoritative timeout signal (a simulator may
    /// legitimately exit with status [`crate::tasklib::RC_TIMEOUT`]).
    pub timed_out: bool,
}

/// Runs task payloads on a consumer thread.
pub trait Executor: Send + Sync {
    /// Execute the payload; return (result vector, return code).
    fn run(&self, task: &TaskSpec, consumer: usize) -> (Vec<f64>, i32);

    /// Cancellation-aware variant driven by the scheduler runtime.
    /// Executors that can abort mid-flight (child processes, chunked
    /// sleeps) override this and poll `cancel`; the default ignores it
    /// and runs the attempt to completion.
    fn run_cancellable(&self, task: &TaskSpec, consumer: usize, cancel: &CancelSet) -> ExecOutcome {
        let _ = cancel;
        let (results, rc) = self.run(task, consumer);
        ExecOutcome { results, rc, timed_out: false }
    }
}

/// Executor for dummy [`Payload::Sleep`] tasks with time compression:
/// a virtual second lasts `time_scale` real seconds.
pub struct SleepExecutor {
    pub time_scale: f64,
}

impl SleepExecutor {
    fn seconds(task: &TaskSpec) -> f64 {
        match &task.payload {
            Payload::Sleep { seconds } => *seconds,
            other => panic!("SleepExecutor got {other:?}"),
        }
    }
}

impl Executor for SleepExecutor {
    fn run(&self, task: &TaskSpec, _consumer: usize) -> (Vec<f64>, i32) {
        let seconds = Self::seconds(task);
        let real = seconds * self.time_scale;
        if real > 0.0 {
            thread::sleep(Duration::from_secs_f64(real));
        }
        (vec![seconds], 0)
    }

    /// Sleep in small slices so a kill-on-cancel lands within ~1 ms, and
    /// enforce the per-attempt budget: `timeout_s` is in *virtual*
    /// seconds (the same unit as the sleep itself), scaled like the
    /// sleep, so the threaded runtime truncates exactly where the DES
    /// does.
    fn run_cancellable(&self, task: &TaskSpec, _consumer: usize, cancel: &CancelSet) -> ExecOutcome {
        let seconds = Self::seconds(task);
        let mut remaining = seconds * self.time_scale;
        let budget = task.timeout_s.map(|s| s * self.time_scale);
        let mut elapsed = 0.0f64;
        const POLL: f64 = 0.001;
        while remaining > 0.0 {
            if cancel.is_cancelled(task.id) {
                return ExecOutcome { results: Vec::new(), rc: RC_CANCELLED, timed_out: false };
            }
            if budget.is_some_and(|b| elapsed >= b) {
                return ExecOutcome { results: Vec::new(), rc: RC_TIMEOUT, timed_out: true };
            }
            let slice = remaining.min(POLL);
            thread::sleep(Duration::from_secs_f64(slice));
            remaining -= slice;
            elapsed += slice;
        }
        ExecOutcome { results: vec![seconds], rc: 0, timed_out: false }
    }
}

pub(crate) enum ToProducer {
    Request { buffer: usize, amount: usize },
    Results(Vec<TaskResult>),
    /// Coalesced credit request + result flush from root slot `buffer`:
    /// one channel send where an uncoalesced root would pay two.
    Flush { buffer: usize, amount: usize, results: Vec<TaskResult> },
    /// Recalled tasks returning from a draining tree (stamps intact).
    Returned(Vec<TaskSpec>),
    /// Root slot `buffer` reports its subtree drained.
    RecallAck { buffer: usize },
}

pub(crate) enum ToBuffer {
    Assign(Vec<TaskSpec>),
    /// A consumer finished its whole dispatched batch: every result rides
    /// one channel send (batch length 1 under `dispatch_batch = 1`).
    DoneBatch { consumer: usize, results: Vec<TaskResult> },
    ChildRequest { child: usize, amount: usize },
    ChildResults(Vec<TaskResult>),
    /// Coalesced credit request + result flush from child slot `child`.
    ChildFlush { child: usize, amount: usize, results: Vec<TaskResult> },
    /// Steal request from the sibling at slot `thief`.
    Steal { thief: usize, amount: usize },
    /// Reply to our steal request (possibly empty): the victim's slot, its
    /// remaining queue depth, its pending cancellation notices, and the
    /// surrendered tasks.
    Stolen { from_slot: usize, left: usize, cancels: Vec<TaskId>, tasks: Vec<TaskSpec> },
    /// Cancellation notice fanning out toward the leaves.
    Cancel { id: TaskId },
    /// Recall notice (drain-and-graft transition) fanning out toward the
    /// leaves: stop requesting, return queued tasks upstream, ack when
    /// drained.
    Recall,
    /// Recalled tasks returned by a child buffer.
    ChildReturned(Vec<TaskSpec>),
    /// Child slot `child` acked the recall.
    ChildRecallAck { child: usize },
    Shutdown,
}

enum ToConsumer {
    /// Run the tasks back to back, reporting all results in one
    /// [`ToBuffer::DoneBatch`] — N executions per channel round trip.
    RunBatch(Vec<TaskSpec>),
    Stop,
}

/// Where a node's upstream messages go: rank 0, an interior parent, or
/// (in a remote worker) the socket gateway standing in for the parent.
#[derive(Clone)]
pub(crate) enum ParentLink {
    Producer(Sender<ToProducer>),
    Buffer(Sender<ToBuffer>),
}

/// Per-node counter snapshots shared between the node threads (writers)
/// and the producer thread (reader: final report + the reshape
/// controller's live lag measurement).
pub(crate) type SharedStats = Arc<Mutex<Vec<Option<NodeStats>>>>;

/// What a node feeds: consumer threads (leaf) or child node threads.
enum ChildLink {
    Consumers(Vec<Sender<ToConsumer>>),
    Buffers(Vec<Sender<ToBuffer>>),
}

/// Outcome of a scheduler run.
pub struct Report {
    pub results: Vec<TaskResult>,
    pub filling: FillingRate,
    pub wall_secs: f64,
    pub producer_msgs_in: u64,
    pub producer_msgs_out: u64,
    /// Per-node counters of the buffer tree, in node-id order.
    pub node_stats: Vec<NodeStats>,
    /// Per-level filling statistics (mean/min subtree rate), mirroring
    /// the DES report so both runtimes expose the same observability.
    pub level_fill: Vec<LevelFill>,
    /// Effective tree depth at the end of the run (the auto controller's
    /// choice under [`TreeShape::Auto`] / [`TreeShape::Calibrated`],
    /// possibly revised online by `--reshape`).
    pub depth: usize,
    /// Effective per-level interior fanout at the end of the run
    /// (root-down; empty for the flat layout).
    pub fanout: Vec<usize>,
    /// Drain-and-graft transitions executed by the reshape controller
    /// (empty without [`SchedulerConfig::reshape`]).
    pub reshapes: Vec<ReshapeEvent>,
}

impl Report {
    pub fn rate(&self, np: usize) -> f64 {
        self.filling.rate(np)
    }

    /// Results that were cancelled before running.
    pub fn cancelled(&self) -> usize {
        self.results.iter().filter(|r| r.cancelled()).count()
    }
}

/// Sink handing engine submissions (and cancellations) to the producer
/// state machine. Shared with [`super::net`], whose root loop drives the
/// same engine over socket links instead of channels.
pub(crate) struct ProducerSink {
    pub(crate) next_id: u64,
    pub(crate) staged: Vec<TaskSpec>,
    pub(crate) cancels: Vec<TaskId>,
}

impl TaskSink for ProducerSink {
    fn submit(&mut self, payload: Payload) -> u64 {
        self.submit_job(JobSpec::new(payload))
    }
}

impl JobSink for ProducerSink {
    fn submit_job(&mut self, spec: JobSpec) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.staged.push(spec.into_task(id));
        id
    }

    fn cancel(&mut self, id: TaskId) {
        self.cancels.push(id);
    }
}

/// Run `engine`'s workload on the hierarchical scheduler.
///
/// Blocks until every task (including dynamically created ones) completed,
/// then returns the full result set and the schedule metrics.
///
/// With [`SchedulerConfig::reshape`] set, the run proceeds in **epochs**:
/// one buffer tree per epoch, torn down and rebuilt at a new shape
/// whenever the reshape controller fires. A transition is drain-and-graft:
/// the producer broadcasts a recall, every node returns its queued tasks
/// upstream (stamps intact) and acks once its subtree is drained — per
/// mpsc FIFO, a node's returned tasks and result flushes always reach its
/// parent before its ack, so when every root has acked the old tree is
/// provably empty — then the old threads are joined and the next epoch's
/// tree is spawned. The producer state (pending queue, conservation
/// accounting) carries across epochs; only the wiring is rebuilt.
pub fn run_scheduler(
    cfg: &SchedulerConfig,
    mut engine: Box<dyn SearchEngine>,
    executor: Arc<dyn Executor>,
) -> Report {
    let np = cfg.np;
    let t0 = Instant::now();
    // Queue clocks run in *virtual* seconds (wall seconds ÷ time_scale),
    // the unit `timeout_s`, deadlines and aging steps are expressed in —
    // so policy ordering matches the DES exactly under time compression.
    let clock_scale = 1.0 / cfg.time_scale.max(1e-9);

    // Engine intake happens before the tree is built: under
    // [`TreeShape::Auto`] the calibration phase below executes a few of
    // the staged tasks inline to measure real durations.
    let mut sink = ProducerSink { next_id: 0, staged: Vec::new(), cancels: Vec::new() };
    let mut filling = FillingRate::new();
    let mut all_results: Vec<TaskResult> = Vec::new();
    engine.start(&mut sink);

    // Mirror of the DES resolution path: only TreeShape::Auto pays for a
    // measurement; everything funnels through the one shared resolver.
    let measured = match cfg.shape {
        TreeShape::Auto => calibrate_threaded(
            np,
            &mut sink,
            &mut *engine,
            &executor,
            t0,
            clock_scale,
            &mut filling,
            &mut all_results,
        ),
        _ => Calibration::fallback(),
    };
    let mut shape = resolve_shape(cfg, measured);
    // Online re-shaping: the drift reference is whatever calibration
    // chose the initial shape.
    let reference_cal = match cfg.shape {
        TreeShape::Calibrated(c) => c,
        _ => measured,
    };
    let mut controller = cfg.reshape.map(|p| {
        ReshapeController::new(
            cfg,
            p,
            shape.clone(),
            reference_cal,
            t0.elapsed().as_secs_f64() * clock_scale,
        )
    });
    // Live per-node stats publishing (for the controller's rolling lag
    // measurement) is only paid for when re-shaping is on.
    let live_stats = controller.is_some();

    let poll_interval = Duration::from_millis(cfg.flush_interval_ms.max(1));
    // Producer state survives epochs; the channel wiring does not.
    let mut carried: Option<ProducerState> = None;

    enum Outcome {
        Done,
        Reshape,
    }

    // --- epoch loop: one buffer tree per iteration ---
    let (topo, node_stats, state) = loop {
        let topo = TreeTopology::build(np, cfg.consumers_per_buffer, shape.0, &shape.1);
        let n_nodes = topo.n_nodes();
        crate::debugln!(
            "scheduler: np={} nodes={} depth={} roots={:?}",
            np,
            n_nodes,
            topo.depth,
            topo.roots
        );

        // Spawn the whole tree behind its channels; the producer keeps a
        // sender per root plus the shared stats mirror.
        let (prod_tx, prod_rx) = channel::<ToProducer>();
        let tree = spawn_tree(
            &topo,
            cfg,
            &executor,
            &ParentLink::Producer(prod_tx),
            t0,
            clock_scale,
            live_stats,
        );
        let root_txs = tree.root_txs.clone();
        let stats = Arc::clone(&tree.stats);

        // --- producer loop (runs on the caller thread) ---
        let mut state = match carried.take() {
            Some(mut s) => {
                s.rewire(topo.roots.len());
                s
            }
            None => ProducerState::new(topo.roots.len())
                .with_policy(cfg.policy)
                .with_classes(cfg.class_table()),
        };

        state.set_now(t0.elapsed().as_secs_f64() * clock_scale);
        drain_engine(&mut state, &mut sink, &mut *engine, &root_txs, &mut all_results);
        let done = engine.poll(&mut sink);
        drain_engine(&mut state, &mut sink, &mut *engine, &root_txs, &mut all_results);
        state.set_engine_done(done);

        let mut outcome = Outcome::Done;
        loop {
            state.set_now(t0.elapsed().as_secs_f64() * clock_scale);
            // Shutdown check (engine may have submitted nothing at all).
            let shutdown_acts = state.maybe_shutdown();
            if perform_producer(shutdown_acts, &root_txs) {
                break;
            }
            let msg = match prod_rx.recv_timeout(poll_interval) {
                Ok(m) => m,
                Err(RecvTimeoutError::Timeout) => {
                    // Give session-style engines a chance to inject work.
                    let done = engine.poll(&mut sink);
                    drain_engine(&mut state, &mut sink, &mut *engine, &root_txs, &mut all_results);
                    state.set_engine_done(done);
                    // Reshape tick: rebuild the rolling calibration from
                    // the roots' live lag counters and re-run the shape
                    // controller (both in virtual seconds, mirroring the
                    // DES exactly).
                    if !state.is_recalling() && !state.shutdown_sent() {
                        let now = t0.elapsed().as_secs_f64() * clock_scale;
                        let fire = match controller.as_mut() {
                            Some(ctrl) => {
                                let (mut lag_n, mut lag_sum) = (0u64, 0.0f64);
                                {
                                    let rows = stats.lock().unwrap();
                                    for &r in &topo.roots {
                                        if let Some(s) = &rows[r] {
                                            lag_n += s.req_lag_n;
                                            lag_sum += s.req_lag_mean * s.req_lag_n as f64;
                                        }
                                    }
                                }
                                ctrl.observe_root_lag(lag_n, lag_sum);
                                ctrl.observe_class_mix(&state.class_stats());
                                ctrl.maybe_reshape(now).is_some()
                            }
                            None => false,
                        };
                        if fire {
                            let acts = state.begin_recall();
                            perform_producer(acts, &root_txs);
                        }
                    }
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => break,
            };
            state.set_now(t0.elapsed().as_secs_f64() * clock_scale);
            match msg {
                ToProducer::Request { buffer, amount } => {
                    let acts = state.on_request(buffer, amount);
                    perform_producer(acts, &root_txs);
                }
                ToProducer::Results(results) => {
                    state.on_results(results.len());
                    if let Some(ctrl) = controller.as_mut() {
                        for r in &results {
                            ctrl.observe_result(r);
                        }
                    }
                    for r in &results {
                        // Cancelled tasks never ran: keep them out of the
                        // filling-rate trace.
                        if !r.cancelled() {
                            filling.record(r);
                        }
                        engine.on_done(r, &mut sink);
                    }
                    all_results.extend(results);
                    drain_engine(&mut state, &mut sink, &mut *engine, &root_txs, &mut all_results);
                }
                ToProducer::Flush { buffer, amount, results } => {
                    let acts = state.on_flush(buffer, amount, results.len());
                    perform_producer(acts, &root_txs);
                    if let Some(ctrl) = controller.as_mut() {
                        for r in &results {
                            ctrl.observe_result(r);
                        }
                    }
                    for r in &results {
                        if !r.cancelled() {
                            filling.record(r);
                        }
                        engine.on_done(r, &mut sink);
                    }
                    all_results.extend(results);
                    drain_engine(&mut state, &mut sink, &mut *engine, &root_txs, &mut all_results);
                }
                ToProducer::Returned(tasks) => {
                    state.on_returned(tasks);
                }
                ToProducer::RecallAck { buffer } => {
                    if state.on_recall_ack(buffer) {
                        outcome = Outcome::Reshape;
                        break;
                    }
                }
            }
        }

        // Teardown. After a drain every node is empty, so a shutdown
        // notice walks the tree and stops every thread; after a normal
        // completion the shutdown broadcast already did.
        if matches!(outcome, Outcome::Reshape) {
            for tx in &root_txs {
                let _ = tx.send(ToBuffer::Shutdown);
            }
        }
        drop(root_txs);
        tree.join();

        let node_stats: Vec<NodeStats> = stats
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .map(|(id, s)| {
                s.clone().unwrap_or_else(|| {
                    // Node thread died without reporting; synthesize an
                    // empty row so the report stays index-aligned with
                    // the topology.
                    BufferState::for_tree_node(&topo, id, cfg).stats(id, topo.nodes[id].level)
                })
            })
            .collect();

        match outcome {
            Outcome::Done => break (topo, node_stats, state),
            Outcome::Reshape => {
                // Graft: adopt the controller's shape and spin up the
                // next epoch with the carried producer state.
                if let Some(ctrl) = controller.as_mut() {
                    shape = ctrl.shape().clone();
                    ctrl.grafted(t0.elapsed().as_secs_f64() * clock_scale);
                }
                carried = Some(state);
            }
        }
    };
    engine.finish();

    let level_fill = filling.level_fill(&topo);
    let reshapes = controller.as_ref().map(|c| c.events().to_vec()).unwrap_or_default();
    // Report the controller's final shape (mirrors the DES): a transition
    // decided in the run's last instants is reflected here even when the
    // workload finished before the graft could complete.
    let (depth, fanout) = match &controller {
        Some(c) => c.shape().clone(),
        None => shape,
    };
    Report {
        results: all_results,
        filling,
        wall_secs: t0.elapsed().as_secs_f64(),
        producer_msgs_in: state.msgs_in,
        producer_msgs_out: state.msgs_out,
        node_stats,
        level_fill,
        depth,
        fanout,
        reshapes,
    }
}

/// A running buffer tree: the senders wiring it together plus the join
/// handles of every node and consumer thread. Produced by [`spawn_tree`];
/// consumed by [`SpawnedTree::join`] at teardown.
///
/// The local producer loop ([`run_scheduler`]) and the remote-worker
/// gateway ([`super::net`]) both sit on top of this: the only difference
/// is what the roots' [`ParentLink`] points at.
pub(crate) struct SpawnedTree {
    /// Senders to the roots, indexed by root slot.
    pub(crate) root_txs: Vec<Sender<ToBuffer>>,
    /// Per-node counter snapshots (written by node threads on a flush
    /// cadence when `live_stats`, and always at stop).
    pub(crate) stats: SharedStats,
    node_txs: Vec<Sender<ToBuffer>>,
    node_handles: Vec<thread::JoinHandle<()>>,
    consumer_handles: Vec<thread::JoinHandle<()>>,
}

impl SpawnedTree {
    /// Drop every sender into the tree and join all of its threads.
    /// Callers must have delivered (or implied, by disconnect) a shutdown
    /// first; joining an active tree would block until its channels hang
    /// up.
    pub(crate) fn join(self) {
        drop(self.root_txs);
        drop(self.node_txs);
        for h in self.node_handles {
            let _ = h.join();
        }
        for h in self.consumer_handles {
            let _ = h.join();
        }
    }
}

/// Build the channel fabric for `topo` and spawn one thread per buffer
/// node and per consumer. Root nodes report upstream to a clone of
/// `root_parent` — the producer channel in-process, or the socket gateway
/// in a remote worker.
pub(crate) fn spawn_tree(
    topo: &TreeTopology,
    cfg: &SchedulerConfig,
    executor: &Arc<dyn Executor>,
    root_parent: &ParentLink,
    t0: Instant,
    clock_scale: f64,
    live_stats: bool,
) -> SpawnedTree {
    let n_nodes = topo.n_nodes();
    let flush_interval = Duration::from_millis(cfg.flush_interval_ms);

    // One channel per tree node, created up front so siblings/children
    // can be wired regardless of spawn order.
    let mut node_txs: Vec<Sender<ToBuffer>> = Vec::with_capacity(n_nodes);
    let mut node_rxs: Vec<Option<Receiver<ToBuffer>>> = Vec::with_capacity(n_nodes);
    for _ in 0..n_nodes {
        let (tx, rx) = channel::<ToBuffer>();
        node_txs.push(tx);
        node_rxs.push(Some(rx));
    }

    let stats: SharedStats = Arc::new(Mutex::new(vec![None; n_nodes]));
    let mut node_handles = Vec::new();
    let mut consumer_handles = Vec::new();

    for id in 0..n_nodes {
        let state = BufferState::for_tree_node(topo, id, cfg);
        let level = topo.nodes[id].level;
        let slot = topo.nodes[id].slot;
        let rx = node_rxs[id].take().expect("receiver taken once");
        let parent = match topo.nodes[id].parent {
            None => root_parent.clone(),
            Some(p) => ParentLink::Buffer(node_txs[p].clone()),
        };
        let siblings: Vec<Sender<ToBuffer>> =
            topo.sibling_group(id).iter().map(|&s| node_txs[s].clone()).collect();
        // Kill switch shared by this leaf and its consumers (unused but
        // harmless at interior nodes).
        let cancel = Arc::new(CancelSet::new());
        let children = match &topo.nodes[id].kind {
            TreeNodeKind::Leaf { n_consumers, rank_base } => {
                let mut cons_txs = Vec::with_capacity(*n_consumers);
                for local in 0..*n_consumers {
                    let (ctx, crx) = channel::<ToConsumer>();
                    cons_txs.push(ctx);
                    let rank = rank_base + local;
                    let exec = Arc::clone(executor);
                    let back = node_txs[id].clone();
                    let cancel = Arc::clone(&cancel);
                    let handle = thread::Builder::new()
                        .name(format!("consumer-{rank}"))
                        .stack_size(256 * 1024)
                        .spawn(move || consumer_loop(crx, back, exec, rank, local, t0, cancel))
                        .expect("spawn consumer");
                    consumer_handles.push(handle);
                }
                ChildLink::Consumers(cons_txs)
            }
            TreeNodeKind::Interior { children } => {
                ChildLink::Buffers(children.iter().map(|&c| node_txs[c].clone()).collect())
            }
        };
        let stats = Arc::clone(&stats);
        let handle = thread::Builder::new()
            .name(format!("buffer-{id}"))
            .stack_size(256 * 1024)
            .spawn(move || {
                node_loop(
                    state,
                    rx,
                    parent,
                    slot,
                    siblings,
                    children,
                    cancel,
                    flush_interval,
                    t0,
                    clock_scale,
                    stats,
                    id,
                    level,
                    live_stats,
                )
            })
            .expect("spawn buffer node");
        node_handles.push(handle);
    }

    // Senders to the tree's direct upstream clients, indexed by root slot.
    let root_txs: Vec<Sender<ToBuffer>> = topo.roots.iter().map(|&r| node_txs[r].clone()).collect();
    SpawnedTree { root_txs, stats, node_txs, node_handles, consumer_handles }
}

/// How many staged tasks the threaded calibration phase executes inline
/// to measure real durations, and how many channel echoes time the
/// message round trip. Both are kept small: calibration must stay "short"
/// even for minute-scale simulators.
const CAL_TASKS: usize = 2;
const CAL_PROBE_ROUNDS: u32 = 64;

/// The threaded side of the [`TreeShape::Auto`] calibration phase.
///
/// * **Producer round trip** — timed over [`CAL_PROBE_ROUNDS`] echoes
///   through a real channel pair to a peer thread: the same hop a root
///   node's request/grant takes, minus the protocol work.
/// * **Mean task duration** — up to [`CAL_TASKS`] of the engine's staged
///   tasks are executed as probes, **concurrently** on their own threads,
///   so the calibration stall is one task duration, not [`CAL_TASKS`].
///   These are *real* completions: their results feed the engine and the
///   final report exactly as scheduled executions would. A failed attempt
///   with retry budget left is re-staged (attempt bumped) for the
///   scheduler to retry transparently, so job semantics are preserved;
///   only *successful* attempts contribute duration samples — a
///   crash-fast simulator must not convince the controller that tasks are
///   millisecond-scale.
///
/// Both measurements are converted to virtual seconds (`÷ time_scale`),
/// the unit the shared controller — and the DES — work in, so identical
/// calibration inputs yield identical shapes on both runtimes.
#[allow(clippy::too_many_arguments)]
fn calibrate_threaded(
    np: usize,
    sink: &mut ProducerSink,
    engine: &mut dyn SearchEngine,
    executor: &Arc<dyn Executor>,
    t0: Instant,
    clock_scale: f64,
    filling: &mut FillingRate,
    all_results: &mut Vec<TaskResult>,
) -> Calibration {
    // Round-trip probe: echo thread + channel pair.
    let (req_tx, req_rx) = channel::<u32>();
    let (rep_tx, rep_rx) = channel::<u32>();
    let echo = thread::Builder::new()
        .name("calibration-echo".into())
        .stack_size(64 * 1024)
        .spawn(move || {
            while let Ok(x) = req_rx.recv() {
                if rep_tx.send(x).is_err() {
                    break;
                }
            }
        })
        .expect("spawn calibration echo");
    let probe_t0 = Instant::now();
    let mut rounds = 0u32;
    for i in 0..CAL_PROBE_ROUNDS {
        if req_tx.send(i).is_ok() && rep_rx.recv().is_ok() {
            rounds += 1;
        }
    }
    let rtt_wall = probe_t0.elapsed().as_secs_f64() / rounds.max(1) as f64;
    drop(req_tx);
    let _ = echo.join();

    // Duration probe: run the first staged tasks concurrently on probe
    // threads — skipping any task the engine already cancelled during
    // `start()`, so a cancel issued before scheduling is honoured exactly
    // as in Manual mode (the cancelled task stays staged and is dropped
    // by the normal producer cancel path).
    let cancelled: HashSet<TaskId> = sink.cancels.iter().copied().collect();
    let mut sample: Vec<f64> = Vec::new();
    let mut probes: Vec<TaskSpec> = Vec::new();
    let mut i = 0;
    // One distinct consumer rank per concurrent probe (a consumer runs one
    // task at a time — the overlap invariant holds for probes too).
    let n_probes = CAL_TASKS.min(np.max(1));
    while probes.len() < n_probes && i < sink.staged.len() {
        if cancelled.contains(&sink.staged[i].id) {
            i += 1;
        } else {
            probes.push(sink.staged.remove(i));
        }
    }
    let handles: Vec<_> = probes
        .into_iter()
        .enumerate()
        .map(|(rank, task)| {
            let exec = Arc::clone(executor);
            thread::Builder::new()
                .name("calibration-probe".into())
                .spawn(move || {
                    let begin = t0.elapsed().as_secs_f64();
                    let out = exec.run_cancellable(&task, rank, &CancelSet::new());
                    let finish = t0.elapsed().as_secs_f64();
                    (rank, task, out, begin, finish)
                })
                .expect("spawn calibration probe")
        })
        .collect();
    for handle in handles {
        let (rank, task, out, begin, finish) =
            handle.join().expect("calibration probe panicked");
        if out.rc != 0 && task.attempt < task.max_retries {
            let mut spec = task;
            spec.attempt += 1;
            sink.staged.insert(0, spec);
            continue;
        }
        if out.rc == 0 {
            sample.push((finish - begin) * clock_scale);
        }
        let result = TaskResult {
            id: task.id,
            consumer: rank,
            results: out.results,
            begin,
            finish,
            rc: out.rc,
            attempt: task.attempt,
            timed_out: out.timed_out,
        };
        if !result.cancelled() {
            filling.record(&result);
        }
        engine.on_done(&result, sink);
        all_results.push(result);
    }
    let mean_task_s = if sample.is_empty() {
        Calibration::fallback().mean_task_s
    } else {
        sample.iter().sum::<f64>() / sample.len() as f64
    };
    let cal =
        Calibration { producer_rtt: (rtt_wall * clock_scale).max(1e-9), mean_task_s };
    crate::debugln!(
        "calibration: rtt={:.3e}s mean_task={:.3}s (virtual)",
        cal.producer_rtt,
        cal.mean_task_s
    );
    cal
}

/// Flush everything the engine staged — submissions *and* cancellations —
/// into the producer state machine. A cancellation that drops a
/// still-pending task synthesizes its `RC_CANCELLED` result here and hands
/// it straight back to the engine, which may stage more work, so the loop
/// runs until the sink is drained.
fn drain_engine(
    state: &mut ProducerState,
    sink: &mut ProducerSink,
    engine: &mut dyn SearchEngine,
    root_txs: &[Sender<ToBuffer>],
    all_results: &mut Vec<TaskResult>,
) {
    while !sink.staged.is_empty() || !sink.cancels.is_empty() {
        let acts = state.push_tasks(std::mem::take(&mut sink.staged));
        perform_producer(acts, root_txs);
        for id in std::mem::take(&mut sink.cancels) {
            let (dropped, acts) = state.on_cancel(id);
            perform_producer(acts, root_txs);
            if let Some(spec) = dropped {
                let r = TaskResult::cancelled_for(&spec);
                engine.on_done(&r, sink);
                all_results.push(r);
            }
        }
    }
}

/// Execute producer actions; returns true when shutdown was broadcast.
fn perform_producer(actions: Vec<ProducerAction>, root_txs: &[Sender<ToBuffer>]) -> bool {
    let mut shutdown = false;
    for act in actions {
        match act {
            ProducerAction::SendTasks { buffer, tasks } => {
                let _ = root_txs[buffer].send(ToBuffer::Assign(tasks));
            }
            ProducerAction::BroadcastCancel { id } => {
                for tx in root_txs {
                    let _ = tx.send(ToBuffer::Cancel { id });
                }
            }
            ProducerAction::BroadcastRecall => {
                for tx in root_txs {
                    let _ = tx.send(ToBuffer::Recall);
                }
            }
            ProducerAction::BroadcastShutdown => {
                for tx in root_txs {
                    let _ = tx.send(ToBuffer::Shutdown);
                }
                shutdown = true;
            }
        }
    }
    shutdown
}

/// Route one batch of protocol actions out of a node. Returns true when the
/// node initiated its own stop (shutdown forwarded / consumers stopped).
fn perform_node_actions(
    acts: Vec<BufferAction>,
    parent: &ParentLink,
    slot: usize,
    siblings: &[Sender<ToBuffer>],
    children: &ChildLink,
    cancel: &CancelSet,
) -> bool {
    let mut stopping = false;
    for act in acts {
        match act {
            BufferAction::RunBatch { consumer, tasks } => {
                if let ChildLink::Consumers(cons) = children {
                    let _ = cons[consumer].send(ToConsumer::RunBatch(tasks));
                }
            }
            BufferAction::SendToChild { child, tasks } => {
                if let ChildLink::Buffers(bufs) = children {
                    let _ = bufs[child].send(ToBuffer::Assign(tasks));
                }
            }
            BufferAction::RequestTasks { amount } => match parent {
                ParentLink::Producer(tx) => {
                    let _ = tx.send(ToProducer::Request { buffer: slot, amount });
                }
                ParentLink::Buffer(tx) => {
                    let _ = tx.send(ToBuffer::ChildRequest { child: slot, amount });
                }
            },
            BufferAction::FlushResults(rs) => {
                if !rs.is_empty() {
                    match parent {
                        ParentLink::Producer(tx) => {
                            let _ = tx.send(ToProducer::Results(rs));
                        }
                        ParentLink::Buffer(tx) => {
                            let _ = tx.send(ToBuffer::ChildResults(rs));
                        }
                    }
                }
            }
            BufferAction::Flush { amount, results } => match parent {
                ParentLink::Producer(tx) => {
                    let _ = tx.send(ToProducer::Flush { buffer: slot, amount, results });
                }
                ParentLink::Buffer(tx) => {
                    let _ = tx.send(ToBuffer::ChildFlush { child: slot, amount, results });
                }
            },
            BufferAction::StealRequest { victim, amount } => {
                let _ = siblings[victim].send(ToBuffer::Steal { thief: slot, amount });
            }
            BufferAction::StealGrant { thief, from_slot, left, cancels, tasks } => {
                let _ = siblings[thief].send(ToBuffer::Stolen { from_slot, left, cancels, tasks });
            }
            BufferAction::CancelRunning { consumer: _, id } => {
                // The set is shared by every consumer of this leaf, so the
                // id alone identifies the attempt to kill; the executor
                // notices at its next cancellation poll.
                cancel.request(id);
            }
            BufferAction::CancelChildren { id } => {
                if let ChildLink::Buffers(bufs) = children {
                    for c in bufs {
                        let _ = c.send(ToBuffer::Cancel { id });
                    }
                }
            }
            BufferAction::ShutdownConsumers => {
                if let ChildLink::Consumers(cons) = children {
                    for c in cons {
                        let _ = c.send(ToConsumer::Stop);
                    }
                }
                stopping = true;
            }
            BufferAction::ShutdownChildren => {
                if let ChildLink::Buffers(bufs) = children {
                    for c in bufs {
                        let _ = c.send(ToBuffer::Shutdown);
                    }
                }
                stopping = true;
            }
            BufferAction::ReturnTasks(tasks) => match parent {
                ParentLink::Producer(tx) => {
                    let _ = tx.send(ToProducer::Returned(tasks));
                }
                ParentLink::Buffer(tx) => {
                    let _ = tx.send(ToBuffer::ChildReturned(tasks));
                }
            },
            BufferAction::RecallChildren => {
                if let ChildLink::Buffers(bufs) = children {
                    for c in bufs {
                        let _ = c.send(ToBuffer::Recall);
                    }
                }
            }
            BufferAction::AckRecall => match parent {
                ParentLink::Producer(tx) => {
                    let _ = tx.send(ToProducer::RecallAck { buffer: slot });
                }
                ParentLink::Buffer(tx) => {
                    let _ = tx.send(ToBuffer::ChildRecallAck { child: slot });
                }
            },
        }
    }
    stopping
}

#[allow(clippy::too_many_arguments)]
fn node_loop(
    mut state: BufferState,
    rx: Receiver<ToBuffer>,
    parent: ParentLink,
    slot: usize,
    siblings: Vec<Sender<ToBuffer>>,
    children: ChildLink,
    cancel: Arc<CancelSet>,
    flush_interval: Duration,
    t0: Instant,
    clock_scale: f64,
    stats: SharedStats,
    id: usize,
    level: usize,
    live_stats: bool,
) {
    let mut stopping = false;
    state.set_now(t0.elapsed().as_secs_f64() * clock_scale);
    let acts = state.on_start();
    stopping |= perform_node_actions(acts, &parent, slot, &siblings, &children, &cancel);
    // Live counter publishing for the reshape controller. Published on a
    // wall-clock cadence *regardless of traffic* — a saturated node never
    // hits the idle tick, and saturation is exactly the regime whose
    // request→grant lag the controller must see.
    let mut last_publish = Instant::now();
    while !stopping {
        let msg = rx.recv_timeout(flush_interval);
        state.set_now(t0.elapsed().as_secs_f64() * clock_scale);
        if live_stats && last_publish.elapsed() >= flush_interval {
            stats.lock().unwrap()[id] = Some(state.stats(id, level));
            last_publish = Instant::now();
        }
        let acts = match msg {
            Ok(ToBuffer::Assign(tasks)) => state.on_assign(tasks),
            Ok(ToBuffer::DoneBatch { consumer, results }) => {
                // Retire any kill marks that lost the race to these
                // completions — the consumer-side clear can run *before*
                // the mark is even set, which would leak it forever.
                for r in &results {
                    cancel.clear(r.id);
                }
                state.on_done_batch(consumer, results)
            }
            Ok(ToBuffer::ChildRequest { child, amount }) => state.on_child_request(child, amount),
            Ok(ToBuffer::ChildResults(rs)) => state.on_child_results(rs),
            Ok(ToBuffer::ChildFlush { child, amount, results }) => {
                state.on_child_flush(child, amount, results)
            }
            // In the threaded runtime the routing token IS the slot.
            Ok(ToBuffer::Steal { thief, amount }) => state.on_steal_request(thief, thief, amount),
            Ok(ToBuffer::Stolen { from_slot, left, cancels, tasks }) => {
                state.on_steal_grant(from_slot, left, cancels, tasks)
            }
            Ok(ToBuffer::Cancel { id }) => state.on_cancel(id),
            Ok(ToBuffer::Recall) => state.on_recall(),
            Ok(ToBuffer::ChildReturned(tasks)) => state.on_child_returned(tasks),
            Ok(ToBuffer::ChildRecallAck { child }) => state.on_child_recall_ack(child),
            Ok(ToBuffer::Shutdown) => state.on_shutdown(),
            Err(RecvTimeoutError::Timeout) => state.on_tick(),
            Err(RecvTimeoutError::Disconnected) => break,
        };
        stopping |= perform_node_actions(acts, &parent, slot, &siblings, &children, &cancel);
    }
    stats.lock().unwrap()[id] = Some(state.stats(id, level));
}

#[allow(clippy::too_many_arguments)]
fn consumer_loop(
    rx: Receiver<ToConsumer>,
    back: Sender<ToBuffer>,
    exec: Arc<dyn Executor>,
    rank: usize,
    local: usize,
    t0: Instant,
    cancel: Arc<CancelSet>,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            ToConsumer::RunBatch(tasks) => {
                let mut results = Vec::with_capacity(tasks.len());
                for task in tasks {
                    let begin = t0.elapsed().as_secs_f64();
                    // A kill mark landing between dispatch and execution
                    // aborts the queued attempt before it starts — the
                    // batched equivalent of killing a running task.
                    let out = if cancel.is_cancelled(task.id) {
                        ExecOutcome { results: Vec::new(), rc: RC_CANCELLED, timed_out: false }
                    } else {
                        exec.run_cancellable(&task, rank, &cancel)
                    };
                    // Retire any kill mark: it either fired (rc is
                    // RC_CANCELLED) or lost the race to completion.
                    cancel.clear(task.id);
                    let finish = t0.elapsed().as_secs_f64();
                    results.push(TaskResult {
                        id: task.id,
                        consumer: rank,
                        results: out.results,
                        begin,
                        finish,
                        rc: out.rc,
                        attempt: task.attempt,
                        timed_out: out.timed_out,
                    });
                }
                if back.send(ToBuffer::DoneBatch { consumer: local, results }).is_err() {
                    break;
                }
            }
            ToConsumer::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::VecSink;

    /// Engine that submits `n` sleep tasks up front.
    struct StaticSleeps {
        n: usize,
        secs: f64,
    }

    impl SearchEngine for StaticSleeps {
        fn start(&mut self, sink: &mut dyn JobSink) {
            for _ in 0..self.n {
                sink.submit(Payload::Sleep { seconds: self.secs });
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn JobSink) {}
    }

    /// Engine that chains: each completion spawns one follow-up until a
    /// total budget is exhausted (the TC3 pattern).
    struct Chaining {
        initial: usize,
        total: usize,
        created: usize,
    }

    impl SearchEngine for Chaining {
        fn start(&mut self, sink: &mut dyn JobSink) {
            for _ in 0..self.initial {
                sink.submit(Payload::Sleep { seconds: 0.5 });
                self.created += 1;
            }
        }
        fn on_done(&mut self, _r: &TaskResult, sink: &mut dyn JobSink) {
            if self.created < self.total {
                sink.submit(Payload::Sleep { seconds: 0.5 });
                self.created += 1;
            }
        }
    }

    fn quick_cfg(np: usize) -> SchedulerConfig {
        SchedulerConfig {
            np,
            consumers_per_buffer: 4,
            time_scale: 0.001, // 1 virtual s = 1 ms real
            flush_interval_ms: 5,
            ..SchedulerConfig::default()
        }
    }

    #[test]
    fn static_workload_runs_all_tasks() {
        let report = run_scheduler(
            &quick_cfg(8),
            Box::new(StaticSleeps { n: 40, secs: 1.0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert_eq!(report.results.len(), 40);
        assert_eq!(report.filling.overlap_violations(), 0);
        // All ids distinct.
        let mut ids: Vec<u64> = report.results.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 40);
    }

    #[test]
    fn empty_engine_terminates() {
        let report = run_scheduler(
            &quick_cfg(4),
            Box::new(StaticSleeps { n: 0, secs: 0.0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert!(report.results.is_empty());
    }

    #[test]
    fn dynamic_chaining_completes_budget() {
        let report = run_scheduler(
            &quick_cfg(4),
            Box::new(Chaining { initial: 4, total: 20, created: 0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert_eq!(report.results.len(), 20);
    }

    #[test]
    fn single_consumer_is_serial() {
        let report = run_scheduler(
            &quick_cfg(1),
            Box::new(StaticSleeps { n: 5, secs: 1.0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert_eq!(report.results.len(), 5);
        assert_eq!(report.filling.overlap_violations(), 0);
    }

    #[test]
    fn depth2_tree_runs_all_tasks_through_relays() {
        let mut cfg = quick_cfg(8); // 2 leaves of 4 consumers
        cfg.depth = 2;
        cfg.fanout = vec![2]; // one relay over the two leaves
        let report = run_scheduler(
            &cfg,
            Box::new(StaticSleeps { n: 60, secs: 1.0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert_eq!(report.results.len(), 60);
        assert_eq!(report.filling.overlap_violations(), 0);
        // 2 leaves + 1 relay, all saw the shutdown.
        assert_eq!(report.node_stats.len(), 3);
        assert!(report.node_stats.iter().all(|s| s.saw_shutdown));
        assert!(report.node_stats.iter().all(|s| s.max_queue <= s.credit_bound));
    }

    #[test]
    fn depth3_tree_with_stealing_conserves_tasks() {
        let mut cfg = quick_cfg(8); // 2 leaves of 4
        cfg.depth = 3;
        cfg.fanout = vec![2];
        cfg.steal = true;
        let report = run_scheduler(
            &cfg,
            Box::new(Chaining { initial: 8, total: 40, created: 0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert_eq!(report.results.len(), 40);
        let mut ids: Vec<u64> = report.results.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 40, "no duplicates under stealing");
        assert!(report.node_stats.iter().all(|s| s.saw_shutdown));
        assert!(report.node_stats.iter().all(|s| s.max_queue <= s.credit_bound));
    }

    #[test]
    fn engine_sink_ids_match_results() {
        let mut sink = VecSink::new();
        let mut e = StaticSleeps { n: 3, secs: 0.0 };
        e.start(&mut sink);
        assert_eq!(sink.submitted.iter().map(|t| t.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }
}
