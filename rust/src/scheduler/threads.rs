//! The threaded scheduler runtime.
//!
//! Executes the protocol of [`super::protocol`] with real OS threads and
//! channels: one producer thread (≈ MPI rank 0), one thread per buffer
//! process, one thread per consumer process. The search engine runs inside
//! the producer thread, exactly as CARAVAN runs the Python search engine
//! attached to rank 0; consumers execute task payloads through a
//! user-supplied [`Executor`].
//!
//! On a small host this is concurrency rather than parallelism, which is
//! fine for the framework's own behaviour (dummy `Sleep` tasks idle, and
//! in-process evaluations are serialized by the PJRT executor anyway).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use super::metrics::FillingRate;
use super::protocol::{BufferAction, BufferState, ProducerAction, ProducerState};
use crate::config::SchedulerConfig;
use crate::tasklib::{Payload, SearchEngine, TaskResult, TaskSink, TaskSpec};

/// Runs task payloads on a consumer thread.
pub trait Executor: Send + Sync {
    /// Execute the payload; return (result vector, return code).
    fn run(&self, task: &TaskSpec, consumer: usize) -> (Vec<f64>, i32);
}

/// Executor for dummy [`Payload::Sleep`] tasks with time compression:
/// a virtual second lasts `time_scale` real seconds.
pub struct SleepExecutor {
    pub time_scale: f64,
}

impl Executor for SleepExecutor {
    fn run(&self, task: &TaskSpec, _consumer: usize) -> (Vec<f64>, i32) {
        match &task.payload {
            Payload::Sleep { seconds } => {
                let real = seconds * self.time_scale;
                if real > 0.0 {
                    thread::sleep(Duration::from_secs_f64(real));
                }
                (vec![*seconds], 0)
            }
            other => panic!("SleepExecutor got {other:?}"),
        }
    }
}

enum ToProducer {
    Request { buffer: usize, amount: usize },
    Results(Vec<TaskResult>),
}

enum ToBuffer {
    Assign(Vec<TaskSpec>),
    Done { consumer: usize, result: TaskResult },
    Shutdown,
}

enum ToConsumer {
    Run(TaskSpec),
    Stop,
}

/// Outcome of a scheduler run.
pub struct Report {
    pub results: Vec<TaskResult>,
    pub filling: FillingRate,
    pub wall_secs: f64,
    pub producer_msgs_in: u64,
    pub producer_msgs_out: u64,
}

impl Report {
    pub fn rate(&self, np: usize) -> f64 {
        self.filling.rate(np)
    }
}

/// Sink handing engine submissions to the producer state machine.
struct ProducerSink {
    next_id: u64,
    staged: Vec<TaskSpec>,
}

impl TaskSink for ProducerSink {
    fn submit(&mut self, payload: Payload) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.staged.push(TaskSpec::new(id, payload));
        id
    }
}

/// Run `engine`'s workload on the hierarchical scheduler.
///
/// Blocks until every task (including dynamically created ones) completed,
/// then returns the full result set and the schedule metrics.
pub fn run_scheduler(
    cfg: &SchedulerConfig,
    mut engine: Box<dyn SearchEngine>,
    executor: Arc<dyn Executor>,
) -> Report {
    let np = cfg.np;
    let layout = cfg.buffer_layout();
    let nb = layout.len();
    crate::debugln!("scheduler: np={} buffers={} layout={:?}", np, nb, layout);

    let t0 = Instant::now();

    // Channels.
    let (prod_tx, prod_rx) = channel::<ToProducer>();
    let mut buf_txs: Vec<Sender<ToBuffer>> = Vec::with_capacity(nb);
    let mut buf_handles = Vec::new();
    let mut consumer_handles = Vec::new();

    let mut global_consumer = 0usize;
    for (b, &nc) in layout.iter().enumerate() {
        let (btx, brx) = channel::<ToBuffer>();
        buf_txs.push(btx.clone());

        // Spawn this buffer's consumers.
        let mut cons_txs: Vec<Sender<ToConsumer>> = Vec::with_capacity(nc);
        for local in 0..nc {
            let (ctx, crx) = channel::<ToConsumer>();
            cons_txs.push(ctx);
            let rank = global_consumer;
            global_consumer += 1;
            let exec = Arc::clone(&executor);
            let back = btx.clone();
            let handle = thread::Builder::new()
                .name(format!("consumer-{rank}"))
                .stack_size(256 * 1024)
                .spawn(move || consumer_loop(crx, back, exec, rank, local, t0))
                .expect("spawn consumer");
            consumer_handles.push(handle);
        }

        let ptx = prod_tx.clone();
        let flush_interval = Duration::from_millis(cfg.flush_interval_ms);
        let (credit, flush_every) = (cfg.credit_factor, cfg.flush_every);
        let handle = thread::Builder::new()
            .name(format!("buffer-{b}"))
            .stack_size(256 * 1024)
            .spawn(move || buffer_loop(b, nc, credit, flush_every, brx, ptx, cons_txs, flush_interval))
            .expect("spawn buffer");
        buf_handles.push(handle);
    }
    drop(prod_tx);

    // --- producer loop (runs on the caller thread) ---
    let mut state = ProducerState::new(nb);
    let mut sink = ProducerSink { next_id: 0, staged: Vec::new() };
    let mut filling = FillingRate::new();
    let mut all_results: Vec<TaskResult> = Vec::new();

    engine.start(&mut sink);
    let acts = state_push(&mut state, &mut sink);
    perform_producer(acts, &buf_txs);
    let done = engine.poll(&mut sink);
    let acts = state_push(&mut state, &mut sink);
    perform_producer(acts, &buf_txs);
    state.set_engine_done(done);

    let poll_interval = Duration::from_millis(cfg.flush_interval_ms.max(1));
    loop {
        // Shutdown check (engine may have submitted nothing at all).
        let shutdown_acts = state.maybe_shutdown();
        if perform_producer(shutdown_acts, &buf_txs) {
            break;
        }
        let msg = match prod_rx.recv_timeout(poll_interval) {
            Ok(m) => m,
            Err(RecvTimeoutError::Timeout) => {
                // Give session-style engines a chance to inject work.
                let done = engine.poll(&mut sink);
                let acts = state_push(&mut state, &mut sink);
                perform_producer(acts, &buf_txs);
                state.set_engine_done(done);
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        };
        match msg {
            ToProducer::Request { buffer, amount } => {
                let acts = state.on_request(buffer, amount);
                perform_producer(acts, &buf_txs);
            }
            ToProducer::Results(results) => {
                state.on_results(results.len());
                for r in &results {
                    filling.record(r);
                    engine.on_done(r, &mut sink);
                }
                all_results.extend(results);
                let acts = state_push(&mut state, &mut sink);
                perform_producer(acts, &buf_txs);
            }
        }
    }
    engine.finish();

    // Join everything.
    drop(buf_txs);
    for h in buf_handles {
        let _ = h.join();
    }
    for h in consumer_handles {
        let _ = h.join();
    }

    Report {
        results: all_results,
        filling,
        wall_secs: t0.elapsed().as_secs_f64(),
        producer_msgs_in: state.msgs_in,
        producer_msgs_out: state.msgs_out,
    }
}

/// Push whatever the engine staged into the producer state machine.
fn state_push(state: &mut ProducerState, sink: &mut ProducerSink) -> Vec<ProducerAction> {
    if sink.staged.is_empty() {
        Vec::new()
    } else {
        state.push_tasks(std::mem::take(&mut sink.staged))
    }
}

/// Execute producer actions; returns true when shutdown was broadcast.
fn perform_producer(actions: Vec<ProducerAction>, buf_txs: &[Sender<ToBuffer>]) -> bool {
    let mut shutdown = false;
    for act in actions {
        match act {
            ProducerAction::SendTasks { buffer, tasks } => {
                let _ = buf_txs[buffer].send(ToBuffer::Assign(tasks));
            }
            ProducerAction::BroadcastShutdown => {
                for tx in buf_txs {
                    let _ = tx.send(ToBuffer::Shutdown);
                }
                shutdown = true;
            }
        }
    }
    shutdown
}

fn buffer_loop(
    buffer_id: usize,
    n_consumers: usize,
    credit_factor: usize,
    flush_every: usize,
    rx: Receiver<ToBuffer>,
    producer: Sender<ToProducer>,
    consumers: Vec<Sender<ToConsumer>>,
    flush_interval: Duration,
) {
    let mut state = BufferState::new(n_consumers, credit_factor, flush_every);
    let mut stopping = false;
    let perform = |state: &mut BufferState,
                   acts: Vec<BufferAction>,
                   stopping: &mut bool| {
        for act in acts {
            match act {
                BufferAction::RunOn { consumer, task } => {
                    let _ = consumers[consumer].send(ToConsumer::Run(task));
                }
                BufferAction::RequestTasks { amount } => {
                    let _ = producer.send(ToProducer::Request { buffer: buffer_id, amount });
                }
                BufferAction::FlushResults(rs) => {
                    if !rs.is_empty() {
                        let _ = producer.send(ToProducer::Results(rs));
                    }
                }
                BufferAction::ShutdownConsumers => {
                    for c in &consumers {
                        let _ = c.send(ToConsumer::Stop);
                    }
                    *stopping = true;
                }
            }
        }
        let _ = state;
    };

    let acts = state.on_start();
    perform(&mut state, acts, &mut stopping);
    while !stopping {
        match rx.recv_timeout(flush_interval) {
            Ok(ToBuffer::Assign(tasks)) => {
                let acts = state.on_assign(tasks);
                perform(&mut state, acts, &mut stopping);
            }
            Ok(ToBuffer::Done { consumer, result }) => {
                let acts = state.on_done(consumer, result);
                perform(&mut state, acts, &mut stopping);
            }
            Ok(ToBuffer::Shutdown) => {
                let acts = state.on_shutdown();
                perform(&mut state, acts, &mut stopping);
            }
            Err(RecvTimeoutError::Timeout) => {
                let acts = state.on_tick();
                perform(&mut state, acts, &mut stopping);
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
}

fn consumer_loop(
    rx: Receiver<ToConsumer>,
    back: Sender<ToBuffer>,
    exec: Arc<dyn Executor>,
    rank: usize,
    local: usize,
    t0: Instant,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            ToConsumer::Run(task) => {
                let begin = t0.elapsed().as_secs_f64();
                let (results, rc) = exec.run(&task, rank);
                let finish = t0.elapsed().as_secs_f64();
                let result = TaskResult { id: task.id, consumer: rank, results, begin, finish, rc };
                if back.send(ToBuffer::Done { consumer: local, result }).is_err() {
                    break;
                }
            }
            ToConsumer::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::VecSink;

    /// Engine that submits `n` sleep tasks up front.
    struct StaticSleeps {
        n: usize,
        secs: f64,
    }

    impl SearchEngine for StaticSleeps {
        fn start(&mut self, sink: &mut dyn TaskSink) {
            for _ in 0..self.n {
                sink.submit(Payload::Sleep { seconds: self.secs });
            }
        }
        fn on_done(&mut self, _r: &TaskResult, _s: &mut dyn TaskSink) {}
    }

    /// Engine that chains: each completion spawns one follow-up until a
    /// total budget is exhausted (the TC3 pattern).
    struct Chaining {
        initial: usize,
        total: usize,
        created: usize,
    }

    impl SearchEngine for Chaining {
        fn start(&mut self, sink: &mut dyn TaskSink) {
            for _ in 0..self.initial {
                sink.submit(Payload::Sleep { seconds: 0.5 });
                self.created += 1;
            }
        }
        fn on_done(&mut self, _r: &TaskResult, sink: &mut dyn TaskSink) {
            if self.created < self.total {
                sink.submit(Payload::Sleep { seconds: 0.5 });
                self.created += 1;
            }
        }
    }

    fn quick_cfg(np: usize) -> SchedulerConfig {
        SchedulerConfig {
            np,
            consumers_per_buffer: 4,
            time_scale: 0.001, // 1 virtual s = 1 ms real
            flush_interval_ms: 5,
            ..SchedulerConfig::default()
        }
    }

    #[test]
    fn static_workload_runs_all_tasks() {
        let report = run_scheduler(
            &quick_cfg(8),
            Box::new(StaticSleeps { n: 40, secs: 1.0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert_eq!(report.results.len(), 40);
        assert_eq!(report.filling.overlap_violations(), 0);
        // All ids distinct.
        let mut ids: Vec<u64> = report.results.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 40);
    }

    #[test]
    fn empty_engine_terminates() {
        let report = run_scheduler(
            &quick_cfg(4),
            Box::new(StaticSleeps { n: 0, secs: 0.0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert!(report.results.is_empty());
    }

    #[test]
    fn dynamic_chaining_completes_budget() {
        let report = run_scheduler(
            &quick_cfg(4),
            Box::new(Chaining { initial: 4, total: 20, created: 0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert_eq!(report.results.len(), 20);
    }

    #[test]
    fn single_consumer_is_serial() {
        let report = run_scheduler(
            &quick_cfg(1),
            Box::new(StaticSleeps { n: 5, secs: 1.0 }),
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        assert_eq!(report.results.len(), 5);
        assert_eq!(report.filling.overlap_violations(), 0);
    }

    #[test]
    fn engine_sink_ids_match_results() {
        let mut sink = VecSink::new();
        let mut e = StaticSleeps { n: 3, secs: 0.0 };
        e.start(&mut sink);
        assert_eq!(sink.submitted.iter().map(|t| t.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }
}
