//! Configuration for the scheduler, the DES latency model, and the
//! application scenarios. All defaults follow the paper where it states
//! them (e.g. one buffer per 384 consumers).

#![warn(missing_docs)]

/// How every queue in the scheduler (the producer's pending queue and
/// each buffer-tree node's local queue) orders its tasks. Implemented once
/// in [`crate::scheduler::protocol::PrioQueue`], so the threaded runtime
/// and the DES can never disagree on scheduling semantics.
///
/// ```
/// use caravan::config::SchedPolicy;
///
/// assert_eq!(SchedPolicy::parse("deadline"), Some(SchedPolicy::Deadline));
/// assert_eq!(SchedPolicy::parse("aging:2.5"), Some(SchedPolicy::Aging { step: 2.5 }));
/// assert_eq!(SchedPolicy::parse("bogus"), None);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SchedPolicy {
    /// Strict priority bands, FIFO within a band — the Job-API-v2
    /// behaviour. A sustained high-priority stream starves lower bands.
    Strict,
    /// Strict priority bands; within a band, least *slack* first. A
    /// task's effective deadline is `enqueue time + timeout_s` (tasks
    /// without a timeout sort last, FIFO among themselves), so urgent
    /// work runs before work that can afford to wait.
    Deadline,
    /// [`SchedPolicy::Deadline`] within a band, plus **priority aging**
    /// across bands: a band's effective priority rises by one level per
    /// `step` seconds its head task has been waiting. A priority-`p` task
    /// facing a sustained priority-`q` stream is popped after at most
    /// `(q_eff − p + 1) × step` seconds of queueing, where `q_eff` is the
    /// stream's own effective priority (`q` plus the boost of its backlog
    /// head) — bounded by the backlog, never by the stream's length (the
    /// bounded-wait property; see the README's starvation bound).
    Aging {
        /// Seconds of queue wait per effective-priority level gained.
        step: f64,
    },
}

impl SchedPolicy {
    /// Parse a CLI spelling: `strict`, `deadline`, `aging` (default
    /// 30 s/level) or `aging:SECONDS`.
    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s {
            "strict" => Some(SchedPolicy::Strict),
            "deadline" => Some(SchedPolicy::Deadline),
            "aging" => Some(SchedPolicy::Aging { step: 30.0 }),
            _ => {
                let step = s.strip_prefix("aging:")?.parse().ok()?;
                Some(SchedPolicy::Aging { step })
            }
        }
    }
}

/// Measured inputs to the adaptive tree-shaping controller
/// ([`crate::scheduler::protocol::choose_shape`]). All values are in
/// *virtual* seconds — the DES derives them exactly from its latency
/// model, the threaded runtime measures wall clock and divides by its
/// `time_scale` — so both runtimes feed the controller the same units and
/// the same inputs always yield the same shape.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Calibration {
    /// Producer request→grant round trip as seen by a direct child
    /// (two message hops plus the producer's service + queueing time).
    /// This is the signal that blows up when rank 0 saturates.
    pub producer_rtt: f64,
    /// Mean task duration. Together with the consumer count this gives
    /// the leaf drain rate the producer must keep up with.
    pub mean_task_s: f64,
}

impl Calibration {
    /// Fallbacks when a measurement is impossible (no tasks staged, probe
    /// failed): a fast producer and second-scale tasks — the regime where
    /// the paper's flat layout is known to work.
    pub fn fallback() -> Self {
        Self { producer_rtt: 1e-4, mean_task_s: 1.0 }
    }
}

/// When and how aggressively the scheduler re-shapes the buffer tree
/// *online* (CLI: `--reshape`). A shape chosen once at calibration goes
/// stale exactly when the workload gets interesting — e.g. an MOEA
/// shifting from cheap to expensive generations — so the protocol layer
/// periodically rebuilds a **rolling [`Calibration`]** from live
/// measurements (per-root request→grant lag, observed task durations),
/// re-runs the shape controller, and when the chosen shape diverges,
/// executes a drain-and-graft transition: credit is withdrawn, every
/// queued task is recalled to the producer with its `enqueued_t`
/// preserved, the tree is rebuilt at the new shape, and the recalled
/// tasks are re-granted — no task lost, duplicated, or re-ordered within
/// its scheduling band.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReshapePolicy {
    /// Width of the rolling measurement window in (virtual) seconds; the
    /// controller re-evaluates the shape once per window.
    pub window: f64,
    /// Minimum relative drift of a calibration input (producer round
    /// trip or mean task duration) against the calibration that chose
    /// the current shape before a transition may fire. `0.25` = 25 %.
    pub drift_threshold: f64,
    /// Minimum (virtual) seconds between two transitions, so a noisy
    /// boundary between regimes cannot thrash the tree.
    pub cooldown: f64,
}

impl Default for ReshapePolicy {
    fn default() -> Self {
        Self { window: 10.0, drift_threshold: 0.25, cooldown: 30.0 }
    }
}

/// How the buffer tree's depth and fanout are decided.
///
/// The controller behind the auto modes is one pure function shared by
/// both runtimes:
///
/// ```
/// use caravan::config::{Calibration, SchedulerConfig};
/// use caravan::scheduler::choose_shape;
///
/// let cfg = SchedulerConfig { np: 4096, consumers_per_buffer: 64, ..Default::default() };
/// // A fast producer keeps the paper's flat layout…
/// let (depth, fans) = choose_shape(&cfg, &Calibration { producer_rtt: 1e-4, mean_task_s: 5.0 });
/// assert_eq!((depth, fans.len()), (1, 0));
/// // …a lag-dominated one inserts relay levels (narrow at the root).
/// let (depth, _) = choose_shape(&cfg, &Calibration { producer_rtt: 5e-3, mean_task_s: 0.5 });
/// assert!(depth >= 2);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TreeShape {
    /// Use [`SchedulerConfig::depth`] / [`SchedulerConfig::fanout`] as
    /// given — the PR 1 knobs.
    Manual,
    /// Run a short calibration phase at startup (producer round-trip and
    /// mean task duration), then let the controller pick depth/fanout.
    /// The user never sets a shape knob.
    Auto,
    /// Auto with the measurement already supplied — what [`TreeShape::Auto`]
    /// becomes once its calibration phase resolves. Lets tests (and users
    /// with known environments) get deterministic auto-shaping without a
    /// measurement phase.
    Calibrated(Calibration),
}

impl TreeShape {
    /// True when the controller (not the manual knobs) decides the shape.
    pub fn is_auto(&self) -> bool {
        !matches!(self, TreeShape::Manual)
    }
}

/// How a starved buffer node picks the sibling to steal queued tasks from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StealPolicy {
    /// Blind rotation over sibling slots (the PR 1 behaviour).
    RoundRobin,
    /// Prefer the sibling with the deepest *known* queue. Depth estimates
    /// come from steal replies (each grant reports the victim's remaining
    /// queue) and from incoming steal requests (the thief is starved, so
    /// its depth is ~0); unknown siblings are treated as deepest, so the
    /// first attempts explore in rotation before exploiting.
    DeepestQueue,
}

/// Scheduler topology + flow-control parameters (threaded runtime and DES).
///
/// The buffered layer generalizes to an *N-level tree*: `depth = 1` is the
/// paper's fixed producer → buffer → consumer shape; `depth ≥ 2` inserts
/// interior relay levels between the producer and the leaf buffers (with
/// a per-level fan-out plan), so rank 0 talks to a handful of children
/// instead of to every buffer.
///
/// ```
/// use caravan::config::SchedulerConfig;
///
/// let cfg = SchedulerConfig {
///     np: 1000,
///     consumers_per_buffer: 384,
///     depth: 2,
///     fanout: vec![4, 8], // narrow at the root, wide near the leaves
///     ..Default::default()
/// };
/// assert_eq!(cfg.num_buffers(), 3);
/// assert_eq!(cfg.fanout_at(1), 4); // level 1 = the producer's children
/// assert_eq!(cfg.tree().depth, 2);
/// ```
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Number of consumer processes N_p.
    pub np: usize,
    /// Consumers per leaf buffer process. Paper default: 384.
    pub consumers_per_buffer: usize,
    /// Number of buffer levels between the producer and the consumers.
    /// 1 = the paper's two-party protocol (producer → buffers). Used when
    /// `shape` is [`TreeShape::Manual`]; under auto shaping the controller
    /// overrides it.
    pub depth: usize,
    /// **Per-level** children per interior buffer node, ordered from the
    /// root level downward: `fanout[0]` is the fan-in of the level-1
    /// nodes (the producer's direct children), the last element repeats
    /// for every deeper level. A single element is the uniform fanout of
    /// v4 and earlier (`fanout: 8` → `fanout: vec![8]`). Narrower values
    /// near the root keep fan-in small where request traffic
    /// concentrates; wider values near the leaves are cheap because
    /// results batch and leaf requests are low-rate. Under auto shaping
    /// the maximum element is the *upper bound* the controller may pick.
    pub fanout: Vec<usize>,
    /// How depth/fanout are decided: the manual knobs above, or the
    /// adaptive controller fed by a calibration measurement.
    pub shape: TreeShape,
    /// Online tree re-shaping under lag drift (`None` = the v4 behaviour:
    /// the shape picked at startup is final). See [`ReshapePolicy`].
    pub reshape: Option<ReshapePolicy>,
    /// Allow starved buffer nodes to steal queued tasks from a sibling
    /// before escalating demand to their parent.
    pub steal: bool,
    /// Victim-selection policy when `steal` is enabled.
    pub steal_policy: StealPolicy,
    /// Queue-ordering policy at every level (producer + buffer tree).
    /// With a non-empty [`Self::classes`] registry this is the *default*
    /// for unregistered class ids; registered classes bring their own.
    pub policy: SchedPolicy,
    /// Tenant-class registry: [`crate::tenancy::JobClass`] N here defines
    /// class id N (name, per-class [`SchedPolicy`], fair-share weight,
    /// admission quota). Empty (the default) = single-tenant behaviour:
    /// one implicit class using [`Self::policy`] with weight 1 and no
    /// quota. See [`crate::tenancy`].
    pub classes: Vec<crate::tenancy::JobClass>,
    /// A buffer keeps `credit_factor × subtree-consumers` tasks on hand.
    pub credit_factor: usize,
    /// Result-store batch size before a flush to the parent.
    pub flush_every: usize,
    /// Real seconds per virtual second for `Payload::Sleep` executors
    /// (time compression in tests/examples; 1.0 = real time).
    pub time_scale: f64,
    /// Buffer tick interval (threaded mode) for flushing stale results.
    pub flush_interval_ms: u64,
    /// Run-ahead dispatch depth: how many queued tasks a leaf node may
    /// hand a consumer in one `RunBatch` message. The consumer executes
    /// them back to back and reports one batched completion, so N tasks
    /// pay one message round trip. 1 (the default) is per-task dispatch —
    /// exactly the pre-v10 behaviour.
    pub dispatch_batch: usize,
    /// Merge a credit request and a result flush emitted in the same
    /// protocol step into one upstream `Flush` message (request + results
    /// ride one send). Purely a transport coalescing: the receiver
    /// processes the two halves in the same order the separate messages
    /// would have arrived.
    pub coalesce_flush: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            np: 8,
            consumers_per_buffer: 384,
            depth: 1,
            fanout: vec![8],
            shape: TreeShape::Manual,
            reshape: None,
            steal: false,
            steal_policy: StealPolicy::DeepestQueue,
            policy: SchedPolicy::Strict,
            classes: Vec::new(),
            credit_factor: 2,
            flush_every: 16,
            time_scale: 1.0,
            flush_interval_ms: 50,
            dispatch_batch: 1,
            coalesce_flush: true,
        }
    }
}

impl SchedulerConfig {
    /// Number of leaf buffer processes: ⌈np / consumers_per_buffer⌉.
    pub fn num_buffers(&self) -> usize {
        self.np.div_ceil(self.consumers_per_buffer).max(1)
    }

    /// Effective fanout of the interior nodes at buffer `level` (1 = the
    /// producer's direct children): `fanout[level − 1]`, with the last
    /// element repeating for deeper levels and an empty vector reading
    /// as 1.
    pub fn fanout_at(&self, level: usize) -> usize {
        match self.fanout.as_slice() {
            [] => 1,
            f => *f.get(level.saturating_sub(1)).unwrap_or(f.last().expect("non-empty")),
        }
        .max(1)
    }

    /// Largest per-level fanout — the upper bound the auto-shape
    /// controller may use at any level.
    pub fn max_fanout(&self) -> usize {
        self.fanout.iter().copied().max().unwrap_or(1).max(1)
    }

    /// Consumers assigned to each leaf buffer (balanced; sums to `np`).
    pub fn buffer_layout(&self) -> Vec<usize> {
        let nb = self.num_buffers();
        let base = self.np / nb;
        let extra = self.np % nb;
        (0..nb).map(|b| base + usize::from(b < extra)).collect()
    }

    /// Materialize the buffer tree this configuration describes.
    pub fn tree(&self) -> TreeTopology {
        TreeTopology::build(self.np, self.consumers_per_buffer, self.depth, &self.fanout)
    }

    /// The compact per-class `(weight, policy)` table every scheduler
    /// queue is built from (see [`crate::tenancy::ClassTable`]).
    pub fn class_table(&self) -> crate::tenancy::ClassTable {
        crate::tenancy::ClassTable::from_registry(&self.classes)
    }

    /// Name of class `id` for reports (`"default"` when unregistered).
    pub fn class_name(&self, id: crate::tenancy::ClassId) -> &str {
        self.classes.get(id as usize).map_or("default", |c| c.name.as_str())
    }
}

/// Render a per-level fanout plan for reports and logs: `"6x8"` means
/// fanout 6 at the root level and 8 below; `"-"` is the flat layout.
/// The one spelling shared by the CLI, the benches and the tracked
/// fig3 artifact.
pub fn fanout_label(fans: &[usize]) -> String {
    if fans.is_empty() {
        "-".to_string()
    } else {
        fans.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("x")
    }
}

/// Role of a node in the buffer tree.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TreeNodeKind {
    /// Feeds consumer processes directly.
    Leaf {
        /// Consumer processes attached to this leaf.
        n_consumers: usize,
        /// Global rank of this leaf's first consumer (ranks are contiguous).
        rank_base: usize,
    },
    /// Relays tasks downward and batches results upward between its parent
    /// and its child buffer nodes.
    Interior {
        /// Node ids of the children, in slot order.
        children: Vec<usize>,
    },
}

/// One node of the buffer tree (the producer itself is not a node here —
/// it is the implicit parent of [`TreeTopology::roots`]).
#[derive(Clone, Debug)]
pub struct TreeNode {
    /// Parent node id; `None` = direct child of the producer.
    pub parent: Option<usize>,
    /// Index of this node within its parent's child list.
    pub slot: usize,
    /// Buffer level: 1 = directly under the producer, `depth` = leaf level.
    pub level: usize,
    /// Leaf or interior role (and the corresponding wiring).
    pub kind: TreeNodeKind,
    /// Consumers in this node's subtree.
    pub subtree_consumers: usize,
    /// Siblings sharing this node's parent (excluding the node itself).
    pub n_siblings: usize,
}

impl TreeNode {
    /// True when this node feeds consumers directly.
    pub fn is_leaf(&self) -> bool {
        matches!(self.kind, TreeNodeKind::Leaf { .. })
    }
}

/// The N-level buffer tree: leaves first (in consumer-rank order), then
/// interior levels bottom-up. Subtree consumer ranks are contiguous by
/// construction, so per-level filling rates reduce to rank ranges.
#[derive(Clone, Debug)]
pub struct TreeTopology {
    /// Every buffer node: leaves first (consumer-rank order), then
    /// interior levels bottom-up.
    pub nodes: Vec<TreeNode>,
    /// Node ids that are direct children of the producer (level 1).
    pub roots: Vec<usize>,
    /// Number of buffer levels (1 = the paper's flat layout).
    pub depth: usize,
    /// Total consumer processes under the tree.
    pub np: usize,
}

impl TreeTopology {
    /// Build the tree for `np` consumers grouped `consumers_per_buffer`
    /// per leaf, with `depth` buffer levels and the given **per-level**
    /// fanout plan (`fanout[0]` = fan-in of the level-1 nodes, last
    /// element repeating for deeper levels; see
    /// [`SchedulerConfig::fanout`]).
    pub fn build(np: usize, consumers_per_buffer: usize, depth: usize, fanout: &[usize]) -> Self {
        let depth = depth.max(1);
        // One source of truth for the plan semantics (root-down indexing,
        // last element repeating, empty reads as 1): SchedulerConfig.
        let cfg = SchedulerConfig {
            np,
            consumers_per_buffer,
            fanout: fanout.to_vec(),
            ..SchedulerConfig::default()
        };
        let layout = cfg.buffer_layout();

        let mut nodes: Vec<TreeNode> = Vec::new();
        let mut level_nodes: Vec<usize> = Vec::new();
        let mut rank_base = 0usize;
        for &nc in &layout {
            let id = nodes.len();
            nodes.push(TreeNode {
                parent: None,
                slot: 0,
                level: depth,
                kind: TreeNodeKind::Leaf { n_consumers: nc, rank_base },
                subtree_consumers: nc,
                n_siblings: 0,
            });
            rank_base += nc;
            level_nodes.push(id);
        }

        // Interior levels from depth-1 down to 1, grouping the per-level
        // fanout's worth of children per parent. Children stay contiguous
        // in rank order.
        for level in (1..depth).rev() {
            let mut next_level = Vec::new();
            let groups: Vec<Vec<usize>> =
                level_nodes.chunks(cfg.fanout_at(level)).map(|c| c.to_vec()).collect();
            for children in groups {
                let id = nodes.len();
                let subtree: usize =
                    children.iter().map(|&c| nodes[c].subtree_consumers).sum();
                let n_ch = children.len();
                for (slot, &c) in children.iter().enumerate() {
                    nodes[c].parent = Some(id);
                    nodes[c].slot = slot;
                    nodes[c].n_siblings = n_ch - 1;
                }
                nodes.push(TreeNode {
                    parent: None,
                    slot: 0,
                    level,
                    kind: TreeNodeKind::Interior { children },
                    subtree_consumers: subtree,
                    n_siblings: 0,
                });
                next_level.push(id);
            }
            level_nodes = next_level;
        }

        let n_roots = level_nodes.len();
        for (slot, &r) in level_nodes.iter().enumerate() {
            nodes[r].slot = slot;
            nodes[r].n_siblings = n_roots - 1;
        }
        TreeTopology { nodes, roots: level_nodes, depth, np }
    }

    /// Total buffer nodes in the tree (leaves + interior relays).
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Node ids of every leaf, in consumer-rank order.
    pub fn leaf_ids(&self) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&i| self.nodes[i].is_leaf()).collect()
    }

    /// First consumer rank in `node`'s subtree (ranks are contiguous).
    pub fn subtree_rank_base(&self, node: usize) -> usize {
        match &self.nodes[node].kind {
            TreeNodeKind::Leaf { rank_base, .. } => *rank_base,
            TreeNodeKind::Interior { children } => self.subtree_rank_base(children[0]),
        }
    }

    /// `(first_rank, n_consumers)` of every node at buffer level `level`.
    pub fn level_groups(&self, level: usize) -> Vec<(usize, usize)> {
        (0..self.nodes.len())
            .filter(|&i| self.nodes[i].level == level)
            .map(|i| (self.subtree_rank_base(i), self.nodes[i].subtree_consumers))
            .collect()
    }

    /// Child node ids of `node` (empty for leaves).
    pub fn children_of(&self, node: usize) -> &[usize] {
        match &self.nodes[node].kind {
            TreeNodeKind::Leaf { .. } => &[],
            TreeNodeKind::Interior { children } => children,
        }
    }

    /// Node ids sharing `node`'s parent, in slot order (including `node`).
    pub fn sibling_group(&self, node: usize) -> Vec<usize> {
        match self.nodes[node].parent {
            None => self.roots.clone(),
            Some(p) => self.children_of(p).to_vec(),
        }
    }
}

/// Latency/overhead model for the discrete-event simulation of the
/// scheduler (§3 evaluation on the K computer).
///
/// Values are seconds of virtual time. Defaults are of the order measured
/// on commodity MPI clusters and give Fig. 3-like behaviour; the benches
/// sweep them where the conclusion could be sensitive.
#[derive(Clone, Debug)]
pub struct DesLatencyConfig {
    /// One-way point-to-point message latency.
    pub msg_latency: f64,
    /// Producer CPU time consumed per message handled (serialization,
    /// queueing). This is what melts a single-master design at scale.
    pub producer_service: f64,
    /// Buffer CPU time per message handled.
    pub buffer_service: f64,
    /// Per-task consumer-side overhead: temp-dir creation + process spawn +
    /// output parsing (§3 names these as the reason sub-second tasks are
    /// out of scope).
    pub task_overhead: f64,
    /// Delay between a kill-on-cancel notice reaching a leaf and the
    /// running attempt actually dying — the virtual-time analogue of the
    /// external-process executor's cancellation poll interval.
    pub cancel_poll: f64,
    /// Per-edge one-way latency of the buffer-tree links, root-down: index
    /// 0 is the producer↔level-1 edge, index 1 the level-1↔level-2 edge,
    /// and the last element repeats for deeper edges — the same indexing
    /// convention as [`SchedulerConfig::fanout`]. Empty (the default)
    /// means every tree edge costs [`DesLatencyConfig::msg_latency`].
    /// Consumer-facing leaf edges always use `msg_latency`: consumers are
    /// co-located with their leaf buffer, only tree links go over the
    /// wire. This is what lets `choose_shape` see a multi-host topology —
    /// a slow root edge raises the producer round trip, which deepens the
    /// auto-shaped tree exactly as a remote `caravan worker` link would.
    pub link_latency: Vec<f64>,
}

impl DesLatencyConfig {
    /// Latency of the edge *above* a node at `level` (roots are level 1,
    /// so `edge_latency(1)` is the producer↔root link). Indexes
    /// [`DesLatencyConfig::link_latency`] root-down, repeating the last
    /// element for deeper edges; with no per-edge overrides every edge is
    /// [`DesLatencyConfig::msg_latency`].
    pub fn edge_latency(&self, level: usize) -> f64 {
        match self.link_latency.len() {
            0 => self.msg_latency,
            n => self.link_latency[level.saturating_sub(1).min(n - 1)],
        }
    }
}

impl Default for DesLatencyConfig {
    fn default() -> Self {
        Self {
            msg_latency: 20e-6,
            producer_service: 50e-6,
            buffer_service: 50e-6,
            task_overhead: 0.05,
            cancel_poll: 0.01,
            link_latency: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_latency_indexes_root_down_and_repeats_last() {
        let uniform = DesLatencyConfig::default();
        assert_eq!(uniform.edge_latency(1), uniform.msg_latency);
        assert_eq!(uniform.edge_latency(3), uniform.msg_latency);
        let lat =
            DesLatencyConfig { link_latency: vec![5e-3, 1e-4], ..DesLatencyConfig::default() };
        assert_eq!(lat.edge_latency(1), 5e-3, "index 0 = producer↔root edge");
        assert_eq!(lat.edge_latency(2), 1e-4);
        assert_eq!(lat.edge_latency(3), 1e-4, "last element repeats for deeper edges");
    }

    #[test]
    fn per_level_fanout_indexes_root_down_and_repeats_last() {
        let c = SchedulerConfig { fanout: vec![4, 8], ..Default::default() };
        assert_eq!(c.fanout_at(1), 4, "level 1 = the producer's direct children");
        assert_eq!(c.fanout_at(2), 8);
        assert_eq!(c.fanout_at(3), 8, "last element repeats for deeper levels");
        assert_eq!(c.max_fanout(), 8);
        let empty = SchedulerConfig { fanout: Vec::new(), ..Default::default() };
        assert_eq!(empty.fanout_at(1), 1);
        assert_eq!(empty.max_fanout(), 1);
    }

    #[test]
    fn per_level_fanout_builds_narrow_root_wide_leaves() {
        // 64 leaves; root level groups by 4, leaf-adjacent by 8:
        // 64 → 8 (level 2, fanout 8) → 2 (level 1, fanout 4).
        let t = TreeTopology::build(64, 1, 3, &[4, 8]);
        assert_eq!(t.level_groups(3).len(), 64);
        assert_eq!(t.level_groups(2).len(), 8);
        assert_eq!(t.level_groups(1).len(), 2);
        assert_eq!(t.roots.len(), 2);
        // Uniform single-element plan matches the old scalar behaviour.
        let u = TreeTopology::build(64, 1, 3, &[8]);
        assert_eq!(u.level_groups(2).len(), 8);
        assert_eq!(u.level_groups(1).len(), 1);
    }

    #[test]
    fn sched_policy_parses_cli_spellings() {
        assert_eq!(SchedPolicy::parse("strict"), Some(SchedPolicy::Strict));
        assert_eq!(SchedPolicy::parse("deadline"), Some(SchedPolicy::Deadline));
        assert_eq!(SchedPolicy::parse("aging"), Some(SchedPolicy::Aging { step: 30.0 }));
        assert_eq!(SchedPolicy::parse("aging:2.5"), Some(SchedPolicy::Aging { step: 2.5 }));
        assert_eq!(SchedPolicy::parse("bogus"), None);
        assert_eq!(SchedPolicy::parse("aging:x"), None);
    }

    #[test]
    fn default_matches_paper_ratio() {
        let c = SchedulerConfig::default();
        assert_eq!(c.consumers_per_buffer, 384);
        assert_eq!(c.depth, 1);
    }

    #[test]
    fn buffer_layout_sums_and_balances() {
        let c = SchedulerConfig { np: 1000, consumers_per_buffer: 384, ..Default::default() };
        let layout = c.buffer_layout();
        assert_eq!(layout.len(), 3);
        assert_eq!(layout.iter().sum::<usize>(), 1000);
        let (mn, mx) = (layout.iter().min().unwrap(), layout.iter().max().unwrap());
        assert!(mx - mn <= 1, "{layout:?}");
    }

    #[test]
    fn tiny_np_gets_single_buffer() {
        let c = SchedulerConfig { np: 3, ..Default::default() };
        assert_eq!(c.num_buffers(), 1);
        assert_eq!(c.buffer_layout(), vec![3]);
    }

    #[test]
    fn layout_property_total_is_np() {
        use crate::testutil::{check, pair, usize_in};
        check("layout sums to np", pair(usize_in(1..5000), usize_in(1..500)), |&(np, cpb)| {
            let c = SchedulerConfig { np, consumers_per_buffer: cpb, ..Default::default() };
            let l = c.buffer_layout();
            l.iter().sum::<usize>() == np && !l.iter().any(|&x| x == 0)
        });
    }

    #[test]
    fn depth1_tree_is_flat_buffer_layer() {
        let c = SchedulerConfig { np: 1000, consumers_per_buffer: 384, ..Default::default() };
        let t = c.tree();
        assert_eq!(t.depth, 1);
        assert_eq!(t.roots.len(), 3);
        assert_eq!(t.nodes.len(), 3);
        assert!(t.nodes.iter().all(|n| n.is_leaf() && n.parent.is_none() && n.level == 1));
        assert_eq!(t.nodes.iter().map(|n| n.subtree_consumers).sum::<usize>(), 1000);
    }

    #[test]
    fn depth3_tree_reduces_root_fanin() {
        // 16384 consumers / 384 per leaf = 43 leaves; fanout 8 →
        // level 2 has 6 relays, level 1 has 1 relay: rank 0 talks to 1 child.
        let c = SchedulerConfig {
            np: 16384,
            consumers_per_buffer: 384,
            depth: 3,
            fanout: vec![8],
            ..Default::default()
        };
        let t = c.tree();
        assert_eq!(t.leaf_ids().len(), 43);
        assert_eq!(t.level_groups(3).len(), 43);
        assert_eq!(t.level_groups(2).len(), 6);
        assert_eq!(t.level_groups(1).len(), 1);
        assert_eq!(t.roots.len(), 1);
        // Every level partitions the full rank space.
        for level in 1..=3 {
            let groups = t.level_groups(level);
            let total: usize = groups.iter().map(|&(_, n)| n).sum();
            assert_eq!(total, 16384, "level {level}");
        }
    }

    #[test]
    fn tree_subtrees_are_contiguous_and_partition_ranks_property() {
        use crate::testutil::{check, pair, usize_in};
        check(
            "tree partitions consumer ranks at every level",
            pair(pair(usize_in(1..300), usize_in(1..20)), pair(usize_in(1..5), usize_in(1..6))),
            |&((np, cpb), (depth, fanout))| {
                let t = TreeTopology::build(np, cpb, depth, &[fanout]);
                // Roots exist and subtree totals are consistent.
                if t.roots.is_empty() {
                    return false;
                }
                let root_total: usize =
                    t.roots.iter().map(|&r| t.nodes[r].subtree_consumers).sum();
                if root_total != np {
                    return false;
                }
                for level in 1..=t.depth {
                    let mut groups = t.level_groups(level);
                    groups.sort();
                    let mut next = 0usize;
                    for (base, n) in groups {
                        if base != next || n == 0 {
                            return false;
                        }
                        next = base + n;
                    }
                    if next != np {
                        return false;
                    }
                }
                // Parent/slot links are mutually consistent.
                for (id, n) in t.nodes.iter().enumerate() {
                    if let Some(p) = n.parent {
                        if t.children_of(p).get(n.slot) != Some(&id) {
                            return false;
                        }
                    } else if t.roots.get(n.slot) != Some(&id) {
                        return false;
                    }
                }
                true
            },
        );
    }
}
