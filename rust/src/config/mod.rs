//! Configuration for the scheduler, the DES latency model, and the
//! application scenarios. All defaults follow the paper where it states
//! them (e.g. one buffer per 384 consumers).

/// Scheduler topology + flow-control parameters (threaded runtime and DES).
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Number of consumer processes N_p.
    pub np: usize,
    /// Consumers per buffer process. Paper default: 384.
    pub consumers_per_buffer: usize,
    /// A buffer keeps `credit_factor × consumers` tasks on hand.
    pub credit_factor: usize,
    /// Result-store batch size before a flush to the producer.
    pub flush_every: usize,
    /// Real seconds per virtual second for `Payload::Sleep` executors
    /// (time compression in tests/examples; 1.0 = real time).
    pub time_scale: f64,
    /// Buffer tick interval (threaded mode) for flushing stale results.
    pub flush_interval_ms: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            np: 8,
            consumers_per_buffer: 384,
            credit_factor: 2,
            flush_every: 16,
            time_scale: 1.0,
            flush_interval_ms: 50,
        }
    }
}

impl SchedulerConfig {
    /// Number of buffer processes: ⌈np / consumers_per_buffer⌉.
    pub fn num_buffers(&self) -> usize {
        self.np.div_ceil(self.consumers_per_buffer).max(1)
    }

    /// Consumers assigned to each buffer (balanced; sums to `np`).
    pub fn buffer_layout(&self) -> Vec<usize> {
        let nb = self.num_buffers();
        let base = self.np / nb;
        let extra = self.np % nb;
        (0..nb).map(|b| base + usize::from(b < extra)).collect()
    }
}

/// Latency/overhead model for the discrete-event simulation of the
/// scheduler (§3 evaluation on the K computer).
///
/// Values are seconds of virtual time. Defaults are of the order measured
/// on commodity MPI clusters and give Fig. 3-like behaviour; the benches
/// sweep them where the conclusion could be sensitive.
#[derive(Clone, Debug)]
pub struct DesLatencyConfig {
    /// One-way point-to-point message latency.
    pub msg_latency: f64,
    /// Producer CPU time consumed per message handled (serialization,
    /// queueing). This is what melts a single-master design at scale.
    pub producer_service: f64,
    /// Buffer CPU time per message handled.
    pub buffer_service: f64,
    /// Per-task consumer-side overhead: temp-dir creation + process spawn +
    /// output parsing (§3 names these as the reason sub-second tasks are
    /// out of scope).
    pub task_overhead: f64,
}

impl Default for DesLatencyConfig {
    fn default() -> Self {
        Self {
            msg_latency: 20e-6,
            producer_service: 50e-6,
            buffer_service: 50e-6,
            task_overhead: 0.05,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_ratio() {
        let c = SchedulerConfig::default();
        assert_eq!(c.consumers_per_buffer, 384);
    }

    #[test]
    fn buffer_layout_sums_and_balances() {
        let c = SchedulerConfig { np: 1000, consumers_per_buffer: 384, ..Default::default() };
        let layout = c.buffer_layout();
        assert_eq!(layout.len(), 3);
        assert_eq!(layout.iter().sum::<usize>(), 1000);
        let (mn, mx) = (layout.iter().min().unwrap(), layout.iter().max().unwrap());
        assert!(mx - mn <= 1, "{layout:?}");
    }

    #[test]
    fn tiny_np_gets_single_buffer() {
        let c = SchedulerConfig { np: 3, ..Default::default() };
        assert_eq!(c.num_buffers(), 1);
        assert_eq!(c.buffer_layout(), vec![3]);
    }

    #[test]
    fn layout_property_total_is_np() {
        use crate::testutil::{check, pair, usize_in};
        check("layout sums to np", pair(usize_in(1..5000), usize_in(1..500)), |&(np, cpb)| {
            let c = SchedulerConfig { np, consumers_per_buffer: cpb, ..Default::default() };
            let l = c.buffer_layout();
            l.iter().sum::<usize>() == np && !l.iter().any(|&x| x == 0)
        });
    }
}
