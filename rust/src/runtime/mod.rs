//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas evacuation
//! model from the rust hot path. Python never runs at request time — the
//! artifacts under `artifacts/` are produced once by `make artifacts`.
//!
//! Flow (see /opt/xla-example/load_hlo): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `PjRtClient::cpu()
//! .compile` → `execute` per evaluation. Compilation happens once per
//! variant; executions are cheap and reused across the whole optimization
//! run (10^3–10^5 evaluations).

mod server;

pub use server::PjrtServer;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::evac::sim::{AgentState, SimArrays, SimOutput, SimParams};
use crate::util::json::Json;

/// Shape signature of one compiled variant (from `artifacts/meta.json`).
#[derive(Clone, Debug, PartialEq)]
pub struct VariantSpec {
    pub name: String,
    pub file: String,
    pub a: usize,
    pub l: usize,
    pub n: usize,
    pub s: usize,
    pub t: usize,
}

/// Parsed `artifacts/meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub variants: Vec<VariantSpec>,
    pub physics: SimParams,
}

impl ArtifactMeta {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let body = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json — run `make artifacts`", dir.display()))?;
        let json = Json::parse(&body).context("parsing meta.json")?;
        let phys = json.get("physics").ok_or_else(|| anyhow!("meta.json: missing physics"))?;
        let need = |k: &str| -> Result<f64> {
            phys.get_f64(k).ok_or_else(|| anyhow!("meta.json: physics.{k} missing"))
        };
        let physics = SimParams {
            dt: need("dt")? as f32,
            v_free: need("v_free")? as f32,
            rho_jam: need("rho_jam")? as f32,
            v_min_frac: need("v_min_frac")? as f32,
            penalty: need("penalty")? as f32,
            max_steps: 0, // per-variant (T)
        };
        let vars = json
            .get("variants")
            .and_then(|v| match v {
                Json::Obj(m) => Some(m),
                _ => None,
            })
            .ok_or_else(|| anyhow!("meta.json: missing variants"))?;
        let mut variants = Vec::new();
        for (name, spec) in vars {
            let g = |k: &str| -> Result<usize> {
                spec.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("meta.json: variants.{name}.{k}"))
            };
            variants.push(VariantSpec {
                name: name.clone(),
                file: spec
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("meta.json: variants.{name}.file"))?
                    .to_string(),
                a: g("A")?,
                l: g("L")?,
                n: g("N")?,
                s: g("S")?,
                t: g("T")?,
            });
        }
        Ok(Self { dir, variants, physics })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| anyhow!("variant {name:?} not in meta.json"))
    }
}

/// A compiled evacuation model on the CPU PJRT client.
///
/// NOT `Send` (PJRT handles are thread-bound): use it on the thread that
/// loaded it, or through [`PjrtServer`] — the executor actor that owns a
/// model and serves evaluations over a channel.
pub struct PjrtEvacModel {
    exe: xla::PjRtLoadedExecutable,
    pub spec: VariantSpec,
    pub physics: SimParams,
}

impl PjrtEvacModel {
    /// Load + compile `variant` from the artifacts directory.
    pub fn load(dir: impl AsRef<Path>, variant: &str) -> Result<Self> {
        let meta = ArtifactMeta::load(&dir)?;
        let spec = meta.variant(variant)?.clone();
        let path = meta.dir.join(&spec.file);
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;
        let mut physics = meta.physics;
        physics.max_steps = spec.t;
        crate::info!("compiled {} (A={} L={} T={})", spec.file, spec.a, spec.l, spec.t);
        Ok(Self { exe, spec, physics })
    }

    /// Validate that scenario arrays fit this variant's baked shapes.
    pub fn check_arrays(&self, arrays: &SimArrays) -> Result<()> {
        if arrays.length.len() != self.spec.l + 1 {
            bail!("length: {} != L+1 = {}", arrays.length.len(), self.spec.l + 1);
        }
        if arrays.next_link.len() != self.spec.n * self.spec.s {
            bail!("next_link: {} != N*S = {}", arrays.next_link.len(), self.spec.n * self.spec.s);
        }
        if arrays.shelter_node.len() != self.spec.s {
            bail!("shelter_node: {} != S = {}", arrays.shelter_node.len(), self.spec.s);
        }
        Ok(())
    }

    /// Execute one simulation. `init` must have exactly `A` agents.
    pub fn run(&self, arrays: &SimArrays, init: &AgentState) -> Result<SimOutput> {
        if init.n_agents() != self.spec.a {
            bail!("agents: {} != A = {}", init.n_agents(), self.spec.a);
        }
        self.check_arrays(arrays)?;
        let inputs = [
            xla::Literal::vec1(&init.link),
            xla::Literal::vec1(&init.pos),
            xla::Literal::vec1(&init.dest),
            xla::Literal::vec1(&arrays.length),
            xla::Literal::vec1(&arrays.to),
            xla::Literal::vec1(&arrays.next_link),
            xla::Literal::vec1(&arrays.shelter_node),
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (f1, remaining, arrivals) =
            result.to_tuple3().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let f1: f32 = f1.get_first_element().map_err(|e| anyhow!("f1: {e:?}"))?;
        let remaining: f32 =
            remaining.get_first_element().map_err(|e| anyhow!("remaining: {e:?}"))?;
        let curve: Vec<f32> = arrivals.to_vec().map_err(|e| anyhow!("arrivals: {e:?}"))?;
        let n = init.n_agents() as f32;
        let steps_used =
            curve.iter().position(|&c| c >= n).map(|i| i + 1).unwrap_or(self.spec.t);
        Ok(SimOutput {
            evac_time: f1 as f64,
            remaining: remaining.round() as usize,
            arrivals: curve.iter().map(|&c| c.max(0.0) as u32).collect(),
            steps_used,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests that need compiled artifacts live in rust/tests/;
    // here only the pure parsing logic.

    #[test]
    fn meta_parsing_roundtrip() {
        let dir = std::env::temp_dir().join(format!("caravan_meta_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"physics": {"dt": 2.0, "v_free": 1.4, "rho_jam": 2.0,
                 "v_min_frac": 0.05, "penalty": 600.0},
                "variants": {"tiny": {"A": 256, "L": 98, "N": 30, "S": 3,
                 "T": 512, "file": "evac_tiny.hlo.txt"}}}"#,
        )
        .unwrap();
        let meta = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(meta.variants.len(), 1);
        let v = meta.variant("tiny").unwrap();
        assert_eq!((v.a, v.l, v.n, v.s, v.t), (256, 98, 30, 3, 512));
        assert_eq!(meta.physics.dt, 2.0);
        assert!(meta.variant("nope").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn meta_missing_fields_rejected() {
        let dir = std::env::temp_dir().join(format!("caravan_meta_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), r#"{"variants": {}}"#).unwrap();
        assert!(ArtifactMeta::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
