//! The PJRT executor actor.
//!
//! PJRT handles are thread-bound (`!Send`), but consumers across the
//! scheduler need a shared [`SimBackend`]. [`PjrtServer`] owns the
//! compiled model on a dedicated thread and serves evaluation requests
//! over a channel: compile once, execute many — the request path never
//! touches Python *or* recompiles.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::PjrtEvacModel;
use crate::evac::evaluator::SimBackend;
use crate::evac::sim::{AgentState, SimArrays, SimOutput};

enum Req {
    Run { init: AgentState, reply: Sender<Result<SimOutput>> },
    Stop,
}

/// Handle to the executor thread. Cloning is not supported — wrap in
/// `Arc` to share across consumers (requests are serialized by the single
/// model anyway, which matches the one-core host).
pub struct PjrtServer {
    tx: Mutex<Sender<Req>>,
    thread: Mutex<Option<JoinHandle<()>>>,
    variant: String,
}

impl PjrtServer {
    /// Spawn the actor: loads + compiles `variant` on its own thread.
    /// Blocks until compilation finished (or failed).
    pub fn start(artifacts_dir: PathBuf, variant: &str, arrays: SimArrays) -> Result<Self> {
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let var = variant.to_string();
        let thread = std::thread::Builder::new()
            .name(format!("pjrt-{var}"))
            .spawn(move || {
                let model = match PjrtEvacModel::load(&artifacts_dir, &var) {
                    Ok(m) => {
                        if let Err(e) = m.check_arrays(&arrays) {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                        let _ = ready_tx.send(Ok(()));
                        m
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Run { init, reply } => {
                            let _ = reply.send(model.run(&arrays, &init));
                        }
                        Req::Stop => break,
                    }
                }
            })
            .expect("spawn pjrt server");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt server thread died during startup"))??;
        Ok(Self { tx: Mutex::new(tx), thread: Mutex::new(Some(thread)), variant: variant.into() })
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }

    /// Run one simulation (blocks for the result).
    pub fn run_sim(&self, init: AgentState) -> Result<SimOutput> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Req::Run { init, reply: reply_tx })
            .map_err(|_| anyhow!("pjrt server stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("pjrt server dropped request"))?
    }
}

impl Drop for PjrtServer {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Req::Stop);
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

impl SimBackend for PjrtServer {
    fn run(&self, init: AgentState) -> SimOutput {
        self.run_sim(init).expect("PJRT execution failed")
    }

    fn name(&self) -> &'static str {
        "pjrt-aot"
    }
}
