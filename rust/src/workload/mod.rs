//! Synthetic workloads of the paper's §3 performance evaluation.
//!
//! * **TC1** — N tasks up front, durations ~ U[20, 30] s.
//! * **TC2** — N tasks up front, durations ~ power-law exponent −2 on
//!   [5, 100] s (heavy tail: most tasks < 10 s, a few near 100 s).
//! * **TC3** — N/4 tasks up front; each completion spawns one more until
//!   N tasks total (the dynamic pattern of optimization workloads).
//!
//! Each is a [`SearchEngine`] submitting [`Payload::Sleep`] tasks, so the
//! same object drives both the threaded runtime and the DES.

use crate::api::JobSink;
use crate::tasklib::{Payload, SearchEngine, TaskResult, TaskSink};
use crate::util::rng::Pcg64;

/// Which test case of §3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TestCase {
    TC1,
    TC2,
    TC3,
}

impl TestCase {
    pub fn parse(s: &str) -> Option<TestCase> {
        match s.trim().to_ascii_lowercase().as_str() {
            "1" | "tc1" => Some(TestCase::TC1),
            "2" | "tc2" => Some(TestCase::TC2),
            "3" | "tc3" => Some(TestCase::TC3),
            _ => None,
        }
    }
}

/// Duration distributions used by the test cases.
#[derive(Clone, Copy, Debug)]
pub enum DurationDist {
    /// U[lo, hi].
    Uniform { lo: f64, hi: f64 },
    /// Power law with the given exponent on [lo, hi].
    PowerLaw { lo: f64, hi: f64, exponent: f64 },
}

impl DurationDist {
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match *self {
            DurationDist::Uniform { lo, hi } => rng.range_f64(lo, hi),
            DurationDist::PowerLaw { lo, hi, exponent } => rng.power_law(lo, hi, exponent),
        }
    }

    /// The paper's distributions.
    pub fn tc1() -> Self {
        DurationDist::Uniform { lo: 20.0, hi: 30.0 }
    }

    pub fn tc23() -> Self {
        DurationDist::PowerLaw { lo: 5.0, hi: 100.0, exponent: -2.0 }
    }
}

/// The §3 workload engine: generates `n_total` sleep tasks according to the
/// chosen test case.
pub struct TestCaseEngine {
    case: TestCase,
    n_total: usize,
    created: usize,
    rng: Pcg64,
}

impl TestCaseEngine {
    pub fn new(case: TestCase, n_total: usize, seed: u64) -> Self {
        Self { case, n_total, created: 0, rng: Pcg64::new(seed) }
    }

    fn dist(&self) -> DurationDist {
        match self.case {
            TestCase::TC1 => DurationDist::tc1(),
            TestCase::TC2 | TestCase::TC3 => DurationDist::tc23(),
        }
    }

    fn submit_one(&mut self, sink: &mut dyn JobSink) {
        let d = self.dist().sample(&mut self.rng);
        sink.submit(Payload::Sleep { seconds: d });
        self.created += 1;
    }

    pub fn created(&self) -> usize {
        self.created
    }
}

impl SearchEngine for TestCaseEngine {
    fn start(&mut self, sink: &mut dyn JobSink) {
        let up_front = match self.case {
            TestCase::TC1 | TestCase::TC2 => self.n_total,
            TestCase::TC3 => (self.n_total / 4).max(1).min(self.n_total),
        };
        for _ in 0..up_front {
            self.submit_one(sink);
        }
    }

    fn on_done(&mut self, _r: &TaskResult, sink: &mut dyn JobSink) {
        if self.case == TestCase::TC3 && self.created < self.n_total {
            self.submit_one(sink);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::VecSink;

    fn durations(sink: &VecSink) -> Vec<f64> {
        sink.submitted
            .iter()
            .map(|t| match t.payload {
                Payload::Sleep { seconds } => seconds,
                _ => panic!(),
            })
            .collect()
    }

    #[test]
    fn tc1_submits_all_with_uniform_durations() {
        let mut e = TestCaseEngine::new(TestCase::TC1, 100, 1);
        let mut sink = VecSink::new();
        e.start(&mut sink);
        let ds = durations(&sink);
        assert_eq!(ds.len(), 100);
        assert!(ds.iter().all(|&d| (20.0..30.0).contains(&d)));
    }

    #[test]
    fn tc2_has_heavy_tail_within_bounds() {
        let mut e = TestCaseEngine::new(TestCase::TC2, 2000, 2);
        let mut sink = VecSink::new();
        e.start(&mut sink);
        let ds = durations(&sink);
        assert_eq!(ds.len(), 2000);
        assert!(ds.iter().all(|&d| (5.0..=100.0).contains(&d)));
        let short = ds.iter().filter(|&&d| d < 10.0).count();
        // ~52.6% expected below 10 s for exponent −2 on [5,100].
        assert!(short > 900 && short < 1200, "short={short}");
    }

    #[test]
    fn tc3_starts_quarter_then_chains_to_total() {
        let mut e = TestCaseEngine::new(TestCase::TC3, 40, 3);
        let mut sink = VecSink::new();
        e.start(&mut sink);
        assert_eq!(sink.submitted.len(), 10);
        // Simulate completions.
        let mut done = 0;
        while done < 40 {
            let spec = sink.submitted[done].clone();
            let r = TaskResult {
                id: spec.id,
                consumer: 0,
                results: vec![],
                begin: 0.0,
                finish: 1.0,
                rc: 0,
                attempt: 0,
                timed_out: false,
            };
            e.on_done(&r, &mut sink);
            done += 1;
        }
        assert_eq!(sink.submitted.len(), 40);
        assert_eq!(e.created(), 40);
        // Further completions create nothing.
        let r = TaskResult {
            id: 0,
            consumer: 0,
            results: vec![],
            begin: 0.0,
            finish: 1.0,
            rc: 0,
            attempt: 0,
            timed_out: false,
        };
        e.on_done(&r, &mut sink);
        assert_eq!(sink.submitted.len(), 40);
    }

    #[test]
    fn parse_test_case() {
        assert_eq!(TestCase::parse("tc1"), Some(TestCase::TC1));
        assert_eq!(TestCase::parse("2"), Some(TestCase::TC2));
        assert_eq!(TestCase::parse("x"), None);
    }
}
