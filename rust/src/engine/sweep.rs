//! Trivial parameter-parallel engines: grid sweeps and random sampling —
//! the "embarrassingly parallel" use cases of §1 (parameter
//! parallelization), complementing the dynamic engines (MOEA, MCMC).
//!
//! Both engines are [`JobEngine`]s on the Job API v2: the parameter point
//! rides along as the job context, so there is no engine-side `TaskId ->
//! point` bookkeeping. Constructors return the ready-to-run
//! [`JobAdapter`] (it derefs to the engine for accessors like
//! [`GridEngine::size`]).

use std::sync::{Arc, Mutex};

use crate::api::{JobAdapter, JobEngine, JobSpec, Jobs};
use crate::tasklib::TaskResult;
use crate::util::rng::Pcg64;

/// Collected `(point, results)` pairs, shared out of a sweep engine.
pub type SweepOutcome = Arc<Mutex<Vec<(Vec<f64>, Vec<f64>)>>>;

/// Full-factorial grid over the given per-dimension values.
pub struct GridEngine {
    axes: Vec<Vec<f64>>,
    seed: u64,
    outcome: SweepOutcome,
}

impl GridEngine {
    pub fn new(axes: Vec<Vec<f64>>, seed: u64) -> (JobAdapter<Self>, SweepOutcome) {
        assert!(!axes.is_empty() && axes.iter().all(|a| !a.is_empty()));
        let outcome: SweepOutcome = Arc::new(Mutex::new(Vec::new()));
        (
            JobAdapter::new(Self { axes, seed, outcome: Arc::clone(&outcome) }),
            outcome,
        )
    }

    /// Total number of grid points.
    pub fn size(&self) -> usize {
        self.axes.iter().map(Vec::len).product()
    }
}

impl JobEngine for GridEngine {
    type Ctx = Vec<f64>;

    fn start(&mut self, jobs: &mut Jobs<'_, Vec<f64>>) {
        let dims = self.axes.len();
        let mut idx = vec![0usize; dims];
        loop {
            let point: Vec<f64> = (0..dims).map(|d| self.axes[d][idx[d]]).collect();
            jobs.submit(JobSpec::eval(point.clone()).seed(self.seed), point);
            // Odometer increment.
            let mut d = 0;
            loop {
                if d == dims {
                    return;
                }
                idx[d] += 1;
                if idx[d] < self.axes[d].len() {
                    break;
                }
                idx[d] = 0;
                d += 1;
            }
        }
    }

    fn on_done(&mut self, result: &TaskResult, point: Vec<f64>, _jobs: &mut Jobs<'_, Vec<f64>>) {
        self.outcome.lock().unwrap().push((point, result.results.clone()));
    }
}

/// `n` uniform random points in a bounding box.
pub struct RandomEngine {
    bounds: Vec<(f64, f64)>,
    n: usize,
    rng: Pcg64,
    outcome: SweepOutcome,
}

impl RandomEngine {
    pub fn new(
        bounds: Vec<(f64, f64)>,
        n: usize,
        seed: u64,
    ) -> (JobAdapter<Self>, SweepOutcome) {
        let outcome: SweepOutcome = Arc::new(Mutex::new(Vec::new()));
        (
            JobAdapter::new(Self {
                bounds,
                n,
                rng: Pcg64::new(seed),
                outcome: Arc::clone(&outcome),
            }),
            outcome,
        )
    }
}

impl JobEngine for RandomEngine {
    type Ctx = Vec<f64>;

    fn start(&mut self, jobs: &mut Jobs<'_, Vec<f64>>) {
        for k in 0..self.n {
            let point: Vec<f64> =
                self.bounds.iter().map(|&(lo, hi)| self.rng.range_f64(lo, hi)).collect();
            jobs.submit(JobSpec::eval(point.clone()).seed(k as u64), point);
        }
    }

    fn on_done(&mut self, result: &TaskResult, point: Vec<f64>, _jobs: &mut Jobs<'_, Vec<f64>>) {
        self.outcome.lock().unwrap().push((point, result.results.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{run_des, ConstResults, DesConfig};
    use crate::util::stats::nan_worst_slice;

    #[test]
    fn grid_enumerates_cartesian_product() {
        let (engine, outcome) = GridEngine::new(vec![vec![0.0, 1.0], vec![0.0, 0.5, 1.0]], 0);
        assert_eq!(engine.size(), 6);
        let r = run_des(
            &DesConfig::new(4),
            Box::new(engine),
            Box::new(ConstResults::new(1.0, 2.0, 2, 0)),
        );
        assert_eq!(r.results.len(), 6);
        let got = outcome.lock().unwrap();
        assert_eq!(got.len(), 6);
        let mut points: Vec<Vec<f64>> = got.iter().map(|(p, _)| p.clone()).collect();
        // nan_worst_slice, not `partial_cmp().unwrap()`: one NaN
        // coordinate must never panic a result sort (float-ord rule).
        points.sort_by(|a, b| nan_worst_slice(a, b));
        assert_eq!(points[0], vec![0.0, 0.0]);
        assert_eq!(points[5], vec![1.0, 1.0]);
        assert!(got.iter().all(|(_, res)| res.len() == 2));
    }

    #[test]
    fn random_engine_samples_in_bounds() {
        let (engine, outcome) = RandomEngine::new(vec![(-1.0, 1.0), (10.0, 20.0)], 50, 7);
        let r = run_des(
            &DesConfig::new(8),
            Box::new(engine),
            Box::new(ConstResults::new(1.0, 2.0, 1, 0)),
        );
        assert_eq!(r.results.len(), 50);
        let got = outcome.lock().unwrap();
        assert_eq!(got.len(), 50);
        for (p, _) in got.iter() {
            assert!((-1.0..1.0).contains(&p[0]));
            assert!((10.0..20.0).contains(&p[1]));
        }
    }

    #[test]
    fn grid_point_sort_survives_nan_coordinates() {
        // Regression (mirrors the PR 4/6 NaN sweeps): a grid axis fed a
        // NaN — e.g. a bound computed from a failed calibration — used to
        // panic the result sort via `Vec<f64>::partial_cmp().unwrap()`.
        // The nan_worst_slice comparator must order it deterministically
        // to the back instead.
        let (engine, outcome) = GridEngine::new(vec![vec![0.0, f64::NAN], vec![1.0]], 0);
        let r = run_des(
            &DesConfig::new(2),
            Box::new(engine),
            Box::new(ConstResults::new(1.0, 2.0, 1, 0)),
        );
        assert_eq!(r.results.len(), 2);
        let got = outcome.lock().unwrap();
        let mut points: Vec<Vec<f64>> = got.iter().map(|(p, _)| p.clone()).collect();
        points.sort_by(|a, b| nan_worst_slice(a, b));
        assert_eq!(points[0], vec![0.0, 1.0]);
        assert!(points[1][0].is_nan(), "NaN point sorts last, never panics");
    }
}
