//! Search engines — the module that "determines the policy on how
//! parameter space is explored" (§2.1).
//!
//! * [`session`] — the await-style client API of §2.3 (`Task.create`,
//!   `await_task`, callbacks, concurrent activities).
//! * [`sweep`] — grid and random sampling (trivial parameter parallelism).
//! * [`nsga2`] / [`moea`] — NSGA-II with the paper's asynchronous
//!   generation update (§4.2) plus the synchronous baseline.
//! * [`mcmc`] — Metropolis sampling (the dynamic-exploration use case).

pub mod mcmc;
pub mod moea;
pub mod nsga2;
pub mod session;
pub mod sweep;

pub use mcmc::{McmcConfig, McmcEngine, McmcOutcome};
pub use moea::{MoeaConfig, MoeaOutcome, Nsga2Engine};
pub use nsga2::{dominates, fast_non_dominated_sort, Individual};
pub use session::{Session, SessionHandle, TaskHandle};
pub use sweep::{GridEngine, RandomEngine};
