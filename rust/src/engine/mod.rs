//! Search engines — the module that "determines the policy on how
//! parameter space is explored" (§2.1).
//!
//! * [`session`] — the await-style client API of §2.3 (`Task.create`,
//!   `await_task`, callbacks, concurrent activities).
//! * [`sweep`] — grid and random sampling (trivial parameter parallelism).
//! * [`nsga2`] / [`moea`] — NSGA-II with the paper's asynchronous
//!   generation update (§4.2) plus the synchronous baseline.
//! * [`mcmc`] — Metropolis sampling (the dynamic-exploration use case).
//!
//! All engines are built on the Job API v2 ([`crate::api`]): they submit
//! typed [`JobSpec`](crate::api::JobSpec)s with an engine-owned context
//! value, so none of them keeps a `TaskId -> context` map. Constructors
//! return a ready-to-run [`JobAdapter`](crate::api::JobAdapter) (it derefs
//! to the engine), so `Box::new(engine)` still plugs into `run_scheduler`
//! and `run_des` unchanged.

pub mod mcmc;
pub mod moea;
pub mod nsga2;
pub mod session;
pub mod sweep;

pub use mcmc::{McmcConfig, McmcEngine, McmcOutcome};
pub use moea::{MoeaConfig, MoeaOutcome, Nsga2Engine};
pub use nsga2::{dominates, fast_non_dominated_sort, Individual};
pub use session::{Session, SessionHandle, TaskHandle};
pub use sweep::{GridEngine, RandomEngine};
