//! The await-style client API of §2.3 — the Rust analogue of
//!
//! ```python
//! with Server.start():
//!     task = Task.create("sleep 1")
//!     Server.await_task(task)       # blocks until the task is finished
//! ```
//!
//! [`Session::start`] launches the hierarchical scheduler on a background
//! thread; any number of user threads ("concurrent activities", cf.
//! `Server.async`) may then create tasks and block on their results:
//!
//! ```no_run
//! use std::sync::Arc;
//! use caravan::api::JobSpec;
//! use caravan::config::SchedulerConfig;
//! use caravan::engine::Session;
//! use caravan::scheduler::SleepExecutor;
//!
//! let session = Session::start(
//!     SchedulerConfig { np: 4, ..Default::default() },
//!     Arc::new(SleepExecutor { time_scale: 0.001 }),
//! );
//! let t = session.submit(JobSpec::sleep(2.0).priority(3).retries(1));
//! let result = session.await_task(&t);
//! assert_eq!(result.rc, 0);
//! session.shutdown();
//! ```
//!
//! The session is built on the Job API v2: [`Session::submit`] takes a
//! [`JobSpec`] (priority, retries, timeout, tag), [`Session::cancel`]
//! requests best-effort cancellation, [`Session::await_any`] blocks on a
//! set of handles, and [`Session::status`] reports a handle's
//! [`JobStatus`]. The legacy `create_task(payload)` calls still work.
//!
//! Callbacks (`task.add_callback` in the Python API) are supported through
//! [`Session::create_task_with_callback`]; the callback runs on the
//! scheduler thread and may itself create tasks.
//!
//! Internally the session engine is a [`JobEngine`] whose per-job context
//! carries the waiter channel and the optional callback — the framework's
//! context map replaces the session's old `waiters`/`callbacks` HashMaps.

// BTreeMap, not HashMap: the session surfaces per-task status to callers
// and sits in a deterministic-output path (the `hash-iter` lint rule
// covers this file).
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{JobAdapter, JobEngine, JobSpec, JobStatus, Jobs};
use crate::config::SchedulerConfig;
use crate::scheduler::threads::{run_scheduler, Executor, Report};
use crate::tasklib::{Payload, TaskId, TaskResult};
use crate::tenancy::{Admission, AdmissionController, ClassId};

/// Callback invoked on the scheduler thread when a task completes. It may
/// submit follow-up tasks through the provided handle.
pub type Callback = Box<dyn FnOnce(&TaskResult, &SessionHandle) + Send>;

/// A created task: await it via [`Session::await_task`].
///
/// The task id is resolved lazily: creation does not block on the
/// scheduler thread (callbacks run *on* that thread and may create tasks —
/// blocking there would deadlock). The id lives in a shared [`OnceLock`]
/// cell the scheduler thread fills during its next drain, so handles are
/// `Sync`, and [`SessionHandle::cancel`] / [`Session::status`] never have
/// to block — safe to call from completion callbacks.
pub struct TaskHandle {
    id: Arc<OnceLock<TaskId>>,
    rx: Mutex<Receiver<TaskResult>>,
    /// Used by `Drop` to retire this task's status entry.
    ctl: Sender<Ctl>,
}

impl TaskHandle {
    /// The scheduler-assigned task id, if already resolved (non-blocking).
    pub fn try_id(&self) -> Option<TaskId> {
        self.id.get().copied()
    }

    /// The scheduler-assigned task id (waits briefly on first call while
    /// the scheduler thread registers the submission).
    pub fn id(&self) -> TaskId {
        // 200 µs × 150 000 = 30 s: far beyond any healthy drain tick.
        for _ in 0..150_000u32 {
            if let Some(id) = self.try_id() {
                return id;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        panic!("session closed or wedged before assigning a task id");
    }
}

impl Drop for TaskHandle {
    fn drop(&mut self) {
        // Nobody can query this task's status any more: let the session
        // retire the entry so long-lived sessions do not accumulate one
        // per task ever submitted. (An unresolved id means the submission
        // never registered; there is nothing to retire.)
        if let Some(id) = self.try_id() {
            let _ = self.ctl.send(Ctl::Forget { id });
        }
    }
}

/// One submission parked at the admission boundary (or in flight on the
/// control channel): everything the engine needs to register it.
struct PendingSubmit {
    spec: JobSpec,
    waiter: Sender<TaskResult>,
    reply: Arc<OnceLock<TaskId>>,
    callback: Option<Callback>,
}

/// The shared per-class admission state: consulted synchronously by
/// submitters, released by the engine as final results arrive.
type SharedAdmission = Arc<Mutex<AdmissionController<PendingSubmit>>>;

enum Ctl {
    /// A submission the admission controller already counted in flight.
    Submit(PendingSubmit),
    /// Cancel the task whose id lives in the shared cell. For a directly
    /// admitted task the cell is always filled by the time this is
    /// drained (its `Submit` precedes it on this same FIFO channel); a
    /// submission still parked at the admission boundary has no id yet —
    /// cancellation of parked work is a no-op (it runs when released).
    Cancel { id: Arc<OnceLock<TaskId>> },
    /// A handle was dropped: retire its status entry.
    Forget { id: TaskId },
    Close,
}

/// Cloneable handle used inside callbacks to create further tasks.
#[derive(Clone)]
pub struct SessionHandle {
    ctl: Sender<Ctl>,
    adm: SharedAdmission,
}

impl SessionHandle {
    /// Submit a typed job (the v2 entry point). Quota-blind: a job of a
    /// class at quota is *held back* at the session boundary and released
    /// as earlier jobs of the class finish — never rejected — so the
    /// pre-tenancy fire-and-forget semantics are preserved while the
    /// scheduler-side in-flight count stays bounded. Use
    /// [`SessionHandle::try_submit`] to observe the admission decision.
    pub fn submit(&self, spec: JobSpec) -> TaskHandle {
        let (_, handle) = self.submit_admission(spec, None, true);
        handle.expect("quota-blind submit always yields a handle")
    }

    /// Submit with typed admission control: [`Admission::Accepted`] jobs
    /// enter the scheduler immediately, [`Admission::Queued`] jobs are
    /// held at the boundary (their handle resolves once released), and
    /// [`Admission::Rejected`] jobs — the class's bounded backlog is full
    /// — are **not** submitted and yield no handle.
    pub fn try_submit(&self, spec: JobSpec) -> (Admission, Option<TaskHandle>) {
        self.submit_admission(spec, None, false)
    }

    pub fn create_task(&self, payload: Payload) -> TaskHandle {
        self.submit(JobSpec::new(payload))
    }

    pub fn create_task_with_callback(&self, payload: Payload, cb: Callback) -> TaskHandle {
        self.submit_with_callback(JobSpec::new(payload), cb)
    }

    pub fn submit_with_callback(&self, spec: JobSpec, cb: Callback) -> TaskHandle {
        let (_, handle) = self.submit_admission(spec, Some(cb), true);
        handle.expect("quota-blind submit always yields a handle")
    }

    /// Request best-effort cancellation. Never blocks — the id resolution
    /// happens on the scheduler thread, so this is safe inside callbacks.
    pub fn cancel(&self, task: &TaskHandle) {
        let _ = self.ctl.send(Ctl::Cancel { id: Arc::clone(&task.id) });
    }

    /// The shared admission path. `quota_blind` parks at-quota
    /// submissions instead of ever rejecting them (the legacy `submit`
    /// contract); `try_submit` exposes the full three-way decision.
    fn submit_admission(
        &self,
        spec: JobSpec,
        callback: Option<Callback>,
        quota_blind: bool,
    ) -> (Admission, Option<TaskHandle>) {
        let (wtx, wrx) = channel();
        let id = Arc::new(OnceLock::new());
        let class = spec.class;
        let pending = PendingSubmit { spec, waiter: wtx, reply: Arc::clone(&id), callback };
        let (decision, released) = {
            let mut adm = self.adm.lock().unwrap();
            if quota_blind {
                adm.offer_unbounded(class, pending)
            } else {
                adm.offer(class, pending)
            }
        };
        if decision == Admission::Rejected {
            return (Admission::Rejected, None);
        }
        if let Some(p) = released {
            self.ctl.send(Ctl::Submit(p)).expect("session closed");
        }
        (decision, Some(TaskHandle { id, rx: Mutex::new(wrx), ctl: self.ctl.clone() }))
    }
}

/// Per-job context the session engine attaches to every submission: who is
/// waiting for the result, what (if anything) to run on completion, and
/// which tenant class to credit back at the admission boundary.
struct SessionCtx {
    waiter: Sender<TaskResult>,
    callback: Option<Callback>,
    class: ClassId,
}

/// The session engine: a [`JobEngine`] that pulls submissions from the
/// control channel during `poll`.
struct SessionEngine {
    ctl_rx: Receiver<Ctl>,
    handle: SessionHandle,
    status: Arc<Mutex<BTreeMap<TaskId, JobStatus>>>,
    adm: SharedAdmission,
    closed: bool,
}

impl JobEngine for SessionEngine {
    type Ctx = SessionCtx;

    fn start(&mut self, _jobs: &mut Jobs<'_, SessionCtx>) {}

    fn on_done(&mut self, result: &TaskResult, ctx: SessionCtx, jobs: &mut Jobs<'_, SessionCtx>) {
        if let Some(cb) = ctx.callback {
            cb(result, &self.handle);
            // The callback may have pushed submissions into the control
            // channel; drain them immediately so follow-up tasks are
            // scheduled without waiting for the next poll tick.
            self.drain(jobs);
        }
        // Update-only: if the handle was already dropped, its `Forget`
        // retired the entry — re-inserting here would leak one status row
        // per fire-and-forget task for the session's lifetime.
        if let Some(slot) = self.status.lock().unwrap().get_mut(&result.id) {
            *slot = JobStatus::from_result(result);
        }
        let _ = ctx.waiter.send(result.clone());
        // Credit the class back at the admission boundary; a held-back
        // submission of the class (if any) takes the freed slot now.
        let released = self.adm.lock().unwrap().complete(ctx.class);
        if let Some(p) = released {
            self.register(p, jobs);
        }
    }

    fn poll(&mut self, jobs: &mut Jobs<'_, SessionCtx>) -> bool {
        self.drain(jobs);
        // Submissions parked at the admission boundary are invisible to
        // the scheduler's own quiescence accounting: the session is only
        // done when none remain.
        self.closed && !self.adm.lock().unwrap().any_waiting()
    }
}

impl SessionEngine {
    /// Hand one admitted submission to the scheduler and resolve its id.
    fn register(&self, p: PendingSubmit, jobs: &mut Jobs<'_, SessionCtx>) {
        let class = p.spec.class;
        let id =
            jobs.submit(p.spec, SessionCtx { waiter: p.waiter, callback: p.callback, class });
        self.status.lock().unwrap().insert(id, JobStatus::Queued);
        let _ = p.reply.set(id);
    }

    fn drain(&mut self, jobs: &mut Jobs<'_, SessionCtx>) {
        while let Ok(msg) = self.ctl_rx.try_recv() {
            match msg {
                Ctl::Submit(p) => self.register(p, jobs),
                Ctl::Cancel { id } => {
                    // The Submit that fills the cell precedes this message
                    // on the FIFO control channel, so it is always set.
                    if let Some(&id) = id.get() {
                        jobs.cancel(id);
                    }
                }
                Ctl::Forget { id } => {
                    self.status.lock().unwrap().remove(&id);
                }
                Ctl::Close => {
                    self.closed = true;
                }
            }
        }
    }
}

/// A running scheduler session (the `Server.start()` context).
pub struct Session {
    handle: SessionHandle,
    status: Arc<Mutex<BTreeMap<TaskId, JobStatus>>>,
    thread: Mutex<Option<JoinHandle<Report>>>,
}

impl Session {
    /// Start the scheduler with `cfg` on a background thread. The
    /// [`crate::tenancy::JobClass`] registry in
    /// [`SchedulerConfig::classes`] drives both the in-tree fair-share
    /// lanes and the per-class admission quotas at this boundary.
    pub fn start(cfg: SchedulerConfig, executor: Arc<dyn Executor>) -> Session {
        let (ctl_tx, ctl_rx) = channel();
        let adm: SharedAdmission = Arc::new(Mutex::new(AdmissionController::new(&cfg.classes)));
        let handle = SessionHandle { ctl: ctl_tx, adm: Arc::clone(&adm) };
        let status: Arc<Mutex<BTreeMap<TaskId, JobStatus>>> = Arc::new(Mutex::new(BTreeMap::new()));
        let engine = SessionEngine {
            ctl_rx,
            handle: handle.clone(),
            status: Arc::clone(&status),
            adm: Arc::clone(&adm),
            closed: false,
        };
        let thread = std::thread::Builder::new()
            .name("caravan-session".into())
            .spawn(move || run_scheduler(&cfg, Box::new(JobAdapter::new(engine)), executor))
            .expect("spawn session");
        Session { handle, status, thread: Mutex::new(Some(thread)) }
    }

    pub fn handle(&self) -> SessionHandle {
        self.handle.clone()
    }

    /// Submit a typed job: `session.submit(JobSpec::sleep(1.0).priority(2))`.
    /// Quota-blind (see [`SessionHandle::submit`]): at-quota submissions
    /// are held back, never rejected.
    pub fn submit(&self, spec: JobSpec) -> TaskHandle {
        self.handle.submit(spec)
    }

    /// Submit with typed admission control (see
    /// [`SessionHandle::try_submit`]): returns the [`Admission`] decision
    /// and a handle unless the job was rejected.
    pub fn try_submit(&self, spec: JobSpec) -> (Admission, Option<TaskHandle>) {
        self.handle.try_submit(spec)
    }

    /// Admission-boundary load of `class`: `(in_flight, held_back)`.
    pub fn admission_load(&self, class: ClassId) -> (usize, usize) {
        let adm = self.handle.adm.lock().unwrap();
        (adm.in_flight(class), adm.queued(class))
    }

    /// `Task.create` — submit a task with default scheduling.
    pub fn create_task(&self, payload: Payload) -> TaskHandle {
        self.handle.create_task(payload)
    }

    /// `task.add_callback` at creation time.
    pub fn create_task_with_callback(&self, payload: Payload, cb: Callback) -> TaskHandle {
        self.handle.create_task_with_callback(payload, cb)
    }

    /// Request best-effort cancellation of `task`. If it was still
    /// queued, it is dropped; if it is already *running*, the executor is
    /// asked to kill the attempt (the external-process executor kills the
    /// child within its poll interval). Either way the waiters receive an
    /// `RC_CANCELLED` result and no retry is consumed. Never blocks.
    pub fn cancel(&self, task: &TaskHandle) {
        self.handle.cancel(task);
    }

    /// Lifecycle state of `task`. Non-blocking: an id not yet registered
    /// by the scheduler thread reports as `Queued`.
    pub fn status(&self, task: &TaskHandle) -> JobStatus {
        match task.try_id() {
            None => JobStatus::Queued,
            Some(id) => {
                self.status.lock().unwrap().get(&id).copied().unwrap_or(JobStatus::Queued)
            }
        }
    }

    /// `Server.await_task` — block until the task finishes.
    pub fn await_task(&self, task: &TaskHandle) -> TaskResult {
        task.rx.lock().unwrap().recv().expect("scheduler dropped the task")
    }

    /// Block until *any* of the given (still-pending) tasks finishes;
    /// returns its index and result. Handles whose receiver is currently
    /// held by a concurrent `await_task` are skipped rather than waited on
    /// (that caller will consume the result), so one blocked handle never
    /// stalls the scan past other finished tasks. Panics on an empty
    /// slice, and — mirroring [`Session::await_task`] — when *no* handle
    /// can ever produce a result (every result already consumed, or the
    /// scheduler exited), instead of spinning forever.
    pub fn await_any(&self, tasks: &[TaskHandle]) -> (usize, TaskResult) {
        use std::sync::mpsc::TryRecvError;
        assert!(!tasks.is_empty(), "await_any on an empty task set");
        loop {
            let mut dead = 0;
            for (i, t) in tasks.iter().enumerate() {
                if let Ok(rx) = t.rx.try_lock() {
                    match rx.try_recv() {
                        Ok(r) => return (i, r),
                        Err(TryRecvError::Disconnected) => dead += 1,
                        Err(TryRecvError::Empty) => {}
                    }
                }
            }
            if dead == tasks.len() {
                panic!("await_any: every result was already consumed or the scheduler exited");
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// `Server.await_all_tasks` over an explicit set.
    pub fn await_all(&self, tasks: &[TaskHandle]) -> Vec<TaskResult> {
        tasks.iter().map(|t| self.await_task(t)).collect()
    }

    /// End the session: no more submissions; waits for in-flight tasks and
    /// returns the scheduler report.
    pub fn shutdown(&self) -> Report {
        let _ = self.handle.ctl.send(Ctl::Close);
        let th = self.thread.lock().unwrap().take().expect("already shut down");
        th.join().expect("scheduler thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::SleepExecutor;
    use crate::tasklib::RC_CANCELLED;

    fn session(np: usize) -> Session {
        Session::start(
            SchedulerConfig {
                np,
                consumers_per_buffer: 4,
                flush_interval_ms: 2,
                ..Default::default()
            },
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        )
    }

    #[test]
    fn await_single_task() {
        let s = session(2);
        let t = s.create_task(Payload::Sleep { seconds: 3.0 });
        let r = s.await_task(&t);
        assert_eq!(r.id, t.id());
        assert_eq!(r.results, vec![3.0]);
        let report = s.shutdown();
        assert_eq!(report.results.len(), 1);
    }

    #[test]
    fn paper_example_ten_tasks() {
        // §2.3 minimal program: ten tasks in parallel.
        let s = session(4);
        let tasks: Vec<TaskHandle> =
            (0..10).map(|i| s.create_task(Payload::Sleep { seconds: 1.0 + (i % 3) as f64 })).collect();
        let results = s.await_all(&tasks);
        assert_eq!(results.len(), 10);
        assert!(results.iter().all(|r| r.ok()));
        s.shutdown();
    }

    #[test]
    fn task_handles_are_sync() {
        // OnceLock-based handles can be shared by reference across
        // threads (the std::cell::Cell version was !Sync).
        fn assert_sync<T: Sync>() {}
        assert_sync::<TaskHandle>();
        let s = Arc::new(session(2));
        let t = Arc::new(s.create_task(Payload::Sleep { seconds: 1.0 }));
        let t2 = Arc::clone(&t);
        let joiner = std::thread::spawn(move || t2.id());
        let id_here = t.id();
        assert_eq!(joiner.join().unwrap(), id_here);
        s.await_task(&t);
        s.shutdown();
    }

    #[test]
    fn session_runs_over_deep_buffer_tree() {
        // The await-style API is runtime-agnostic: the same session works
        // when the scheduler runs a depth-3 buffer tree with stealing.
        let s = Session::start(
            SchedulerConfig {
                np: 8,
                consumers_per_buffer: 2, // 4 leaves
                depth: 3,
                fanout: vec![2],
                steal: true,
                flush_interval_ms: 2,
                ..Default::default()
            },
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        let tasks: Vec<TaskHandle> =
            (0..12).map(|i| s.create_task(Payload::Sleep { seconds: 1.0 + (i % 4) as f64 })).collect();
        let results = s.await_all(&tasks);
        assert_eq!(results.len(), 12);
        assert!(results.iter().all(|r| r.ok()));
        let report = s.shutdown();
        assert_eq!(report.results.len(), 12);
        // 4 leaves + 2 relays + 1 root relay.
        assert_eq!(report.node_stats.len(), 7);
        assert!(report.node_stats.iter().all(|st| st.saw_shutdown));
    }

    #[test]
    fn callback_chains_ten_more_tasks() {
        // §2.3 callback example: 10 tasks, each spawning one follow-up.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let s = session(4);
        let spawned = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<TaskHandle> = (0..10)
            .map(|i| {
                let counter = Arc::clone(&spawned);
                s.create_task_with_callback(
                    Payload::Sleep { seconds: (i % 3 + 1) as f64 },
                    Box::new(move |_r, h| {
                        h.create_task(Payload::Sleep { seconds: 1.0 });
                        counter.fetch_add(1, Ordering::SeqCst);
                    }),
                )
            })
            .collect();
        s.await_all(&tasks);
        let report = s.shutdown();
        assert_eq!(spawned.load(Ordering::SeqCst), 10);
        assert_eq!(report.results.len(), 20);
    }

    #[test]
    fn concurrent_activities_of_sequential_tasks() {
        // §2.3 async/await example: three concurrent activities, each
        // running five sequential tasks.
        let s = Arc::new(session(4));
        let mut activities = Vec::new();
        for n in 0..3u64 {
            let s2 = Arc::clone(&s);
            activities.push(std::thread::spawn(move || {
                let mut finishes = Vec::new();
                for t in 0..5u64 {
                    let task = s2.create_task(Payload::Sleep { seconds: ((t + n) % 3 + 1) as f64 });
                    let r = s2.await_task(&task);
                    finishes.push(r.finish);
                }
                // Sequential within the activity: finishes increase.
                for w in finishes.windows(2) {
                    assert!(w[1] >= w[0]);
                }
            }));
        }
        for a in activities {
            a.join().unwrap();
        }
        let report = Arc::try_unwrap(s).ok().map(|s| s.shutdown()).expect("sole owner");
        assert_eq!(report.results.len(), 15);
    }

    #[test]
    fn cancel_queued_tasks_resolves_waiters() {
        // One consumer; the first task occupies it long enough that the
        // rest are certainly still queued when the cancellations land.
        let s = Session::start(
            SchedulerConfig {
                np: 1,
                consumers_per_buffer: 1,
                flush_interval_ms: 2,
                time_scale: 0.02, // first task ≈ 200 ms real
                ..Default::default()
            },
            Arc::new(SleepExecutor { time_scale: 0.02 }),
        );
        let long = s.submit(JobSpec::sleep(10.0));
        let queued: Vec<TaskHandle> = (0..3).map(|_| s.submit(JobSpec::sleep(5.0))).collect();
        for t in &queued {
            s.cancel(t);
        }
        for t in &queued {
            let r = s.await_task(t);
            assert_eq!(r.rc, RC_CANCELLED, "queued task must be dropped");
            assert_eq!(s.status(t), JobStatus::Cancelled);
        }
        let r = s.await_task(&long);
        assert!(r.ok(), "running task is unaffected by other cancellations");
        assert_eq!(s.status(&long), JobStatus::Done);
        let report = s.shutdown();
        assert_eq!(report.results.len(), 4);
        assert_eq!(report.cancelled(), 3);
    }

    #[test]
    fn cancel_kills_running_task_without_consuming_retry() {
        // One consumer, real-time scale: uncancelled, the task would hold
        // the consumer for ~30 s. Cancelling it mid-flight must kill the
        // attempt within the executor's poll interval, resolve the waiter
        // with RC_CANCELLED, and leave the retry budget untouched.
        let s = Session::start(
            SchedulerConfig {
                np: 1,
                consumers_per_buffer: 1,
                flush_interval_ms: 2,
                ..Default::default()
            },
            Arc::new(SleepExecutor { time_scale: 1.0 }),
        );
        let t = s.submit(JobSpec::sleep(30.0).retries(3));
        // Give the scheduler ample time to dispatch it onto the consumer.
        std::thread::sleep(Duration::from_millis(300));
        s.cancel(&t);
        let t0 = std::time::Instant::now();
        let r = s.await_task(&t);
        assert_eq!(r.rc, RC_CANCELLED, "running attempt must be killed");
        assert_eq!(r.attempt, 0, "kill-on-cancel must not consume a retry");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "kill must land within the poll interval, not after the 30 s sleep"
        );
        assert_eq!(s.status(&t), JobStatus::Cancelled);
        let report = s.shutdown();
        assert_eq!(report.results.len(), 1);
        assert_eq!(report.cancelled(), 1);
        let killed: u64 = report.node_stats.iter().map(|st| st.cancelled_killed).sum();
        assert_eq!(killed, 1, "the leaf must have requested exactly one kill");
    }

    #[test]
    fn admission_bounds_per_class_in_flight() {
        use crate::tenancy::JobClass;
        // One registered class with quota 2: of six submissions, two are
        // accepted, two parked at the boundary, two rejected — the
        // scheduler-side in-flight count never exceeds the quota and the
        // backlog is bounded, not buffered without limit.
        let s = Session::start(
            SchedulerConfig {
                np: 2,
                consumers_per_buffer: 2,
                flush_interval_ms: 2,
                classes: vec![JobClass::new("quota", 1).quota(2)],
                ..Default::default()
            },
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        let mut accepted = Vec::new();
        let mut parked = Vec::new();
        let mut rejected = 0;
        for _ in 0..6 {
            let (d, h) = s.try_submit(JobSpec::sleep(50.0));
            match d {
                Admission::Accepted => accepted.push(h.expect("accepted jobs have handles")),
                Admission::Queued => parked.push(h.expect("parked jobs have handles")),
                Admission::Rejected => {
                    assert!(h.is_none(), "rejected jobs must not get a handle");
                    rejected += 1;
                }
            }
        }
        assert_eq!(accepted.len(), 2);
        assert_eq!(parked.len(), 2);
        assert_eq!(rejected, 2);
        assert_eq!(s.admission_load(0), (2, 2));
        // Everything admitted — parked included — still completes.
        for t in accepted.iter().chain(parked.iter()) {
            assert!(s.await_task(t).ok());
        }
        assert_eq!(s.admission_load(0), (0, 0));
        let report = s.shutdown();
        assert_eq!(report.results.len(), 4, "rejected jobs never entered the scheduler");
    }

    #[test]
    fn quota_blind_submit_parks_and_survives_close() {
        use crate::tenancy::JobClass;
        // The legacy `submit` never rejects: beyond quota 1 the rest park
        // at the boundary and drain one at a time. Closing the session
        // with work still parked must not lose it — the engine only
        // reports done when the boundary is empty.
        let s = Session::start(
            SchedulerConfig {
                np: 1,
                consumers_per_buffer: 1,
                flush_interval_ms: 2,
                classes: vec![JobClass::new("solo", 1).quota(1)],
                ..Default::default()
            },
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        let tasks: Vec<TaskHandle> = (0..5).map(|_| s.submit(JobSpec::sleep(5.0))).collect();
        let (in_flight, held) = s.admission_load(0);
        assert!(in_flight <= 1, "quota must bound scheduler-side in-flight");
        assert!(held >= 3, "the rest wait at the boundary");
        let report = s.shutdown();
        assert_eq!(report.results.len(), 5, "parked submissions drain before shutdown");
        drop(tasks);
    }

    #[test]
    fn await_any_returns_a_finished_task() {
        let s = session(2);
        let tasks: Vec<TaskHandle> = vec![
            s.submit(JobSpec::sleep(50.0)),
            s.submit(JobSpec::sleep(1.0)),
        ];
        let (idx, r) = s.await_any(&tasks);
        assert_eq!(idx, 1, "the short task finishes first");
        assert!(r.ok());
        s.await_task(&tasks[0]);
        s.shutdown();
    }
}
