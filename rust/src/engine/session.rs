//! The await-style client API of §2.3 — the Rust analogue of
//!
//! ```python
//! with Server.start():
//!     task = Task.create("sleep 1")
//!     Server.await_task(task)       # blocks until the task is finished
//! ```
//!
//! [`Session::start`] launches the hierarchical scheduler on a background
//! thread; any number of user threads ("concurrent activities", cf.
//! `Server.async`) may then create tasks and block on their results:
//!
//! ```no_run
//! use std::sync::Arc;
//! use caravan::config::SchedulerConfig;
//! use caravan::engine::Session;
//! use caravan::scheduler::SleepExecutor;
//! use caravan::tasklib::Payload;
//!
//! let session = Session::start(
//!     SchedulerConfig { np: 4, ..Default::default() },
//!     Arc::new(SleepExecutor { time_scale: 0.001 }),
//! );
//! let t = session.create_task(Payload::Sleep { seconds: 2.0 });
//! let result = session.await_task(&t);
//! assert_eq!(result.rc, 0);
//! session.shutdown();
//! ```
//!
//! Callbacks (`task.add_callback` in the Python API) are supported through
//! [`Session::create_task_with_callback`]; the callback runs on the
//! scheduler thread and may itself create tasks.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::SchedulerConfig;
use crate::scheduler::threads::{run_scheduler, Executor, Report};
use crate::tasklib::{Payload, SearchEngine, TaskId, TaskResult, TaskSink};

/// Callback invoked on the scheduler thread when a task completes. It may
/// submit follow-up tasks through the provided handle.
pub type Callback = Box<dyn FnOnce(&TaskResult, &SessionHandle) + Send>;

/// A created task: await it via [`Session::await_task`].
///
/// The task id is resolved lazily: creation does not block on the
/// scheduler thread (callbacks run *on* that thread and may create tasks —
/// blocking there would deadlock).
pub struct TaskHandle {
    id_rx: Receiver<TaskId>,
    id: std::cell::Cell<Option<TaskId>>,
    rx: Receiver<TaskResult>,
}

impl TaskHandle {
    /// The scheduler-assigned task id (blocks briefly on first call).
    pub fn id(&self) -> TaskId {
        if let Some(id) = self.id.get() {
            return id;
        }
        let id = self.id_rx.recv().expect("session closed");
        self.id.set(Some(id));
        id
    }
}

enum Ctl {
    Submit { payload: Payload, waiter: Sender<TaskResult>, reply: Sender<TaskId>, callback: Option<Callback> },
    Close,
}

/// Cloneable handle used inside callbacks to create further tasks.
#[derive(Clone)]
pub struct SessionHandle {
    ctl: Sender<Ctl>,
}

impl SessionHandle {
    pub fn create_task(&self, payload: Payload) -> TaskHandle {
        self.create_task_with(payload, None)
    }

    pub fn create_task_with_callback(&self, payload: Payload, cb: Callback) -> TaskHandle {
        self.create_task_with(payload, Some(cb))
    }

    fn create_task_with(&self, payload: Payload, callback: Option<Callback>) -> TaskHandle {
        let (wtx, wrx) = channel();
        let (rtx, rrx) = channel();
        self.ctl
            .send(Ctl::Submit { payload, waiter: wtx, reply: rtx, callback })
            .expect("session closed");
        TaskHandle { id_rx: rrx, id: std::cell::Cell::new(None), rx: wrx }
    }
}

/// The session engine: a [`SearchEngine`] that pulls submissions from the
/// control channel during `poll`.
struct SessionEngine {
    ctl_rx: Receiver<Ctl>,
    handle: SessionHandle,
    waiters: HashMap<TaskId, Sender<TaskResult>>,
    callbacks: HashMap<TaskId, Callback>,
    closed: bool,
}

impl SearchEngine for SessionEngine {
    fn start(&mut self, _sink: &mut dyn TaskSink) {}

    fn on_done(&mut self, result: &TaskResult, sink: &mut dyn TaskSink) {
        if let Some(cb) = self.callbacks.remove(&result.id) {
            cb(result, &self.handle);
            // The callback may have pushed submissions into the control
            // channel; drain them immediately so follow-up tasks are
            // scheduled without waiting for the next poll tick.
            self.drain(sink);
        }
        if let Some(w) = self.waiters.remove(&result.id) {
            let _ = w.send(result.clone());
        }
    }

    fn poll(&mut self, sink: &mut dyn TaskSink) -> bool {
        self.drain(sink);
        self.closed
    }
}

impl SessionEngine {
    fn drain(&mut self, sink: &mut dyn TaskSink) {
        while let Ok(msg) = self.ctl_rx.try_recv() {
            match msg {
                Ctl::Submit { payload, waiter, reply, callback } => {
                    let id = sink.submit(payload);
                    self.waiters.insert(id, waiter);
                    if let Some(cb) = callback {
                        self.callbacks.insert(id, cb);
                    }
                    let _ = reply.send(id);
                }
                Ctl::Close => {
                    self.closed = true;
                }
            }
        }
    }
}

/// A running scheduler session (the `Server.start()` context).
pub struct Session {
    handle: SessionHandle,
    thread: Mutex<Option<JoinHandle<Report>>>,
}

impl Session {
    /// Start the scheduler with `cfg` on a background thread.
    pub fn start(cfg: SchedulerConfig, executor: Arc<dyn Executor>) -> Session {
        let (ctl_tx, ctl_rx) = channel();
        let handle = SessionHandle { ctl: ctl_tx };
        let engine = SessionEngine {
            ctl_rx,
            handle: handle.clone(),
            waiters: HashMap::new(),
            callbacks: HashMap::new(),
            closed: false,
        };
        let thread = std::thread::Builder::new()
            .name("caravan-session".into())
            .spawn(move || run_scheduler(&cfg, Box::new(engine), executor))
            .expect("spawn session");
        Session { handle, thread: Mutex::new(Some(thread)) }
    }

    pub fn handle(&self) -> SessionHandle {
        self.handle.clone()
    }

    /// `Task.create` — submit a task.
    pub fn create_task(&self, payload: Payload) -> TaskHandle {
        self.handle.create_task(payload)
    }

    /// `task.add_callback` at creation time.
    pub fn create_task_with_callback(&self, payload: Payload, cb: Callback) -> TaskHandle {
        self.handle.create_task_with_callback(payload, cb)
    }

    /// `Server.await_task` — block until the task finishes.
    pub fn await_task(&self, task: &TaskHandle) -> TaskResult {
        task.rx.recv().expect("scheduler dropped the task")
    }

    /// `Server.await_all_tasks` over an explicit set.
    pub fn await_all(&self, tasks: &[TaskHandle]) -> Vec<TaskResult> {
        tasks.iter().map(|t| self.await_task(t)).collect()
    }

    /// End the session: no more submissions; waits for in-flight tasks and
    /// returns the scheduler report.
    pub fn shutdown(&self) -> Report {
        let _ = self.handle.ctl.send(Ctl::Close);
        let th = self.thread.lock().unwrap().take().expect("already shut down");
        th.join().expect("scheduler thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::SleepExecutor;

    fn session(np: usize) -> Session {
        Session::start(
            SchedulerConfig {
                np,
                consumers_per_buffer: 4,
                flush_interval_ms: 2,
                ..Default::default()
            },
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        )
    }

    #[test]
    fn await_single_task() {
        let s = session(2);
        let t = s.create_task(Payload::Sleep { seconds: 3.0 });
        let r = s.await_task(&t);
        assert_eq!(r.id, t.id());
        assert_eq!(r.results, vec![3.0]);
        let report = s.shutdown();
        assert_eq!(report.results.len(), 1);
    }

    #[test]
    fn paper_example_ten_tasks() {
        // §2.3 minimal program: ten tasks in parallel.
        let s = session(4);
        let tasks: Vec<TaskHandle> =
            (0..10).map(|i| s.create_task(Payload::Sleep { seconds: 1.0 + (i % 3) as f64 })).collect();
        let results = s.await_all(&tasks);
        assert_eq!(results.len(), 10);
        assert!(results.iter().all(|r| r.ok()));
        s.shutdown();
    }

    #[test]
    fn session_runs_over_deep_buffer_tree() {
        // The await-style API is runtime-agnostic: the same session works
        // when the scheduler runs a depth-3 buffer tree with stealing.
        let s = Session::start(
            SchedulerConfig {
                np: 8,
                consumers_per_buffer: 2, // 4 leaves
                depth: 3,
                fanout: 2,
                steal: true,
                flush_interval_ms: 2,
                ..Default::default()
            },
            Arc::new(SleepExecutor { time_scale: 0.001 }),
        );
        let tasks: Vec<TaskHandle> =
            (0..12).map(|i| s.create_task(Payload::Sleep { seconds: 1.0 + (i % 4) as f64 })).collect();
        let results = s.await_all(&tasks);
        assert_eq!(results.len(), 12);
        assert!(results.iter().all(|r| r.ok()));
        let report = s.shutdown();
        assert_eq!(report.results.len(), 12);
        // 4 leaves + 2 relays + 1 root relay.
        assert_eq!(report.node_stats.len(), 7);
        assert!(report.node_stats.iter().all(|st| st.saw_shutdown));
    }

    #[test]
    fn callback_chains_ten_more_tasks() {
        // §2.3 callback example: 10 tasks, each spawning one follow-up.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let s = session(4);
        let spawned = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<TaskHandle> = (0..10)
            .map(|i| {
                let counter = Arc::clone(&spawned);
                s.create_task_with_callback(
                    Payload::Sleep { seconds: (i % 3 + 1) as f64 },
                    Box::new(move |_r, h| {
                        h.create_task(Payload::Sleep { seconds: 1.0 });
                        counter.fetch_add(1, Ordering::SeqCst);
                    }),
                )
            })
            .collect();
        s.await_all(&tasks);
        let report = s.shutdown();
        assert_eq!(spawned.load(Ordering::SeqCst), 10);
        assert_eq!(report.results.len(), 20);
    }

    #[test]
    fn concurrent_activities_of_sequential_tasks() {
        // §2.3 async/await example: three concurrent activities, each
        // running five sequential tasks.
        let s = Arc::new(session(4));
        let mut activities = Vec::new();
        for n in 0..3u64 {
            let s2 = Arc::clone(&s);
            activities.push(std::thread::spawn(move || {
                let mut finishes = Vec::new();
                for t in 0..5u64 {
                    let task = s2.create_task(Payload::Sleep { seconds: ((t + n) % 3 + 1) as f64 });
                    let r = s2.await_task(&task);
                    finishes.push(r.finish);
                }
                // Sequential within the activity: finishes increase.
                for w in finishes.windows(2) {
                    assert!(w[1] >= w[0]);
                }
            }));
        }
        for a in activities {
            a.join().unwrap();
        }
        let report = Arc::try_unwrap(s).ok().map(|s| s.shutdown()).expect("sole owner");
        assert_eq!(report.results.len(), 15);
    }
}
